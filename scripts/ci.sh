#!/usr/bin/env bash
# Tier-1 CI: bytecode-compile the whole tree, then the repo's canonical test
# command (ROADMAP.md "Tier-1 verify"). Run from the repo root.
set -euo pipefail
cd "$(dirname "$0")/.."

python -m compileall -q src benchmarks examples scripts
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"

# docs stay honest: the EXPERIMENTS.md tables must be exactly what the
# committed BENCH_*.json artifacts render to, and every markdown link /
# anchor in README / EXPERIMENTS / docs/ must resolve
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python scripts/render_experiments.py --check
python scripts/check_links.py

# fast-mode smoke of the async-staleness benchmark artifact path (temp dir:
# the committed BENCH_async.json is the paper-scale sweep, not this smoke)
SMOKE_DIR="$(mktemp -d)"
trap 'rm -rf "$SMOKE_DIR"' EXIT
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.bench_async \
  --rounds 200 --threshold 1e-3 --policy-rounds 200 \
  --json "$SMOKE_DIR/BENCH_async.json"
python -c "import json, sys; d = json.load(open(sys.argv[1])); \
assert d['staleness'], 'empty async sweep'; \
assert d['policy_rescue'], 'empty policy sweep'" \
  "$SMOKE_DIR/BENCH_async.json"
