#!/usr/bin/env bash
# Tier-1 CI: bytecode-compile the whole tree, then the repo's canonical test
# command (ROADMAP.md "Tier-1 verify"). Run from the repo root.
set -euo pipefail
cd "$(dirname "$0")/.."

python -m compileall -q src benchmarks examples scripts
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"

# docs stay honest: the EXPERIMENTS.md tables must be exactly what the
# committed BENCH_*.json artifacts render to, and every markdown link /
# anchor in README / EXPERIMENTS / docs/ must resolve
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python scripts/render_experiments.py --check
python scripts/check_links.py

# multi-device section: the sharding/collective tests on a fake 8-device
# mesh, including the HLO wire-dtype assertions and the neural-player
# two-axis mesh cases (they skip on one device, so running them WITHOUT
# this flag would silently drop the acceptance pin)
XLA_FLAGS="${XLA_FLAGS:+$XLA_FLAGS }--xla_force_host_platform_device_count=8" \
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
  python -m pytest -x -q tests/test_collective.py tests/test_sharding.py \
  tests/test_lowbit_sync.py tests/test_async_mesh.py \
  tests/test_selection.py tests/test_pearl_trainer.py tests/test_neural.py

# fast-mode smokes of every --json benchmark artifact path (temp dir: the
# committed BENCH_*.json are the paper-scale sweeps, not these smokes)
SMOKE_DIR="$(mktemp -d)"
trap 'rm -rf "$SMOKE_DIR"' EXIT
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.bench_async \
  --rounds 200 --threshold 1e-3 --policy-rounds 200 \
  --json "$SMOKE_DIR/BENCH_async.json"
python -c "import json, sys; d = json.load(open(sys.argv[1])); \
assert d['staleness'], 'empty async sweep'; \
assert d['policy_rescue'], 'empty policy sweep'" \
  "$SMOKE_DIR/BENCH_async.json"

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.bench_engine \
  --rounds 100 --topology-rounds 200 --policy-rounds 100 \
  --json "$SMOKE_DIR/BENCH_engine.json"
python -c "import json, sys; d = json.load(open(sys.argv[1])); \
assert d['matrix'], 'empty engine matrix'; \
assert d['topology'], 'empty topology sweep'; \
assert d['gossip_policy'], 'empty gossip policy sweep'" \
  "$SMOKE_DIR/BENCH_engine.json"

# the collective wire sweep needs the fake mesh; its in-benchmark asserts
# re-verify the 2-byte wire and the exact bf16-vs-f32 byte halving
XLA_FLAGS="${XLA_FLAGS:+$XLA_FLAGS }--xla_force_host_platform_device_count=8" \
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
  python -m benchmarks.bench_collective --rounds 100 \
  --json "$SMOKE_DIR/BENCH_collective.json"
python -c "import json, sys; d = json.load(open(sys.argv[1])); \
assert d['wire'], 'empty wire sweep (no fake mesh?)'; \
assert d['parity'], 'empty parity sweep'; \
assert all(r['compressed_wire'] for r in d['wire'] if r['sync'] == 'bf16'), \
'bf16 wire not compressed in compiled HLO'" \
  "$SMOKE_DIR/BENCH_collective.json"

# wall-clock smoke on the same fake mesh: seconds are machine-local noise
# at CI scale, but the matrix must be non-empty, the async D=0 path must
# stay bit-for-bit on lockstep, and the int8/int4 collectives must carry
# u8 operands in the compiled HLO (the drift check re-pins the byte fields
# against the committed artifact and schema-checks the seconds)
XLA_FLAGS="${XLA_FLAGS:+$XLA_FLAGS }--xla_force_host_platform_device_count=8" \
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
  python -m benchmarks.bench_wallclock \
  --rounds 100 --timed-rounds 4 --warmup 1 --repeats 2 \
  --json "$SMOKE_DIR/BENCH_wallclock.json"
python -c "import json, sys; d = json.load(open(sys.argv[1])); \
assert d['rows'], 'empty wall-clock matrix'; \
assert all(r['d0_bitwise_equal'] for r in d['parity']), \
'async D=0 drifted from lockstep'; \
assert all(w['compressed_wire_dtypes'] == ['u8'] \
for w in d['wire'] if w['sync'] in ('int8', 'int4')), \
'low-bit wire not u8 in compiled HLO'" \
  "$SMOKE_DIR/BENCH_wallclock.json"
python scripts/check_bench_drift.py \
  "$SMOKE_DIR/BENCH_wallclock.json" BENCH_wallclock.json

# neural players end to end on the fake two-axis mesh: the smoke runs the
# SAME rounds as the committed artifact (losses drift-compare at tolerance,
# bytes and wire dtypes exactly; seconds schema-only). The in-benchmark
# asserts re-verify the compiled sync gather dtype per wire and the
# predicted uplink byte ratios
XLA_FLAGS="${XLA_FLAGS:+$XLA_FLAGS }--xla_force_host_platform_device_count=8" \
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
  python -m benchmarks.bench_neural --rounds 6 --repeats 1 \
  --json "$SMOKE_DIR/BENCH_neural.json"
python -c "import json, sys; d = json.load(open(sys.argv[1])); \
assert d['rows'], 'empty neural matrix (no fake mesh?)'; \
assert {w['sync']: w['compressed_gather_dtypes'] for w in d['wire']} \
== {'exact': [], 'bf16': ['u16'], 'int8_ef': ['u8']}, \
'neural sync wire not at the claimed dtype in compiled HLO'" \
  "$SMOKE_DIR/BENCH_neural.json"
python scripts/check_bench_drift.py \
  "$SMOKE_DIR/BENCH_neural.json" BENCH_neural.json

# selection-policy smoke: the deterministic sweeps replay the committed
# trajectories at a reduced budget, so the acceptance headline — greedy
# bytes-to-eq strictly no worse than the uniform control at the same
# fraction — is re-asserted on every push, and the drift check pins the
# mask-driven byte accounting against the committed artifact
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.bench_selection \
  --rounds 250 --mean-field-rounds 100 --staleness-rounds 100 \
  --json "$SMOKE_DIR/BENCH_selection.json"
python -c "import json, sys; d = json.load(open(sys.argv[1])); \
rows = {r['policy']: r for r in d['selection']}; \
assert rows, 'empty selection sweep'; \
g, u = rows['greedy_shapley']['bytes_to_eq'], rows['uniform']['bytes_to_eq']; \
assert g is not None and u is not None, 'selection sweep missed threshold'; \
assert g <= u, f'greedy bytes-to-eq {g} worse than uniform {u}'; \
assert d['mean_field'] and d['staleness'], 'empty composition sweeps'" \
  "$SMOKE_DIR/BENCH_selection.json"
python scripts/check_bench_drift.py \
  "$SMOKE_DIR/BENCH_selection.json" BENCH_selection.json

# incentive-layer smoke: the free-rider collapse must hold exactly (a
# price at or below the cheapest cost moves ZERO uplink bytes at any
# budget) and the best-response masks must replay the committed byte
# accounting; realized participation is feedback-dependent and checked
# by the drift spec's closed-form-rate tolerance instead
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.bench_incentives \
  --rounds 250 --collapse-rounds 100 \
  --json "$SMOKE_DIR/BENCH_incentives.json"
python -c "import json, sys; d = json.load(open(sys.argv[1])); \
assert d['price_sweep'], 'empty price sweep'; \
assert all(r['collapsed'] and r['bytes_up_total'] == 0 \
for r in d['collapse']), 'free-rider collapse not exact'; \
rows = {r['scheme']: r for r in d['vs_greedy']}; \
assert rows['best_response_aligned']['bytes_to_eq'] is not None, \
'aligned incentive coalition missed threshold'; \
assert rows['best_response_misaligned']['rounds_to_eq'] is None, \
'misaligned coalition unexpectedly converged'" \
  "$SMOKE_DIR/BENCH_incentives.json"
python scripts/check_bench_drift.py \
  "$SMOKE_DIR/BENCH_incentives.json" BENCH_incentives.json

# million-player scaling smoke: the n = 10^6 mean-field row must actually
# run, and its per-player downlink must equal the n = 10^2 row's (the O(d)
# wire is flat in n — the tentpole claim); the drift check then pins every
# byte/state field against the committed artifact
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.bench_scaling \
  --json "$SMOKE_DIR/BENCH_scaling.json"
python -c "import json, sys; d = json.load(open(sys.argv[1])); \
mf = {r['n']: r for r in d['mean_field']}; \
assert 1000000 in mf, 'n=10^6 mean-field row missing'; \
assert mf[1000000]['bytes_down_per_player'] \
== mf[100]['bytes_down_per_player'], 'per-player downlink not flat in n'; \
assert mf[1000000]['ref_state_bytes_per_player'] \
== mf[100]['ref_state_bytes_per_player'], 'per-player state not flat in n'; \
assert d['exact'] and d['gap'], 'empty exact/gap sweep'" \
  "$SMOKE_DIR/BENCH_scaling.json"
python scripts/check_bench_drift.py \
  "$SMOKE_DIR/BENCH_scaling.json" BENCH_scaling.json
