#!/usr/bin/env bash
# Tier-1 CI: bytecode-compile the whole tree, then the repo's canonical test
# command (ROADMAP.md "Tier-1 verify"). Run from the repo root.
set -euo pipefail
cd "$(dirname "$0")/.."

python -m compileall -q src benchmarks examples scripts
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
