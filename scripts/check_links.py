"""Markdown link-and-anchor checker for the repo's documentation.

Scans the given markdown files (default: README.md, EXPERIMENTS.md,
CHANGES.md, ROADMAP.md and everything under docs/) for inline links
``[text](target)`` and reference definitions ``[label]: target`` and fails
loudly when

- a relative file target does not exist (resolved against the linking
  file's directory),
- an anchored target (``path#heading`` or ``#heading``) names a heading
  that does not exist in the target file (GitHub slugification: lowercase,
  spaces to dashes, punctuation stripped, duplicate slugs suffixed -1, -2,
  ...),

while external schemes (http/https/mailto) are recorded but not fetched —
CI must not depend on the network. Exits non-zero iff any link is broken
(the count is printed, not used as the status — 256 broken links must not
wrap to a green exit), so ``python scripts/check_links.py`` composes with
``set -e`` in scripts/ci.sh.
"""

from __future__ import annotations

import os
import re
import sys

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

DEFAULT_FILES = ["README.md", "EXPERIMENTS.md", "CHANGES.md", "ROADMAP.md"]

# [text](target) — skips images' leading ! lazily (images use the same
# resolution rules) and tolerates titles: [t](path "title")
INLINE = re.compile(r"\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
REFDEF = re.compile(r"^\s{0,3}\[[^\]]+\]:\s+(\S+)", re.M)
HEADING = re.compile(r"^(#{1,6})\s+(.*?)\s*#*\s*$", re.M)
CODE_FENCE = re.compile(r"```.*?```", re.S)
INLINE_CODE = re.compile(r"`[^`\n]*`")


def github_slug(heading: str) -> str:
    """GitHub's anchor slug for a heading (ASCII-ish approximation that is
    exact for this repo's headings)."""
    h = re.sub(r"`([^`]*)`", r"\1", heading)          # strip code spans
    h = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", h)    # link text only
    h = h.strip().lower()
    h = re.sub(r"[^\w\- ]", "", h, flags=re.UNICODE)  # drop punctuation
    return h.replace(" ", "-")


def slugs_of(path: str) -> set[str]:
    with open(path, encoding="utf-8") as f:
        text = CODE_FENCE.sub("", f.read())
    seen: dict[str, int] = {}
    out = set()
    for m in HEADING.finditer(text):
        s = github_slug(m.group(2))
        n = seen.get(s, 0)
        seen[s] = n + 1
        out.add(s if n == 0 else f"{s}-{n}")
    return out


def targets_in(path: str) -> list[str]:
    with open(path, encoding="utf-8") as f:
        text = f.read()
    text = CODE_FENCE.sub("", text)
    text = INLINE_CODE.sub("", text)
    return INLINE.findall(text) + REFDEF.findall(text)


def check_file(path: str) -> list[str]:
    errors = []
    base = os.path.dirname(path)
    for target in targets_in(path):
        if re.match(r"^[a-z][a-z0-9+.-]*:", target):   # http:, mailto:, ...
            continue
        rel = os.path.relpath(path, ROOT)
        frag = None
        if "#" in target:
            target, frag = target.split("#", 1)
        dest = path if not target else os.path.normpath(
            os.path.join(base, target))
        if not os.path.exists(dest):
            errors.append(f"{rel}: broken link -> {target or '#' + frag}")
            continue
        if frag is not None:
            if not dest.endswith((".md", ".markdown")):
                continue   # anchors into non-markdown are out of scope
            if github_slug(frag) not in slugs_of(dest):
                errors.append(
                    f"{rel}: missing anchor -> "
                    f"{os.path.relpath(dest, ROOT)}#{frag}")
    return errors


def main(argv: list[str]) -> int:
    files = [os.path.join(ROOT, f) for f in (argv or DEFAULT_FILES)]
    docs = os.path.join(ROOT, "docs")
    if not argv and os.path.isdir(docs):
        for dirpath, _, names in sorted(os.walk(docs)):
            files += sorted(
                os.path.join(dirpath, f) for f in names if f.endswith(".md")
            )
    errors = []
    checked = 0
    for f in files:
        if not os.path.exists(f):
            continue
        checked += 1
        errors.extend(check_file(f))
    for e in errors:
        print(f"BROKEN: {e}", file=sys.stderr)
    print(f"checked {checked} files, {len(errors)} broken link(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
