"""Render the Dry-run and Roofline tables of EXPERIMENTS.md from the dry-run
JSON records (idempotent: replaces content between the AUTO markers).

    PYTHONPATH=src python scripts/render_experiments.py
"""

from __future__ import annotations

import json
import os
import re

ROOT = os.path.join(os.path.dirname(__file__), "..")
MD = os.path.join(ROOT, "EXPERIMENTS.md")
SINGLE = os.path.join(ROOT, "experiments", "dryrun_singlepod.json")
MULTI = os.path.join(ROOT, "experiments", "dryrun_multipod.json")

BEGIN = "<!-- AUTO-DRYRUN-BEGIN -->"
END = "<!-- AUTO-DRYRUN-END -->"


def _load(path):
    if not os.path.exists(path):
        return []
    with open(path) as f:
        return json.load(f)


def _fmt_bytes(b):
    if b >= 1e9:
        return f"{b / 1e9:.2f}G"
    if b >= 1e6:
        return f"{b / 1e6:.1f}M"
    return f"{b / 1e3:.0f}K"


def render() -> str:
    single = _load(SINGLE)
    multi = _load(MULTI)
    lines = []

    lines.append("### Dry-run summary (compile proof, both meshes)\n")
    ok_s = [r for r in single if "error" not in r]
    ok_m = [r for r in multi if "error" not in r]
    lines.append(f"- single-pod 16x16 (256 chips): **{len(ok_s)}/{len(single)}"
                 "** combos lowered + compiled")
    lines.append(f"- multi-pod 2x16x16 (512 chips): **{len(ok_m)}/{len(multi)}"
                 "** combos lowered + compiled")
    for r in single + multi:
        if "error" in r:
            lines.append(f"  - FAIL {r['arch']}/{r['shape']}/{r['mesh']}: "
                         f"{r['error'][:120]}")
    lines.append("")

    lines.append("### Multi-pod lowering proof (2x16x16, per-combo)\n")
    lines.append("| arch | shape | kind | peak mem/dev | collective ops | "
                 "compile s |")
    lines.append("|---|---|---|---|---|---|")
    for r in ok_m:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['kind']} | "
            f"{_fmt_bytes(r['peak_memory_bytes'])} | {r['collective_ops']} | "
            f"{r['compile_s']} |")
    lines.append("")

    lines.append("### Roofline table — single-pod 16x16, trip-count-corrected "
                 "(Section Roofline)\n")
    lines.append("All terms in seconds per step, per-chip convention "
                 "(197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s ICI). "
                 "`useful` = MODEL_FLOPS / HLO_FLOPs.\n")
    lines.append("| arch | shape | compute s | memory s | collective s | "
                 "bottleneck | useful | peak mem/dev | what would move the "
                 "dominant term |")
    lines.append("|---|---|---|---|---|---|---|---|---|")
    suggestions = {
        ("memory", "train"): "flash/fused attention keeps S^2 scores in VMEM; "
                             "bf16 master-grad copies",
        ("memory", "prefill"): "flash attention kernel (kernels/) removes "
                               "S^2 HBM traffic",
        ("memory", "decode"): "KV-cache layout/quantization; batch more "
                              "requests per chip",
        ("collective", "train"): "shard or replicate to kill activation "
                                 "all-reduces; overlap grad reduce",
        ("collective", "prefill"): "reduce tensor-parallel span; all-to-all "
                                   "scheduling for MoE",
        ("collective", "decode"): "replicate small weights; duplicate KV "
                                  "heads per chip",
        ("compute", "train"): "remat policy (drop cheap ops only); MXU-"
                              "aligned tiles",
        ("compute", "prefill"): "MXU-aligned flash tiles",
        ("compute", "decode"): "speculative/multi-token decode",
    }
    for r in ok_s:
        mode = ("train" if r["shape"] == "train_4k"
                else "prefill" if r["shape"] == "prefill_32k" else "decode")
        sug = suggestions.get((r["bottleneck"], mode), "")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
            f"**{r['bottleneck']}** | {r['useful_flops_ratio']:.2f} | "
            f"{_fmt_bytes(r['peak_memory_bytes'])} | {sug} |")
    lines.append("")
    return "\n".join(lines)


def main():
    block = render()
    with open(MD) as f:
        text = f.read()
    pattern = re.compile(re.escape(BEGIN) + ".*?" + re.escape(END), re.S)
    new = pattern.sub(BEGIN + "\n" + block + "\n" + END, text)
    with open(MD, "w") as f:
        f.write(new)
    print(f"rendered {MD}")


if __name__ == "__main__":
    main()
