"""Regenerate the EXPERIMENTS.md benchmark tables from the committed
``BENCH_*.json`` artifacts, so the documented numbers cannot silently drift
from the benchmark data (scripts/ci.sh renders and then requires
``git diff --exit-code EXPERIMENTS.md``).

Idempotent: replaces the content between each pair of AUTO markers

    <!-- AUTO-BENCH-STALENESS-BEGIN --> ... <!-- AUTO-BENCH-STALENESS-END -->
    <!-- AUTO-BENCH-POLICY-BEGIN -->    ... <!-- AUTO-BENCH-POLICY-END -->
    <!-- AUTO-BENCH-GOSSIP-BEGIN -->    ... <!-- AUTO-BENCH-GOSSIP-END -->

and leaves the surrounding prose alone. Missing artifacts render an explicit
"(artifact missing)" stub rather than stale numbers.

    PYTHONPATH=src python scripts/render_experiments.py [--check]

``--check`` exits non-zero if rendering would change EXPERIMENTS.md (for CI
without relying on git state). This replaced the seed's dead dry-run-table
renderer (its ``experiments/dryrun_*.json`` inputs never shipped).
"""

from __future__ import annotations

import json
import os
import re
import sys

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
MD = os.path.join(ROOT, "EXPERIMENTS.md")
ASYNC = os.path.join(ROOT, "BENCH_async.json")
ENGINE = os.path.join(ROOT, "BENCH_engine.json")
COLLECTIVE = os.path.join(ROOT, "BENCH_collective.json")
WALLCLOCK = os.path.join(ROOT, "BENCH_wallclock.json")
SCALING = os.path.join(ROOT, "BENCH_scaling.json")
NEURAL = os.path.join(ROOT, "BENCH_neural.json")
SELECTION = os.path.join(ROOT, "BENCH_selection.json")
INCENTIVES = os.path.join(ROOT, "BENCH_incentives.json")


def _load(path):
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def _kb(b):
    if b is None:
        return "—"
    if b >= 1e6:
        return f"{b / 1e6:.2f} MB"
    return f"{b / 1e3:.1f} KB"


def _err(row):
    if row.get("diverged"):
        return "**diverges**"
    return f"{row['final_rel_error']:.1e}"


def _rounds(row):
    return "—" if row["rounds_to_eq"] is None else str(row["rounds_to_eq"])


def render_staleness(data) -> str:
    if data is None:
        return "*(BENCH_async.json artifact missing — run the benchmark)*"
    lines = [
        "| schedule | D | rounds-to-eq | bytes-to-eq | mean staleness |",
        "|---|---|---|---|---|",
    ]
    seen_lockstep = False
    for r in data["staleness"]:
        if r["max_staleness"] == 0:
            # every schedule's D=0 row IS the lockstep run (the bit-for-bit
            # pin), so render it once instead of once per schedule
            if seen_lockstep:
                continue
            seen_lockstep = True
            sched = "(lockstep)"
        else:
            sched = r["schedule"]
        lines.append(
            f"| {sched} | {r['max_staleness']} | {_rounds(r)} | "
            f"{_kb(r['bytes_to_eq'])} | {r['mean_staleness']:.2f} |")
    return "\n".join(lines)


def render_policy(data) -> str:
    if data is None or "policy_rescue" not in data:
        return "*(BENCH_async.json policy_rescue sweep missing — run the " \
               "benchmark)*"
    lines = [
        "| policy | D | rounds-to-eq | final rel. error |",
        "|---|---|---|---|",
    ]
    for r in data["policy_rescue"]:
        lines.append(
            f"| {r['policy']} | {r['max_staleness']} | {_rounds(r)} | "
            f"{_err(r)} |")
    return "\n".join(lines)


def render_gossip(data) -> str:
    if data is None or "gossip_policy" not in data:
        return "*(BENCH_engine.json gossip_policy sweep missing — run the " \
               "benchmark)*"
    lines = [
        "| update | policy | gossip_steps | rounds-to-eq | bytes-to-eq | "
        "final rel. error |",
        "|---|---|---|---|---|---|",
    ]
    for r in data["gossip_policy"]:
        lines.append(
            f"| {r['update']} | {r['policy']} | {r['gossip_steps']} | "
            f"{_rounds(r)} | {_kb(r['bytes_to_eq'])} | {_err(r)} |")
    return "\n".join(lines)


def render_wire(data) -> str:
    if data is None or not data.get("wire"):
        return "*(BENCH_collective.json wire sweep missing — run the " \
               "benchmark under XLA_FLAGS="\
               "--xla_force_host_platform_device_count=8)*"
    lines = [
        "| collective | sync | HLO ops | operand dtypes | wire bytes/round |",
        "|---|---|---|---|---|",
    ]
    for r in data["wire"]:
        lines.append(
            f"| {r['collective']} | {r['sync']} | "
            f"{', '.join(r['wire_ops'])} | "
            f"{', '.join(r['wire_dtypes'])} | "
            f"{r['wire_bytes_per_round']} |")
    return "\n".join(lines)


def render_wire_parity(data) -> str:
    if data is None or not data.get("parity"):
        return "*(BENCH_collective.json parity sweep missing — run the " \
               "benchmark)*"
    lines = [
        "| topology | sync | host rel. error | mesh rel. error | "
        "max final drift |",
        "|---|---|---|---|---|",
    ]
    for r in data["parity"]:
        lines.append(
            f"| {r['topology']} | {r['sync']} | {r['host_rel_error']:.1e} | "
            f"{r['mesh_rel_error']:.1e} | {r['max_final_drift']:.1e} |")
    return "\n".join(lines)


def _sec(v):
    if v is None:
        return "—"
    return f"{v * 1e3:.1f} ms" if v < 1.0 else f"{v:.2f} s"


def render_wallclock(data) -> str:
    if data is None or not data.get("rows"):
        return "*(BENCH_wallclock.json artifact missing — run " \
               "`python -m benchmarks.run --wallclock --json " \
               "BENCH_wallclock.json` on a multi-device host)*"
    lines = [
        "| sync | engine | D | bytes/round | rounds-to-eq | bytes-to-eq | "
        "sec/round (med) | sec/round (p90) | sec-to-eq |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in data["rows"]:
        lines.append(
            f"| {r['sync']} | {r['engine']} | {r['max_staleness']} | "
            f"{_kb(r['bytes_per_round'])} | {_rounds(r)} | "
            f"{_kb(r['bytes_to_eq'])} | {_sec(r['sec_per_round_median'])} | "
            f"{_sec(r['sec_per_round_p90'])} | {_sec(r['sec_to_eq'])} |")
    timing = data.get("timing", {})
    lines.append(
        f"\n*Timed over {timing.get('repeats', '?')} repeats of "
        f"{timing.get('timed_rounds', '?')} rounds each "
        f"({data.get('device_count', '?')} devices, "
        f"tcmalloc={'yes' if timing.get('tcmalloc') else 'no'}); "
        f"equilibrium threshold {data.get('eq_threshold', '?')} on the "
        f"relative error. Seconds are machine-local: the drift checker "
        f"pins the byte columns exactly and only schema-checks timings.*")
    return "\n".join(lines)


def render_scaling(data) -> str:
    if data is None or not data.get("mean_field"):
        return "*(BENCH_scaling.json artifact missing — run the benchmark)*"
    lines = [
        "| view | n | down B/player/round | ref state B/player | "
        "rounds-to-eq | final rel. error |",
        "|---|---|---|---|---|---|",
    ]
    for label, section in (("mean-field", "mean_field"),
                           ("exact joint", "exact")):
        for r in data.get(section, []):
            lines.append(
                f"| {label} | {r['n']:,} | {r['bytes_down_per_player']:,} | "
                f"{r['ref_state_bytes_per_player']:,} | {_rounds(r)} | "
                f"{r['final_rel_error']:.1e} |")
    lines += [
        "",
        "What the O(d) summary costs in accuracy (the ``gap`` sweep — "
        "closed-form equilibrium distance and the converged uncorrected "
        "run, both shrinking as O(1/(n-1)); the self-corrected view "
        "matches the exact engine at every n):",
        "",
        "| n | closed-form gap | converged run gap | "
        "self-corrected == exact |",
        "|---|---|---|---|",
    ]
    for r in data.get("gap", []):
        lines.append(
            f"| {r['n']:,} | {r['closed_form_gap']:.1e} | "
            f"{r['run_gap']:.1e} | "
            f"{'yes' if r['corrected_matches_exact'] else '**NO**'} |")
    return "\n".join(lines)


def render_neural(data) -> str:
    if data is None or not data.get("rows"):
        return "*(BENCH_neural.json artifact missing — run " \
               "`python benchmarks/bench_neural.py --json " \
               "BENCH_neural.json` on a multi-device host)*"
    wire = {w["sync"]: w for w in data.get("wire", [])}
    roof = {(r["sync"], r["tau"]): r for r in data.get("roofline", [])}
    lines = [
        "| sync | tau | bytes/round | loss (first → final) | rounds-to-eq | "
        "bytes-to-eq | wire gather | ICI s/local step |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in data["rows"]:
        w = wire.get(r["sync"], {})
        gather = ", ".join(w.get("compressed_gather_dtypes", [])) or "f32"
        ici = roof.get((r["sync"], r["tau"]), {}).get("ici_s_per_local_step")
        lines.append(
            f"| {r['sync']} | {r['tau']} | {_kb(r['bytes_per_round'])} | "
            f"{r['loss_first']:.4f} → {r['loss_final']:.4f} | "
            f"{_rounds(r)} | {_kb(r['bytes_to_eq'])} | {gather} | "
            f"{'—' if ici is None else f'{ici:.2e}'} |")
    lines.append(
        f"\n*{data.get('n_players', '?')} × {data.get('arch', '?')} players "
        f"on the two-axis (players × model) mesh "
        f"({data.get('device_count', '?')} devices), Pallas kernels on; "
        f"loss target {data.get('loss_target', '?')}. The wire-gather "
        f"column is the compiled player-axis all-gather operand dtype "
        f"(dry-run HLO); ICI seconds are the billed bytes at the "
        f"production-mesh link bandwidth (`launch/perf.py`'s pod-collective "
        f"term) — per LOCAL step they fall tau-fold, Theorem 3.4 as wire "
        f"time. Seconds columns in the artifact are machine-local and "
        f"schema-checked only.*")
    return "\n".join(lines)


def render_selection(data) -> str:
    if data is None or not data.get("selection"):
        return "*(BENCH_selection.json artifact missing — run the benchmark)*"
    lines = [
        "| policy | fraction | rounds-to-eq | bytes-to-eq | "
        "final rel. error |",
        "|---|---|---|---|---|",
    ]
    for r in data["selection"]:
        lines.append(
            f"| {r['policy']} | {r['fraction']} | {_rounds(r)} | "
            f"{_kb(r['bytes_to_eq'])} | {_err(r)} |")
    lines += [
        "",
        "Composed with the sampled mean-field view "
        "(``MeanFieldView(sample=k)``, the one mask-compatible summary "
        "mode) and, below that, with strong-coupling stragglers — the "
        "honest negative: deterministic value-driven masks act like "
        "adversarial staleness at strong coupling, and even the "
        "delay-adaptive step-size policy cannot rescue them:",
        "",
        "| sweep | policy | step-size policy | rounds-to-eq | "
        "final rel. error |",
        "|---|---|---|---|---|",
    ]
    for r in data.get("mean_field", []):
        lines.append(
            f"| mean-field (n={r['n']}, sample={r['sample']}) | "
            f"{r['policy']} | theorem34 | {_rounds(r)} | {_err(r)} |")
    for r in data.get("staleness", []):
        lines.append(
            f"| straggler D={r['max_staleness']} | {r['policy']} | "
            f"{r['stepsize_policy']} | {_rounds(r)} | {_err(r)} |")
    return "\n".join(lines)


def render_incentives(data) -> str:
    if data is None or not data.get("price_sweep"):
        return ("*(BENCH_incentives.json artifact missing — run the "
                "benchmark)*")
    lines = [
        "| price | closed-form rate s\\* | realized rate | rounds-to-eq | "
        "bytes-to-eq |",
        "|---|---|---|---|---|",
    ]
    for r in data["price_sweep"]:
        lines.append(
            f"| {r['price']} | {r['closed_form_rate']:.2f} | "
            f"{r['realized_participation']:.2f} | {_rounds(r)} | "
            f"{_kb(r['bytes_to_eq'])} |")
    lines += [
        "",
        "The free-rider cliff (the honest negative: a price at or below "
        "the cheapest cost empties the coalition before the first sync — "
        "zero bytes move at ANY budget):",
        "",
        "| price | collapsed | total uplink bytes | final rel. error |",
        "|---|---|---|---|",
    ]
    for r in data.get("collapse", []):
        lines.append(
            f"| {r['price']} | {r['collapsed']} | {r['bytes_up_total']} | "
            f"{_err(r)} |")
    lines += [
        "",
        "Incentive coalition vs the value-driven greedy mask at the same "
        "realized budget (k = 2 of 10): payments route by COST, greedy by "
        "VALUE — the pair brackets what a price can and cannot buy:",
        "",
        "| scheme | rounds-to-eq | bytes-to-eq | final rel. error |",
        "|---|---|---|---|",
    ]
    for r in data.get("vs_greedy", []):
        lines.append(
            f"| {r['scheme']} | {_rounds(r)} | {_kb(r['bytes_to_eq'])} | "
            f"{_err(r)} |")
    return "\n".join(lines)


SECTIONS = {
    "AUTO-BENCH-STALENESS": lambda: render_staleness(_load(ASYNC)),
    "AUTO-BENCH-POLICY": lambda: render_policy(_load(ASYNC)),
    "AUTO-BENCH-GOSSIP": lambda: render_gossip(_load(ENGINE)),
    "AUTO-BENCH-WIRE": lambda: render_wire(_load(COLLECTIVE)),
    "AUTO-BENCH-WIRE-PARITY": lambda: render_wire_parity(_load(COLLECTIVE)),
    "AUTO-BENCH-WALLCLOCK": lambda: render_wallclock(_load(WALLCLOCK)),
    "AUTO-BENCH-SCALING": lambda: render_scaling(_load(SCALING)),
    "AUTO-BENCH-NEURAL": lambda: render_neural(_load(NEURAL)),
    "AUTO-BENCH-SELECTION": lambda: render_selection(_load(SELECTION)),
    "AUTO-BENCH-INCENTIVES": lambda: render_incentives(_load(INCENTIVES)),
}


def render(text: str) -> str:
    for tag, make in SECTIONS.items():
        begin, end = f"<!-- {tag}-BEGIN -->", f"<!-- {tag}-END -->"
        if begin not in text or end not in text:
            raise SystemExit(
                f"EXPERIMENTS.md is missing the {begin} / {end} markers — "
                f"the rendered tables have nowhere to go")
        pattern = re.compile(re.escape(begin) + ".*?" + re.escape(end), re.S)
        text = pattern.sub(begin + "\n" + make() + "\n" + end, text)
    return text


def main() -> None:
    check = "--check" in sys.argv[1:]
    with open(MD) as f:
        old = f.read()
    new = render(old)
    if check:
        if new != old:
            print("EXPERIMENTS.md is out of date with the BENCH_*.json "
                  "artifacts; run scripts/render_experiments.py",
                  file=sys.stderr)
            raise SystemExit(1)
        print("EXPERIMENTS.md is in sync with the BENCH artifacts")
        return
    if new != old:
        with open(MD, "w") as f:
            f.write(new)
        print(f"rendered {MD}")
    else:
        print(f"{MD} already up to date")


if __name__ == "__main__":
    main()
