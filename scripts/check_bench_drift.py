#!/usr/bin/env python
"""Compare a smoke-scale benchmark artifact against the committed one.

The bench-smoke CI job used to re-run near-paper-scale sweeps on every push
and never looked at the result — expensive and useless. Now it runs true
smoke scale and this script checks the smoke output has not *drifted* from
the committed ``BENCH_*.json``:

- **byte fields are exact**: per-round byte accounting is pure arithmetic
  over (strategy, topology, shapes) — any difference is a real accounting
  regression, regardless of how few rounds the smoke ran;
- **rounds-to-equilibrium within tolerance**: the sweeps are deterministic,
  but platform-level float differences can wiggle a threshold crossing by a
  few rounds. A smoke row that never reached equilibrium inside its reduced
  budget is skipped UNLESS the committed run also never reached it at a
  larger budget (then "smoke reached, committed did not" is drift: a
  diverging cell started converging or vice versa);
- **divergence is one-sided**: a cell that diverges at smoke scale must
  also diverge in the committed run (a stable cell newly blowing up is
  drift). The converse is NOT checked — the ``diverged`` sentinel
  (final error > 1e3) is budget-dependent, and a slowly diverging cell
  legitimately has not crossed it inside the reduced smoke budget.

Usage: check_bench_drift.py SMOKE.json COMMITTED.json [--tol 0.1]
Exits 1 on drift, with one line per violation.
"""

from __future__ import annotations

import argparse
import json
import sys

# benchmark name -> list sections:
#   {section: (key_fields, exact_fields[, approx_fields])}.
# ``exact_fields`` must match bit-for-bit (pure accounting arithmetic);
# ``approx_fields`` are float metrics compared at the --tol relative
# tolerance (deterministic sweeps, but platform-level float differences
# legitimately wiggle a converged error in the last digits).
# ``rounds_to_eq`` and ``diverged`` are handled structurally (see below);
# fields absent from a row are ignored, so one spec serves all artifacts.
SPECS = {
    "bench_engine": {
        "matrix": (("update", "sync"), ()),
        "topology": (("topology", "tau"), ("bytes_per_round",)),
        "gossip_policy": (("update", "policy", "gossip_steps"),
                          ("bytes_per_round",)),
    },
    "bench_async": {
        "staleness": (("schedule", "max_staleness"), ("bytes_per_round",)),
        "policy_rescue": (("schedule", "policy", "max_staleness"), ()),
    },
    "bench_collective": {
        "wire": (("collective", "sync"),
                 ("wire_bytes_per_round", "wire_dtypes", "compressed_wire")),
        "parity": (("topology", "sync"), ()),
    },
    # seconds are machine-local and EXCLUDED from drift on purpose (the
    # committed artifact's timings describe the machine that produced it);
    # they are schema-checked instead — see _check_wallclock_row.
    "bench_wallclock": {
        "rows": (("sync", "engine"), ("bytes_per_round", "max_staleness")),
        "parity": (("sync",), ("d0_bitwise_equal",)),
        "wire": (("sync",), ("wire_dtypes", "compressed_wire_dtypes")),
    },
    # neural players on the two-axis mesh: byte fields and wire dtypes are
    # exact (accounting + compiled HLO), losses are float metrics at the
    # relative tolerance, seconds schema-only (same rule as bench_wallclock)
    "bench_neural": {
        "rows": (("sync", "tau"),
                 ("param_count", "bytes_per_round",
                  "uplink_bytes_per_round", "uplink_overhead_bytes"),
                 ("loss_first", "loss_final")),
        "wire": (("sync",),
                 ("wire_dtypes", "compressed_gather_dtypes")),
        "roofline": (("sync", "tau"), ("bytes_per_round",),
                     ("ici_s_per_round", "ici_s_per_local_step")),
    },
    # the million-player sweep: every byte/state field is pure accounting
    # (pinned exactly — per-player flatness in n is the whole claim), while
    # the converged errors / equilibrium gaps are float metrics checked at
    # the relative tolerance
    # the selection-policy sweep: masks are seed-deterministic, so the
    # per-round byte accounting is exact; equilibrium metrics are handled
    # structurally (rounds_to_eq tolerance, one-sided diverged)
    "bench_selection": {
        "selection": (("policy",), ("fraction", "tau", "bytes_per_round")),
        "mean_field": (("policy",),
                       ("fraction", "tau", "n", "sample",
                        "bytes_per_round")),
        "staleness": (("stepsize_policy", "policy"),
                      ("max_staleness", "tau", "bytes_per_round")),
    },
    # the incentive layer: the free-rider collapse is pure game logic
    # (zero uplink bytes at ANY budget — pinned exactly) and the
    # full-participation round is pure accounting; realized participation
    # depends on the value-estimate feedback loop at the run's scale and
    # is deliberately NOT pinned
    "bench_incentives": {
        "price_sweep": (("scheme",),
                        ("price", "payment", "tau", "bytes_full_round"),
                        ("closed_form_rate",)),
        "collapse": (("scheme",),
                     ("price", "payment", "tau", "bytes_full_round",
                      "bytes_up_total", "collapsed", "closed_form_rate")),
        "vs_greedy": (("scheme",),
                      ("fraction", "tau", "bytes_full_round")),
    },
    "bench_scaling": {
        "mean_field": (("n",),
                       ("d", "tau", "bytes_per_round",
                        "bytes_up_per_player", "bytes_down_per_player",
                        "ref_state_bytes_per_player"),
                       ("final_rel_error",)),
        "exact": (("n",),
                  ("d", "tau", "bytes_per_round", "bytes_up_per_player",
                   "bytes_down_per_player", "ref_state_bytes_per_player"),
                  ("final_rel_error",)),
        "gap": (("n",), ("d", "corrected_matches_exact"),
                ("closed_form_gap", "run_gap")),
    },
}

#: seconds fields every wallclock row must carry with a positive value
_WALLCLOCK_SECONDS = ("sec_per_round_median", "sec_per_round_p90")


def _check_wallclock_row(prefix: str, row: dict) -> list[str]:
    """Schema (not drift) checks on one wallclock matrix row.

    Timings must exist and be positive — a zero or missing median means the
    timed loop did not run, which no amount of machine variance explains.
    Byte totals must be self-consistent: full-participation star rounds move
    a constant wire, so ``bytes_to_eq`` is exactly per-round bytes times the
    threshold-crossing round.
    """
    errors = []
    for f in _WALLCLOCK_SECONDS:
        v = row.get(f)
        if not (isinstance(v, (int, float)) and v > 0):
            errors.append(f"{prefix}.{f}: expected a positive number, "
                          f"got {v!r}")
    r_eq = row.get("rounds_to_eq")
    if r_eq is not None:
        v = row.get("sec_to_eq")
        if not (isinstance(v, (int, float)) and v > 0):
            errors.append(f"{prefix}.sec_to_eq: expected a positive number "
                          f"(rounds_to_eq={r_eq}), got {v!r}")
        expect = row.get("bytes_per_round", 0) * r_eq
        if row.get("bytes_to_eq") != expect:
            errors.append(
                f"{prefix}.bytes_to_eq: {row.get('bytes_to_eq')!r} != "
                f"bytes_per_round * rounds_to_eq = {expect}")
    return errors


def _key(row, fields):
    return tuple(row.get(f) for f in fields)


def compare(smoke: dict, committed: dict, tol: float) -> list[str]:
    name = committed.get("benchmark")
    if smoke.get("benchmark") != name:
        return [f"benchmark name mismatch: smoke={smoke.get('benchmark')!r} "
                f"committed={name!r}"]
    spec = SPECS.get(name)
    if spec is None:
        return [f"no drift spec for benchmark {name!r} — add one to "
                f"scripts/check_bench_drift.py"]
    errors = []
    for section, fields_spec in spec.items():
        key_fields, exact_fields = fields_spec[0], fields_spec[1]
        approx_fields = fields_spec[2] if len(fields_spec) > 2 else ()
        srows = {_key(r, key_fields): r for r in smoke.get(section, [])}
        crows = {_key(r, key_fields): r for r in committed.get(section, [])}
        if not srows:
            errors.append(f"{name}.{section}: smoke artifact has no rows")
            continue
        if name in ("bench_wallclock", "bench_neural") and section == "rows":
            for origin, rows in (("smoke", srows), ("committed", crows)):
                for key, row in rows.items():
                    errors.extend(_check_wallclock_row(
                        f"{name}.{section}{key}[{origin}]", row))
        for key, crow in crows.items():
            srow = srows.get(key)
            if srow is None:
                errors.append(f"{name}.{section}{key}: row missing from "
                              f"smoke artifact")
                continue
            for f in exact_fields:
                if f in crow and srow.get(f) != crow[f]:
                    errors.append(
                        f"{name}.{section}{key}.{f}: smoke={srow.get(f)!r} "
                        f"!= committed={crow[f]!r}")
            for f in approx_fields:
                if f not in crow:
                    continue
                s, c = srow.get(f), crow[f]
                if not isinstance(s, (int, float)) or \
                        abs(s - c) > tol * max(abs(c), 1e-12):
                    errors.append(
                        f"{name}.{section}{key}.{f}: smoke={s!r} outside "
                        f"{tol:.0%} of committed={c!r}")
            if srow.get("diverged") and not crow.get("diverged", False) \
                    and "diverged" in crow:
                errors.append(
                    f"{name}.{section}{key}: smoke run diverged but the "
                    f"committed run did not")
            if "rounds_to_eq" in crow:
                c_hit, s_hit = crow["rounds_to_eq"], srow.get("rounds_to_eq")
                if c_hit is None:
                    if s_hit is not None:
                        errors.append(
                            f"{name}.{section}{key}.rounds_to_eq: smoke "
                            f"reached equilibrium at {s_hit} but the "
                            f"committed run never did")
                elif s_hit is not None:
                    # both reached: deterministic sweeps, small platform tol
                    if abs(s_hit - c_hit) > max(1, tol * c_hit):
                        errors.append(
                            f"{name}.{section}{key}.rounds_to_eq: smoke="
                            f"{s_hit} committed={c_hit} (tol {tol:.0%})")
                # smoke budget may simply be too small to reach c_hit: only
                # flag when the smoke budget provably covered it
                elif "rounds" in srow and srow["rounds"] >= c_hit:
                    errors.append(
                        f"{name}.{section}{key}.rounds_to_eq: committed "
                        f"reached at {c_hit} <= smoke budget "
                        f"{srow['rounds']} but smoke never reached")
    return errors


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("smoke", help="freshly produced smoke-scale artifact")
    ap.add_argument("committed", help="committed BENCH_*.json to check "
                                      "against")
    ap.add_argument("--tol", type=float, default=0.1,
                    help="relative tolerance on rounds-to-equilibrium "
                         "(default 0.1)")
    args = ap.parse_args()
    with open(args.smoke) as f:
        smoke = json.load(f)
    with open(args.committed) as f:
        committed = json.load(f)
    errors = compare(smoke, committed, args.tol)
    for e in errors:
        print(f"DRIFT: {e}", file=sys.stderr)
    if errors:
        raise SystemExit(1)
    print(f"{args.smoke} is consistent with {args.committed}")


if __name__ == "__main__":
    main()
