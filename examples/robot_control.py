"""Distributed mobile-robot control as MpFL (paper Section 4.2).

    PYTHONPATH=src python examples/robot_control.py

Five robots hold positions balancing an anchor attraction against pairwise
displacement constraints — each robot optimizes its own objective, so the
stable configuration is a Nash equilibrium, found here with PEARL-SGD under
gradient noise (sigma^2 = 100). Prints the final formation and per-robot
objective values, and the communication savings of tau = 8 vs tau = 1.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import stepsize
from repro.core.games import make_robot_game
from repro.core.metrics import final_plateau
from repro.core.pearl import pearl_sgd

game = make_robot_game()
consts = game.constants()
x_star = game.equilibrium()
print("equilibrium positions:", np.asarray(x_star).ravel().round(3))

x0 = jnp.zeros((game.n, game.d))
for tau in (1, 8):
    gamma = stepsize.gamma_robot(consts, tau)
    r = pearl_sgd(game, x0, tau=tau, rounds=400, gamma=gamma,
                  key=jax.random.PRNGKey(0))
    print(f"tau={tau}: plateau rel err={final_plateau(r.rel_errors, 50):.3e}  "
          f"final positions={np.asarray(r.x_final).ravel().round(3)}")

r = pearl_sgd(game, x0, tau=8, rounds=400,
              gamma=stepsize.gamma_robot(consts, 8), key=jax.random.PRNGKey(0))
print("\nper-robot objectives at the found equilibrium:")
for i in range(game.n):
    f_i = float(game.objective(i, r.x_final))
    f_s = float(game.objective(i, x_star))
    print(f"  robot {i + 1}: f_i={f_i:8.3f}   (at x*: {f_s:8.3f})")
