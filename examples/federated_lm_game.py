"""End-to-end driver: MpFL with LANGUAGE-MODEL players (the production story).

    PYTHONPATH=src python examples/federated_lm_game.py [--steps 300] [--tau 8]

Three silos each own a ~100M-parameter llama-style LM (a width-reduced
smollm-360m) and a private heterogeneous token distribution. They play the
paper's Section 2.2 consensus game: each minimizes its own LM loss plus a
proximal pull toward the stale across-player parameter mean. PEARL-SGD =
tau local SGD steps per synchronization; the synchronization is the only
cross-silo communication.

The players run through :class:`repro.train.NeuralPlayerAdapter`: on a
multi-device host (real or ``XLA_FLAGS=--xla_force_host_platform_device_count=8``)
they land on the two-axis (players x model) mesh with the sync lowered to an
explicit shard_map collective; on one device the same code compiles the host
path. ``--sync``/``--topology``/``--participation`` select the wire and the
communication regime; the ledger bills what the drawn masks actually moved.
"""

import argparse
import dataclasses
import time

from repro.configs import get_config
from repro.models.model import param_shapes
from repro.optim.optimizers import sgd
from repro.roofline.analysis import count_params
from repro.train import NeuralPlayerAdapter
from repro.train.pearl_trainer import PearlCommReport


def build_player_config(target_params: str):
    """~100M-param llama-style player ('full') or a CPU-friendly reduction."""
    base = get_config("smollm-360m")
    if target_params == "full":
        # 12 layers x d_model 768 =~ 100M params mostly in embeddings + FFN
        return dataclasses.replace(
            base, name="lm-player-100m", n_layers=12, d_model=768, n_heads=12,
            n_kv_heads=4, head_dim=64, d_ff=2048, vocab_size=49152,
            dtype="float32", attn_chunk=256,
        )
    return base.smoke_variant()


def build_sync(name: str, participation: float):
    """The wire (--sync) composed with the participation model."""
    import jax.numpy as jnp

    from repro.core.engine import Int4Sync, Int8Sync, PartialParticipation

    if participation < 1.0:
        if name != "exact":
            raise SystemExit(
                "--participation composes the mask with the exact wire in "
                "this example; pick one of the two")
        return {"sync": PartialParticipation(fraction=participation, seed=0)}
    return {
        "exact": {},
        "bf16": {"sync_dtype": jnp.bfloat16},
        "int8": {"sync": Int8Sync()},
        "int4": {"sync": Int4Sync()},
    }[name]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300,
                    help="total LOCAL steps per player")
    ap.add_argument("--tau", type=int, default=8)
    ap.add_argument("--players", type=int, default=3)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--size", choices=["full", "smoke"], default="smoke",
                    help="'full' = ~100M params/player (slow on CPU)")
    ap.add_argument("--prox", type=float, default=1e-3)
    ap.add_argument("--sync", choices=["exact", "bf16", "int8", "int4"],
                    default="exact", help="wire representation of the sync")
    ap.add_argument("--topology", choices=["star", "ring"], default="star")
    ap.add_argument("--participation", type=float, default=1.0,
                    help="< 1.0 draws a per-round participation mask")
    ap.add_argument("--no-kernels", action="store_true",
                    help="use the pure-jnp model path")
    args = ap.parse_args(argv)

    from repro.core.topology import Ring
    from repro.data.synthetic import DataConfig, SyntheticTokenStream

    cfg = build_player_config(args.size)
    n_params = count_params(param_shapes(cfg))
    kwargs = build_sync(args.sync, args.participation)
    if args.topology == "ring":
        kwargs["topology"] = Ring()

    adapter = NeuralPlayerAdapter(
        cfg, sgd(3e-2), n_players=args.players, tau=args.tau,
        prox_lambda=args.prox, seed=0, use_kernels=not args.no_kernels,
        **kwargs,
    )
    mesh_desc = (dict(adapter.mesh.shape) if adapter.mesh is not None
                 else "host (single device)")
    print(f"player model: {cfg.name}  params={n_params / 1e6:.1f}M  "
          f"players={args.players}  tau={args.tau}  mesh={mesh_desc}")

    stream = SyntheticTokenStream(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq, batch_size=args.batch,
        n_players=args.players, seed=0,
    ))

    rounds = max(1, args.steps // args.tau)
    t0 = time.time()
    for r in range(rounds):
        hist = adapter.run(stream, rounds=1)
        rec = hist[-1]
        if r % max(1, rounds // 10) == 0 or r == rounds - 1:
            print(f"round {r:4d}/{rounds}  lm_loss={rec['lm_loss']:.4f}  "
                  f"({time.time() - t0:.0f}s)")

    # mask-aware: bills the blocks/links the drawn masks actually moved
    report = adapter.comm_report()
    base = PearlCommReport(n_players=args.players, param_count=n_params,
                           tau=1, rounds=args.steps)
    print(f"\ncommunication ledger ({args.sync} on the wire, "
          f"{args.topology} topology):")
    print(f"  PEARL tau={args.tau}: {report.total_bytes / 1e9:.2f} GB over "
          f"{rounds} syncs")
    print(f"  non-local (tau=1, fp32): {base.total_bytes / 1e9:.2f} GB over "
          f"{args.steps} syncs")
    if report.total_bytes:
        print(f"  saving: {base.total_bytes / report.total_bytes:.1f}x — the "
              "paper's claim, realized at LM scale")
    return adapter


if __name__ == "__main__":
    main()
