"""End-to-end driver: MpFL with LANGUAGE-MODEL players (the production story).

    PYTHONPATH=src python examples/federated_lm_game.py [--steps 300] [--tau 8]

Three silos each own a ~100M-parameter llama-style LM (a width-reduced
smollm-360m) and a private heterogeneous token distribution. They play the
paper's Section 2.2 consensus game: each minimizes its own LM loss plus a
proximal pull toward the stale across-player parameter mean. PEARL-SGD =
tau local AdamW/SGD steps per synchronization; the synchronization is the
only cross-silo communication.

On the production mesh each player is a pod (launch/dryrun.py --pearl lowers
exactly this program on the 2x16x16 mesh); here the same code runs all
players on CPU via vmap. Prints per-round losses and the communication ledger.
"""

import argparse
import dataclasses
import time

from repro.configs import get_config
from repro.data.synthetic import DataConfig, SyntheticTokenStream
from repro.models.model import param_shapes
from repro.optim.optimizers import sgd
from repro.roofline.analysis import count_params
from repro.train.pearl_trainer import PearlCommReport, PearlTrainer


def build_player_config(target_params: str):
    """~100M-param llama-style player ('full') or a CPU-friendly reduction."""
    base = get_config("smollm-360m")
    if target_params == "full":
        # 12 layers x d_model 768 =~ 100M params mostly in embeddings + FFN
        return dataclasses.replace(
            base, name="lm-player-100m", n_layers=12, d_model=768, n_heads=12,
            n_kv_heads=4, head_dim=64, d_ff=2048, vocab_size=49152,
            dtype="float32", attn_chunk=256,
        )
    return base.smoke_variant()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300,
                    help="total LOCAL steps per player")
    ap.add_argument("--tau", type=int, default=8)
    ap.add_argument("--players", type=int, default=3)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--size", choices=["full", "smoke"], default="smoke",
                    help="'full' = ~100M params/player (slow on CPU)")
    ap.add_argument("--prox", type=float, default=1e-3)
    args = ap.parse_args()

    cfg = build_player_config(args.size)
    n_params = count_params(param_shapes(cfg))
    print(f"player model: {cfg.name}  params={n_params / 1e6:.1f}M  "
          f"players={args.players}  tau={args.tau}")

    stream = SyntheticTokenStream(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq, batch_size=args.batch,
        n_players=args.players, seed=0,
    ))
    trainer = PearlTrainer(cfg, sgd(3e-2), n_players=args.players,
                           tau=args.tau, prox_lambda=args.prox, seed=0)

    rounds = max(1, args.steps // args.tau)
    t0 = time.time()
    for r in range(rounds):
        hist = trainer.run(stream, rounds=1)
        rec = hist[-1]
        if r % max(1, rounds // 10) == 0 or r == rounds - 1:
            print(f"round {r:4d}/{rounds}  lm_loss={rec['lm_loss']:.4f}  "
                  f"({time.time() - t0:.0f}s)")

    report = PearlCommReport(n_players=args.players, param_count=n_params,
                             tau=args.tau, rounds=rounds)
    base = PearlCommReport(n_players=args.players, param_count=n_params,
                           tau=1, rounds=args.steps)
    print("\ncommunication ledger (fp32 on the wire):")
    print(f"  PEARL tau={args.tau}: {report.total_bytes / 1e9:.2f} GB over "
          f"{rounds} syncs")
    print(f"  non-local (tau=1):   {base.total_bytes / 1e9:.2f} GB over "
          f"{args.steps} syncs")
    print(f"  saving: {base.total_bytes / report.total_bytes:.1f}x — the "
          "paper's claim, realized at LM scale")


if __name__ == "__main__":
    main()
