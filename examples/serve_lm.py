"""Serving example: batched prefill + token-by-token decode with KV cache.

    PYTHONPATH=src python examples/serve_lm.py [--arch zamba2-1.2b]

Loads a reduced variant of any assigned architecture, prefills a batch of
prompts, and greedily decodes continuations — exercising the exact
``serve_step`` the decode_32k/long_500k dry-run shapes lower (ring-buffer
caches for windowed layers, O(1) recurrent state for SSM/xLSTM blocks).
"""

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.models import init_params
from repro.serve.decode import generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="zamba2-1.2b", choices=list(ARCH_IDS))
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch).smoke_variant()
    params = init_params(cfg, jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size)}
    if cfg.modality == "vision":
        batch["patch_embeds"] = 0.1 * jax.random.normal(
            key, (args.batch, cfg.n_modality_tokens, cfg.d_model))
    if cfg.enc_layers:
        batch["enc_frames"] = 0.1 * jax.random.normal(
            key, (args.batch, args.prompt_len, cfg.d_model))

    t0 = time.time()
    out = generate(params, cfg, batch, max_new_tokens=args.new_tokens,
                   capacity=args.prompt_len + args.new_tokens + 8,
                   window=cfg.sliding_window if cfg.family == "hybrid" else 0)
    dt = time.time() - t0
    print(f"arch={args.arch} (reduced)  decode: "
          f"{args.batch * args.new_tokens / dt:.1f} tok/s on CPU")
    print("generated token ids:")
    print(np.asarray(out))


if __name__ == "__main__":
    main()
