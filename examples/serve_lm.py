"""Serving example: equilibrium player policies through the decode stack.

    PYTHONPATH=src python examples/serve_lm.py [--arch zamba2-1.2b]

Trains a small MpFL consensus game for a few PEARL rounds
(:class:`repro.train.NeuralPlayerAdapter` — on a multi-device host the
players land on the two-axis mesh), then serves EACH player's equilibrium
policy through the exact ``serve_step`` the decode_32k/long_500k dry-run
shapes lower (batched prefill + token-by-token decode, ring-buffer caches
for windowed layers, O(1) recurrent state for SSM/xLSTM blocks) under
synthetic prompt traffic drawn from that player's own distribution.

``--rounds 0`` skips training and serves the random init (the legacy
smoke); encoder/vision architectures only support that mode, since the
PEARL trainer drives text-token players.
"""

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.models import init_params
from repro.serve.decode import generate


def equilibrium_players(cfg, n_players: int, rounds: int, tau: int):
    """Train the consensus game briefly; return per-player param pytrees."""
    from repro.data.synthetic import DataConfig, SyntheticTokenStream
    from repro.optim.optimizers import sgd
    from repro.train import NeuralPlayerAdapter

    adapter = NeuralPlayerAdapter(cfg, sgd(3e-2), n_players=n_players,
                                  tau=tau, prox_lambda=1e-3, seed=0)
    stream = SyntheticTokenStream(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=32, batch_size=2,
        n_players=n_players, seed=0,
    ))
    hist = adapter.run(stream, rounds=rounds)
    print(f"trained {n_players} players for {rounds} rounds "
          f"(tau={tau}): lm_loss {hist[0]['lm_loss']:.4f} -> "
          f"{hist[-1]['lm_loss']:.4f}")
    return [adapter.player_params(i) for i in range(n_players)], stream


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="zamba2-1.2b", choices=list(ARCH_IDS))
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--players", type=int, default=2)
    ap.add_argument("--rounds", type=int, default=2,
                    help="PEARL rounds before serving; 0 = random init")
    ap.add_argument("--tau", type=int, default=2)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch).smoke_variant()
    multimodal = cfg.modality == "vision" or bool(cfg.enc_layers)
    if args.rounds > 0 and multimodal:
        raise SystemExit(
            f"{args.arch} needs encoder/vision inputs; the PEARL players "
            f"are text-token LMs — rerun with --rounds 0")

    key = jax.random.PRNGKey(1)
    if args.rounds > 0:
        players, stream = equilibrium_players(cfg, args.players,
                                              args.rounds, args.tau)
        # synthetic traffic: each player's prompts come from ITS distribution
        prompts = [stream.batch(i, step=10_000)[:args.batch,
                                                :args.prompt_len]
                   for i in range(args.players)]
    else:
        players = [init_params(cfg, jax.random.PRNGKey(0))]
        prompts = [jax.random.randint(
            key, (args.batch, args.prompt_len), 0, cfg.vocab_size)]

    window = cfg.sliding_window if cfg.family == "hybrid" else 0
    for i, (params, tokens) in enumerate(zip(players, prompts)):
        batch = {"tokens": jax.numpy.asarray(tokens)}
        if cfg.modality == "vision":
            batch["patch_embeds"] = 0.1 * jax.random.normal(
                key, (args.batch, cfg.n_modality_tokens, cfg.d_model))
        if cfg.enc_layers:
            batch["enc_frames"] = 0.1 * jax.random.normal(
                key, (args.batch, args.prompt_len, cfg.d_model))
        t0 = time.time()
        out = generate(params, cfg, batch, max_new_tokens=args.new_tokens,
                       capacity=args.prompt_len + args.new_tokens + 8,
                       window=window)
        dt = time.time() - t0
        tag = f"player {i}" if args.rounds > 0 else "random init"
        print(f"arch={args.arch} (reduced)  {tag}  decode: "
              f"{args.batch * args.new_tokens / dt:.1f} tok/s on CPU")
        print(np.asarray(out))
    return players


if __name__ == "__main__":
    main()
