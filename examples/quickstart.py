"""Quickstart: solve a 5-player game with the PEARL engine in ~20 lines.

    PYTHONPATH=src python examples/quickstart.py
    PYTHONPATH=src python examples/quickstart.py --method extragradient --sync bf16
    PYTHONPATH=src python examples/quickstart.py --method optimistic_gradient --sync partial
    PYTHONPATH=src python examples/quickstart.py --topology ring

Builds the paper's Section 4.1 quadratic game, runs the chosen local update
rule under the chosen communication strategy and topology for a few
synchronization intervals tau, and prints the relative error after a fixed
communication budget — the paper's headline: more local steps, fewer
communications, same (or better) accuracy. ``--method/--sync/--topology``
expose the engine's pluggable update x compression/participation x topology
matrix (see README "Engine architecture" and "Topology layer"). Server-free
topologies use a weak-coupling game: gossip's stale inconsistent views act
like delays under the antisymmetric coupling, so its stability margin shrinks
as the coupling grows.
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import stepsize
from repro.core.engine import PLAYER_UPDATES, SYNC_STRATEGIES, PearlEngine
from repro.core.games import make_quadratic_game
from repro.core.topology import TOPOLOGIES

parser = argparse.ArgumentParser(description=__doc__)
parser.add_argument("--method", choices=sorted(PLAYER_UPDATES), default="sgd",
                    help="local update rule each player runs between syncs")
parser.add_argument("--sync", choices=sorted(SYNC_STRATEGIES), default="exact",
                    help="compression/participation strategy at each round")
parser.add_argument("--topology", choices=sorted(TOPOLOGIES), default="star",
                    help="communication graph (star = the paper's server)")
parser.add_argument("--rounds", type=int, default=2500,
                    help="communication budget (rounds)")
args = parser.parse_args()

topology = TOPOLOGIES[args.topology]()
L_B = 20.0 if topology.is_server else 1.0
game = make_quadratic_game(n=5, d=10, M=100, L_B=L_B, batch_size=1)
consts = game.constants()
print(f"game: n={game.n} d={game.d} kappa={consts.kappa:.0f} q={consts.q:.3f}")
print(f"engine: method={args.method} sync={args.sync} topology={args.topology}")

x0 = jnp.asarray(np.random.default_rng(0).standard_normal((game.n, game.d)))
engine = PearlEngine(update=PLAYER_UPDATES[args.method](),
                     sync=SYNC_STRATEGIES[args.sync](),
                     topology=topology)

for tau in (1, 4, 20):
    gamma = stepsize.gamma_constant(consts, tau)
    result = engine.run(game, x0, tau=tau, rounds=args.rounds, gamma=gamma,
                        key=jax.random.PRNGKey(0))
    print(f"tau={tau:2d}  gamma={gamma:.2e}  comms={result.communications}  "
          f"local steps={result.iterations}  "
          f"rel err={result.rel_errors[-1]:.3e}  "
          f"wire={result.total_bytes / 1e6:.1f}MB")

if args.method == "sgd":
    print("\nLarger tau => smaller error for the SAME number of communications "
          "(Theorem 3.4).")
else:
    print(f"\nNote: the Theorem 3.4 step-size rule is tuned for sgd; "
          f"{args.method} may need a smaller gamma at large tau.")
