"""Quickstart: solve a 5-player game with PEARL-SGD in ~20 lines.

    PYTHONPATH=src python examples/quickstart.py

Builds the paper's Section 4.1 quadratic game, runs PEARL-SGD with the
theoretical step-size for a few synchronization intervals tau, and prints the
relative error after a fixed communication budget — the paper's headline:
more local steps, fewer communications, same (or better) accuracy.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import stepsize
from repro.core.games import make_quadratic_game
from repro.core.pearl import pearl_sgd

game = make_quadratic_game(n=5, d=10, M=100, batch_size=1)
consts = game.constants()
print(f"game: n={game.n} d={game.d} kappa={consts.kappa:.0f} q={consts.q:.3f}")

x0 = jnp.asarray(np.random.default_rng(0).standard_normal((game.n, game.d)))
rounds = 2500  # communication budget (enough to reach the noise plateau)

for tau in (1, 4, 20):
    gamma = stepsize.gamma_constant(consts, tau)
    result = pearl_sgd(game, x0, tau=tau, rounds=rounds, gamma=gamma,
                       key=jax.random.PRNGKey(0))
    print(f"tau={tau:2d}  gamma={gamma:.2e}  comms={result.communications}  "
          f"local steps={result.iterations}  "
          f"rel err={result.rel_errors[-1]:.3e}")

print("\nLarger tau => smaller error for the SAME number of communications "
      "(Theorem 3.4).")
