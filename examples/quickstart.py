"""Quickstart: solve a 5-player game with the PEARL engine in ~20 lines.

    PYTHONPATH=src python examples/quickstart.py
    PYTHONPATH=src python examples/quickstart.py --method extragradient --sync bf16
    PYTHONPATH=src python examples/quickstart.py --method optimistic_gradient --sync partial
    PYTHONPATH=src python examples/quickstart.py --topology ring
    PYTHONPATH=src python examples/quickstart.py --staleness 4 --delay straggler
    PYTHONPATH=src python examples/quickstart.py --staleness 16 --delay straggler --policy delay_adaptive
    PYTHONPATH=src python examples/quickstart.py --topology ring --policy spectral
    PYTHONPATH=src python examples/quickstart.py --incentive 0.45

Builds the paper's Section 4.1 quadratic game, runs the chosen local update
rule under the chosen communication strategy and topology for a few
synchronization intervals tau, and prints the relative error after a fixed
communication budget — the paper's headline: more local steps, fewer
communications, same (or better) accuracy. ``--method/--sync/--topology``
expose the engine's pluggable update x compression/participation x topology
matrix (see README "Engine architecture" and "Topology layer");
``--staleness D`` drops the lockstep barrier and runs the bounded-staleness
async engine under the ``--delay`` schedule (README "Async rounds");
``--policy`` swaps the Theorem 3.4 step-size rule for a context-aware one
(README "Step-size policies" — ``delay_adaptive`` needs ``--staleness``,
``spectral`` a server-free ``--topology``; the engine rejects mismatches);
``--incentive PRICE`` makes participation strategic — each player joins a
round iff payment plus network value covers its private cost, and the mask
is the best-response fixed point (README "Strategic participation").
Server-free topologies and async runs use a weak-coupling game: stale
inconsistent views act like delays under the antisymmetric coupling, so the
stability margin shrinks as the coupling grows.
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import stepsize
from repro.core.async_engine import DELAY_SCHEDULES, AsyncPearlEngine
from repro.core.engine import PLAYER_UPDATES, SYNC_STRATEGIES, PearlEngine
from repro.core.games import make_quadratic_game
from repro.core.selection import SELECTION_POLICIES, resolve_selection
from repro.core.stepsize import STEPSIZE_POLICIES
from repro.core.topology import TOPOLOGIES

parser = argparse.ArgumentParser(description=__doc__)
parser.add_argument("--method", choices=sorted(PLAYER_UPDATES), default="sgd",
                    help="local update rule each player runs between syncs")
parser.add_argument("--sync", choices=sorted(SYNC_STRATEGIES), default="exact",
                    help="compression/participation strategy at each round")
parser.add_argument("--topology", choices=sorted(TOPOLOGIES), default="star",
                    help="communication graph (star = the paper's server)")
parser.add_argument("--staleness", type=int, default=0, metavar="D",
                    help="bounded-staleness async rounds: players read "
                         "broadcasts up to D rounds old (0 = lockstep)")
parser.add_argument("--delay", choices=sorted(DELAY_SCHEDULES),
                    default="uniform",
                    help="delay schedule for --staleness > 0")
parser.add_argument("--policy", choices=sorted(STEPSIZE_POLICIES),
                    default="theorem34",
                    help="step-size policy (theorem34 = the paper's fixed "
                         "rule; delay_adaptive needs --staleness; spectral "
                         "needs a server-free --topology)")
parser.add_argument("--selection", choices=sorted(SELECTION_POLICIES),
                    default=None,
                    help="value-driven participation scheduling on the sync "
                         "axis (replaces --sync; greedy_shapley/ucb/"
                         "power_of_choice score observed deltas, uniform is "
                         "the bit-for-bit partial-participation control); "
                         "needs the star topology")
parser.add_argument("--incentive", type=float, default=None, metavar="PRICE",
                    help="strategic participation: pay PRICE per round and "
                         "let each player best-respond (price <= 0.2 is the "
                         "free-rider collapse, >= 0.8 buys everyone; "
                         "replaces --sync/--selection; needs the star "
                         "topology)")
parser.add_argument("--rounds", type=int, default=2500,
                    help="communication budget (rounds)")
args = parser.parse_args()
if args.staleness < 0:
    parser.error(f"--staleness must be >= 0, got {args.staleness}")

if args.selection is not None and args.sync != "exact":
    parser.error("--selection replaces --sync (a selection policy IS the "
                 "sync strategy); drop one of them")
if args.incentive is not None:
    if args.selection is not None:
        parser.error("--incentive IS a selection policy (best_response); "
                     "drop --selection")
    if args.sync != "exact":
        parser.error("--incentive replaces --sync (the best-response mask "
                     "IS the sync strategy); drop one of them")
    if args.incentive < 0:
        parser.error(f"--incentive must be >= 0, got {args.incentive}")

topology = TOPOLOGIES[args.topology]()
L_B = 20.0 if topology.is_server and args.staleness == 0 else 1.0
game = make_quadratic_game(n=5, d=10, M=100, L_B=L_B, batch_size=1)
consts = game.constants()
print(f"game: n={game.n} d={game.d} kappa={consts.kappa:.0f} q={consts.q:.3f}")
print(f"engine: method={args.method} sync={args.sync} "
      f"topology={args.topology} staleness={args.staleness}"
      + (f" delay={args.delay}" if args.staleness else "")
      + (f" policy={args.policy}" if args.policy != "theorem34" else "")
      + (f" selection={args.selection}" if args.selection else "")
      + (f" incentive_price={args.incentive}"
         if args.incentive is not None else ""))

if args.incentive is not None:
    from repro.core.incentives import BestResponseParticipation

    sync = BestResponseParticipation(price=args.incentive)
elif args.selection:
    sync = resolve_selection(args.selection)
else:
    sync = SYNC_STRATEGIES[args.sync]()

x0 = jnp.asarray(np.random.default_rng(0).standard_normal((game.n, game.d)))
if args.staleness > 0:
    from repro.core.async_engine import ConstantDelay

    # "constant" means pinned AT the bound (the registry default lag=1
    # would quietly ignore --staleness)
    delays = (ConstantDelay(lag=args.staleness) if args.delay == "constant"
              else DELAY_SCHEDULES[args.delay]())
    engine = AsyncPearlEngine(update=PLAYER_UPDATES[args.method](),
                              sync=sync,
                              topology=topology,
                              delays=delays,
                              max_staleness=args.staleness,
                              policy=args.policy)
else:
    engine = PearlEngine(update=PLAYER_UPDATES[args.method](),
                         sync=sync,
                         topology=topology,
                         policy=args.policy)

for tau in (1, 4, 20):
    gamma = stepsize.gamma_constant(consts, tau)
    result = engine.run(game, x0, tau=tau, rounds=args.rounds, gamma=gamma,
                        key=jax.random.PRNGKey(0))
    print(f"tau={tau:2d}  gamma={gamma:.2e}  comms={result.communications}  "
          f"local steps={result.iterations}  "
          f"rel err={result.rel_errors[-1]:.3e}  "
          f"wire={result.total_bytes / 1e6:.1f}MB")

if args.method == "sgd":
    print("\nLarger tau => smaller error for the SAME number of communications "
          "(Theorem 3.4).")
else:
    print(f"\nNote: the Theorem 3.4 step-size rule is tuned for sgd; "
          f"{args.method} may need a smaller gamma at large tau.")
