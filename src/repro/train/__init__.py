"""Training: single-player train_step + MpFL PearlTrainer (players = pods)."""

from repro.train.neural import NeuralPlayerAdapter, two_axis_mesh
from repro.train.pearl_trainer import PearlCommReport, PearlTrainer, make_pearl_round
from repro.train.train_step import lm_loss, make_loss_fn, make_train_step

__all__ = ["NeuralPlayerAdapter", "two_axis_mesh",
           "PearlCommReport", "PearlTrainer", "make_pearl_round",
           "lm_loss", "make_loss_fn", "make_train_step"]
