"""Neural PEARL players on the two-axis mesh.

The paper's players are neural learners with individual objectives;
:class:`~repro.train.pearl_trainer.PearlTrainer` supplies the PEARL loop
(tau local steps against a frozen stale reference, one synchronization per
round) for any player-stacked param pytree. This module binds it to the
real model stack:

- **players** come from the model configs (``get_config("smollm-360m")``,
  ``get_config("xlstm-125m")``, ...) — per-player param pytrees initialized
  per player, local updates through ``train_step.make_loss_fn`` with the
  Pallas kernel path on by default;
- **the mesh is two-axis**: the player/pod collective axis (PR 5) times the
  within-player tensor-parallel axis, with per-leaf PartitionSpecs from
  :func:`repro.models.sharding.param_partition_specs` threaded into the
  shard_map collectives as ``mesh_inner_specs`` — so the sync all-gather
  crosses only the player axis while each player's matrices stay
  model-sharded;
- **wire claims stay HLO-verified**: :meth:`NeuralPlayerAdapter.
  lower_round_hlo` compiles the trainer's round dry-run so tests and
  benchmarks can assert the quantized sync's operand dtype with
  :func:`repro.core.collective.assert_wire_dtype`, same as the PR 5/6
  matrix wires.

On a single device (plain tier-1 CI) the adapter degrades to the host
lowering — ``mesh=None`` compiles the identical legacy program — so smokes
run anywhere; the multi-device CI job exercises the sharded paths on the
fake 8-device mesh.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.configs.base import ModelConfig
from repro.core.collective import PLAYER_AXIS
from repro.models.model import param_shapes
from repro.models.sharding import param_partition_specs
from repro.optim.optimizers import Optimizer
from repro.train.pearl_trainer import PearlTrainer

__all__ = ["NeuralPlayerAdapter", "two_axis_mesh"]


def two_axis_mesh(n_players: int, *, devices=None,
                  axis_name: str = PLAYER_AXIS,
                  model_axis: str = "model") -> Mesh | None:
    """A ``(players, model)`` mesh sized to the available devices.

    The player axis takes the largest divisor of ``n_players`` that fits;
    the model axis absorbs the remaining device factor (within-player
    tensor parallelism — :func:`~repro.models.sharding.param_partition_specs`
    shards head/ffn/vocab dims over it when divisible). Returns ``None``
    when only a trivial 1x1 mesh would fit a multi-player run: a mesh with
    no wire would make the HLO-level claims vacuous, and the host lowering
    is bit-identical anyway.
    """
    if n_players < 1:
        raise ValueError(f"n_players must be >= 1, got {n_players}")
    devs = list(jax.devices() if devices is None else devices)
    psize = max(k for k in range(1, min(n_players, len(devs)) + 1)
                if n_players % k == 0)
    msize = max(1, len(devs) // psize)
    if psize * msize < 2:
        return None
    grid = np.array(devs[: psize * msize]).reshape(psize, msize)
    return Mesh(grid, (axis_name, model_axis))


class NeuralPlayerAdapter:
    """PearlTrainer with real neural players, sharded on the two-axis mesh.

    Thin by design: model construction, sharding policy, and the PEARL loop
    all already exist — this class wires them together (mesh construction,
    spec threading, kernel path) and adds the dry-run HLO surface the wire
    assertions need. All ``PearlTrainer`` keywords pass through (``sync``,
    ``sync_dtype``, ``topology``, ``delays``/``max_staleness``,
    ``policy``, ...).

    ``devices=None`` sizes the mesh to ``jax.devices()``;
    ``devices=False`` forces the host lowering (no mesh).
    """

    def __init__(self, cfg: ModelConfig, optimizer: Optimizer, *,
                 n_players: int, tau: int, prox_lambda: float,
                 use_kernels: bool = True, devices=None,
                 axis_name: str = PLAYER_AXIS, **trainer_kwargs):
        self.cfg = cfg
        self.n_players = n_players
        self.mesh = (None if devices is False
                     else two_axis_mesh(n_players, devices=devices or None,
                                        axis_name=axis_name))
        self.inner_specs = None
        if self.mesh is not None:
            self.inner_specs = param_partition_specs(
                param_shapes(cfg), cfg,
                model_size=self.mesh.shape["model"])
            trainer_kwargs.update(mesh=self.mesh, mesh_axis=axis_name,
                                  mesh_inner_specs=self.inner_specs)
        self.trainer = PearlTrainer(
            cfg, optimizer, n_players=n_players, tau=tau,
            prox_lambda=prox_lambda, use_kernels=use_kernels,
            **trainer_kwargs,
        )

    def run(self, stream, rounds: int):
        return self.trainer.run(stream, rounds)

    def comm_report(self, rounds: int | None = None):
        return self.trainer.comm_report(rounds)

    def player_params(self, i: int):
        """One player's (unstacked) param pytree — e.g. for serving."""
        return jax.tree.map(lambda x: x[i], self.trainer.params)

    def lower_round_hlo(self, *, seq_len: int = 32,
                        batch_size: int = 2) -> str:
        """Optimized HLO of the compiled round (dry-run, nothing executed).

        The assertion surface for the wire claims: feed to
        :func:`repro.core.collective.assert_wire_dtype` /
        :func:`~repro.core.collective.wire_dtype_report`.
        """
        tr = self.trainer
        tokens = {"tokens": jnp.zeros(
            (self.n_players, tr.tau, batch_size, seq_len), jnp.int32)}
        if tr._general:
            args = (tr.params, tr.opt_state, tokens, tr.refs, tr.snapshot,
                    jnp.ones((self.n_players,), bool),
                    jnp.asarray(tr._mixes[0]))
            if tr._policy_active:
                args = args + (jnp.ones((self.n_players,), jnp.float32),)
        elif tr._lowbit:
            args = (tr.params, tr.opt_state, tokens, tr.xbar,
                    tr._wire_state)
        else:
            args = (tr.params, tr.opt_state, tokens, tr.xbar)
        return tr._round.lower(*args).compile().as_text()
