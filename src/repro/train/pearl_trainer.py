"""PEARL-SGD for neural players — the paper's technique at production scale.

Each of ``n`` players/silos owns a full model (one per pod on the multi-pod
mesh) trained on its own heterogeneous data shard. The players are coupled
through the consensus game of paper Section 2.2:

    f_i(x^i; x^{-i}) = h_i(x^i) + (lambda/2) ||x^i - mean_j x^j||^2,

whose first-order conditions are exactly an n-player equilibrium — the MpFL
instance we scale up. PEARL-SGD (Algorithm 1) becomes:

  - tau local steps per round: each player minimizes its LM loss plus the
    proximal pull toward the *stale* across-player mean (snapshot at the
    last synchronization) — zero cross-player communication;
  - one synchronization per round: recompute the across-player mean. On the
    production mesh, player = pod, so this mean is THE only ``pod``-axis
    collective; every step of the tau-step inner scan stays pod-local.

This module is the neural-player adapter over the unified engine: the
"tau local steps under vmap, then one collective" round template comes from
:func:`repro.core.engine.make_federated_round` (the same structure the dense
:class:`~repro.core.engine.PearlEngine` compiles for vector games), the wire
quantization comes from the engine's :class:`~repro.core.engine.SyncStrategy`
objects, and :class:`PearlCommReport` derives its bytes-per-scalar from the
active sync dtype instead of hard-coding fp32.

The non-local baseline (SGDA / gradient play, tau = 1) synchronizes every
step; the paper's claim — same accuracy with tau-fold less communication —
shows up in the dry-run HLO as a tau-fold drop in pod-axis collective bytes
per local step (EXPERIMENTS.md Section Perf).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.engine import (
    ExactSync,
    QuantizedSync,
    SyncStrategy,
    make_federated_round,
    resolve_sync,
)
from repro.optim.optimizers import Optimizer, apply_updates, clip_by_global_norm
from repro.train.train_step import make_loss_fn

Array = jax.Array


def _resolve_trainer_sync(sync: SyncStrategy | None, sync_dtype) -> SyncStrategy:
    """The neural trainer implements exact and quantized synchronization only:
    mask-based strategies (partial participation, dropout links) would need
    the round to merge stale per-player pytrees, which the pod-mapped
    collective does not express yet (see ROADMAP "Adaptive participation")."""
    strategy = resolve_sync(sync, sync_dtype)
    if not isinstance(strategy, (ExactSync, QuantizedSync)):
        raise NotImplementedError(
            f"PearlTrainer supports ExactSync/QuantizedSync, got "
            f"{type(strategy).__name__}"
        )
    return strategy


def tree_mean(stacked, axis: int = 0, sync_dtype=None, sync: SyncStrategy | None = None):
    """Across-player parameter mean — the PEARL synchronization collective.

    The wire representation is delegated to the engine's sync strategy:
    ``QuantizedSync(jnp.bfloat16)`` (or the ``sync_dtype`` shorthand)
    quantizes the operands BEFORE the cross-player reduction, so the pod-axis
    collective moves half (or less) the bytes — the paper's "gradient
    compression" future-work item composed with local steps: wire bytes fall
    by tau x (32/bits). Convergence-wise this adds bounded quantization noise
    to the stale snapshot, absorbed by Theorem 3.4's sigma^2 term (validated
    in tests/test_pearl_trainer.py).
    """
    strategy = _resolve_trainer_sync(sync, sync_dtype)
    quantized = isinstance(strategy, QuantizedSync)

    def mean(x):
        if quantized:
            # Quantize then reduce. NOTE (Section Perf, recorded negative
            # result): the XLA CPU build reassociates the convert around its
            # f32 reduction accumulator, so the compiled cross-pod wire stays
            # f32 in the dry-run HLO; forcing bf16 on the wire needs an
            # explicit shard_map psum over a bf16 buffer on real TPU
            # backends. The convergence semantics (bounded quantization
            # noise) hold either way and are what the tests validate.
            return jnp.mean(strategy.compress(x), axis=axis).astype(jnp.float32)
        return jnp.mean(x, axis=axis, dtype=jnp.float32)

    return jax.tree.map(mean, stacked)


def stack_players(params_list):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *params_list)


def make_pearl_round(
    cfg: ModelConfig,
    optimizer: Optimizer,
    *,
    tau: int,
    prox_lambda: float,
    aux_weight: float = 0.01,
    clip_norm: float = 1.0,
    window: int = 0,
    use_kernels: bool = False,
    unroll: bool = False,
    sync_dtype=None,
    sync: SyncStrategy | None = None,
) -> Callable:
    """Build one compiled PEARL round on the engine's federated-round template.

    ``pearl_round(stacked_params, stacked_opt, batches, xbar)``:
      - stacked_params/opt: player-stacked pytrees, leading dim n (sharded
        over ``pod`` on the production mesh);
      - batches: {"tokens": (n, tau, B_local, S)} — tau local batches per
        player drawn from that player's distribution D_i;
      - xbar: stale across-player mean (pytree, replicated).

    Returns (new_params, new_opt, new_xbar, metrics). ``new_xbar`` is the
    synchronization output; in PEARL it is computed once per round.
    """
    strategy = _resolve_trainer_sync(sync, sync_dtype)
    loss_fn = make_loss_fn(cfg, aux_weight=aux_weight, window=window,
                           use_kernels=use_kernels, prox_lambda=prox_lambda)

    def local_step(carry, tokens, xbar):
        """One optimizer step of a single player against the frozen xbar."""
        p, o = carry
        (_, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            p, {"tokens": tokens}, xbar
        )
        if clip_norm:
            grads = clip_by_global_norm(grads, clip_norm)
        updates, o = optimizer.update(grads, o, p)
        p = apply_updates(p, updates)
        return (p, o), metrics

    round_fn = make_federated_round(
        local_step,
        lambda stacked: tree_mean(stacked[0], sync=strategy),
        unroll=unroll,
    )

    def pearl_round(stacked_params, stacked_opt, batches, xbar):
        # --- tau local steps per player, then the only cross-player
        # (pod-axis) collective: the across-player mean ---
        (new_p, new_o), new_xbar, metrics = round_fn(
            (stacked_params, stacked_opt), batches["tokens"], xbar
        )
        return new_p, new_o, new_xbar, metrics

    return pearl_round


@dataclasses.dataclass
class PearlCommReport:
    """Communication accounting for a PEARL training run (paper Section 3.1).

    ``bytes_per_scalar`` derives from the active sync dtype when not given
    explicitly: fp32 exact sync reports 4, a ``sync_dtype=jnp.bfloat16``
    compressed sync reports 2. The accounting is direction-aware and follows
    what :func:`tree_mean` actually does: players quantize BEFORE the
    reduction (uplink at the sync dtype) while the server broadcasts the f32
    mean (downlink at 4). An explicit ``bytes_per_scalar`` overrides both
    directions (legacy behavior). NOTE the dense engine's
    :class:`~repro.core.engine.QuantizedSync` compresses the opposite
    direction (broadcast quantized, uplink exact) — the two systems quantize
    different wires, and each accounting matches its own system.
    """

    n_players: int
    param_count: int
    tau: int
    rounds: int
    bytes_per_scalar: int | None = None
    sync_dtype: Any = None

    def __post_init__(self):
        self._explicit_bps = self.bytes_per_scalar is not None
        if self.bytes_per_scalar is None:
            self.bytes_per_scalar = (
                int(np.dtype(self.sync_dtype).itemsize)
                if self.sync_dtype is not None else 4
            )

    @property
    def downlink_bytes_per_scalar(self) -> int:
        """f32 mean broadcast unless an explicit override was given."""
        return self.bytes_per_scalar if self._explicit_bps else 4

    @classmethod
    def from_sync(cls, sync: SyncStrategy, *, n_players: int, param_count: int,
                  tau: int, rounds: int) -> "PearlCommReport":
        """Report for an engine sync strategy (exact or quantized)."""
        dtype = sync.dtype if isinstance(sync, QuantizedSync) else None
        return cls(n_players=n_players, param_count=param_count, tau=tau,
                   rounds=rounds, sync_dtype=dtype)

    @property
    def sync_bytes_per_round(self) -> int:
        # each player uploads its block (D_i = param_count) and downloads the
        # joint/mean vector: per the paper the downlink carries the full
        # concatenation; the consensus game needs only the mean (same size).
        up = self.n_players * self.param_count * self.bytes_per_scalar
        down = self.n_players * self.param_count * self.downlink_bytes_per_scalar
        return up + down

    def per_round_bytes(self) -> tuple[np.ndarray, np.ndarray]:
        """(uplink, downlink) byte arrays of shape ``(rounds,)`` — the same
        per-round shape :class:`repro.core.engine.PearlResult` records."""
        up = np.full(
            (self.rounds,),
            self.n_players * self.param_count * self.bytes_per_scalar,
            dtype=np.int64,
        )
        down = np.full(
            (self.rounds,),
            self.n_players * self.param_count * self.downlink_bytes_per_scalar,
            dtype=np.int64,
        )
        return up, down

    @property
    def total_bytes(self) -> int:
        return self.rounds * self.sync_bytes_per_round

    def vs_nonlocal(self) -> float:
        """Bytes ratio vs tau=1 for the same number of local steps."""
        return 1.0 / self.tau


class PearlTrainer:
    """Host-side loop around :func:`make_pearl_round` (small-scale/CPU runs)."""

    def __init__(self, cfg: ModelConfig, optimizer: Optimizer, *, n_players: int,
                 tau: int, prox_lambda: float, seed: int = 0, **round_kwargs):
        from repro.models.model import init_params

        self.cfg = cfg
        self.tau = tau
        self.n_players = n_players
        self.sync = _resolve_trainer_sync(round_kwargs.get("sync"),
                                          round_kwargs.get("sync_dtype"))
        keys = jax.random.split(jax.random.PRNGKey(seed), n_players)
        params = [init_params(cfg, k) for k in keys]
        self.params = stack_players(params)
        self.opt_state = jax.vmap(optimizer.init)(self.params)
        self.xbar = tree_mean(self.params)
        self._round = jax.jit(make_pearl_round(
            cfg, optimizer, tau=tau, prox_lambda=prox_lambda, **round_kwargs
        ))
        self.history: list[dict] = []

    def run(self, stream, rounds: int):
        """stream: SyntheticTokenStream with n_players configured."""
        import numpy as np

        step = 0
        for r in range(rounds):
            batches = np.stack([
                stream.player_batches(step + t) for t in range(self.tau)
            ], axis=1)  # (n, tau, B, S)
            self.params, self.opt_state, self.xbar, metrics = self._round(
                self.params, self.opt_state, {"tokens": jnp.asarray(batches)},
                self.xbar,
            )
            step += self.tau
            rec = {k: float(jnp.mean(v)) for k, v in metrics.items()}
            rec["round"] = r
            self.history.append(rec)
        return self.history

    def comm_report(self, rounds: int | None = None) -> PearlCommReport:
        """Byte accounting for this trainer's sync strategy over ``rounds``
        (defaults to the rounds run so far)."""
        from repro.roofline.analysis import count_params
        from repro.models.model import param_shapes

        return PearlCommReport.from_sync(
            self.sync,
            n_players=self.n_players,
            param_count=count_params(param_shapes(self.cfg)),
            tau=self.tau,
            rounds=len(self.history) if rounds is None else rounds,
        )
