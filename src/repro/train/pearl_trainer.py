"""PEARL-SGD for neural players — the paper's technique at production scale.

Each of ``n`` players/silos owns a full model (one per pod on the multi-pod
mesh) trained on its own heterogeneous data shard. The players are coupled
through the consensus game of paper Section 2.2:

    f_i(x^i; x^{-i}) = h_i(x^i) + (lambda/2) ||x^i - mean_j x^j||^2,

whose first-order conditions are exactly an n-player equilibrium — the MpFL
instance we scale up. PEARL-SGD (Algorithm 1) becomes:

  - tau local steps per round: each player minimizes its LM loss plus the
    proximal pull toward the *stale* across-player mean (snapshot at the
    last synchronization) — zero cross-player communication;
  - one synchronization per round: recompute the across-player mean. On the
    production mesh, player = pod, so this mean is THE only ``pod``-axis
    collective; every step of the tau-step inner scan stays pod-local.

This module is the neural-player adapter over the unified engine: the
"tau local steps under vmap, then one collective" round template comes from
:func:`repro.core.engine.make_federated_round` (the same structure the dense
:class:`~repro.core.engine.PearlEngine` compiles for vector games), the wire
quantization comes from the engine's :class:`~repro.core.engine.SyncStrategy`
objects, and :class:`PearlCommReport` derives its bytes-per-scalar from the
active sync dtype instead of hard-coding fp32.

Synchronization is a general **stale-block merge** over the stacked-player
pytree, so every engine communication regime works for neural players too:

- the server keeps a per-player ``snapshot`` (each player's last transmitted
  parameters); participants overwrite their slot, non-participants' stale
  blocks survive — mask strategies (:class:`PartialParticipation`,
  :class:`DropoutSync`) compose with any topology;
- each player's proximal reference is a :class:`~repro.core.topology.Topology`
  mixing row over the snapshot: ``ref_i = sum_j W_ij snapshot_j``. The
  :class:`~repro.core.topology.Star` server is the ``W = ones/n`` special
  case (exact across-player mean); a ring/torus/random graph pulls each
  player toward its neighborhood mean instead — decentralized consensus. The
  consensus game is *aggregative* (the gradient needs only the reference, not
  individual opponents), so gossip messages carry one parameter block per
  edge: a player moves ``deg(i)`` model-sizes per round instead of the star
  downlink's full mean — the edge-aware accounting in
  :meth:`PearlCommReport.per_round_bytes`.

Unlike the dense engine (where a non-participating player's round is
discarded, matching the paper's participation model), neural players always
keep training locally — the mask gates only the wire: non-participants
neither upload their block nor receive a fresh reference.

The non-local baseline (SGDA / gradient play, tau = 1) synchronizes every
step; the paper's claim — same accuracy with tau-fold less communication —
shows up in the dry-run HLO as a tau-fold drop in pod-axis collective bytes
per local step (EXPERIMENTS.md Section Perf).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.engine import (
    ExactSync,
    QuantizedSync,
    SyncStrategy,
    make_federated_round,
    resolve_sync,
)
from repro.core.spec import (
    EngineSpec,
    merge_trainer_spec,
    resolve_stale_sync,
    validate_spec,
    validate_tree_mean,
    validate_tree_mean_lowbit,
    warn_legacy,
)
from repro.core.stepsize import (
    RoundContext,
    StepsizePolicy,
    Theorem34Policy,
    resolve_policy,
)
from repro.core.topology import (
    Star,
    Topology,
    direction_itemsizes,
    gossip_round_bytes,
    spectral_gap,
    star_round_bytes,
)
from repro.optim.optimizers import Optimizer, apply_updates, clip_by_global_norm
from repro.train.train_step import make_loss_fn

Array = jax.Array


def tree_mean(stacked, axis: int = 0, sync_dtype=None,
              sync: SyncStrategy | None = None, *, mesh=None,
              mesh_axis: str = "players", mesh_inner_specs=None):
    """Across-player parameter mean — the PEARL synchronization collective.

    The wire representation is delegated to the engine's sync strategy:
    ``QuantizedSync(jnp.bfloat16)`` (or the ``sync_dtype`` shorthand)
    quantizes the operands BEFORE the cross-player reduction, so the pod-axis
    collective moves half (or less) the bytes — the paper's "gradient
    compression" future-work item composed with local steps: wire bytes fall
    by tau x (32/bits). Convergence-wise this adds bounded quantization noise
    to the stale snapshot, absorbed by Theorem 3.4's sigma^2 term (validated
    in tests/test_pearl_trainer.py).

    With ``mesh=None`` (the host path) the quantized wire is an *intent*,
    not a property of the compiled program: XLA reassociates the convert
    around its f32 reduction accumulator, so the compiled cross-pod wire
    stays f32 (the Section Perf negative result, PR 1–4). Passing a ``mesh``
    (player dimension on ``mesh_axis``, e.g.
    :func:`repro.core.collective.player_mesh` or the production mesh with
    ``mesh_axis="pod"``) dispatches to
    :func:`repro.core.collective.sharded_tree_mean`, which lowers the sync
    to an explicit shard_map collective over the wire *bit pattern* — the
    compressed representation provably survives to the HLO wire (asserted
    in tests/test_collective.py). The no-mesh branch resolves at trace time
    and compiles the identical legacy program.
    """
    strategy = resolve_sync(sync, sync_dtype)
    validate_tree_mean(strategy, axis, mesh)
    if mesh is not None:
        from repro.core.collective import sharded_tree_mean

        return sharded_tree_mean(stacked, mesh=mesh, sync=strategy,
                                 axis_name=mesh_axis,
                                 inner_specs=mesh_inner_specs)
    quantized = isinstance(strategy, QuantizedSync)

    def mean(x):
        if quantized:
            # Quantize then reduce. NOTE: on this host path XLA reassociates
            # the convert around its f32 reduction accumulator, so the
            # compiled cross-pod wire stays f32 in the dry-run HLO — pass
            # mesh= to lower the collective explicitly and keep the bf16
            # wire (repro.core.collective; Section Perf records both
            # measurements). The convergence semantics (bounded quantization
            # noise) hold either way and are what the tests validate.
            return jnp.mean(strategy.compress(x), axis=axis).astype(jnp.float32)
        return jnp.mean(x, axis=axis, dtype=jnp.float32)

    return jax.tree.map(mean, stacked)


def tree_mean_lowbit(stacked, wire_state, sync, *, mesh=None,
                     mesh_axis: str = "players", mesh_inner_specs=None):
    """Across-player mean over a low-bit error-feedback wire, for pytrees.

    The engine's ``Int8Sync``/``Int4Sync`` wire, extended from ``(n, d)``
    matrices to player-stacked param pytrees: each leaf ``(n, ...)`` is
    flattened per player to ``(n, D_leaf)`` so the strategy's last-axis
    block scale becomes one f32 scale per (player, leaf). The transmit
    tensor is ``t = x + e`` (``e`` the carried residual, zero for
    ``error_feedback=False``), the wire moves ``roundtrip(t)``, and the new
    residual ``e' = t - roundtrip(t)`` is returned for the caller to carry
    across rounds — the trainer threads it through the jitted round.

    With a ``mesh`` the reduction goes through
    :func:`repro.core.collective.sharded_tree_mean`, whose ``LowBitCodec``
    ``decode(encode(t))`` is bit-identical to ``roundtrip(t)`` — so the
    compiled collective's operand is the single u8 payload (scale bytes ++
    lanes), asserted on dry-run HLO. The flattened ``(n, D)`` wire has no
    within-player axes, so ``mesh_inner_specs`` is accepted for signature
    symmetry but the gather itself is player-axis only.

    Returns ``(mean, new_wire_state)``; ``mean`` matches the shape of one
    player's pytree, f32.
    """
    del mesh_inner_specs   # the flattened wire has no inner axes to thread
    validate_tree_mean_lowbit(sync)
    stateful = sync.has_wire_state

    t_flat = jax.tree.map(
        lambda x, e: (x + e).reshape(x.shape[0], -1) if stateful
        else x.reshape(x.shape[0], -1),
        stacked, wire_state if stateful else stacked,
    )
    rt = jax.tree.map(sync.roundtrip, t_flat)
    if mesh is None:
        mean = jax.tree.map(
            lambda r, x: jnp.mean(r, axis=0, dtype=jnp.float32).reshape(
                x.shape[1:]), rt, stacked)
    else:
        from repro.core.collective import sharded_tree_mean

        mean_flat = sharded_tree_mean(t_flat, mesh=mesh, sync=sync,
                                      axis_name=mesh_axis)
        mean = jax.tree.map(lambda m, x: m.reshape(x.shape[1:]),
                            mean_flat, stacked)
    if not stateful:
        return mean, wire_state
    new_state = jax.tree.map(
        lambda t, r, x: (t - r).reshape(x.shape), t_flat, rt, stacked)
    return mean, new_state


def stack_players(params_list):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *params_list)


def needs_general_round(strategy: SyncStrategy, topology: Topology) -> bool:
    """The legacy star round (one replicated mean, everyone participates) is
    enough iff the topology is the server and the strategy draws no mask."""
    return (not topology.is_server) or strategy.uses_mask


def _per_player(mask, like):
    """Broadcast a (n,) mask against a stacked leaf (n, ...)."""
    return mask.reshape((-1,) + (1,) * (like.ndim - 1))


def _make_pearl_round(
    cfg: ModelConfig,
    optimizer: Optimizer,
    *,
    tau: int,
    prox_lambda: float,
    aux_weight: float = 0.01,
    clip_norm: float = 1.0,
    window: int = 0,
    use_kernels: bool = False,
    unroll: bool = False,
    sync_dtype=None,
    sync: SyncStrategy | None = None,
    topology: Topology | None = None,
    external_refs: bool = False,
    policy: StepsizePolicy | str | None = None,
    mesh=None,
    mesh_axis: str = "players",
    mesh_inner_specs=None,
    view=None,
) -> Callable:
    """Build one compiled PEARL round on the engine's federated-round template.

    Star topology with full participation (the default) keeps the legacy
    signature and numerics — ``pearl_round(stacked_params, stacked_opt,
    batches, xbar)``:
      - stacked_params/opt: player-stacked pytrees, leading dim n (sharded
        over ``pod`` on the production mesh);
      - batches: {"tokens": (n, tau, B_local, S)} — tau local batches per
        player drawn from that player's distribution D_i;
      - xbar: stale across-player mean (pytree, replicated).
    Returns (new_params, new_opt, new_xbar, metrics). ``new_xbar`` is the
    synchronization output; in PEARL it is computed once per round.

    Any mask strategy or graph topology compiles the general stale-block
    merge round instead — ``pearl_round(stacked_params, stacked_opt,
    batches, refs, snapshot, mask, mix)``:
      - refs: player-stacked references (each player's own stale
        neighborhood mean, leading dim n);
      - snapshot: player-stacked last-transmitted parameters;
      - mask: (n,) bool — who synchronizes this round (drawn host-side by
        the strategy so the compiled round stays deterministic);
      - mix: (n, n) mixing-matrix row weights for this round (host-supplied
        so time-varying graphs never retrace).
    Returns (new_params, new_opt, new_refs, new_snapshot, metrics), where
    participants' snapshot slots take their freshly compressed blocks
    (stale blocks survive) and their refs re-mix over the merged snapshot.

    A non-identity ``policy`` (:class:`~repro.core.stepsize.StepsizePolicy`)
    appends one argument to the general round — ``gamma_scale``, an ``(n,)``
    per-player step-size multiplier the HOST computes each round from the
    policy and the realized staleness counters (the policies are relative
    corrections to the base rate, which here lives inside the optimizer, so
    the round applies them as multipliers on the optimizer's update). Only
    the general round supports it: a policy that conditions on staleness
    needs the async host loop's counters, and the spectral policy needs a
    graph topology — both imply the general round; mismatches are rejected
    here so the compiled round can never silently ignore a policy.

    A ``mesh`` (player dimension on ``mesh_axis`` — ``"pod"`` on the
    production multi-pod mesh, where player = pod) lowers the round's
    cross-player communication through the explicit shard_map collectives
    of :mod:`repro.core.collective`, so a ``QuantizedSync`` (or low-bit)
    wire provably stays compressed in the compiled HLO. The star fast path
    goes through :func:`~repro.core.collective.sharded_tree_mean`; the
    general stale-block merge through
    :func:`~repro.core.collective.sharded_stale_merge` — per-player params,
    refs, and mixing rows are sharded carries on the player axis, the
    host-drawn mask and the snapshot enter replicated, and the one
    all-gather ships participants' freshly encoded blocks with masked slots
    zeroed. ``mesh_inner_specs`` optionally carries the per-leaf
    PartitionSpecs of the non-player dims (the launcher's tensor-parallel
    layout) so the collectives cross only the player axis. The host loop is
    still chosen in two places: ``mesh=None`` compiles the identical legacy
    program (trace-time branch, pinned collective-free), and the async
    reference refresh (``external_refs=True``) stays host logic — its
    in-round merge is purely elementwise (participants overwrite their own
    snapshot block; no cross-player collective is needed until the
    host-side delayed re-mix), so that round compiles under a mesh as plain
    sharded SPMD with no in-round wire at all.

    ``view`` names the reference axis in the engines' ``JointView``
    vocabulary. The consensus game is aggregative, so the star fast path
    ALREADY IS the O(d) mean-field wire (players receive the across-player
    mean, never the ``(n, d)`` joint — :class:`PearlCommReport` bills one
    block of downlink): ``view=MeanFieldView(self_correction=False)``
    declares exactly that and is the only explicit view accepted, on the
    fast path only. Views the trainer has no wire for — ``StarView``'s
    full-joint broadcast, corrected/second-moment/sampled summaries, or a
    summary over the general stale-block round's partial/stale snapshot —
    are rejected loudly rather than silently renamed.
    """
    if tau < 1:
        # a zero-length inner scan would silently return the players
        # unchanged — same eager validation as the dense engine's
        # validate_round_args / stepsize.gamma_constant
        raise ValueError(f"tau must be >= 1, got {tau}")
    strategy = resolve_sync(sync, sync_dtype)
    topo = topology if topology is not None else Star()
    policy = resolve_policy(policy)
    scaled = not isinstance(policy, Theorem34Policy)
    # THE compatibility matrix (repro.core.spec) raises every composition
    # rejection for this round — before any model state is touched, so the
    # configuration is known valid before cfg is consulted
    validate_spec(
        EngineSpec(sync=strategy, topology=topo, policy=policy, view=view,
                   mesh=mesh, mesh_axis=mesh_axis),
        trainer=True, external_refs=external_refs,
        staleness_available=external_refs,
        policy_remedy="construct PearlTrainer with delays/max_staleness "
                      "(the event-shaped host loop supplies the counters)",
    )
    loss_fn = make_loss_fn(cfg, aux_weight=aux_weight, window=window,
                           use_kernels=use_kernels, prox_lambda=prox_lambda)

    def local_step(carry, tokens, bcast):
        """One optimizer step of a single player against its frozen reference."""
        ref, scale = bcast if scaled else (bcast, None)
        p, o = carry
        (_, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            p, {"tokens": tokens}, ref
        )
        if clip_norm:
            grads = clip_by_global_norm(grads, clip_norm)
        updates, o = optimizer.update(grads, o, p)
        if scaled:
            # the policy's per-player multiplier on the optimizer's update —
            # the step-size correction relative to the base learning rate
            updates = jax.tree.map(lambda u: scale * u, updates)
        p = apply_updates(p, updates)
        return (p, o), metrics

    # ``external_refs`` compiles the stale-block merge round even when the
    # star fast path would suffice, and skips the in-round reference re-mix:
    # the async trainer refreshes references host-side from DELAYED
    # snapshots, so computing fresh ones here would be wasted work.
    if not external_refs and not needs_general_round(strategy, topo):
        if hasattr(strategy, "wire_encode"):
            # Low-bit wire: the sync is stateful (error-feedback residual),
            # so the round carries wire_state explicitly — signature
            # pearl_round(params, opt, batches, xbar, wire_state) returning
            # (..., new_wire_state, metrics).
            round_fn = make_federated_round(
                local_step, lambda stacked: None, unroll=unroll,
            )

            def pearl_round(stacked_params, stacked_opt, batches, xbar,
                            wire_state):
                (new_p, new_o), _, metrics = round_fn(
                    (stacked_params, stacked_opt), batches["tokens"], xbar
                )
                new_xbar, new_state = tree_mean_lowbit(
                    new_p, wire_state, strategy, mesh=mesh,
                    mesh_axis=mesh_axis, mesh_inner_specs=mesh_inner_specs,
                )
                return new_p, new_o, new_xbar, new_state, metrics

            return pearl_round

        round_fn = make_federated_round(
            local_step,
            lambda stacked: tree_mean(stacked[0], sync=strategy, mesh=mesh,
                                      mesh_axis=mesh_axis,
                                      mesh_inner_specs=mesh_inner_specs),
            unroll=unroll,
        )

        def pearl_round(stacked_params, stacked_opt, batches, xbar):
            # --- tau local steps per player, then the only cross-player
            # (pod-axis) collective: the across-player mean ---
            (new_p, new_o), new_xbar, metrics = round_fn(
                (stacked_params, stacked_opt), batches["tokens"], xbar
            )
            return new_p, new_o, new_xbar, metrics

        return pearl_round

    # General stale-block merge: per-player references (broadcast_in_axes=0),
    # the collective replaced by mask-merge + topology mixing.
    round_fn = make_federated_round(
        local_step, lambda stacked: None, unroll=unroll, broadcast_in_axes=0,
    )

    def pearl_round(stacked_params, stacked_opt, batches, refs, snapshot,
                    mask, mix, gamma_scale=None):
        if scaled and gamma_scale is None:
            raise ValueError(
                f"this round was compiled with {type(policy).__name__}: "
                f"pass the (n,) per-player gamma_scale the host computed"
            )
        bcast = (refs, gamma_scale) if scaled else refs
        (new_p, new_o), _, metrics = round_fn(
            (stacked_params, stacked_opt), batches["tokens"], bcast
        )
        if mesh is not None and not external_refs:
            # Mesh lowering of the merge below: one all-gather of the
            # participants' encoded blocks (masked slots zeroed) at the
            # wire dtype, merge + per-row re-mix computed device-local.
            # decode(encode(x)) is bit-identical to compress(x).astype, so
            # the host/mesh trajectories differ by reduction order only.
            from repro.core.collective import sharded_stale_merge

            new_refs, new_snapshot = sharded_stale_merge(
                new_p, snapshot, refs, mask, mix, mesh=mesh, sync=strategy,
                axis_name=mesh_axis, inner_specs=mesh_inner_specs,
            )
            return new_p, new_o, new_refs, new_snapshot, metrics
        # Participants put their freshly quantized block on the wire; the
        # stale blocks of everyone else survive in the snapshot.
        wire = jax.tree.map(
            lambda p: strategy.compress(p).astype(p.dtype), new_p
        )
        new_snapshot = jax.tree.map(
            lambda w, s: jnp.where(_per_player(mask, w), w, s),
            wire, snapshot,
        )
        if external_refs:
            # the host loop refreshes references itself (from delayed
            # snapshots); return them unchanged
            return new_p, new_o, refs, new_snapshot, metrics
        # Each participant re-mixes its reference over the merged snapshot
        # (star: the exact mean row ones/n); non-participants keep their
        # stale reference — they received nothing this round.
        mixed = jax.tree.map(
            lambda s: jnp.einsum("ij,j...->i...", mix.astype(s.dtype), s),
            new_snapshot,
        )
        new_refs = jax.tree.map(
            lambda mx, r: jnp.where(_per_player(mask, mx), mx, r),
            mixed, refs,
        )
        return new_p, new_o, new_refs, new_snapshot, metrics

    return pearl_round


def make_pearl_round(cfg, optimizer, **kwargs):
    """Deprecated public entry to the compiled-round builder.

    Identical behavior to the internal builder (the pins hold bit-for-bit);
    it only adds a one-time :class:`DeprecationWarning` pointing new code at
    :class:`PearlTrainer` + :class:`repro.core.spec.EngineSpec`, which own
    the host state (masks, staleness counters, wire residuals) this raw
    round makes the caller thread by hand. See README "Migrating to
    EngineSpec"."""
    warn_legacy(
        "make_pearl_round",
        "construct PearlTrainer(..., spec=EngineSpec(sync=..., "
        "topology=..., policy=..., view=..., mesh=...)) — it compiles the "
        "same round and owns the host-side state",
    )
    return _make_pearl_round(cfg, optimizer, **kwargs)


@dataclasses.dataclass
class PearlCommReport:
    """Communication accounting for a PEARL training run (paper Section 3.1).

    ``bytes_per_scalar`` derives from the active sync dtype when not given
    explicitly: fp32 exact sync reports 4, a ``sync_dtype=jnp.bfloat16``
    compressed sync reports 2. The accounting is direction-aware and follows
    what :func:`tree_mean` actually does: players quantize BEFORE the
    reduction (uplink at the sync dtype) while the server broadcasts the f32
    mean (downlink at 4) — i.e. :func:`repro.core.topology.direction_itemsizes`
    with ``compressed="up"``, the shared helper through which the dense
    engine also resolves its (opposite, ``compressed="down"``) asymmetry: the
    two systems quantize different wires, and each accounting names its
    direction through the one helper (pinned in tests/test_topology.py). An
    explicit ``bytes_per_scalar`` overrides both directions (legacy
    behavior).

    A server-free ``topology`` switches to edge-aware gossip accounting: the
    consensus game is aggregative, so each active directed edge moves ONE
    parameter block — ``deg(i) * param_count`` scalars per player per round
    instead of the star downlink's ``n_players * param_count``.

    Participation-aware billing: ``participants`` (per-round uploads under
    star) and ``messages`` (per-round directed active links under gossip)
    override the full-participation defaults — :meth:`PearlTrainer.comm_report`
    passes the actually-drawn mask history, so a ``PartialParticipation``
    trainer is billed for what it moved, matching the dense engine's
    participant-aware :class:`~repro.core.engine.PearlResult` (lossy
    ``bills_full_round`` strategies keep full billing).
    """

    n_players: int
    param_count: int
    tau: int
    rounds: int
    bytes_per_scalar: int | None = None
    sync_dtype: Any = None
    topology: Topology | None = None
    participants: Any = None   # (rounds,) billed uploads; None = everyone
    messages: Any = None       # (rounds,) billed gossip links; None = all edges
    sync: Any = None           # full strategy (low-bit wires resolve via it)
    blocks_per_player: int = 1  # pytree leaves per upload (scale overhead)

    def __post_init__(self):
        explicit = self.bytes_per_scalar is not None
        if explicit:
            up = down = int(self.bytes_per_scalar)
        elif self.sync is not None:
            up, down = direction_itemsizes(self.sync, 4, compressed="up")
        else:
            strategy = (QuantizedSync(self.sync_dtype)
                        if self.sync_dtype is not None else ExactSync())
            up, down = direction_itemsizes(strategy, 4, compressed="up")
        self.bytes_per_scalar = up
        self._down_bps = down
        # low-bit wires bill one f32 scale per transmitted leaf on top of
        # the lane payload (the engine's wire_overhead_bytes_per_block, with
        # block = flattened param leaf here); zero for every other strategy
        per_block = (getattr(self.sync, "wire_overhead_bytes_per_block", 0)
                     if not explicit else 0)
        self.uplink_overhead_bytes = int(self.blocks_per_player * per_block)

    @property
    def downlink_bytes_per_scalar(self) -> int:
        """f32 mean broadcast unless an explicit override was given."""
        return self._down_bps

    @classmethod
    def from_sync(cls, sync: SyncStrategy, *, n_players: int, param_count: int,
                  tau: int, rounds: int, topology: Topology | None = None,
                  participants=None, messages=None,
                  blocks_per_player: int = 1) -> "PearlCommReport":
        """Report for an engine sync strategy under a topology."""
        dtype = sync.dtype if isinstance(sync, QuantizedSync) else None
        lowbit = sync if hasattr(sync, "wire_encode") else None
        return cls(n_players=n_players, param_count=param_count, tau=tau,
                   rounds=rounds, sync_dtype=dtype, topology=topology,
                   participants=participants, messages=messages,
                   sync=lowbit, blocks_per_player=blocks_per_player)

    @property
    def sync_bytes_per_round(self) -> int:
        if self.rounds == 0:
            return 0
        up, down = self.per_round_bytes()
        return int(up[0] + down[0])

    def per_round_bytes(self) -> tuple[np.ndarray, np.ndarray]:
        """(uplink, downlink) byte arrays of shape ``(rounds,)`` — the same
        per-round shape :class:`repro.core.engine.PearlResult` records, via
        the same :mod:`repro.core.topology` helpers."""
        topo = self.topology if self.topology is not None else Star()
        if topo.is_server:
            # each player uploads its block (D_i = param_count) and downloads
            # the joint/mean vector: per the paper the downlink carries the
            # full concatenation; the consensus game needs only the mean
            # (same size).
            if self.participants is not None:
                billed = np.asarray(self.participants)
            else:
                billed = np.full((self.rounds,), self.n_players)
            up, down = star_round_bytes(
                billed,
                n=self.n_players, block_scalars=self.param_count,
                up_itemsize=self.bytes_per_scalar,
                down_itemsize=self.downlink_bytes_per_scalar,
                down_blocks=1,   # the server rebroadcasts only the mean
            )
            # per-leaf f32 scales ride the uplink of each billed upload
            up = up + billed * self.uplink_overhead_bytes
            return up, down
        if self.messages is not None:
            msgs = np.asarray(self.messages)
        else:
            edges = topo.directed_edge_counts(self.n_players)
            msgs = edges[np.arange(self.rounds) % len(edges)]
        up, down = gossip_round_bytes(
            msgs, payload_blocks=1, block_scalars=self.param_count,
            itemsize=self.bytes_per_scalar,
        )
        # stateless low-bit relays carry their per-leaf scales per message
        return up + msgs * self.uplink_overhead_bytes, down

    @property
    def total_bytes(self) -> int:
        up, down = self.per_round_bytes()
        return int(up.sum() + down.sum())

    def vs_nonlocal(self) -> float:
        """Bytes ratio vs tau=1 for the same number of local steps."""
        return 1.0 / self.tau


class PearlTrainer:
    """Host-side loop around :func:`make_pearl_round` (small-scale/CPU runs).

    Star topology with full participation keeps the legacy xbar-carry loop;
    any mask strategy or graph topology threads the general stale-block
    state instead: ``snapshot`` (per-player last-transmitted parameters),
    ``refs`` (per-player stale neighborhood means), a host-drawn per-round
    participation mask, and the round's mixing matrix (cycled for
    time-varying graphs). ``xbar`` stays available either way as the uniform
    across-player mean of the latest snapshot (diagnostics/back-compat).

    **Asynchronous rounds** (``delays`` + ``max_staleness``, or a
    :class:`~repro.core.async_engine.StaleSync` as ``sync``) run the same
    event-shaped loop as :class:`~repro.core.async_engine.AsyncPearlEngine`:
    players always submit on time (their fresh blocks merge into the
    snapshot at each sync they participate in), but the *reference* a player
    receives back is the topology mix over the snapshot as it stood
    ``delay`` rounds ago — merge-on-arrival into the stale-block machinery,
    with a host-side ring buffer of the last ``max_staleness + 1`` merged
    snapshots. Per-player round counters (``player_rounds``,
    ``player_snapshot_round``) record how many syncs each player merged and
    which round's broadcast it last saw; ``staleness_log`` keeps the
    realized delay table. ``max_staleness = 0`` with full participation
    reproduces the lockstep stale-block round.

    A **step-size policy** (``policy=`` — name or
    :class:`~repro.core.stepsize.StepsizePolicy`) scales each player's
    optimizer update per round: the host computes the ``(n,)`` multiplier
    row from the policy and the *actual* per-player reference-staleness
    counters (``_ref_delays`` — the history-clipped delay each player's
    current reference realized, aging +1 per round sat out), then feeds it
    to the compiled round. ``delay_adaptive`` requires the async loop
    (those counters), ``spectral`` requires a graph topology (and a
    caller-supplied ``coupling`` estimate — the neural consensus game has
    no closed-form constants); mismatches raise at construction.

    A ``mesh=`` keyword (forwarded to :func:`make_pearl_round`) lowers the
    round's cross-player communication under shard_map with an explicit
    wire dtype — see :mod:`repro.core.collective`. The star fast path goes
    through ``sharded_tree_mean``; masks, graph topologies, and the async
    loop compile the general merge through ``sharded_stale_merge`` (the
    host still draws masks, refreshes delayed references, and bills bytes —
    the lowering changes where the merge arithmetic runs, not the
    semantics, so accounting is identical across lowerings).

    A low-bit ``sync`` (``Int8Sync``/``Int4Sync``) on the star fast path
    threads the error-feedback residual through the jitted round
    (:func:`tree_mean_lowbit`) and bills the per-leaf f32 scale overhead;
    the general merge accepts only the stateless (``error_feedback=False``)
    variant.
    """

    def __init__(self, cfg: ModelConfig, optimizer: Optimizer, *, n_players: int,
                 tau: int, prox_lambda: float, seed: int = 0,
                 topology: Topology | None = None, delays=None,
                 max_staleness: int = 0,
                 policy: StepsizePolicy | str | None = None,
                 coupling: float = 1.0, spec: EngineSpec | None = None,
                 **round_kwargs):
        from repro.models.model import init_params

        self.cfg = cfg
        self.tau = tau
        self.n_players = n_players
        # spec= is sugar over the legacy kwargs (same two-sources-of-truth
        # rule as the engines; update/gossip_steps have no trainer analog)
        topology, policy, round_kwargs = merge_trainer_spec(
            spec, topology=topology, policy=policy,
            round_kwargs=round_kwargs)
        sync_arg = round_kwargs.get("sync")
        # the StaleSync spelling: the delay model travels with the
        # strategy; the inner strategy supplies the wire semantics
        inner, delays, max_staleness = resolve_stale_sync(
            sync_arg, delays, max_staleness)
        if inner is not sync_arg:
            round_kwargs["sync"] = inner
        self.sync = resolve_sync(round_kwargs.get("sync"),
                                 round_kwargs.get("sync_dtype"))
        self.topology = topology if topology is not None else Star()
        self.policy = resolve_policy(policy)
        self._async = delays is not None
        # THE compatibility matrix (repro.core.spec) raises every
        # composition rejection for this trainer — including selection
        # validation, which runs with mesh=None regardless of the round's
        # mesh kwarg: the trainer's general merge is the ONE mask-aware
        # mesh lowering (sharded_stale_merge ships masked_payload zero-bit
        # rows).
        validate_spec(
            EngineSpec(sync=self.sync, topology=self.topology,
                       policy=self.policy, view=round_kwargs.get("view")),
            trainer=True, trainer_init=True, delays=delays,
            max_staleness=max_staleness, external_refs=self._async,
            staleness_available=self._async,
            policy_remedy="construct the trainer with delays/max_staleness "
                          "(or a StaleSync)",
            coupling=coupling,
        )
        self.delays = delays
        self.max_staleness = int(max_staleness)
        # stateful selection policies (core/selection.py): host-side state,
        # masks drawn by select() from observed per-player param deltas
        self._selection = getattr(self.sync, "stateful_selection", False)
        self._general = (needs_general_round(self.sync, self.topology)
                         or self._async)
        self._policy_active = not isinstance(self.policy, Theorem34Policy)
        gap = (1.0 if self.topology.is_server
               else float(spectral_gap(self.topology.mixing_matrix(n_players))))
        # the neural consensus game publishes no closed-form constants, so
        # the coupling ratio L_F/L_max is caller-supplied (1.0 = uncoupled)
        self._ss_ctx = RoundContext(tau=tau, max_staleness=self.max_staleness,
                                    spectral_gap=gap, coupling=float(coupling))
        # staleness (in rounds) carried by each player's CURRENT reference —
        # the "actual counters" a delay-adaptive policy conditions on (the
        # history-clipped realized delay, aging +1 while a player sits out)
        self._ref_delays = np.zeros(n_players, dtype=np.int64)
        keys = jax.random.split(jax.random.PRNGKey(seed), n_players)
        params = [init_params(cfg, k) for k in keys]
        self.params = stack_players(params)
        self.opt_state = jax.vmap(optimizer.init)(self.params)
        self.xbar = tree_mean(self.params)
        self._round = jax.jit(_make_pearl_round(
            cfg, optimizer, tau=tau, prox_lambda=prox_lambda,
            topology=self.topology, external_refs=self._async,
            policy=self.policy, **round_kwargs
        ))
        self._lowbit = (not self._general
                        and hasattr(self.sync, "wire_encode"))
        if self._lowbit:
            # error-feedback residual (zeros when error_feedback=False, in
            # which case the round returns it unchanged)
            self._wire_state = jax.tree.map(jnp.zeros_like, self.params)
        if self._general:
            # init acts as round 0's broadcast: everyone's block is known
            self.snapshot = self.params
            self._mixes = self.topology.mixing_stack(n_players)
            self._adjs = self.topology.adjacency_stack(n_players)
            self.refs = self._mix_refs(0)
            self._sync_state = (self.sync.select_state(n_players)
                                if self._selection
                                else self.sync.init_state())
        if self._async:
            # ring buffer of merged snapshots, newest first: index =
            # staleness in rounds (slot 0 is the current snapshot)
            self._snap_hist = [self.snapshot]
            self.player_rounds = np.zeros(n_players, dtype=np.int64)
            self.player_snapshot_round = np.full(n_players, -1,
                                                 dtype=np.int64)
            self.staleness_log: list[np.ndarray] = []
        self._global_round = 0
        # per-round billing records (what the drawn masks actually moved)
        self._round_participants: list[int] = []
        self._round_messages: list[int] = []
        self.history: list[dict] = []

    def _mix_refs(self, round_idx: int):
        mix = jnp.asarray(self._mixes[round_idx % len(self._mixes)])
        return jax.tree.map(
            lambda s: jnp.einsum("ij,j...->i...", mix.astype(s.dtype), s),
            self.snapshot,
        )

    def _draw_mask(self) -> Array:
        if self._selection:
            # the trainer analog of the async engine's drawn delay row is
            # the staleness the refs consumed THIS round actually carry
            delay_row = (jnp.asarray(self._ref_delays, jnp.float32)
                         if self._async else None)
            self._sync_state, m = self.sync.select(
                self._sync_state, self.n_players, self._global_round,
                delay_row)
            return m
        self._sync_state, ctx = self.sync.pre_round(self._sync_state)
        m = self.sync.mask(self.n_players, ctx)
        if m is None:
            m = jnp.ones((self.n_players,), dtype=bool)
        return m

    def _observe_selection(self, mask, prev_params):
        """Fold the round's realized per-player parameter movement into the
        selection policy's value estimates (flattened ``(n, D)`` deltas;
        non-participants are zeroed inside the Shapley scorer)."""
        new_l = jax.tree.leaves(self.params)
        old_l = jax.tree.leaves(prev_params)
        delta = jnp.concatenate(
            [(a - b).reshape(self.n_players, -1)
             for a, b in zip(new_l, old_l)], axis=1)
        self._sync_state = self.sync.observe(
            self._sync_state, mask, delta, self._global_round)

    def _refresh_stale_refs(self, delay_row: np.ndarray, round_idx: int,
                            arrived_mask: np.ndarray):
        """Merge-on-arrival reference refresh over DELAYED snapshots.

        Each arriving player ``i`` receives
        ``mix_row_i @ snapshot_history[delay_row[i]]`` — the broadcast as it
        stood ``delay_row[i]`` rounds ago (clipped to the history actually
        recorded); everyone else keeps its old reference. Arrivals are
        grouped by delay and only their mix ROWS are computed against that
        group's snapshot (at most one mixed row per arriving player, none
        for the rest), then rows are gathered back into player order.
        Returns ``(new_refs, effective_delays)`` — the latter is the
        history-clipped staleness each player actually realized.
        """
        mix = jnp.asarray(self._mixes[round_idx % len(self._mixes)])
        effective = np.minimum(np.asarray(delay_row, dtype=np.int64),
                               len(self._snap_hist) - 1)
        groups: dict[int, list[int]] = {}
        stay = []
        for i in range(self.n_players):
            if arrived_mask[i]:
                groups.setdefault(int(effective[i]), []).append(i)
            else:
                stay.append(i)
        order = np.empty(self.n_players, dtype=np.int64)
        pieces, pos = [], 0
        for k, idx in sorted(groups.items()):
            rows = jnp.asarray(np.asarray(idx))
            pieces.append(jax.tree.map(
                lambda s: jnp.einsum("ij,j...->i...",
                                     mix[rows].astype(s.dtype), s),
                self._snap_hist[k],
            ))
            order[idx] = pos + np.arange(len(idx))
            pos += len(idx)
        if stay:
            keep = jnp.asarray(np.asarray(stay))
            pieces.append(jax.tree.map(lambda r: r[keep], self.refs))
            order[stay] = pos + np.arange(len(stay))
        perm = jnp.asarray(order)
        new_refs = jax.tree.map(
            lambda *ls: jnp.concatenate(ls, axis=0)[perm], *pieces)
        return new_refs, effective

    def run(self, stream, rounds: int):
        """stream: SyntheticTokenStream with n_players configured."""
        import numpy as np

        if rounds < 1:
            raise ValueError(f"rounds must be >= 1, got {rounds}")
        delay_table = None
        if self._async:
            from repro.core.async_engine import draw_delay_table

            # start at the persistent global round so a second run() call
            # continues the schedule instead of replaying it from round 0;
            # one extra row because the refs built at the END of local round
            # r are consumed in global round g+1 and so carry ITS delay
            delay_table = draw_delay_table(
                self.delays, rounds + 1, self.n_players, self.max_staleness,
                start=self._global_round,
            )
        step = 0
        for r in range(rounds):
            batches = np.stack([
                stream.player_batches(step + t) for t in range(self.tau)
            ], axis=1)  # (n, tau, B, S)
            tokens = {"tokens": jnp.asarray(batches)}
            if self._general:
                g = self._global_round
                mask = self._draw_mask()
                m_np = np.asarray(mask)
                self._round_participants.append(int(m_np.sum()))
                adj = self._adjs[g % len(self._adjs)]
                self._round_messages.append(
                    int((adj & np.outer(m_np, m_np)).sum()))
                mix = jnp.asarray(self._mixes[g % len(self._mixes)])
                round_args = (self.params, self.opt_state, tokens, self.refs,
                              self.snapshot, mask, mix)
                if self._policy_active:
                    # per-player multiplier from the staleness the refs being
                    # consumed THIS round actually carry (host counters)
                    scale = self.policy.round_gammas(
                        1.0, self._ss_ctx.with_delays(self._ref_delays))
                    scale_row = jnp.full((self.n_players,), scale,
                                         dtype=jnp.float32) \
                        if np.ndim(scale) == 0 else \
                        jnp.asarray(scale, dtype=jnp.float32)
                    round_args = round_args + (scale_row,)
                prev_params = self.params if self._selection else None
                (self.params, self.opt_state, new_refs, self.snapshot,
                 metrics) = self._round(*round_args)
                if self._selection:
                    self._observe_selection(mask, prev_params)
                if self._async:
                    # merge-on-arrival: uploads landed on time (the snapshot
                    # merge above), but the broadcast each participant takes
                    # home — consumed in the NEXT round — is next_row[i]
                    # rounds stale. staleness_log[r] records the delays the
                    # refs consumed DURING round r carried (the engine's
                    # result.staleness convention).
                    next_row = delay_table[r + 1]
                    self._snap_hist.insert(0, self.snapshot)
                    del self._snap_hist[self.max_staleness + 1:]
                    self.refs, effective = self._refresh_stale_refs(
                        next_row, g, m_np)
                    # arrivals' new refs carry their realized delay; a
                    # non-participant's reference just aged one round
                    self._ref_delays = np.where(m_np, effective,
                                                self._ref_delays + 1)
                    self.player_rounds += m_np.astype(np.int64)
                    # g - effective = the round whose merged snapshot the
                    # arriving player sees (-1 = still only the init)
                    arrived = g - effective
                    self.player_snapshot_round = np.where(
                        m_np, np.maximum(self.player_snapshot_round, arrived),
                        self.player_snapshot_round)
                    self.staleness_log.append(delay_table[r])
                else:
                    self.refs = new_refs
                    # lockstep general round: participants re-mixed fresh
                    # references (staleness 0); everyone else aged by one
                    self._ref_delays = np.where(m_np, 0,
                                                self._ref_delays + 1)
                self.xbar = tree_mean(self.snapshot)
            elif self._lowbit:
                (self.params, self.opt_state, self.xbar, self._wire_state,
                 metrics) = self._round(
                    self.params, self.opt_state, tokens, self.xbar,
                    self._wire_state,
                )
            else:
                self.params, self.opt_state, self.xbar, metrics = self._round(
                    self.params, self.opt_state, tokens, self.xbar,
                )
            step += self.tau
            rec = {k: float(jnp.mean(v)) for k, v in metrics.items()}
            rec["round"] = r
            self.history.append(rec)
            self._global_round += 1
        return self.history

    def comm_report(self, rounds: int | None = None) -> PearlCommReport:
        """Byte accounting for this trainer's sync strategy and topology.

        With the default ``rounds=None`` the report bills the rounds actually
        run, using the participation masks that were drawn — a
        ``PartialParticipation`` trainer pays only for the blocks/links it
        moved (lossy ``bills_full_round`` strategies still pay in full). An
        explicit ``rounds`` produces a prospective full-participation
        estimate instead (no mask history exists for unrun rounds).
        """
        from repro.roofline.analysis import count_params
        from repro.models.model import param_shapes

        n_rounds = len(self.history) if rounds is None else rounds
        participants = messages = None
        if rounds is None and self._general and not self.sync.bills_full_round:
            if self.topology.is_server:
                participants = np.asarray(
                    self._round_participants[:n_rounds], dtype=np.int64)
            else:
                messages = np.asarray(
                    self._round_messages[:n_rounds], dtype=np.int64)
        shapes = param_shapes(self.cfg)
        return PearlCommReport.from_sync(
            self.sync,
            n_players=self.n_players,
            param_count=count_params(shapes),
            tau=self.tau,
            rounds=n_rounds,
            topology=self.topology,
            participants=participants,
            messages=messages,
            blocks_per_player=len(jax.tree.leaves(shapes)),
        )
