"""Loss and single-player train step (the building block PEARL wraps).

The loss is next-token cross-entropy over the text segment (VLM patch
positions and audio encoder frames carry no labels) plus the weighted MoE
load-balance auxiliary.
"""

from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.model import forward
from repro.optim.optimizers import (
    Optimizer,
    apply_updates,
    clip_by_global_norm,
    global_norm,
)

Array = jax.Array


def lm_loss(logits: Array, tokens: Array, text_offset: int = 0) -> Array:
    """Mean next-token NLL. logits (B, S_total, V) fp32; tokens (B, S_text).

    ``text_offset`` skips leading non-text positions (vision patches) so
    logits[:, text_offset + t] predicts tokens[:, t + 1].
    """
    s_text = tokens.shape[1]
    lg = logits[:, text_offset : text_offset + s_text - 1]
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(lg, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def make_loss_fn(cfg: ModelConfig, *, aux_weight: float = 0.01,
                 window: int = 0, use_kernels: bool = False,
                 prox_lambda: float = 0.0) -> Callable:
    """Build ``loss(params, batch, ref_params=None) -> (scalar, metrics)``.

    ``prox_lambda`` adds the MpFL consensus-game coupling
    ``lambda/2 * ||params - ref_params||^2`` against a *stale* reference
    (the across-player mean from the last PEARL synchronization) — the
    Section 2.2 personalized-FL instance of the n-player game.
    """
    text_offset = cfg.n_modality_tokens if cfg.modality == "vision" else 0

    def loss_fn(params, batch, ref_params=None):
        out = forward(params, cfg, batch, mode="train", window=window,
                      use_kernels=use_kernels)
        loss = lm_loss(out["logits"], batch["tokens"], text_offset)
        total = loss + aux_weight * out["aux"]
        metrics = {"lm_loss": loss, "aux_loss": out["aux"]}
        if prox_lambda > 0.0 and ref_params is not None:
            sq = sum(
                jnp.sum(jnp.square(p.astype(jnp.float32) - r.astype(jnp.float32)))
                for p, r in zip(jax.tree.leaves(params),
                                jax.tree.leaves(ref_params))
            )
            total = total + 0.5 * prox_lambda * sq
            metrics["prox"] = sq
        return total, metrics

    return loss_fn


def make_train_step(cfg: ModelConfig, optimizer: Optimizer, *,
                    aux_weight: float = 0.01, clip_norm: float = 1.0,
                    window: int = 0, use_kernels: bool = False) -> Callable:
    """Build ``train_step(params, opt_state, batch) -> (params, opt, metrics)``."""
    loss_fn = make_loss_fn(cfg, aux_weight=aux_weight, window=window,
                           use_kernels=use_kernels)

    def train_step(params, opt_state, batch):
        (total, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch
        )
        if clip_norm:
            grads = clip_by_global_norm(grads, clip_norm)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        metrics = dict(metrics, total_loss=total, grad_norm=global_norm(grads))
        return params, opt_state, metrics

    return train_step
