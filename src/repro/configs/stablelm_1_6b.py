"""StableLM 2 1.6B — dense decoder, full multi-head attention
[hf:stabilityai/stablelm-2-1_6b].

24 layers, d_model 2048, 32 heads (kv=32 — MHA), d_ff 5632, vocab 100352.
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="stablelm-1.6b",
        family="dense",
        n_layers=24,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        d_ff=5632,
        vocab_size=100352,
        citation="hf:stabilityai/stablelm-2-1_6b",
        sliding_window=8192,
    )
)
