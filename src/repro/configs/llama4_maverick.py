"""Llama 4 Maverick 400B-A17B — interleaved dense/MoE early-fusion decoder
[hf:meta-llama/Llama-4-Scout-17B-16E family].

48 layers, d_model 5120, 40 heads (GQA kv=8), 128 routed experts with top-1
routing and per-expert d_ff 8192, plus one always-on shared expert; vocab
202048. Maverick interleaves dense and MoE FFN layers (``moe_every=2``).
Early fusion: image patches arrive as stub-frontend embeddings.
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="llama4-maverick-400b-a17b",
        family="moe",
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        d_ff=16384,            # dense (non-MoE) interleaved layers
        vocab_size=202048,
        citation="hf:meta-llama/Llama-4-Scout-17B-16E (Maverick variant)",
        n_experts=128,
        top_k=1,
        moe_d_ff=8192,
        n_shared_experts=1,
        moe_every=2,
        modality="vision",
        n_modality_tokens=1024,
        sliding_window=8192,
    )
)
