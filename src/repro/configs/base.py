"""Model configuration system for the assigned architecture pool.

Every architecture in the assignment is described by a single frozen
:class:`ModelConfig`. Heterogeneous stacks (hybrid SSM/attention, alternating
mLSTM/sLSTM, interleaved dense/MoE) are expressed via ``layer_types()``, a
per-layer type list that the model assembler groups into contiguous runs and
compiles with ``jax.lax.scan`` per run (bounded HLO size at 88 layers).
"""

from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "moe", "vlm", "audio", "hybrid", "ssm"]

# Layer type tags used by layer_types():
#   "attn"   - attention + dense FFN decoder block
#   "moe"    - attention + MoE FFN decoder block
#   "mamba"  - Mamba2 (SSD) block
#   "mlstm"  - matrix-LSTM block (xLSTM)
#   "slstm"  - scalar-LSTM block (xLSTM)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture hyperparameters (exact assigned values in configs/<id>.py)."""

    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    citation: str = ""

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0            # per-expert FFN width
    n_shared_experts: int = 0    # DeepSeek/Moonlight-style always-on experts
    moe_every: int = 1           # 1 = every layer MoE; 2 = interleave dense/MoE
    capacity_factor: float = 1.25
    moe_group_size: int = 512    # GShard dispatch group (perf knob, see Perf)

    # --- SSM / hybrid ---
    ssm_state: int = 0
    ssm_expand: int = 2          # d_inner = ssm_expand * d_model
    conv_kernel: int = 4
    attn_every: int = 0          # hybrid: one attention block every N layers

    # --- xLSTM ---
    slstm_every: int = 0         # one sLSTM block every N layers (rest mLSTM)

    # --- attention ---
    head_dim: int = 0            # 0 -> d_model // n_heads
    rope_theta: float = 10_000.0
    sliding_window: int = 0      # 0 = full causal attention
    attn_chunk: int = 1024       # query-chunk size for memory-bounded attention

    # --- encoder-decoder (audio) ---
    enc_layers: int = 0

    # --- modality frontend stubs ---
    modality: Literal["", "vision", "audio"] = ""
    n_modality_tokens: int = 0   # patches / frames prepended per sample

    # --- numerics ---
    dtype: str = "bfloat16"
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # --- cost-model instrumentation (roofline/cost_model.py) ---
    # XLA's HloCostAnalysis visits while-loop bodies ONCE (no trip-count
    # multiplication), so scanned layer stacks/chunk loops undercount FLOPs.
    # The cost model compiles tiny per-layer-kind variants with loops
    # unrolled and recombines analytically. These fields exist only for that:
    override_layer_types: tuple[str, ...] | None = None   # replace layer stack
    unroll_loops: bool = False                             # unroll scans in HLO
    ssm_chunk: int = 256                                   # SSD/mLSTM chunk len

    # ------------------------------------------------------------------ utils
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    def layer_types(self) -> tuple[str, ...]:
        """Per-layer block type tags, length ``n_layers`` (decoder stack)."""
        if self.override_layer_types is not None:
            return self.override_layer_types
        types = []
        for i in range(self.n_layers):
            if self.family == "ssm" and self.slstm_every:
                types.append("slstm" if (i + 1) % self.slstm_every == 0 else "mlstm")
            elif self.family == "ssm":
                types.append("mlstm")
            elif self.family == "hybrid":
                is_attn = self.attn_every and (i + 1) % self.attn_every == 0
                types.append("attn" if is_attn else "mamba")
            elif self.n_experts:
                is_moe = (i % self.moe_every) == (self.moe_every - 1)
                types.append("moe" if is_moe else "attn")
            else:
                types.append("attn")
        return tuple(types)

    def layer_runs(self) -> tuple[tuple[str, int], ...]:
        """Contiguous (type, count) runs of :meth:`layer_types` for scan grouping."""
        runs: list[tuple[str, int]] = []
        for t in self.layer_types():
            if runs and runs[-1][0] == t:
                runs[-1] = (t, runs[-1][1] + 1)
            else:
                runs.append((t, 1))
        return tuple(runs)

    def supports_long_decode(self) -> tuple[bool, str]:
        """Can this arch serve a 500k-token context sub-quadratically?

        SSM/hybrid blocks carry O(1) state. Attention archs qualify only via
        the sliding-window variant (cache ring-buffered to the window).
        """
        if self.family in ("ssm", "hybrid"):
            return True, "recurrent state is O(1) in context length"
        if self.sliding_window > 0:
            return True, f"sliding-window attention (window={self.sliding_window})"
        return False, "full attention; enable sliding_window for long_500k"

    def smoke_variant(self) -> "ModelConfig":
        """Reduced config for CPU smoke tests (<=2 layers, d_model<=512, <=4 experts)."""
        n_heads = min(self.n_heads, 4)
        n_kv = max(1, min(self.n_kv_heads, n_heads))
        while n_heads % n_kv:
            n_kv -= 1
        d_model = 256
        head_dim = d_model // n_heads
        n_layers = 2
        updates = dict(
            name=self.name + "-smoke",
            n_layers=n_layers,
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=head_dim,
            d_ff=512 if self.d_ff else 0,
            vocab_size=512,
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            moe_d_ff=256 if self.moe_d_ff else 0,
            n_shared_experts=min(self.n_shared_experts, 1),
            moe_every=1 if self.n_experts else self.moe_every,
            capacity_factor=8.0,  # no capacity drops -> deterministic smoke tests
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            attn_every=2 if self.attn_every else 0,
            slstm_every=2 if self.slstm_every else 0,
            enc_layers=2 if self.enc_layers else 0,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            attn_chunk=32,
            n_modality_tokens=8 if self.n_modality_tokens else 0,
            dtype="float32",
        )
        return dataclasses.replace(self, **updates)


_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    if cfg.name in _REGISTRY:
        raise ValueError(f"duplicate config {cfg.name}")
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    import repro.configs  # noqa: F401  (triggers registration side effects)

    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def all_configs() -> dict[str, ModelConfig]:
    import repro.configs  # noqa: F401

    return dict(_REGISTRY)
