"""SmolLM 360M — small dense llama-architecture decoder
[hf:HuggingFaceTB/SmolLM-135M family].

32 layers, d_model 960, 15 heads (GQA kv=5), d_ff 2560, vocab 49152.
15 heads do not divide the 16-way model axis: attention projections are
replicated across ``model`` and the FFN/vocab dimensions carry the tensor
parallelism instead (see models/sharding.py).
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="smollm-360m",
        family="dense",
        n_layers=32,
        d_model=960,
        n_heads=15,
        n_kv_heads=5,
        d_ff=2560,
        vocab_size=49152,
        citation="hf:HuggingFaceTB/SmolLM-135M (360M variant)",
        sliding_window=8192,
    )
)
