"""Assigned input shapes and per-shape ShapeDtypeStruct builders.

The four assigned shapes exercise different execution modes:

- ``train_4k``    — training step (loss + grad + update) on 4k sequences.
- ``prefill_32k`` — inference prefill: forward over the full 32k prompt,
  producing a populated KV cache + last-token logits.
- ``decode_32k``  — inference decode: ONE new token against a 32k KV cache.
- ``long_500k``   — long-context decode: one token against a 524,288-token
  context; requires sub-quadratic attention (SSM state or sliding-window
  ring-buffer cache).
"""

from __future__ import annotations

import dataclasses
from typing import Literal

Mode = Literal["train", "prefill", "decode"]


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    mode: Mode


TRAIN_4K = InputShape("train_4k", 4_096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32_768, 128, "decode")
LONG_500K = InputShape("long_500k", 524_288, 1, "decode")

ALL_SHAPES: tuple[InputShape, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES: dict[str, InputShape] = {s.name: s for s in ALL_SHAPES}


def get_shape(name: str) -> InputShape:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; available: {sorted(SHAPES)}")
    return SHAPES[name]
