"""Chameleon 34B — early-fusion mixed-modal decoder [arXiv:2405.09818].

48 layers, d_model 8192, 64 heads (GQA kv=8), d_ff 22016, vocab 65536
(text + VQ image codes share one codebook-extended vocabulary). Early fusion:
image content enters as precomputed patch/VQ embeddings from the stubbed
vision frontend (``n_modality_tokens`` per sample) interleaved with text
token embeddings — the transformer backbone we implement consumes both.
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="chameleon-34b",
        family="vlm",
        n_layers=48,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=22016,
        vocab_size=65536,
        citation="arXiv:2405.09818 (Chameleon)",
        modality="vision",
        n_modality_tokens=1024,
        sliding_window=8192,
    )
)
