"""xLSTM 125M — sLSTM + mLSTM recurrent block stack [arXiv:2405.04517].

12 layers, d_model 768, 4 heads, vocab 50304, d_ff = 0 (projections live
inside the blocks: mLSTM pre-up-projects by 2x, sLSTM uses a 4/3-factor
gated FFN). One sLSTM block every 4th layer, mLSTM otherwise. Decode is
O(1)-state recurrent, so long_500k runs natively.
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="xlstm-125m",
        family="ssm",
        n_layers=12,
        d_model=768,
        n_heads=4,
        n_kv_heads=4,
        d_ff=0,
        vocab_size=50304,
        citation="arXiv:2405.04517 (xLSTM)",
        slstm_every=4,
    )
)
