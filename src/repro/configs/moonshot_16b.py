"""Moonlight 16B-A3B (Moonshot) — DeepSeek-style fine-grained MoE decoder
[hf:moonshotai/Moonlight-16B-A3B].

48 layers, d_model 2048, 16 heads (kv=16), 64 routed experts (top-6) with
per-expert d_ff 1408 plus shared expert(s); vocab 163840. The assignment
lists the family tag as [dense] but specifies "MoE 64e top-6" — we build it
as the MoE it is and note the tag discrepancy here.
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="moonshot-v1-16b-a3b",
        family="moe",
        n_layers=48,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=1408,             # dense layers (first block is dense in DeepSeek-style stacks)
        vocab_size=163840,
        citation="hf:moonshotai/Moonlight-16B-A3B",
        n_experts=64,
        top_k=6,
        moe_d_ff=1408,
        n_shared_experts=2,
        moe_every=1,
        sliding_window=8192,
    )
)
