"""IBM Granite 34B Code — dense llama-architecture decoder [arXiv:2405.04324].

88 layers, d_model 6144, 48 heads with multi-query attention (GQA kv=1),
d_ff 24576, vocab 49152. The single KV head is replicated across the tensor-
parallel axis (1 does not divide 16); query heads shard 48/16 = 3 per device.
``sliding_window`` enables the sub-quadratic variant used only for the
``long_500k`` decode shape (full causal attention otherwise).
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="granite-34b",
        family="dense",
        n_layers=88,
        d_model=6144,
        n_heads=48,
        n_kv_heads=1,
        d_ff=24576,
        vocab_size=49152,
        citation="arXiv:2405.04324 (Granite Code Models)",
        sliding_window=8192,
    )
)
