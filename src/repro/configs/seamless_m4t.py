"""SeamlessM4T Medium — encoder-decoder multimodal translation backbone
[arXiv:2308.11596].

12 encoder + 12 decoder layers, d_model 1024, 16 heads (kv=16), d_ff 4096,
vocab 256206. The speech frontend (mel-spectrogram + conv feature extractor)
is a STUB: ``input_specs()`` provides precomputed frame embeddings of shape
(batch, seq, d_model) consumed by the bidirectional encoder; the decoder is
causal with cross-attention into the encoder memory. Decode shapes exercise
the decoder against a cached encoder memory + KV cache.
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="seamless-m4t-medium",
        family="audio",
        n_layers=12,
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        d_ff=4096,
        vocab_size=256206,
        citation="arXiv:2308.11596 (SeamlessM4T)",
        enc_layers=12,
        modality="audio",
        sliding_window=8192,
    )
)
