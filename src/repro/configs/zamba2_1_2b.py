"""Zamba2 1.2B — hybrid Mamba2 backbone with interleaved attention blocks
[arXiv:2411.15242].

38 layers, d_model 2048, ssm_state 64, d_inner 4096 (expand 2); one
attention block (32 heads, kv=32, d_ff 8192) every 6th layer, Mamba2
otherwise. Long-context decode is native: the recurrent state is O(1) in
context length, and the sparse attention blocks use a sliding window.
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="zamba2-1.2b",
        family="hybrid",
        n_layers=38,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        d_ff=8192,
        vocab_size=32000,
        citation="arXiv:2411.15242 (Zamba2)",
        ssm_state=64,
        ssm_expand=2,
        conv_kernel=4,
        attn_every=6,
        sliding_window=4096,
    )
)
