"""Assigned architecture configs (importing this package registers them)."""

from repro.configs import (  # noqa: F401  (registration side effects)
    chameleon_34b,
    granite_34b,
    llama4_maverick,
    moonshot_16b,
    qwen3_moe_30b,
    seamless_m4t,
    smollm_360m,
    stablelm_1_6b,
    xlstm_125m,
    zamba2_1_2b,
)
from repro.configs.base import ModelConfig, all_configs, get_config
from repro.configs.shapes import ALL_SHAPES, SHAPES, InputShape, get_shape

ARCH_IDS = tuple(sorted(all_configs()))

__all__ = [
    "ModelConfig",
    "all_configs",
    "get_config",
    "ARCH_IDS",
    "InputShape",
    "ALL_SHAPES",
    "SHAPES",
    "get_shape",
]
