"""Qwen3 30B-A3B — fine-grained MoE decoder [hf:Qwen/Qwen3-30B-A3B].

48 layers, d_model 2048, 32 heads (GQA kv=4), 128 routed experts with top-8
routing and tiny per-expert d_ff 768; vocab 151936. Every layer is MoE; no
shared expert.
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="qwen3-moe-30b-a3b",
        family="moe",
        n_layers=48,
        d_model=2048,
        n_heads=32,
        n_kv_heads=4,
        d_ff=768,
        vocab_size=151936,
        citation="hf:Qwen/Qwen3-30B-A3B",
        n_experts=128,
        top_k=8,
        moe_d_ff=768,
        n_shared_experts=0,
        moe_every=1,
        sliding_window=8192,
    )
)
