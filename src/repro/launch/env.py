"""Process-environment bootstrap for wall-clock measurement.

Seconds are only comparable when the process environment is pinned. Two
env knobs move CPU wall-clock enough to swamp a wire-compression win, and
BOTH must be set before ``import jax`` (the backend reads them once at
client init):

- ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` — the fake
  N-device mesh every sharded collective in this repo lowers against
  (without it the CPU backend exposes one device and
  :func:`repro.core.collective.player_mesh` refuses the trivial mesh);
- ``TF_CPP_MIN_LOG_LEVEL=4`` — the XLA runtime's C++ logging writes to
  stderr on the timed path; silence it.

Two more are allocator hygiene, applied when available and harmless when
not:

- ``LD_PRELOAD=<libtcmalloc>`` — glibc malloc's arena contention skews
  multi-threaded XLA CPU timings; tcmalloc is preloaded IF the library
  exists on this machine (it cannot be installed from here, and a dangling
  LD_PRELOAD would print a loader warning into every timing run);
- ``TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD`` — raised so tcmalloc's
  large-allocation reports never land in the timed window.

``LD_PRELOAD`` and ``XLA_FLAGS`` cannot take effect in an
already-running process, so :func:`ensure_wallclock_env` re-execs the
interpreter ONCE (sentinel-guarded) with the pinned environment — call it
at the very top of a benchmark ``__main__``, before any jax-importing
module. :func:`wallclock_env` is the pure helper that just computes the
mapping, for callers (CI shells) that export it themselves.
"""

from __future__ import annotations

import glob
import os
import sys

#: sentinel env var marking a process already re-exec'd with the pinned env
_SENTINEL = "REPRO_WALLCLOCK_ENV"

#: where distro tcmalloc builds land (gperftools package names vary)
_TCMALLOC_GLOBS = (
    "/usr/lib/x86_64-linux-gnu/libtcmalloc.so*",
    "/usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so*",
    "/usr/lib/*/libtcmalloc*.so*",
    "/usr/lib64/libtcmalloc*.so*",
)


def find_tcmalloc() -> str | None:
    """Path to a tcmalloc shared library on this machine, or None."""
    for pattern in _TCMALLOC_GLOBS:
        hits = sorted(glob.glob(pattern))
        if hits:
            return hits[0]
    return None


def wallclock_env(device_count: int = 8) -> dict[str, str]:
    """The pinned environment for a wall-clock benchmark process.

    Returns only the variables that need SETTING (an existing
    ``--xla_force_host_platform_device_count`` in ``XLA_FLAGS`` is
    preserved rather than overridden, so CI's exported mesh size wins).
    """
    env: dict[str, str] = {}
    flags = os.environ.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" not in flags:
        flag = f"--xla_force_host_platform_device_count={device_count}"
        env["XLA_FLAGS"] = f"{flags} {flag}".strip()
    env.setdefault("TF_CPP_MIN_LOG_LEVEL",
                   os.environ.get("TF_CPP_MIN_LOG_LEVEL", "4"))
    env["TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD"] = "60000000000"
    tcmalloc = find_tcmalloc()
    if tcmalloc is not None and "tcmalloc" not in os.environ.get(
            "LD_PRELOAD", ""):
        preload = os.environ.get("LD_PRELOAD", "")
        env["LD_PRELOAD"] = f"{preload}:{tcmalloc}".strip(":")
    return env


def ensure_wallclock_env(device_count: int = 8) -> bool:
    """Pin the wall-clock environment, re-exec'ing the interpreter once.

    Call FIRST in a benchmark ``__main__``, before importing jax (directly
    or transitively). If the environment is already pinned (sentinel set,
    e.g. by a previous re-exec or by CI exporting it), returns False and
    the caller proceeds. Otherwise sets the env and replaces the process
    via ``os.execv`` — the re-exec'd process starts this module again with
    ``LD_PRELOAD``/``XLA_FLAGS`` active from the loader on.
    """
    if os.environ.get(_SENTINEL) == "1":
        return False
    os.environ.update(wallclock_env(device_count))
    os.environ[_SENTINEL] = "1"
    # re-exec'ing ``python -m pkg.mod`` lands in ``python path/to/mod.py``,
    # whose sys.path[0] is the module's DIRECTORY — carry the current
    # process's resolved import path across the exec so package-relative
    # imports (benchmarks.*, repro.*) keep resolving.
    os.environ["PYTHONPATH"] = os.pathsep.join(
        p or os.getcwd() for p in sys.path)
    os.execv(sys.executable, [sys.executable] + sys.argv)
    raise AssertionError("unreachable: execv does not return")
