import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run (deliverable e): lower + compile every
(architecture x input-shape) combination on the production meshes and emit
trip-count-corrected roofline terms (deliverable g).

The two lines above MUST precede any other import: jax locks the device
count at first initialization, and the dry-run needs 512 placeholder host
devices so ``jax.make_mesh((2, 16, 16), ...)`` can build the 2-pod mesh.
Only this entrypoint sets the flag — smoke tests and benchmarks see the
single real CPU device.

For each combo we compile twice:
  1. the production program (scan-over-layers) — proves the sharding config
     lowers and compiles, and provides memory_analysis();
  2. tiny per-layer-kind component variants with loops unrolled — provides
     trip-count-corrected FLOPs/bytes/collective bytes (XLA cost analysis
     does not multiply while-loop bodies; see roofline/cost_model.py).

Usage:
  python -m repro.launch.dryrun --arch granite-34b --shape train_4k
  python -m repro.launch.dryrun --arch all --shape all --multi-pod both --out dry.json
  python -m repro.launch.dryrun --arch stablelm-1.6b --shape train_4k --pearl --multi-pod yes
"""

import argparse
import json
import sys
import time
import traceback


def run_combo(arch: str, shape_name: str, *, multi_pod: bool, pearl: bool = False,
              tau: int = 8, save_hlo: str | None = None,
              corrected: bool = True) -> dict:
    from repro.configs import get_config, get_shape
    from repro.launch import builders
    from repro.launch.mesh import make_production_mesh
    from repro.launch.specs import pick_window
    from repro.roofline import analysis as ra
    from repro.roofline.cost_model import corrected_cost

    cfg = get_config(arch)
    shape = get_shape(shape_name)
    window = pick_window(cfg, shape)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    chips = mesh.size
    t0 = time.time()

    # ---- 1. production program: prove it lowers + compiles; memory ----
    if pearl:
        if not multi_pod:
            raise ValueError("PEARL dry-run needs the multi-pod mesh (players=pods)")
        lowered, shapes = builders.build_pearl_lowered(
            cfg, shape, mesh, window=window, tau=tau)
        kind = f"pearl_round(tau={tau})"
    else:
        lowered, shapes = builders.build_lowered(cfg, shape, mesh, window=window)
        kind = {"decode": "serve_step", "prefill": "prefill",
                "train": "train_step"}[shape.mode]
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    peak = (getattr(mem, "argument_size_in_bytes", 0)
            + getattr(mem, "output_size_in_bytes", 0)
            + getattr(mem, "temp_size_in_bytes", 0))
    hlo = compiled.as_text()
    raw_cost = dict(compiled.cost_analysis())
    raw_coll = ra.parse_collectives(hlo, chips_per_pod=256)

    # ---- 2. corrected costs from unrolled component variants ----
    detail = {}
    if corrected and not pearl:
        t0 = time.time()
        cost, detail = corrected_cost(cfg, shape, mesh, window=window)
        detail["correct_s"] = round(time.time() - t0, 1)
        cost_dict = {"flops": cost.flops, "bytes accessed": cost.bytes}
        coll = cost.collectives
    else:
        cost_dict, coll = raw_cost, raw_coll

    n_active = ra.active_params(cfg, shapes)
    n_total = ra.count_params(shapes)
    model_flops = ra.model_flops_estimate(cfg, shape, n_active)
    report = ra.build_report(
        arch=arch, shape=shape_name, mesh_name=mesh_name, chips=chips,
        cost=cost_dict, collectives=coll, peak_memory=peak,
        model_flops=model_flops,
    )
    rec = report.to_json()
    rec.update(
        kind=kind, window=window, params_total=n_total, params_active=n_active,
        lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
        collective_ops=coll.count, collective_by_op=coll.bytes_by_op,
        raw_flops_per_device=raw_cost.get("flops", 0.0),
        hlo_bytes=len(hlo), corrected=bool(corrected and not pearl),
        **{f"detail_{k}": v for k, v in detail.items()},
    )
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(hlo)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True, help="arch id or 'all'")
    ap.add_argument("--shape", required=True, help="shape name or 'all'")
    ap.add_argument("--multi-pod", choices=["no", "yes", "both"], default="no")
    ap.add_argument("--pearl", action="store_true",
                    help="lower a PEARL round instead of a plain train step")
    ap.add_argument("--tau", type=int, default=8)
    ap.add_argument("--no-correct", action="store_true",
                    help="skip the unrolled cost-correction compiles")
    ap.add_argument("--out", default="")
    ap.add_argument("--save-hlo", default="")
    ap.add_argument("--skip-existing", action="store_true",
                    help="reuse non-error records already present in --out")
    args = ap.parse_args()

    from repro.configs import ARCH_IDS, SHAPES

    archs = list(ARCH_IDS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    pods = {"no": [False], "yes": [True], "both": [False, True]}[args.multi_pod]

    records = []
    done = {}
    if args.skip_existing and args.out and os.path.exists(args.out):
        with open(args.out) as f:
            for r in json.load(f):
                if "error" not in r:
                    done[(r["arch"], r["shape"], r["mesh"])] = r
    for arch in archs:
        for shape in shapes:
            for mp in pods:
                mesh_name = "2x16x16" if mp else "16x16"
                tag = f"{arch}/{shape}/{mesh_name}"
                if (arch, shape, mesh_name) in done:
                    records.append(done[(arch, shape, mesh_name)])
                    print(f"SKIP {tag} (existing record reused)", flush=True)
                    continue
                try:
                    rec = run_combo(arch, shape, multi_pod=mp, pearl=args.pearl,
                                    tau=args.tau,
                                    save_hlo=args.save_hlo or None,
                                    corrected=not args.no_correct)
                    records.append(rec)
                    print(f"OK   {tag}: compute={rec['compute_s']:.4f}s "
                          f"memory={rec['memory_s']:.4f}s "
                          f"collective={rec['collective_s']:.4f}s "
                          f"bottleneck={rec['bottleneck']} "
                          f"useful={rec['useful_flops_ratio']:.2f} "
                          f"(compile {rec['compile_s']}s)", flush=True)
                except Exception as e:  # noqa: BLE001 — report and continue
                    traceback.print_exc()
                    records.append({"arch": arch, "shape": shape,
                                    "mesh": "2x16x16" if mp else "16x16",
                                    "error": str(e)})
                    print(f"FAIL {tag}: {e}", flush=True)
                if args.out:
                    with open(args.out, "w") as f:
                        json.dump(records, f, indent=1)

    failures = [r for r in records if "error" in r]
    print(f"\n{len(records) - len(failures)}/{len(records)} combos lowered+compiled")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
