"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --smoke \
        --steps 20 [--pearl --players 2 --tau 4]

On real hardware this would run under one process per host with the
production mesh; on this CPU container it drives the same code paths on the
single device (optionally with a reduced config via --smoke). Supports both
classical single-model training and the MpFL PEARL mode (players + tau +
consensus coupling), with checkpointing/resume.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.ckpt import latest_step, restore_checkpoint, save_checkpoint
from repro.configs import ARCH_IDS, get_config
from repro.data.synthetic import DataConfig, SyntheticTokenStream
from repro.models.model import init_params
from repro.optim.optimizers import adamw, cosine_schedule, sgd
from repro.train.pearl_trainer import PearlTrainer
from repro.train.train_step import make_train_step


def train_single(args, cfg):
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    opt = adamw(cosine_schedule(args.lr, warmup=20, total=args.steps))
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(cfg, opt))
    stream = SyntheticTokenStream(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq, batch_size=args.batch,
        n_players=1, seed=args.seed,
    ))

    start = 0
    if args.ckpt_dir:
        last = latest_step(args.ckpt_dir)
        if last is not None:
            state = restore_checkpoint(args.ckpt_dir, last,
                                       {"params": params, "opt": opt_state})
            params, opt_state = state["params"], state["opt"]
            start = last
            print(f"resumed from step {start}")

    t0 = time.time()
    for step in range(start, args.steps):
        batch = {"tokens": jnp.asarray(stream.batch(0, step))}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d}  loss={float(metrics['lm_loss']):.4f}  "
                  f"grad_norm={float(metrics['grad_norm']):.3f}  "
                  f"({time.time() - t0:.0f}s)", flush=True)
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt_dir, step + 1,
                            {"params": params, "opt": opt_state})
    return params


def train_pearl(args, cfg):
    trainer = PearlTrainer(cfg, sgd(args.lr), n_players=args.players,
                           tau=args.tau, prox_lambda=args.prox,
                           seed=args.seed)
    stream = SyntheticTokenStream(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq, batch_size=args.batch,
        n_players=args.players, seed=args.seed,
    ))
    rounds = max(1, args.steps // args.tau)
    t0 = time.time()
    for r in range(rounds):
        hist = trainer.run(stream, rounds=1)
        if r % args.log_every == 0 or r == rounds - 1:
            print(f"round {r:4d}  lm_loss={hist[-1]['lm_loss']:.4f}  "
                  f"({time.time() - t0:.0f}s)", flush=True)
    return trainer.params


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="smollm-360m", choices=list(ARCH_IDS))
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke variant (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    # MpFL / PEARL
    ap.add_argument("--pearl", action="store_true")
    ap.add_argument("--players", type=int, default=2)
    ap.add_argument("--tau", type=int, default=4)
    ap.add_argument("--prox", type=float, default=1e-3)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke_variant()
    print(f"arch={cfg.name}  layers={cfg.n_layers}  d_model={cfg.d_model}  "
          f"devices={jax.device_count()}")
    if args.pearl:
        train_pearl(args, cfg)
    else:
        train_single(args, cfg)


if __name__ == "__main__":
    main()
