"""Launchers: production mesh, dry-run, perf hillclimb, training CLI."""

from repro.launch.env import (
    ensure_wallclock_env,
    find_tcmalloc,
    wallclock_env,
)
from repro.launch.mesh import make_debug_mesh, make_production_mesh

__all__ = [
    "make_production_mesh",
    "make_debug_mesh",
    "wallclock_env",
    "ensure_wallclock_env",
    "find_tcmalloc",
]
