"""ShapeDtypeStruct input builders for every (architecture x shape) combo.

``input_specs`` mirrors what the data pipeline / serving frontend would feed
each step, as abstract shapes only — the dry-run lowers against these without
allocating anything. Modality frontends are stubbed exactly here: VLM archs
receive (B, n_patches, d_model) patch embeddings, the audio enc-dec receives
(B, seq, d_model) frame embeddings (DESIGN.md carve-out).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.configs.shapes import InputShape
from repro.models.model import init_cache

SDS = jax.ShapeDtypeStruct


def train_input_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    b, s = shape.global_batch, shape.seq_len
    specs = {}
    if cfg.modality == "vision":
        n_mod = cfg.n_modality_tokens
        specs["tokens"] = SDS((b, s - n_mod), jnp.int32)
        specs["patch_embeds"] = SDS((b, n_mod, cfg.d_model), jnp.dtype(cfg.dtype))
    elif cfg.enc_layers:
        specs["tokens"] = SDS((b, s), jnp.int32)
        specs["enc_frames"] = SDS((b, s, cfg.d_model), jnp.dtype(cfg.dtype))
    else:
        specs["tokens"] = SDS((b, s), jnp.int32)
    return specs


def decode_input_specs(cfg: ModelConfig, shape: InputShape, *,
                       window: int = 0) -> dict:
    """Specs for serve_step: one token + a seq_len-deep cache."""
    b, s = shape.global_batch, shape.seq_len
    enc_len = s if cfg.enc_layers else 0
    cache = jax.eval_shape(
        lambda: init_cache(cfg, b, s, window=window, enc_len=enc_len)
    )
    return {"token": SDS((b, 1), jnp.int32), "cache": cache}


def input_specs(cfg: ModelConfig, shape: InputShape, *, window: int = 0) -> dict:
    if shape.mode in ("train", "prefill"):
        return train_input_specs(cfg, shape)
    return decode_input_specs(cfg, shape, window=window)


def pick_window(cfg: ModelConfig, shape: InputShape) -> int:
    """Sliding-window policy per DESIGN.md:

    - hybrid archs always use their architectural window on attention blocks;
    - pure-attention archs enable the window only for long_500k (the
      sub-quadratic requirement); all other shapes run full attention.
    """
    if cfg.family == "hybrid":
        return cfg.sliding_window
    if shape.name == "long_500k":
        return cfg.sliding_window
    return 0
