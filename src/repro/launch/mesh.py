"""Production mesh construction (deliverable e).

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first jax
init; tests and benches see the single real CPU device.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e production mesh: 16x16 per pod; 2 pods when ``multi_pod``."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(*, data: int = 1, model: int = 1, pod: int = 0):
    """Small mesh over however many (possibly fake) devices are available."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def data_axes(mesh) -> tuple[str, ...]:
    """Axes carrying batch parallelism for ``mesh``."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def model_axis_size(mesh) -> int:
    return mesh.shape["model"]
