"""Shared jit/lower builders for dry-runs and the corrected cost model.

Each builder returns ``(lowered, param_shapes)`` for one execution mode on a
given mesh, with in_shardings from the sharding policy. These are imported by
``launch.dryrun`` (which sets the 512-device XLA flag first) and by
``roofline.cost_model`` (component variants).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.configs.shapes import InputShape
from repro.launch.mesh import data_axes, model_axis_size
from repro.launch.specs import decode_input_specs, train_input_specs
from repro.models.model import param_shapes
from repro.models.sharding import (
    batch_specs,
    cache_partition_specs,
    param_partition_specs,
)
from repro.optim.optimizers import adamw, sgd
from repro.serve.decode import make_serve_step
from repro.train.train_step import make_train_step


def _shard(mesh, specs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs)


def _zero1_opt_specs(ospecs, opt_shapes, axes, mesh):
    """ZeRO-1: additionally shard optimizer moments over the data axes.

    Adam m/v are touched only at the update, so sharding them over ``data``
    costs one reduce-scatter/all-gather pair per step but divides optimizer
    HBM by the data-parallel degree — the fix that brings the 400B llama4
    train step under the per-chip HBM budget (EXPERIMENTS.md Section Perf).
    """
    import numpy as np

    n_data = int(np.prod([mesh.shape[a] for a in axes]))
    d = axes if len(axes) > 1 else axes[0]

    def upd(spec, leaf):
        if len(leaf.shape) != len(spec) or not leaf.shape:
            return spec
        for i, (s, dim) in enumerate(zip(spec, leaf.shape)):
            if s is None and dim % n_data == 0 and dim >= n_data:
                return P(*spec[:i], d, *spec[i + 1 :])
        return spec

    return jax.tree.map(upd, ospecs, opt_shapes)


def build_train_lowered(cfg: ModelConfig, shape: InputShape, mesh, *,
                        window: int = 0, sharding_profile: str = "tp"):
    """sharding_profile:
    - "tp"      — default: tensor-parallel over ``model``, batch over data axes.
    - "tp+zero1" — as "tp" plus optimizer moments sharded over ``data``.
    - "fsdp"    — as "tp+zero1" plus parameters/gradients sharded over
      ``data`` too (ZeRO-3 semantics: XLA inserts per-layer all-gathers).
      Required for 400B-class training state to fit HBM (Section Perf).
    - "dp_only" — replicate parameters, spread the batch over data x model
      axes too (pure data parallelism). The Perf winner for small models whose
      head counts don't divide the model axis (e.g. xlstm-125m): it removes
      the per-layer activation all-reduces entirely.
    """
    axes = data_axes(mesh)
    msize = model_axis_size(mesh)
    if sharding_profile == "dp_only":
        axes = (*axes, "model")
        msize = 1
    shapes = param_shapes(cfg)
    pspecs = param_partition_specs(shapes, cfg, model_size=msize, data_axes=axes)
    if sharding_profile == "fsdp":
        pspecs = _zero1_opt_specs(pspecs, shapes, axes, mesh)
    opt = adamw(3e-4)
    opt_shapes = jax.eval_shape(opt.init, shapes)
    ospecs = param_partition_specs(opt_shapes, cfg, model_size=msize,
                                   data_axes=axes)
    ospecs = jax.tree.map(
        lambda spec, leaf: spec if len(leaf.shape) == len(spec) else P(),
        ospecs, opt_shapes,
    )
    if sharding_profile in ("tp+zero1", "fsdp"):
        ospecs = _zero1_opt_specs(ospecs, opt_shapes, axes, mesh)
    bspecs = batch_specs(cfg, "train", data_axes=axes)
    binputs = train_input_specs(cfg, shape)

    step = make_train_step(cfg, opt, window=window)
    jitted = jax.jit(
        step,
        in_shardings=(_shard(mesh, pspecs), _shard(mesh, ospecs),
                      _shard(mesh, bspecs)),
    )
    with mesh:
        lowered = jitted.lower(shapes, opt_shapes, binputs)
    return lowered, shapes


def build_prefill_lowered(cfg: ModelConfig, shape: InputShape, mesh, *,
                          window: int = 0, sharding_profile: str = "tp"):
    """Inference prefill: forward over the prompt emitting last-token logits
    + a populated KV/recurrent cache (no backward, no optimizer)."""
    from repro.models.model import prefill as prefill_fn

    axes = data_axes(mesh)
    msize = model_axis_size(mesh)
    if sharding_profile == "dp_only":
        axes = (*axes, "model")
        msize = 1
    shapes = param_shapes(cfg)
    pspecs = param_partition_specs(shapes, cfg, model_size=msize, data_axes=axes)
    bspecs = batch_specs(cfg, "prefill", data_axes=axes)
    binputs = train_input_specs(cfg, shape)

    def step(params, batch):
        return prefill_fn(params, cfg, batch, capacity=shape.seq_len,
                          window=window)

    jitted = jax.jit(
        step,
        in_shardings=(_shard(mesh, pspecs), _shard(mesh, bspecs)),
    )
    with mesh:
        lowered = jitted.lower(shapes, binputs)
    return lowered, shapes


def build_decode_lowered(cfg: ModelConfig, shape: InputShape, mesh, *,
                         window: int = 0):
    axes = data_axes(mesh)
    msize = model_axis_size(mesh)
    shapes = param_shapes(cfg)
    pspecs = param_partition_specs(shapes, cfg, model_size=msize, data_axes=axes)
    inputs = decode_input_specs(cfg, shape, window=window)
    n_data = 1
    for a in axes:
        n_data *= mesh.shape[a]
    shard_seq = shape.global_batch < n_data
    cspecs = cache_partition_specs(inputs["cache"], data_axes=axes,
                                   shard_seq=shard_seq)
    d = axes if len(axes) > 1 else axes[0]
    tok_spec = P(None, None) if shard_seq else P(d, None)

    serve = make_serve_step(cfg, window=window)
    jitted = jax.jit(
        serve,
        in_shardings=(_shard(mesh, pspecs), _shard(mesh, cspecs),
                      NamedSharding(mesh, tok_spec)),
    )
    with mesh:
        lowered = jitted.lower(shapes, inputs["cache"], inputs["token"])
    return lowered, shapes


def build_pearl_lowered(cfg: ModelConfig, shape: InputShape, mesh, *,
                        window: int = 0, tau: int = 8, n_players: int = 2,
                        prox_lambda: float = 1e-4, unroll: bool = False,
                        sync_dtype=None, sharded_sync: bool = False):
    """One PEARL round: players on the pod axis, tau local steps, one sync.

    ``sharded_sync`` lowers the synchronization through the explicit
    shard_map collective over the mesh's ``pod`` axis
    (:mod:`repro.core.collective`) instead of leaving the cross-pod mean to
    GSPMD — with a ``sync_dtype`` the compiled pod-axis collective's operand
    is the 2-byte wire representation (the claim ``launch/perf.py`` measures
    on the dry-run HLO). The default keeps the legacy GSPMD lowering.
    """
    from repro.train.pearl_trainer import make_pearl_round, tree_mean

    msize = model_axis_size(mesh)
    shapes = param_shapes(cfg)
    base = param_partition_specs(shapes, cfg, model_size=msize,
                                 data_axes=("data",))
    pspecs = jax.tree.map(lambda spec: P("pod", *spec), base)
    stacked_shapes = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct((n_players, *l.shape), l.dtype), shapes
    )
    opt = sgd(1e-3)
    opt_shapes = jax.eval_shape(jax.vmap(opt.init), stacked_shapes)
    ospecs = jax.tree.map(
        lambda leaf: P("pod", *([None] * (len(leaf.shape) - 1))), opt_shapes
    )
    xbar_shapes = jax.eval_shape(tree_mean, stacked_shapes)
    xspecs = param_partition_specs(xbar_shapes, cfg, model_size=msize,
                                   data_axes=("data",))
    b_local = shape.global_batch // n_players
    batch_sds = {"tokens": jax.ShapeDtypeStruct(
        (n_players, tau, b_local, shape.seq_len), jnp.int32)}
    bspec = {"tokens": P("pod", None, "data", None)}

    mesh_kwargs = {}
    if sharded_sync:
        if "pod" not in mesh.axis_names:
            raise ValueError(
                f"sharded_sync needs the multi-pod mesh (players live on the "
                f"pod axis), got axes {mesh.axis_names}"
            )
        # the stacked player dim is unsharded over data/model, so the
        # collective's inner specs are the per-player xbar specs
        mesh_kwargs = dict(mesh=mesh, mesh_axis="pod",
                           mesh_inner_specs=xspecs)
    rnd = make_pearl_round(cfg, opt, tau=tau, prox_lambda=prox_lambda,
                           window=window, unroll=unroll,
                           sync_dtype=sync_dtype, **mesh_kwargs)
    jitted = jax.jit(
        rnd,
        in_shardings=(_shard(mesh, pspecs), _shard(mesh, ospecs),
                      _shard(mesh, bspec), _shard(mesh, xspecs)),
    )
    with mesh:
        lowered = jitted.lower(stacked_shapes, opt_shapes, batch_sds,
                               xbar_shapes)
    return lowered, shapes


def build_lowered(cfg: ModelConfig, shape: InputShape, mesh, *, window: int = 0,
                  sharding_profile: str = "tp"):
    """Mode dispatch: train_step / prefill / serve_step per shape.mode."""
    if shape.mode == "decode":
        return build_decode_lowered(cfg, shape, mesh, window=window)
    if shape.mode == "prefill":
        return build_prefill_lowered(cfg, shape, mesh, window=window,
                                     sharding_profile=sharding_profile)
    return build_train_lowered(cfg, shape, mesh, window=window,
                               sharding_profile=sharding_profile)
