import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Perf hillclimbing driver (Section Perf of EXPERIMENTS.md).

Each experiment is a named (arch x shape) pair plus a list of VARIANTS — a
config/builder mutation encoding one hypothesis from the napkin math. For
every variant we recompute the trip-count-corrected roofline terms and print
before/after, so the hypothesis -> change -> measure -> validate loop is
mechanical:

  python -m repro.launch.perf --pair moe      # qwen3 train_4k
  python -m repro.launch.perf --pair small    # xlstm train_4k
  python -m repro.launch.perf --pair pearl    # stablelm multi-pod PEARL round
  python -m repro.launch.perf --pair granite  # granite-34b prefill_32k

Results land in experiments/perf_<pair>.json.
"""

import argparse
import dataclasses
import json
import time

import jax
from jax.sharding import PartitionSpec as P


def _terms(cost, chips):
    from repro.roofline.analysis import HBM_BW, ICI_BW, PEAK_FLOPS

    return {
        "compute_s": cost.flops / PEAK_FLOPS,
        "memory_s": cost.bytes / HBM_BW,
        "collective_s": cost.collectives.total_bytes / ICI_BW,
        "pod_collective_bytes": cost.collectives.pod_bytes,
        "collective_by_op": cost.collectives.bytes_by_op,
    }


def run_variant(arch: str, shape_name: str, *, label: str, hypothesis: str,
                cfg_updates: dict | None = None, window: int | None = None,
                sharding_profile: str = "tp", multi_pod: bool = False) -> dict:
    from repro.configs import get_config, get_shape
    from repro.launch.mesh import make_production_mesh
    from repro.launch.specs import pick_window
    from repro.roofline.cost_model import corrected_cost

    cfg = get_config(arch)
    if cfg_updates:
        cfg = dataclasses.replace(cfg, **cfg_updates)
    shape = get_shape(shape_name)
    w = pick_window(cfg, shape) if window is None else window
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    cost, detail = corrected_cost(cfg, shape, mesh, window=w,
                                  sharding_profile=sharding_profile)
    rec = {
        "label": label, "hypothesis": hypothesis, "arch": arch,
        "shape": shape_name, "window": w, "profile": sharding_profile,
        "cfg_updates": cfg_updates or {}, "wall_s": round(time.time() - t0, 1),
    }
    rec.update(_terms(cost, mesh.size))
    return rec


def _sharded_state_bytes_per_chip(cfg, mesh, sharding_profile: str) -> float:
    """Analytic resident bytes/chip for params + grads + Adam moments under
    the given sharding profile (what memory_analysis cannot attribute:
    its argument sizes are logical/global)."""
    import numpy as np

    from repro.launch.builders import _zero1_opt_specs
    from repro.launch.mesh import data_axes, model_axis_size
    from repro.models.model import param_shapes
    from repro.models.sharding import param_partition_specs
    from repro.optim.optimizers import adamw

    axes = data_axes(mesh)
    msize = model_axis_size(mesh)
    if sharding_profile == "dp_only":
        axes = (*axes, "model")
        msize = 1
    shapes = param_shapes(cfg)
    pspecs = param_partition_specs(shapes, cfg, model_size=msize,
                                   data_axes=axes)
    if sharding_profile == "fsdp":
        pspecs = _zero1_opt_specs(pspecs, shapes, axes, mesh)
    opt = adamw(3e-4)
    opt_shapes = jax.eval_shape(opt.init, shapes)
    ospecs = param_partition_specs(opt_shapes, cfg, model_size=msize,
                                   data_axes=axes)
    ospecs = jax.tree.map(
        lambda spec, leaf: spec if len(leaf.shape) == len(spec) else P(),
        ospecs, opt_shapes)
    if sharding_profile in ("tp+zero1", "fsdp"):
        ospecs = _zero1_opt_specs(ospecs, opt_shapes, axes, mesh)

    def shard_factor(spec):
        f = 1
        for axis in spec:
            if axis is None:
                continue
            for a in (axis if isinstance(axis, tuple) else (axis,)):
                f *= mesh.shape[a]
        return f

    def tally(shapes_tree, specs_tree, copies=1.0):
        total = 0.0
        for (path, leaf), (_, spec) in zip(
            jax.tree_util.tree_flatten_with_path(shapes_tree)[0],
            jax.tree_util.tree_flatten_with_path(specs_tree)[0],
        ):
            n = 1
            for d in leaf.shape:
                n *= d
            total += copies * n * leaf.dtype.itemsize / shard_factor(spec)
        return total

    # params + grads (same sharding) + opt state
    return tally(shapes, pspecs, copies=2.0) + tally(opt_shapes, ospecs)


def run_memory_variant(arch: str, shape_name: str, *, label: str,
                       hypothesis: str, sharding_profile: str = "tp",
                       cfg_updates: dict | None = None,
                       multi_pod: bool = False, compile: bool = True) -> dict:
    """Compile the PRODUCTION program and report peak-memory metrics:
    temp bytes from memory_analysis (live activations/buffers) plus the
    analytic per-chip resident state under the sharding profile."""
    from repro.configs import get_config, get_shape
    from repro.launch.builders import build_lowered
    from repro.launch.mesh import make_production_mesh
    from repro.launch.specs import pick_window

    cfg = get_config(arch)
    if cfg_updates:
        cfg = dataclasses.replace(cfg, **cfg_updates)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    temps = 0
    if compile:
        lowered, _ = build_lowered(cfg, shape, mesh,
                                   window=pick_window(cfg, shape),
                                   sharding_profile=sharding_profile)
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        temps = getattr(mem, "temp_size_in_bytes", 0)
    state = _sharded_state_bytes_per_chip(cfg, mesh, sharding_profile) \
        if shape.mode == "train" else 0.0
    return {
        "label": label, "hypothesis": hypothesis, "arch": arch,
        "shape": shape_name, "profile": sharding_profile,
        "cfg_updates": cfg_updates or {},
        "temp_bytes": int(temps),
        "state_bytes_per_chip": int(state),
        "state_gb_per_chip": state / 1e9,
        "chips": mesh.size, "wall_s": round(time.time() - t0, 1),
    }


def run_pearl_variant(arch: str, shape_name: str, *, label: str,
                      hypothesis: str, tau: int, sync_dtype=None,
                      sharded_sync: bool = False) -> dict:
    """PEARL pod-collective accounting: lower a round, parse pod-axis bytes.

    Costs inside the tau-step local scan are per-HLO-visit; the pod-axis
    collective (the sync) sits OUTSIDE the scan, so its bytes are exact. We
    report pod-collective bytes PER LOCAL STEP — the metric PEARL divides by
    tau (paper Theorem 3.4's communication saving, measured on compiled HLO).

    ``sharded_sync`` routes the sync through the explicit shard_map
    collective layer (repro.core.collective); the record then also carries
    the POD-AXIS collectives' operand dtypes, the direct evidence that a
    ``sync_dtype`` wire survived compilation (``wire_dtypes`` / a
    ``compressed_wire`` flag). Only pod-spanning lines are inspected: a
    model's within-pod data/model collectives may legitimately carry bf16
    activations, and counting them would fake the cross-pod claim.
    """
    from repro.configs import get_config, get_shape
    from repro.core.collective import compressed_wire_ops, wire_dtype_report
    from repro.launch.builders import build_pearl_lowered
    from repro.launch.mesh import make_production_mesh
    from repro.roofline.analysis import (
        ICI_BW,
        parse_collectives,
        pod_collective_lines,
    )

    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=True)
    t0 = time.time()
    lowered, _ = build_pearl_lowered(cfg, shape, mesh, window=0, tau=tau,
                                     sync_dtype=sync_dtype,
                                     sharded_sync=sharded_sync)
    compiled = lowered.compile()
    hlo = compiled.as_text()
    coll = parse_collectives(hlo, chips_per_pod=256)
    pod_hlo = pod_collective_lines(hlo, chips_per_pod=256)
    return {
        "label": label, "hypothesis": hypothesis, "arch": arch,
        "shape": shape_name, "tau": tau,
        "pod_collective_bytes_per_round": coll.pod_bytes,
        "pod_collective_bytes_per_local_step": coll.pod_bytes / tau,
        "pod_collective_s_per_local_step": coll.pod_bytes / tau / ICI_BW,
        "collective_by_op": coll.bytes_by_op,
        "sharded_sync": sharded_sync,
        "wire_dtypes": sorted({o.operand_dtype for o in
                               wire_dtype_report(pod_hlo)}),
        "compressed_wire": bool(compressed_wire_ops(pod_hlo)),
        "wall_s": round(time.time() - t0, 1),
    }


PAIRS = {}


def pair(name):
    def deco(fn):
        PAIRS[name] = fn
        return fn
    return deco


@pair("moe")
def pair_moe():
    """qwen3-moe-30b-a3b x train_4k: MoE dispatch-einsum and collective load."""
    a, s = "qwen3-moe-30b-a3b", "train_4k"
    return [
        run_variant(a, s, label="baseline(group=512,cf=1.25)",
                    hypothesis="baseline GShard grouping"),
        run_variant(a, s, label="group=256",
                    hypothesis="dispatch einsum FLOPs scale with group size; "
                               "halving group halves dispatch compute at equal "
                               "capacity variance",
                    cfg_updates={"moe_group_size": 256}),
        run_variant(a, s, label="group=128",
                    hypothesis="further halving keeps winning until capacity "
                               "quantization (ceil) dominates",
                    cfg_updates={"moe_group_size": 128}),
        run_variant(a, s, label="group=256,cf=1.0",
                    hypothesis="cf 1.25->1.0 cuts expert matmul + all-to-all "
                               "bytes by 20% at the cost of more drops",
                    cfg_updates={"moe_group_size": 256, "capacity_factor": 1.0}),
    ]


@pair("small")
def pair_small():
    """xlstm-125m x train_4k: collective-bound from unshardable 4-head blocks."""
    a, s = "xlstm-125m", "train_4k"
    return [
        run_variant(a, s, label="baseline(tp)",
                    hypothesis="baseline: weights partially replicated, "
                               "per-layer activation all-reduces dominate"),
        run_variant(a, s, label="dp_only",
                    hypothesis="125M params fit one chip; pure data "
                               "parallelism removes ALL per-layer activation "
                               "all-reduces; only the gradient all-reduce "
                               "remains (one per step, overlappable)",
                    sharding_profile="dp_only"),
    ]


@pair("granite")
def pair_granite():
    """granite-34b x prefill_32k: memory-dominated; the chunk knob moves PEAK
    LIVE memory (temp bytes of the compiled program), not bytes-accessed —
    which is exactly the VMEM/HBM working-set trade the flash kernel makes."""
    a, s = "granite-34b", "prefill_32k"
    return [
        run_memory_variant(a, s, label="baseline(chunk=1024)",
                           hypothesis="live score buffer per chunk ~ "
                                      "B_loc*H_loc*chunk*S"),
        run_memory_variant(a, s, label="chunk=256",
                           hypothesis="4x smaller chunks -> ~4x smaller live "
                                      "score buffers at equal FLOPs",
                           cfg_updates={"attn_chunk": 256}),
        run_memory_variant(a, s, label="chunk=4096",
                           hypothesis="4x larger chunks -> ~4x larger live "
                                      "buffers (regression expected)",
                           cfg_updates={"attn_chunk": 4096}),
    ]


@pair("llama4mem")
def pair_llama4mem():
    """llama4 400B x train_4k: HBM feasibility — fp32 Adam moments blow the
    16 GB/chip budget on one pod; ZeRO-1 shards them over data."""
    a, s = "llama4-maverick-400b-a17b", "train_4k"
    return [
        run_memory_variant(a, s, label="baseline(tp)", compile=False,
                           hypothesis="TP-16 replicates params over data=16: "
                                      "6.4 TB fp32 state / 16 >> 16 GB HBM"),
        run_memory_variant(a, s, label="tp+zero1", compile=False,
                           hypothesis="sharding m/v over data removes 15/16 "
                                      "of optimizer bytes; params still "
                                      "replicated -> still infeasible",
                           sharding_profile="tp+zero1"),
        run_memory_variant(a, s, label="fsdp(1 pod)", compile=False,
                           hypothesis="ZeRO-3: params+grads+moments over "
                                      "data x model = 256 -> 6.4 TB/256 = "
                                      "~25 GB, still over 16 GB",
                           sharding_profile="fsdp"),
        run_memory_variant(a, s, label="fsdp(2 pods)", multi_pod=True,
                           hypothesis="512-way FSDP halves resident state "
                                      "again -> ~12.5 GB/chip, fits; compile "
                                      "proves the all-gather program lowers",
                           sharding_profile="fsdp"),
    ]


@pair("pearl")
def pair_pearl():
    """stablelm-1.6b x train_4k on 2 pods: the paper's technique itself."""
    a, s = "stablelm-1.6b", "train_4k"
    import jax.numpy as jnp

    out = [
        run_pearl_variant(a, s, label=f"pearl(tau={t})",
                          hypothesis="pod-axis bytes per local step = "
                                     "sync_bytes / tau (Thm 3.4 realized as "
                                     "cross-pod traffic)", tau=t)
        for t in (1, 2, 8)
    ]
    out.append(run_pearl_variant(
        a, s, label="pearl(tau=8)+bf16 sync",
        hypothesis="compressed broadcast (paper future work): quantizing the "
                   "sync operands should halve wire bytes again -> 16x vs "
                   "tau=1 fp32. MEASURED: unchanged on this GSPMD lowering — "
                   "XLA reassociates the convert around its f32 reduce "
                   "(and the CPU build float-normalizes bf16 collectives). "
                   "The honest negative result that motivated the explicit "
                   "collective layer; see the sharded variant below.",
        tau=8, sync_dtype=jnp.bfloat16))
    out.append(run_pearl_variant(
        a, s, label="pearl(tau=8)+bf16 shard_map",
        hypothesis="explicit wire (repro.core.collective): ship the sync as "
                   "its bf16 bit pattern under shard_map so neither "
                   "reassociation nor float normalization can re-widen it — "
                   "the pod-axis collective operand must be 2-byte in the "
                   "compiled HLO (wire_dtypes/compressed_wire record it) "
                   "and pod bytes/local step halve vs the f32 sync.",
        tau=8, sync_dtype=jnp.bfloat16, sharded_sync=True))
    return out


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--pair", choices=sorted(PAIRS), required=True)
    ap.add_argument("--out", default="")
    args = ap.parse_args()

    records = PAIRS[args.pair]()
    out = args.out or f"experiments/perf_{args.pair}.json"
    os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
    with open(out, "w") as f:
        json.dump(records, f, indent=1)

    base = records[0]
    for r in records:
        if "compute_s" in r:
            print(f"{r['label']:28s} compute={r['compute_s']:.4f}s "
                  f"memory={r['memory_s']:.4f}s "
                  f"collective={r['collective_s']:.4f}s "
                  f"(vs base mem x{r['memory_s'] / max(base['memory_s'], 1e-12):.2f}, "
                  f"coll x{r['collective_s'] / max(base['collective_s'], 1e-12):.2f})",
                  flush=True)
        elif "pod_collective_bytes_per_local_step" in r:
            print(f"{r['label']:28s} pod_bytes/local_step="
                  f"{r['pod_collective_bytes_per_local_step'] / 1e9:.3f} GB "
                  f"({r['pod_collective_s_per_local_step']:.4f}s)", flush=True)
        else:
            print(f"{r['label']:28s} temp={r['temp_bytes'] / 1e9:.2f} GB "
                  f"state/chip={r['state_gb_per_chip']:.2f} GB", flush=True)


if __name__ == "__main__":
    main()
