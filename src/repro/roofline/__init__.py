"""Roofline: HLO cost/collective parsing + trip-count-corrected cost model."""
