"""Trip-count-corrected HLO costs for the roofline (Section Roofline).

XLA's ``compiled.cost_analysis()`` visits each while-loop body ONCE — it does
not multiply by trip count — so FLOPs/bytes/collectives of scanned layer
stacks and chunk loops are undercounted. The production artifact keeps scans
(bounded HLO size); for *costs* we compile tiny component variants with all
loops unrolled and recombine:

    cost(model) = cost(base)                      # embed + head + loss + opt
                + sum_kind  n_kind * body_kind    # per-layer-kind marginals
                + enc_layers * enc_body           # audio encoder
                + slstm analytic extra            # time recurrence stays a loop

where ``body_kind = cost(base + one KIND layer) - cost(base)``. Every variant
uses ``unroll_loops=True`` (layer scans, SSD/mLSTM chunk scans unrolled) and a
single-chunk attention so nothing hides inside a loop. The sLSTM *time* scan
cannot be unrolled (seq_len iterations); its per-step recurrence cost is added
analytically (documented approximation: recurrent matvec dominates).
"""

from __future__ import annotations

import dataclasses
from collections import Counter

import jax

from repro.configs.base import ModelConfig
from repro.configs.shapes import InputShape
from repro.roofline.analysis import CollectiveStats, parse_collectives


@dataclasses.dataclass
class MeasuredCost:
    flops: float
    bytes: float
    collectives: CollectiveStats

    def __sub__(self, o: "MeasuredCost") -> "MeasuredCost":
        return MeasuredCost(
            self.flops - o.flops,
            self.bytes - o.bytes,
            CollectiveStats(
                bytes_by_op={
                    k: self.collectives.bytes_by_op.get(k, 0)
                    - o.collectives.bytes_by_op.get(k, 0)
                    for k in set(self.collectives.bytes_by_op)
                    | set(o.collectives.bytes_by_op)
                },
                total_bytes=self.collectives.total_bytes - o.collectives.total_bytes,
                pod_bytes=self.collectives.pod_bytes - o.collectives.pod_bytes,
                count=self.collectives.count - o.collectives.count,
            ),
        )

    def scaled_add(self, o: "MeasuredCost", k: float) -> "MeasuredCost":
        return MeasuredCost(
            self.flops + k * o.flops,
            self.bytes + k * o.bytes,
            CollectiveStats(
                bytes_by_op={
                    key: self.collectives.bytes_by_op.get(key, 0)
                    + int(k * o.collectives.bytes_by_op.get(key, 0))
                    for key in set(self.collectives.bytes_by_op)
                    | set(o.collectives.bytes_by_op)
                },
                total_bytes=int(self.collectives.total_bytes
                                + k * o.collectives.total_bytes),
                pod_bytes=int(self.collectives.pod_bytes
                              + k * o.collectives.pod_bytes),
                count=int(self.collectives.count + k * o.collectives.count),
            ),
        )


def _measure(cfg: ModelConfig, shape: InputShape, mesh, window: int,
             sharding_profile: str = "tp") -> MeasuredCost:
    from repro.launch.builders import build_lowered

    lowered, _ = build_lowered(cfg, shape, mesh, window=window,
                               sharding_profile=sharding_profile)
    compiled = lowered.compile()
    cost = dict(compiled.cost_analysis())
    coll = parse_collectives(compiled.as_text(), chips_per_pod=256)
    return MeasuredCost(
        flops=float(cost.get("flops", 0.0)),
        bytes=float(cost.get("bytes accessed", 0.0)),
        collectives=coll,
    )


def _variant(cfg: ModelConfig, dec_types: tuple[str, ...], enc: int) -> ModelConfig:
    # attn_chunk >= 2048 keeps unrolled chunk-body count modest at 32k
    # sequences (16 bodies) without the single-chunk S^2 einsum XLA chokes on.
    return dataclasses.replace(
        cfg,
        override_layer_types=dec_types,
        n_layers=max(len(dec_types), 1),
        enc_layers=enc,
        unroll_loops=True,
        attn_chunk=max(cfg.attn_chunk, 2048),
    )


def _slstm_extra(cfg: ModelConfig, shape: InputShape, mesh, n_slstm: int
                 ) -> MeasuredCost:
    """Analytic per-device extra for the sequential sLSTM time recurrence.

    The scan body (recurrent matvec R h + gate elementwise) is counted once by
    HLO cost analysis; the remaining (L-1) iterations are added here. Train
    multiplies by 3 (fwd + ~2x transpose loop). Bytes: the recurrent weights
    and carried state are re-touched every iteration.
    """
    if n_slstm == 0 or shape.mode == "decode":
        return MeasuredCost(0.0, 0.0, CollectiveStats({}, 0, 0, 0))
    n_data = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            n_data *= mesh.shape[a]
    b_local = max(1, shape.global_batch // n_data)
    dh = cfg.d_model // cfg.n_heads
    rec_flops = 2.0 * b_local * cfg.n_heads * dh * 4 * dh
    gate_flops = 16.0 * b_local * cfg.d_model
    steps = shape.seq_len - 1
    factor = 3.0 if shape.mode == "train" else 1.0
    flops = factor * steps * (rec_flops + gate_flops) * n_slstm
    r_bytes = cfg.n_heads * dh * 4 * dh * 4
    state_bytes = 10.0 * b_local * cfg.d_model * 4
    bytes_ = factor * steps * (r_bytes + state_bytes) * n_slstm
    return MeasuredCost(flops, bytes_, CollectiveStats({}, 0, 0, 0))


def corrected_cost(cfg: ModelConfig, shape: InputShape, mesh, *, window: int,
                   sharding_profile: str = "tp") -> tuple[MeasuredCost, dict]:
    """Trip-count-corrected per-device cost for one (arch x shape x mesh).

    Returns (cost, detail) where detail records the component measurements.
    """
    counts = Counter(cfg.layer_types())
    enc = cfg.enc_layers
    detail: dict = {"layer_counts": dict(counts)}

    base_enc = 1 if enc else 0     # keep cross-attn structure in dec variants
    base = _measure(_variant(cfg, (), base_enc), shape, mesh, window,
                    sharding_profile)
    total = base
    detail["base_flops"] = base.flops

    if enc:
        enc0 = _measure(_variant(cfg, (), 0), shape, mesh, window,
                        sharding_profile)
        enc_body = base - enc0
        # base already contains ONE encoder layer
        total = total.scaled_add(enc_body, enc - 1)
        detail["enc_body_flops"] = enc_body.flops

    for kind, n in counts.items():
        with_kind = _measure(_variant(cfg, (kind,), base_enc), shape, mesh,
                             window, sharding_profile)
        body = with_kind - base
        total = total.scaled_add(body, n)
        detail[f"body_{kind}_flops"] = body.flops

    extra = _slstm_extra(cfg, shape, mesh, counts.get("slstm", 0))
    total = total.scaled_add(extra, 1.0)
    if extra.flops:
        detail["slstm_analytic_flops"] = extra.flops
    return total, detail
