"""Roofline-term derivation from compiled dry-run artifacts (deliverable g).

Per (arch x shape x mesh) we derive, from ``lowered.compile()``:

  compute term    = HLO_FLOPs_global / (chips * 197e12  bf16 FLOP/s)
  memory term     = HLO_bytes_global / (chips * 819e9   B/s HBM)
  collective term = collective_bytes_global / (chips * 50e9 B/s ICI per link)

``cost_analysis()`` on a partitioned module reports *per-device* numbers; we
multiply by chip count for the global view and divide back for the terms, so
either convention yields the same seconds. collective_bytes is not in
cost_analysis — we parse the optimized HLO text and sum the result-shape
bytes of every collective op (all-reduce counted twice: a ring all-reduce
moves ~2x the buffer). Collectives over the ``pod`` axis are additionally
tallied separately (``pod_collective_bytes``) by their replica-group span —
that is the byte count PEARL-SGD divides by tau.
"""

from __future__ import annotations

import dataclasses
import json
import re

import numpy as np

PEAK_FLOPS = 197e12        # TPU v5e bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVE_RE = re.compile(
    r"=\s*(?:\([^)]*\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](T\([0-9,]+\))?"
)


def _shape_bytes(text: str) -> int:
    """Sum byte sizes of every typed array shape in an HLO result clause."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _result_clause(line: str) -> str:
    """The result-shape portion of an HLO instruction line (LHS of the op)."""
    idx = line.find("= ")
    if idx < 0:
        return line
    rest = line[idx + 2 :]
    op = _COLLECTIVE_RE.search(line)
    if op:
        return rest[: op.end() - idx - 2]
    return rest


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_op: dict[str, int]
    total_bytes: int
    pod_bytes: int            # collectives whose replica group spans pods
    count: int


def parse_collectives(hlo_text: str, *, chips_per_pod: int = 256) -> CollectiveStats:
    """Sum collective-op bytes from optimized HLO text (per-device module)."""
    by_op: dict[str, int] = {}
    pod_bytes = 0
    count = 0
    for line in hlo_text.splitlines():
        m = _COLLECTIVE_RE.search(line)
        if not m:
            continue
        op = m.group(1)
        # result clause sits inside the match: "= <shape> <op>(".
        nbytes = _shape_bytes(m.group(0))
        factor = 2 if op == "all-reduce" else 1
        moved = nbytes * factor
        by_op[op] = by_op.get(op, 0) + moved
        count += 1
        # does the replica group cross a pod boundary?
        span = _group_span(line)
        if span and span > chips_per_pod:
            pod_bytes += moved
    return CollectiveStats(
        bytes_by_op=by_op,
        total_bytes=sum(by_op.values()),
        pod_bytes=pod_bytes,
        count=count,
    )


def pod_collective_lines(hlo_text: str, *, chips_per_pod: int = 256) -> str:
    """The HLO lines whose collective replica groups span pod boundaries.

    For feeding cross-pod-only views into per-line analyses (e.g.
    ``repro.core.collective.wire_dtype_report``): a model's data/model-axis
    collectives may legitimately carry bf16 activations, so wire-dtype
    claims about the PEARL sync must be made on the pod-axis lines only.
    """
    keep = []
    for line in hlo_text.splitlines():
        if not _COLLECTIVE_RE.search(line):
            continue
        span = _group_span(line)
        if span and span > chips_per_pod:
            keep.append(line)
    return "\n".join(keep)


def _group_span(line: str) -> int | None:
    """Max replica-group span (min..max device-id distance within a group).

    Iota form ``[N,M]<=[dims](T(perm))?``: without a transpose the N groups
    are contiguous runs of M devices (span M); with a transpose the members
    stride by N (span (M-1)*N + 1) — the pattern a ``pod``-major axis
    collective produces on the (pod, data, model) mesh.
    """
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        n_groups, group_size = int(m.group(1)), int(m.group(2))
        if m.group(4):  # transposed iota: strided groups
            return (group_size - 1) * n_groups + 1
        return group_size
    m = _GROUPS_RE.search(line)
    if not m:
        return None
    span = 0
    for grp in re.findall(r"\{([0-9, ]+)\}", "{" + m.group(1) + "}"):
        ids = [int(x) for x in grp.replace(" ", "").split(",") if x]
        if ids:
            span = max(span, max(ids) - min(ids) + 1)
    return span or None


@dataclasses.dataclass
class RooflineReport:
    """Per (arch x shape x mesh) roofline summary (all terms in seconds)."""

    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    pod_collective_bytes: float
    peak_memory_bytes: float
    model_flops: float
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    useful_flops_ratio: float

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def build_report(
    *,
    arch: str,
    shape: str,
    mesh_name: str,
    chips: int,
    cost: dict,
    collectives: CollectiveStats,
    peak_memory: float,
    model_flops: float,
) -> RooflineReport:
    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_dev / HBM_BW
    collective_s = collectives.total_bytes / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    hlo_flops_global = flops_dev * chips
    ratio = model_flops / hlo_flops_global if hlo_flops_global else 0.0
    return RooflineReport(
        arch=arch,
        shape=shape,
        mesh=mesh_name,
        chips=chips,
        flops_per_device=flops_dev,
        bytes_per_device=bytes_dev,
        collective_bytes_per_device=float(collectives.total_bytes),
        pod_collective_bytes=float(collectives.pod_bytes),
        peak_memory_bytes=float(peak_memory),
        model_flops=float(model_flops),
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        bottleneck=bottleneck,
        useful_flops_ratio=float(ratio),
    )


def count_params(shapes_tree) -> int:
    import jax

    return int(sum(np.prod(l.shape) for l in jax.tree.leaves(shapes_tree)))


def active_params(cfg, shapes_tree) -> int:
    """Active parameter count per token (MoE experts scaled by top_k/E)."""
    import jax

    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes_tree)[0]:
        keys = [str(p.key) if hasattr(p, "key") else str(p.idx) for p in path]
        n = int(np.prod(leaf.shape))
        if cfg.n_experts and "moe" in keys and keys[-1] in ("gate", "up", "down") \
                and len(leaf.shape) >= 3:
            n = n * cfg.top_k // cfg.n_experts
        total += n
    return total


def model_flops_estimate(cfg, shape, n_active_params: int) -> float:
    """6 * N_active * tokens for training; 2 * N_active * tokens for inference."""
    tokens = shape.global_batch * (shape.seq_len if shape.mode != "decode" else 1)
    factor = 6.0 if shape.mode == "train" else 2.0
    return factor * n_active_params * tokens
