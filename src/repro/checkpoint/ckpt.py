"""Pytree checkpointing (npz + path-keyed layout, resume-safe).

Arrays are gathered to host and stored under '/'-joined tree paths; restore
rebuilds into the *target* pytree structure (so sharding/placement of the
restored state is decided by the caller, e.g. ``jax.device_put`` with the
production specs). Step metadata lives alongside for trainer resume.
"""

from __future__ import annotations

import json
import os
from typing import Any

import jax
import numpy as np


def _flatten_with_paths(tree, *, widen: bool = False) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path
        )
        arr = np.asarray(leaf)
        if widen and arr.dtype.kind not in "fiub":
            # npz cannot round-trip extension dtypes (bfloat16): widen to f32;
            # restore casts back to the target leaf dtype.
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def save_checkpoint(directory: str, step: int, state: dict[str, Any]) -> str:
    """Save {name: pytree} state dicts. Returns the checkpoint path."""
    os.makedirs(directory, exist_ok=True)
    path = os.path.join(directory, f"ckpt_{step:08d}")
    payload = {}
    for name, tree in state.items():
        for k, v in _flatten_with_paths(tree, widen=True).items():
            payload[f"{name}|{k}"] = v
    np.savez(path + ".npz", **payload)
    with open(path + ".json", "w") as f:
        json.dump({"step": step, "names": sorted(state)}, f)
    return path + ".npz"


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(f[len("ckpt_") : -len(".json")])
        for f in os.listdir(directory)
        if f.startswith("ckpt_") and f.endswith(".json")
    ]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, targets: dict[str, Any]
                       ) -> dict[str, Any]:
    """Restore into the structure (and dtypes) of ``targets``."""
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    with np.load(path) as data:
        out = {}
        for name, target in targets.items():
            leaves_paths = jax.tree_util.tree_flatten_with_path(target)
            rebuilt = []
            for pth, leaf in leaves_paths[0]:
                key = "/".join(
                    str(p.key) if hasattr(p, "key") else str(p.idx) for p in pth
                )
                arr = data[f"{name}|{key}"]
                rebuilt.append(jax.numpy.asarray(arr).astype(leaf.dtype))
            out[name] = jax.tree_util.tree_unflatten(leaves_paths[1], rebuilt)
    return out
