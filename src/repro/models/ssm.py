"""Mamba2 (SSD) block: chunked selective-state-space scan + O(1) decode.

State-space recurrence per head (state size N, head dim P):

    h_t = a_t * h_{t-1} + dt_t * (B_t outer x_t)      h in R^{P x N}
    y_t = h_t @ C_t + D * x_t

with input-dependent ``a_t = exp(dt_t * A)`` (A < 0 per head), ``B_t, C_t``
shared across heads (single group), and ``dt_t = softplus(...)`` per head.

The train/prefill path uses the chunked (block-parallel) SSD algorithm:
within a chunk of length Q the contribution is an attention-like masked
``(C B^T ⊙ decay) x`` product; across chunks a short ``lax.scan`` carries the
(H, P, N) state. Live memory is O(L*Q) per head instead of O(L^2) or
O(L*P*N). The Pallas kernel in :mod:`repro.kernels.mamba2_scan` implements
the same chunk kernel with VMEM tiling; ``ref.py`` holds the sequential
oracle both are tested against.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import (
    causal_conv1d,
    causal_conv1d_step,
    dense_init,
    init_causal_conv,
    rms_norm,
)

Array = jax.Array


def init_mamba(key: Array, d_model: int, d_inner: int, n_heads: int,
               ssm_state: int, conv_kernel: int) -> dict:
    """Parameters for one Mamba2 block (single B/C group)."""
    k_in, k_conv, k_out, k_dt = jax.random.split(key, 4)
    # in_proj emits [z (d_inner), x (d_inner), B (N), C (N), dt (H)]
    proj_out = 2 * d_inner + 2 * ssm_state + n_heads
    return {
        "in_proj": dense_init(k_in, (d_model, proj_out)),
        "conv": init_causal_conv(k_conv, d_inner + 2 * ssm_state, conv_kernel),
        "A_log": jnp.zeros((n_heads,), jnp.float32),   # A = -exp(A_log) = -1
        "D": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "out_norm": jnp.ones((d_inner,), jnp.float32),
        "out_proj": dense_init(k_out, (d_inner, d_model)),
    }


def _split_proj(params: dict, x: Array, d_inner: int, n: int, h: int):
    """Project input and split into (z, xBC, dt)."""
    proj = x @ params["in_proj"].astype(x.dtype)
    z = proj[..., :d_inner]
    x_bc = proj[..., d_inner : 2 * d_inner + 2 * n]
    dt_raw = proj[..., 2 * d_inner + 2 * n :]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    return z, x_bc, dt


def ssd_chunked(x: Array, dt: Array, A: Array, B: Array, C: Array,
                chunk: int = 256, h0: Array | None = None,
                unroll: bool = False):
    """Chunked SSD scan.

    Args:
      x:  (batch, L, H, P) inputs.
      dt: (batch, L, H) step sizes (post-softplus, fp32).
      A:  (H,) negative decay rates.
      B:  (batch, L, N); C: (batch, L, N) (single group).
      h0: optional initial state (batch, H, P, N).

    Returns (y (batch, L, H, P), h_final (batch, H, P, N)).
    """
    bsz, L, H, P = x.shape
    N = B.shape[-1]
    Q = min(chunk, L)
    while L % Q:
        Q //= 2
    nc = L // Q

    dtype = x.dtype
    log_a = (dt * A[None, None, :]).astype(jnp.float32)            # (b, L, H) <= 0
    xr = x.reshape(bsz, nc, Q, H, P)
    br = B.reshape(bsz, nc, Q, N)
    cr = C.reshape(bsz, nc, Q, N)
    dtr = dt.reshape(bsz, nc, Q, H)
    lar = log_a.reshape(bsz, nc, Q, H)

    # cumulative decay within each chunk (inclusive)
    cum = jnp.cumsum(lar, axis=2)                                  # (b, nc, Q, H)
    total = cum[:, :, -1]                                          # (b, nc, H)

    # ---- intra-chunk: attention-like masked product ----
    # decay(t, s) = exp(cum_t - cum_s) for s <= t  (strictly: prod_{s<r<=t} a_r)
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]            # (b,nc,t,s,H)
    mask = (jnp.arange(Q)[:, None] >= jnp.arange(Q)[None, :])[None, None, :, :, None]
    decay = jnp.where(mask, jnp.exp(seg), 0.0).astype(dtype)
    cb = jnp.einsum("bgtn,bgsn->bgts", cr, br).astype(dtype)       # (b,nc,t,s)
    w = cb[..., None] * decay * dtr[:, :, None, :, :].astype(dtype)  # (b,nc,t,s,H)
    y_intra = jnp.einsum("bgtsh,bgshp->bgthp", w, xr)

    # ---- chunk states: S_g = sum_s exp(total - cum_s) dt_s B_s (x) x_s ----
    state_decay = jnp.exp(total[:, :, None, :] - cum).astype(dtype)  # (b,nc,Q,H)
    su = jnp.einsum("bgsh,bgshp,bgsn->bghpn",
                    state_decay * dtr.astype(dtype), xr, br)        # (b,nc,H,P,N)

    # ---- inter-chunk recurrence over nc chunks ----
    a_chunk = jnp.exp(total).astype(dtype)                          # (b, nc, H)

    def scan_fn(h, inp):
        a_g, s_g = inp
        h_new = a_g[:, :, None, None] * h + s_g
        return h_new, h

    init = (jnp.zeros((bsz, H, P, N), dtype) if h0 is None else h0.astype(dtype))
    h_final, h_prevs = jax.lax.scan(
        scan_fn,
        init,
        (jnp.moveaxis(a_chunk, 1, 0), jnp.moveaxis(su, 1, 0)),
        unroll=unroll,
    )
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                           # (b,nc,H,P,N)

    # ---- inter-chunk contribution: y_t += C_t . (exp(cum_t) h_prev) ----
    in_decay = jnp.exp(cum).astype(dtype)                           # (b,nc,Q,H)
    y_inter = jnp.einsum("bgtn,bghpn->bgthp", cr, h_prevs) * in_decay[..., None]

    y = (y_intra + y_inter).reshape(bsz, L, H, P)
    return y, h_final


def mamba_block(params: dict, x: Array, *, d_inner: int, n_heads: int,
                ssm_state: int, chunk: int = 256, return_cache: bool = False,
                use_kernel: bool = False, unroll: bool = False):
    """Full Mamba2 block forward (train/prefill). x: (B, L, D).

    With ``return_cache`` the final recurrent state + conv window are returned
    for decode continuation.
    """
    bsz, L, _ = x.shape
    P = d_inner // n_heads
    z, x_bc_raw, dt = _split_proj(params, x, d_inner, ssm_state, n_heads)
    x_bc = jax.nn.silu(causal_conv1d(params["conv"], x_bc_raw))
    xs = x_bc[..., :d_inner].reshape(bsz, L, n_heads, P)
    B = x_bc[..., d_inner : d_inner + ssm_state]
    C = x_bc[..., d_inner + ssm_state :]
    A = -jnp.exp(params["A_log"])
    if use_kernel:
        from repro.kernels.mamba2_scan.ops import ssd_scan

        y, h_final = ssd_scan(xs, dt, A, B, C, chunk=chunk)
    else:
        y, h_final = ssd_chunked(xs, dt, A, B, C, chunk=chunk, unroll=unroll)
    y = y + params["D"].astype(y.dtype)[None, None, :, None] * xs
    y = y.reshape(bsz, L, d_inner)
    y = rms_norm(y * jax.nn.silu(z), params["out_norm"])
    out = y @ params["out_proj"].astype(x.dtype)
    if not return_cache:
        return out
    k = params["conv"]["w"].shape[0]
    pad = jnp.pad(x_bc_raw, ((0, 0), (k - 1, 0), (0, 0)))
    cache = {"h": h_final, "conv": pad[:, L : L + k - 1, :]}
    return out, cache


def init_mamba_cache(bsz: int, d_inner: int, n_heads: int, ssm_state: int,
                     conv_kernel: int, dtype) -> dict:
    P = d_inner // n_heads
    return {
        "h": jnp.zeros((bsz, n_heads, P, ssm_state), dtype),
        "conv": jnp.zeros((bsz, conv_kernel - 1, d_inner + 2 * ssm_state), dtype),
    }


def mamba_decode_step(params: dict, cache: dict, x: Array, *, d_inner: int,
                      n_heads: int, ssm_state: int) -> tuple[Array, dict]:
    """One-token recurrent step. x: (B, 1, D) -> (y (B, 1, D), new cache)."""
    bsz = x.shape[0]
    P = d_inner // n_heads
    z, x_bc, dt = _split_proj(params, x[:, 0], d_inner, ssm_state, n_heads)
    conv_win, x_bc = causal_conv1d_step(params["conv"], cache["conv"], x_bc)
    x_bc = jax.nn.silu(x_bc)
    xs = x_bc[..., :d_inner].reshape(bsz, n_heads, P)
    B = x_bc[..., d_inner : d_inner + ssm_state]
    C = x_bc[..., d_inner + ssm_state :]
    A = -jnp.exp(params["A_log"])
    a = jnp.exp(dt * A[None, :]).astype(x.dtype)                    # (B, H)
    upd = jnp.einsum("bh,bhp,bn->bhpn", dt.astype(x.dtype), xs, B)
    h = a[:, :, None, None] * cache["h"] + upd
    y = jnp.einsum("bhpn,bn->bhp", h, C)
    y = y + params["D"].astype(y.dtype)[None, :, None] * xs
    y = y.reshape(bsz, d_inner)
    y = rms_norm(y * jax.nn.silu(z), params["out_norm"])
    out = (y @ params["out_proj"].astype(x.dtype))[:, None, :]
    return out, {"h": h, "conv": conv_win}
