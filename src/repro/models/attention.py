"""GQA attention: memory-bounded prefill/train path + KV-cache decode path.

Prefill/train uses a query-chunked online-softmax formulation (a pure-jnp
flash pattern: scores for one query chunk at a time, O(S * chunk) live
memory) so 32k-sequence dry-runs do not materialize S^2 score tensors. The
Pallas kernel in :mod:`repro.kernels.flash_attention` implements the same
contract for the TPU target; ``use_kernel=True`` switches to it.

Sliding-window masking makes dense architectures eligible for the
``long_500k`` decode shape: windowed layers keep a ring-buffer cache of
``window`` entries (see :mod:`repro.serve.cache`).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, dense_init

Array = jax.Array

NEG_INF = -1e30


def init_attention(key: Array, d_model: int, n_heads: int, n_kv: int, head_dim: int) -> dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": dense_init(kq, (d_model, n_heads, head_dim)),
        "wk": dense_init(kk, (d_model, n_kv, head_dim)),
        "wv": dense_init(kv, (d_model, n_kv, head_dim)),
        "wo": dense_init(ko, (n_heads, head_dim, d_model), in_axis=2),
    }


def qkv_project(params: dict, x: Array, positions: Array, rope_theta: float,
                use_rope: bool = True):
    """x: (B, S, D) -> q (B,S,H,hd), k/v (B,S,KV,hd), roped."""
    dtype = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(dtype))
    if use_rope:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    return q, k, v


def _expand_kv(k: Array, n_heads: int) -> Array:
    """(B, S, KV, hd) -> (B, S, H, hd) by repeating each KV head H/KV times."""
    b, s, kv, hd = k.shape
    rep = n_heads // kv
    return jnp.repeat(k, rep, axis=2) if rep > 1 else k


def chunked_attention(
    q: Array,
    k: Array,
    v: Array,
    *,
    chunk: int = 1024,
    causal: bool = True,
    window: int = 0,
    q_offset: Array | None = None,
    unroll: bool = False,
) -> Array:
    """Query-chunked softmax attention.

    Args:
      q: (B, Sq, H, hd); k, v: (B, Sk, H, hd) (KV already head-expanded).
      chunk: query-chunk size (memory bound: B*H*chunk*Sk live scores).
      causal: apply causal mask (query position i attends to key j <= i).
      window: if > 0, additionally mask keys with i - j >= window.
      q_offset: scalar offset of query positions relative to key positions
        (decode: Sq=1 queries sit at position ``q_offset``).

    Returns (B, Sq, H, hd).
    """
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    scale = hd**-0.5
    offset = jnp.asarray(0 if q_offset is None else q_offset, jnp.int32)

    kt = jnp.swapaxes(k, 1, 2)  # (B, H, Sk, hd)
    vt = jnp.swapaxes(v, 1, 2)

    n_chunks = max(1, sq // chunk)
    if sq % chunk:
        # fall back to a single chunk when the sequence doesn't tile
        n_chunks, chunk_ = 1, sq
    else:
        chunk_ = chunk

    qs = jnp.swapaxes(q, 1, 2).reshape(b, h, n_chunks, chunk_, hd)
    key_pos = jnp.arange(sk)

    def one_chunk(c):
        qc = qs[:, :, c]                                   # (B, H, cq, hd)
        q_pos = offset + c * chunk_ + jnp.arange(chunk_)
        scores = jnp.einsum("bhqk,bhsk->bhqs", qc, kt) * scale
        mask = jnp.ones((chunk_, sk), bool)
        if causal:
            mask &= q_pos[:, None] >= key_pos[None, :]
        if window > 0:
            mask &= q_pos[:, None] - key_pos[None, :] < window
        scores = jnp.where(mask, scores.astype(jnp.float32), NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(qc.dtype)
        return jnp.einsum("bhqs,bhsk->bhqk", probs, vt)

    if n_chunks == 1:
        out = one_chunk(0)[None]
    else:
        # scan (not map) so the cost model can unroll chunk bodies into the
        # HLO — XLA's cost analysis does not multiply while-loop trip counts.
        _, out = jax.lax.scan(
            lambda carry, c: (carry, one_chunk(c)),
            0, jnp.arange(n_chunks), unroll=unroll,
        )                                                     # (n, B, H, cq, hd)
    out = jnp.moveaxis(out, 0, 2).reshape(b, h, sq, hd)
    return jnp.swapaxes(out, 1, 2)


def attention_block(
    params: dict,
    x: Array,
    positions: Array,
    *,
    n_heads: int,
    rope_theta: float,
    chunk: int,
    causal: bool = True,
    window: int = 0,
    kv_override: tuple[Array, Array] | None = None,
    use_kernel: bool = False,
    return_kv: bool = False,
    unroll: bool = False,
):
    """Full attention sub-block: QKV -> (flash) attention -> output proj.

    ``kv_override`` supplies externally-computed K/V (cross-attention).
    ``return_kv`` additionally returns the (unexpanded, roped) K/V for KV
    cache construction at prefill.
    """
    q, k, v = qkv_project(params, x, positions, rope_theta,
                          use_rope=kv_override is None)
    if kv_override is not None:
        k, v = kv_override
    kv_raw = (k, v)
    k = _expand_kv(k, n_heads)
    v = _expand_kv(v, n_heads)
    if use_kernel:
        from repro.kernels.flash_attention.ops import flash_attention

        out = flash_attention(q, k, v, causal=causal, window=window)
    else:
        out = chunked_attention(q, k, v, chunk=chunk, causal=causal,
                                window=window, unroll=unroll)
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    if return_kv:
        return out, kv_raw
    return out


def decode_attention(
    params: dict,
    x: Array,
    k_cache: Array,
    v_cache: Array,
    cache_len: Array,
    position: Array,
    *,
    n_heads: int,
    rope_theta: float,
    window: int = 0,
    ring: bool = False,
) -> tuple[Array, Array, Array]:
    """One-token decode against a KV cache.

    Args:
      x: (B, 1, D) current token activations.
      k_cache, v_cache: (B, C, KV, hd) — C is the cache capacity (= seq_len
        for full-attention layers; = window for ring-buffered layers).
      cache_len: number of valid entries currently in the cache (scalar).
      position: absolute position of the new token (scalar).
      ring: if True the cache is a ring buffer (sliding-window layers);
        the new KV overwrites slot ``position % C``.

    Returns (attn_out (B,1,D), new_k_cache, new_v_cache).
    """
    b = x.shape[0]
    q, k_new, v_new = qkv_project(
        params, x, position[None][None].repeat(b, 0), rope_theta
    )
    capacity = k_cache.shape[1]
    slot = jnp.where(ring, position % capacity, position)
    zero = jnp.zeros((), slot.dtype)
    k_cache = jax.lax.dynamic_update_slice(
        k_cache, k_new.astype(k_cache.dtype), (zero, slot, zero, zero)
    )
    v_cache = jax.lax.dynamic_update_slice(
        v_cache, v_new.astype(v_cache.dtype), (zero, slot, zero, zero)
    )

    k = _expand_kv(k_cache, n_heads)
    v = _expand_kv(v_cache, n_heads)
    scale = q.shape[-1] ** -0.5
    scores = jnp.einsum("bshk,bchk->bhc", q, k.astype(q.dtype)) * scale  # s == 1
    idx = jnp.arange(capacity)
    valid = idx <= jnp.minimum(cache_len, position)
    if ring:
        valid = idx < jnp.minimum(capacity, position + 1)
    elif window > 0:
        valid &= position - idx < window
    scores = jnp.where(valid[None, None, :], scores.astype(jnp.float32), NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhc,bchk->bhk", probs, v.astype(q.dtype))[:, None]  # (B,1,H,hd)
    attn = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    return attn, k_cache, v_cache
