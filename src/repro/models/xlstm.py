"""xLSTM blocks: chunkwise-parallel mLSTM and sequential sLSTM [arXiv:2405.04517].

mLSTM keeps a matrix memory ``C in R^{dh x dh}`` per head with exponential
input gates and forget-gate decay, stabilized in log space:

    m_t = max(logf_t + m_{t-1}, logi_t)
    C_t = exp(logf_t + m_{t-1} - m_t) C_{t-1} + exp(logi_t - m_t) v_t k_t^T
    n_t = exp(logf_t + m_{t-1} - m_t) n_{t-1} + exp(logi_t - m_t) k_t
    h_t = (C_t q_t) / max(|n_t . q_t|, exp(-m_t))

The train/prefill path evaluates this in chunkwise-parallel form (intra-chunk
attention-like masked product + inter-chunk state carry in a ``lax.scan``) —
mirrored by the Pallas kernel in :mod:`repro.kernels.mlstm_chunk`.

sLSTM has genuinely sequential recurrence (recurrent weights R act on
``h_{t-1}``), so prefill is a ``lax.scan`` over time — the paper's point that
sLSTM trades parallelism for memory mixing. Decode for both is O(1)-state,
which is what qualifies xlstm-125m for the ``long_500k`` shape.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import (
    causal_conv1d,
    causal_conv1d_step,
    dense_init,
    init_causal_conv,
    rms_norm,
)

Array = jax.Array


# =============================================================== mLSTM block
def init_mlstm(key: Array, d_model: int, n_heads: int, proj_factor: int = 2,
               conv_kernel: int = 4) -> dict:
    d_inner = proj_factor * d_model
    dh = d_inner // n_heads
    ks = jax.random.split(key, 8)
    return {
        "up_x": dense_init(ks[0], (d_model, d_inner)),
        "up_z": dense_init(ks[1], (d_model, d_inner)),
        "conv": init_causal_conv(ks[2], d_inner, conv_kernel),
        "wq": dense_init(ks[3], (d_inner, n_heads, dh)),
        "wk": dense_init(ks[4], (d_inner, n_heads, dh)),
        "wv": dense_init(ks[5], (d_inner, n_heads, dh)),
        "w_if": dense_init(ks[6], (d_inner, n_heads, 2)),
        "if_bias": jnp.concatenate(
            [jnp.zeros((n_heads, 1)), 3.0 * jnp.ones((n_heads, 1))], axis=-1
        ),
        "out_norm": jnp.ones((d_inner,), jnp.float32),
        "down": dense_init(ks[7], (d_inner, d_model)),
    }


def mlstm_chunked(
    q: Array, k: Array, v: Array, logi: Array, logf: Array,
    *, chunk: int = 256, state: tuple[Array, Array, Array] | None = None,
    unroll: bool = False,
):
    """Chunkwise-parallel stabilized mLSTM.

    Args:
      q, k, v: (B, L, H, dh); logi, logf: (B, L, H) gate pre-activations in
        log space (logf = logsigmoid(raw_f), logi = raw_i).
      state: optional (C (B,H,dh,dh), n (B,H,dh), m (B,H)) carry-in.

    Returns (h (B, L, H, dh), final state).
    """
    bsz, L, H, dh = q.shape
    Q = min(chunk, L)
    while L % Q:
        Q //= 2
    nc = L // Q
    scale = dh**-0.5
    dtype = q.dtype

    qr = q.reshape(bsz, nc, Q, H, dh) * scale
    kr = k.reshape(bsz, nc, Q, H, dh)
    vr = v.reshape(bsz, nc, Q, H, dh)
    li = logi.reshape(bsz, nc, Q, H).astype(jnp.float32)
    lf = logf.reshape(bsz, nc, Q, H).astype(jnp.float32)
    Fl = jnp.cumsum(lf, axis=2)                                    # (b,nc,Q,H)

    if state is None:
        C0 = jnp.zeros((bsz, H, dh, dh), dtype)
        n0 = jnp.zeros((bsz, H, dh), dtype)
        m0 = jnp.full((bsz, H), -1e30, jnp.float32)
    else:
        C0, n0, m0 = state

    b_term = li - Fl                                               # (b,nc,Q,H)
    cmax_in = jax.lax.cummax(b_term, axis=2)                       # running max

    def chunk_body(carry, inp):
        C_prev, n_prev, m_prev = carry
        qc, kc, vc, lic, Flc, cmaxc = inp                           # per chunk
        # cmax_t = max(m_prev - 0, cummax_s<=t (li_s - Fl_s)); note m carries
        # the previous chunk's total decay already folded in.
        cmax = jnp.maximum(m_prev[:, None, :], cmaxc)              # (b,Q,H)
        m_t = Flc + cmax
        inter = jnp.exp(m_prev[:, None, :] - cmax).astype(dtype)   # (b,Q,H)
        # intra-chunk weights w[t, s] = exp(Fl_t - Fl_s + li_s - m_t)
        seg = (Flc[:, :, None, :] - Flc[:, None, :, :]
               + lic[:, None, :, :] - m_t[:, :, None, :])          # (b,t,s,H)
        mask = (jnp.arange(qc.shape[1])[:, None]
                >= jnp.arange(qc.shape[1])[None, :])[None, :, :, None]
        w = jnp.where(mask, jnp.exp(seg), 0.0).astype(dtype)
        qk = jnp.einsum("bthd,bshd->btsh", qc, kc)                 # (b,t,s,H)
        num = (jnp.einsum("btsh,bshd->bthd", w * qk, vc)
               + inter[..., None] * jnp.einsum("bthe,bhde->bthd", qc, C_prev))
        den = (jnp.einsum("btsh->bth", w * qk)
               + inter * jnp.einsum("bthd,bhd->bth", qc, n_prev))
        denom = jnp.maximum(jnp.abs(den), jnp.exp(-m_t).astype(dtype))
        h = num / denom[..., None]
        # ---- carry to next chunk ----
        F_tot = Flc[:, -1]                                         # (b,H)
        m_new = m_t[:, -1]
        # exp(Flc_Q + m_prev - m_new) = exp(m_prev - cmax_Q)
        carry_decay = jnp.exp(m_prev + F_tot - m_new).astype(dtype)
        upd_w = jnp.exp(lic + F_tot[:, None] - Flc - m_new[:, None]).astype(dtype)
        C_new = (carry_decay[:, :, None, None] * C_prev
                 + jnp.einsum("bsh,bshd,bshe->bhde", upd_w, vc, kc))
        n_new = (carry_decay[:, :, None] * n_prev
                 + jnp.einsum("bsh,bshd->bhd", upd_w, kc))
        return (C_new, n_new, m_new), h

    inputs = tuple(
        jnp.moveaxis(t, 1, 0) for t in (qr, kr, vr, li, Fl, cmax_in)
    )
    (C, n, m), hs = jax.lax.scan(chunk_body, (C0, n0, m0), inputs,
                                 unroll=unroll)
    h = jnp.moveaxis(hs, 0, 1).reshape(bsz, L, H, dh)
    return h, (C, n, m)


def mlstm_block(params: dict, x: Array, *, n_heads: int, chunk: int = 256,
                return_cache: bool = False, use_kernel: bool = False,
                unroll: bool = False):
    """Full mLSTM residual block body. x: (B, L, D)."""
    bsz, L, _ = x.shape
    dtype = x.dtype
    xu = x @ params["up_x"].astype(dtype)
    z = x @ params["up_z"].astype(dtype)
    xc = jax.nn.silu(causal_conv1d(params["conv"], xu))
    q = jnp.einsum("bld,dhk->blhk", xc, params["wq"].astype(dtype))
    k = jnp.einsum("bld,dhk->blhk", xc, params["wk"].astype(dtype))
    v = jnp.einsum("bld,dhk->blhk", xu, params["wv"].astype(dtype))
    gates = (jnp.einsum("bld,dhg->blhg", xc.astype(jnp.float32), params["w_if"])
             + params["if_bias"])
    logi = gates[..., 0]
    logf = jax.nn.log_sigmoid(gates[..., 1])
    if use_kernel:
        from repro.kernels.mlstm_chunk.ops import mlstm_scan

        h, (C, n, m) = mlstm_scan(q, k, v, logi, logf, chunk=chunk)
    else:
        h, (C, n, m) = mlstm_chunked(q, k, v, logi, logf, chunk=chunk,
                                     unroll=unroll)
    h = h.reshape(bsz, L, -1)
    h = rms_norm(h, params["out_norm"]) * jax.nn.silu(z)
    out = h @ params["down"].astype(dtype)
    if not return_cache:
        return out
    kk = params["conv"]["w"].shape[0]
    pad = jnp.pad(xu, ((0, 0), (kk - 1, 0), (0, 0)))
    cache = {"C": C, "n": n, "m": m, "conv": pad[:, L : L + kk - 1, :]}
    return out, cache


def init_mlstm_cache(bsz: int, d_model: int, n_heads: int, dtype,
                     proj_factor: int = 2, conv_kernel: int = 4) -> dict:
    d_inner = proj_factor * d_model
    dh = d_inner // n_heads
    return {
        "C": jnp.zeros((bsz, n_heads, dh, dh), dtype),
        "n": jnp.zeros((bsz, n_heads, dh), dtype),
        "m": jnp.full((bsz, n_heads), -1e30, jnp.float32),
        "conv": jnp.zeros((bsz, conv_kernel - 1, d_inner), dtype),
    }


def mlstm_decode_step(params: dict, cache: dict, x: Array, *, n_heads: int
                      ) -> tuple[Array, dict]:
    """One-token mLSTM step. x: (B, 1, D)."""
    bsz = x.shape[0]
    dtype = x.dtype
    xt = x[:, 0]
    xu = xt @ params["up_x"].astype(dtype)
    z = xt @ params["up_z"].astype(dtype)
    conv_win, xc = causal_conv1d_step(params["conv"], cache["conv"], xu)
    xc = jax.nn.silu(xc)
    q = jnp.einsum("bd,dhk->bhk", xc, params["wq"].astype(dtype))
    k = jnp.einsum("bd,dhk->bhk", xc, params["wk"].astype(dtype))
    v = jnp.einsum("bd,dhk->bhk", xu, params["wv"].astype(dtype))
    gates = (jnp.einsum("bd,dhg->bhg", xc.astype(jnp.float32), params["w_if"])
             + params["if_bias"])
    logi = gates[..., 0]
    logf = jax.nn.log_sigmoid(gates[..., 1])

    m_new = jnp.maximum(logf + cache["m"], logi)                   # (B, H)
    f_eff = jnp.exp(logf + cache["m"] - m_new).astype(dtype)
    i_eff = jnp.exp(logi - m_new).astype(dtype)
    C = f_eff[..., None, None] * cache["C"] + i_eff[..., None, None] * (
        v[..., :, None] * k[..., None, :]
    )
    n = f_eff[..., None] * cache["n"] + i_eff[..., None] * k
    scale = q.shape[-1] ** -0.5
    num = jnp.einsum("bhde,bhe->bhd", C, q * scale)
    den = jnp.maximum(
        jnp.abs(jnp.einsum("bhd,bhd->bh", n, q * scale)),
        jnp.exp(-m_new).astype(dtype),
    )
    h = (num / den[..., None]).reshape(bsz, -1)
    h = rms_norm(h, params["out_norm"]) * jax.nn.silu(z)
    out = (h @ params["down"].astype(dtype))[:, None]
    return out, {"C": C, "n": n, "m": m_new, "conv": conv_win}


# =============================================================== sLSTM block
def init_slstm(key: Array, d_model: int, n_heads: int, conv_kernel: int = 4,
               ffn_factor: float = 4.0 / 3.0) -> dict:
    dh = d_model // n_heads
    ks = jax.random.split(key, 5)
    d_ff = int(2 * ffn_factor * d_model)
    return {
        "conv": init_causal_conv(ks[0], d_model, conv_kernel),
        "w": dense_init(ks[1], (d_model, n_heads, 4, dh)),          # z i f o
        "r": dense_init(ks[2], (n_heads, dh, 4, dh), in_axis=1),
        "b": jnp.zeros((n_heads, 4, dh), jnp.float32),
        "out_norm": jnp.ones((d_model,), jnp.float32),
        "ffn_up": dense_init(ks[3], (d_model, d_ff)),
        "ffn_down": dense_init(ks[4], (d_ff // 2, d_model)),
    }


def _slstm_cell(params: dict, wx_t: Array, state: dict):
    """One sLSTM time step from precomputed input projection wx_t (B,H,4,dh)."""
    h_prev, c_prev, n_prev, m_prev = state["h"], state["c"], state["n"], state["m"]
    rec = jnp.einsum("bhd,hdge->bhge", h_prev, params["r"].astype(h_prev.dtype))
    pre = wx_t + rec + params["b"].astype(wx_t.dtype)               # (B,H,4,dh)
    z = jnp.tanh(pre[:, :, 0])
    i_raw = pre[:, :, 1].astype(jnp.float32)
    f_raw = pre[:, :, 2].astype(jnp.float32)
    o = jax.nn.sigmoid(pre[:, :, 3])
    logf = jax.nn.log_sigmoid(f_raw)
    m_new = jnp.maximum(logf + m_prev, i_raw)
    i_eff = jnp.exp(i_raw - m_new).astype(z.dtype)
    f_eff = jnp.exp(logf + m_prev - m_new).astype(z.dtype)
    c = f_eff * c_prev + i_eff * z
    n = f_eff * n_prev + i_eff
    h = o * c / jnp.maximum(n, 1e-6)
    return {"h": h, "c": c, "n": n, "m": m_new}


def slstm_block(params: dict, x: Array, *, n_heads: int,
                return_cache: bool = False):
    """Full sLSTM block (sequential over time). x: (B, L, D)."""
    bsz, L, d_model = x.shape
    dtype = x.dtype
    xc = jax.nn.silu(causal_conv1d(params["conv"], x))
    wx = jnp.einsum("bld,dhge->blhge", xc, params["w"].astype(dtype))

    state = init_slstm_state(bsz, d_model, n_heads, dtype)

    def step(state, wx_t):
        new = _slstm_cell(params, wx_t, state)
        return new, new["h"]

    final, hs = jax.lax.scan(step, state, jnp.moveaxis(wx, 1, 0))
    h = jnp.moveaxis(hs, 0, 1).reshape(bsz, L, d_model)
    h = rms_norm(h, params["out_norm"])
    u = h @ params["ffn_up"].astype(dtype)
    a, b = jnp.split(u, 2, axis=-1)
    out = (jax.nn.silu(a) * b) @ params["ffn_down"].astype(dtype)
    if not return_cache:
        return out
    k = params["conv"]["w"].shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    cache = dict(final)
    cache["conv"] = pad[:, L : L + k - 1, :]
    return out, cache


def init_slstm_state(bsz: int, d_model: int, n_heads: int, dtype) -> dict:
    dh = d_model // n_heads
    shape = (bsz, n_heads, dh)
    return {
        "h": jnp.zeros(shape, dtype),
        "c": jnp.zeros(shape, dtype),
        "n": jnp.zeros(shape, dtype),
        "m": jnp.full((bsz, n_heads, dh), -1e30, jnp.float32),
    }


def init_slstm_cache(bsz: int, d_model: int, n_heads: int, dtype,
                     conv_kernel: int = 4) -> dict:
    cache = init_slstm_state(bsz, d_model, n_heads, dtype)
    cache["conv"] = jnp.zeros((bsz, conv_kernel - 1, d_model), dtype)
    return cache


def slstm_decode_step(params: dict, cache: dict, x: Array, *, n_heads: int
                      ) -> tuple[Array, dict]:
    """One-token sLSTM step. x: (B, 1, D)."""
    bsz, _, d_model = x.shape
    dtype = x.dtype
    conv_win, xc = causal_conv1d_step(params["conv"], cache["conv"], x[:, 0])
    xc = jax.nn.silu(xc)
    wx = jnp.einsum("bd,dhge->bhge", xc, params["w"].astype(dtype))
    state = {k: cache[k] for k in ("h", "c", "n", "m")}
    new = _slstm_cell(params, wx, state)
    h = rms_norm(new["h"].reshape(bsz, d_model), params["out_norm"])
    u = h @ params["ffn_up"].astype(dtype)
    a, b = jnp.split(u, 2, axis=-1)
    out = ((jax.nn.silu(a) * b) @ params["ffn_down"].astype(dtype))[:, None]
    new["conv"] = conv_win
    return out, new
