"""Model assembly: init / forward / prefill / decode for all 10 architectures.

A model is a pytree of parameters plus pure functions driven by
:class:`repro.configs.base.ModelConfig`. Heterogeneous stacks are split into
contiguous same-type *runs* (``cfg.layer_runs()``); each run's parameters are
stacked along a leading layer axis and executed with ``jax.lax.scan`` (with
``jax.checkpoint`` per layer in train mode), which keeps HLO size and
activation memory bounded for 88-layer dry-runs.

Execution modes:
- ``train``   — full forward, logits for every position (loss in train/).
- ``prefill`` — forward that additionally emits per-layer caches (KV /
  recurrent states) for decode continuation.
- ``decode``  — ONE token against the cache (see :func:`decode_step`).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import ssm, xlstm
from repro.models.layers import (
    dense_init,
    embed_init,
    embed_tokens,
    init_rms_norm,
    init_swiglu,
    rms_norm,
    swiglu,
    unembed,
)
from repro.models.moe import init_moe, moe_ffn

Array = jax.Array


# ===================================================================== init
def _init_attn_layer(key: Array, cfg: ModelConfig, kind: str,
                     cross: bool = False) -> dict:
    ks = jax.random.split(key, 5)
    hd = cfg.resolved_head_dim
    p = {
        "ln1": init_rms_norm(cfg.d_model),
        "attn": attn.init_attention(ks[0], cfg.d_model, cfg.n_heads,
                                    cfg.n_kv_heads, hd),
        "ln2": init_rms_norm(cfg.d_model),
    }
    if kind == "moe":
        p["moe"] = init_moe(ks[1], cfg.d_model, cfg.n_experts, cfg.moe_d_ff,
                            cfg.n_shared_experts, cfg.moe_d_ff)
    else:
        p["mlp"] = init_swiglu(ks[1], cfg.d_model, cfg.d_ff)
    if cross:
        p["ln_x"] = init_rms_norm(cfg.d_model)
        p["xattn"] = attn.init_attention(ks[2], cfg.d_model, cfg.n_heads,
                                         cfg.n_kv_heads, hd)
    return p


def _init_layer(key: Array, cfg: ModelConfig, kind: str, cross: bool) -> dict:
    if kind in ("attn", "moe"):
        return _init_attn_layer(key, cfg, kind, cross)
    if kind == "mamba":
        return {
            "ln": init_rms_norm(cfg.d_model),
            "mamba": ssm.init_mamba(key, cfg.d_model, cfg.d_inner, cfg.n_heads,
                                    cfg.ssm_state, cfg.conv_kernel),
        }
    if kind == "mlstm":
        return {
            "ln": init_rms_norm(cfg.d_model),
            "mlstm": xlstm.init_mlstm(key, cfg.d_model, cfg.n_heads),
        }
    if kind == "slstm":
        return {
            "ln": init_rms_norm(cfg.d_model),
            "slstm": xlstm.init_slstm(key, cfg.d_model, cfg.n_heads),
        }
    raise ValueError(f"unknown layer kind {kind!r}")


def _init_runs(key: Array, cfg: ModelConfig, runs, cross: bool) -> list[dict]:
    out = []
    for r, (kind, count) in enumerate(runs):
        keys = jax.random.split(jax.random.fold_in(key, r), count)
        stacked = jax.vmap(lambda k: _init_layer(k, cfg, kind, cross))(keys)
        out.append(stacked)
    return out


def init_params(cfg: ModelConfig, key: Array) -> dict:
    """Initialize the full parameter pytree for ``cfg``."""
    k_emb, k_dec, k_enc, k_head, k_mod = jax.random.split(key, 5)
    cross = cfg.enc_layers > 0
    params: dict[str, Any] = {
        "embed": embed_init(k_emb, cfg.vocab_size, cfg.d_model),
        "blocks": _init_runs(k_dec, cfg, cfg.layer_runs(), cross),
        "final_norm": init_rms_norm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(k_head, (cfg.d_model, cfg.vocab_size))
    if cfg.enc_layers:
        params["enc_proj"] = dense_init(k_mod, (cfg.d_model, cfg.d_model))
        params["enc_blocks"] = _init_runs(
            k_enc, cfg, (("attn", cfg.enc_layers),), cross=False
        )
        params["enc_norm"] = init_rms_norm(cfg.d_model)
    if cfg.modality == "vision":
        params["vision_proj"] = dense_init(k_mod, (cfg.d_model, cfg.d_model))
    return params


def param_shapes(cfg: ModelConfig) -> dict:
    """ShapeDtypeStruct pytree of the parameters — no allocation (dry-run)."""
    return jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))


# =================================================================== context
@dataclasses.dataclass(frozen=True)
class RunCtx:
    """Static + traced context threaded through block application."""

    cfg: ModelConfig
    positions: Array                 # (B, S) query positions
    window: int                      # sliding window (0 = full attention)
    mode: str                        # train | prefill
    memory_kv_fn: Any = None         # layer params -> (k, v) for cross-attn
    use_kernels: bool = False


def _apply_layer(kind: str, p: dict, x: Array, ctx: RunCtx):
    """One block; returns (x, aux, cache) — cache only populated at prefill."""
    cfg = ctx.cfg
    want_cache = ctx.mode == "prefill"
    aux = jnp.zeros((), jnp.float32)
    cache: dict[str, Array] = {}

    if kind in ("attn", "moe"):
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        res = attn.attention_block(
            p["attn"], h, ctx.positions,
            n_heads=cfg.n_heads, rope_theta=cfg.rope_theta,
            chunk=cfg.attn_chunk, causal=True, window=ctx.window,
            use_kernel=ctx.use_kernels, return_kv=want_cache,
            unroll=cfg.unroll_loops,
        )
        if want_cache:
            res, (k, v) = res
            cache["k"], cache["v"] = k, v
        x = x + res
        if "xattn" in p:
            h = rms_norm(x, p["ln_x"], cfg.norm_eps)
            mk, mv = ctx.memory_kv_fn(p["xattn"])
            res = attn.attention_block(
                p["xattn"], h, ctx.positions,
                n_heads=cfg.n_heads, rope_theta=cfg.rope_theta,
                chunk=cfg.attn_chunk, causal=False, window=0,
                kv_override=(mk, mv), unroll=cfg.unroll_loops,
            )
            if want_cache:
                cache["xk"], cache["xv"] = mk, mv
            x = x + res
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        if kind == "moe":
            out, aux = moe_ffn(p["moe"], h, top_k=cfg.top_k,
                               capacity_factor=cfg.capacity_factor,
                               group_size=cfg.moe_group_size)
        else:
            out = swiglu(p["mlp"], h)
        x = x + out
    elif kind == "mamba":
        h = rms_norm(x, p["ln"], cfg.norm_eps)
        res = ssm.mamba_block(
            p["mamba"], h, d_inner=cfg.d_inner, n_heads=cfg.n_heads,
            ssm_state=cfg.ssm_state, chunk=cfg.ssm_chunk,
            return_cache=want_cache, use_kernel=ctx.use_kernels,
            unroll=cfg.unroll_loops,
        )
        if want_cache:
            res, cache = res
        x = x + res
    elif kind == "mlstm":
        h = rms_norm(x, p["ln"], cfg.norm_eps)
        res = xlstm.mlstm_block(p["mlstm"], h, n_heads=cfg.n_heads,
                                chunk=cfg.ssm_chunk,
                                return_cache=want_cache,
                                use_kernel=ctx.use_kernels,
                                unroll=cfg.unroll_loops)
        if want_cache:
            res, cache = res
        x = x + res
    elif kind == "slstm":
        h = rms_norm(x, p["ln"], cfg.norm_eps)
        res = xlstm.slstm_block(p["slstm"], h, n_heads=cfg.n_heads,
                                return_cache=want_cache)
        if want_cache:
            res, cache = res
        x = x + res
    else:
        raise ValueError(kind)
    return x, aux, cache


def _apply_runs(blocks: list[dict], runs, x: Array, ctx: RunCtx):
    """Scan each stacked run; returns (x, total_aux, caches per run)."""
    total_aux = jnp.zeros((), jnp.float32)
    caches = []

    for (kind, _count), stacked in zip(runs, blocks):

        def body(carry, layer_params, kind=kind):
            h, aux_sum = carry
            h, aux, cache = _apply_layer(kind, layer_params, h, ctx)
            return (h, aux_sum + aux), cache

        if ctx.mode == "train":
            body = jax.checkpoint(body)
        (x, total_aux), run_cache = jax.lax.scan(
            body, (x, total_aux), stacked, unroll=ctx.cfg.unroll_loops)
        caches.append(run_cache)
    return x, total_aux, caches


# ==================================================================== forward
def _encode(params: dict, cfg: ModelConfig, frames: Array, ctx_kernels: bool):
    """Bidirectional encoder over stub frame embeddings. frames: (B,S,D)."""
    x = (frames @ params["enc_proj"].astype(frames.dtype))
    positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
    ctx = RunCtx(cfg=cfg, positions=positions, window=0, mode="train",
                 use_kernels=ctx_kernels)

    for (kind, _), stacked in zip((("attn", cfg.enc_layers),),
                                  params["enc_blocks"]):

        def body(carry, layer_params):
            h = rms_norm(carry, layer_params["ln1"], cfg.norm_eps)
            res = attn.attention_block(
                layer_params["attn"], h, ctx.positions,
                n_heads=cfg.n_heads, rope_theta=cfg.rope_theta,
                chunk=cfg.attn_chunk, causal=False, window=0,
                unroll=cfg.unroll_loops,
            )
            h2 = carry + res
            out = swiglu(layer_params["mlp"], rms_norm(h2, layer_params["ln2"],
                                                       cfg.norm_eps))
            return h2 + out, None

        x, _ = jax.lax.scan(body, x, stacked, unroll=cfg.unroll_loops)
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def forward(
    params: dict,
    cfg: ModelConfig,
    batch: dict,
    *,
    mode: str = "train",
    window: int = 0,
    use_kernels: bool = False,
) -> dict:
    """Forward pass (train or prefill).

    batch keys: ``tokens`` (B, S_text) int32; ``patch_embeds`` (B, P, D) for
    vision archs; ``enc_frames`` (B, S_enc, D) for the audio enc-dec.

    Returns dict with ``logits`` (B, S_total, V) fp32, ``aux`` (MoE load
    balance loss), ``caches`` (prefill only) and ``memory`` (audio only).
    """
    dtype = jnp.dtype(cfg.dtype)
    tokens = batch["tokens"]
    x = embed_tokens(params["embed"], tokens, dtype)

    memory = None
    memory_kv_fn = None
    if cfg.enc_layers:
        memory = _encode(params, cfg, batch["enc_frames"].astype(dtype),
                         use_kernels)

        def memory_kv_fn(xattn_params, memory=memory):
            k = jnp.einsum("bsd,dhk->bshk", memory,
                           xattn_params["wk"].astype(memory.dtype))
            v = jnp.einsum("bsd,dhk->bshk", memory,
                           xattn_params["wv"].astype(memory.dtype))
            return k, v

    if cfg.modality == "vision" and "patch_embeds" in batch:
        patches = batch["patch_embeds"].astype(dtype)
        patches = patches @ params["vision_proj"].astype(dtype)
        x = jnp.concatenate([patches, x], axis=1)   # early fusion

    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    ctx = RunCtx(cfg=cfg, positions=positions, window=window, mode=mode,
                 memory_kv_fn=memory_kv_fn, use_kernels=use_kernels)
    x, aux, caches = _apply_runs(params["blocks"], cfg.layer_runs(), x, ctx)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = unembed(x, head)
    out = {"logits": logits, "aux": aux}
    if mode == "prefill":
        out["caches"] = caches
        out["length"] = jnp.asarray(s, jnp.int32)
    if memory is not None:
        out["memory"] = memory
    return out


# ================================================================== decoding
def init_cache(cfg: ModelConfig, batch_size: int, capacity: int, *,
               window: int = 0, enc_len: int = 0, dtype=None) -> dict:
    """Empty decode cache sized for ``capacity`` context tokens.

    Windowed attention layers get ring buffers of ``min(window, capacity)``.
    SSM/xLSTM layers get O(1) state slots. The audio enc-dec also carries the
    per-layer cross-attention K/V over an ``enc_len``-frame memory.
    """
    dtype = dtype or jnp.dtype(cfg.dtype)
    hd = cfg.resolved_head_dim
    kv = cfg.n_kv_heads
    attn_cap = min(window, capacity) if window else capacity

    def one(kind):
        if kind in ("attn", "moe"):
            c = {
                "k": jnp.zeros((batch_size, attn_cap, kv, hd), dtype),
                "v": jnp.zeros((batch_size, attn_cap, kv, hd), dtype),
            }
            if cfg.enc_layers:
                c["xk"] = jnp.zeros((batch_size, enc_len, kv, hd), dtype)
                c["xv"] = jnp.zeros((batch_size, enc_len, kv, hd), dtype)
            return c
        if kind == "mamba":
            return ssm.init_mamba_cache(batch_size, cfg.d_inner, cfg.n_heads,
                                        cfg.ssm_state, cfg.conv_kernel, dtype)
        if kind == "mlstm":
            return xlstm.init_mlstm_cache(batch_size, cfg.d_model, cfg.n_heads,
                                          dtype)
        if kind == "slstm":
            return xlstm.init_slstm_cache(batch_size, cfg.d_model, cfg.n_heads,
                                          dtype)
        raise ValueError(kind)

    runs = []
    for kind, count in cfg.layer_runs():
        sliced = one(kind)
        runs.append(jax.tree.map(
            lambda a: jnp.broadcast_to(a, (count, *a.shape)), sliced))
    return {"runs": runs, "length": jnp.zeros((), jnp.int32)}


def _kv_into_cache(kv: Array, capacity: int, ring: bool) -> Array:
    """Place prefill K or V (B, S, KV, hd) into a capacity-C cache buffer."""
    b, s, n_kv, hd = kv.shape
    if not ring:
        if s > capacity:
            raise ValueError(f"prefill length {s} exceeds cache capacity {capacity}")
        return jnp.pad(kv, ((0, 0), (0, capacity - s), (0, 0), (0, 0)))
    take = min(s, capacity)
    last = kv[:, s - take:]
    slots = jnp.arange(s - take, s) % capacity
    buf = jnp.zeros((b, capacity, n_kv, hd), kv.dtype)
    return buf.at[:, slots].set(last)


def prefill(params: dict, cfg: ModelConfig, batch: dict, *, capacity: int,
            window: int = 0, use_kernels: bool = False) -> tuple[Array, dict]:
    """Run the prompt and build the decode cache.

    Returns (last-token logits (B, V), cache).
    """
    out = forward(params, cfg, batch, mode="prefill", window=window,
                  use_kernels=use_kernels)
    attn_cap = min(window, capacity) if window else capacity
    ring = window > 0

    runs = []
    for (kind, _), cache in zip(cfg.layer_runs(), out["caches"]):
        if kind in ("attn", "moe"):
            fixed = dict(cache)
            fixed["k"] = jax.vmap(
                lambda k: _kv_into_cache(k, attn_cap, ring))(cache["k"])
            fixed["v"] = jax.vmap(
                lambda v: _kv_into_cache(v, attn_cap, ring))(cache["v"])
            runs.append(fixed)
        else:
            runs.append(cache)
    cache = {"runs": runs, "length": out["length"]}
    return out["logits"][:, -1], cache


def decode_step(
    params: dict,
    cfg: ModelConfig,
    cache: dict,
    token: Array,
    *,
    window: int = 0,
) -> tuple[Array, dict]:
    """Generate logits for ONE new token and update the cache.

    Args:
      token: (B, 1) int32 — the token being fed at position ``cache.length``.

    Returns (logits (B, V) fp32, new cache).
    """
    dtype = jnp.dtype(cfg.dtype)
    pos = cache["length"]
    x = embed_tokens(params["embed"], token, dtype)     # (B, 1, D)
    new_runs = []

    for (kind, _), stacked_p, stacked_c in zip(cfg.layer_runs(),
                                               params["blocks"],
                                               cache["runs"]):

        def body(h, inp, kind=kind):
            p, c = inp
            if kind in ("attn", "moe"):
                hn = rms_norm(h, p["ln1"], cfg.norm_eps)
                res, k_new, v_new = attn.decode_attention(
                    p["attn"], hn, c["k"], c["v"], pos, pos,
                    n_heads=cfg.n_heads, rope_theta=cfg.rope_theta,
                    window=window, ring=window > 0,
                )
                h = h + res
                c_out = dict(c, k=k_new, v=v_new)
                if "xattn" in p:
                    hn = rms_norm(h, p["ln_x"], cfg.norm_eps)
                    res = attn.attention_block(
                        p["xattn"], hn, jnp.zeros_like(token),
                        n_heads=cfg.n_heads, rope_theta=cfg.rope_theta,
                        chunk=cfg.attn_chunk, causal=False, window=0,
                        kv_override=(c["xk"], c["xv"]),
                    )
                    h = h + res
                hn = rms_norm(h, p["ln2"], cfg.norm_eps)
                if kind == "moe":
                    res, _ = moe_ffn(p["moe"], hn, top_k=cfg.top_k,
                                     capacity_factor=cfg.capacity_factor,
                                     group_size=cfg.moe_group_size)
                else:
                    res = swiglu(p["mlp"], hn)
                return h + res, c_out
            if kind == "mamba":
                hn = rms_norm(h, p["ln"], cfg.norm_eps)
                res, c_out = ssm.mamba_decode_step(
                    p["mamba"], c, hn, d_inner=cfg.d_inner,
                    n_heads=cfg.n_heads, ssm_state=cfg.ssm_state)
                return h + res, c_out
            if kind == "mlstm":
                hn = rms_norm(h, p["ln"], cfg.norm_eps)
                res, c_out = xlstm.mlstm_decode_step(p["mlstm"], c, hn,
                                                     n_heads=cfg.n_heads)
                return h + res, c_out
            if kind == "slstm":
                hn = rms_norm(h, p["ln"], cfg.norm_eps)
                res, c_out = xlstm.slstm_decode_step(p["slstm"], c, hn,
                                                     n_heads=cfg.n_heads)
                return h + res, c_out
            raise ValueError(kind)

        x, new_c = jax.lax.scan(body, x, (stacked_p, stacked_c),
                                unroll=cfg.unroll_loops)
        new_runs.append(new_c)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = unembed(x[:, 0], head)
    return logits, {"runs": new_runs, "length": pos + 1}
