"""Architecture zoo: shared layers + per-family blocks + assembler."""

from repro.models.model import (
    decode_step,
    forward,
    init_cache,
    init_params,
    param_shapes,
    prefill,
)

__all__ = [
    "decode_step",
    "forward",
    "init_cache",
    "init_params",
    "param_shapes",
    "prefill",
]
