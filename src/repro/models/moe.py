"""Mixture-of-Experts FFN with GShard-style capacity dispatch.

Tokens are grouped by their leading (batch) dimension; each group dispatches
independently to ``E`` experts with per-group capacity
``C = ceil(S * top_k * capacity_factor / E)``. Dispatch/combine are dense
einsums — the canonical TPU formulation: with tokens sharded over
(``pod``, ``data``) and experts sharded over ``model``, XLA lowers the
dispatch einsums to all-to-alls over the expert axis (visible in the dry-run
HLO and counted by the roofline's collective term).

The router adds the standard load-balance auxiliary loss (Switch/GShard),
returned alongside the output so the train step can weight it.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init

Array = jax.Array


def init_moe(key: Array, d_model: int, n_experts: int, d_ff: int,
             n_shared: int = 0, shared_d_ff: int = 0) -> dict:
    kr, kg, ku, kd, ks = jax.random.split(key, 5)
    params = {
        "router": dense_init(kr, (d_model, n_experts)),
        "gate": dense_init(kg, (n_experts, d_model, d_ff), in_axis=1),
        "up": dense_init(ku, (n_experts, d_model, d_ff), in_axis=1),
        "down": dense_init(kd, (n_experts, d_ff, d_model), in_axis=1),
    }
    if n_shared:
        from repro.models.layers import init_swiglu

        params["shared"] = init_swiglu(ks, d_model, n_shared * (shared_d_ff or d_ff))
    return params


def _top_k_dispatch(probs: Array, top_k: int, capacity: int):
    """Build dispatch/combine tensors from router probabilities.

    Args:
      probs: (G, S, E) router softmax.
    Returns:
      dispatch: (G, S, E, C) one-hot bool-ish float;
      combine:  (G, S, E, C) combine weights;
      aux: load-balance loss scalar.
    """
    g, s, e = probs.shape
    remaining = probs
    location = jnp.zeros((g, e), jnp.int32)     # next free slot per expert
    dispatch = jnp.zeros((g, s, e, capacity), probs.dtype)
    combine = jnp.zeros((g, s, e, capacity), probs.dtype)
    total_weight = jnp.zeros((g, s), probs.dtype)

    for _ in range(top_k):
        choice = jnp.argmax(remaining, axis=-1)                    # (G, S)
        onehot = jax.nn.one_hot(choice, e, dtype=probs.dtype)      # (G, S, E)
        gate = jnp.sum(remaining * onehot, axis=-1)                # (G, S)
        remaining = remaining * (1.0 - onehot)
        # slot index for each token within its chosen expert (FIFO by position)
        pos = jnp.cumsum(onehot, axis=1) - onehot + location[:, None, :]
        slot = jnp.sum(pos * onehot, axis=-1).astype(jnp.int32)    # (G, S)
        keep = (slot < capacity).astype(probs.dtype)               # capacity drop
        slot_oh = jax.nn.one_hot(slot, capacity, dtype=probs.dtype)
        d = onehot[..., None] * slot_oh[:, :, None, :] * keep[..., None, None]
        dispatch = dispatch + d
        combine = combine + gate[..., None, None] * d
        total_weight = total_weight + gate * keep
        location = location + jnp.sum(onehot, axis=1).astype(jnp.int32)

    # renormalize combine weights over the kept top-k choices
    combine = combine / jnp.maximum(total_weight, 1e-9)[..., None, None]
    # Switch-style load-balance loss: E * sum_e fraction_e * prob_e
    frac = jnp.mean(jnp.sum(dispatch, axis=-1), axis=1)            # (G, E)
    mean_prob = jnp.mean(probs, axis=1)                            # (G, E)
    aux = e * jnp.mean(jnp.sum(frac * mean_prob, axis=-1))
    return dispatch, combine, aux


def moe_ffn(
    params: dict,
    x: Array,
    *,
    top_k: int,
    capacity_factor: float = 1.25,
    group_size: int = 512,
    router_in_fp32: bool = True,
) -> tuple[Array, Array]:
    """Apply the MoE FFN. x: (B, S, D) -> (out (B, S, D), aux-loss scalar).

    Tokens are re-grouped to ``(T/group_size, group_size, D)`` before
    dispatch so the dispatch/combine tensors stay ``O(T * group_size * k)``
    instead of ``O(T * S * k)`` — the standard GShard grouping. ``group_size``
    trades dispatch-einsum FLOPs (linear in it) against capacity-drop
    variance; it is a tuning knob for the Perf loop.
    """
    b, s, d = x.shape
    e = params["router"].shape[1]
    dtype = x.dtype
    tokens = b * s
    gs = min(group_size, tokens)
    while tokens % gs:
        gs //= 2
    x_in = x
    x = x.reshape(tokens // gs, gs, d)
    g, s_, _ = x.shape

    router_x = x.astype(jnp.float32) if router_in_fp32 else x
    logits = router_x @ params["router"].astype(router_x.dtype)   # (G, S, E)
    probs = jax.nn.softmax(logits, axis=-1).astype(dtype)

    capacity = max(1, math.ceil(s_ * top_k * capacity_factor / e))
    dispatch, combine, aux = _top_k_dispatch(probs, top_k, capacity)

    # dispatch tokens to experts: (E, G, C, D)
    expert_in = jnp.einsum("gsec,gsd->egcd", dispatch, x)
    gate = jnp.einsum("egcd,edf->egcf", expert_in, params["gate"].astype(dtype))
    up = jnp.einsum("egcd,edf->egcf", expert_in, params["up"].astype(dtype))
    act = jax.nn.silu(gate) * up
    expert_out = jnp.einsum("egcf,efd->egcd", act, params["down"].astype(dtype))
    out = jnp.einsum("gsec,egcd->gsd", combine, expert_out)
    out = out.reshape(b, s, d)

    if "shared" in params:
        from repro.models.layers import swiglu

        out = out + swiglu(params["shared"], x_in)
    return out, aux.astype(jnp.float32)
