"""Shared neural-network primitives (pure-functional, pytree params).

Conventions:
- Parameters are nested dicts of ``float32`` arrays; compute is cast to the
  config dtype (bf16 on the TPU target) at block entry.
- Linear weights are stored ``(in, out)`` (or head-factored) with no biases
  (llama-style) unless a block explicitly needs them.
- All functions are shape-polymorphic in batch/sequence and jit/vmap/scan
  safe.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


# ------------------------------------------------------------------ initialers
def dense_init(key: Array, shape: tuple[int, ...], in_axis: int = 0) -> Array:
    """Truncated-normal fan-in init (std = 1/sqrt(fan_in))."""
    fan_in = shape[in_axis]
    std = fan_in**-0.5
    return std * jax.random.truncated_normal(key, -3.0, 3.0, shape, jnp.float32)


def embed_init(key: Array, vocab: int, dim: int) -> Array:
    return jax.random.truncated_normal(key, -3.0, 3.0, (vocab, dim), jnp.float32)


# ------------------------------------------------------------------------ norm
def rms_norm(x: Array, scale: Array, eps: float = 1e-5) -> Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * scale).astype(dtype)


def init_rms_norm(dim: int) -> Array:
    return jnp.ones((dim,), jnp.float32)


# ------------------------------------------------------------------------ RoPE
def rope_frequencies(head_dim: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """Rotary position embedding.

    Args:
      x: (..., seq, heads, head_dim)
      positions: (..., seq) integer positions.
    """
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)                      # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs      # (..., s, hd/2)
    cos = jnp.cos(angles)[..., None, :]                            # (..., s, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------------------- FFN
def init_swiglu(key: Array, d_model: int, d_ff: int) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": dense_init(k1, (d_model, d_ff)),
        "up": dense_init(k2, (d_model, d_ff)),
        "down": dense_init(k3, (d_ff, d_model)),
    }


def swiglu(params: dict, x: Array) -> Array:
    dtype = x.dtype
    g = x @ params["gate"].astype(dtype)
    u = x @ params["up"].astype(dtype)
    return (jax.nn.silu(g) * u) @ params["down"].astype(dtype)


# ------------------------------------------------------------------- embedding
def embed_tokens(embedding: Array, tokens: Array, dtype) -> Array:
    return embedding.astype(dtype)[tokens]


def unembed(x: Array, head: Array) -> Array:
    """Project to vocab logits in float32 for a numerically-stable loss."""
    return (x @ head.astype(x.dtype)).astype(jnp.float32)


# ---------------------------------------------------------------- depthwise conv
def init_causal_conv(key: Array, channels: int, kernel: int) -> dict:
    return {
        "w": dense_init(key, (kernel, channels), in_axis=0),
        "b": jnp.zeros((channels,), jnp.float32),
    }


def causal_conv1d(params: dict, x: Array) -> Array:
    """Depthwise causal conv over time. x: (batch, seq, channels)."""
    k = params["w"].shape[0]
    w = params["w"].astype(x.dtype)
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + x.shape[1], :] * w[i] for i in range(k))
    return out + params["b"].astype(x.dtype)


def causal_conv1d_step(params: dict, window: Array, x_t: Array) -> tuple[Array, Array]:
    """Single decode step. window: (batch, kernel-1, C) past inputs; x_t: (batch, C).

    Returns (new_window, y_t).
    """
    w = params["w"].astype(x_t.dtype)
    full = jnp.concatenate([window, x_t[:, None, :]], axis=1)      # (b, k, C)
    y = jnp.einsum("bkc,kc->bc", full, w) + params["b"].astype(x_t.dtype)
    return full[:, 1:, :], y
