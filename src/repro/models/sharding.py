"""Partition-spec policy: parameter/activation sharding over the production mesh.

Mesh axes: ``data`` (+ optional ``pod``) carry batch parallelism; ``model``
carries tensor/expert parallelism. Rules (DESIGN.md Section 5):

- attention Q/KV/O shard the *head* axis over ``model`` when head count is
  divisible by the axis size, else replicate (e.g. smollm's 15 heads,
  granite's single KV head);
- FFN up/gate shard d_ff (column), down shards d_ff (row);
- embeddings / lm_head shard the vocab axis;
- MoE experts shard the *expert* axis (expert parallelism — dispatch einsums
  become all-to-alls over ``model``);
- Mamba2/xLSTM inner projections shard the inner dim when divisible;
- norms, gates, scalar per-head params replicate.

Stacked layer runs carry a leading layer axis; rules match on *trailing*
dimensions, so every rule below is written for the unstacked shape and
``None`` is prepended for the stack axis automatically.
"""

from __future__ import annotations

from typing import Sequence

import jax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig


def _prepend(spec: P, extra: int) -> P:
    return P(*([None] * extra), *spec)


def _div(n: int, size: int) -> bool:
    return size > 1 and n % size == 0


def _leaf_spec(path: tuple[str, ...], shape: tuple[int, ...], cfg: ModelConfig,
               model_size: int, data_axes: tuple[str, ...]) -> P:
    """Base spec for the *unstacked* trailing dims of one parameter leaf."""
    name = path[-1]
    joined = "/".join(path)
    m = "model"

    def maybe(dim_size: int) -> str | None:
        return m if _div(dim_size, model_size) else None

    # ---- embeddings & head ----
    if name == "embed":
        return P(maybe(shape[-2]), None)                      # (V, D)
    if name == "lm_head":
        return P(None, maybe(shape[-1]))                      # (D, V)
    if name in ("enc_proj", "vision_proj"):
        return P(None, maybe(shape[-1]))

    # ---- attention ----
    if "attn" in path or "xattn" in path:
        if name in ("wq", "wk", "wv"):                        # (D, H, hd)
            return P(None, maybe(shape[-2]), None)
        if name == "wo":                                      # (H, hd, D)
            return P(maybe(shape[-3]), None, None)

    # ---- MoE ----
    if "moe" in path:
        if name == "router":                                  # (D, E)
            return P(None, maybe(shape[-1]))
        if name in ("gate", "up", "down") and len(shape) >= 3:  # (E, D, F)
            return P(maybe(shape[-3]), None, None)

    # ---- dense FFN (mlp / shared expert / slstm ffn) ----
    if name in ("gate", "up", "ffn_up"):                      # (D, F)
        return P(None, maybe(shape[-1]))
    if name in ("down", "ffn_down"):                          # (F, D)
        return P(maybe(shape[-2]), None)

    # ---- Mamba2 ----
    if "mamba" in path:
        if name == "in_proj":                                 # (D, proj_out)
            return P(None, maybe(shape[-1]))
        if name == "out_proj":                                # (d_inner, D)
            return P(maybe(shape[-2]), None)

    # ---- mLSTM ----
    if "mlstm" in path:
        if name in ("up_x", "up_z"):                          # (D, d_inner)
            return P(None, maybe(shape[-1]))
        if name in ("wq", "wk", "wv"):                        # (d_inner, H, dh)
            # one mesh axis only: prefer head sharding, else inner-dim
            if _div(shape[-2], model_size):
                return P(None, m, None)
            return P(maybe(shape[-3]), None, None)

    # everything else (norms, biases, convs, gates, sLSTM recurrent) replicates
    return P()


def param_partition_specs(shapes: dict, cfg: ModelConfig, *,
                          model_size: int,
                          data_axes: tuple[str, ...] = ("data",)) -> dict:
    """PartitionSpec pytree matching a ``param_shapes(cfg)`` pytree."""

    def build(path, leaf):
        keys = tuple(
            p.key if hasattr(p, "key") else str(p.idx) for p in path
        )
        spec = _leaf_spec(keys, leaf.shape, cfg, model_size, data_axes)
        extra = len(leaf.shape) - len(spec)
        if extra > 0:
            spec = _prepend(spec, extra)
        return spec

    return jax.tree_util.tree_map_with_path(build, shapes)


def batch_specs(cfg: ModelConfig, mode: str, *, data_axes: tuple[str, ...],
                shard_cache_seq: bool = False) -> dict:
    """PartitionSpecs for step inputs.

    Training/prefill shard the batch over the data axes. Decode with batch=1
    (long_500k) instead shards the KV-cache *sequence* dimension over
    ``data`` (``shard_cache_seq=True``) — context parallelism for cache
    reads.
    """
    d = data_axes if len(data_axes) > 1 else data_axes[0]
    spec = {"tokens": P(d, None)}
    if cfg.modality == "vision":
        spec["patch_embeds"] = P(d, None, None)
    if cfg.enc_layers:
        spec["enc_frames"] = P(d, None, None)
    return spec


def cache_partition_specs(cache_shapes: dict, *, data_axes: tuple[str, ...],
                          shard_seq: bool = False) -> dict:
    """Specs for the decode cache.

    Default: batch over data axes, KV heads over ``model`` when divisible.
    ``shard_seq``: shard the cache *sequence/capacity* axis over ``data``
    (batch=1 long-context decode).
    """
    d = data_axes if len(data_axes) > 1 else data_axes[0]

    def build(path, leaf):
        keys = tuple(p.key if hasattr(p, "key") else str(p.idx) for p in path)
        name = keys[-1]
        nd = len(leaf.shape)
        if name in ("k", "v", "xk", "xv"):      # (runs, B, C, KV, hd)
            if shard_seq:
                return P(None, None, d, None, None)
            return P(None, d, None, None, None)
        if name == "length":
            return P()
        # recurrent states: (runs, B, ...) -> batch over data unless batch=1
        if nd >= 2:
            if shard_seq:
                return P(*([None] * nd))
            return P(None, d, *([None] * (nd - 2)))
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(build, cache_shapes)
