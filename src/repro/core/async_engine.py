"""Bounded-staleness asynchronous PEARL: rounds without the lockstep barrier.

PEARL-SGD's analysis (and the lockstep :class:`~repro.core.engine.PearlEngine`
scan) assumes every player arrives at the synchronization barrier together —
exactly the assumption heterogeneous real-world clients break. This module
drops the barrier while keeping everything a single compiled program:

- each player still submits its block on time (the server's copy of a
  player's own block is always that player's latest iterate), but the
  *broadcast it optimizes against* may be stale: at round ``r`` player ``i``
  reads the joint snapshot from round ``r - delay[r, i]``, with the
  per-player integer staleness drawn from a pluggable :class:`DelaySchedule`
  and clipped to the staleness bound ``D`` (``max_staleness``);
- the scan carries a ring buffer of the last ``D + 1`` joint snapshots
  (``(D + 1, n, d)`` under the star; ``(D + 1, n, n, d)`` stacked per-player
  views under gossip) and the ``(rounds, n)`` staleness table rides the scan
  inputs, so the whole event schedule jits into one ``lax.scan`` — no host
  round-trips, no retracing across delay draws;
- ``D = 0`` collapses the buffer to a single slot and reproduces the
  lockstep ``_engine_scan`` **bit-for-bit**, including the RNG chain
  (``key -> (key, sub); sub -> n player keys; player key -> tau step keys``)
  — tests/test_async_engine.py pins this, anchoring the async subsystem to
  the PR 1/2 numerics.

Staleness composes with the existing communication axes rather than
replacing them: compression applies to the (stale) broadcast a player reads,
participation masks gate whose fresh block lands in the next snapshot, and
server-free topologies delay the *mixing input* each receiver processes.
:class:`StaleSync` packages ``(inner strategy, delay schedule, bound)`` as a
first-class :class:`~repro.core.engine.SyncStrategy` so the delay model
travels with the strategy object; the lockstep engine rejects it loudly
instead of silently ignoring the delays.

Wire accounting is unchanged from the lockstep engine (staleness delays
*arrival*, not transmission), so bytes-to-equilibrium comparisons against
the synchronous engine are apples-to-apples — ``benchmarks/bench_async.py``
sweeps the equilibrium neighborhood and wire cost over the staleness bound.

Staleness indexing conventions (shared with the trainer's host loop — the
fine print behind every counter in this subsystem; see docs/ARCHITECTURE.md
for how the axes compose):

- **Delay table**: entry ``(r, i)`` is how many ROUNDS old the broadcast
  player ``i`` reads at round ``r`` — 0 means the current snapshot
  (lockstep), and entries are clipped to ``[0, max_staleness]`` by
  :func:`draw_delay_table`, THE one place schedule draws become engine
  input.
- **Ring buffer**: slot index == staleness in rounds; ``buf[0]`` is always
  the current snapshot and the buffer holds ``max_staleness + 1`` slots,
  every slot initialized to ``x0`` (before a player has heard anything,
  the freshest available snapshot is the init).
- **Uploads are never late** in this model: the server's copy of a
  player's own block is always that player's latest submission — staleness
  corrupts only the opponents' rows a player reads (sender-side staleness
  is an open ROADMAP item).
- **Diagnostics**: ``AsyncPearlResult.staleness`` (and the trainer's
  ``staleness_log[r]``) record the delays the references consumed DURING
  round ``r`` carried; the trainer's per-player counters additionally
  history-clip (a player cannot read further back than rounds that exist)
  and age by +1 for each round a player sits out.
- **Step-size policies** see the same drawn row: the ``delay_adaptive``
  policy's per-player gammas use exactly the delays this table realized,
  so slowing is applied to the players whose reads are stale, not to an
  average.
"""

from __future__ import annotations

import abc
import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import (
    ExactSync,
    JointView,
    PearlResult,
    PlayerUpdate,
    SgdUpdate,
    SyncStrategy,
    _SummaryRefGame,
    account_round_bytes,
    as_round_gammas,
    build_round_context,
    relative_error_curve,
    relative_error_curve_from_sq,
    summary_wire,
    validate_round_args,
)
from repro.core.game import VectorGame
from repro.core.spec import (
    EngineSpec,
    apply_spec,
    resolve_stale_sync,
    validate_spec,
)
from repro.core.stepsize import (
    RoundContext,
    StepsizePolicy,
    Theorem34Policy,
    resolve_policy,
)
from repro.core.topology import Star, Topology

Array = jax.Array


# =========================================================================
# Delay schedules — per-player integer staleness, drawn host-side
# =========================================================================
class DelaySchedule(abc.ABC):
    """Per-round, per-player broadcast staleness (in rounds).

    Implementations are frozen hashable dataclasses carrying an int seed.
    :meth:`draw` runs host-side and returns the full ``(rounds, n)`` int
    table; the engine clips it to ``[0, max_staleness]`` and feeds it to the
    compiled scan as a traced input, so changing the delay realization never
    retraces. Entry ``(r, i)`` = how many rounds old the snapshot player
    ``i`` reads at round ``r`` (0 = the current one, i.e. lockstep).
    """

    name: str = "delay"

    @abc.abstractmethod
    def draw(self, rounds: int, n: int, max_staleness: int) -> np.ndarray:
        """Return an int array of shape ``(rounds, n)`` in [0, max_staleness]."""


@dataclasses.dataclass(frozen=True)
class ZeroDelay(DelaySchedule):
    """Everyone always reads the freshest snapshot — the lockstep schedule."""

    name: str = "zero"

    def draw(self, rounds, n, max_staleness):
        del max_staleness
        return np.zeros((rounds, n), dtype=np.int32)


@dataclasses.dataclass(frozen=True)
class ConstantDelay(DelaySchedule):
    """Deterministic lag: every player is always ``lag`` rounds behind
    (clipped to the staleness bound). The cleanest knob for studying how the
    equilibrium neighborhood degrades with staleness."""

    lag: int = 1
    name: str = "constant"

    def __post_init__(self):
        if self.lag < 0:
            raise ValueError(f"ConstantDelay.lag must be >= 0, got {self.lag}")

    def draw(self, rounds, n, max_staleness):
        return np.full((rounds, n), min(self.lag, max_staleness),
                       dtype=np.int32)


@dataclasses.dataclass(frozen=True)
class UniformDelay(DelaySchedule):
    """IID uniform staleness in ``{0, ..., max_staleness}`` per (round,
    player) — the standard bounded-delay adversary of asynchronous SGD
    analyses."""

    seed: int = 0
    name: str = "uniform"

    def draw(self, rounds, n, max_staleness):
        rng = np.random.default_rng(self.seed)
        return rng.integers(0, max_staleness + 1, size=(rounds, n),
                            dtype=np.int32)


@dataclasses.dataclass(frozen=True)
class StragglerDelay(DelaySchedule):
    """Straggler-heavy: a fixed ``fraction`` of the players (chosen by
    ``seed``) is always maximally stale, the rest flip between fresh and
    one-round-late — the bimodal pattern of a cluster with a few slow
    clients (cf. client heterogeneity in federated minimax settings)."""

    fraction: float = 0.25
    seed: int = 0
    name: str = "straggler"

    def __post_init__(self):
        if not 0.0 <= self.fraction <= 1.0:
            raise ValueError(
                f"StragglerDelay.fraction must be in [0, 1], "
                f"got {self.fraction}"
            )

    def draw(self, rounds, n, max_staleness):
        rng = np.random.default_rng(self.seed)
        n_slow = int(math.ceil(self.fraction * n))
        slow = rng.permutation(n)[:n_slow]
        delays = rng.integers(0, min(1, max_staleness) + 1,
                              size=(rounds, n)).astype(np.int32)
        delays[:, slow] = max_staleness
        return delays


def draw_delay_table(delays: DelaySchedule, rounds: int, n: int,
                     max_staleness: int, *, start: int = 0) -> np.ndarray:
    """Validated, clipped ``(rounds, n)`` staleness table starting at round
    ``start`` — THE one place a schedule's draw is turned into engine input
    (shared by :class:`AsyncPearlEngine` and the trainer's host loop).

    ``start > 0`` continues the schedule where a previous call left off: the
    full ``start + rounds`` table is drawn and the prefix discarded, so entry
    ``(r, i)`` is always *global* round ``start + r``'s delay regardless of
    how the rounds were batched into calls.
    """
    table = np.asarray(delays.draw(start + rounds, n, max_staleness))
    if table.shape != (start + rounds, n):
        raise ValueError(
            f"{type(delays).__name__}.draw returned shape {table.shape}, "
            f"expected {(start + rounds, n)}"
        )
    return np.clip(table[start:], 0, max_staleness).astype(np.int32)


# =========================================================================
# StaleSync — staleness as a first-class SyncStrategy axis
# =========================================================================
@dataclasses.dataclass(frozen=True)
class StaleSync(SyncStrategy):
    """Wrap any sync strategy with a bounded-staleness delay model.

    Composes staleness with the existing compression / participation axes:
    all wire semantics (``view``/``mask``/``compress``/byte accounting)
    delegate to ``inner``, while the delay schedule and bound travel with
    the strategy object. Only :class:`AsyncPearlEngine` (which owns the
    snapshot ring buffer) can honor the delays, so ``requires_async`` makes
    the lockstep :class:`~repro.core.engine.PearlEngine` reject this wrapper
    instead of silently running it as its inner strategy.
    """

    inner: SyncStrategy = dataclasses.field(default_factory=ExactSync)
    delays: DelaySchedule = dataclasses.field(default_factory=UniformDelay)
    max_staleness: int = 0
    name: str = "stale"
    requires_async = True

    def __post_init__(self):
        if self.max_staleness < 0:
            raise ValueError(
                f"StaleSync.max_staleness must be >= 0, "
                f"got {self.max_staleness}"
            )
        if isinstance(self.inner, StaleSync):
            raise ValueError("StaleSync cannot wrap another StaleSync")

    # wire semantics delegate wholesale to the inner strategy
    @property
    def uses_mask(self):
        return self.inner.uses_mask

    @property
    def bills_full_round(self):
        return self.inner.bills_full_round

    @property
    def has_wire_state(self):
        return self.inner.has_wire_state

    @property
    def wire_overhead_bytes_per_block(self):
        return self.inner.wire_overhead_bytes_per_block

    def init_state(self):
        return self.inner.init_state()

    def pre_round(self, state):
        return self.inner.pre_round(state)

    def init_wire_state(self, x):
        return self.inner.init_wire_state(x)

    def pre_wire(self, x, state):
        return self.inner.pre_wire(x, state)

    def post_wire(self, t, state):
        return self.inner.post_wire(t, state)

    def roundtrip(self, x):
        return self.inner.roundtrip(x)

    def view(self, i, x_sync, ctx):
        return self.inner.view(i, x_sync, ctx)

    def mask(self, n, ctx):
        return self.inner.mask(n, ctx)

    def compress(self, x):
        return self.inner.compress(x)

    def wire_itemsize(self, base_bps):
        return self.inner.wire_itemsize(base_bps)

    def round_bytes(self, participants, n, d, base_bps):
        return self.inner.round_bytes(participants, n, d, base_bps)


# =========================================================================
# The bounded-staleness scan
# =========================================================================
@partial(jax.jit,
         static_argnames=("update", "sync", "topology", "tau", "stochastic",
                          "max_staleness", "gossip_steps", "policy", "ss_ctx",
                          "mesh", "mesh_axis", "overlap", "view",
                          "record_trajectory"))
def _async_engine_scan(game: VectorGame, x0: Array, gammas: Array,
                       delays: Array, key: Array, *, update,
                       sync: SyncStrategy, topology: Topology, tau: int,
                       stochastic: bool, max_staleness: int,
                       gossip_steps: int = 1,
                       policy: StepsizePolicy = Theorem34Policy(),
                       ss_ctx: RoundContext | None = None,
                       mesh=None, mesh_axis: str = "players",
                       overlap: bool = False,
                       view: JointView | None = None,
                       record_trajectory: bool = True,
                       x_star: Array | None = None):
    """One compiled program: rounds-scan with a snapshot ring buffer.

    Mirrors the lockstep ``_engine_scan`` op-for-op — same RNG chain, same
    mask-merge, same residual — with one change: the reference a player
    optimizes against comes from ``buf[delay[r, i]]`` instead of the current
    snapshot. ``buf[0]`` always holds the current state, so an all-zero
    delay table reproduces the lockstep trajectories bit-for-bit (the D = 0
    pin). The buffer initializes to ``x0`` in every slot: before a player
    has heard anything, the freshest available snapshot is the init.

    Three trace-time star cases:

    - **legacy host** (``mesh=None``, stateless sync): the raw-snapshot
      buffer with ``view`` applied at READ time — byte-identical code to
      PR 4, preserving every existing bit-for-bit pin;
    - **wire-buffered** (a ``mesh``, or an error-feedback sync): the buffer
      holds the *post-wire broadcasts* (what receivers actually decoded)
      instead of raw snapshots — device-resident carry state, so the whole
      bounded-staleness round lowers under ``shard_map`` and with error
      feedback the ONE transmit tensor per round has a well-defined
      residual. At ``D = 0`` the buffer carry disappears at trace time and
      the program is the lockstep mesh scan;
    - **overlap** (``overlap=True``): double-buffered wire — the carry holds
      round ``t-1``'s gathered broadcast; round ``t`` issues its gather with
      NO data dependence on this round's local steps, so XLA is free to
      overlap the collective with the tau-step compute. Semantically this IS
      ``ConstantDelay(1)`` (validated by the engine), measured by
      ``benchmarks/bench_wallclock.py``.

    ``policy`` sees the round's DRAWN delay row (``ss_ctx.with_delays``), so
    a delay-adaptive policy slows exactly the players whose reads are stale
    this round. The identity policy (and any policy at ``max_staleness = 0``
    that resolves to it) keeps the compiled program bit-for-bit the
    policy-free one — same trace-time collapse as the buffer read.

    Gossip: ``gossip_steps`` Metropolis sweeps per round. At ``D = 0`` all
    receivers read the same current views, so the sweeps run once globally —
    the lockstep ``mix_views`` code verbatim, bit-for-bit for ANY sweep
    count. At ``D > 0`` each receiver simulates the full-network sweeps
    locally on its delayed snapshot and keeps its own row (a receiver that
    processes late relays processes ALL of that round's relays late).

    Returns ``(x_final, xs, residuals, participants, links)`` with the exact
    shapes/meanings of the lockstep scan, so the byte accounting is shared.
    """
    from repro.core import collective

    n = x0.shape[0]
    depth = max_staleness + 1
    if ss_ctx is None:
        ss_ctx = RoundContext(tau=tau, max_staleness=max_staleness)

    def vmap_players(local_fn, player_keys, delay_row, gamma):
        """vmap ``local_fn(i, pkey, d_i, gamma_i)`` over players; per-player
        gammas enter the vmap only when the policy emits an ``(n,)`` row
        (trace-time branch, keeping the scalar path bit-for-bit legacy)."""
        g_row = policy.round_gammas(gamma, ss_ctx.with_delays(delay_row))
        if jnp.ndim(g_row) == 0:
            return jax.vmap(lambda i, k, d: local_fn(i, k, d, g_row))(
                jnp.arange(n), player_keys, delay_row)
        return jax.vmap(local_fn)(jnp.arange(n), player_keys, delay_row,
                                  g_row)

    def tau_local_steps(i, pkey, x_start, x_ref, gamma, game_=game):
        """``game_`` defaults to the real game (legacy closure binding);
        the mean-field branch passes the ``_SummaryRefGame`` shim."""
        state0 = update.init_state(game_, i, x_start, x_ref)
        keys = jax.random.split(pkey, tau)

        def step(c, k):
            x_i, st = c
            x_i, st = update.step(game_, i, x_i, x_ref, gamma, k, st,
                                  stochastic)
            return (x_i, st), None

        (x_i, _), _ = jax.lax.scan(step, (x_start, state0), keys)
        return x_i

    use_wire = sync.has_wire_state or mesh is not None
    mean_field = view is not None and view.summary_based
    # Stateful selection policies (core/selection.py) replace the
    # pre_round/mask chain with select/observe; the flag is trace-time, so
    # every legacy strategy compiles the identical program. Selection only
    # reaches the legacy star body: mesh x mask is rejected (and overlap
    # requires a mesh), no selection policy carries wire state, and
    # server-free gossip has no scorer (validate_selection).
    selection = getattr(sync, "stateful_selection", False)

    def star_wire(x_sync, ws):
        """(decoded broadcast, next wire state): what every receiver sees
        this round. The ONE place the transmit tensor is formed, shared by
        the wire-buffered and overlap cases."""
        t = sync.pre_wire(x_sync, ws) if sync.has_wire_state else x_sync
        if mesh is None:
            x_wire = sync.roundtrip(t)
        else:
            x_wire = collective.sharded_joint_wire(
                t, mesh=mesh, sync=sync, axis_name=mesh_axis)
        if sync.has_wire_state:
            ws = sync.post_wire(t, ws)
        return x_wire, ws

    if mean_field:
        # Mean-field star under staleness: the ring buffer holds past
        # DECODED summary broadcasts — (depth - 1, moments, d) — instead of
        # joint snapshots, so the stale-read state stays O(moments * d) per
        # slot. Self-correction additionally needs each player's own
        # contribution to the SAME stale population (the leave-one-out
        # subtraction must remove what the stale summary actually
        # contains), so a second buffer carries the per-player power sums
        # at (depth - 1, n, moments, d) — the same order as the exact
        # path's joint ring buffer. D = 0 carries neither buffer and
        # compiles the lockstep mean-field program bit-for-bit.
        moments = view.moments
        shim = _SummaryRefGame(game)

        def round_body(carry, scan_in):
            gamma, ridx, delay_row = scan_in
            if depth == 1:
                x_sync, key, s, ws = carry
            elif view.self_correction:
                buf_pop, buf_pows, x_sync, key, s, ws = carry
            else:
                buf_pop, x_sync, key, s, ws = carry
            key, sub = jax.random.split(key)
            player_keys = jax.random.split(sub, n)
            s, ctx = sync.pre_round(s)
            del ctx   # mask strategies are rejected for mean-field views

            pop = game.population_summary(x_sync, moments)
            pop_wire, ws = summary_wire(sync, pop, ws)
            if depth > 1:
                full_pop = jnp.concatenate([pop_wire[None], buf_pop])
                if view.self_correction:
                    pows_cur = jnp.stack(
                        [x_sync ** (p + 1) for p in range(moments)], axis=1)
                    full_pows = jnp.concatenate([pows_cur[None], buf_pows])

            def local(i, pkey, d_i, g_i):
                own = x_sync[i]
                pop_d = pop_wire if depth == 1 else full_pop[d_i]
                if view.self_correction:
                    own_pows = (jnp.stack(
                        [own ** (p + 1) for p in range(moments)])
                        if depth == 1 else full_pows[d_i, i])
                    summary = (n * pop_d - own_pows) / (n - 1)
                else:
                    summary = pop_d
                return tau_local_steps(i, pkey, own, (own, summary), g_i,
                                       shim)

            x_next = vmap_players(local, player_keys, delay_row, gamma)
            participants = jnp.asarray(n, jnp.int32)
            res = jnp.sqrt(jnp.sum(game.operator_via_summary(x_next) ** 2))
            out = (x_next, res, participants, participants)
            if depth == 1:
                return (x_next, key, s, ws), out
            if view.self_correction:
                return (full_pop[:-1], full_pows[:-1], x_next, key, s,
                        ws), out
            return (full_pop[:-1], x_next, key, s, ws), out

        pop0 = game.population_summary(x0, moments)
        ws0 = sync.init_wire_state(pop0)
        if depth == 1:
            init = (x0, key, sync.init_state(), ws0)
        else:
            # slots hold what a receiver would have DECODED before round 0
            slot0 = (sync.roundtrip(pop0) if sync.has_wire_state
                     else sync.compress(pop0).astype(pop0.dtype))
            buf_pop0 = jnp.broadcast_to(slot0[None],
                                        (depth - 1, *slot0.shape))
            if view.self_correction:
                pows0 = jnp.stack(
                    [x0 ** (p + 1) for p in range(moments)], axis=1)
                buf_pows0 = jnp.broadcast_to(pows0[None],
                                             (depth - 1, *pows0.shape))
                init = (buf_pop0, buf_pows0, x0, key, sync.init_state(),
                        ws0)
            else:
                init = (buf_pop0, x0, key, sync.init_state(), ws0)
    elif topology.is_server and overlap:
        def round_body(carry, scan_in):
            gamma, _, delay_row = scan_in
            g_prev, x_sync, key, s, ws = carry
            key, sub = jax.random.split(key)
            player_keys = jax.random.split(sub, n)
            s, ctx = sync.pre_round(s)
            del ctx   # masks are rejected for the overlap path
            # this round's gather depends only on x_sync (last round's
            # result), never on this round's locals — XLA can ship it while
            # the tau steps below run; the locals read LAST round's wire
            g_cur, ws = star_wire(x_sync, ws)

            def local(i, pkey, d_i, g_i):
                del d_i   # structurally ConstantDelay(1)
                x_ref = g_prev.at[i].set(x_sync[i])
                return tau_local_steps(i, pkey, x_sync[i], x_ref, g_i)

            x_next = vmap_players(local, player_keys, delay_row, gamma)
            participants = jnp.asarray(n, jnp.int32)
            res = jnp.sqrt(jnp.sum(game.operator(x_next) ** 2))
            return (g_cur, x_next, key, s, ws), (x_next, res, participants,
                                                 participants)

        init = (sync.roundtrip(x0), x0, key, sync.init_state(),
                sync.init_wire_state(x0))
    elif topology.is_server and use_wire:
        def round_body(carry, scan_in):
            gamma, _, delay_row = scan_in
            if depth == 1:
                x_sync, key, s, ws = carry
            else:
                buf, x_sync, key, s, ws = carry
            key, sub = jax.random.split(key)
            player_keys = jax.random.split(sub, n)
            s, ctx = sync.pre_round(s)
            x_wire, ws = star_wire(x_sync, ws)
            if depth > 1:
                # full[k] = the broadcast from k rounds ago (k = 0: this
                # round's); the carry keeps the trailing depth-1 slots
                full = jnp.concatenate([x_wire[None], buf])

            def local(i, pkey, d_i, g_i):
                # D = 0 collapses the buffer read at trace time: the program
                # is exactly the lockstep mesh scan (the pin the mesh path
                # is held to — tests/test_async_mesh.py)
                x_stale = x_wire if depth == 1 else full[d_i]
                x_ref = x_stale.at[i].set(x_sync[i])
                return tau_local_steps(i, pkey, x_sync[i], x_ref, g_i)

            x_prop = vmap_players(local, player_keys, delay_row, gamma)
            m = sync.mask(n, ctx)
            if m is None:
                x_next = x_prop
                participants = jnp.asarray(n, jnp.int32)
            else:
                x_next = jnp.where(m[:, None], x_prop, x_sync)
                participants = jnp.sum(m).astype(jnp.int32)
            res = jnp.sqrt(jnp.sum(game.operator(x_next) ** 2))
            out = (x_next, res, participants, participants)
            if depth == 1:
                return (x_next, key, s, ws), out
            return (full[:-1], x_next, key, s, ws), out

        ws0 = sync.init_wire_state(x0)
        if depth == 1:
            init = (x0, key, sync.init_state(), ws0)
        else:
            # slots hold what a receiver would have DECODED before round 0
            buf0 = jnp.broadcast_to(sync.roundtrip(x0)[None],
                                    (depth - 1, *x0.shape))
            init = (buf0, x0, key, sync.init_state(), ws0)
    elif topology.is_server:
        def round_body(carry, scan_in):
            gamma, ridx, delay_row = scan_in
            buf, x_sync, key, s = carry
            key, sub = jax.random.split(key)
            player_keys = jax.random.split(sub, n)
            if selection:
                # the policy sees the round's DRAWN staleness row, so a
                # staleness-aware policy can de-prioritize stale players
                s, m = sync.select(s, n, ridx, delay_row)
                ctx = ()
            else:
                s, ctx = sync.pre_round(s)

            def local(i, pkey, d_i, g_i):
                # the freshest broadcast this player has RECEIVED is d_i
                # rounds old; its own block is always live (the player starts
                # from x_sync[i] and the game contract ignores row i of the
                # reference), so staleness affects only the opponents' rows.
                # D = 0 resolves the buffer read at trace time: the one slot
                # is the current snapshot, and skipping the dynamic gather
                # keeps the compiled program identical to the lockstep scan
                # (the gather alone perturbs XLA fusion at the ULP level).
                x_stale = x_sync if depth == 1 else buf[d_i]
                x_ref = sync.view(i, x_stale, ctx)
                return tau_local_steps(i, pkey, x_sync[i], x_ref, g_i)

            x_prop = vmap_players(local, player_keys, delay_row, gamma)
            if not selection:
                m = sync.mask(n, ctx)
            if m is None:
                x_next = x_prop
                participants = jnp.asarray(n, jnp.int32)
            else:
                x_next = jnp.where(m[:, None], x_prop, x_sync)
                participants = jnp.sum(m).astype(jnp.int32)
            if selection:
                s = sync.observe(s, m, x_prop - x_sync, ridx)
            res = jnp.sqrt(jnp.sum(game.operator(x_next) ** 2))
            buf_next = jnp.concatenate([x_next[None], buf[:-1]])
            return (buf_next, x_next, key, s), (x_next, res, participants,
                                                participants)

        buf0 = jnp.broadcast_to(x0[None], (depth, *x0.shape))
        init = (buf0, x0, key,
                sync.select_state(n) if selection else sync.init_state())
    else:
        # Server-free gossip under staleness: a receiver processes the wire
        # messages from ``delay`` rounds ago — it mixes over the network
        # state as of its read time, except that senders' own decision
        # blocks are anchored fresh (a sender's latest submission is what
        # sits on its outgoing edge buffers; staleness corrupts only the
        # relayed estimates of OTHERS). Multi-sweep rounds follow the same
        # rule: a late receiver runs ALL of the round's gossip_steps sweeps
        # on its delayed network state (billing scales with the sweep count
        # either way — the wire moved the messages on time).
        W_stack = jnp.asarray(topology.mixing_stack(n), dtype=x0.dtype)
        A_stack = jnp.asarray(topology.adjacency_stack(n), dtype=bool)
        T = W_stack.shape[0]
        diag = jnp.arange(n)

        def round_body(carry, scan_in):
            gamma, ridx, delay_row = scan_in
            Vbuf, x_sync, key, s = carry
            key, sub = jax.random.split(key)
            player_keys = jax.random.split(sub, n)
            s, ctx = sync.pre_round(s)
            W = W_stack[ridx % T]
            A = A_stack[ridx % T]

            def local(i, pkey, d_i, g_i):
                V_read = Vbuf[0] if depth == 1 else Vbuf[d_i]
                return tau_local_steps(i, pkey, x_sync[i], V_read[i], g_i)

            x_prop = vmap_players(local, player_keys, delay_row, gamma)
            m = sync.mask(n, ctx)
            if m is None:
                mf = jnp.ones((n,), dtype=W.dtype)
                x_used = x_prop
                participants = jnp.asarray(n, jnp.int32)
            else:
                mf = m.astype(W.dtype)
                x_used = jnp.where(m[:, None], x_prop, x_sync)
                participants = jnp.sum(m).astype(jnp.int32)

            pair = mf[:, None] * mf[None, :]
            link_w = jnp.where(A, W * pair, 0.0)
            self_w = 1.0 - jnp.sum(link_w, axis=1)

            def global_sweeps(V_m):
                """``gossip_steps`` anchored full-network Metropolis sweeps
                — the lockstep ``mix_views`` body, op-for-op."""
                V_m = V_m.at[diag, diag].set(x_used)
                for _ in range(gossip_steps):
                    wire = sync.compress(V_m).astype(V_m.dtype)
                    V_m = (jnp.einsum("ij,jkd->ikd", link_w, wire)
                           + self_w[:, None, None] * V_m)
                    V_m = V_m.at[diag, diag].set(x_used)
                return V_m

            def mix_receiver(i, d_i):
                Vd = Vbuf[d_i]
                if gossip_steps == 1:
                    # single-row form, byte-identical to the PR 4 code path
                    Vd = Vd.at[diag, diag].set(x_used)
                    wire = sync.compress(Vd).astype(Vd.dtype)
                    v_i = (jnp.einsum("j,jkd->kd", link_w[i], wire)
                           + self_w[i] * Vd[i])
                    return v_i.at[i].set(x_used[i])
                # multi-sweep: a receiver that processes late relays
                # processes ALL of this round's sweeps on its delayed
                # network state, then keeps its own refreshed row
                return global_sweeps(Vd)[i]

            if depth == 1:
                # every receiver reads the same current views: run the
                # sweeps once globally — the lockstep mix_views program,
                # bit-for-bit for ANY gossip_steps
                V_next = global_sweeps(Vbuf[0])
            else:
                V_next = jax.vmap(mix_receiver)(jnp.arange(n), delay_row)
            if m is not None:
                # lockstep invariant: a masked-out receiver exchanges
                # nothing and KEEPS its current view (its link row is
                # zeroed, self weight 1) — it must not time-travel back to
                # its stale read slot
                V_cur = Vbuf[0].at[diag, diag].set(x_used)
                V_next = jnp.where(mf[:, None, None] > 0, V_next, V_cur)
            links = gossip_steps * jnp.sum((A & (pair > 0)).astype(jnp.int32))
            res = jnp.sqrt(jnp.sum(game.operator(x_used) ** 2))
            Vbuf_next = jnp.concatenate([V_next[None], Vbuf[:-1]])
            return (Vbuf_next, x_used, key, s), (x_used, res, participants,
                                                  links)

        V0 = jnp.broadcast_to(x0[None], (n, *x0.shape))
        Vbuf0 = jnp.broadcast_to(V0[None], (depth, *V0.shape))
        init = (Vbuf0, x0, key, sync.init_state())

    scan_in = (gammas, jnp.arange(gammas.shape[0]), delays)
    if record_trajectory:
        scan_body = round_body
    else:
        # identical carried computation; the scan EMITS the per-round
        # squared error scalar instead of stacking the (n, d) iterate
        def scan_body(carry, scan_in_r):
            carry, (x_r, res, p, l) = round_body(carry, scan_in_r)
            return carry, (jnp.sum((x_r - x_star) ** 2), res, p, l)
    carry, (ys, residuals, participants, links) = jax.lax.scan(
        scan_body, init, scan_in
    )
    if mean_field:
        # the summary buffers (and at self-correction the power-sum buffer)
        # precede x in the carry only at D > 0
        x_index = 0 if depth == 1 else (2 if view.self_correction else 1)
    else:
        # the wire-buffered star case at D = 0 has no leading buffer
        # component
        x_index = 0 if (topology.is_server and use_wire and not overlap
                        and depth == 1) else 1
    return carry[x_index], ys, residuals, participants, links


# =========================================================================
# Result type with realized-staleness diagnostics
# =========================================================================
@dataclasses.dataclass(frozen=True)
class AsyncPearlResult(PearlResult):
    """:class:`~repro.core.engine.PearlResult` plus the realized staleness
    table (``(rounds, n)`` ints) the run actually executed."""

    staleness: np.ndarray | None = None

    @property
    def mean_staleness(self) -> float:
        return 0.0 if self.staleness is None else float(self.staleness.mean())

    @property
    def max_realized_staleness(self) -> int:
        return 0 if self.staleness is None else int(self.staleness.max())


# =========================================================================
# The engine
# =========================================================================
@dataclasses.dataclass(frozen=True)
class AsyncPearlEngine:
    """Bounded-staleness PEARL loop: ``update`` x ``sync`` x ``topology`` x
    ``delay model``, one compiled scan.

    Drop-in alongside :class:`~repro.core.engine.PearlEngine` with the same
    ``run`` / ``trajectory`` surface. The delay model can be given either
    directly (``delays`` + ``max_staleness``) or packaged in a
    :class:`StaleSync` passed as ``sync`` (whose inner strategy then
    supplies the wire semantics); the two spellings are equivalent, and
    mixing them — a StaleSync *plus* a non-default engine-level delay model
    — is ambiguous and rejected. ``max_staleness = 0`` reproduces the
    lockstep engine bit-for-bit on the star topology.

    Joint baselines read fresh iterates mid-round by definition, so they
    are rejected. Gossip rounds run ``gossip_steps`` Metropolis sweeps (a
    late receiver simulates all of a round's sweeps on its delayed network
    state). A ``mesh`` lowers the star exchange — including the snapshot
    ring buffer, which rides the scan carry device-resident — through
    :mod:`repro.core.collective`; ``overlap=True`` additionally
    double-buffers the wire so the collective ships during the local steps.
    """

    update: PlayerUpdate = SgdUpdate()
    sync: SyncStrategy = ExactSync()
    topology: Topology = Star()
    delays: DelaySchedule = ZeroDelay()
    max_staleness: int = 0
    gossip_steps: int = 1
    policy: StepsizePolicy | str | None = None   # None = Theorem34Policy()
    mesh: object = None     # jax.sharding.Mesh with the player axis, or None
    mesh_axis: str = "players"
    #: double-buffer the star wire: this round's gather ships while the tau
    #: local steps run against LAST round's broadcast. Requires a mesh (the
    #: point is overlapping a real collective) and an explicitly declared
    #: ConstantDelay(1)/max_staleness=1 delay model — overlap IS one round
    #: of staleness, and the engine refuses to hide that.
    overlap: bool = False
    #: reference axis (:class:`~repro.core.engine.JointView`); None keeps
    #: the legacy topology-decided views. A MeanFieldView runs the O(d)
    #: summary path with a summary ring buffer (dense summaries only —
    #: sampled interaction is lockstep-engine territory).
    view: JointView | None = None
    #: optional EngineSpec bundling the shared engine axes; axes the spec
    #: sets overwrite the defaults (setting an axis both ways is rejected —
    #: see repro.core.spec). The async-only knobs (delays/max_staleness/
    #: overlap) stay constructor kwargs.
    spec: EngineSpec | None = None

    def __post_init__(self):
        apply_spec(self)

    def _resolved_policy(self) -> StepsizePolicy:
        return resolve_policy(self.policy)

    def _resolved(self) -> tuple[SyncStrategy, DelaySchedule, int]:
        """(wire strategy, delay schedule, bound) after StaleSync unwrap."""
        sync, delays, D = resolve_stale_sync(
            self.sync,
            None if self.delays == ZeroDelay() else self.delays,
            self.max_staleness,
        )
        return sync, ZeroDelay() if delays is None else delays, D

    def _check(
        self, game: VectorGame | None = None
    ) -> tuple[SyncStrategy, DelaySchedule, int, JointView]:
        # delegate to THE compatibility matrix (repro.core.spec): every
        # composition rejection for this engine is raised there.
        sync, delays, D = self._resolved()
        view = validate_spec(
            EngineSpec(
                update=self.update, sync=sync, topology=self.topology,
                gossip_steps=self.gossip_steps,
                policy=self._resolved_policy(), view=self.view,
                mesh=self.mesh, mesh_axis=self.mesh_axis,
            ),
            async_=True, game=game, delays=delays, max_staleness=D,
            overlap=self.overlap,
        )
        return sync, delays, D, view

    def _scan(self, game, x0, *, rounds, tau, gamma, key, stochastic,
              record_trajectory=True, x_star=None):
        if key is None:
            key = jax.random.PRNGKey(0)
        sync, delays, D, view = self._check(game)
        validate_round_args(tau, rounds)
        gammas = as_round_gammas(gamma, rounds)
        table = draw_delay_table(delays, rounds, x0.shape[0], D)
        policy = self._resolved_policy()
        # the context is a STATIC jit argument with game-derived floats; the
        # identity policy ignores it, so skip it to keep the scan's jit
        # cache shared across game instances of the same shape
        ss_ctx = (None if isinstance(policy, Theorem34Policy) else
                  build_round_context(game, self.topology, tau=tau,
                                      max_staleness=D))
        outs = _async_engine_scan(
            game, x0, gammas, jnp.asarray(table), key,
            update=self.update, sync=sync, topology=self.topology,
            tau=tau, stochastic=stochastic, max_staleness=D,
            gossip_steps=self.gossip_steps, policy=policy, ss_ctx=ss_ctx,
            mesh=self.mesh, mesh_axis=self.mesh_axis, overlap=self.overlap,
            view=view, record_trajectory=record_trajectory, x_star=x_star,
        )
        return sync, view, table, outs

    def run(
        self,
        game: VectorGame,
        x0: Array,
        *,
        rounds: int,
        tau: int = 1,
        gamma,
        key: Array | None = None,
        stochastic: bool = True,
        x_star: Array | None = None,
        record_trajectory: bool = False,
    ) -> AsyncPearlResult:
        """Run ``rounds`` asynchronous rounds and record diagnostics.

        Same contract as :meth:`repro.core.engine.PearlEngine.run`
        (including ``record_trajectory``); the result additionally carries
        the realized staleness table. Byte accounting is identical to the
        lockstep engine's — staleness delays arrival, not transmission — so
        sync-vs-async byte comparisons at matched ``tau`` are direct.
        """
        if x_star is None:
            x_star = game.equilibrium()
        sync, view, table, (x_final, ys, residuals, participants, links) = \
            self._scan(game, x0, rounds=rounds, tau=tau, gamma=gamma,
                       key=key, stochastic=stochastic,
                       record_trajectory=record_trajectory,
                       x_star=None if record_trajectory else x_star)
        if view.summary_based:
            res0 = jnp.sqrt(jnp.sum(game.operator_via_summary(x0) ** 2))
        else:
            res0 = jnp.sqrt(jnp.sum(game.operator(x0) ** 2))
        n, d = x0.shape
        bytes_up, bytes_down = account_round_bytes(
            update=self.update, sync=sync, topology=self.topology,
            gossip_steps=self.gossip_steps, participants=participants,
            links=links, n=n, d=d,
            base_bps=int(np.dtype(x0.dtype).itemsize), rounds=rounds,
            view=view,
        )
        if record_trajectory:
            rel_errors = relative_error_curve(x0, x_star, ys)
        else:
            rel_errors = relative_error_curve_from_sq(x0, x_star, ys)
        return AsyncPearlResult(
            x_final=x_final,
            rel_errors=rel_errors,
            residuals=np.concatenate([[float(res0)], np.asarray(residuals)]),
            tau=tau,
            rounds=rounds,
            bytes_up=bytes_up,
            bytes_down=bytes_down,
            xs=ys if record_trajectory else None,
            staleness=table,
        )

    def trajectory(
        self,
        game: VectorGame,
        x0: Array,
        *,
        rounds: int,
        tau: int = 1,
        gamma,
        key: Array | None = None,
        stochastic: bool = True,
    ) -> Array:
        """Raw per-round iterates ``(rounds, n, d)`` — no equilibrium needed."""
        _, _, _, (_, xs, _, _, _) = self._scan(
            game, x0, rounds=rounds, tau=tau, gamma=gamma, key=key,
            stochastic=stochastic, record_trajectory=True,
        )
        return xs


# ------------------------------------------------------------------ registry
DELAY_SCHEDULES = {
    "zero": ZeroDelay,
    "constant": ConstantDelay,
    "uniform": UniformDelay,
    "straggler": StragglerDelay,
}
