"""Unified PEARL engine: one rounds-scan, pluggable updates x communication.

The paper's central object is a single loop — per-player local updates against
a frozen snapshot, punctuated by periodic synchronization. Before this module
the repo implemented that loop four separate times (PEARL-SGD, joint
extragradient, PEARL-EG, Local-SGD-on-the-sum), each with a hard-coded update
rule and exactly one sync pattern. :class:`PearlEngine` factors the loop into
three orthogonal protocols:

- :class:`PlayerUpdate` — what ONE local step does on a player's own block
  (:class:`SgdUpdate`, :class:`ExtragradientUpdate`,
  :class:`OptimisticGradientUpdate`, :class:`HeavyBallUpdate`);
- :class:`SyncStrategy` — the wire behaviour of one synchronization:
  compression (:class:`ExactSync`, :class:`QuantizedSync`) and participation
  (:class:`PartialParticipation`, :class:`DropoutSync`), plus the bytes each
  synchronization moves in each direction;
- :class:`~repro.core.topology.Topology` — WHO talks to whom: the default
  :class:`~repro.core.topology.Star` is the paper's server broadcast (the
  bit-for-bit legacy path); graph topologies (ring, torus, random, ...)
  replace it with doubly-stochastic neighbor averaging over per-player views
  of the joint action, composed orthogonally with the same compression and
  participation strategies;
- the step-size *schedule* — a scalar, a per-round array (Thm 3.6), or any
  callable ``rounds -> (rounds,)`` such as
  :func:`repro.core.stepsize.gamma_warmup_cosine`;
- the step-size *policy* — :class:`~repro.core.stepsize.StepsizePolicy` maps
  the round context (tau, per-player staleness, spectral gap, coupling) to
  the per-player gammas the scan actually uses; the default
  :class:`~repro.core.stepsize.Theorem34Policy` is the identity (the
  schedule's value, bit-for-bit the policy-free program), and policies whose
  required context an engine cannot supply are rejected loudly at ``run()``
  (see docs/ARCHITECTURE.md for the full matrix).

Fully-communicating baselines (joint extragradient, Local SGD on the summed
objective) do not fit the per-player template — their step reads the OTHER
players' fresh iterates mid-round — so they plug in as :class:`JointUpdate`
rules that own the whole within-round computation while the engine keeps
rounds, diagnostics, and communication accounting.
:class:`DecentralizedExtragradientUpdate` is the server-free analogue: a
two-phase round (extrapolate, mix, correct, mix) the gossip scan owns.

Math conventions shared by both engines (the fine print that makes the
bit-for-bit pins meaningful — see also docs/THEORY.md):

- **RNG chain**: per round ``key -> (key, sub)``; per-player keys
  ``split(sub, n)``; per-step keys ``split(player_key, tau)``. Each update
  rule consumes its step key exactly as the legacy loops did, which is why
  the engine reproduces the legacy ``pearl_sgd`` / ``pearl_eg`` trajectories
  bit-for-bit (tests/test_engine.py pins this). Strategy randomness
  (participation masks) and topology never touch this chain.
- **Reference-snapshot ownership**: under the star, the ENGINE owns the
  joint snapshot ``x_sync`` — a player's reference is
  ``sync.view(i, x_sync)`` with its own row always live; under gossip each
  PLAYER owns a full per-player view ``V_i`` of the joint action, refreshed
  by anchored neighbor averaging (own diagonal pinned to the live block
  before and after every sweep).
- **Within-round freezing**: the reference a player optimizes against is
  frozen for all ``tau`` local steps of a round (the paper's Algorithm 1
  semantics); only synchronization refreshes it.
- **Byte-accounting direction**: the engine compresses the BROADCAST
  (upload exact, download at the wire dtype, ``compressed="down"``), the
  neural trainer compresses PRE-REDUCTION (``compressed="up"``) — both
  resolve through :func:`repro.core.topology.direction_itemsizes`.
"""

from __future__ import annotations

import abc
import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.game import VectorGame
from repro.core.spec import (
    EngineSpec,
    apply_spec,
    check_summary_view,
    resolve_view,
    validate_spec,
)
from repro.core.stepsize import (
    RoundContext,
    StepsizePolicy,
    Theorem34Policy,
    resolve_policy,
)
from repro.core.topology import (
    Star,
    Topology,
    direction_itemsizes,
    gossip_round_bytes,
    spectral_gap,
    star_round_bytes,
)

Array = jax.Array


# =========================================================================
# Result type (extended with communication accounting)
# =========================================================================
@dataclasses.dataclass(frozen=True)
class PearlResult:
    """Trajectory diagnostics recorded at synchronization points.

    ``bytes_up`` / ``bytes_down`` are per-round wire bytes derived from the
    active :class:`SyncStrategy` and :class:`~repro.core.topology.Topology`
    (no wall clock involved). Star: uplink counts each participating player's
    block once; downlink counts the joint vector to every participating
    player — the Section 3.1 convention of
    :class:`repro.core.metrics.CommunicationModel`, now per-round and
    compression-aware. Server-free topologies are edge-aware: every directed
    active link's message is counted once, in ``bytes_up`` (there is no
    server downlink), via :func:`repro.core.topology.gossip_round_bytes`.
    """

    x_final: Array          # (n, d) final joint action x_{tau R}
    rel_errors: np.ndarray  # (R+1,) ||x_{tau p} - x*||^2 / ||x_0 - x*||^2
    residuals: np.ndarray   # (R+1,) ||F(x_{tau p})||
    tau: int
    rounds: int
    bytes_up: np.ndarray | None = None    # (R,) uplink bytes per round
    bytes_down: np.ndarray | None = None  # (R,) downlink bytes per round
    #: full (rounds, n, d) per-round iterates — populated only when ``run``
    #: is called with ``record_trajectory=True``; the default run carries
    #: O(rounds) error scalars through the scan instead of materializing
    #: the trajectory (a rounds x n x d tensor is the dominant memory term
    #: at large n, and error curves never needed it)
    xs: Array | None = None

    @property
    def iterations(self) -> int:
        return self.tau * self.rounds

    @property
    def communications(self) -> int:
        """Number of synchronization rounds (the paper's communication cost)."""
        return self.rounds

    @property
    def total_bytes(self) -> int:
        """Total wire bytes over the run (0 when accounting was not recorded)."""
        if self.bytes_up is None or self.bytes_down is None:
            return 0
        return int(self.bytes_up.sum() + self.bytes_down.sum())


# =========================================================================
# Shared diagnostics / accounting (used by PearlEngine and AsyncPearlEngine)
# =========================================================================
def validate_round_args(tau: int, rounds: int) -> None:
    """Reject degenerate loop bounds before they reach the compiled scan.

    ``tau = 0`` would silently return the iterates unchanged via a zero-length
    inner scan (and ``rounds = 0`` via a zero-length rounds-scan), which reads
    like instant convergence in every downstream diagnostic — mirror the
    eager validation of :func:`repro.core.stepsize.gamma_constant`.
    """
    if tau < 1:
        raise ValueError(f"tau must be >= 1, got {tau}")
    if rounds < 1:
        raise ValueError(f"rounds must be >= 1, got {rounds}")


def relative_error_curve(x0: Array, x_star: Array, xs: Array) -> np.ndarray:
    """``(R+1,)`` relative-error trajectory with a guarded denominator.

    Normalizes by ``||x0 - x*||^2``. When the run starts AT the equilibrium
    (or within float equality of it) that denominator is zero and the naive
    division produces NaNs; in that case the curve falls back to absolute
    squared errors — identically zero at the start instead of the usual 1.0
    sentinel, and still meaningful if the iterates ever leave the equilibrium.
    """
    init_err_sq = jnp.sum((x0 - x_star) ** 2)
    at_equilibrium = not bool(init_err_sq > 0.0)
    denom = 1.0 if at_equilibrium else init_err_sq
    errs = jnp.sum((xs - x_star[None]) ** 2, axis=(1, 2)) / denom
    first = 0.0 if at_equilibrium else 1.0
    return np.concatenate([[first], np.asarray(errs)])


def relative_error_curve_from_sq(x0: Array, x_star: Array,
                                 err_sq: Array) -> np.ndarray:
    """:func:`relative_error_curve` from in-scan ``(R,)`` squared errors.

    The ``record_trajectory=False`` path computes ``||x_r - x*||^2`` inside
    the rounds-scan (O(rounds) scalars instead of a ``(rounds, n, d)``
    stacked trajectory) and this helper applies the same guarded
    normalization the trajectory-based curve uses — including the
    at-equilibrium fallback to absolute errors.
    """
    init_err_sq = jnp.sum((x0 - x_star) ** 2)
    at_equilibrium = not bool(init_err_sq > 0.0)
    denom = 1.0 if at_equilibrium else init_err_sq
    errs = jnp.asarray(err_sq) / denom
    first = 0.0 if at_equilibrium else 1.0
    return np.concatenate([[first], np.asarray(errs)])


def account_round_bytes(
    *,
    update,
    sync: "SyncStrategy",
    topology: Topology,
    gossip_steps: int,
    participants,
    links,
    n: int,
    d: int,
    base_bps: int,
    rounds: int,
    view: "JointView | None" = None,
) -> tuple[np.ndarray, np.ndarray]:
    """Per-round (uplink, downlink) byte arrays for one engine run.

    The single place the scan outputs (``participants`` server-message counts,
    ``links`` directed-edge counts) turn into wire bytes, shared by the
    lockstep and the bounded-staleness engines — staleness delays *arrival*,
    it never changes what the wire moved.

    A summary-based ``view`` (:class:`MeanFieldView`) changes the downlink
    honestly: each participant still uploads its ``d``-block exact, but
    downloads only the ``moments`` summary blocks at the wire dtype (plus
    one scale per summary block for low-bit wires) — O(d) per player per
    round, flat in ``n``. The sampled mode bills identically: a player's
    personalized summary is still ``moments`` blocks on the wire.
    """
    parts = np.asarray(participants, dtype=np.int64)
    if isinstance(update, JointUpdate):
        per_sync_up, per_sync_down = ExactSync().round_bytes(
            parts, n, d, base_bps
        )
        return (update.syncs_per_round * per_sync_up,
                update.syncs_per_round * per_sync_down)
    if view is not None and view.summary_based:
        up_item, down_item = direction_itemsizes(sync, base_bps,
                                                 compressed="down")
        up, down = star_round_bytes(
            parts, n=n, block_scalars=d, up_itemsize=up_item,
            down_itemsize=down_item, down_blocks=view.moments,
        )
        overhead = getattr(sync, "wire_overhead_bytes_per_block", 0)
        if overhead:
            down = down + parts * view.moments * overhead
        return up, down
    if topology.is_server:
        return sync.round_bytes(parts, n, d, base_bps)
    # Edge-aware: each directed active link carries one view-relay message
    # (n blocks — general games need multi-hop relay; the aggregative
    # consensus trainer pays only 1 block per edge, see PearlCommReport).
    # Lossy strategies are billed for every scheduled edge whether or not
    # the mask delivered it.
    msgs = np.asarray(links, dtype=np.int64)
    if sync.bills_full_round:
        full = topology.directed_edge_counts(n)
        sweeps = gossip_steps * getattr(update, "mixes_per_round", 1)
        msgs = sweeps * full[np.arange(rounds) % len(full)]
    sent, recv = gossip_round_bytes(
        msgs, payload_blocks=n, block_scalars=d,
        itemsize=sync.wire_itemsize(base_bps),
    )
    # low-bit payloads ship one f32 scale per relayed block on top of lanes
    overhead = getattr(sync, "wire_overhead_bytes_per_block", 0)
    if overhead:
        sent = sent + msgs * n * overhead
    return sent, recv


# =========================================================================
# Schedules
# =========================================================================
def as_round_gammas(gamma, rounds: int) -> jnp.ndarray:
    """Normalize a step-size spec to a per-round array of shape (rounds,).

    Accepts a scalar (constant step-size, Thms 3.3/3.4 and Cor 3.5), an array
    of per-round values (Thm 3.6's round-indexed schedule — the paper keeps
    gamma_k constant *within* each round), or any callable
    ``rounds -> (rounds,)`` array (e.g. :func:`stepsize.gamma_warmup_cosine`).
    """
    if callable(gamma):
        gamma = gamma(rounds)
    g = jnp.asarray(gamma, dtype=jnp.float64 if jax.config.read("jax_enable_x64") else jnp.float32)
    if g.ndim == 0:
        return jnp.full((rounds,), g)
    if g.shape != (rounds,):
        raise ValueError(f"gamma must be scalar or shape ({rounds},), got {g.shape}")
    return g


def build_round_context(game: VectorGame, topology: Topology, *, tau: int,
                        max_staleness: int = 0) -> RoundContext:
    """Static :class:`~repro.core.stepsize.RoundContext` for one engine run.

    The one place the engines assemble what a step-size policy may condition
    on: the coupling estimate is the game's ratio ``L_F / L_max`` of joint
    to per-player smoothness (1.0 — an uncoupled game, no correction — when
    the game publishes no constants); the spectral gap is ``1 - |lambda_2|``
    of the topology's Metropolis matrix (1.0 for the server broadcast;
    time-varying graphs use their union graph's matrix, an optimistic
    single-graph surrogate). ``delay_row`` is left ``None`` — the scans
    splice in the per-round staleness row where one exists.
    """
    try:
        c = game.constants()
        coupling = float(c.L_F / c.L_max) if c.L_max > 0 else 1.0
    except NotImplementedError:
        coupling = 1.0
    gap = (1.0 if topology.is_server
           else float(spectral_gap(topology.mixing_matrix(game.n))))
    return RoundContext(tau=tau, max_staleness=max_staleness,
                        spectral_gap=gap, coupling=coupling)


# =========================================================================
# PlayerUpdate protocol — one local step on a player's own block
# =========================================================================
class PlayerUpdate(abc.ABC):
    """One local step of player ``i`` against the frozen reference ``x_ref``.

    Implementations are frozen (hashable) dataclasses so they can be jit
    static arguments. ``state`` is per-player local memory (e.g. momentum),
    re-initialized at every synchronization — the snapshot the player reasons
    against has changed, so carrying stale local memory across rounds would
    mix gradients of different games.
    """

    name: str = "update"

    def init_state(self, game: VectorGame, i: Array, x_i: Array, x_ref: Array):
        """Local state at the start of a round (default: stateless)."""
        del game, i, x_i, x_ref
        return ()

    @abc.abstractmethod
    def step(self, game: VectorGame, i: Array, x_i: Array, x_ref: Array,
             gamma: Array, key: Array, state, stochastic: bool):
        """Return ``(x_i_next, state_next)`` for one local step."""


def _grad(game, i, x_i, x_ref, key, stochastic: bool):
    if stochastic:
        return game.player_grad_stoch(i, x_i, x_ref, key)
    return game.player_grad(i, x_i, x_ref)


@dataclasses.dataclass(frozen=True)
class SgdUpdate(PlayerUpdate):
    """Plain local SGD — paper Algorithm 1's inner step."""

    name: str = "sgd"

    def step(self, game, i, x_i, x_ref, gamma, key, state, stochastic):
        g = _grad(game, i, x_i, x_ref, key, stochastic)
        return x_i - gamma * g, state


@dataclasses.dataclass(frozen=True)
class ExtragradientUpdate(PlayerUpdate):
    """Local extragradient (Korpelevich) on the player's own block.

    The paper's conclusion lists extragradient incorporation as future work;
    composed with PEARL communication this is the beyond-paper ``pearl_eg``.
    """

    name: str = "extragradient"

    def step(self, game, i, x_i, x_ref, gamma, key, state, stochastic):
        k1, k2 = jax.random.split(key)
        g_half = _grad(game, i, x_i, x_ref, k1, stochastic)
        x_half = x_i - gamma * g_half
        g = _grad(game, i, x_half, x_ref, k2, stochastic)
        return x_i - gamma * g, state


@dataclasses.dataclass(frozen=True)
class OptimisticGradientUpdate(PlayerUpdate):
    """Optimistic gradient (OGDA): ``x - gamma * (2 g_k - g_{k-1})``.

    Single oracle call per step (vs extragradient's two). The past-gradient
    state initializes to the deterministic gradient at the round snapshot, so
    the first local step of each round reduces to plain gradient descent.
    """

    name: str = "optimistic_gradient"

    def init_state(self, game, i, x_i, x_ref):
        return game.player_grad(i, x_i, x_ref)

    def step(self, game, i, x_i, x_ref, gamma, key, state, stochastic):
        g = _grad(game, i, x_i, x_ref, key, stochastic)
        return x_i - gamma * (2.0 * g - state), g


@dataclasses.dataclass(frozen=True)
class HeavyBallUpdate(PlayerUpdate):
    """Polyak heavy-ball momentum on the local block (velocity resets at sync)."""

    beta: float = 0.9
    name: str = "heavy_ball"

    def init_state(self, game, i, x_i, x_ref):
        return jnp.zeros_like(x_i)

    def step(self, game, i, x_i, x_ref, gamma, key, state, stochastic):
        g = _grad(game, i, x_i, x_ref, key, stochastic)
        v = self.beta * state + g
        return x_i - gamma * v, v


@dataclasses.dataclass(frozen=True)
class DecentralizedExtragradientUpdate(PlayerUpdate):
    """Round-level extragradient over the gossip views (server-free only).

    Plain gossip PEARL pays for stability with extra mixing sweeps: the
    per-player views lag consensus, the lag acts like staleness under
    antisymmetric coupling, and at strong coupling the Theorem 3.4 step size
    diverges unless ``gossip_steps`` is cranked up (the PR 2 bytes-for-margin
    tradeoff). This update removes the tradeoff with the extragradient
    mechanism instead of more averaging — each round runs TWO phases with a
    mixing sweep interleaved between them:

    1. *extrapolation*: ``tau`` local gradient steps from ``x_i`` against the
       own view ``V_i`` produce the half-point ``x_half_i``;
    2. one anchored mixing sweep relays the half-points — ``V_half`` is each
       player's view of the extrapolated joint action;
    3. *correction*: ``tau`` local gradient steps RESTARTED from ``x_i``
       against ``V_half_i`` produce ``x_next_i``;
    4. a second anchored sweep mixes the new iterates into the carried views.

    With ``tau = 1`` and a complete graph this is exactly the joint
    extragradient (:class:`JointExtragradientUpdate`) evaluated blockwise —
    the correction gradient sees the opponents' half-steps, which is what
    kills the antisymmetric-coupling rotation. ``gossip_steps = 1`` then
    suffices at strong coupling (tests/test_stepsize_policies.py pins the
    configuration where plain gossip diverges and this converges; the
    BENCH_engine.json sweep tracks the byte cost: 2 sweeps/round vs the
    ``gossip_steps >= 4`` plain gossip needs for the same margin).

    Only meaningful where views exist: the engine rejects it on the star
    (use :class:`JointExtragradientUpdate` — the server broadcast IS exact
    mixing), under participation masks (a half-point relayed to nobody has
    no extragradient semantics), and in the bounded-staleness engine (the
    mid-round sweep has no per-receiver delayed equivalent).
    """

    name: str = "decentralized_extragradient"
    mixes_per_round: int = dataclasses.field(default=2, init=False, repr=False)

    def step(self, game, i, x_i, x_ref, gamma, key, state, stochastic):
        # the local phases are plain gradient steps; the extragradient
        # structure lives at the round level (the engine's two-phase body)
        g = _grad(game, i, x_i, x_ref, key, stochastic)
        return x_i - gamma * g, state


# =========================================================================
# JointUpdate protocol — fully-communicating baselines
# =========================================================================
class JointUpdate(abc.ABC):
    """A round that operates on the WHOLE joint action with fresh iterates.

    Used for baselines whose step cannot be decomposed into stale-snapshot
    player blocks (joint extragradient syncs at the midpoint; Local SGD on the
    summed objective follows the wrong vector field entirely).
    ``syncs_per_round`` feeds the communication accounting; ``keys_per_round``
    is how many PRNG keys the round consumes — the engine splits the carry
    key into ``1 + keys_per_round`` exactly like the legacy loops did, which
    keeps the stochastic baselines bit-for-bit reproducible.
    """

    name: str = "joint"
    syncs_per_round: int = 1
    keys_per_round: int = 1

    @abc.abstractmethod
    def round(self, game: VectorGame, x: Array, gamma: Array, keys: Array,
              stochastic: bool) -> Array:
        """Return the next joint action; ``keys`` has ``keys_per_round`` keys."""


@dataclasses.dataclass(frozen=True)
class JointExtragradientUpdate(JointUpdate):
    """Fully-synchronized stochastic extragradient on the joint operator.

    Two synchronizations per iteration: the extrapolation point ``x_half`` is
    broadcast so every player's second gradient sees the others' half-steps.
    """

    name: str = "joint_extragradient"
    syncs_per_round: int = 2
    keys_per_round: int = 2

    def round(self, game, x, gamma, keys, stochastic):
        k1, k2 = keys
        if stochastic:
            g_half = game.operator_stoch(x, k1)
            x_half = x - gamma * g_half
            g = game.operator_stoch(x_half, k2)
        else:
            x_half = x - gamma * game.operator(x)
            g = game.operator(x_half)
        return x - gamma * g


@dataclasses.dataclass(frozen=True)
class SumLocalSgdUpdate(JointUpdate):
    """Local SGD on the summed objective — the Section B failure mode.

    Classical FL applied to the naive finite-sum formulation: the bilinear
    couplings cancel in the sum, so the iterates follow a vector field that
    diverges whenever ``lambda_min(A) < 1/10`` (Figure 4 left).
    """

    name: str = "sum_local_sgd"
    syncs_per_round: int = 1
    keys_per_round: int = 1

    def round(self, game, x, gamma, keys, stochastic):
        g = game.sum_gradient(x, keys[0] if stochastic else None)
        return x - gamma * g


# =========================================================================
# Blockwise low-bit quantization (int8 / int4 with per-block scales)
# =========================================================================
#: f32 scale factor shipped per player block alongside a low-bit payload.
SCALE_BYTES = 4


def _block_scale(x: Array, qmax: float) -> Array:
    """Per-block symmetric quantization scale over the last axis.

    One f32 scale per ``d``-vector (player block, or per-(view, block) for
    gossip view tensors), floored at ``tiny`` so an all-zero block dequantizes
    to exact zeros instead of NaNs.
    """
    s = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / qmax
    return jnp.maximum(s, jnp.finfo(jnp.float32).tiny).astype(jnp.float32)


def int8_quantize(x: Array) -> tuple[Array, Array]:
    """``(q, scale)``: symmetric int8 lanes in [-127, 127] + per-block scale."""
    s = _block_scale(x, 127.0)
    q = jnp.clip(jnp.round(x / s), -127, 127).astype(jnp.int8)
    return q, s


def int4_quantize(x: Array) -> tuple[Array, Array]:
    """``(q, scale)``: symmetric 4-bit lanes in [-7, 7] (stored int8) +
    per-block scale. Two lanes pack into one byte via :func:`int4_pack`."""
    s = _block_scale(x, 7.0)
    q = jnp.clip(jnp.round(x / s), -7, 7).astype(jnp.int8)
    return q, s


def lowbit_dequantize(q: Array, scale: Array, dtype) -> Array:
    """Dequantize int lanes with their per-block scale back to ``dtype``."""
    return (q.astype(jnp.float32) * scale).astype(dtype)


def int4_pack(q: Array) -> Array:
    """Pack int4 lanes (int8 values in [-8, 7], last axis EVEN) into bytes.

    Offset-binary nibbles: lane + 8 in [0, 15]; even lanes take the low
    nibble, odd lanes the high nibble. Bitwise-invertible
    (:func:`int4_unpack`), which tests/test_lowbit_sync.py pins.
    """
    if q.shape[-1] % 2:
        raise ValueError(
            f"int4 packing needs an even last axis (two lanes per byte), "
            f"got shape {q.shape}; pad the block or use Int8Sync"
        )
    u = (q.astype(jnp.int32) + 8).astype(jnp.uint8)
    lo, hi = u[..., 0::2], u[..., 1::2]
    return lo | (hi << 4)


def int4_unpack(packed: Array) -> Array:
    """Inverse of :func:`int4_pack`: bytes back to interleaved int4 lanes."""
    lo = (packed & 0xF).astype(jnp.int32) - 8
    hi = (packed >> 4).astype(jnp.int32) - 8
    q = jnp.stack([lo, hi], axis=-1)
    return q.reshape(*packed.shape[:-1], 2 * packed.shape[-1]).astype(jnp.int8)


# =========================================================================
# SyncStrategy protocol — what the server broadcast looks like
# =========================================================================
class SyncStrategy(abc.ABC):
    """Wire behaviour of one synchronization round (topology-agnostic).

    A strategy controls three things:
    - ``view(i, x_sync, ctx)`` — under the :class:`~repro.core.topology.Star`
      server broadcast, the reference snapshot player ``i`` locally optimizes
      against (its own row is always exact: a player never quantizes its own
      live block). Graph topologies do not use ``view``: their references
      come from neighbor averaging, with ``compress`` applied to the wire —
      compression composes with any topology instead of being baked in here;
    - ``mask(n, ctx)`` — which players participate this round (``None`` =
      everyone); non-participants keep their stale block in the next
      snapshot, and under gossip their links carry nothing;
    - ``round_bytes(participants, n, d, base_bps)`` — per-round wire bytes
      for the star topology, routed through the shared direction-aware
      helpers in :mod:`repro.core.topology`.

    Strategies are frozen hashable dataclasses (randomized ones carry an int
    seed, not a PRNG key, so they can be jit static args); per-round
    randomness lives in a key threaded through the rounds-scan, independent
    of the sampling-noise key chain — switching strategy never perturbs the
    gradient noise stream.
    """

    name: str = "sync"
    uses_mask: bool = False          # True for participation-drawing strategies
    bills_full_round: bool = False   # True when lost transmissions are still paid
    has_wire_state: bool = False     # True when the wire carries state (EF)
    #: extra wire bytes per transmitted d-block beyond the per-scalar
    #: itemsize (the f32 scale a low-bit payload ships per block)
    wire_overhead_bytes_per_block: int = 0

    # ----------------------------------------------------------- round state
    def init_state(self):
        return ()

    def pre_round(self, state):
        """Advance per-round strategy state; returns ``(state, ctx)``."""
        return state, ()

    # ----------------------------------------------------- wire round state
    # Strategies with ``has_wire_state`` (error feedback) thread a residual
    # through the engines' star broadcast: each round the TRANSMIT tensor is
    # ``pre_wire(x, state)`` (iterates plus carried residual), receivers see
    # its wire round-trip, and ``post_wire`` banks what the wire dropped.
    # Stateless strategies keep the legacy ``view`` path bit-for-bit.
    def init_wire_state(self, x: Array):
        """Wire-state pytree carried by the rounds-scan (default: none)."""
        del x
        return ()

    def pre_wire(self, x: Array, state) -> Array:
        """The tensor actually transmitted this round."""
        del state
        return x

    def post_wire(self, t: Array, state):
        """Next wire state, given this round's transmit tensor."""
        del t
        return state

    def roundtrip(self, x: Array) -> Array:
        """What receivers decode from ``x`` after the wire (identity for an
        exact wire). Deterministic, so the host path and the mesh-lowered
        collective produce identical values from the same transmit tensor."""
        return x

    # ------------------------------------------------------------- semantics
    def view(self, i: Array, x_sync: Array, ctx) -> Array:
        del i, ctx
        return x_sync

    def mask(self, n: int, ctx) -> Array | None:
        """Boolean participation mask of shape ``(n,)`` or None for all."""
        del n, ctx
        return None

    # ----------------------------------------------------------- trainer use
    def compress(self, x: Array) -> Array:
        """Wire representation of a tensor (used by the neural trainer's
        pre-reduction quantization); exact by default."""
        return x

    # ------------------------------------------------------------ accounting
    def wire_itemsize(self, base_bps: int) -> int:
        """Bytes per scalar on the broadcast wire."""
        return base_bps

    def round_bytes(self, participants: np.ndarray, n: int, d: int,
                    base_bps: int) -> tuple[np.ndarray, np.ndarray]:
        """Per-round (uplink, downlink) byte arrays for the star topology.

        ``participants`` is the per-round count of players whose blocks the
        server actually received; strategies with ``bills_full_round`` (lossy
        links) are billed for all ``n`` regardless of delivery. Uplink: one
        ``d``-block per billed player at the joint dtype. Downlink: the
        ``n*d`` joint vector to each billed player at the (possibly
        compressed) wire dtype — the engine compresses the broadcast, so the
        shared helper is called with ``compressed="down"``.
        """
        billed = np.atleast_1d(np.asarray(participants)).astype(np.int64)
        if self.bills_full_round:
            billed = np.full_like(billed, n)
        up_item, down_item = direction_itemsizes(self, base_bps,
                                                 compressed="down")
        return star_round_bytes(billed, n=n, block_scalars=d,
                                up_itemsize=up_item, down_itemsize=down_item)


def resolve_sync(sync: "SyncStrategy | None", sync_dtype) -> "SyncStrategy":
    """Resolve the ``(sync, sync_dtype)`` argument pair used across adapters:
    an explicit strategy wins, a bare dtype is shorthand for
    ``QuantizedSync(dtype)``, neither means :class:`ExactSync`."""
    if sync is not None:
        return sync
    if sync_dtype is not None:
        return QuantizedSync(sync_dtype)
    return ExactSync()


@dataclasses.dataclass(frozen=True)
class ExactSync(SyncStrategy):
    """Every round, every player; full-precision broadcast (Algorithm 1)."""

    name: str = "exact"


@dataclasses.dataclass(frozen=True)
class QuantizedSync(SyncStrategy):
    """Compressed broadcast: players see the others' blocks quantized to
    ``dtype`` (e.g. ``jnp.bfloat16``) while keeping their own block exact —
    the paper's Section 3.1 compression future-work composed with local
    steps. Quantization noise on the stale snapshot is absorbed by the
    Theorem 3.4 ``sigma^2`` term."""

    dtype: Any = jnp.bfloat16
    name: str = "quantized"

    def view(self, i, x_sync, ctx):
        x_ref = x_sync.astype(self.dtype).astype(x_sync.dtype)
        return x_ref.at[i].set(x_sync[i])

    def compress(self, x):
        return x.astype(self.dtype)

    def wire_itemsize(self, base_bps):
        del base_bps
        return int(np.dtype(self.dtype).itemsize)


@dataclasses.dataclass(frozen=True)
class _LowBitSync(SyncStrategy):
    """Shared plumbing for the sub-bf16 wire: per-player-block scale factors
    plus an optional error-feedback residual.

    Low-bit symmetric quantization is *biased* (round-to-nearest on a coarse
    grid), and under PEARL's repeated broadcast the bias compounds: the
    iterates stall in a neighborhood set by the grid resolution instead of
    contracting to the equilibrium (int4's 16 levels make this visible —
    tests/test_lowbit_sync.py records the boundary). ``error_feedback=True``
    (default) carries the standard fix in sync-strategy wire state: the
    residual ``e`` of what the wire dropped is added back before the next
    quantization, ``t = x + e``, ``e' = t - Q(t)``, so the *time-averaged*
    transmitted signal is unbiased and the quantized trajectory reaches the
    exact-sync fixed point (docs/THEORY.md sketches the argument).

    Wire layout (what :mod:`repro.core.collective` ships per player block):
    the f32 scale bitcast to 4 bytes, then the quantized lanes — ONE u8
    payload per block, so the dry-run HLO of a low-bit sharded sync shows a
    single u8 collective operand (no side-channel f32 gather to re-widen).
    Accounting matches: ``wire_itemsize`` bills the lanes,
    ``wire_overhead_bytes_per_block`` the scale.

    Error feedback is defined for the star broadcast, where ONE wire tensor
    per round has a well-defined residual; gossip relays per-edge views and
    the trainer's pre-reduction compression never sees engine state, so both
    reject ``error_feedback=True`` loudly (stateless low-bit composes fine).
    """

    error_feedback: bool = True
    wire_overhead_bytes_per_block = SCALE_BYTES

    # subclasses set: name, _qmax/_quantize, wire_itemsize
    def _quantize(self, x):
        raise NotImplementedError

    @property
    def has_wire_state(self):
        return self.error_feedback

    def init_wire_state(self, x):
        return jnp.zeros_like(x) if self.error_feedback else ()

    def pre_wire(self, x, state):
        return x + state if self.error_feedback else x

    def post_wire(self, t, state):
        if not self.error_feedback:
            return state
        return t - self.roundtrip(t)

    def roundtrip(self, x):
        q, s = self._quantize(x)
        return lowbit_dequantize(q, s, x.dtype)

    def view(self, i, x_sync, ctx):
        # stateless path only: the engines route error feedback through
        # pre_wire/post_wire and never call view for has_wire_state syncs
        return self.roundtrip(x_sync).at[i].set(x_sync[i])

    def compress(self, x):
        return self.roundtrip(x)

    # ------------------------------------------------------------- the wire
    # Consumed by repro.core.collective: encode to the u8 payload that
    # crosses the mesh axis, decode back after the gather/permute.
    def wire_encode(self, x: Array) -> Array:
        q, s = self._quantize(x)
        scale_bytes = jax.lax.bitcast_convert_type(s, jnp.uint8).reshape(
            *s.shape[:-1], SCALE_BYTES)
        return jnp.concatenate([scale_bytes, self._pack(q)], axis=-1)

    def wire_decode(self, payload: Array, dtype) -> Array:
        scale_bytes = payload[..., :SCALE_BYTES]
        s = jax.lax.bitcast_convert_type(
            scale_bytes.reshape(*scale_bytes.shape[:-1], 1, SCALE_BYTES),
            jnp.float32,
        ).reshape(*scale_bytes.shape[:-1], 1)
        return lowbit_dequantize(self._unpack(payload[..., SCALE_BYTES:]),
                                 s, dtype)

    def _pack(self, q):
        return jax.lax.bitcast_convert_type(q, jnp.uint8)

    def _unpack(self, payload):
        return jax.lax.bitcast_convert_type(payload, jnp.int8)

    def round_bytes(self, participants, n, d, base_bps):
        up, down = super().round_bytes(participants, n, d, base_bps)
        billed = np.atleast_1d(np.asarray(participants)).astype(np.int64)
        # the engine compresses the broadcast: each billed player downloads
        # n blocks, each carrying its f32 scale on top of the lane payload
        return up, down + billed * n * self.wire_overhead_bytes_per_block


@dataclasses.dataclass(frozen=True)
class Int8Sync(_LowBitSync):
    """1-byte wire: symmetric int8 lanes + per-player-block f32 scale, with
    error feedback by default. Halves the bf16 wire again; the residual keeps
    the broadcast unbiased so the trajectory still reaches the exact-sync
    fixed point."""

    name: str = "int8"

    def _quantize(self, x):
        return int8_quantize(x)

    def wire_itemsize(self, base_bps):
        del base_bps
        return 1


@dataclasses.dataclass(frozen=True)
class Int4Sync(_LowBitSync):
    """Half-byte wire: two 4-bit lanes per byte + per-player-block f32 scale.

    Requires an even block dimension ``d`` (two lanes per byte; no silent
    padding, so billing at 0.5 B/scalar stays exact). Without error feedback
    the 16-level grid visibly stalls the trajectory — the honest boundary
    tests/test_lowbit_sync.py records; with the residual it converges.
    """

    name: str = "int4"

    def _quantize(self, x):
        # reject odd blocks on the HOST path too, not just when int4_pack
        # hits the mesh wire — the two lowerings must agree on what runs
        if x.shape[-1] % 2:
            raise ValueError(
                f"int4 sync needs an even last axis (two lanes per byte), "
                f"got shape {x.shape}; pad the block or use Int8Sync"
            )
        return int4_quantize(x)

    def _pack(self, q):
        return int4_pack(q)

    def _unpack(self, payload):
        return int4_unpack(payload)

    def wire_itemsize(self, base_bps):
        del base_bps
        return 0.5


class _RandomizedSync(SyncStrategy):
    """Shared plumbing for strategies that draw a per-round player mask."""

    seed: int
    uses_mask = True

    def init_state(self):
        return jax.random.PRNGKey(self.seed)

    def pre_round(self, state):
        state, sub = jax.random.split(state)
        return state, sub


@dataclasses.dataclass(frozen=True)
class PartialParticipation(_RandomizedSync):
    """Each round an independent random subset of players synchronizes
    (GreedyFed-style client sampling transplanted to the game setting): a
    player participates with probability ``fraction``; the rest keep their
    stale block and move no bytes this round."""

    fraction: float = 0.5
    seed: int = 0
    name: str = "partial"

    def __post_init__(self):
        if not 0.0 <= self.fraction <= 1.0:
            raise ValueError(
                f"PartialParticipation.fraction must be in [0, 1], "
                f"got {self.fraction}"
            )

    def mask(self, n, ctx):
        return jax.random.uniform(ctx, (n,)) < self.fraction


@dataclasses.dataclass(frozen=True)
class DropoutSync(_RandomizedSync):
    """Unreliable links: every player transmits, but each round a player's
    sync is LOST with probability ``p`` (its stale block survives on the
    server). Unlike :class:`PartialParticipation` the bytes are still paid —
    ``bills_full_round`` makes the accounting charge every transmission
    (all ``n`` players on star, every active edge under gossip) regardless
    of delivery, staying integer-typed throughout."""

    p: float = 0.1
    seed: int = 0
    name: str = "dropout"
    bills_full_round = True

    def __post_init__(self):
        if not 0.0 <= self.p <= 1.0:
            raise ValueError(f"DropoutSync.p must be in [0, 1], got {self.p}")

    def mask(self, n, ctx):
        return jax.random.uniform(ctx, (n,)) >= self.p


# =========================================================================
# JointView protocol — what player i sees of the population each round
# =========================================================================
class JointView(abc.ABC):
    """The REFERENCE axis of a round: what player ``i`` optimizes against.

    Every PEARL round has the same skeleton — tau local steps against a
    frozen reference, then an exchange that refreshes the reference — and
    the engines historically hard-wired two reference shapes to the
    topology: the star broadcast (every player reads the server's joint
    snapshot) and the gossip per-player views. ``JointView`` names that
    axis explicitly so both become instances of one abstraction and a third
    can exist: :class:`MeanFieldView`, where a player's reference is an
    O(d) tensor of population moments instead of the ``(n, d)`` joint —
    the mean-field structural win (*Federated Learning as a Mean-Field
    Game*, PAPERS.md) that makes per-player state, compute, and wire flat
    in ``n``.

    Views are frozen hashable dataclasses (jit static arguments) and carry
    no array state — the scan owns the reference tensors; the view decides
    their SHAPE and semantics. ``ref_scalars_per_player`` is the honest
    size of what one player holds/receives per round (the scaling
    benchmark's per-player memory column); ``summary_based`` is the
    trace-time dispatch bit.
    """

    name: str = "view"
    #: True when the per-player reference is an O(d) population summary
    #: rather than (a view of) the full (n, d) joint action
    summary_based: bool = False

    @abc.abstractmethod
    def ref_scalars_per_player(self, n: int, d: int) -> int:
        """Scalars of reference state one player reads each round."""


@dataclasses.dataclass(frozen=True)
class StarView(JointView):
    """The paper's server broadcast: every player reads the full joint
    snapshot (its own row kept live) — the bit-for-bit legacy star path,
    now named. Requires a server topology. O(n d) per player."""

    name: str = "star"

    def ref_scalars_per_player(self, n, d):
        return n * d


@dataclasses.dataclass(frozen=True)
class GossipView(JointView):
    """Server-free per-player views: player ``i`` carries a full ``(n, d)``
    estimate of the joint action, refreshed by anchored neighbor averaging
    — the decentralized-VI path, unchanged. Requires a graph topology.
    O(n d) per player."""

    name: str = "gossip"

    def ref_scalars_per_player(self, n, d):
        return n * d


@dataclasses.dataclass(frozen=True)
class MeanFieldView(JointView):
    """O(d) references: players best-respond to population moments.

    The server maintains the ``(moments, d)`` population sufficient
    statistics of the joint action (row ``p`` = ``mean_i (x^i)**(p+1)``;
    see :class:`repro.core.game.AggregativeGame`) and broadcasts THAT — the
    wire and each player's reference are ``moments * d`` scalars regardless
    of ``n``. Requires the server topology (the summary is one maintained
    tensor, the star's defining property) and an
    :class:`~repro.core.game.AggregativeGame` (a game whose coupling
    genuinely factors through the moments — the engine cannot check the
    math, only the contract).

    ``self_correction=True`` (default) applies the exact leave-one-out
    identity ``mean_{j!=i} (x^j)**p = (n * pop_p - (x^i)**p) / (n - 1)``
    to each player's read, so for a true aggregative game the summary path
    follows the exact engine to reduction-order ULPs at ANY n.
    ``self_correction=False`` is the infinitesimal-player idealization
    (every player reads the raw population moments, own contribution
    included): per-player error O(beta * heterogeneity / (n - 1)), the gap
    the scaling benchmark measures shrinking with n.

    ``sample=k`` replaces the dense summary with per-round resampled
    neighbor subsets: player ``i`` reads the moments of ``k`` opponents
    drawn uniformly WITH replacement from the other ``n - 1`` players (the
    finite-n sampled-interaction correction, generalizing per-round
    Erdos-Renyi rounds to the summary path). Draws come from the fold-in
    key hierarchy ``fold_in(fold_in(PRNGKey(seed), round), player)`` —
    round r's subsets are derivable without replaying rounds ``0..r-1``,
    the same per-round hierarchy discipline as
    :class:`repro.core.topology.ResampledErdosRenyi`, and independent of
    the sampling-noise key chain. Sampled subsets exclude the reader by
    construction, so the leave-one-out correction is built in and
    ``self_correction`` is ignored.
    """

    moments: int = 1
    self_correction: bool = True
    sample: int | None = None
    seed: int = 0
    name: str = "mean_field"
    summary_based = True

    def __post_init__(self):
        if self.moments not in (1, 2):
            raise ValueError(
                f"MeanFieldView.moments must be 1 (opponent mean) or 2 "
                f"(+ mean of squares), got {self.moments}"
            )
        if self.sample is not None and self.sample < 1:
            raise ValueError(
                f"MeanFieldView.sample must be >= 1 (or None for the dense "
                f"summary), got {self.sample}"
            )

    def ref_scalars_per_player(self, n, d):
        del n
        return self.moments * d


# ``resolve_view`` / ``check_summary_view`` moved to repro.core.spec (the
# single compatibility matrix) and are re-exported above for compatibility.


class _SummaryRefGame:
    """Pytree shim routing the PlayerUpdate oracle calls to the summary API.

    The update rules pass ``x_ref`` OPAQUELY from the engine to
    ``game.player_grad(_stoch)``, so the mean-field scan can hand them a
    ``(own_ref, summary)`` pair instead of the ``(n, d)`` joint and wrap
    the game in this shim — every existing :class:`PlayerUpdate`
    (sgd/extragradient/optimistic/heavy-ball) then runs unchanged on O(d)
    references, including :class:`OptimisticGradientUpdate`'s
    deterministic-gradient state init.
    """

    __slots__ = ("inner",)

    def __init__(self, inner):
        self.inner = inner

    def player_grad(self, i, x_i, ref):
        own_ref, summary = ref
        return self.inner.player_grad_summary(i, x_i, own_ref, summary)

    def player_grad_stoch(self, i, x_i, ref, key):
        own_ref, summary = ref
        return self.inner.player_grad_stoch_summary(i, x_i, own_ref,
                                                    summary, key)


jax.tree_util.register_pytree_node(
    _SummaryRefGame,
    lambda g: ((g.inner,), None),
    lambda aux, children: _SummaryRefGame(children[0]),
)


def summary_wire(sync: SyncStrategy, pop: Array, ws):
    """(decoded summary, next wire state): what players read after the wire.

    THE one place the mean-field engines apply a sync strategy to the
    ``(moments, d)`` summary tensor — compression acts on the O(d) summary,
    never the joint. Stateless strategies use the gossip wire idiom
    ``compress(pop)`` re-widened to the compute dtype (bf16 round-trip for
    :class:`QuantizedSync`, quantize-dequantize for stateless low-bit,
    identity for :class:`ExactSync`); error-feedback strategies run their
    ``pre_wire -> roundtrip -> post_wire`` chain with the residual banked
    against the summary (an O(d) residual — the wire state scales with the
    summary, not the population).
    """
    if sync.has_wire_state:
        t = sync.pre_wire(pop, ws)
        return sync.roundtrip(t), sync.post_wire(t, ws)
    return sync.compress(pop).astype(pop.dtype), ws


# =========================================================================
# The engine
# =========================================================================
@partial(jax.jit,
         static_argnames=("update", "sync", "topology", "tau", "stochastic",
                          "gossip_steps", "policy", "ss_ctx", "mesh",
                          "mesh_axis", "view", "record_trajectory"))
def _engine_scan(game: VectorGame, x0: Array, gammas: Array, key: Array, *,
                 update, sync: SyncStrategy, topology: Topology, tau: int,
                 stochastic: bool, gossip_steps: int = 1,
                 policy: StepsizePolicy = Theorem34Policy(),
                 ss_ctx: RoundContext | None = None,
                 mesh=None, mesh_axis: str = "players",
                 view: JointView | None = None,
                 record_trajectory: bool = True, x_star: Array | None = None):
    """One compiled program: rounds-scan over (local phase -> synchronize).

    RNG chain (bit-compatible with the legacy loops): per round
    ``key, sub = split(key)``; per-player keys ``split(sub, n)``; per-step
    keys ``split(player_key, tau)``. Strategy randomness (participation
    masks) is threaded separately so it never perturbs sampling noise — and
    neither does the topology: the gossip path splits keys identically.

    ``policy`` maps the round's scheduled gamma + the static ``ss_ctx`` to
    the step sizes the players actually use. The identity policy returns
    the scheduled gamma object itself, so the scalar path below compiles
    the LITERAL policy-free program (per-player gammas only enter the vmap
    when a policy emits an ``(n,)`` row — resolved at trace time).

    A ``mesh`` (with the player dimension on ``mesh_axis``) lowers the
    synchronization exchange through :mod:`repro.core.collective` so the
    wire dtype provably survives to the compiled collective (star: the
    joint-snapshot gather; gossip: every Metropolis relay). ``mesh=None``
    branches at trace time and compiles the identical legacy program — the
    bit-for-bit pin discipline.

    ``view`` selects the reference axis (:class:`JointView`): ``None`` (or
    the matching :class:`StarView`/:class:`GossipView`) compiles the legacy
    topology-decided program unchanged; a :class:`MeanFieldView` runs the
    O(d) summary branch, where the only broadcast tensor is the
    ``(moments, d)`` population moments and compression applies to THAT.

    ``record_trajectory=False`` replaces the stacked ``(rounds, n, d)``
    trajectory output with in-scan squared errors ``||x_r - x*||^2``
    against the traced ``x_star`` — O(rounds) scalars, the only memory
    shape that survives million-player runs. The carried round bodies are
    identical either way; only the scan's emitted outputs change.

    Returns ``(x_final, ys, residuals, participants, links)`` where ``ys``
    is the stacked trajectory (``record_trajectory=True``) or the per-round
    squared error scalars, and ``links`` is the per-round wire-message
    count (server messages under star, directed active edges under gossip)
    feeding the edge-aware byte accounting.
    """
    from repro.core import collective

    n = x0.shape[0]
    # stateful selection policies (repro.core.selection) dispatch at trace
    # time: their value-estimate state rides the strategy-state carry slot,
    # and the mask comes from select/observe instead of pre_round/mask —
    # legacy strategies compile the identical program
    selection = getattr(sync, "stateful_selection", False)
    if ss_ctx is None:
        ss_ctx = RoundContext(tau=tau)

    def vmap_players(local_fn, player_keys, gamma):
        """vmap ``local_fn(i, pkey, gamma_i)`` over players, threading
        per-player gammas only when the policy emits an ``(n,)`` row. The
        branch resolves at trace time: a scalar-emitting policy (identity in
        particular) stays CLOSED OVER like the legacy loop did, so the
        compiled program is bit-for-bit the policy-free one."""
        g_row = policy.round_gammas(gamma, ss_ctx)
        if jnp.ndim(g_row) == 0:
            return jax.vmap(lambda i, k: local_fn(i, k, g_row))(
                jnp.arange(n), player_keys)
        return jax.vmap(local_fn)(jnp.arange(n), player_keys, g_row)

    def tau_local_steps(i, pkey, x_start, x_ref, gamma, game_=game):
        """tau local steps for player i against the frozen reference view.

        ``game_`` defaults to the real game (the legacy program, closure
        binding unchanged); the mean-field branch passes the
        :class:`_SummaryRefGame` shim so the same update rules run on
        ``(own_ref, summary)`` references."""
        state0 = update.init_state(game_, i, x_start, x_ref)
        keys = jax.random.split(pkey, tau)

        def step(c, k):
            x_i, st = c
            x_i, st = update.step(game_, i, x_i, x_ref, gamma, k, st,
                                  stochastic)
            return (x_i, st), None

        (x_i, _), _ = jax.lax.scan(step, (x_start, state0), keys)
        return x_i

    if isinstance(update, JointUpdate):
        def round_body(carry, scan_in):
            gamma, _ = scan_in
            x, key, s = carry
            # split exactly as the legacy loops did (key, k1, ..., k_m) so
            # stochastic baseline trajectories stay bit-for-bit reproducible
            keys = jax.random.split(key, 1 + update.keys_per_round)
            x_next = update.round(game, x, gamma, keys[1:], stochastic)
            res = jnp.sqrt(jnp.sum(game.operator(x_next) ** 2))
            full = jnp.asarray(n, jnp.int32)
            return (x_next, keys[0], s), (x_next, res, full, full)

        init = (x0, key, sync.init_state())
    elif view is not None and view.summary_based:
        # Mean-field star: the server maintains the (moments, d) population
        # sufficient statistics — the ONE tensor on the wire. Per-player
        # reference, compute, and wire are O(moments * d) regardless of n;
        # the joint action itself exists only as the (n, d) scan carry (one
        # row per player — each player owns O(d) of it). Residuals go
        # through the game's O(n d) summary-corrected operator, never the
        # O(n^2 d) vmapped full-joint oracle.
        moments = view.moments
        shim = _SummaryRefGame(game)

        def round_body(carry, scan_in):
            gamma, ridx = scan_in
            x_sync, key, s, ws = carry
            key, sub = jax.random.split(key)
            player_keys = jax.random.split(sub, n)
            if selection:
                # selection composes with sampled interaction only
                # (check_summary_view): participants refresh their block,
                # absentees stay stale in the live carry the sampled
                # reads index — no population statistic is falsified
                s, m = sync.select(s, n, ridx, None)
            else:
                s, ctx = sync.pre_round(s)
                del ctx   # legacy mask strategies are rejected here

            if view.sample is None:
                pop = game.population_summary(x_sync, moments)
                pop_wire, ws = summary_wire(sync, pop, ws)

                def local(i, pkey, g_i):
                    own = x_sync[i]
                    if view.self_correction:
                        # exact leave-one-out moments from the population
                        # moments and the player's own contribution
                        own_pows = jnp.stack(
                            [own ** (p + 1) for p in range(moments)])
                        summary = (n * pop_wire - own_pows) / (n - 1)
                    else:
                        summary = pop_wire
                    return tau_local_steps(i, pkey, own, (own, summary),
                                           g_i, shim)
            else:
                # per-round resampled neighbor subsets from one fold-in key
                # hierarchy (seed -> round -> player): reproducible without
                # replaying earlier rounds, independent of the sampling-
                # noise chain. Offsets in [1, n-1] exclude the reader, so
                # the leave-one-out correction is built in.
                round_key = jax.random.fold_in(
                    jax.random.PRNGKey(view.seed), ridx)

                def local(i, pkey, g_i):
                    own = x_sync[i]
                    k_i = jax.random.fold_in(round_key, i)
                    offs = jax.random.randint(k_i, (view.sample,), 1, n)
                    nbrs = x_sync[jnp.mod(i + offs, n)]
                    summary = jnp.stack(
                        [jnp.mean(nbrs ** (p + 1), axis=0)
                         for p in range(moments)])
                    # per-player summaries have no single wire tensor, so
                    # only stateless compression composes (EF is rejected)
                    summary = sync.compress(summary).astype(summary.dtype)
                    return tau_local_steps(i, pkey, own, (own, summary),
                                           g_i, shim)

            x_prop = vmap_players(local, player_keys, gamma)
            if selection:
                x_next = jnp.where(m[:, None], x_prop, x_sync)
                participants = jnp.sum(m).astype(jnp.int32)
                s = sync.observe(s, m, x_prop - x_sync, ridx)
            else:
                x_next = x_prop
                participants = jnp.asarray(n, jnp.int32)
            res = jnp.sqrt(jnp.sum(game.operator_via_summary(x_next) ** 2))
            return (x_next, key, s, ws), (x_next, res, participants,
                                          participants)

        init = (x0, key,
                sync.select_state(n) if selection else sync.init_state(),
                sync.init_wire_state(game.population_summary(x0, moments)))
    elif topology.is_server:
        def round_body(carry, scan_in):
            gamma, ridx = scan_in
            x_sync, key, s, ws = carry
            key, sub = jax.random.split(key)
            player_keys = jax.random.split(sub, n)
            if selection:
                # stateful selection: the mask comes from the policy's
                # carried value estimates (PAST rounds only — no peeking at
                # this round's deltas), not from a pre_round key draw
                s, m = sync.select(s, n, ridx, None)
                ctx = ()
            else:
                s, ctx = sync.pre_round(s)

            if sync.has_wire_state:
                # Error feedback: ONE transmit tensor per round — the
                # iterates plus the carried residual. Receivers decode its
                # deterministic wire round-trip (host) or the bit-pattern
                # collective's output (mesh; identical values, asserted in
                # tests), and the residual banks what the wire dropped.
                t = sync.pre_wire(x_sync, ws)
                if mesh is None:
                    x_wire = sync.roundtrip(t)
                else:
                    x_wire = collective.sharded_joint_wire(
                        t, mesh=mesh, sync=sync, axis_name=mesh_axis)
                ws = sync.post_wire(t, ws)
            elif mesh is not None:
                # Explicit wire: every block crosses the player axis once at
                # the strategy's wire dtype (bit-pattern collective); each
                # player restores its own row exact on top — the
                # QuantizedSync.view semantics, now HLO-verifiable.
                x_wire = collective.sharded_joint_wire(
                    x_sync, mesh=mesh, sync=sync, axis_name=mesh_axis)

            def local(i, pkey, g_i):
                if mesh is None and not sync.has_wire_state:
                    x_ref = sync.view(i, x_sync, ctx)
                else:
                    x_ref = x_wire.at[i].set(x_sync[i])
                return tau_local_steps(i, pkey, x_sync[i], x_ref, g_i)

            x_prop = vmap_players(local, player_keys, gamma)
            if not selection:
                m = sync.mask(n, ctx)
            if m is None:
                x_next = x_prop
                participants = jnp.asarray(n, jnp.int32)
            else:
                x_next = jnp.where(m[:, None], x_prop, x_sync)
                participants = jnp.sum(m).astype(jnp.int32)
            if selection:
                s = sync.observe(s, m, x_prop - x_sync, ridx)
            res = jnp.sqrt(jnp.sum(game.operator(x_next) ** 2))
            return (x_next, key, s, ws), (x_next, res, participants,
                                          participants)

        # legacy strategies carry an empty wire-state pytree: zero ops, so
        # the compiled program (and every bit-for-bit pin) is unchanged
        init = (x0, key,
                sync.select_state(n) if selection else sync.init_state(),
                sync.init_wire_state(x0))
    else:
        # Server-free gossip: each player carries a VIEW of the whole joint
        # action (the decentralized-VI formulation — node i evaluates only
        # its own operator block but holds a full variable copy). Each round:
        # tau local steps on the own block against the own view, then one
        # neighbor-averaging exchange V_i <- sum_j W~_ij wire(V_j) where W~
        # renormalizes around non-participating links (lost mass goes to the
        # diagonal, preserving row-stochasticity) and ``wire`` is the sync
        # strategy's compression. Own blocks are anchored: mixing updates
        # player i's estimates of OTHERS, never its decision variable.
        W_stack = jnp.asarray(topology.mixing_stack(n), dtype=x0.dtype)
        A_stack = jnp.asarray(topology.adjacency_stack(n), dtype=bool)
        T = W_stack.shape[0]
        diag = jnp.arange(n)
        # Static circulant decomposition for the mesh-lowered relay: one
        # collective_permute per neighbor offset (ring/rotation-invariant
        # graphs, single static member); otherwise the all-gather relay.
        mesh_offsets = (collective.circulant_offsets(topology.adjacency(n))
                        if mesh is not None and T == 1 else None)

        def mix_views(V_in, x_anchor, link_w, self_w):
            """``gossip_steps`` anchored consensus sweeps over the views.

            Own blocks are anchored before AND after every sweep: mixing
            refreshes player i's estimates of OTHERS, never its decision
            variable."""
            V_m = V_in.at[diag, diag].set(x_anchor)
            for _ in range(gossip_steps):
                if mesh is None:
                    wire = sync.compress(V_m).astype(V_m.dtype)
                    V_m = (jnp.einsum("ij,jkd->ikd", link_w, wire)
                           + self_w[:, None, None] * V_m)
                else:
                    V_m = collective.sharded_mix_sweep(
                        V_m, link_w, self_w, mesh=mesh, sync=sync,
                        axis_name=mesh_axis, offsets=mesh_offsets)
                V_m = V_m.at[diag, diag].set(x_anchor)
            return V_m

        if isinstance(update, DecentralizedExtragradientUpdate):
            # Two-phase extragradient round: extrapolate -> mix -> correct
            # -> mix. Full participation only (checked upstream), so the
            # link weights are the plain Metropolis rows.
            def round_body(carry, scan_in):
                gamma, ridx = scan_in
                V, x_sync, key, s = carry
                key, sub = jax.random.split(key)
                phase_keys = jax.random.split(sub, 2)
                half_keys = jax.random.split(phase_keys[0], n)
                full_keys = jax.random.split(phase_keys[1], n)
                s, ctx = sync.pre_round(s)
                del ctx   # mask strategies are rejected for this update
                W = W_stack[ridx % T]
                A = A_stack[ridx % T]
                link_w = jnp.where(A, W, 0.0)
                self_w = 1.0 - jnp.sum(link_w, axis=1)

                def half(i, pkey, g_i):
                    return tau_local_steps(i, pkey, x_sync[i], V[i], g_i)

                x_half = vmap_players(half, half_keys, gamma)
                V_half = mix_views(V, x_half, link_w, self_w)

                def correct(i, pkey, g_i):
                    # extragradient restart: the correction phase re-runs
                    # from x_i, not from the half-point, against the
                    # extrapolated neighborhood view
                    return tau_local_steps(i, pkey, x_sync[i], V_half[i], g_i)

                x_next = vmap_players(correct, full_keys, gamma)
                V_next = mix_views(V_half, x_next, link_w, self_w)
                participants = jnp.asarray(n, jnp.int32)
                links = (2 * gossip_steps
                         * jnp.sum(A.astype(jnp.int32)))
                res = jnp.sqrt(jnp.sum(game.operator(x_next) ** 2))
                return (V_next, x_next, key, s), (x_next, res, participants,
                                                  links)
        else:
            def round_body(carry, scan_in):
                gamma, ridx = scan_in
                V, x_sync, key, s = carry
                key, sub = jax.random.split(key)
                player_keys = jax.random.split(sub, n)
                s, ctx = sync.pre_round(s)
                W = W_stack[ridx % T]
                A = A_stack[ridx % T]

                def local(i, pkey, g_i):
                    return tau_local_steps(i, pkey, x_sync[i], V[i], g_i)

                x_prop = vmap_players(local, player_keys, gamma)
                m = sync.mask(n, ctx)
                if m is None:
                    mf = jnp.ones((n,), dtype=W.dtype)
                    x_used = x_prop
                    participants = jnp.asarray(n, jnp.int32)
                else:
                    mf = m.astype(W.dtype)
                    x_used = jnp.where(m[:, None], x_prop, x_sync)
                    participants = jnp.sum(m).astype(jnp.int32)

                pair = mf[:, None] * mf[None, :]
                link_w = jnp.where(A, W * pair, 0.0)      # active off-diag
                self_w = 1.0 - jnp.sum(link_w, axis=1)    # lost mass -> diag
                # gossip_steps > 1 trades extra wire sweeps for tighter view
                # consensus — strongly-coupled games need it for stability at
                # the Theorem 3.4 step size (see tests/test_topology.py).
                V_next = mix_views(V, x_used, link_w, self_w)
                links = gossip_steps * jnp.sum(
                    (A & (pair > 0)).astype(jnp.int32))
                res = jnp.sqrt(jnp.sum(game.operator(x_used) ** 2))
                return (V_next, x_used, key, s), (x_used, res, participants,
                                                  links)

        V0 = jnp.broadcast_to(x0[None], (n, *x0.shape))
        init = (V0, x0, key, sync.init_state())

    gossip = not (isinstance(update, JointUpdate) or topology.is_server)
    scan_in = (gammas, jnp.arange(gammas.shape[0]))
    if record_trajectory:
        scan_body = round_body
    else:
        # identical carried computation; the scan EMITS the per-round
        # squared error scalar instead of stacking the (n, d) iterate
        def scan_body(carry, scan_in_r):
            carry, (x_r, res, p, l) = round_body(carry, scan_in_r)
            return carry, (jnp.sum((x_r - x_star) ** 2), res, p, l)
    carry, (ys, residuals, participants, links) = jax.lax.scan(
        scan_body, init, scan_in
    )
    x_final = carry[1] if gossip else carry[0]
    return x_final, ys, residuals, participants, links


@dataclasses.dataclass(frozen=True)
class PearlEngine:
    """Composable PEARL loop: ``update`` x ``sync`` x ``topology`` x schedule.

    Every algorithm in :mod:`repro.core.pearl` and
    :mod:`repro.core.baselines` is a ~5-line adapter over this class; new
    variants (compressed sync, partial participation, momentum locals,
    gossip graphs) are constructor arguments, not new scan loops. The default
    :class:`~repro.core.topology.Star` topology reproduces the PR 1 engine
    bit-for-bit; graph topologies run the server-free neighbor-averaging
    path and compose with any (compression x participation) strategy. Joint
    baselines read fresh iterates mid-round and therefore require the star.

    A ``mesh`` (1-D over ``mesh_axis``, see
    :func:`repro.core.collective.player_mesh`) lowers every synchronization
    exchange to explicit shard_map collectives whose operand dtype IS the
    sync strategy's wire dtype — the compressed wire provably survives
    compilation instead of being billed on faith. Full-participation
    strategies only: a participation mask is host-loop semantics (who moved
    nothing must be billed nothing), so ``mesh`` x mask strategies are
    rejected rather than compiling a full exchange the accounting would
    contradict. ``mesh=None`` (default) compiles the identical legacy
    program.
    """

    update: PlayerUpdate | JointUpdate = SgdUpdate()
    sync: SyncStrategy = ExactSync()
    topology: Topology = Star()
    gossip_steps: int = 1   # mixing sweeps per round on graph topologies
    policy: StepsizePolicy | str | None = None   # None = Theorem34Policy()
    mesh: Any = None        # jax.sharding.Mesh with the player axis, or None
    mesh_axis: str = "players"
    #: reference axis (JointView). None = the topology decides (StarView
    #: under a server, GossipView on a graph — the legacy programs,
    #: bit-for-bit). MeanFieldView runs the O(d) summary path.
    view: JointView | None = None
    #: optional EngineSpec bundling the axes above; axes the spec sets
    #: overwrite the defaults (setting an axis both ways is rejected —
    #: see repro.core.spec).
    spec: EngineSpec | None = None

    def __post_init__(self):
        apply_spec(self)

    def _resolved_policy(self) -> StepsizePolicy:
        return resolve_policy(self.policy)

    def _context_for(self, policy: StepsizePolicy, game: VectorGame,
                     tau: int) -> RoundContext | None:
        """Round context for the scan — ``None`` for the identity policy.

        The context is a STATIC jit argument carrying game-derived floats
        (coupling, spectral gap), so building it for the identity policy —
        which ignores it — would needlessly retrace the scan for every
        distinct game instance of the same shape."""
        if isinstance(policy, Theorem34Policy):
            return None
        return build_round_context(game, self.topology, tau=tau)

    def _check_topology(self, game: VectorGame | None = None) -> JointView:
        # delegate to THE compatibility matrix (repro.core.spec): every
        # composition rejection for this engine is raised there.
        return validate_spec(
            EngineSpec(
                update=self.update, sync=self.sync, topology=self.topology,
                gossip_steps=self.gossip_steps,
                policy=self._resolved_policy(), view=self.view,
                mesh=self.mesh, mesh_axis=self.mesh_axis,
            ),
            game=game,
        )

    def run(
        self,
        game: VectorGame,
        x0: Array,
        *,
        rounds: int,
        tau: int = 1,
        gamma,
        key: Array | None = None,
        stochastic: bool = True,
        x_star: Array | None = None,
        record_trajectory: bool = False,
    ) -> PearlResult:
        """Run ``rounds`` synchronization rounds and record diagnostics.

        Args:
          game:       the n-player game.
          x0:         initial joint action, shape ``(n, d)``.
          rounds:     number of communication rounds ``R``.
          tau:        local steps per round (ignored by joint updates, which
                      define their own within-round structure).
          gamma:      scalar, per-round ``(rounds,)`` array, or callable
                      ``rounds -> array`` (schedule).
          key:        PRNG key (drives sampling noise; strategy randomness is
                      seeded independently by the strategy itself).
          stochastic: use the players' stochastic oracles or full gradients.
          x_star:     equilibrium for error tracking; defaults to
                      ``game.equilibrium()``.
          record_trajectory: materialize the full ``(rounds, n, d)``
                      trajectory on :attr:`PearlResult.xs` (the legacy
                      behavior, bit-for-bit pinned). The default carries
                      only O(rounds) error scalars through the scan — the
                      memory shape that survives million-player runs.
        """
        if key is None:
            key = jax.random.PRNGKey(0)
        if x_star is None:
            x_star = game.equilibrium()
        view = self._check_topology(game)
        validate_round_args(tau, rounds)
        gammas = as_round_gammas(gamma, rounds)
        policy = self._resolved_policy()
        x_final, ys, residuals, participants, links = _engine_scan(
            game, x0, gammas, key,
            update=self.update, sync=self.sync, topology=self.topology,
            tau=tau, stochastic=stochastic, gossip_steps=self.gossip_steps,
            policy=policy, ss_ctx=self._context_for(policy, game, tau),
            mesh=self.mesh, mesh_axis=self.mesh_axis, view=view,
            record_trajectory=record_trajectory,
            x_star=None if record_trajectory else x_star,
        )
        if view.summary_based:
            res0 = jnp.sqrt(jnp.sum(game.operator_via_summary(x0) ** 2))
        else:
            res0 = jnp.sqrt(jnp.sum(game.operator(x0) ** 2))

        n, d = x0.shape
        bytes_up, bytes_down = account_round_bytes(
            update=self.update, sync=self.sync, topology=self.topology,
            gossip_steps=self.gossip_steps, participants=participants,
            links=links, n=n, d=d,
            base_bps=int(np.dtype(x0.dtype).itemsize), rounds=rounds,
            view=view,
        )

        if record_trajectory:
            rel_errors = relative_error_curve(x0, x_star, ys)
        else:
            rel_errors = relative_error_curve_from_sq(x0, x_star, ys)
        return PearlResult(
            x_final=x_final,
            rel_errors=rel_errors,
            residuals=np.concatenate([[float(res0)], np.asarray(residuals)]),
            tau=1 if isinstance(self.update, JointUpdate) else tau,
            rounds=rounds,
            bytes_up=bytes_up,
            bytes_down=bytes_down,
            xs=ys if record_trajectory else None,
        )

    def trajectory(
        self,
        game: VectorGame,
        x0: Array,
        *,
        rounds: int,
        tau: int = 1,
        gamma,
        key: Array | None = None,
        stochastic: bool = True,
    ) -> Array:
        """Raw per-round iterates ``(rounds, n, d)`` — no equilibrium needed.

        For runs where :meth:`run`'s error tracking does not apply (e.g. the
        Section B divergence demonstration, where no equilibrium is reached).
        """
        if key is None:
            key = jax.random.PRNGKey(0)
        view = self._check_topology(game)
        validate_round_args(tau, rounds)
        gammas = as_round_gammas(gamma, rounds)
        policy = self._resolved_policy()
        _, xs, _, _, _ = _engine_scan(
            game, x0, gammas, key,
            update=self.update, sync=self.sync, topology=self.topology,
            tau=tau, stochastic=stochastic, gossip_steps=self.gossip_steps,
            policy=policy, ss_ctx=self._context_for(policy, game, tau),
            mesh=self.mesh, mesh_axis=self.mesh_axis, view=view,
            record_trajectory=True,
        )
        return xs


# =========================================================================
# Generic federated-round scaffold (shared with the neural trainer)
# =========================================================================
def make_federated_round(
    local_step: Callable,
    collect: Callable,
    *,
    unroll: bool = False,
    broadcast_in_axes=None,
) -> Callable:
    """The PEARL round template over arbitrary per-player state pytrees.

    ``local_step(carry_i, batch, broadcast) -> (carry_i, metrics)`` is one
    local optimization step of a single player; ``collect(stacked_carry)``
    is the synchronization collective (e.g. the across-player parameter
    mean). The returned ``round_fn(stacked_carry, stacked_batches,
    broadcast)`` scans ``tau`` local steps per player (leading batch axis),
    vmaps over players, then collects — the exact structure
    :func:`_engine_scan` uses for dense games, reused by
    :mod:`repro.train.pearl_trainer` for neural players where actions are
    whole parameter pytrees.

    ``broadcast_in_axes=None`` (default) replicates one broadcast to every
    player — the star server's joint snapshot. ``broadcast_in_axes=0`` maps
    over a player-stacked broadcast so each player optimizes against its OWN
    reference (per-player stale views under gossip / partial participation).
    """

    def round_fn(stacked_carry, stacked_batches, broadcast):
        def player(carry_i, batches_i, broadcast_i):
            def step(c, b):
                return local_step(c, b, broadcast_i)

            return jax.lax.scan(step, carry_i, batches_i, unroll=unroll)

        new_carry, metrics = jax.vmap(
            player, in_axes=(0, 0, broadcast_in_axes)
        )(stacked_carry, stacked_batches, broadcast)
        return new_carry, collect(new_carry), metrics

    return round_fn


# ------------------------------------------------------------------ registry
PLAYER_UPDATES: dict[str, Callable[[], PlayerUpdate]] = {
    "sgd": SgdUpdate,
    "extragradient": ExtragradientUpdate,
    "optimistic_gradient": OptimisticGradientUpdate,
    "heavy_ball": HeavyBallUpdate,
    "decentralized_eg": DecentralizedExtragradientUpdate,  # server-free only
}

SYNC_STRATEGIES: dict[str, Callable[[], SyncStrategy]] = {
    "exact": ExactSync,
    "bf16": lambda: QuantizedSync(jnp.bfloat16),
    "int8": Int8Sync,
    "int4": Int4Sync,
    "partial": PartialParticipation,
    "dropout": DropoutSync,
}

JOINT_VIEWS: dict[str, Callable[[], JointView]] = {
    "star": StarView,
    "gossip": GossipView,
    "mean_field": MeanFieldView,
}
