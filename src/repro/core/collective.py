"""Sharded collectives: an explicit, HLO-verifiable wire dtype for PEARL sync.

The engine and trainer *bill* a compressed synchronization at 2 bytes per
scalar (``QuantizedSync(jnp.bfloat16)``), but billing is accounting fiction
unless the compiled program actually moves 2-byte buffers across the player
axis. The host-path lowering cannot guarantee that: XLA owns the reduction,
and two independent compiler passes re-widen the wire —

- **reduction reassociation**: ``mean(convert_bf16(x))`` is rewritten so the
  convert feeds an f32 accumulator (the ``launch/perf.py`` negative result
  recorded in PR 1);
- **float normalization**: backends without native bf16 collectives (the CPU
  build that runs CI, via ``--xla_force_host_platform_device_count``) legalize
  *every* bf16 collective — even pure data movement like ``all-gather`` and
  ``collective-permute`` — by hoisting a ``convert`` above the op, so the
  on-wire buffer is f32 again. An ``optimization_barrier`` does not help:
  legalization is not an optimization pass.

This module lowers the synchronization explicitly under
:func:`~jax.experimental.shard_map.shard_map` on a dedicated *player* mesh
axis, and defeats both passes by shipping the quantized payload as its **bit
pattern**: ``bitcast(astype(x, bf16), uint16)``. Integer buffers are never
float-normalized and carry no accumulator to reassociate around, so the
compiled HLO provably contains a cross-player collective with a 2-byte
operand — asserted by :func:`wire_dtype_report` on the dry-run HLO text, not
trusted from byte accounting (tests/test_collective.py; the CI multi-device
job runs them on a fake 8-device mesh).

Three collectives cover the engine's and trainer's communication regimes:

- :func:`sharded_tree_mean` — the star mean over player-stacked pytrees (the
  trainer's ``tree_mean``): quantize → all-gather bits → dequantize → local
  mean. Gathering and then reducing locally (instead of ``psum``) is what
  keeps the wire honest: an all-reduce owns its accumulator and is legalized
  to f32 on CPU, while the gather moves exactly the wire representation and
  leaves the f32 reduction *after* the wire. It also makes the ``ExactSync``
  path **bit-for-bit** with the host ``jnp.mean``: every device reduces the
  same gathered buffer in the same order.
- :func:`sharded_joint_wire` — the engine's star broadcast: each player's
  block crosses the wire once at the wire dtype; every player gets the joint
  snapshot back (own row restored exact by the caller, preserving
  ``QuantizedSync.view`` semantics).
- :func:`sharded_mix_sweep` — one Metropolis gossip sweep. Circulant graphs
  with one player per device (ring, and any topology whose adjacency depends
  only on ``(j - i) mod n``) lower each neighbor offset to a
  ``collective_permute`` of the wire bits — a player receives ``deg(i)``
  view relays per sweep, matching the edge-aware byte accounting; general /
  time-varying graphs fall back to the all-gather relay with the mixing row
  applied locally.

**Pin discipline**: nothing here touches the no-mesh path. ``mesh=None``
callers branch at trace time and compile the identical legacy program
(tests pin that the host ``tree_mean`` lowering contains no collectives at
all); the sharded path is a new program, compared against the host path by
value (exact in f32, bounded quantization noise in bf16).
"""

from __future__ import annotations

import dataclasses
import functools
import re
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

try:  # jax >= 0.6 re-exports shard_map at the top level
    from jax import shard_map as _shard_map  # type: ignore[attr-defined]
except ImportError:  # the pinned 0.4.x toolchain
    from jax.experimental.shard_map import shard_map as _shard_map

Array = jax.Array

#: Default mesh-axis name for the per-player dimension. Production multi-pod
#: launches map players onto the ``pod`` axis instead (one player per pod);
#: every entry point takes ``axis_name`` so both spellings work.
PLAYER_AXIS = "players"

# Wire-size -> integer container for the bit-pattern trick on float-quantized
# strategies. Sub-byte strategies (int8/int4 with per-block scales) bypass
# this table: they own their u8 payload layout via wire_encode/wire_decode.
_BITS_CONTAINER = {1: jnp.uint8, 2: jnp.uint16, 4: jnp.uint32, 8: jnp.uint64}


# =========================================================================
# Mesh construction / validation
# =========================================================================
def player_mesh(n_players: int, *, axis_name: str = PLAYER_AXIS,
                devices=None) -> Mesh:
    """A 1-D mesh over the player axis, sized to the available devices.

    Uses the largest divisor of ``n_players`` that fits the device count, so
    every device holds the same number of player blocks (``shard_map``
    requires even sharding). Raises when only the trivial 1-device "mesh"
    would fit a multi-player run — a collective layer with no wire would make
    every HLO-level claim vacuous; CI and local development get real fake
    devices via ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
    """
    if n_players < 1:
        raise ValueError(f"n_players must be >= 1, got {n_players}")
    devs = list(jax.devices() if devices is None else devices)
    size = max(k for k in range(1, min(n_players, len(devs)) + 1)
               if n_players % k == 0)
    if size == 1 and n_players > 1:
        raise ValueError(
            f"cannot build a multi-device player mesh for n_players="
            f"{n_players} from {len(devs)} device(s): no divisor of "
            f"{n_players} >= 2 fits. Run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=8 (the CI "
            f"multi-device job's fake mesh) or on a real multi-device "
            f"backend."
        )
    return Mesh(np.array(devs[:size]), (axis_name,))


def _axis_size(mesh: Mesh, axis_name: str) -> int:
    if axis_name not in mesh.axis_names:
        raise ValueError(
            f"mesh {mesh.axis_names} has no axis {axis_name!r}; pass the "
            f"axis carrying the player dimension (axis_name=...)"
        )
    return mesh.shape[axis_name]


def _validate_players(n: int, mesh: Mesh, axis_name: str) -> None:
    size = _axis_size(mesh, axis_name)
    if n % size:
        raise ValueError(
            f"player dimension {n} does not divide evenly over mesh axis "
            f"{axis_name!r} of size {size}; use player_mesh(n) to size the "
            f"mesh to a divisor"
        )


# =========================================================================
# Wire representation: the bit-pattern trick
# =========================================================================
def wire_spec(sync) -> "WireSpec | LowBitCodec | None":
    """The on-wire codec for a sync strategy's compression.

    ``None`` means the strategy transmits at the carrier dtype (f32) and no
    bitcast is needed. Float-quantized strategies ship ``astype(wire_dtype)``
    reinterpreted as ``uint<8*itemsize>`` so no backend pass can re-widen the
    buffer (see module docstring) — unless the backend natively moves that
    dtype across collectives (:func:`native_collective_dtype`, TPU bf16), in
    which case the bitcast round-trip is skipped and the HLO operand-dtype
    assertion stays the gate. Sub-byte strategies (``Int8Sync``/``Int4Sync``)
    own their wire layout: :class:`LowBitCodec` delegates to the strategy's
    ``wire_encode``/``wire_decode``, which emit ONE u8 payload per block with
    the f32 scale bitcast into its leading bytes — so the dry-run HLO of a
    low-bit sync shows a single u8 collective operand, no f32 side channel.
    """
    if hasattr(sync, "wire_encode"):
        return LowBitCodec(sync)
    wire_itemsize = int(sync.wire_itemsize(4))
    if wire_itemsize >= 4:
        return None
    dtype = getattr(sync, "dtype", None)
    if dtype is None:
        raise ValueError(
            f"{type(sync).__name__} reports a {wire_itemsize}-byte wire but "
            f"carries no wire dtype to quantize to"
        )
    if np.dtype(dtype).itemsize not in _BITS_CONTAINER:
        raise ValueError(f"unsupported wire itemsize for dtype {dtype}")
    if native_collective_dtype(jnp.dtype(dtype).name):
        return WireSpec(dtype=dtype, container=None)
    return WireSpec(dtype=dtype,
                    container=_BITS_CONTAINER[np.dtype(dtype).itemsize])


@dataclasses.dataclass(frozen=True)
class WireSpec:
    dtype: Any        # quantization dtype (e.g. bfloat16)
    container: Any    # integer container on the wire (uint16); None = native

    def encode(self, x: Array) -> Array:
        if self.container is None:
            return x.astype(self.dtype)
        return jax.lax.bitcast_convert_type(x.astype(self.dtype),
                                            self.container)

    def decode(self, bits: Array, carrier_dtype) -> Array:
        if self.container is None:
            return bits.astype(carrier_dtype)
        return jax.lax.bitcast_convert_type(bits, self.dtype).astype(
            carrier_dtype)


@dataclasses.dataclass(frozen=True)
class LowBitCodec:
    """Adapter giving a low-bit sync strategy the WireSpec encode/decode
    surface. The strategy owns the payload layout (scale bytes + packed
    lanes); values produced by ``decode(encode(x))`` are bit-identical to the
    strategy's host-path ``roundtrip(x)`` — the mesh/host parity contract."""

    sync: Any

    def encode(self, x: Array) -> Array:
        return self.sync.wire_encode(x)

    def decode(self, payload: Array, carrier_dtype) -> Array:
        return self.sync.wire_decode(payload, carrier_dtype)


@functools.lru_cache(maxsize=None)
def _native_collective_dtype(platform: str, dtype_name: str) -> bool:
    """Whether ``platform`` moves ``dtype_name`` collectives natively.

    Probes by compiling a tiny two-device shard_map all-gather and reading
    the optimized HLO's collective *operand* dtype — the same assertion
    surface every other wire claim uses, so the fallback can never silently
    re-widen: if legalization hoists a convert above the gather (CPU float
    normalization), the operand reads f32 and the probe says False.
    """
    del platform   # cache key only; jax.devices() already reflects it
    devs = jax.devices()
    if len(devs) < 2:
        return False   # no wire to probe; the bitcast path is always correct
    probe_mesh = Mesh(np.array(devs[:2]), (PLAYER_AXIS,))

    def gather(x):
        return jax.lax.all_gather(x, PLAYER_AXIS, axis=0, tiled=True)

    fn = _shard_map(gather, mesh=probe_mesh, in_specs=(P(PLAYER_AXIS),),
                    out_specs=P(), check_rep=False)
    x = jax.ShapeDtypeStruct((2, 8), jnp.dtype(dtype_name))
    hlo = jax.jit(fn).lower(x).compile().as_text()
    return any(o.op == "all-gather" and o.operand_dtype == _HLO_DTYPE_NAMES.get(
        dtype_name, dtype_name) for o in wire_dtype_report(hlo))


def native_collective_dtype(dtype_name: str) -> bool:
    """Public probe: True iff the current backend's compiled all-gather keeps
    a ``dtype_name`` operand on the wire (TPU bf16; False on the CPU build,
    whose float normalization legalizes every sub-f32 float collective)."""
    return _native_collective_dtype(jax.default_backend(), dtype_name)


#: numpy dtype name -> HLO element-type spelling, for the probe's assertion.
_HLO_DTYPE_NAMES = {"bfloat16": "bf16", "float16": "f16", "float32": "f32"}


def _reject_mask(sync, what: str) -> None:
    if sync.uses_mask:
        raise ValueError(
            f"{what} is a full-participation collective; "
            f"{type(sync).__name__} draws a participation mask and needs the "
            f"host-side stale-block merge round"
        )


# =========================================================================
# Star collectives
# =========================================================================
def sharded_tree_mean(stacked, *, mesh: Mesh, sync=None, sync_dtype=None,
                      axis_name: str = PLAYER_AXIS, inner_specs=None):
    """Across-player mean of a player-stacked pytree with an explicit wire.

    The mesh-lowered counterpart of :func:`repro.train.pearl_trainer.tree_mean`
    (which dispatches here when given a mesh). Each leaf ``(n, ...)`` is
    sharded over ``axis_name``; inside ``shard_map`` every device encodes its
    local player blocks at the wire dtype, all-gathers the *bits*, decodes,
    and reduces locally in f32. ``inner_specs`` optionally gives the per-leaf
    :class:`~jax.sharding.PartitionSpec` of the non-player dims (the
    production launcher passes its tensor-parallel specs so the gather
    crosses only the player/pod axis); default replicated.
    """
    from repro.core.engine import resolve_sync

    strategy = resolve_sync(sync, sync_dtype)
    _reject_mask(strategy, "sharded_tree_mean")
    wire = wire_spec(strategy)
    leaves = jax.tree.leaves(stacked)
    if not leaves:
        return stacked
    n = leaves[0].shape[0]
    _validate_players(n, mesh, axis_name)

    def body(tree):
        def mean(xl):
            if wire is None:
                allv = jax.lax.all_gather(xl, axis_name, axis=0, tiled=True)
                return jnp.mean(allv, axis=0, dtype=jnp.float32)
            bits = jax.lax.all_gather(wire.encode(xl), axis_name, axis=0,
                                      tiled=True)
            vals = wire.decode(bits, jnp.float32)
            return jnp.mean(vals, axis=0).astype(jnp.float32)

        return jax.tree.map(mean, tree)

    if inner_specs is None:
        in_specs = jax.tree.map(lambda _: P(axis_name), stacked)
        out_specs = jax.tree.map(lambda _: P(), stacked)
    else:
        in_specs = jax.tree.map(lambda s: P(axis_name, *s), inner_specs)
        out_specs = jax.tree.map(lambda s: P(*s), inner_specs)
    return _shard_map(body, mesh=mesh, in_specs=(in_specs,),
                      out_specs=out_specs, check_rep=False)(stacked)


def sharded_joint_wire(x: Array, *, mesh: Mesh, sync,
                       axis_name: str = PLAYER_AXIS) -> Array:
    """The engine's star broadcast: gather every player's block over the wire.

    ``x`` is the joint action ``(n, d)``. Each player's block crosses the
    player axis once at the strategy's wire dtype; the result is the joint
    snapshot as every player *receives* it (quantization round-trip applied,
    replicated). Callers restore own-row exactness on top — a player never
    quantizes its own live block (``QuantizedSync.view`` semantics).
    """
    _reject_mask(sync, "sharded_joint_wire")
    wire = wire_spec(sync)
    _validate_players(x.shape[0], mesh, axis_name)

    def body(xl):
        if wire is None:
            return jax.lax.all_gather(xl, axis_name, axis=0, tiled=True)
        bits = jax.lax.all_gather(wire.encode(xl), axis_name, axis=0,
                                  tiled=True)
        return wire.decode(bits, x.dtype)

    return _shard_map(body, mesh=mesh, in_specs=(P(axis_name),),
                      out_specs=P(), check_rep=False)(x)


# =========================================================================
# The general stale-block merge (masks / graphs / delayed refs)
# =========================================================================
def masked_payload(x_local, mask_local, wire) -> Array:
    """Per-device wire payload for the stale-block merge.

    Participants' blocks cross at the wire encoding; non-participants' slots
    are **zero bits**. The SPMD gather is static-shape — a runtime mask
    cannot change how many buffers cross — so "masked players ship zero wire
    bytes" is a payload-content claim: the masked slots carry no information
    (and cost nothing under any compressing transport). Exposed so tests can
    pin the zeroed rows value-level, alongside the HLO operand-dtype
    assertion.
    """
    enc = x_local if wire is None else wire.encode(x_local)
    keep = mask_local.astype(bool).reshape(
        (-1,) + (1,) * (enc.ndim - 1))
    return jnp.where(keep, enc, jnp.zeros_like(enc))


def sharded_stale_merge(new_params, snapshot, refs, mask, mix, *,
                        mesh: Mesh, sync=None, sync_dtype=None,
                        axis_name: str = PLAYER_AXIS, inner_specs=None):
    """Mesh lowering of the trainer's general stale-block merge.

    Host-loop semantics (``repro.train.pearl_trainer.make_pearl_round``):

    - participants overwrite their snapshot block with the freshly
      compressed local params; non-participants' blocks stay stale;
    - every participant re-mixes its reference from the merged snapshot via
      its row of ``mix``; non-participants keep their stale reference.

    Per-player params/refs and the mixing rows are sharded carries on
    ``axis_name``; the snapshot and the host-drawn mask enter replicated
    (each device needs every player's stale block to apply its mixing rows).
    One all-gather moves the **participants'** freshly encoded blocks — the
    only cross-player collective in the round, at the wire dtype, with
    masked slots zeroed (:func:`masked_payload`). ``decode(encode(x))`` is
    bit-identical to the host path's ``compress(x).astype(dtype)``, so
    host/mesh trajectory differences are reduction-order only; byte
    accounting is computed host-side from the drawn masks and is untouched
    by the lowering (the PR 5 invariance rule).

    Returns ``(new_refs, new_snapshot)`` — refs sharded over ``axis_name``,
    snapshot replicated.
    """
    from repro.core.engine import resolve_sync

    strategy = resolve_sync(sync, sync_dtype)
    wire = wire_spec(strategy)
    leaves = jax.tree.leaves(new_params)
    if not leaves:
        return refs, snapshot
    n = leaves[0].shape[0]
    _validate_players(n, mesh, axis_name)
    k = n // _axis_size(mesh, axis_name)

    def body(p_l, snap_f, refs_l, mask_f, mix_l):
        me = jax.lax.axis_index(axis_name)
        mask_l = jax.lax.dynamic_slice_in_dim(mask_f, me * k, k)
        keep_f = mask_f.astype(bool)
        keep_l = mask_l.astype(bool)

        def leaf(p, snap, ref):
            payload = masked_payload(p, keep_l, wire)
            gathered = jax.lax.all_gather(payload, axis_name, axis=0,
                                          tiled=True)
            fresh = gathered if wire is None else wire.decode(gathered,
                                                              p.dtype)
            merged = jnp.where(
                keep_f.reshape((-1,) + (1,) * (snap.ndim - 1)), fresh, snap)
            mixed = jnp.einsum("ij,j...->i...", mix_l.astype(merged.dtype),
                               merged)
            new_ref = jnp.where(
                keep_l.reshape((-1,) + (1,) * (ref.ndim - 1)), mixed, ref)
            return new_ref, merged

        p_leaves, treedef = jax.tree.flatten(p_l)
        out_r, out_s = [], []
        for p, s, rf in zip(p_leaves, jax.tree.leaves(snap_f),
                            jax.tree.leaves(refs_l)):
            nr, ns = leaf(p, s, rf)
            out_r.append(nr)
            out_s.append(ns)
        return (jax.tree.unflatten(treedef, out_r),
                jax.tree.unflatten(treedef, out_s))

    if inner_specs is None:
        sharded = jax.tree.map(lambda _: P(axis_name), new_params)
        replicated = jax.tree.map(lambda _: P(), new_params)
    else:
        sharded = jax.tree.map(lambda s: P(axis_name, *s), inner_specs)
        replicated = jax.tree.map(lambda s: P(None, *s), inner_specs)
    return _shard_map(
        body, mesh=mesh,
        in_specs=(sharded, replicated, sharded, P(), P(axis_name, None)),
        out_specs=(sharded, replicated), check_rep=False,
    )(new_params, snapshot, refs, mask, mix)


# =========================================================================
# Gossip: Metropolis mixing over mesh neighbors
# =========================================================================
def circulant_offsets(adjacency: np.ndarray) -> tuple[int, ...] | None:
    """Nonzero offsets of a circulant adjacency, or None if not circulant.

    ``A`` is circulant when ``A[i, j]`` depends only on ``(j - i) mod n`` —
    the ring (offsets ±1) and any rotation-invariant graph. Circulant graphs
    lower each offset to one ``collective_permute`` over the mesh, so a
    player receives exactly ``deg`` neighbor messages per sweep.
    """
    A = np.asarray(adjacency, dtype=bool)
    n = A.shape[0]
    if n == 0:
        return ()
    base = A[0]
    for i in range(1, n):
        if not np.array_equal(A[i], np.roll(base, i)):
            return None
    return tuple(int(o) for o in np.flatnonzero(base))


def sharded_mix_sweep(V: Array, link_w: Array, self_w: Array, *, mesh: Mesh,
                      sync, axis_name: str = PLAYER_AXIS,
                      offsets: tuple[int, ...] | None = None) -> Array:
    """One Metropolis sweep ``V_i <- sum_j W~_ij wire(V_j) +
    self_w_i V_i`` with the relay crossing the mesh at the wire dtype.

    ``V`` is the stacked per-player views ``(n, n, d)``; ``link_w`` the
    (possibly participation-masked) off-diagonal mixing weights; ``self_w``
    the renormalized diagonal. Diagonal anchoring stays with the caller (the
    engine pins own blocks before and after every sweep, same as the host
    path).

    With ``offsets`` (a static circulant decomposition from
    :func:`circulant_offsets`, one player per device) each offset is one
    ``collective_permute`` of the encoded view — ``deg`` messages per player
    per sweep, the quantity :func:`repro.core.topology.gossip_round_bytes`
    bills. Otherwise every device all-gathers the encoded views and applies
    its mixing rows locally (full relay; same wire dtype guarantee).
    """
    wire = wire_spec(sync)
    n = V.shape[0]
    _validate_players(n, mesh, axis_name)
    per_dev = n // _axis_size(mesh, axis_name)
    carrier = V.dtype

    def encode(x):
        if wire is None:
            return x
        return wire.encode(x)

    def decode(bits):
        if wire is None:
            return bits
        return wire.decode(bits, carrier)

    if offsets is not None and per_dev == 1 and _axis_size(
            mesh, axis_name) == n:
        # Receiver i's in-neighbor at offset o is player (i + o) mod n
        # (adjacency row A[i, i+o]), so source s ships its view to
        # destination (s - o) mod n. Written direction-correct, this also
        # handles directed circulants, not just the symmetric graphs the
        # Metropolis topologies produce.
        perms = {o: [(s, (s - o) % n) for s in range(n)] for o in offsets}

        def body(V_l, lw_l, sw_l):
            # V_l: (1, n, d); lw_l: (1, n); sw_l: (1,)
            me = jax.lax.axis_index(axis_name)
            acc = sw_l[:, None, None] * V_l
            payload = encode(V_l)
            for o in offsets:
                recv = decode(jax.lax.ppermute(payload, axis_name, perms[o]))
                src = (me + o) % n    # who this device received from
                w = jax.lax.dynamic_index_in_dim(lw_l[0], src, keepdims=False)
                acc = acc + w * recv
            return acc

        return _shard_map(
            body, mesh=mesh,
            in_specs=(P(axis_name), P(axis_name), P(axis_name)),
            out_specs=P(axis_name), check_rep=False,
        )(V, link_w, self_w)

    def body(V_l, lw_l, sw_l):
        # V_l: (k, n, d); lw_l: (k, n); sw_l: (k,)
        allv = decode(jax.lax.all_gather(encode(V_l), axis_name, axis=0,
                                         tiled=True))
        mixed = jnp.einsum("kj,jnd->knd", lw_l.astype(carrier), allv)
        return mixed + sw_l[:, None, None] * V_l

    return _shard_map(
        body, mesh=mesh,
        in_specs=(P(axis_name), P(axis_name), P(axis_name)),
        out_specs=P(axis_name), check_rep=False,
    )(V, link_w, self_w)


# =========================================================================
# HLO-level wire verification
# =========================================================================
_COLLECTIVE_OPERAND_RE = re.compile(
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(\s*(\w+)\[([0-9,]*)\]"
)

#: dtypes whose presence as a collective operand proves a <= 2-byte wire.
COMPRESSED_WIRE_DTYPES = frozenset(
    {"bf16", "f16", "u16", "s16", "u8", "s8", "pred"})

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}


@dataclasses.dataclass(frozen=True)
class WireOp:
    op: str            # HLO collective op name
    operand_dtype: str  # first operand's element type, as spelled in HLO
    operand_bytes: int  # first operand's buffer size (per participant)


def wire_dtype_report(hlo_text: str) -> list[WireOp]:
    """Every collective in optimized HLO text with its operand dtype.

    This is the assertion surface for the explicit-wire claim: the dry-run
    HLO of a quantized sharded sync must contain a cross-player collective
    whose *operand* is a 2-byte type, and the exact-sync lowering must not.
    Reads the operand (what goes on the wire), not the result — an all-gather
    result is just the concatenation of operands, but an all-reduce result
    hides the accumulator dtype the wire actually used.
    """
    ops = []
    for m in _COLLECTIVE_OPERAND_RE.finditer(hlo_text):
        op, dtype, dims = m.group(1), m.group(2), m.group(3)
        count = 1
        if dims:
            for d in dims.split(","):
                count *= int(d)
        ops.append(WireOp(op=op, operand_dtype=dtype,
                          operand_bytes=count * _DTYPE_BYTES.get(dtype, 0)))
    return ops


def compressed_wire_ops(hlo_text: str) -> list[WireOp]:
    """The collectives whose operand proves a compressed (< 4-byte) wire."""
    return [o for o in wire_dtype_report(hlo_text)
            if o.operand_dtype in COMPRESSED_WIRE_DTYPES]


def assert_wire_dtype(hlo_text: str, *, compressed: bool) -> list[WireOp]:
    """Raise unless the HLO's collectives match the claimed wire.

    ``compressed=True`` demands at least one collective with a <= 2-byte
    operand; ``compressed=False`` demands that *no* collective carries one
    (the f32 path must not accidentally quantize). Returns the report for
    logging. Used by tests and by ``benchmarks.bench_collective``.
    """
    report = wire_dtype_report(hlo_text)
    small = [o for o in report if o.operand_dtype in COMPRESSED_WIRE_DTYPES]
    if compressed and not small:
        raise AssertionError(
            f"expected a compressed-wire collective in the HLO, found only: "
            f"{report or 'no collectives at all'}"
        )
    if not compressed and small:
        raise AssertionError(
            f"exact-sync lowering unexpectedly moved compressed buffers: "
            f"{small}"
        )
    return report
