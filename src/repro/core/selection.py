"""Value-driven participation: stateful selection policies on the mask axis.

Every mask strategy the engines shipped so far is value-blind — uniform
partial participation and Bernoulli dropout draw who talks without looking
at what anyone contributed. This module adds the selection-policy layer
(ROADMAP item 4, the GreedyFed direction): a :class:`SelectionPolicy` is a
:class:`~repro.core.engine.SyncStrategy` whose per-round participation mask
is chosen from OBSERVED round context — the deltas players shipped in past
rounds, visit counts, the round index, and (in the async engine) the drawn
per-player staleness row — instead of a coin flip.

Protocol (three methods on top of the SyncStrategy surface):

- ``select_state(n)``   — the policy's state pytree (value estimates,
  visit counts, and for the uniform policy the PRNG chain). Rides the
  engines' rounds-scan carry in the slot the legacy strategies use for
  their key chain; host numpy in the trainer's event loop.
- ``select(state, n, ridx, delay_row)`` → ``(state, mask)`` — the round's
  ``(n,)`` boolean participation mask, computed from PAST observations
  only (the mask must not peek at the current round's deltas: selection
  happens before anyone computes). ``delay_row`` is the async engine's
  realized per-player staleness for the round, ``None`` under lockstep.
- ``observe(state, mask, delta, ridx)`` — fold the round's arriving
  player deltas (``(n, d)`` rows, non-participants zeroed by the mask)
  into the value estimates.

Engines dispatch on the ``stateful_selection`` class flag at trace time,
so the compiled program of every legacy strategy is untouched; the legacy
``pre_round``/``mask`` surface raises loudly here instead of silently
running a value-blind draw.

The value estimate is a GTG-Shapley-style marginal-progress score
(GreedyFed; see SNIPPETS.md snippet 1 and docs/THEORY.md for the honest
caveat): for the round's coalition-progress game

    v(S) = || sum_{i in S} delta_i ||^2

the Shapley value has a CLOSED FORM — ``v(S ∪ {i}) − v(S) = ||δ_i||² +
2 Σ_{j∈S} δ_i·δ_j`` and each opponent precedes ``i`` in half of the
orderings, so

    φ_i = ||δ_i||² + Σ_{j≠i} δ_i·δ_j = δ_i · Δ,   Δ = Σ_j δ_j,

with efficiency ``Σ_i φ_i = v(N)`` for free. No permutation sampling: the
estimate is exactly permutation-invariant in the arriving deltas (a
property test pins this). Outside cooperative-game assumptions this is a
heuristic ranking signal, not a payoff division — the equilibrium game is
not a transferable-utility coalition game.

Two design points the equilibrium setting forces (both found the hard way;
the failure modes are in docs/THEORY.md):

- **Values are RAW magnitudes, normalized at select time.** The EWM keeps
  the unnormalized Shapley progress, so a player far from equilibrium
  (huge deltas) outranks a converged one — that magnitude gap IS the
  allocation signal. Normalizing per round (each round's scores summing
  to 1) erases it: after warm-up every participant looks equally
  valuable and greed degenerates to round-robin. The running scale is
  divided out in :meth:`SelectionPolicy.priorities` instead (values /
  max|values|), so the knobs below are dimensionless.
- **Aging guarantees every player is re-selected.** Unlike FL — where an
  unselected client merely contributes nothing — an unselected PLAYER's
  block is frozen in the joint state, and the game cannot reach
  equilibrium until every block moves. Pure greed starves low-value
  players forever (observed: top-k locks onto one pair and the error
  plateaus at the frozen-block subgame). ``priority_i += aging · age_i``
  (``age_i`` = rounds since i last participated, normalized values ≤ 1)
  bounds any player's starvation at ~``2/aging`` rounds.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.engine import SyncStrategy

__all__ = [
    "SelectionPolicy",
    "GreedyShapley",
    "UCBSelection",
    "PowerOfChoice",
    "SampledGreedy",
    "UniformSelection",
    "SELECTION_POLICIES",
    "is_selection_policy",
    "resolve_selection",
    "validate_selection",
    "shapley_progress",
]


def is_selection_policy(sync) -> bool:
    """True when ``sync`` is a stateful selection policy (trace-time flag
    the engines and trainer branch on)."""
    return getattr(sync, "stateful_selection", False)


def shapley_progress(delta, mask):
    """Exact per-player Shapley value of the round's progress game.

    ``delta`` is the ``(n, d)`` matrix of arriving player deltas, ``mask``
    the ``(n,)`` participation mask. For ``v(S) = ||Σ_{i∈S} δ_i||²`` the
    Shapley value is ``φ_i = δ_i · Δ`` (module docstring) — a closed form
    over the SET of arriving deltas, hence permutation-invariant by
    construction, with ``Σ φ_i = v(participants)``. Non-participants ship
    nothing and score 0.
    """
    dm = jnp.where(mask[:, None], delta, 0.0)
    return dm @ jnp.sum(dm, axis=0)


def _top_k_mask(priority, n: int, k: int):
    """Boolean mask of the ``k`` highest-priority players.

    ``jax.lax.top_k`` breaks ties toward the lowest index, which makes the
    optimistic cold start (unseen players at +inf) a deterministic
    round-robin sweep before any greedy behavior kicks in."""
    _, idx = jax.lax.top_k(priority, k)
    return jnp.zeros((n,), dtype=bool).at[idx].set(True)


class SelectionPolicy(SyncStrategy):
    """Base of the selection axis; mixes into the SyncStrategy protocol.

    Subclasses are frozen hashable dataclasses (jit static args) declaring
    ``fraction`` (participation budget) and ``seed``. Value-driven policies
    select EXACTLY ``participants(n) = max(1, round(fraction·n))`` players
    per round (a fixed budget, unlike the Bernoulli draw of
    :class:`~repro.core.engine.PartialParticipation` whose fraction only
    holds in expectation); :class:`UniformSelection` keeps the Bernoulli
    draw to stay bit-for-bit with the legacy strategy.

    Selection is server-side scheduling: the server scores arriving deltas
    and decides who talks next round. Server-free gossip has no scorer, and
    the dense engines' mesh lowering compiles a full wire exchange that
    mask-aware billing would contradict — :func:`validate_selection`
    rejects both (the trainer's general merge DOES lower masked, via
    ``collective.masked_payload``; that is the one mask × mesh path).
    """

    stateful_selection = True
    uses_mask = True

    fraction: float
    seed: int

    def _validate_fraction(self):
        if not 0.0 < self.fraction <= 1.0:
            raise ValueError(
                f"{type(self).__name__}.fraction must be in (0, 1], "
                f"got {self.fraction}"
            )

    def participants(self, n: int) -> int:
        """The fixed per-round participation budget k."""
        return max(1, round(self.fraction * n))

    # --------------------------------------------------- selection protocol
    def select_state(self, n: int):
        """Value estimates, visit counts, rounds-since-selected; unseen
        players (count 0) are selected optimistically (+inf priority) so
        every player is observed once before greed takes over."""
        return {"values": jnp.zeros((n,), jnp.float32),
                "counts": jnp.zeros((n,), jnp.int32),
                "age": jnp.zeros((n,), jnp.int32)}

    def priorities(self, state):
        """Shared priority base: normalized value + aging bonus.

        Values are divided by the running max magnitude so the ``aging``
        coefficient is dimensionless (module docstring); unseen players
        rank +inf, which with ``top_k``'s lowest-index tie-break makes the
        cold start a deterministic sweep of the whole population."""
        vhat = state["values"] / (jnp.max(jnp.abs(state["values"])) + 1e-30)
        vhat = vhat + jnp.float32(self.aging) * state["age"].astype(
            jnp.float32)
        return jnp.where(state["counts"] > 0, vhat, jnp.inf)

    def select(self, state, n: int, ridx, delay_row):
        raise NotImplementedError

    def observe(self, state, mask, delta, ridx):
        """Exponentially-weighted memory over RAW Shapley progress (the
        GTG-Shapley estimator of GreedyFed): participants' values move
        toward this round's score, absentees keep theirs, and everyone's
        rounds-since-selected clock ticks."""
        del ridx
        phi = shapley_progress(delta, mask)
        beta = jnp.float32(self.memory)
        values = jnp.where(mask, beta * state["values"] + (1 - beta) * phi,
                           state["values"])
        counts = state["counts"] + mask.astype(jnp.int32)
        age = jnp.where(mask, 0, state["age"] + 1)
        return {"values": values, "counts": counts, "age": age}

    # -------------------------------------------- legacy surface: loud stop
    # Engines dispatch on ``stateful_selection`` and never touch the
    # pre_round/mask chain; any code path that still does would silently
    # run a value-blind draw, so it raises instead.
    def init_state(self):
        raise RuntimeError(
            f"{type(self).__name__} is a stateful selection policy: use "
            f"select_state(n)/select/observe (the engines dispatch on "
            f"stateful_selection), not the pre_round/mask chain"
        )

    def pre_round(self, state):
        raise RuntimeError(
            f"{type(self).__name__} draws masks via select(), not "
            f"pre_round() — this code path cannot honor stateful selection"
        )

    def mask(self, n, ctx):
        raise RuntimeError(
            f"{type(self).__name__} draws masks via select(), not "
            f"mask() — this code path cannot honor stateful selection"
        )


@dataclasses.dataclass(frozen=True)
class GreedyShapley(SelectionPolicy):
    """Greedy top-k by exponentially-weighted Shapley marginal progress.

    The GreedyFed rule: keep an EWM (``memory``) of each player's
    closed-form Shapley share of round progress (:func:`shapley_progress`)
    and pick the ``k = round(fraction·n)`` most valuable players each
    round. Unseen players rank +inf — an optimistic cold start that sweeps
    the whole population once (deterministically, lowest index first)
    before the greedy ranking takes over.

    ``staleness_penalty`` composes with the async engine: each round the
    drawn staleness row is subtracted from the priorities
    (``priority_i −= penalty · delay_i``), de-prioritizing players whose
    broadcasts arrive stale. 0.0 (default) is staleness-blind — the
    lockstep engine, which has no delay row, accepts only that value.
    """

    fraction: float = 0.5
    memory: float = 0.9
    aging: float = 0.05
    staleness_penalty: float = 0.0
    seed: int = 0
    name: str = "greedy_shapley"

    def __post_init__(self):
        self._validate_fraction()
        if not 0.0 <= self.memory < 1.0:
            raise ValueError(
                f"GreedyShapley.memory must be in [0, 1), got {self.memory}"
            )
        if self.aging < 0.0:
            raise ValueError(
                f"GreedyShapley.aging must be >= 0, got {self.aging}"
            )
        if self.staleness_penalty < 0.0:
            raise ValueError(
                f"GreedyShapley.staleness_penalty must be >= 0, "
                f"got {self.staleness_penalty}"
            )

    def select(self, state, n, ridx, delay_row):
        del ridx
        priority = self.priorities(state)
        if delay_row is not None and self.staleness_penalty > 0.0:
            priority = priority - self.staleness_penalty * jnp.asarray(
                delay_row, jnp.float32)
        return state, _top_k_mask(priority, n, self.participants(n))


@dataclasses.dataclass(frozen=True)
class UCBSelection(SelectionPolicy):
    """Bandit selection: EWM progress value plus a UCB exploration bonus.

    ``priority_i = value_i + c · sqrt(log(t + 2) / count_i)`` — the
    standard upper-confidence trade-off, so rarely-observed players keep
    being re-checked even after a bad early round (where plain greedy
    would write them off on one noisy estimate).
    """

    fraction: float = 0.5
    memory: float = 0.9
    aging: float = 0.05
    c: float = 0.5
    seed: int = 0
    name: str = "ucb"

    def __post_init__(self):
        self._validate_fraction()
        if not 0.0 <= self.memory < 1.0:
            raise ValueError(
                f"UCBSelection.memory must be in [0, 1), got {self.memory}"
            )
        if self.aging < 0.0:
            raise ValueError(
                f"UCBSelection.aging must be >= 0, got {self.aging}"
            )
        if self.c < 0.0:
            raise ValueError(f"UCBSelection.c must be >= 0, got {self.c}")

    def select(self, state, n, ridx, delay_row):
        del delay_row
        bonus = self.c * jnp.sqrt(
            jnp.log(jnp.asarray(ridx, jnp.float32) + 2.0)
            / jnp.maximum(state["counts"], 1).astype(jnp.float32))
        priority = self.priorities(state) + bonus
        return state, _top_k_mask(priority, n, self.participants(n))


@dataclasses.dataclass(frozen=True)
class PowerOfChoice(SelectionPolicy):
    """Power-of-choice: a random candidate set, then greedy within it.

    Each round a uniformly random candidate set of ``candidates`` players
    (default ``min(2k, n)``) is drawn from the per-round key
    ``fold_in(PRNGKey(seed), round)`` — the PR 7 per-``(seed, round)``
    discipline, so round r's candidate set is reproducible without
    replaying rounds 0..r−1 — and the ``k`` most valuable candidates
    participate. Interpolates uniform (candidates = k) and greedy
    (candidates = n) while keeping every player reachable every round.
    """

    fraction: float = 0.5
    memory: float = 0.9
    aging: float = 0.05
    candidates: int | None = None
    seed: int = 0
    name: str = "power_of_choice"

    def __post_init__(self):
        self._validate_fraction()
        if not 0.0 <= self.memory < 1.0:
            raise ValueError(
                f"PowerOfChoice.memory must be in [0, 1), got {self.memory}"
            )
        if self.aging < 0.0:
            raise ValueError(
                f"PowerOfChoice.aging must be >= 0, got {self.aging}"
            )
        if self.candidates is not None and self.candidates < 1:
            raise ValueError(
                f"PowerOfChoice.candidates must be >= 1, "
                f"got {self.candidates}"
            )

    def candidate_count(self, n: int) -> int:
        k = self.participants(n)
        m = 2 * k if self.candidates is None else self.candidates
        return min(max(m, k), n)

    def candidate_mask(self, n: int, ridx):
        """The round's candidate set — pure function of (seed, round)."""
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), ridx)
        perm = jax.random.permutation(key, n)
        return jnp.zeros((n,), dtype=bool).at[
            perm[: self.candidate_count(n)]].set(True)

    def select(self, state, n, ridx, delay_row):
        del delay_row
        cand = self.candidate_mask(n, ridx)
        priority = jnp.where(cand, self.priorities(state), -jnp.inf)
        return state, _top_k_mask(priority, n, self.participants(n))


@dataclasses.dataclass(frozen=True)
class SampledGreedy(SelectionPolicy):
    """Greedy selection with O(k) state — the mean-field-scale variant.

    Every other value-driven policy carries three ``(n,)`` arrays of state,
    which at mean-field scale (PR 8's million-player path, where the JOINT
    state is O(d)) would make selection the only O(n) object in the round.
    This policy tracks only ``t = min(tracked, n)`` players: a slot table
    of ``(ids, values)`` pairs plus a round-robin cursor, so the carried
    state is O(t) = O(k) regardless of the population size.

    Per round, the budget ``k = participants(n)`` splits into

    - ``e = min(k, max(1, round(explore · k)))`` **explore** slots filled by
      the cursor's round-robin sweep ``cursor, cursor+1, …  (mod n)`` —
      every player is probed once per ``n/e`` rounds, which is both the
      discovery channel and the anti-starvation guarantee (the aging bonus
      needs per-player clocks this policy refuses to carry);
    - ``k − e`` **exploit** slots holding the highest-valued tracked ids.

    The two sets can overlap, so the realized participation is AT MOST
    ``k`` — the byte bill is what the mask says, never more. ``observe``
    folds the round's Shapley progress into the tracked slots' EWMs and
    performs ONE insertion per round: the best-scoring participant not yet
    tracked evicts the worst slot iff it beats that slot's value (empty
    slots lose to everyone). One insertion, not a re-sort of the
    population — the whole update touches O(t) state.

    The O(n) arrays inside ``observe`` (the delta matrix, the scatter that
    marks tracked ids) are the round's own traffic, already materialized by
    the engine; only the CARRY shrinks to O(k).
    """

    fraction: float = 0.5
    memory: float = 0.9
    tracked: int = 16
    explore: float = 0.25
    seed: int = 0
    name: str = "sampled_greedy"

    def __post_init__(self):
        self._validate_fraction()
        if not 0.0 <= self.memory < 1.0:
            raise ValueError(
                f"SampledGreedy.memory must be in [0, 1), got {self.memory}"
            )
        if self.tracked < 1:
            raise ValueError(
                f"SampledGreedy.tracked must be >= 1, got {self.tracked}"
            )
        if not 0.0 < self.explore <= 1.0:
            raise ValueError(
                f"SampledGreedy.explore must be in (0, 1], "
                f"got {self.explore}"
            )

    def slots(self, n: int) -> int:
        return min(self.tracked, n)

    def explore_count(self, n: int) -> int:
        k = self.participants(n)
        return min(k, max(1, round(self.explore * k)))

    def select_state(self, n: int):
        t = self.slots(n)
        return {"ids": jnp.full((t,), -1, jnp.int32),
                "values": jnp.zeros((t,), jnp.float32),
                "cursor": jnp.zeros((), jnp.int32)}

    def select(self, state, n, ridx, delay_row):
        del ridx, delay_row
        k = self.participants(n)
        e = self.explore_count(n)
        explore_ids = (state["cursor"]
                       + jnp.arange(e, dtype=jnp.int32)) % n
        mask = jnp.zeros((n,), dtype=bool).at[explore_ids].set(True)
        if k - e > 0:
            slot_val = jnp.where(state["ids"] >= 0, state["values"],
                                 -jnp.inf)
            top = min(k - e, self.slots(n))
            _, sidx = jax.lax.top_k(slot_val, top)
            # empty slots scatter out of bounds and are dropped
            exploit_ids = jnp.where(state["ids"][sidx] >= 0,
                                    state["ids"][sidx], n)
            mask = mask.at[exploit_ids].set(True, mode="drop")
        state = dict(state, cursor=(state["cursor"] + e) % n)
        return state, mask

    def observe(self, state, mask, delta, ridx):
        del ridx
        n = mask.shape[0]
        phi = shapley_progress(delta, mask)
        ids, values = state["ids"], state["values"]
        beta = jnp.float32(self.memory)
        # EWM update for tracked slots whose player participated
        slot_phi = phi[jnp.clip(ids, 0, n - 1)]
        hit = (ids >= 0) & mask[jnp.clip(ids, 0, n - 1)]
        values = jnp.where(hit, beta * values + (1 - beta) * slot_phi,
                           values)
        # one insertion: best untracked participant vs the worst slot
        tracked = jnp.zeros((n,), dtype=bool).at[ids].set(
            True, mode="drop")
        cand_phi = jnp.where(mask & ~tracked, phi, -jnp.inf)
        cid = jnp.argmax(cand_phi)
        cval = cand_phi[cid]
        slot_val = jnp.where(ids >= 0, values, -jnp.inf)
        ws = jnp.argmin(slot_val)
        do = jnp.isfinite(cval) & (cval > slot_val[ws])
        ids = ids.at[ws].set(jnp.where(do, cid.astype(jnp.int32), ids[ws]))
        values = values.at[ws].set(jnp.where(do, cval, values[ws]))
        return dict(state, ids=ids, values=values)


@dataclasses.dataclass(frozen=True)
class UniformSelection(SelectionPolicy):
    """Value-blind control on the selection axis, pinned bit-for-bit to
    :class:`~repro.core.engine.PartialParticipation`.

    Same key chain (``state = PRNGKey(seed)``; per round ``state, sub =
    split(state)``; ``mask = uniform(sub, (n,)) < fraction``), so a run
    under this policy realizes the IDENTICAL masks, trajectories, and byte
    bill as the legacy strategy — the control every value-driven policy is
    benchmarked against, inside the selection API. Note the Bernoulli draw:
    the fraction holds in expectation, not per round (the legacy
    semantics), unlike the fixed top-k budget of the other policies.
    """

    fraction: float = 0.5
    seed: int = 0
    name: str = "uniform"

    def __post_init__(self):
        self._validate_fraction()

    def select_state(self, n: int):
        del n
        return jax.random.PRNGKey(self.seed)

    def select(self, state, n, ridx, delay_row):
        del ridx, delay_row
        state, sub = jax.random.split(state)
        return state, jax.random.uniform(sub, (n,)) < self.fraction

    def observe(self, state, mask, delta, ridx):
        del mask, delta, ridx
        return state


def resolve_selection(selection) -> "SelectionPolicy | None":
    """Normalize a ``selection`` argument: an instance wins, a registry
    name constructs one, ``None`` stays ``None`` (no selection axis)."""
    if selection is None or is_selection_policy(selection):
        return selection
    if isinstance(selection, str):
        # the incentive layer registers its policy on import; make the
        # registry complete for name lookups without a hard dependency
        from repro.core import incentives  # noqa: F401

        try:
            return SELECTION_POLICIES[selection]()
        except KeyError:
            raise ValueError(
                f"unknown selection policy {selection!r}; "
                f"known: {sorted(SELECTION_POLICIES)}"
            ) from None
    raise TypeError(
        f"selection must be a SelectionPolicy, registry name, or None, "
        f"got {type(selection).__name__}"
    )


def validate_selection(sync, *, server: bool, mesh,
                       topology_name: str = "Star") -> None:
    """THE shared rejection point for the selection axis (both engines and
    the trainer call it, so the wording cannot drift). No-op for
    non-selection strategies."""
    if not is_selection_policy(sync):
        return
    if not server:
        raise ValueError(
            f"{type(sync).__name__} is server-side participation "
            f"scheduling: the server scores arriving deltas and decides "
            f"who talks next round, and the {topology_name} gossip "
            f"topology has no scorer to run it — use the Star topology, "
            f"or the value-blind PartialParticipation mask on graphs"
        )
    if mesh is not None:
        raise ValueError(
            f"mesh lowering covers full-participation synchronization; "
            f"{type(sync).__name__} draws a per-round participation mask, "
            f"and compiling a full wire exchange the mask-aware byte "
            f"accounting contradicts would make the billing dishonest — "
            f"use the host path (mesh=None); the TRAINER's general merge "
            f"is the one mask-aware mesh lowering (masked_payload)"
        )


# ------------------------------------------------------------------ registry
# (repro.core.incentives appends "best_response" on import)
SELECTION_POLICIES = {
    "greedy_shapley": GreedyShapley,
    "ucb": UCBSelection,
    "power_of_choice": PowerOfChoice,
    "sampled_greedy": SampledGreedy,
    "uniform": UniformSelection,
}
