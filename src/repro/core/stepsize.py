"""Theoretical step-size rules for PEARL-SGD (Theorems 3.3/3.4/3.6, Cor 3.5).

All rules consume :class:`repro.core.game.GameConstants` and the
synchronization interval ``tau``. Rates/step-sizes follow the paper exactly:

- :func:`gamma_constant`    — Thms 3.3/3.4: ``1/(ell*tau + 2(tau-1) L_max sqrt(kappa))``.
- :func:`gamma_robot`       — Section 4.2 variant ``1/(ell*tau + L_max (tau-1) sqrt(kappa))``.
- :func:`gamma_horizon`     — Cor 3.5: ``1/(mu * eta * (1+2q))`` with
  ``T = 2 (1+2q) eta log(eta)`` solved for ``eta`` (requires ``eta > kappa*tau``).
- :func:`gamma_decreasing`  — Thm 3.6 round-indexed piecewise schedule.

Beyond-paper round schedules consumed by the engine (any callable
``rounds -> (rounds,)`` plugs into :func:`repro.core.engine.as_round_gammas`):

- :func:`gamma_warmup_cosine` — linear warmup to a peak then cosine decay,
  the standard large-batch training schedule transplanted to communication
  rounds (the paper keeps gamma constant within a round, so scheduling at
  round granularity preserves the Thm 3.6 analysis structure).
"""

from __future__ import annotations

import math

import numpy as np

from repro.core.game import GameConstants


def gamma_constant(c: GameConstants, tau: int) -> float:
    """Largest constant step-size allowed by Theorems 3.3 / 3.4."""
    if tau < 1:
        raise ValueError("tau must be >= 1")
    return 1.0 / (c.ell * tau + 2.0 * (tau - 1) * c.L_max * math.sqrt(c.kappa))


def gamma_robot(c: GameConstants, tau: int) -> float:
    """Step-size used for the Section 4.2 robot experiment."""
    return 1.0 / (c.ell * tau + c.L_max * (tau - 1) * math.sqrt(c.kappa))


def contraction_zeta(c: GameConstants, tau: int, gamma: float) -> float:
    """``zeta = 2 - gamma*ell*tau - 2(tau-1) gamma L_max sqrt(kappa/3)`` (> 0)."""
    return 2.0 - gamma * c.ell * tau - 2.0 * (tau - 1) * gamma * c.L_max * math.sqrt(
        c.kappa / 3.0
    )


def linear_rate(c: GameConstants, tau: int, gamma: float) -> float:
    """Per-round contraction factor ``1 - gamma * tau * mu * zeta`` (Thm 3.3/3.4)."""
    return 1.0 - gamma * tau * c.mu * contraction_zeta(c, tau, gamma)


def neighborhood_radius_sq(c: GameConstants, tau: int, gamma: float, sigma_sq: float) -> float:
    """Size of the Theorem 3.4 convergence neighborhood (squared distance)."""
    q = c.q
    zeta = contraction_zeta(c, tau, gamma)
    factor = 1.0 + (tau - 1) * (
        (4.0 + math.sqrt(3.0) * q) * gamma * tau * c.L_max + q / (2.0 * tau)
    )
    return factor * gamma * sigma_sq / (c.mu * zeta)


def solve_eta(c: GameConstants, T: int) -> float:
    """Solve ``T = 2 (1 + 2q) eta log(eta)`` for ``eta`` by bisection."""
    q = c.q
    target = T / (2.0 * (1.0 + 2.0 * q))

    def g(eta: float) -> float:
        return eta * math.log(eta) - target

    lo, hi = 1.0 + 1e-9, 2.0
    while g(hi) < 0:
        hi *= 2.0
    for _ in range(200):
        mid = 0.5 * (lo + hi)
        if g(mid) < 0:
            lo = mid
        else:
            hi = mid
    return 0.5 * (lo + hi)


def gamma_horizon(c: GameConstants, tau: int, T: int) -> float:
    """Corollary 3.5 horizon-dependent constant step-size.

    Raises if ``T`` is too small for the corollary's ``eta > kappa * tau``
    validity condition.
    """
    eta = solve_eta(c, T)
    if eta <= c.kappa * tau:
        raise ValueError(
            f"T={T} too small: eta={eta:.1f} must exceed kappa*tau={c.kappa * tau:.1f}"
        )
    return 1.0 / (c.mu * eta * (1.0 + 2.0 * c.q))


def gamma_decreasing(c: GameConstants, tau: int, rounds: int) -> np.ndarray:
    """Theorem 3.6 round-indexed schedule, returned as an array of length ``rounds``.

    gamma_p = 1/(ell tau (1+2q))            if p <  2 (1+2q) kappa
            = (1/(tau mu)) (2p+1)/(p+1)^2   if p >= 2 (1+2q) kappa
    """
    q = c.q
    p0 = 2.0 * (1.0 + 2.0 * q) * c.kappa
    p = np.arange(rounds, dtype=np.float64)
    warm = 1.0 / (c.ell * tau * (1.0 + 2.0 * q))
    decay = (2.0 * p + 1.0) / ((p + 1.0) ** 2) / (tau * c.mu)
    return np.where(p < p0, warm, decay)


def gamma_warmup_cosine(
    peak: float,
    rounds: int | None = None,
    *,
    warmup_frac: float = 0.1,
    final_frac: float = 0.05,
):
    """Linear warmup to ``peak`` over ``warmup_frac`` of the rounds, then
    cosine decay to ``final_frac * peak`` — per-ROUND, not per-step, so the
    step-size stays constant within each round as the paper's analysis
    assumes.

    With ``rounds`` given, returns the ``(rounds,)`` array directly; without
    it, returns a schedule callable ``rounds -> array`` that plugs straight
    into the engine's ``gamma`` argument.
    """
    if not 0.0 <= warmup_frac < 1.0:
        raise ValueError(f"warmup_frac must be in [0, 1), got {warmup_frac}")

    def build(r: int) -> np.ndarray:
        p = np.arange(r, dtype=np.float64)
        warmup = max(int(round(warmup_frac * r)), 1)
        ramp = peak * (p + 1.0) / warmup
        t = np.clip((p - warmup) / max(r - 1 - warmup, 1), 0.0, 1.0)
        floor = final_frac * peak
        cos = floor + (peak - floor) * 0.5 * (1.0 + np.cos(math.pi * t))
        return np.where(p < warmup, ramp, cos)

    return build(rounds) if rounds is not None else build
