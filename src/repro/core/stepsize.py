"""Theoretical step-size rules for PEARL-SGD (Theorems 3.3/3.4/3.6, Cor 3.5).

All rules consume :class:`repro.core.game.GameConstants` and the
synchronization interval ``tau``. Rates/step-sizes follow the paper exactly:

- :func:`gamma_constant`    — Thms 3.3/3.4: ``1/(ell*tau + 2(tau-1) L_max sqrt(kappa))``.
- :func:`gamma_robot`       — Section 4.2 variant ``1/(ell*tau + L_max (tau-1) sqrt(kappa))``.
- :func:`gamma_horizon`     — Cor 3.5: ``1/(mu * eta * (1+2q))`` with
  ``T = 2 (1+2q) eta log(eta)`` solved for ``eta`` (requires ``eta > kappa*tau``).
- :func:`gamma_decreasing`  — Thm 3.6 round-indexed piecewise schedule.

Beyond-paper round schedules consumed by the engine (any callable
``rounds -> (rounds,)`` plugs into :func:`repro.core.engine.as_round_gammas`):

- :func:`gamma_warmup_cosine` — linear warmup to a peak then cosine decay,
  the standard large-batch training schedule transplanted to communication
  rounds (the paper keeps gamma constant within a round, so scheduling at
  round granularity preserves the Thm 3.6 analysis structure).

Step-size POLICIES (:class:`StepsizePolicy`) are the second, orthogonal
layer: a round *schedule* fixes gamma as a function of the round index
alone, while a policy maps the full round context — ``tau``, the per-player
realized staleness, the topology's spectral gap, a coupling estimate — to
**per-player** step sizes inside the compiled scan. The Theorem 3.4 rule is
the identity policy (:class:`Theorem34Policy`, the default everywhere, which
by construction leaves every compiled program bit-for-bit unchanged);
:class:`DelayAdaptivePolicy` applies the asynchronous-SGD-style
``gamma ~ 1/(tau + delay)`` correction per player from the drawn staleness
table; :class:`SpectralPolicy` converts a gossip graph's mixing time into an
effective staleness and applies the same correction. Engines reject a policy
whose required context they cannot supply (see docs/ARCHITECTURE.md).
"""

from __future__ import annotations

import abc
import dataclasses
import math
from typing import Any

import numpy as np

from repro.core.game import GameConstants


def gamma_constant(c: GameConstants, tau: int) -> float:
    """Largest constant step-size allowed by Theorems 3.3 / 3.4."""
    if tau < 1:
        raise ValueError("tau must be >= 1")
    return 1.0 / (c.ell * tau + 2.0 * (tau - 1) * c.L_max * math.sqrt(c.kappa))


def gamma_robot(c: GameConstants, tau: int) -> float:
    """Step-size used for the Section 4.2 robot experiment."""
    return 1.0 / (c.ell * tau + c.L_max * (tau - 1) * math.sqrt(c.kappa))


def contraction_zeta(c: GameConstants, tau: int, gamma: float) -> float:
    """``zeta = 2 - gamma*ell*tau - 2(tau-1) gamma L_max sqrt(kappa/3)`` (> 0)."""
    return 2.0 - gamma * c.ell * tau - 2.0 * (tau - 1) * gamma * c.L_max * math.sqrt(
        c.kappa / 3.0
    )


def linear_rate(c: GameConstants, tau: int, gamma: float) -> float:
    """Per-round contraction factor ``1 - gamma * tau * mu * zeta`` (Thm 3.3/3.4)."""
    return 1.0 - gamma * tau * c.mu * contraction_zeta(c, tau, gamma)


def neighborhood_radius_sq(c: GameConstants, tau: int, gamma: float, sigma_sq: float) -> float:
    """Size of the Theorem 3.4 convergence neighborhood (squared distance)."""
    q = c.q
    zeta = contraction_zeta(c, tau, gamma)
    factor = 1.0 + (tau - 1) * (
        (4.0 + math.sqrt(3.0) * q) * gamma * tau * c.L_max + q / (2.0 * tau)
    )
    return factor * gamma * sigma_sq / (c.mu * zeta)


def solve_eta(c: GameConstants, T: int) -> float:
    """Solve ``T = 2 (1 + 2q) eta log(eta)`` for ``eta`` by bisection."""
    q = c.q
    target = T / (2.0 * (1.0 + 2.0 * q))

    def g(eta: float) -> float:
        return eta * math.log(eta) - target

    lo, hi = 1.0 + 1e-9, 2.0
    while g(hi) < 0:
        hi *= 2.0
    for _ in range(200):
        mid = 0.5 * (lo + hi)
        if g(mid) < 0:
            lo = mid
        else:
            hi = mid
    return 0.5 * (lo + hi)


def gamma_horizon(c: GameConstants, tau: int, T: int) -> float:
    """Corollary 3.5 horizon-dependent constant step-size.

    Raises if ``T`` is too small for the corollary's ``eta > kappa * tau``
    validity condition.
    """
    eta = solve_eta(c, T)
    if eta <= c.kappa * tau:
        raise ValueError(
            f"T={T} too small: eta={eta:.1f} must exceed kappa*tau={c.kappa * tau:.1f}"
        )
    return 1.0 / (c.mu * eta * (1.0 + 2.0 * c.q))


def gamma_decreasing(c: GameConstants, tau: int, rounds: int) -> np.ndarray:
    """Theorem 3.6 round-indexed schedule, returned as an array of length ``rounds``.

    gamma_p = 1/(ell tau (1+2q))            if p <  2 (1+2q) kappa
            = (1/(tau mu)) (2p+1)/(p+1)^2   if p >= 2 (1+2q) kappa
    """
    q = c.q
    p0 = 2.0 * (1.0 + 2.0 * q) * c.kappa
    p = np.arange(rounds, dtype=np.float64)
    warm = 1.0 / (c.ell * tau * (1.0 + 2.0 * q))
    decay = (2.0 * p + 1.0) / ((p + 1.0) ** 2) / (tau * c.mu)
    return np.where(p < p0, warm, decay)


def gamma_warmup_cosine(
    peak: float,
    rounds: int | None = None,
    *,
    warmup_frac: float = 0.1,
    final_frac: float = 0.05,
):
    """Linear warmup to ``peak`` over ``warmup_frac`` of the rounds, then
    cosine decay to ``final_frac * peak`` — per-ROUND, not per-step, so the
    step-size stays constant within each round as the paper's analysis
    assumes.

    With ``rounds`` given, returns the ``(rounds,)`` array directly; without
    it, returns a schedule callable ``rounds -> array`` that plugs straight
    into the engine's ``gamma`` argument.
    """
    if not 0.0 <= warmup_frac < 1.0:
        raise ValueError(f"warmup_frac must be in [0, 1), got {warmup_frac}")

    def build(r: int) -> np.ndarray:
        p = np.arange(r, dtype=np.float64)
        warmup = max(int(round(warmup_frac * r)), 1)
        ramp = peak * (p + 1.0) / warmup
        t = np.clip((p - warmup) / max(r - 1 - warmup, 1), 0.0, 1.0)
        floor = final_frac * peak
        cos = floor + (peak - floor) * 0.5 * (1.0 + np.cos(math.pi * t))
        return np.where(p < warmup, ramp, cos)

    return build(rounds) if rounds is not None else build


# =========================================================================
# Step-size policies — per-player gammas from the round context
# =========================================================================
def gamma_delay_adaptive(c: GameConstants, tau: int, delay) -> np.ndarray:
    """Delay-corrected Theorem 3.4 step size ``gamma(tau) * tau/(tau + D)``.

    The Theorem 3.4 rule budgets the drift a player accumulates over ``tau``
    local steps against a snapshot that is 0 rounds old. A snapshot that is
    ``D`` rounds old makes the effective drift horizon ``tau + D`` local-step
    equivalents, so the asynchronous-SGD-style correction rescales the
    constant rule by ``tau / (tau + D)`` — i.e. ``gamma ~ 1/(tau + D)`` up to
    the theorem's own constants. Monotone (strictly) non-increasing in BOTH
    ``tau`` and ``D`` (pinned by a hypothesis property test), and exactly
    :func:`gamma_constant` at ``D = 0``.

    ``delay`` may be a scalar or an array (per-player delays -> per-player
    gammas).
    """
    d = np.asarray(delay, dtype=np.float64)
    if (d < 0).any():
        raise ValueError(f"delay must be >= 0, got {delay}")
    return gamma_constant(c, tau) * tau / (tau + d)


@dataclasses.dataclass(frozen=True)
class RoundContext:
    """Everything a :class:`StepsizePolicy` may condition on, for one round.

    ``tau``, ``max_staleness``, ``spectral_gap`` and ``coupling`` are static
    Python numbers (known at trace time — policies may branch on them in
    Python, which is how trace-time identities like the D = 0 collapse are
    implemented). ``delay_row`` is the per-player realized staleness for the
    round: a traced ``(n,)`` int array inside the async engine's scan, a host
    numpy array in the trainer's event loop, or ``None`` when the engine has
    no staleness axis (the lockstep engine).

    ``spectral_gap`` is ``1 - |lambda_2|`` of the topology's Metropolis
    mixing matrix (1.0 for the exact server broadcast); ``coupling`` is the
    game's dimensionless coupling ratio ``L_F / L_max`` (= ``1/q``) — how
    much larger the joint operator's Lipschitz constant is than any single
    player's smoothness, 1.0 for an uncoupled game and 1.0 again as the
    neutral fallback when the game publishes no constants.
    """

    tau: int
    max_staleness: int = 0
    spectral_gap: float = 1.0
    coupling: float = 1.0
    delay_row: Any = None

    def with_delays(self, delay_row) -> "RoundContext":
        return dataclasses.replace(self, delay_row=delay_row)


class StepsizePolicy(abc.ABC):
    """Per-round, per-player step-size selection from the round context.

    Implementations are frozen hashable dataclasses so they ride through
    ``jax.jit`` as static arguments. :meth:`round_gammas` is called inside
    the compiled rounds-scan with the round's base gamma (the active
    schedule's value) and a :class:`RoundContext`; it returns either a
    scalar (uniform across players — returning ``gamma`` unchanged keeps the
    compiled program literally identical to the policy-free engine) or an
    ``(n,)`` array of per-player step sizes.

    ``requires_staleness`` / ``requires_gossip`` declare the context a
    policy cannot do without; engines that cannot supply it reject the
    policy loudly at ``run()`` instead of silently feeding defaults (the
    lockstep engine has no staleness table; the star broadcast has no
    mixing spectrum).
    """

    name: str = "policy"
    requires_staleness: bool = False
    requires_gossip: bool = False

    @abc.abstractmethod
    def round_gammas(self, gamma, ctx: RoundContext):
        """Scalar or ``(n,)`` per-player step sizes for this round."""


@dataclasses.dataclass(frozen=True)
class Theorem34Policy(StepsizePolicy):
    """The paper's rule, unchanged: every player uses the round's scheduled
    gamma. The identity policy — the engine's compiled program with this
    policy is bit-for-bit the policy-free program (the default everywhere).
    """

    name: str = "theorem34"

    def round_gammas(self, gamma, ctx):
        del ctx
        return gamma


@dataclasses.dataclass(frozen=True)
class DelayAdaptivePolicy(StepsizePolicy):
    """``gamma_i = gamma * tau / (tau + strength * delay_i)`` per player.

    The :func:`gamma_delay_adaptive` correction applied inside the scan with
    each player's *drawn* staleness for the round, so a fresh reader keeps
    the full Theorem 3.4 step while a ``D``-stale reader is slowed by
    ``tau/(tau + D)`` — restoring the stability margin that fixed-gamma
    bounded staleness consumes at strong coupling (the BENCH_async.json
    headline: the diverging D = 16 strong-coupling cell converges under this
    policy). At ``max_staleness = 0`` the policy resolves to the identity AT
    TRACE TIME — same trick as the async engine's D = 0 buffer-read collapse
    — so it reproduces :class:`Theorem34Policy` bit-for-bit on the star.

    ``strength`` scales the correction (1.0 = the plain ``1/(tau + D)``
    rule; larger values over-damp stale readers).
    """

    strength: float = 1.0
    name: str = "delay_adaptive"
    requires_staleness = True

    def __post_init__(self):
        if self.strength <= 0.0:
            raise ValueError(
                f"DelayAdaptivePolicy.strength must be > 0, "
                f"got {self.strength}"
            )

    def round_gammas(self, gamma, ctx):
        if ctx.max_staleness == 0 or ctx.delay_row is None:
            return gamma           # trace-time identity: the D = 0 pin
        d = ctx.delay_row.astype(np.float32)   # jnp (traced) or host numpy
        return gamma * ctx.tau / (ctx.tau + self.strength * d)


@dataclasses.dataclass(frozen=True)
class SpectralPolicy(StepsizePolicy):
    """Gossip-aware margin from the mixing matrix's second eigenvalue.

    A gossip exchange does not deliver consensus — the per-player views
    carry a consensus error that contracts by ``|lambda_2|`` per sweep, so
    the views lag the true joint action by roughly the mixing time
    ``lag = |lambda_2| / (1 - |lambda_2|) = (1 - gap) / gap`` rounds
    (``gap`` is :func:`repro.core.topology.spectral_gap`). Every local round
    injects a fresh round's worth (``tau`` local steps) of opponent motion
    into that lag, and under antisymmetric coupling the lagged views act
    exactly like broadcast staleness (the PR 2 observation that gossip's
    stability margin shrinks with coupling strength). The margin deficit
    therefore scales with the EXCESS coupling ratio
    ``C = max(coupling - 1, 0)`` (``coupling = L_F / L_max``; an uncoupled
    game has no deficit), and the policy divides it out of the step size:

        gamma_eff = gamma / (1 + strength * C * lag).

    Uniform across players (the Metropolis spectrum is a global property);
    resolves to the identity at trace time on a fully-mixing graph
    (``lag = 0``) or an uncoupled game (``C = 0``). The default
    ``strength = 2.0`` is calibrated on the ring quadratic sweep
    (BENCH_engine.json): at the coupling where the fixed Theorem 3.4 step
    diverges for every ``gossip_steps`` tried, this policy restores
    convergence at ``gossip_steps = 1``. Requires a server-free topology —
    the star's exact broadcast has no consensus lag, so engines reject the
    combination loudly.
    """

    strength: float = 2.0
    name: str = "spectral"
    requires_gossip = True

    def __post_init__(self):
        if self.strength <= 0.0:
            raise ValueError(
                f"SpectralPolicy.strength must be > 0, got {self.strength}"
            )

    def margin_factor(self, ctx: RoundContext) -> float:
        """The static ``1 / (1 + strength * C * lag)`` step-size multiplier."""
        if ctx.spectral_gap <= 0.0:
            raise ValueError(
                "SpectralPolicy needs a connected topology "
                "(spectral gap 0 means the views never reach consensus)"
            )
        lag = (1.0 - ctx.spectral_gap) / ctx.spectral_gap
        C = max(ctx.coupling - 1.0, 0.0)
        return 1.0 / (1.0 + self.strength * C * lag)

    def round_gammas(self, gamma, ctx):
        f = self.margin_factor(ctx)
        if f == 1.0:
            return gamma           # trace-time identity
        return gamma * f


def resolve_policy(policy: "StepsizePolicy | str | None") -> StepsizePolicy:
    """Normalize the ``policy`` argument used across engines/trainer: an
    instance wins, a registry name constructs one, ``None`` means the
    identity :class:`Theorem34Policy`."""
    if policy is None:
        return Theorem34Policy()
    if isinstance(policy, str):
        try:
            return STEPSIZE_POLICIES[policy]()
        except KeyError:
            raise ValueError(
                f"unknown step-size policy {policy!r}; "
                f"known: {sorted(STEPSIZE_POLICIES)}"
            ) from None
    if not isinstance(policy, StepsizePolicy):
        raise TypeError(
            f"policy must be a StepsizePolicy, registry name, or None, "
            f"got {type(policy).__name__}"
        )
    return policy


def validate_policy_context(policy: StepsizePolicy, *, server: bool,
                            staleness_available: bool,
                            staleness_remedy: str,
                            topology_name: str = "Star") -> None:
    """Reject a policy whose required round context the caller cannot supply.

    THE one place the requires_staleness / requires_gossip contracts are
    enforced — shared by both engines, the trainer, and the compiled trainer
    round, so the rejection semantics (and wording) cannot drift between
    them. ``staleness_remedy`` names the caller-specific fix (which engine
    or constructor argument supplies the staleness counters).
    """
    if policy.requires_staleness and not staleness_available:
        raise ValueError(
            f"{type(policy).__name__} conditions on per-player staleness "
            f"and this engine/round has no staleness counters to feed it — "
            f"it would silently run at delay 0 (i.e. as theorem34); "
            f"{staleness_remedy}"
        )
    if policy.requires_gossip and server:
        raise ValueError(
            f"{type(policy).__name__} conditions on the mixing matrix's "
            f"spectral gap and the {topology_name} server broadcast has no "
            f"consensus lag to correct for — use a server-free topology "
            f"(or the theorem34 policy)"
        )


# ------------------------------------------------------------------ registry
STEPSIZE_POLICIES = {
    "theorem34": Theorem34Policy,
    "delay_adaptive": DelayAdaptivePolicy,
    "spectral": SpectralPolicy,
}
