"""Communication-cost accounting and convergence summaries for MpFL.

The paper measures communication in *rounds*; a production system measures
bytes on the wire. :class:`CommunicationModel` converts (tau, rounds, player
dims) into both, following Section 3.1: every synchronization moves each
player's block up to the server (``d_i`` values) and the concatenated joint
vector ``D = sum_i d_i`` back down to *every* player — the paper's noted
``n``-scaling of the downlink.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np


@dataclasses.dataclass(frozen=True)
class CommunicationModel:
    """Byte accounting for PEARL-SGD synchronizations."""

    dims: tuple[int, ...]            # (d_1, ..., d_n)
    bytes_per_scalar: int = 4        # fp32 on the wire by default

    @property
    def n(self) -> int:
        return len(self.dims)

    @property
    def D(self) -> int:
        return int(sum(self.dims))

    def bytes_per_round(self) -> int:
        """Uplink (each block once) + downlink (joint vector to n players)."""
        up = self.D * self.bytes_per_scalar
        down = self.n * self.D * self.bytes_per_scalar
        return up + down

    def total_bytes(self, rounds: int) -> int:
        return rounds * self.bytes_per_round()

    def bytes_for_iterations(self, iterations: int, tau: int) -> int:
        """Total bytes after ``iterations`` local steps with interval ``tau``."""
        return self.total_bytes(math.ceil(iterations / tau))


def rounds_to_reach(rel_errors: np.ndarray, threshold: float) -> int | None:
    """First sync index where relative error <= threshold (None if never)."""
    hits = np.nonzero(rel_errors <= threshold)[0]
    return int(hits[0]) if hits.size else None


def communication_savings(
    errors_by_tau: dict[int, np.ndarray], threshold: float
) -> dict[int, float]:
    """Communication-round speedup of each tau relative to tau = 1.

    Returns {tau: rounds(tau=1)/rounds(tau)} for taus that reach the
    threshold; the paper's headline claim is that this exceeds 1 and grows
    with tau (up to tau ~ sqrt(kappa)).
    """
    base = rounds_to_reach(errors_by_tau[1], threshold)
    if base is None:
        raise ValueError("tau=1 never reached the threshold")
    out = {}
    for tau, errs in errors_by_tau.items():
        r = rounds_to_reach(errs, threshold)
        if r is not None and r > 0:
            out[tau] = base / r
    return out


def final_plateau(rel_errors: np.ndarray, window: int = 20) -> float:
    """Mean of the trailing ``window`` relative errors — the noise floor
    (Theorem 3.4's neighborhood) reached by a constant-step-size run."""
    w = min(window, len(rel_errors))
    return float(np.mean(rel_errors[-w:]))
