"""EngineSpec: one configuration object, one compatibility matrix.

Nine PRs of axis growth left the engine surface with ~10 orthogonal keyword
axes (``update``, ``sync``, ``topology``, ``gossip_steps``, ``policy``,
``view``, ``mesh``/``mesh_axis``, plus the async-only ``delays``/
``max_staleness``/``overlap``) and the composition rejection matrix smeared
across four modules. This module consolidates both:

- :class:`EngineSpec` — a frozen bundle of the eight axes every entry point
  shares. Both engines and :class:`~repro.train.pearl_trainer.PearlTrainer`
  accept ``spec=``; the spec is pure sugar that resolves to the exact same
  constructor state as the legacy kwargs (pinned bit-for-bit in
  ``tests/test_spec.py``). An axis left ``None`` in the spec keeps the
  target's default; setting the same axis BOTH ways (a non-default kwarg
  and a spec value) is ambiguous and rejected.
- :func:`validate_spec` — THE composition matrix. Every invalid axis
  combination across ``PearlEngine``, ``AsyncPearlEngine``,
  ``make_pearl_round``/``PearlTrainer``, and the trainer collectives is
  rejected here (or by the shared helpers this module owns:
  :func:`resolve_view`, :func:`check_summary_view`,
  :func:`repro.core.selection.validate_selection`,
  :func:`repro.core.stepsize.validate_policy_context`,
  :func:`validate_tree_mean`, :func:`validate_tree_mean_lowbit`) — the
  engine/trainer bodies contain no composition guards of their own, so the
  wording in docs/ARCHITECTURE.md's rejection table cannot drift per call
  site (a test parses that table and fires every row).

Parameter-RANGE validation (``tau >= 1``, fractions in ``[0, 1]``, view
knobs) stays with the objects that own the parameters; this module owns the
rules about how axes COMBINE.

Import discipline: ``engine``/``async_engine``/``selection``/``collective``
all import this module, so everything here imports them lazily inside
function bodies — :mod:`repro.core.spec` sits below the engines in the
import graph.
"""

from __future__ import annotations

import dataclasses
from typing import Any

__all__ = [
    "EngineSpec",
    "apply_spec",
    "merge_trainer_spec",
    "resolve_stale_sync",
    "resolve_view",
    "check_summary_view",
    "validate_spec",
    "validate_tree_mean",
    "validate_tree_mean_lowbit",
    "warn_legacy",
]

#: the axes EngineSpec carries — the shared engine configuration surface
SPEC_AXES = ("update", "sync", "topology", "gossip_steps", "policy",
             "view", "mesh", "mesh_axis")


@dataclasses.dataclass(frozen=True)
class EngineSpec:
    """The axis configuration of one PEARL run, as a single frozen value.

    Every field defaults to ``None`` = "unset": the receiving constructor
    keeps its own default for that axis. Set fields overwrite the target's
    defaults; a target constructed with BOTH a non-default kwarg and a spec
    value for the same axis is rejected (two sources of truth).

    The trainer consumes the subset of axes it has (``sync``, ``topology``,
    ``policy``, ``view``, ``mesh``, ``mesh_axis``); a spec that sets
    ``update`` or ``gossip_steps`` is rejected there — the trainer's local
    rule is the optimizer, and its graph mixing is one sweep per round.
    """

    update: Any = None
    sync: Any = None
    topology: Any = None
    gossip_steps: int | None = None
    policy: Any = None
    view: Any = None
    mesh: Any = None
    mesh_axis: str | None = None

    def set_axes(self) -> dict[str, Any]:
        """The axes this spec actually sets (non-``None`` fields)."""
        return {name: getattr(self, name) for name in SPEC_AXES
                if getattr(self, name) is not None}


def apply_spec(obj) -> None:
    """Merge ``obj.spec`` (an :class:`EngineSpec` or ``None``) into the
    axis fields of a frozen engine dataclass, inside its ``__post_init__``.

    For each axis the spec sets: if the constructor kwarg was left at its
    default, the spec's value wins; if the kwarg was ALSO set to something
    else, the configuration has two sources of truth and is rejected."""
    spec = getattr(obj, "spec", None)
    if spec is None:
        return
    if not isinstance(spec, EngineSpec):
        raise TypeError(
            f"spec must be an EngineSpec (or None), got "
            f"{type(spec).__name__}"
        )
    fields = {f.name: f for f in dataclasses.fields(obj)}
    for name, value in spec.set_axes().items():
        default = fields[name].default
        current = getattr(obj, name)
        if current != default and current != value:
            raise ValueError(
                f"{type(obj).__name__} got {name}= both ways: the spec "
                f"sets {name}={value!r} but the constructor was also "
                f"passed {name}={current!r} — give each axis once (the "
                f"spec is sugar for the same constructor state)"
            )
        object.__setattr__(obj, name, value)


def merge_trainer_spec(spec: EngineSpec | None, *, topology, policy,
                       round_kwargs: dict) -> tuple[Any, Any, dict]:
    """Resolve a trainer's ``spec=`` into its legacy ``(topology, policy,
    **round_kwargs)`` configuration — the same two-sources-of-truth rule as
    :func:`apply_spec`. Returns the merged ``(topology, policy,
    round_kwargs)``."""
    if spec is None:
        return topology, policy, round_kwargs
    if not isinstance(spec, EngineSpec):
        raise TypeError(
            f"spec must be an EngineSpec (or None), got "
            f"{type(spec).__name__}"
        )
    axes = spec.set_axes()
    for name in ("update", "gossip_steps"):
        if name in axes:
            raise ValueError(
                f"PearlTrainer has no {name!r} axis: the trainer's local "
                f"rule is its optimizer and its graph mixing runs one "
                f"sweep per round — build an EngineSpec without {name} "
                f"for the trainer"
            )
    round_kwargs = dict(round_kwargs)
    if "sync" in axes:
        if round_kwargs.get("sync") is not None or \
                round_kwargs.get("sync_dtype") is not None:
            raise ValueError(
                "PearlTrainer got the sync axis both ways: the spec sets "
                "sync= but sync=/sync_dtype= was also passed — give the "
                "axis once"
            )
        round_kwargs["sync"] = axes["sync"]
    for name in ("view", "mesh", "mesh_axis"):
        if name in axes:
            if round_kwargs.get(name) is not None and \
                    round_kwargs.get(name) != axes[name]:
                raise ValueError(
                    f"PearlTrainer got {name}= both ways: the spec sets "
                    f"{name}={axes[name]!r} but "
                    f"{name}={round_kwargs[name]!r} was also passed — "
                    f"give each axis once"
                )
            round_kwargs[name] = axes[name]
    if "topology" in axes:
        if topology is not None and topology != axes["topology"]:
            raise ValueError(
                f"PearlTrainer got the topology axis both ways: the spec "
                f"sets topology={axes['topology']!r} but "
                f"topology={topology!r} was also passed — give the axis "
                f"once"
            )
        topology = axes["topology"]
    if "policy" in axes:
        if policy is not None and policy != axes["policy"]:
            raise ValueError(
                f"PearlTrainer got the policy axis both ways: the spec "
                f"sets policy={axes['policy']!r} but "
                f"policy={policy!r} was also passed — give the axis once"
            )
        policy = axes["policy"]
    return topology, policy, round_kwargs


# =========================================================================
# Shared resolution helpers (moved here from the engines)
# =========================================================================
def resolve_stale_sync(sync, delays, max_staleness):
    """Unwrap a :class:`~repro.core.async_engine.StaleSync` spelling.

    Returns ``(wire strategy, delay schedule, bound)``. The delay model can
    travel inside the StaleSync or as explicit ``delays``/``max_staleness``
    (``delays=None`` here means "not given") — both at once is ambiguous
    and rejected with the wording both the async engine and the trainer
    share."""
    from repro.core.async_engine import StaleSync

    if isinstance(sync, StaleSync):
        if delays is not None or max_staleness != 0:
            raise ValueError(
                "give the delay model either inside StaleSync or via "
                "delays/max_staleness, not both"
            )
        return sync.inner, sync.delays, sync.max_staleness
    return sync, delays, max_staleness


def resolve_view(view, topology):
    """Resolve the engine's ``view`` argument against its topology.

    ``None`` keeps the legacy behavior — the topology decides:
    :class:`~repro.core.engine.StarView` under a server,
    :class:`~repro.core.engine.GossipView` on a graph. Explicit views are
    checked for topology compatibility here (the summary-specific
    composition rules live in :func:`check_summary_view`).
    """
    from repro.core.engine import GossipView, StarView

    if view is None:
        return StarView() if topology.is_server else GossipView()
    if isinstance(view, StarView) and not topology.is_server:
        raise ValueError(
            f"StarView is the server broadcast; got the server-free "
            f"{type(topology).__name__} — use GossipView (or view=None)"
        )
    if isinstance(view, GossipView) and topology.is_server:
        raise ValueError(
            f"GossipView relays per-player views over graph edges; the "
            f"{type(topology).__name__} server has none — use StarView "
            f"(or view=None)"
        )
    if view.summary_based and not topology.is_server:
        raise ValueError(
            f"MeanFieldView is a server-maintained O(d) summary broadcast; "
            f"{type(topology).__name__} gossip relays (n, d) views with no "
            f"single summary owner — use the Star topology (sampled "
            f"interaction is MeanFieldView(sample=k), not a graph)"
        )
    return view


def check_summary_view(view, *, update, sync, mesh, game=None) -> None:
    """The mean-field composition rules, shared by both engines — every
    axis whose semantics a summary reference would silently change is
    rejected loudly. No-op for full-joint views."""
    if not view.summary_based:
        return
    from repro.core.engine import (
        DecentralizedExtragradientUpdate,
        JointUpdate,
    )
    from repro.core.game import AggregativeGame

    if isinstance(update, JointUpdate):
        raise ValueError(
            f"{type(update).__name__} owns the whole within-round "
            f"computation on the replicated (n, d) joint action; "
            f"MeanFieldView never materializes a broadcast joint for it "
            f"to read — joint baselines require the star's full "
            f"broadcast (view=None)"
        )
    if isinstance(update, DecentralizedExtragradientUpdate):
        raise ValueError(
            f"{type(update).__name__} interleaves gossip mixing "
            f"sweeps between its phases and MeanFieldView has no views "
            f"to mix — use sgd/extragradient/optimistic_gradient/"
            f"heavy_ball locals with the summary reference"
        )
    if sync.uses_mask:
        if not getattr(sync, "stateful_selection", False):
            raise ValueError(
                f"{type(sync).__name__} draws a per-round participation "
                f"mask, and a population summary over a PARTIAL population "
                f"silently changes what 'mean_i x^i' means to every reader "
                f"— mean-field views support full-participation strategies "
                f"only (use the exact/quantized/low-bit wires, or a "
                f"selection policy with MeanFieldView(sample=k))"
            )
        if view.sample is None:
            raise ValueError(
                f"{type(sync).__name__} masks who participates, and the "
                f"DENSE population summary would silently average stale "
                f"blocks into what every reader believes is the live "
                f"'mean_i x^i' — selection composes with sampled "
                f"interaction only (MeanFieldView(sample=k): absentees "
                f"simply stay stale in the live snapshot the sampled "
                f"reads index)"
            )
    if mesh is not None:
        raise ValueError(
            "mesh lowering gathers the full (n, d) joint across the "
            "player axis (sharded_joint_wire) — the exact O(n d) wire "
            "MeanFieldView exists to avoid; the summary broadcast is "
            "O(d) and needs no collective lowering, run it with "
            "mesh=None"
        )
    if sync.has_wire_state and view.sample is not None:
        raise ValueError(
            f"{type(sync).__name__} banks an error-feedback "
            f"residual against the ONE broadcast summary; sampled "
            f"interaction (sample={view.sample}) gives every player a "
            f"personalized summary with no single wire tensor — use "
            f"error_feedback=False or the dense summary (sample=None)"
        )
    if game is not None:
        if not isinstance(game, AggregativeGame):
            raise ValueError(
                f"MeanFieldView needs an AggregativeGame (a coupling "
                f"that factors through population moments — "
                f"player_grad_summary); {type(game).__name__} only "
                f"exposes the full-joint oracle, and evaluating it at a "
                f"summary would silently compute a different game"
            )
        if view.moments < game.summary_moments:
            raise ValueError(
                f"{type(game).__name__}.player_grad_summary consumes "
                f"{game.summary_moments} opponent moments but the view "
                f"maintains only {view.moments} — use MeanFieldView("
                f"moments={game.summary_moments})"
            )
        if view.sample is not None and view.sample > game.n - 1:
            raise ValueError(
                f"MeanFieldView.sample={view.sample} exceeds the "
                f"{game.n - 1} opponents a player can draw from"
            )


# =========================================================================
# The one compatibility matrix
# =========================================================================
def validate_spec(spec: EngineSpec, *, async_: bool = False,
                  trainer: bool = False, game=None, delays=None,
                  max_staleness: int = 0, overlap: bool = False,
                  external_refs: bool = False, trainer_init: bool = False,
                  staleness_available: bool | None = None,
                  policy_remedy: str | None = None, coupling=None):
    """Validate one axis configuration against the full composition matrix.

    The single rejection point for every engine/trainer entry:

    - ``validate_spec(spec, game=...)`` — the lockstep
      :class:`~repro.core.engine.PearlEngine` rules; returns the resolved
      :class:`~repro.core.engine.JointView`.
    - ``validate_spec(spec, async_=True, delays=..., max_staleness=...,
      overlap=...)`` — the :class:`~repro.core.async_engine.AsyncPearlEngine`
      rules (``spec.sync`` must already be StaleSync-unwrapped via
      :func:`resolve_stale_sync`); returns the resolved view.
    - ``validate_spec(spec, trainer=True, ...)`` — the neural-trainer rules
      shared by ``make_pearl_round`` (``external_refs``/``policy_remedy``)
      and ``PearlTrainer.__init__`` (additionally ``trainer_init=True`` with
      ``delays``/``max_staleness``/``staleness_available``/``coupling``);
      returns ``None``.

    Every message is verbatim the one the scattered per-module guards used
    to raise — docs/ARCHITECTURE.md's rejection table is the rendered form
    of this function, and ``tests/test_spec.py`` asserts each table row
    still fires.
    """
    if trainer:
        return _validate_trainer(
            spec, delays=delays, max_staleness=max_staleness,
            external_refs=external_refs, trainer_init=trainer_init,
            staleness_available=bool(staleness_available),
            policy_remedy=policy_remedy or "", coupling=coupling,
        )
    if async_:
        return _validate_async(spec, game=game, delays=delays,
                               max_staleness=max_staleness, overlap=overlap)
    return _validate_lockstep(spec, game=game)


def _resolved_axes(spec: EngineSpec):
    """Fill unset axes with the engines' defaults and resolve the policy."""
    from repro.core.engine import ExactSync, SgdUpdate
    from repro.core.stepsize import resolve_policy
    from repro.core.topology import Star

    update = spec.update if spec.update is not None else SgdUpdate()
    sync = spec.sync if spec.sync is not None else ExactSync()
    topology = spec.topology if spec.topology is not None else Star()
    gossip_steps = (spec.gossip_steps if spec.gossip_steps is not None
                    else 1)
    policy = resolve_policy(spec.policy)
    return update, sync, topology, gossip_steps, policy


def _validate_lockstep(spec: EngineSpec, *, game):
    from repro.core.engine import (
        DecentralizedExtragradientUpdate,
        ExactSync,
        JointUpdate,
    )
    from repro.core.stepsize import Theorem34Policy, validate_policy_context

    update, sync, topology, gossip_steps, policy = _resolved_axes(spec)
    view = resolve_view(spec.view, topology)
    check_summary_view(view, update=update, sync=sync, mesh=spec.mesh,
                       game=game)
    if getattr(sync, "stateful_selection", False):
        from repro.core.selection import validate_selection

        validate_selection(sync, server=topology.is_server, mesh=spec.mesh,
                           topology_name=type(topology).__name__)
    if gossip_steps < 1:
        raise ValueError(f"gossip_steps must be >= 1, got {gossip_steps}")
    if getattr(sync, "requires_async", False):
        raise ValueError(
            f"{type(sync).__name__} models bounded staleness and "
            f"needs the snapshot ring buffer of AsyncPearlEngine "
            f"(repro.core.async_engine); the lockstep PearlEngine would "
            f"silently ignore its delay schedule"
        )
    validate_policy_context(
        policy, server=topology.is_server,
        staleness_available=False,
        staleness_remedy="use AsyncPearlEngine",
        topology_name=type(topology).__name__,
    )
    if spec.mesh is not None:
        if isinstance(update, JointUpdate):
            raise ValueError(
                f"{type(update).__name__} owns the whole "
                f"within-round computation on the replicated joint "
                f"action — there is no per-player exchange for the mesh "
                f"collective layer to lower; run joint baselines "
                f"without a mesh"
            )
        if sync.uses_mask:
            raise ValueError(
                f"mesh lowering covers full-participation "
                f"synchronization; {type(sync).__name__} draws a "
                f"per-round participation mask, and compiling a full "
                f"wire exchange the mask-aware byte accounting "
                f"contradicts would make the billing dishonest — use "
                f"the host path (mesh=None) for masked regimes"
            )
    if sync.has_wire_state and not topology.is_server:
        raise ValueError(
            f"{type(sync).__name__} carries an error-feedback "
            f"residual for the ONE transmit tensor of the star "
            f"broadcast; gossip relays per-edge views with no single "
            f"wire tensor to bank a residual against — use "
            f"error_feedback=False (stateless low-bit compression "
            f"composes with any topology) or the Star topology"
        )
    if isinstance(update, DecentralizedExtragradientUpdate):
        if topology.is_server:
            raise ValueError(
                f"{type(update).__name__} interleaves mixing sweeps "
                f"with the extragradient phases and the server broadcast "
                f"has no views to mix — on the Star topology use "
                f"JointExtragradientUpdate (exact mixing every sync)"
            )
        if sync.uses_mask:
            raise ValueError(
                f"{type(update).__name__} relays every player's "
                f"half-point mid-round; a participation mask "
                f"({type(sync).__name__}) would drop half-points "
                f"with no extragradient semantics — full participation "
                f"only"
            )
    if isinstance(update, JointUpdate):
        if not isinstance(policy, Theorem34Policy):
            raise ValueError(
                f"{type(update).__name__} owns the whole "
                f"within-round computation on the joint action — "
                f"per-player step-size policies do not apply; joint "
                f"baselines support only the theorem34 policy"
            )
        if not topology.is_server:
            raise ValueError(
                f"{type(update).__name__} is fully synchronized and "
                f"needs the Star topology, got {type(topology).__name__}"
            )
        if not isinstance(sync, ExactSync):
            raise ValueError(
                f"{type(update).__name__} owns the whole within-round "
                f"computation: the engine never applies "
                f"{type(sync).__name__}'s pre_round/mask/view, and "
                f"billing would silently fall back to ExactSync bytes — "
                f"joint baselines support only sync=ExactSync()"
            )
    return view


def _validate_async(spec: EngineSpec, *, game, delays, max_staleness,
                    overlap):
    from repro.core.async_engine import ConstantDelay
    from repro.core.engine import (
        DecentralizedExtragradientUpdate,
        JointUpdate,
    )
    from repro.core.stepsize import validate_policy_context

    update, sync, topology, gossip_steps, policy = _resolved_axes(spec)
    D = max_staleness
    view = resolve_view(spec.view, topology)
    check_summary_view(view, update=update, sync=sync, mesh=spec.mesh,
                       game=game)
    if view.summary_based and view.sample is not None:
        raise ValueError(
            "sampled neighbor reads (MeanFieldView(sample=...)) index "
            "the live joint snapshot; under staleness every reader "
            "would need the (depth, n, d) joint ring buffer the "
            "summary path exists to avoid — use the dense summary "
            "(sample=None) here, or the lockstep PearlEngine for "
            "sampled interaction"
        )
    if D < 0:
        raise ValueError(f"max_staleness must be >= 0, got {D}")
    if gossip_steps < 1:
        raise ValueError(
            f"gossip_steps must be >= 1, got {gossip_steps}")
    if sync.has_wire_state and not topology.is_server:
        raise ValueError(
            f"{type(sync).__name__} carries an error-feedback residual "
            f"for the ONE transmit tensor of the star broadcast; gossip "
            f"relays per-edge views with no single wire tensor to bank "
            f"a residual against — use error_feedback=False or the Star "
            f"topology"
        )
    if spec.mesh is not None:
        if not topology.is_server:
            raise ValueError(
                "the device-resident async mesh path covers the star "
                "broadcast (one ring buffer of joint snapshots); gossip "
                "staleness is per-receiver view state with no sharded "
                "lowering yet — run graph topologies on the host path "
                "(mesh=None)"
            )
        if sync.uses_mask:
            raise ValueError(
                f"mesh lowering covers full-participation "
                f"synchronization; {type(sync).__name__} draws a "
                f"per-round participation mask — use the host path "
                f"(mesh=None) for masked regimes"
            )
    if getattr(sync, "stateful_selection", False):
        from repro.core.selection import validate_selection

        validate_selection(sync, server=topology.is_server, mesh=spec.mesh,
                           topology_name=type(topology).__name__)
    if overlap:
        if spec.mesh is None:
            raise ValueError(
                "overlap=True double-buffers the sharded wire collective "
                "so XLA can ship it during the local steps; without a "
                "mesh there is no collective to overlap — pass mesh="
                "player_mesh(n) (or drop overlap)"
            )
        if not topology.is_server:
            raise ValueError("overlap=True is a star-broadcast "
                             "optimization; gossip is not supported")
        if D != 1 or delays != ConstantDelay(1):
            raise ValueError(
                "overlap=True makes every player read LAST round's "
                "broadcast — exactly ConstantDelay(1) staleness. "
                "Declare it: delays=ConstantDelay(1), max_staleness=1. "
                "The engine refuses to overlap while claiming lockstep "
                "freshness."
            )
    if isinstance(update, JointUpdate):
        raise ValueError(
            f"{type(update).__name__} reads fresh iterates "
            f"mid-round (fully synchronized) — asynchronous bounded "
            f"staleness does not apply; use the lockstep PearlEngine"
        )
    if isinstance(update, DecentralizedExtragradientUpdate):
        raise ValueError(
            f"{type(update).__name__} interleaves a mixing sweep "
            f"between its extragradient phases, and that MID-ROUND "
            f"sweep has no per-receiver delayed equivalent — use the "
            f"lockstep PearlEngine on a graph topology"
        )
    validate_policy_context(
        policy, server=topology.is_server,
        staleness_available=True, staleness_remedy="",
        topology_name=type(topology).__name__,
    )
    return view


def _trainer_needs_general(sync, topology) -> bool:
    """Mirror of ``pearl_trainer.needs_general_round`` (kept inline so the
    import graph stays acyclic): the star fast path suffices iff the
    topology is the server and the strategy draws no mask."""
    return (not topology.is_server) or sync.uses_mask


def _validate_trainer(spec: EngineSpec, *, delays, max_staleness,
                      external_refs, trainer_init, staleness_available,
                      policy_remedy, coupling):
    from repro.core.stepsize import Theorem34Policy, validate_policy_context
    from repro.core.topology import Star

    sync = spec.sync
    topo = spec.topology if spec.topology is not None else Star()
    from repro.core.stepsize import resolve_policy

    policy = resolve_policy(spec.policy)
    if max_staleness < 0:
        raise ValueError(
            f"max_staleness must be >= 0, got {max_staleness}")
    if max_staleness > 0 and delays is None:
        raise ValueError(
            "max_staleness > 0 needs a delays= DelaySchedule (or a "
            "StaleSync sync) — without one the trainer would silently "
            "run lockstep"
        )
    if getattr(sync, "requires_async", False):
        raise ValueError(
            f"{type(sync).__name__} carries a delay model the compiled "
            f"round cannot honor — construct PearlTrainer with it (or with "
            f"delays/max_staleness), which unwraps it into the event-shaped "
            f"host loop"
        )
    if spec.view is not None:
        from repro.core.engine import MeanFieldView

        view = spec.view
        if not isinstance(view, MeanFieldView):
            raise ValueError(
                f"the neural trainer's reference is always an aggregate "
                f"(the consensus game is aggregative): the star fast path "
                f"broadcasts the O(d) across-player mean, never the (n, d) "
                f"joint — {type(view).__name__} does not describe any "
                f"trainer wire; use view=None or "
                f"MeanFieldView(self_correction=False)"
            )
        if (view.moments != 1 or view.self_correction
                or view.sample is not None):
            raise ValueError(
                f"the trainer's wire is the plain population mean: "
                f"MeanFieldView(moments=1, self_correction=False, "
                f"sample=None) is the only summary it implements — got "
                f"moments={view.moments}, "
                f"self_correction={view.self_correction}, "
                f"sample={view.sample}; the dense engines "
                f"(PearlEngine/AsyncPearlEngine) implement the corrected/"
                f"second-moment/sampled variants"
            )
        if external_refs or _trainer_needs_general(sync, topo):
            raise ValueError(
                f"MeanFieldView names the star full-participation fast "
                f"path's O(d) mean wire; the general stale-block round "
                f"(topology={type(topo).__name__}, "
                f"sync={type(sync).__name__}, "
                f"external_refs={external_refs}) re-mixes per-player "
                f"references over a partial/stale snapshot, which silently "
                f"changes what 'mean_j x^j' means — use view=None there"
            )
    if trainer_init and getattr(sync, "stateful_selection", False):
        # the trainer's general merge is the ONE mask-aware mesh lowering
        # (sharded_stale_merge ships masked_payload zero-bit rows), so
        # selection validates with mesh=None regardless of the round's mesh
        from repro.core.selection import validate_selection

        validate_selection(sync, server=topo.is_server, mesh=None,
                           topology_name=type(topo).__name__)
    scaled = not isinstance(policy, Theorem34Policy)
    if scaled:
        validate_policy_context(
            policy, server=topo.is_server,
            staleness_available=staleness_available,
            staleness_remedy=policy_remedy,
            topology_name=type(topo).__name__,
        )
        if trainer_init and policy.requires_gossip and \
                float(coupling) <= 1.0:
            raise ValueError(
                f"{type(policy).__name__} scales with the excess "
                f"coupling ratio and the neural consensus game has no "
                f"closed-form constants — pass coupling > 1.0 (an "
                f"L_F/L_max estimate); at the default 1.0 the policy "
                f"would silently run as theorem34"
            )
    if scaled and not external_refs and \
            not _trainer_needs_general(sync, topo):
        raise ValueError(
            f"{type(policy).__name__} needs the general stale-block round "
            f"(per-player references carry the per-player scale); the "
            f"star/full-participation fast path has no player axis to "
            f"thread it through — pass external_refs=True, a mask "
            f"strategy, or a graph topology"
        )
    if (external_refs or _trainer_needs_general(sync, topo)) and \
            getattr(sync, "has_wire_state", False):
        raise ValueError(
            f"{type(sync).__name__} carries error-feedback wire state, "
            f"which is defined for the star full-participation broadcast "
            f"(ONE wire tensor per round with a well-defined residual); the "
            f"general stale-block merge (topology={type(topo).__name__}, "
            f"external_refs={external_refs}) has no per-player residual "
            f"carry — construct the strategy with error_feedback=False "
            f"(stateless low-bit) or use the star fast path"
        )
    return None


# -------------------------------------------- trainer collective guards
def validate_tree_mean(strategy, axis: int, mesh) -> None:
    """Composition guards of the trainer's full-participation star
    collective (``tree_mean``)."""
    if strategy.uses_mask:
        raise ValueError(
            f"tree_mean is the full-participation star collective; "
            f"{type(strategy).__name__} draws a participation mask and needs "
            f"the general stale-block merge round (make_pearl_round)"
        )
    if hasattr(strategy, "wire_encode"):
        raise ValueError(
            f"{type(strategy).__name__} is a sub-bf16 engine wire (per-block "
            f"scales + error-feedback state); tree_mean is stateless and "
            f"per-call — use tree_mean_lowbit, which threads the residual "
            f"and returns it (the trainer's star fast path does this "
            f"automatically), or QuantizedSync here"
        )
    if mesh is not None and axis != 0:
        raise ValueError(
            f"the mesh-lowered collective shards the leading player "
            f"axis; got axis={axis}"
        )


def validate_tree_mean_lowbit(sync) -> None:
    """Composition guard of the trainer's low-bit wire collective."""
    if not hasattr(sync, "wire_encode"):
        raise ValueError(
            f"tree_mean_lowbit is the low-bit wire path; "
            f"{type(sync).__name__} has no wire_encode — use tree_mean"
        )


# =========================================================================
# One-time deprecation warnings for the legacy adapter surface
# =========================================================================
_LEGACY_WARNED: set[str] = set()


def warn_legacy(name: str, replacement: str) -> None:
    """Emit ONE DeprecationWarning per process for a legacy entry point.

    The PR 1 adapters and ``make_pearl_round`` keep working bit-for-bit
    (their pins hold); the warning only points new code at the
    :class:`EngineSpec` spelling. See README "Migrating to EngineSpec"."""
    if name in _LEGACY_WARNED:
        return
    _LEGACY_WARNED.add(name)
    import warnings

    warnings.warn(
        f"{name} is a legacy adapter kept for bit-for-bit compatibility; "
        f"new code should configure the engine through "
        f"repro.core.spec.EngineSpec — {replacement}",
        DeprecationWarning,
        stacklevel=3,
    )
