"""Topology layer: who talks to whom at each synchronization.

PEARL-SGD's Algorithm 1 assumes a *star*: a server receives every player's
block and rebroadcasts the joint vector. This module factors that assumption
out of the communication strategies into an explicit :class:`Topology` — a
mixing-matrix abstraction over the player graph — so the engine's
synchronization becomes the orthogonal composition

    Topology (who talks to whom)  x  Compression (wire dtype)
                                  x  Participation (who talks this round).

Server-free topologies replace the broadcast with **neighbor averaging**: the
doubly-stochastic mixing matrix ``W`` acts on the players' *views* of the
joint action (``W @ blocks`` along the player axis). Each player ``i`` keeps a
local estimate ``V_i`` of the whole joint vector, refreshed at every
synchronization by relaying views over the graph edges,

    V_i  <-  sum_j W_ij V_j     (own block pinned: ``V_i[i] = x_i``),

which is the decentralized-VI / networked Nash-seeking setup: node ``i`` can
evaluate only its own block of the game operator but holds a full copy of the
variable. Entry ``j`` of every view performs a consensus iteration anchored at
its owner, so for any *connected* graph all views contract geometrically onto
the true joint action and the equilibrium is preserved; on a disconnected
graph non-neighbor entries stay frozen at their initial values and the
iterates converge to the wrong point (tests/test_topology.py pins both).

Mixing weights are Metropolis–Hastings (``W_ij = 1/(1 + max(deg_i, deg_j))``
on edges, diagonal absorbs the rest), which is symmetric and doubly
stochastic for every undirected graph — no per-topology tuning. The
matrix's second eigenvalue is the graph's consensus speed:
:func:`spectral_gap` returns ``1 - |lambda_2|``, the per-sweep geometric
contraction of the consensus error, and ``(1 - gap)/gap`` is the mixing
time the views lag behind the true joint action — the quantity the
``spectral`` step-size policy (:class:`repro.core.stepsize.SpectralPolicy`)
converts into an effective staleness. Anchored relaying (own diagonal
pinned) contracts by the norm of ``W``'s principal submatrices, which is
*slower* than ``|lambda_2|`` on sparse graphs — the reason gossip's
stability margin shrinks faster with coupling than the bare spectrum
suggests (docs/THEORY.md spells this out).

Byte accounting is **edge-aware** and direction-aware, and lives here so the
dense engine (:class:`repro.core.engine.PearlResult`) and the neural trainer
(:class:`repro.train.pearl_trainer.PearlCommReport`) derive their uplink /
downlink itemsizes from one place (:func:`direction_itemsizes`):

- star: each participant uploads one block, downloads the ``n``-block joint
  vector (:func:`star_round_bytes`);
- gossip: each active directed edge carries one message of
  ``payload_blocks`` blocks (:func:`gossip_round_bytes`). General games relay
  full views (payload ``n`` blocks); aggregative/consensus games — the neural
  trainer — need only the sender's parameters (payload 1), so a player moves
  ``deg(i) * d`` scalars per round instead of the star downlink's ``n * d``.
"""

from __future__ import annotations

import abc
import dataclasses
import math

import numpy as np


# =========================================================================
# Graph / mixing-matrix utilities
# =========================================================================
def metropolis_weights(adjacency: np.ndarray) -> np.ndarray:
    """Symmetric doubly-stochastic mixing matrix from an undirected graph.

    ``W_ij = 1 / (1 + max(deg_i, deg_j))`` on edges; the diagonal absorbs the
    remaining mass. Rows and columns sum to 1 for any symmetric adjacency.
    """
    A = np.asarray(adjacency, dtype=bool)
    if A.ndim != 2 or A.shape[0] != A.shape[1]:
        raise ValueError(f"adjacency must be square, got {A.shape}")
    if not np.array_equal(A, A.T):
        raise ValueError("adjacency must be symmetric (undirected graph)")
    A = A & ~np.eye(A.shape[0], dtype=bool)   # no self-loops
    deg = A.sum(axis=1)
    W = np.where(A, 1.0 / (1.0 + np.maximum(deg[:, None], deg[None, :])), 0.0)
    np.fill_diagonal(W, 1.0 - W.sum(axis=1))
    return W


def is_doubly_stochastic(W: np.ndarray, tol: float = 1e-9) -> bool:
    W = np.asarray(W, dtype=np.float64)
    return bool(
        (W >= -tol).all()
        and np.allclose(W.sum(axis=0), 1.0, atol=tol)
        and np.allclose(W.sum(axis=1), 1.0, atol=tol)
    )


def is_connected(adjacency: np.ndarray) -> bool:
    """BFS connectivity of the undirected graph (n = 1 counts as connected)."""
    A = np.asarray(adjacency, dtype=bool)
    n = A.shape[0]
    if n <= 1:
        return True
    seen = np.zeros(n, dtype=bool)
    frontier = np.zeros(n, dtype=bool)
    seen[0] = frontier[0] = True
    while frontier.any():
        frontier = (A[frontier].any(axis=0)) & ~seen
        seen |= frontier
    return bool(seen.all())


def spectral_gap(W: np.ndarray) -> float:
    """``1 - |lambda_2|`` of a symmetric mixing matrix — the per-round
    geometric contraction rate of the consensus error (0 when disconnected)."""
    eigs = np.sort(np.abs(np.linalg.eigvalsh(np.asarray(W, dtype=np.float64))))
    return float(1.0 - eigs[-2]) if eigs.size > 1 else 1.0


# =========================================================================
# Topology protocol
# =========================================================================
class Topology(abc.ABC):
    """Communication graph over the ``n`` players.

    Implementations are frozen hashable dataclasses (jit static arguments;
    randomized graphs carry an int seed). ``n`` is supplied at use time so one
    topology object serves any player count.
    """

    name: str = "topology"
    is_server: bool = False   # Star: exact broadcast, the legacy engine path

    @abc.abstractmethod
    def adjacency(self, n: int) -> np.ndarray:
        """Boolean ``(n, n)`` symmetric peer adjacency, no self-loops."""

    def mixing_matrix(self, n: int) -> np.ndarray:
        """Doubly-stochastic ``(n, n)`` gossip weights (Metropolis)."""
        return metropolis_weights(self.adjacency(n))

    # Time-varying topologies expose a stack of per-round matrices, cycled by
    # round index; static graphs are the T = 1 special case.
    def mixing_stack(self, n: int) -> np.ndarray:
        return self.mixing_matrix(n)[None]

    def adjacency_stack(self, n: int) -> np.ndarray:
        return self.adjacency(n)[None]

    def degrees(self, n: int) -> np.ndarray:
        return self.adjacency(n).sum(axis=1).astype(np.int64)

    def directed_edge_counts(self, n: int) -> np.ndarray:
        """Directed active-link count per stacked graph, shape ``(T,)`` —
        the number of wire messages a full-participation gossip round moves."""
        return self.adjacency_stack(n).sum(axis=(1, 2)).astype(np.int64)

    def connected(self, n: int) -> bool:
        """Connectivity of the union graph (B-connectivity for time-varying)."""
        return is_connected(self.adjacency_stack(n).any(axis=0))


@dataclasses.dataclass(frozen=True)
class Star(Topology):
    """Hub-and-spoke server — the paper's Algorithm 1 pattern (the default).

    The engine treats the server as an exact broadcast (the bit-for-bit
    legacy path), so the peer adjacency is empty; as a mixing matrix the
    server's exact mean is ``ones / n`` (used by the trainer's consensus
    reference weighting).
    """

    name: str = "star"
    is_server = True

    def adjacency(self, n):
        return np.zeros((n, n), dtype=bool)

    def mixing_matrix(self, n):
        return np.full((n, n), 1.0 / n)


@dataclasses.dataclass(frozen=True)
class Ring(Topology):
    """Cycle graph: each player exchanges with its two neighbors (deg 2)."""

    name: str = "ring"

    def adjacency(self, n):
        A = np.zeros((n, n), dtype=bool)
        if n > 1:
            idx = np.arange(n)
            A[idx, (idx + 1) % n] = True
            A[idx, (idx - 1) % n] = True
        return A


@dataclasses.dataclass(frozen=True)
class Torus(Topology):
    """2-D grid with wraparound (deg <= 4). ``rows`` defaults to the largest
    divisor of ``n`` at most ``sqrt(n)`` (prime ``n`` degenerates to a ring).
    """

    rows: int | None = None
    name: str = "torus"

    def _dims(self, n: int) -> tuple[int, int]:
        if self.rows is not None:
            if n % self.rows:
                raise ValueError(f"Torus(rows={self.rows}) does not divide n={n}")
            return self.rows, n // self.rows
        r = max(d for d in range(1, int(math.isqrt(n)) + 1) if n % d == 0)
        return r, n // r

    def adjacency(self, n):
        rows, cols = self._dims(n)
        A = np.zeros((n, n), dtype=bool)
        for i in range(n):
            r, c = divmod(i, cols)
            for rr, cc in (((r + 1) % rows, c), ((r - 1) % rows, c),
                           (r, (c + 1) % cols), (r, (c - 1) % cols)):
                j = rr * cols + cc
                if j != i:
                    A[i, j] = A[j, i] = True
        return A


@dataclasses.dataclass(frozen=True)
class ErdosRenyi(Topology):
    """G(n, p) random graph, reproducible from ``seed``. May be disconnected —
    check :meth:`Topology.connected` before expecting equilibrium."""

    p: float = 0.5
    seed: int = 0
    name: str = "erdos_renyi"

    def __post_init__(self):
        if not 0.0 <= self.p <= 1.0:
            raise ValueError(f"ErdosRenyi.p must be in [0, 1], got {self.p}")

    def adjacency(self, n):
        rng = np.random.default_rng(self.seed)
        upper = np.triu(rng.random((n, n)) < self.p, k=1)
        return upper | upper.T


@dataclasses.dataclass(frozen=True)
class ResampledErdosRenyi(Topology):
    """Per-round resampled G(n, p): round ``r`` mixes over a FRESH
    Erdos-Renyi draw — sampled-interaction gossip, the graph-world analogue
    of ``MeanFieldView(sample=k)``'s per-round neighbor subsets.

    PRNG discipline (the per-round key-hierarchy fix): round ``r``'s graph
    comes from its OWN dedicated stream ``default_rng([seed, r])`` rather
    than one sequential stream, so graph ``r`` is derivable without
    replaying rounds ``0..r-1`` and every consumer — the host engine, the
    mesh lowering, diagnostics — reconstructs the identical stack from
    ``(seed, r)`` alone (a sequential stream would pin the realization to
    whoever drew first and in what order). The engines index the
    precomputed stacks by ``round % period`` on the host and mesh paths
    alike, so resampled rounds are reproducible across both lowerings by
    construction. ``period`` bounds the stack memory: rounds cycle through
    ``period`` independent draws.

    Connectivity (:meth:`Topology.connected`) is of the UNION graph —
    B-connectivity, the right notion for time-varying mixing.
    """

    p: float = 0.5
    seed: int = 0
    period: int = 8
    name: str = "resampled_erdos_renyi"

    def __post_init__(self):
        if not 0.0 <= self.p <= 1.0:
            raise ValueError(
                f"ResampledErdosRenyi.p must be in [0, 1], got {self.p}")
        if self.period < 1:
            raise ValueError(
                f"ResampledErdosRenyi.period must be >= 1, got {self.period}")

    def _graph(self, n: int, r: int) -> np.ndarray:
        """Round ``r``'s draw — a pure function of ``(seed, r, n, p)``."""
        rng = np.random.default_rng([self.seed, r])
        upper = np.triu(rng.random((n, n)) < self.p, k=1)
        return upper | upper.T

    def adjacency(self, n):
        # the union graph: degree/connectivity diagnostics see every edge
        # that is ever active within one period
        return self.adjacency_stack(n).any(axis=0)

    def adjacency_stack(self, n):
        return np.stack([self._graph(n, r) for r in range(self.period)])

    def mixing_stack(self, n):
        return np.stack([metropolis_weights(self._graph(n, r))
                         for r in range(self.period)])


@dataclasses.dataclass(frozen=True)
class ExplicitGraph(Topology):
    """Arbitrary undirected edge list — e.g. deliberately disconnected
    components for the no-equilibrium counterexamples."""

    edges: tuple[tuple[int, int], ...] = ()
    name: str = "explicit"

    def adjacency(self, n):
        A = np.zeros((n, n), dtype=bool)
        for i, j in self.edges:
            if not (0 <= i < n and 0 <= j < n) or i == j:
                raise ValueError(f"bad edge ({i}, {j}) for n={n}")
            A[i, j] = A[j, i] = True
        return A


@dataclasses.dataclass(frozen=True)
class TimeVarying(Topology):
    """Cycle through member graphs round-robin (round ``r`` uses member
    ``r % T``). Convergence needs the *union* graph connected (B-connectivity),
    not every member."""

    members: tuple[Topology, ...] = ()
    name: str = "time_varying"

    def __post_init__(self):
        if not self.members:
            raise ValueError("TimeVarying needs at least one member topology")
        for m in self.members:
            if m.is_server or isinstance(m, TimeVarying):
                raise ValueError(
                    "TimeVarying members must be flat graph topologies, got "
                    f"{type(m).__name__}"
                )

    def adjacency(self, n):
        return self.adjacency_stack(n).any(axis=0)

    def mixing_stack(self, n):
        return np.concatenate([m.mixing_stack(n) for m in self.members])

    def adjacency_stack(self, n):
        return np.concatenate([m.adjacency_stack(n) for m in self.members])


# =========================================================================
# Shared direction-aware byte accounting
# =========================================================================
def direction_itemsizes(sync, base_itemsize: int, *,
                        compressed: str) -> tuple[int | float, int | float]:
    """(uplink, downlink) bytes per scalar for a sync strategy — THE one
    place both accounting systems resolve the quantization direction.

    The dense engine's :class:`~repro.core.engine.QuantizedSync` compresses
    the *broadcast* (players see quantized neighbor blocks, upload exact):
    ``compressed="down"``. The neural trainer quantizes *pre-reduction*
    (uplink at the wire dtype, f32 mean broadcast back): ``compressed="up"``.
    ``sync.wire_itemsize(base_itemsize)`` supplies the wire dtype's size —
    fractional for sub-byte wires (int4 packs two lanes per byte, 0.5 B per
    scalar); the byte totals below stay exact integers because sub-byte
    strategies require an even block dimension.
    """
    wire = float(sync.wire_itemsize(base_itemsize))
    if wire == int(wire):
        wire = int(wire)
    if compressed == "down":
        return int(base_itemsize), wire
    if compressed == "up":
        return wire, int(base_itemsize)
    raise ValueError(f"compressed must be 'up' or 'down', got {compressed!r}")


def star_round_bytes(participants, *, n: int, block_scalars: int,
                     up_itemsize: int, down_itemsize: int,
                     down_blocks: int | None = None
                     ) -> tuple[np.ndarray, np.ndarray]:
    """Per-round (uplink, downlink) bytes for the server topology.

    Each participant uploads its ``block_scalars`` block once and downloads
    ``down_blocks`` blocks — by default the ``n``-block joint vector (the
    Section 3.1 convention for general games); the aggregative consensus
    trainer passes ``down_blocks=1``, since its server rebroadcasts only the
    mean. ``participants`` may be a scalar or a per-round array; output is
    int64.
    """
    if down_blocks is None:
        down_blocks = n
    p = np.atleast_1d(np.asarray(participants)).astype(np.int64)
    # float math + rint keeps sub-byte itemsizes exact (even block dims only)
    up = np.rint(p * float(block_scalars) * up_itemsize).astype(np.int64)
    down = np.rint(p * float(down_blocks * block_scalars)
                   * down_itemsize).astype(np.int64)
    return up, down


def gossip_round_bytes(messages, *, payload_blocks: int, block_scalars: int,
                       itemsize: float) -> tuple[np.ndarray, np.ndarray]:
    """Per-round (sent, received=0) bytes for server-free topologies.

    ``messages`` is the directed active-link count per round; each message
    carries ``payload_blocks`` blocks of ``block_scalars`` scalars at the
    wire ``itemsize``. Peer exchanges have no server downlink: every wire
    transfer is counted exactly once, in the first ("sent") component, so
    ``up + down`` never double-counts an edge.
    """
    m = np.atleast_1d(np.asarray(messages)).astype(np.int64)
    sent = np.rint(m * float(payload_blocks * block_scalars)
                   * itemsize).astype(np.int64)
    return sent, np.zeros_like(sent)


# ------------------------------------------------------------------ registry
TOPOLOGIES = {
    "star": Star,
    "ring": Ring,
    "torus": Torus,
    "erdos_renyi": lambda: ErdosRenyi(p=0.5, seed=2),
    "resampled_erdos_renyi": lambda: ResampledErdosRenyi(p=0.5, seed=2),
    "ring+torus": lambda: TimeVarying((Ring(), Torus())),
}
