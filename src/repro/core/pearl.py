"""PEARL-SGD — Per-Player Local SGD (paper Algorithm 1).

Every round ``p``:
  1. the server distributes the joint snapshot ``x_{tau p}`` to all players;
  2. each player ``i`` runs ``tau`` SGD steps on its own block with everyone
     else frozen at the snapshot:
         x^i_{k+1} = x^i_k - gamma_k * grad f_{i, xi}(x^i_k ; x^{-i}_{tau p});
  3. the server collects the updated blocks (synchronization).

Here the whole round is a single compiled program: the ``tau`` local steps are
a ``jax.lax.scan`` per player, players run under ``vmap``, and rounds are an
outer ``scan`` — mirroring the fact that no communication happens inside a
round. For the production multi-pod variant where each player owns a sharded
LLM, see :mod:`repro.train.pearl_trainer` (players = pods; synchronization =
the only cross-pod collective).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.game import VectorGame

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class PearlResult:
    """Trajectory diagnostics recorded at synchronization points."""

    x_final: Array          # (n, d) final joint action x_{tau R}
    rel_errors: np.ndarray  # (R+1,) ||x_{tau p} - x*||^2 / ||x_0 - x*||^2
    residuals: np.ndarray   # (R+1,) ||F(x_{tau p})||
    tau: int
    rounds: int

    @property
    def iterations(self) -> int:
        return self.tau * self.rounds

    @property
    def communications(self) -> int:
        """Number of synchronization rounds (the paper's communication cost)."""
        return self.rounds


def _as_round_gammas(gamma, rounds: int) -> jnp.ndarray:
    """Normalize a step-size spec to a per-round array of shape (rounds,).

    Accepts a scalar (constant step-size, Thms 3.3/3.4 and Cor 3.5) or an
    array of per-round values (Thm 3.6's round-indexed schedule — the paper
    keeps gamma_k constant *within* each round).
    """
    g = jnp.asarray(gamma, dtype=jnp.float64 if jax.config.read("jax_enable_x64") else jnp.float32)
    if g.ndim == 0:
        return jnp.full((rounds,), g)
    if g.shape != (rounds,):
        raise ValueError(f"gamma must be scalar or shape ({rounds},), got {g.shape}")
    return g


@partial(jax.jit, static_argnames=("tau", "rounds", "stochastic", "sync_dtype"))
def _run(game: VectorGame, x0: Array, gammas: Array, key: Array, *,
         tau: int, rounds: int, stochastic: bool, sync_dtype=None):
    n = x0.shape[0]

    def local_updates(i, x_sync, gamma, key):
        """tau local SGD steps for player i against the frozen snapshot.

        With ``sync_dtype`` the player sees a QUANTIZED view of the others'
        blocks (compressed broadcast) while keeping its own block exact.
        """
        if sync_dtype is not None:
            x_ref = x_sync.astype(sync_dtype).astype(x_sync.dtype)
            x_ref = x_ref.at[i].set(x_sync[i])
        else:
            x_ref = x_sync

        def step(x_i, k):
            if stochastic:
                g = game.player_grad_stoch(i, x_i, x_ref, k)
            else:
                g = game.player_grad(i, x_i, x_ref)
            return x_i - gamma * g, None

        keys = jax.random.split(key, tau)
        x_i, _ = jax.lax.scan(step, x_sync[i], keys)
        return x_i

    def round_body(carry, inp):
        x_sync, key = carry
        gamma = inp
        key, sub = jax.random.split(key)
        player_keys = jax.random.split(sub, n)
        # All players update in parallel, then the server concatenates: the
        # new joint snapshot IS the synchronization step.
        x_next = jax.vmap(local_updates, in_axes=(0, None, None, 0))(
            jnp.arange(n), x_sync, gamma, player_keys
        )
        res = jnp.sqrt(jnp.sum(game.operator(x_next) ** 2))
        return (x_next, key), (x_next, res)

    (x_final, _), (xs, residuals) = jax.lax.scan(round_body, (x0, key), gammas)
    return x_final, xs, residuals


def pearl_sgd(
    game: VectorGame,
    x0: Array,
    *,
    tau: int,
    rounds: int,
    gamma,
    key: Array | None = None,
    stochastic: bool = True,
    x_star: Array | None = None,
    sync_dtype=None,
) -> PearlResult:
    """Run PEARL-SGD (Algorithm 1) and record sync-point diagnostics.

    Args:
      game:       the n-player game.
      x0:         initial joint action, shape ``(n, d)``.
      tau:        synchronization interval (local steps per round).
      rounds:     number of communication rounds ``R``.
      gamma:      scalar constant step-size or per-round array (Thm 3.6).
      key:        PRNG key (required when ``stochastic=True``).
      stochastic: use the players' stochastic oracles (Thm 3.4/3.6) or the
                  full-batch gradients (Thm 3.3).
      x_star:     equilibrium for error tracking; defaults to
                  ``game.equilibrium()``.
      sync_dtype: quantize the server broadcast (e.g. jnp.bfloat16) — the
                  paper's compression future-work composed with local steps.
    """
    if key is None:
        key = jax.random.PRNGKey(0)
    if x_star is None:
        x_star = game.equilibrium()
    gammas = _as_round_gammas(gamma, rounds)
    x_final, xs, residuals = _run(
        game, x0, gammas, key, tau=tau, rounds=rounds, stochastic=stochastic,
        sync_dtype=sync_dtype,
    )
    init_err_sq = jnp.sum((x0 - x_star) ** 2)
    errs = jnp.sum((xs - x_star[None]) ** 2, axis=(1, 2)) / init_err_sq
    res0 = jnp.sqrt(jnp.sum(game.operator(x0) ** 2))
    rel_errors = np.concatenate([[1.0], np.asarray(errs)])
    residuals = np.concatenate([[float(res0)], np.asarray(residuals)])
    return PearlResult(
        x_final=x_final,
        rel_errors=rel_errors,
        residuals=residuals,
        tau=tau,
        rounds=rounds,
    )


def pearl_sgd_mean(
    game: VectorGame,
    x0: Array,
    *,
    tau: int,
    rounds: int,
    gamma,
    n_seeds: int = 5,
    seed: int = 0,
    x_star: Array | None = None,
) -> tuple[np.ndarray, np.ndarray]:
    """Repeat stochastic PEARL-SGD over seeds; return (mean, std) of rel-error.

    Matches the paper's Figure 2b protocol (5 independent runs, mean +/- std).
    """
    runs = []
    for s in range(n_seeds):
        r = pearl_sgd(
            game, x0, tau=tau, rounds=rounds, gamma=gamma,
            key=jax.random.PRNGKey(seed + s), stochastic=True, x_star=x_star,
        )
        runs.append(r.rel_errors)
    arr = np.stack(runs)
    return arr.mean(axis=0), arr.std(axis=0)
