"""PEARL-SGD — Per-Player Local SGD (paper Algorithm 1).

Every round ``p``:
  1. the server distributes the joint snapshot ``x_{tau p}`` to all players;
  2. each player ``i`` runs ``tau`` SGD steps on its own block with everyone
     else frozen at the snapshot:
         x^i_{k+1} = x^i_k - gamma_k * grad f_{i, xi}(x^i_k ; x^{-i}_{tau p});
  3. the server collects the updated blocks (synchronization).

This module is now a thin adapter over :class:`repro.core.engine.PearlEngine`
(SGD local update x exact-or-quantized sync): the rounds-scan, vmap over
players, and communication accounting all live in the engine. For the
production multi-pod variant where each player owns a sharded LLM, see
:mod:`repro.train.pearl_trainer` (players = pods; synchronization = the only
cross-pod collective).
"""

from __future__ import annotations

import jax
import numpy as np

from repro.core.engine import (
    PearlEngine,
    PearlResult,
    SgdUpdate,
    SyncStrategy,
    as_round_gammas,
    resolve_sync,
)
from repro.core.game import VectorGame
from repro.core.spec import warn_legacy

Array = jax.Array

# Back-compat aliases: PearlResult and the gamma normalizer originated here.
_as_round_gammas = as_round_gammas

__all__ = ["PearlResult", "pearl_sgd", "pearl_sgd_mean"]


def pearl_sgd(
    game: VectorGame,
    x0: Array,
    *,
    tau: int,
    rounds: int,
    gamma,
    key: Array | None = None,
    stochastic: bool = True,
    x_star: Array | None = None,
    sync_dtype=None,
    sync: SyncStrategy | None = None,
) -> PearlResult:
    """Run PEARL-SGD (Algorithm 1) and record sync-point diagnostics.

    Args:
      game:       the n-player game.
      x0:         initial joint action, shape ``(n, d)``.
      tau:        synchronization interval (local steps per round).
      rounds:     number of communication rounds ``R``.
      gamma:      scalar constant step-size, per-round array (Thm 3.6), or a
                  schedule callable ``rounds -> array``.
      key:        PRNG key (required when ``stochastic=True``).
      stochastic: use the players' stochastic oracles (Thm 3.4/3.6) or the
                  full-batch gradients (Thm 3.3).
      x_star:     equilibrium for error tracking; defaults to
                  ``game.equilibrium()``.
      sync_dtype: quantize the server broadcast (e.g. jnp.bfloat16) — shorthand
                  for ``sync=QuantizedSync(sync_dtype)``.
      sync:       any :class:`repro.core.engine.SyncStrategy` (exact,
                  quantized, partial participation, dropout links).
    """
    warn_legacy(
        "pearl_sgd",
        "construct PearlEngine(spec=EngineSpec(update=SgdUpdate(), "
        "sync=...)) and call .run(...) — same compiled round, every axis "
        "in one place",
    )
    engine = PearlEngine(update=SgdUpdate(), sync=resolve_sync(sync, sync_dtype))
    return engine.run(
        game, x0, tau=tau, rounds=rounds, gamma=gamma, key=key,
        stochastic=stochastic, x_star=x_star,
    )


def pearl_sgd_mean(
    game: VectorGame,
    x0: Array,
    *,
    tau: int,
    rounds: int,
    gamma,
    n_seeds: int = 5,
    seed: int = 0,
    x_star: Array | None = None,
) -> tuple[np.ndarray, np.ndarray]:
    """Repeat stochastic PEARL-SGD over seeds; return (mean, std) of rel-error.

    Matches the paper's Figure 2b protocol (5 independent runs, mean +/- std).
    """
    warn_legacy(
        "pearl_sgd_mean",
        "construct PearlEngine(spec=EngineSpec(update=SgdUpdate())) and "
        "loop .run(...) over seeds — the adapter only stacks rel_errors",
    )
    runs = []
    for s in range(n_seeds):
        r = pearl_sgd(
            game, x0, tau=tau, rounds=rounds, gamma=gamma,
            key=jax.random.PRNGKey(seed + s), stochastic=True, x_star=x_star,
        )
        runs.append(r.rel_errors)
    arr = np.stack(runs)
    return arr.mean(axis=0), arr.std(axis=0)
