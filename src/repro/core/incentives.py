"""Strategic participation: the round mask as a best-response equilibrium.

Every policy on the selection axis so far — value-driven or not — is
SERVER-dictated: the server decides who talks and the chosen players comply.
The paper models clients as rational players, and in deployment they are:
a player burns compute, battery, and bandwidth to participate, and joins
only when what it gets back exceeds that cost (*Incentive-Aware Federated
Averaging under Strategic Participation*; *Federated Learning as a Network
Effects Game* — PAPERS.md). This module makes participation itself a game
layered on top of the equilibrium game:

- each player ``i`` carries a private per-round cost of participation
  ``c_i`` (a fixed heterogeneous grid, or caller-supplied);
- its benefit from a round has two parts: the server's **payment** and the
  **progress value** of the round to it. Progress value reuses the
  GTG-Shapley closed form the selection layer already estimates
  (:func:`repro.core.selection.shapley_progress` through the same
  EWM ``observe``): a player whose deltas kept mattering expects its next
  round to matter, scaled by the network effect — a round with more
  participants moves the joint state further, so the per-player progress
  value grows with the participation rate ``k/n`` (the network-effects
  game's defining externality);
- the server sets the payment rule (the mechanism-design knob):
  ``"fixed"`` pays every participant ``price``, ``"proportional"`` pays
  ``price`` scaled by the player's normalized value estimate (pay the
  useful players more), ``"auction"`` splits a fixed per-round ``budget``
  equally among whoever shows up (a budget-balanced all-pay share).

The round mask is then a **simultaneous-move best-response fixed point**:
starting from everyone-in, each sweep recomputes every player's join/stay
decision against the others' current decisions, ``br_iters`` times. For the
``fixed``/``proportional`` rules the payment does not depend on the
coalition and the progress value is increasing in it, so the best-response
map is monotone: from the all-ones start the sweep can only remove players
and the iteration converges monotonically DOWN to the LARGEST equilibrium
(the server-optimistic one) in at most ``n`` sweeps — ``br_iters`` bounds
the cascade depth per round, and a cascade longer than ``br_iters`` resumes
from the same all-ones start next round (the documented non-convergence
fallback: the LAST sweep's mask is used as-is; it over-includes, never
under-includes). The ``auction`` rule is non-monotone (more joiners dilute
the share), so its iteration can 2-cycle; the same last-sweep fallback
applies and is the honest semantics: a simultaneous-move crowd oscillating
around the zero-profit coalition size.

The whole layer is ONE :class:`~repro.core.selection.SelectionPolicy`
subclass, so it threads through :class:`~repro.core.engine.PearlEngine`,
:class:`~repro.core.async_engine.AsyncPearlEngine` (the best responses see
the drawn staleness row: ``staleness_discount`` charges a player for acting
on a stale broadcast, so stale players rationally sit out), and the
trainer's general merge with zero new engine plumbing — the engines cannot
tell a dictated mask from an equilibrium one.

The honest negative this layer exists to expose (pinned in
``BENCH_incentives.json``): price the participation below cost and the
network effect runs BACKWARD — each dropout lowers everyone else's
progress value, which drops more players, the free-rider death spiral of
the network-effects game. An all-False round mask is a legitimate
equilibrium (nobody syncs, the joint state freezes), and the benchmark's
collapse row records exactly where the spiral starts. The closed-form
equilibrium of the continuum game lives in
:mod:`repro.core.games.participation` and is what the tests pin this
policy's realized masks against.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core.selection import SELECTION_POLICIES, SelectionPolicy

__all__ = ["BestResponseParticipation", "PAYMENT_RULES"]

#: the server's payment mechanisms
PAYMENT_RULES = ("fixed", "proportional", "auction")


@dataclasses.dataclass(frozen=True)
class BestResponseParticipation(SelectionPolicy):
    """Participation as a game: the mask is a best-response fixed point.

    Player ``i`` joins round ``r`` iff its utility against the others'
    current decisions is positive:

        u_i(m) = pay_i(k) + value_weight * vhat_i * (k / n)
                 - c_i - staleness_discount * delay_i,    k = |m| with i in,

    where ``vhat_i`` is the selection layer's EWM Shapley value estimate
    normalized to ``[0, 1]`` (unseen players are optimistic at 1.0 — every
    player tries participating before learning it doesn't pay), and
    ``pay_i`` follows the ``payment`` rule. ``fraction`` is inherited from
    the selection surface but NOT a budget here: participation is
    endogenous, the realized rate is an outcome (the benchmark measures
    it), and ``fraction`` stays at its default 1.0.

    Costs default to the fixed heterogeneous midpoint grid
    ``c_i = cost_min + (i + 1/2)(cost_max - cost_min)/n`` — the discrete
    sampling of the uniform cost distribution whose continuum game
    (:class:`repro.core.games.participation.NetworkEffectsParticipationGame`)
    has the closed-form equilibrium the tests pin against. Pass ``costs``
    (a length-``n`` tuple, kept hashable for the jit-static policy) to
    override.
    """

    fraction: float = 1.0
    memory: float = 0.9
    aging: float = 0.0
    payment: str = "fixed"
    price: float = 0.5
    budget: float = 0.0
    cost_min: float = 0.2
    cost_max: float = 0.8
    costs: tuple[float, ...] | None = None
    value_weight: float = 1.0
    staleness_discount: float = 0.0
    br_iters: int = 16
    seed: int = 0
    name: str = "best_response"

    def __post_init__(self):
        self._validate_fraction()
        if self.payment not in PAYMENT_RULES:
            raise ValueError(
                f"BestResponseParticipation.payment must be one of "
                f"{PAYMENT_RULES}, got {self.payment!r}"
            )
        if not 0.0 <= self.memory < 1.0:
            raise ValueError(
                f"BestResponseParticipation.memory must be in [0, 1), "
                f"got {self.memory}"
            )
        if self.price < 0.0:
            raise ValueError(
                f"BestResponseParticipation.price must be >= 0, "
                f"got {self.price}"
            )
        if self.budget < 0.0:
            raise ValueError(
                f"BestResponseParticipation.budget must be >= 0, "
                f"got {self.budget}"
            )
        if self.costs is None and not self.cost_min <= self.cost_max:
            raise ValueError(
                f"BestResponseParticipation needs cost_min <= cost_max, "
                f"got [{self.cost_min}, {self.cost_max}]"
            )
        if self.value_weight < 0.0:
            raise ValueError(
                f"BestResponseParticipation.value_weight must be >= 0, "
                f"got {self.value_weight}"
            )
        if self.staleness_discount < 0.0:
            raise ValueError(
                f"BestResponseParticipation.staleness_discount must be "
                f">= 0, got {self.staleness_discount}"
            )
        if self.br_iters < 1:
            raise ValueError(
                f"BestResponseParticipation.br_iters must be >= 1, "
                f"got {self.br_iters}"
            )

    # ------------------------------------------------------------- pieces
    def cost_vector(self, n: int):
        """The (n,) per-player participation costs (jit-constant)."""
        if self.costs is not None:
            if len(self.costs) != n:
                raise ValueError(
                    f"BestResponseParticipation.costs has "
                    f"{len(self.costs)} entries for n={n} players"
                )
            return jnp.asarray(self.costs, jnp.float32)
        span = self.cost_max - self.cost_min
        return (self.cost_min
                + (jnp.arange(n, dtype=jnp.float32) + 0.5) * (span / n))

    def value_estimates(self, state):
        """EWM Shapley values normalized to [0, 1]; unseen players are
        optimistic at 1.0 (everyone tries participating once)."""
        vhat = state["values"] / (jnp.max(jnp.abs(state["values"])) + 1e-30)
        vhat = jnp.clip(vhat, 0.0, 1.0)
        return jnp.where(state["counts"] > 0, vhat, 1.0)

    def _payment(self, vhat, k, n: int):
        """pay_i for a coalition of size ``k`` (i included)."""
        if self.payment == "fixed":
            return jnp.full_like(vhat, self.price)
        if self.payment == "proportional":
            return self.price * vhat
        # auction: the per-round budget split equally among participants
        return jnp.full_like(vhat, self.budget) / jnp.maximum(k, 1.0)

    # ----------------------------------------------------------- protocol
    def select(self, state, n: int, ridx, delay_row):
        del ridx
        vhat = self.value_estimates(state)
        cost = self.cost_vector(n)
        if delay_row is not None and self.staleness_discount > 0.0:
            cost = cost + self.staleness_discount * jnp.asarray(
                delay_row, jnp.float32)
        m = jnp.ones((n,), dtype=bool)
        # simultaneous-move best-response sweeps from the all-ones start
        # (monotone rules converge DOWN to the largest equilibrium; the
        # last sweep is the documented non-convergence fallback)
        for _ in range(self.br_iters):
            k_others = jnp.sum(m.astype(jnp.float32)) - m.astype(jnp.float32)
            k_if_join = k_others + 1.0
            u = (self._payment(vhat, k_if_join, n)
                 + self.value_weight * vhat * (k_if_join / n)
                 - cost)
            m = u > 0.0
        return state, m


SELECTION_POLICIES["best_response"] = BestResponseParticipation
