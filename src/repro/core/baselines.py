"""Baselines and beyond-paper variants for the MpFL setting.

- :func:`sgda`             — the non-local counterpart (PEARL-SGD with tau=1,
  i.e. stochastic gradient play / simultaneous SGDA); the paper's main
  comparison point for communication complexity.
- :func:`local_sgd_on_sum` — classical Local SGD applied to the joint variable
  on the *summed* objective; provably wrong for games (Section B, Figure 4).
- :func:`extragradient`    — full-synchronization stochastic extragradient on
  the joint operator (Korpelevich); listed by the paper as future work — we
  include it as a stronger fully-communicating baseline.
- :func:`pearl_eg`         — **beyond-paper**: per-player *local extragradient*
  with the same stale-snapshot communication pattern as PEARL-SGD.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.game import VectorGame
from repro.core.pearl import PearlResult, _as_round_gammas

Array = jax.Array


def sgda(game: VectorGame, x0: Array, *, steps: int, gamma, key=None,
         stochastic: bool = True, x_star=None) -> PearlResult:
    """Simultaneous stochastic gradient play — PEARL-SGD with tau = 1."""
    from repro.core.pearl import pearl_sgd

    return pearl_sgd(
        game, x0, tau=1, rounds=steps, gamma=gamma, key=key,
        stochastic=stochastic, x_star=x_star,
    )


@partial(jax.jit, static_argnames=("steps", "stochastic"))
def _local_sgd_sum_run(game, x0, gamma, key, *, steps: int, stochastic: bool):
    def step(carry, _):
        x, key = carry
        key, sub = jax.random.split(key)
        g = game.sum_gradient(x, sub if stochastic else None)
        x = x - gamma * g
        f1 = game.objective(0, x)
        f2 = game.objective(1, x)
        return (x, key), (f1, f2, jnp.sqrt(jnp.sum(x**2)))

    (x, _), (f1s, f2s, norms) = jax.lax.scan(step, (x0, key), None, length=steps)
    return x, f1s, f2s, norms


def local_sgd_on_sum(game, x0: Array, *, steps: int, gamma: float,
                     key=None, stochastic: bool = False):
    """Local SGD on the summed objective of the Section B counterexample.

    Returns (x_final, f1_trace, f2_trace, ||x||_trace). With
    ``lambda_min(A) < 1/10`` the iterates (and one objective) diverge — the
    Figure 4(left) phenomenon showing classical FL algorithms cannot solve
    MpFL.
    """
    if key is None:
        key = jax.random.PRNGKey(0)
    x, f1s, f2s, norms = _local_sgd_sum_run(
        game, x0, gamma, key, steps=steps, stochastic=stochastic
    )
    return x, np.asarray(f1s), np.asarray(f2s), np.asarray(norms)


@partial(jax.jit, static_argnames=("steps", "stochastic"))
def _eg_run(game, x0, gammas, key, *, steps: int, stochastic: bool):
    def step(carry, gamma):
        x, key = carry
        key, k1, k2 = jax.random.split(key, 3)
        if stochastic:
            g_half = game.operator_stoch(x, k1)
            x_half = x - gamma * g_half
            g = game.operator_stoch(x_half, k2)
        else:
            x_half = x - gamma * game.operator(x)
            g = game.operator(x_half)
        x_new = x - gamma * g
        res = jnp.sqrt(jnp.sum(game.operator(x_new) ** 2))
        return (x_new, key), (x_new, res)

    (x, _), (xs, res) = jax.lax.scan(step, (x0, key), gammas)
    return x, xs, res


def extragradient(game: VectorGame, x0: Array, *, steps: int, gamma,
                  key=None, stochastic: bool = True, x_star=None) -> PearlResult:
    """Fully-communicating stochastic extragradient (two syncs per step)."""
    if key is None:
        key = jax.random.PRNGKey(0)
    if x_star is None:
        x_star = game.equilibrium()
    gammas = _as_round_gammas(gamma, steps)
    x_final, xs, residuals = _eg_run(game, x0, gammas, key, steps=steps,
                                     stochastic=stochastic)
    init = jnp.sum((x0 - x_star) ** 2)
    errs = jnp.sum((xs - x_star[None]) ** 2, axis=(1, 2)) / init
    res0 = float(jnp.sqrt(jnp.sum(game.operator(x0) ** 2)))
    return PearlResult(
        x_final=x_final,
        rel_errors=np.concatenate([[1.0], np.asarray(errs)]),
        residuals=np.concatenate([[res0], np.asarray(residuals)]),
        tau=1,
        rounds=steps,
    )


@partial(jax.jit, static_argnames=("tau", "rounds", "stochastic"))
def _pearl_eg_run(game, x0, gammas, key, *, tau: int, rounds: int, stochastic: bool):
    n = x0.shape[0]

    def local(i, x_sync, gamma, key):
        def step(x_i, k):
            k1, k2 = jax.random.split(k)
            if stochastic:
                g_half = game.player_grad_stoch(i, x_i, x_sync, k1)
                x_half = x_i - gamma * g_half
                g = game.player_grad_stoch(i, x_half, x_sync, k2)
            else:
                x_half = x_i - gamma * game.player_grad(i, x_i, x_sync)
                g = game.player_grad(i, x_half, x_sync)
            return x_i - gamma * g, None

        keys = jax.random.split(key, tau)
        x_i, _ = jax.lax.scan(step, x_sync[i], keys)
        return x_i

    def round_body(carry, gamma):
        x_sync, key = carry
        key, sub = jax.random.split(key)
        pkeys = jax.random.split(sub, n)
        x_next = jax.vmap(local, in_axes=(0, None, None, 0))(
            jnp.arange(n), x_sync, gamma, pkeys
        )
        res = jnp.sqrt(jnp.sum(game.operator(x_next) ** 2))
        return (x_next, key), (x_next, res)

    (x, _), (xs, res) = jax.lax.scan(round_body, (x0, key), gammas)
    return x, xs, res


def pearl_eg(game: VectorGame, x0: Array, *, tau: int, rounds: int, gamma,
             key=None, stochastic: bool = True, x_star=None) -> PearlResult:
    """Beyond-paper: Per-Player Local *ExtraGradient* with PEARL communication.

    Each player runs tau extragradient steps on its own block against the
    stale snapshot; one synchronization per round. The paper's conclusion
    lists extragradient incorporation as future work.
    """
    if key is None:
        key = jax.random.PRNGKey(0)
    if x_star is None:
        x_star = game.equilibrium()
    gammas = _as_round_gammas(gamma, rounds)
    x_final, xs, residuals = _pearl_eg_run(
        game, x0, gammas, key, tau=tau, rounds=rounds, stochastic=stochastic
    )
    init = jnp.sum((x0 - x_star) ** 2)
    errs = jnp.sum((xs - x_star[None]) ** 2, axis=(1, 2)) / init
    res0 = float(jnp.sqrt(jnp.sum(game.operator(x0) ** 2)))
    return PearlResult(
        x_final=x_final,
        rel_errors=np.concatenate([[1.0], np.asarray(errs)]),
        residuals=np.concatenate([[res0], np.asarray(residuals)]),
        tau=tau,
        rounds=rounds,
    )
