"""Baselines and beyond-paper variants for the MpFL setting.

- :func:`sgda`             — the non-local counterpart (PEARL-SGD with tau=1,
  i.e. stochastic gradient play / simultaneous SGDA); the paper's main
  comparison point for communication complexity.
- :func:`local_sgd_on_sum` — classical Local SGD applied to the joint variable
  on the *summed* objective; provably wrong for games (Section B, Figure 4).
- :func:`extragradient`    — full-synchronization stochastic extragradient on
  the joint operator (Korpelevich); listed by the paper as future work — we
  include it as a stronger fully-communicating baseline.
- :func:`pearl_eg`         — **beyond-paper**: per-player *local extragradient*
  with the same stale-snapshot communication pattern as PEARL-SGD.

All four are adapters over :class:`repro.core.engine.PearlEngine`: the local
variants plug a :class:`PlayerUpdate` into the shared rounds-scan; the
fully-communicating ones plug a :class:`JointUpdate` (their step reads fresh
iterates mid-round, which the per-player template cannot express).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import (
    ExtragradientUpdate,
    JointExtragradientUpdate,
    PearlEngine,
    PearlResult,
    SumLocalSgdUpdate,
)
from repro.core.game import VectorGame
from repro.core.spec import warn_legacy

Array = jax.Array


def sgda(game: VectorGame, x0: Array, *, steps: int, gamma, key=None,
         stochastic: bool = True, x_star=None) -> PearlResult:
    """Simultaneous stochastic gradient play — PEARL-SGD with tau = 1."""
    from repro.core.pearl import pearl_sgd

    warn_legacy(
        "sgda",
        "run PearlEngine(spec=EngineSpec(update=SgdUpdate())) with tau=1 — "
        "the baseline is the engine's own round at interval 1",
    )
    return pearl_sgd(
        game, x0, tau=1, rounds=steps, gamma=gamma, key=key,
        stochastic=stochastic, x_star=x_star,
    )


def extragradient(game: VectorGame, x0: Array, *, steps: int, gamma,
                  key=None, stochastic: bool = True, x_star=None) -> PearlResult:
    """Fully-communicating stochastic extragradient (two syncs per step)."""
    warn_legacy(
        "extragradient",
        "construct PearlEngine(spec=EngineSpec("
        "update=JointExtragradientUpdate())) and call .run(...)",
    )
    engine = PearlEngine(update=JointExtragradientUpdate())
    return engine.run(
        game, x0, rounds=steps, gamma=gamma, key=key, stochastic=stochastic,
        x_star=x_star,
    )


def pearl_eg(game: VectorGame, x0: Array, *, tau: int, rounds: int, gamma,
             key=None, stochastic: bool = True, x_star=None) -> PearlResult:
    """Beyond-paper: Per-Player Local *ExtraGradient* with PEARL communication.

    Each player runs tau extragradient steps on its own block against the
    stale snapshot; one synchronization per round. The paper's conclusion
    lists extragradient incorporation as future work.
    """
    warn_legacy(
        "pearl_eg",
        "construct PearlEngine(spec=EngineSpec("
        "update=ExtragradientUpdate())) and call .run(...)",
    )
    engine = PearlEngine(update=ExtragradientUpdate())
    return engine.run(
        game, x0, tau=tau, rounds=rounds, gamma=gamma, key=key,
        stochastic=stochastic, x_star=x_star,
    )


def local_sgd_on_sum(game, x0: Array, *, steps: int, gamma: float,
                     key=None, stochastic: bool = False):
    """Local SGD on the summed objective of the Section B counterexample.

    Returns (x_final, f1_trace, f2_trace, ||x||_trace). With
    ``lambda_min(A) < 1/10`` the iterates (and one objective) diverge — the
    Figure 4(left) phenomenon showing classical FL algorithms cannot solve
    MpFL. Runs through the engine's joint-update path; the per-step objective
    and norm traces are recovered from the recorded trajectory.
    """
    warn_legacy(
        "local_sgd_on_sum",
        "construct PearlEngine(spec=EngineSpec("
        "update=SumLocalSgdUpdate())) and call .trajectory(...)",
    )
    engine = PearlEngine(update=SumLocalSgdUpdate())
    xs = engine.trajectory(game, x0, rounds=steps, gamma=gamma, key=key,
                           stochastic=stochastic)
    f1s = jax.vmap(lambda x: game.objective(0, x))(xs)
    f2s = jax.vmap(lambda x: game.objective(1, x))(xs)
    norms = jnp.sqrt(jnp.sum(xs**2, axis=(1, 2)))
    return xs[-1], np.asarray(f1s), np.asarray(f2s), np.asarray(norms)
