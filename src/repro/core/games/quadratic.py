"""Quadratic n-player game (paper Section 4.1 / D.1).

Player ``i``'s objective is the finite sum

    f_i(x^i; x^{-i}) = (1/M) sum_m f_{i,m},
    f_{i,m} = 1/2 <x^i, A_{i,m} x^i> + sum_{j != i} <x^i, B_{i,j,m} x^j>
              + <a_{i,m}, x^i>.

Following Section D.1, the ``A_{i,m}`` are random symmetric matrices with
eigenvalues in ``[mu_A, L_A]`` and the couplings satisfy the antisymmetry
``B_{j,i,m} = -B_{i,j,m}^T``, which makes the joint operator ``F`` strongly
monotone with ``mu = min_i lambda_min(A_i)`` regardless of the coupling
strength (the bilinear terms cancel in ``<F(x)-F(y), x-y>``; see D.1).

The stochastic oracle mini-batches components ``m`` uniformly — exactly the
paper's experimental noise model (Figure 2b).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.game import (
    GameConstants,
    VectorGame,
    register_game,
    spectral_constants_from_block_matrix,
)

Array = jax.Array


@register_game(data=("A", "B", "a"), meta=("n", "d", "M", "batch_size"))
class QuadraticGame(VectorGame):
    """Finite-sum quadratic game. Shapes: A (n,M,d,d), B (n,n,M,d,d), a (n,M,d)."""

    A: Array
    B: Array
    a: Array
    n: int
    d: int
    M: int
    batch_size: int

    # -------------------------------------------------------------- gradients
    def _grad_from_batch(self, i: Array, x_i: Array, x_ref: Array, m: Array) -> Array:
        """Mean gradient over component indices ``m`` (shape (b,))."""
        A_b = jnp.mean(self.A[i, m], axis=0)          # (d, d)
        a_b = jnp.mean(self.a[i, m], axis=0)          # (d,)
        B_b = jnp.mean(self.B[i, :, m], axis=0)       # (n, d, d) mean over batch
        # B[i, i] is identically zero, so summing over all j is the sum over j != i.
        coupling = jnp.einsum("jde,je->d", B_b, x_ref)
        return A_b @ x_i + a_b + coupling

    def player_grad(self, i: Array, x_i: Array, x_ref: Array) -> Array:
        return self._grad_from_batch(i, x_i, x_ref, jnp.arange(self.M))

    def player_grad_stoch(self, i: Array, x_i: Array, x_ref: Array, key: Array) -> Array:
        m = jax.random.randint(key, (self.batch_size,), 0, self.M)
        return self._grad_from_batch(i, x_i, x_ref, m)

    def objective(self, i: int, x: Array) -> Array:
        A_i = jnp.mean(self.A[i], axis=0)
        a_i = jnp.mean(self.a[i], axis=0)
        B_i = jnp.mean(self.B[i], axis=1)             # (n, d, d)
        quad = 0.5 * x[i] @ A_i @ x[i] + a_i @ x[i]
        coup = jnp.einsum("d,jde,je->", x[i], B_i, x)
        return quad + coup

    # ------------------------------------------------------------ diagnostics
    def _block_matrix(self) -> np.ndarray:
        """Dense block matrix H of the affine operator F(x) = Hx + c."""
        n, d = self.n, self.d
        H = np.zeros((n * d, n * d))
        A = np.asarray(jnp.mean(self.A, axis=1))      # (n, d, d)
        B = np.asarray(jnp.mean(self.B, axis=2))      # (n, n, d, d)
        for i in range(n):
            H[i * d : (i + 1) * d, i * d : (i + 1) * d] = A[i]
            for j in range(n):
                if j != i:
                    H[i * d : (i + 1) * d, j * d : (j + 1) * d] = B[i, j]
        return H

    def equilibrium(self) -> Array:
        H = self._block_matrix()
        c = np.asarray(jnp.mean(self.a, axis=1)).reshape(-1)
        return jnp.asarray(np.linalg.solve(H, -c).reshape(self.n, self.d))

    def constants(self) -> GameConstants:
        return spectral_constants_from_block_matrix(
            self._block_matrix(), [self.d] * self.n
        )


def _random_symmetric(rng, d: int, lo: float, hi: float) -> np.ndarray:
    """Random symmetric matrix with eigenvalues uniform in [lo, hi]."""
    Q, _ = np.linalg.qr(rng.standard_normal((d, d)))
    eigs = rng.uniform(lo, hi, size=d)
    return (Q * eigs) @ Q.T


def make_quadratic_game(
    n: int = 5,
    d: int = 10,
    M: int = 100,
    mu_A: float = 1.0,
    L_A: float = 2.0,
    L_B: float = 20.0,
    batch_size: int = 10,
    seed: int = 0,
) -> QuadraticGame:
    """Construct the Section 4.1 game.

    Defaults put the problem in the *weak per-player / strong coupling* regime
    ``L_max << ell`` discussed in Section F.1 — the regime where PEARL-SGD's
    communication gain (factor ~ 1/tau + 1/sqrt(kappa)) is visible.
    """
    rng = np.random.default_rng(seed)
    A = np.stack(
        [[_random_symmetric(rng, d, mu_A, L_A) for _ in range(M)] for _ in range(n)]
    )
    B = np.zeros((n, n, M, d, d))
    for i in range(n):
        for j in range(i + 1, n):
            for m in range(M):
                Bijm = _random_symmetric(rng, d, 0.0, L_B)
                B[i, j, m] = Bijm
                B[j, i, m] = -Bijm.T
    a = rng.standard_normal((n, M, d))
    return QuadraticGame(
        A=jnp.asarray(A), B=jnp.asarray(B), a=jnp.asarray(a),
        n=n, d=d, M=M, batch_size=batch_size,
    )
