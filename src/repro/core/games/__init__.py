"""The paper's experimental games (Sections 4.1, 4.2, B, F.2)."""

from repro.core.games.counterexample import CounterexampleGame, make_counterexample_game
from repro.core.games.meanfield import MeanFieldQuadraticGame, make_mean_field_game
from repro.core.games.minimax_hetero import MinimaxHeteroGame, make_minimax_hetero_game
from repro.core.games.noncoco import NonCocoercivegame, make_noncoco_game
from repro.core.games.participation import (
    NetworkEffectsParticipationGame,
    make_participation_game,
)
from repro.core.games.quadratic import QuadraticGame, make_quadratic_game
from repro.core.games.robot import RobotGame, make_robot_game

__all__ = [
    "CounterexampleGame",
    "make_counterexample_game",
    "MeanFieldQuadraticGame",
    "make_mean_field_game",
    "MinimaxHeteroGame",
    "make_minimax_hetero_game",
    "NetworkEffectsParticipationGame",
    "make_participation_game",
    "NonCocoercivegame",
    "make_noncoco_game",
    "QuadraticGame",
    "make_quadratic_game",
    "RobotGame",
    "make_robot_game",
]
