"""Distributed mobile-robot control game (paper Section 4.2 / D.2).

Robot ``i`` minimizes

    f_i(x) = a_i/2 ||x^i - anc_i||^2  +  b_i/2 sum_j ||x^i - x^j - h_ij||^2

over its own position ``x^i``. Parameter values follow [Kalyva & Psillakis,
Automatica 2024] exactly as reproduced in Section D.2: ``n = 5``, ``d = 1``,
``a_i = 10 + i/6``, ``b_i = i/6`` (1-indexed), anchors ``(1,-4,8,-9,13)`` and
the fixed displacement matrix ``h``. Stochasticity is simulated by adding
Gaussian noise with ``sigma^2 = 100`` to the gradients.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.game import (
    GameConstants,
    VectorGame,
    register_game,
    spectral_constants_from_block_matrix,
)

Array = jax.Array

_H = np.array(
    [
        [0.0, 5.0, -7.0, 9.0, -8.0],
        [-5.0, 0.0, -6.0, 2.0, -9.0],
        [7.0, 6.0, 0.0, 7.0, -4.0],
        [-9.0, -2.0, -7.0, 0.0, -2.0],
        [8.0, 9.0, 4.0, 2.0, 0.0],
    ]
)
_ANCHORS = np.array([1.0, -4.0, 8.0, -9.0, 13.0])


@register_game(data=("a_coef", "b_coef", "anchors", "h"), meta=("n", "d", "sigma"))
class RobotGame(VectorGame):
    """5-robot consensus/displacement game; actions are scalar positions."""

    a_coef: Array   # (n,)
    b_coef: Array   # (n,)
    anchors: Array  # (n, d)
    h: Array        # (n, n, d)
    n: int
    d: int
    sigma: float

    def player_grad(self, i: Array, x_i: Array, x_ref: Array) -> Array:
        # d/dx^i [ b_i/2 sum_j ||x^i - x^j - h_ij||^2 ]. The j = i summand is
        # ||x^i - x^i||^2 == 0 in the SAME variable, so its gradient is zero;
        # subtract the spurious (x_i - x_ref[i]) that a frozen-snapshot sum
        # would otherwise inject during PEARL local steps.
        disp = jnp.sum(x_i[None, :] - x_ref - self.h[i], axis=0)
        disp = disp - (x_i - x_ref[i])
        return self.a_coef[i] * (x_i - self.anchors[i]) + self.b_coef[i] * disp

    def player_grad_stoch(self, i: Array, x_i: Array, x_ref: Array, key: Array) -> Array:
        noise = self.sigma * jax.random.normal(key, (self.d,))
        return self.player_grad(i, x_i, x_ref) + noise

    def objective(self, i: int, x: Array) -> Array:
        anchor_cost = 0.5 * self.a_coef[i] * jnp.sum((x[i] - self.anchors[i]) ** 2)
        disp_cost = 0.5 * self.b_coef[i] * jnp.sum((x[i][None, :] - x - self.h[i]) ** 2)
        return anchor_cost + disp_cost

    # ------------------------------------------------------------ diagnostics
    def _block_matrix(self) -> np.ndarray:
        """F is affine: F(x) = Hx + c with H_ii = a_i + (n-1) b_i, H_ij = -b_i."""
        n, d = self.n, self.d
        a = np.asarray(self.a_coef)
        b = np.asarray(self.b_coef)
        H = np.zeros((n * d, n * d))
        I = np.eye(d)
        for i in range(n):
            for j in range(n):
                blk = (a[i] + (n - 1) * b[i]) * I if i == j else -b[i] * I
                H[i * d : (i + 1) * d, j * d : (j + 1) * d] = blk
        return H

    def _offset(self) -> np.ndarray:
        a = np.asarray(self.a_coef)[:, None]
        b = np.asarray(self.b_coef)[:, None]
        h_sum = np.asarray(jnp.sum(self.h, axis=1))
        return (-a * np.asarray(self.anchors) - b * h_sum).reshape(-1)

    def equilibrium(self) -> Array:
        x = np.linalg.solve(self._block_matrix(), -self._offset())
        return jnp.asarray(x.reshape(self.n, self.d))

    def constants(self) -> GameConstants:
        return spectral_constants_from_block_matrix(
            self._block_matrix(), [self.d] * self.n
        )


def make_robot_game(sigma: float = 10.0) -> RobotGame:
    """The exact Section D.2 instance (``sigma**2 = 100`` gradient noise)."""
    n, d = 5, 1
    i = np.arange(1, n + 1)
    return RobotGame(
        a_coef=jnp.asarray(10.0 + i / 6.0),
        b_coef=jnp.asarray(i / 6.0),
        anchors=jnp.asarray(_ANCHORS[:, None]),
        h=jnp.asarray(_H[:, :, None]),
        n=n,
        d=d,
        sigma=sigma,
    )
