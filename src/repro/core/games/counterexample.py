"""Two-player game where Local SGD on the summed objective fails (Section B).

Equation (4) of the paper:

    f_1(u; v) = 1/2 u^T (A u - a - B^T v) - ||v||^2 / 20
    f_2(v; u) = 1/4 ||v||^2 + 1/2 v^T (B u - b) - ||u||^2 / 20

with ``A > 0``. The per-player gradients are

    grad_u f_1 = A u - a/2 - B^T v / 2
    grad_v f_2 = v/2 + (B u - b)/2

PEARL-SGD drives these to the equilibrium. Classical Local SGD applied to the
*joint* variable on the sum ``(f_1 + f_2)/2`` sees the bilinear couplings
cancel exactly, leaving the negatively-regularized gradient field

    grad_u = A u - a/2 - u/10,      grad_v = 2v/5 - b/2,

so whenever ``lambda_min(A) < 1/10`` the ``u`` dynamics *diverge* — the
paper's Figure 4 phenomenon. We expose both vector fields so the benchmark
can reproduce the figure.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.game import (
    GameConstants,
    VectorGame,
    register_game,
    spectral_constants_from_block_matrix,
)

Array = jax.Array


@register_game(data=("A", "B", "a", "b"), meta=("n", "d", "noise"))
class CounterexampleGame(VectorGame):
    """Equation (4) game; joint action is a (2, d) array of (u, v)."""

    A: Array  # (d, d), symmetric positive definite
    B: Array  # (d, d)
    a: Array  # (d,)
    b: Array  # (d,)
    n: int
    d: int
    noise: float

    def player_grad(self, i: Array, x_i: Array, x_ref: Array) -> Array:
        g_u = self.A @ x_i - self.a / 2.0 - self.B.T @ x_ref[1] / 2.0
        g_v = x_i / 2.0 + (self.B @ x_ref[0] - self.b) / 2.0
        return jnp.where(i == 0, g_u, g_v)

    def player_grad_stoch(self, i: Array, x_i: Array, x_ref: Array, key: Array) -> Array:
        eps = self.noise * jax.random.normal(key, (self.d,))
        return self.player_grad(i, x_i, x_ref) + eps

    def objective(self, i: int, x: Array) -> Array:
        u, v = x[0], x[1]
        f1 = 0.5 * u @ (self.A @ u - self.a - self.B.T @ v) - jnp.sum(v**2) / 20.0
        f2 = 0.25 * jnp.sum(v**2) + 0.5 * v @ (self.B @ u - self.b) - jnp.sum(u**2) / 20.0
        return jnp.where(i == 0, f1, f2)

    def sum_gradient(self, x: Array, key: Array | None = None) -> Array:
        """Gradient of (f1+f2)/2 w.r.t. the *joint* (u, v) — what Local SGD
        on the naive finite-sum formulation would follow (couplings cancel)."""
        u, v = x[0], x[1]
        g_u = 0.5 * (self.A @ u - self.a / 2.0 - u / 10.0)
        g_v = 0.5 * (0.4 * v - self.b / 2.0)
        g = jnp.stack([g_u, g_v])
        if key is not None:
            g = g + self.noise * jax.random.normal(key, g.shape)
        return g

    # ------------------------------------------------------------ diagnostics
    def _block_matrix(self) -> np.ndarray:
        d = self.d
        A = np.asarray(self.A)
        B = np.asarray(self.B)
        H = np.zeros((2 * d, 2 * d))
        H[:d, :d] = A
        H[:d, d:] = -B.T / 2.0
        H[d:, :d] = B / 2.0
        H[d:, d:] = 0.5 * np.eye(d)
        return H

    def equilibrium(self) -> Array:
        c = np.concatenate([-np.asarray(self.a) / 2.0, -np.asarray(self.b) / 2.0])
        x = np.linalg.solve(self._block_matrix(), -c)
        return jnp.asarray(x.reshape(2, self.d))

    def constants(self) -> GameConstants:
        return spectral_constants_from_block_matrix(self._block_matrix(), [self.d] * 2)


def make_counterexample_game(
    d: int = 10,
    eig_lo: float = 0.02,
    eig_hi: float = 1.0,
    coupling: float = 2.0,
    noise: float = 0.0,
    seed: int = 0,
) -> CounterexampleGame:
    """Instance with ``lambda_min(A) < 1/10`` so Local-SGD-on-the-sum diverges."""
    rng = np.random.default_rng(seed)
    Q, _ = np.linalg.qr(rng.standard_normal((d, d)))
    A = (Q * rng.uniform(eig_lo, eig_hi, size=d)) @ Q.T
    B = coupling * rng.standard_normal((d, d)) / np.sqrt(d)
    return CounterexampleGame(
        A=jnp.asarray(A), B=jnp.asarray(B),
        a=jnp.asarray(rng.standard_normal(d)), b=jnp.asarray(rng.standard_normal(d)),
        n=2, d=d, noise=noise,
    )
