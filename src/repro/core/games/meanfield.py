"""Mean-field quadratic game: n players coupled through the opponent mean.

The scaling workload for the engine's O(d) summary path
(:class:`~repro.core.engine.MeanFieldView`). Player ``i`` minimizes

    f_i(x^i; x^{-i}) = 1/2 <x^i, A x^i> + <a_i, x^i>
                       + beta * <x^i, mean_{j != i} x^j>,

with one shared curvature ``A`` (d, d) and per-player linear terms ``a_i``
— per-player parameters are O(d), so a million-player instance costs
O(n d) memory total, and every oracle the mean-field engine touches
(:meth:`player_grad_summary`, :meth:`operator`, :meth:`equilibrium`) is
O(d) per player. The opponent coupling factors EXACTLY through the
opponent mean, which makes ``(own block, opponent mean)`` a true
sufficient statistic: the self-corrected mean-field path agrees with the
exact engine to reduction-order ULPs at any n (tests/test_meanfield.py).

Closed forms (both O(d) linear solves, valid at any n):

- **Exact equilibrium** ``x*``: summing the stationarity conditions
  ``A x_i + a_i + beta/(n-1) (S - x_i) = 0`` gives
  ``(A + beta I) S = -sum_i a_i`` for the aggregate ``S``, then each
  player solves ``(A - beta/(n-1) I) x_i = -a_i - beta/(n-1) S``.
- **Mean-field equilibrium** ``xbar`` (the infinitesimal-player limit,
  opponents replaced by the population mean ``m``): ``(A + beta I) m =
  -mean_i a_i`` and ``A xbar_i = -a_i - beta m``.

Their gap is the finite-n mean-field error: ``x* - xbar = O(beta
heterogeneity / (n-1))`` per player, with matching aggregates as n grows —
the monotone-in-n shrinkage BENCH_scaling.json and the tests measure.

Monotonicity: the joint operator's block matrix is ``I_n (x) A +
beta/(n-1) (ones ones^T - I_n) (x) I_d``, whose eigenvalues are
``eig(A) + beta`` (aggregate direction) and ``eig(A) - beta/(n-1)``
(difference directions) — strongly monotone iff
``lambda_min(A) > beta/(n-1)``, enforced at construction.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.game import AggregativeGame, GameConstants, register_game

Array = jax.Array


@register_game(data=("A", "a"), meta=("n", "d", "beta"))
class MeanFieldQuadraticGame(AggregativeGame):
    """Aggregative quadratic game. Shapes: A (d, d) symmetric, a (n, d)."""

    A: Array
    a: Array
    n: int
    d: int
    beta: float

    summary_moments = 1

    # -------------------------------------------------------------- gradients
    def player_grad(self, i: Array, x_i: Array, x_ref: Array) -> Array:
        """Full-joint contract (row ``i`` of ``x_ref`` ignored): the opponent
        coupling is the leave-one-out mean ``(sum_j x_ref_j - x_ref_i)/(n-1)``.
        O(n d) — the exact engine's oracle, for cross-validation at small n."""
        mean_others = (jnp.sum(x_ref, axis=0) - x_ref[i]) / (self.n - 1)
        return self.A @ x_i + self.a[i] + self.beta * mean_others

    def player_grad_summary(
        self, i: Array, x_i: Array, own_ref: Array, summary: Array
    ) -> Array:
        """O(d) oracle: the believed opponent mean is ``summary[0]``."""
        del own_ref
        return self.A @ x_i + self.a[i] + self.beta * summary[0]

    def objective(self, i: int, x: Array) -> Array:
        mean_others = (jnp.sum(x, axis=0) - x[i]) / (self.n - 1)
        return (0.5 * x[i] @ self.A @ x[i] + self.a[i] @ x[i]
                + self.beta * x[i] @ mean_others)

    # --------------------------------------------------------- joint operator
    def operator(self, x: Array) -> Array:
        """Vectorized exact operator, O(n d) total (never O(n^2 d))."""
        S = jnp.sum(x, axis=0)
        mean_others = (S[None] - x) / (self.n - 1)
        return x @ self.A.T + self.a + self.beta * mean_others

    # ------------------------------------------------------------ diagnostics
    def equilibrium(self) -> Array:
        A = np.asarray(self.A, dtype=np.float64)
        a = np.asarray(self.a, dtype=np.float64)
        beta = float(self.beta)
        c = beta / (self.n - 1)
        S = np.linalg.solve(A + beta * np.eye(self.d), -a.sum(axis=0))
        x = np.linalg.solve(A - c * np.eye(self.d), -(a + c * S[None]).T).T
        return jnp.asarray(x, dtype=jnp.float32)

    def mean_field_equilibrium(self) -> Array:
        """Fixed point of the infinitesimal-player best response (opponents
        replaced by the population mean) — the ``self_correction=False``
        engine's target. The gap to :meth:`equilibrium` is the finite-n
        mean-field error, O(beta * heterogeneity / (n-1)) per player."""
        A = np.asarray(self.A, dtype=np.float64)
        a = np.asarray(self.a, dtype=np.float64)
        beta = float(self.beta)
        m = np.linalg.solve(A + beta * np.eye(self.d), -a.mean(axis=0))
        x = np.linalg.solve(A, -(a + beta * m[None]).T).T
        return jnp.asarray(x, dtype=jnp.float32)

    def constants(self) -> GameConstants:
        A = np.asarray(self.A, dtype=np.float64)
        eigs = np.linalg.eigvalsh(0.5 * (A + A.T))
        beta = float(self.beta)
        mu = float(eigs.min() - beta / (self.n - 1))
        if mu <= 0:
            raise ValueError(f"game is not strongly monotone: mu={mu:.3e}")
        L_F = float(eigs.max() + beta)
        return GameConstants(mu=mu, ell=L_F**2 / mu, L_max=float(eigs.max()),
                             L_F=L_F)


def make_mean_field_game(
    n: int = 100,
    d: int = 8,
    mu_A: float = 1.0,
    L_A: float = 2.0,
    beta: float = 0.5,
    heterogeneity: float = 1.0,
    seed: int = 0,
) -> MeanFieldQuadraticGame:
    """Construct a mean-field quadratic game.

    ``heterogeneity`` scales the spread of the per-player linear terms
    around their common mean: 0 gives the SYMMETRIC game (identical
    players — the mean is a sufficient statistic even without the
    leave-one-out correction, so the uncorrected mean-field path is exact);
    larger values widen the finite-n gap the scaling benchmark measures.
    Per-player draws come from a dedicated sequential stream (seeded
    ``[seed, 1]``), so player ``i``'s offset depends only on ``(seed, i)``
    and growing n EXTENDS the population instead of reshuffling it — the
    n-monotonicity of the mean-field gap is measured on nested populations
    at a fixed seed.
    """
    if n < 2:
        raise ValueError(f"mean-field game needs n >= 2, got {n}")
    if not 0.0 <= beta < mu_A * (n - 1):
        raise ValueError(
            f"need 0 <= beta < mu_A * (n - 1) for strong monotonicity, "
            f"got beta={beta}, mu_A={mu_A}, n={n}"
        )
    rng = np.random.default_rng(seed)
    Q, _ = np.linalg.qr(rng.standard_normal((d, d)))
    A = (Q * rng.uniform(mu_A, L_A, size=d)) @ Q.T
    a_mean = rng.standard_normal(d)
    # player i's offset is draw i of a fixed stream — independent of n
    offsets = np.random.default_rng([seed, 1]).standard_normal((n, d))
    a = a_mean[None] + heterogeneity * offsets
    return MeanFieldQuadraticGame(
        A=jnp.asarray(A, dtype=jnp.float32),
        a=jnp.asarray(a, dtype=jnp.float32),
        n=n, d=d, beta=float(beta),
    )
