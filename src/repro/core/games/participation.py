"""Network-effects participation meta-game (closed-form equilibrium).

*Federated Learning as a Network Effects Game* strips the incentive layer
of :mod:`repro.core.incentives` down to the analytically solvable core: a
population of players with heterogeneous private participation costs, a
flat per-round payment ``p``, and a progress value that scales with the
participation RATE (the network effect ``v``). Player ``i`` joins iff

    u_i(m) = p + v * k_i / n - c_i > 0,     k_i = |m_{-i}| + 1,

i.e. exactly the :class:`~repro.core.incentives.BestResponseParticipation`
utility with every value estimate pinned at its optimistic 1.0 — this
module IS that policy's testbed: no engine, no deltas, just the
participation game, with the equilibrium in closed form.

**Continuum closed form.** With costs uniform on ``[c_min, c_max]`` (CDF
``F``), a participation rate ``s`` is an equilibrium of the continuum game
iff ``s = F(p + v s)``. The best-response iteration from everyone-in
converges to the LARGEST equilibrium:

- ``p + v >= c_max``  →  ``s* = 1``  (even the costliest player profits in
  the full coalition);
- ``p <= c_min``      →  the interior candidate is non-positive — from the
  top the cascade sheds every player: ``s* = 0``, the **free-rider
  collapse** (each dropout lowers the others' network value, which drops
  more players; pricing below the cheapest cost kills participation
  entirely, not proportionally — the death spiral the benchmark pins);
- otherwise           →  ``s* = (p - c_min) / ((c_max - c_min) - v)``,
  the interior fixed point, well-posed under the weak-network-effect
  assumption ``v < c_max - c_min`` this game REQUIRES (at ``v`` above the
  cost spread the interior point turns unstable and the game becomes a
  coordination game with corner equilibria only — rejected at
  construction rather than silently mis-solved).

The discrete game samples the cost distribution at midpoints
``c_i = c_min + (i + 1/2) (c_max - c_min) / n``, so the discrete largest
equilibrium tracks the continuum rate within ``O(1/n)`` (the tests bound
it by ``1.5/n``).
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "NetworkEffectsParticipationGame",
    "make_participation_game",
]


@dataclasses.dataclass(frozen=True)
class NetworkEffectsParticipationGame:
    """The n-player participation game with uniform-grid costs.

    A host-side analytic meta-game, NOT a :class:`~repro.core.game
    .VectorGame`: its "joint action" is the boolean participation profile
    and its equilibrium is over WHO PLAYS, not where the play converges.
    It layers on top of any equilibrium game via
    :class:`~repro.core.incentives.BestResponseParticipation`.
    """

    n: int
    price: float
    value: float       # network-effect strength v
    cost_min: float = 0.2
    cost_max: float = 0.8

    def __post_init__(self):
        if self.n < 1:
            raise ValueError(f"need n >= 1 players, got {self.n}")
        if not self.cost_min <= self.cost_max:
            raise ValueError(
                f"need cost_min <= cost_max, got "
                f"[{self.cost_min}, {self.cost_max}]"
            )
        if self.value < 0.0:
            raise ValueError(f"value must be >= 0, got {self.value}")
        if self.value >= self.cost_max - self.cost_min:
            raise ValueError(
                f"the closed form needs the weak-network-effect regime "
                f"value < cost_max - cost_min (at v >= the cost spread the "
                f"interior fixed point is unstable and only corner "
                f"equilibria remain) — got value={self.value} against "
                f"spread {self.cost_max - self.cost_min}"
            )

    @property
    def costs(self) -> np.ndarray:
        """Midpoint-grid sampling of Uniform[cost_min, cost_max]."""
        span = self.cost_max - self.cost_min
        return (self.cost_min
                + (np.arange(self.n) + 0.5) * (span / self.n))

    # ------------------------------------------------------- discrete game
    def utilities(self, mask: np.ndarray) -> np.ndarray:
        """u_i of JOINING given the others' decisions in ``mask``."""
        m = np.asarray(mask, dtype=bool)
        k_if_join = m.sum() - m + 1          # i's coalition if i joins
        return (self.price + self.value * k_if_join / self.n
                - self.costs)

    def best_response(self, mask: np.ndarray) -> np.ndarray:
        """One simultaneous-move sweep: everyone re-decides against
        ``mask``."""
        return self.utilities(mask) > 0.0

    def best_response_iterate(self, iters: int | None = None
                              ) -> tuple[np.ndarray, bool]:
        """Iterate from everyone-in; returns ``(mask, converged)``.

        The all-ones start makes the monotone iteration converge DOWN to
        the largest equilibrium in at most ``n`` sweeps; ``converged`` is
        False only if ``iters`` cut the cascade short."""
        iters = self.n if iters is None else iters
        m = np.ones(self.n, dtype=bool)
        for _ in range(iters):
            nxt = self.best_response(m)
            if np.array_equal(nxt, m):
                return m, True
            m = nxt
        return m, np.array_equal(self.best_response(m), m)

    # ------------------------------------------------------- continuum form
    def equilibrium_rate(self) -> float:
        """Closed-form largest-equilibrium participation rate s*."""
        if self.price + self.value >= self.cost_max:
            return 1.0
        if self.price <= self.cost_min:
            return 0.0
        return float((self.price - self.cost_min)
                     / ((self.cost_max - self.cost_min) - self.value))

    @property
    def collapse_price(self) -> float:
        """The free-rider threshold: any price at or below it yields the
        all-out equilibrium from the everyone-in start."""
        return self.cost_min


def make_participation_game(n: int = 20, price: float = 0.4,
                            value: float = 0.2, cost_min: float = 0.2,
                            cost_max: float = 0.8
                            ) -> NetworkEffectsParticipationGame:
    """Defaults sit squarely in the interior regime:
    ``s* = (0.4 - 0.2) / (0.6 - 0.2) = 0.5`` — half the population
    participates at equilibrium."""
    return NetworkEffectsParticipationGame(
        n=n, price=price, value=value, cost_min=cost_min,
        cost_max=cost_max)
