"""Non-cocoercive game satisfying (CVX), (SM), (QSM), (SCO) — Section F.2.

Player ``i`` (cyclically) minimizes ``f_i(x^i; x^{i+1}) = (x^i)^2/2 *
phi(x^{i+1})`` with ``phi(t) = mu + (ell - mu) sin^2 t``. The joint operator

    F(x)_i = x^i * phi(x^{i+1 mod n})

satisfies QSM with modulus ``mu`` and SCO with parameter ``ell`` around the
unique equilibrium ``x* = 0``, yet is neither Lipschitz nor monotone — the
paper's witness that its assumption set strictly generalizes cocoercivity.
Useful as a stress test: PEARL-SGD must still converge here.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.game import GameConstants, VectorGame, register_game

Array = jax.Array


@register_game(data=(), meta=("n", "d", "mu", "ell"))
class NonCocoercivegame(VectorGame):
    """Cyclic sin^2-modulated quadratic game; d = 1 actions."""

    n: int
    d: int
    mu: float
    ell: float

    def _phi(self, t: Array) -> Array:
        return self.mu + (self.ell - self.mu) * jnp.sin(t) ** 2

    def player_grad(self, i: Array, x_i: Array, x_ref: Array) -> Array:
        nxt = jnp.mod(i + 1, self.n)
        return x_i * self._phi(x_ref[nxt])

    def objective(self, i: int, x: Array) -> Array:
        nxt = (i + 1) % self.n
        return 0.5 * jnp.sum(x[i] ** 2) * jnp.sum(self._phi(x[nxt]))

    def equilibrium(self) -> Array:
        return jnp.zeros((self.n, self.d))

    def constants(self) -> GameConstants:
        # QSM holds with mu; SCO holds with ell; L_i = sup phi = ell.
        # F is *not* Lipschitz (L_F unbounded) — theory only needs the others.
        return GameConstants(mu=self.mu, ell=self.ell, L_max=self.ell, L_F=float("inf"))


def make_noncoco_game(n: int = 4, mu: float = 0.5, ell: float = 4.0) -> NonCocoercivegame:
    return NonCocoercivegame(n=n, d=1, mu=mu, ell=ell)
