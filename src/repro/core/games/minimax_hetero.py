"""Client-heterogeneity minimax game (*Federated Minimax Optimization with
Client Heterogeneity*).

Each player is a CLIENT running its own saddle problem: its block
``x^i = (u^i, v^i)`` stacks a minimizing half and a maximizing half of
dimension ``m`` each (``d = 2m``), with local payoff

    L_i(u, v) = (mu_i / 2)(||u||^2 - ||v||^2) + gamma_i <u, v>
                + couplings + <a_i, x^i>,

whose simultaneous-gradient operator on the block is

    F_i(x^i) = (grad_u L_i, -grad_v L_i) = (mu_i I + gamma_i R) x^i,
    R = [[0, I_m], [-I_m, 0]]   (the symplectic rotation),

i.e. a rotation of heterogeneous strength ``gamma_i`` around a strongly
monotone core of heterogeneous curvature ``mu_i``. That PER-CLIENT spread
is the point: federated minimax results degrade with client heterogeneity,
and here the heterogeneity knob spreads both the conditioning
(``mu_i in [mu, mu(1 + heterogeneity)]``) and the rotation intensity
(``gamma_i in [0, gamma_max]``, client 0 a pure minimizer, the last client
almost a pure game) — the straggler analog in problem space rather than
time. Cross-client couplings follow the paper's Section D.1 antisymmetry
``B_{j,i} = -B_{i,j}^T``, so they cancel in the monotonicity inner product
and the joint operator stays strongly monotone with
``mu = min_i mu_i`` at ANY coupling strength; the closed-form equilibrium
solves the affine system in float64.

The stochastic oracle adds isotropic Gaussian noise to the exact gradient
(variance ``sigma^2`` per coordinate) — the bounded-variance model of
Assumption 3.3, keeping this game's noise orthogonal to its heterogeneity
(the quadratic game's finite-sum oracle couples the two).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.game import (
    GameConstants,
    VectorGame,
    register_game,
    spectral_constants_from_block_matrix,
)

Array = jax.Array

__all__ = ["MinimaxHeteroGame", "make_minimax_hetero_game"]


@register_game(data=("A", "B", "a"), meta=("n", "d", "sigma"))
class MinimaxHeteroGame(VectorGame):
    """Affine heterogeneous-minimax game.

    Shapes: A (n, d, d) per-client operator blocks (mu_i I + gamma_i R),
    B (n, n, d, d) antisymmetric couplings (B[i, i] = 0), a (n, d)."""

    A: Array
    B: Array
    a: Array
    n: int
    d: int
    sigma: float

    # -------------------------------------------------------------- gradients
    def player_grad(self, i: Array, x_i: Array, x_ref: Array) -> Array:
        # B[i, i] is identically zero, so the j-sum is the sum over j != i
        coupling = jnp.einsum("jde,je->d", self.B[i], x_ref)
        return self.A[i] @ x_i + self.a[i] + coupling

    def player_grad_stoch(self, i: Array, x_i: Array, x_ref: Array,
                          key: Array) -> Array:
        noise = self.sigma * jax.random.normal(key, (self.d,))
        return self.player_grad(i, x_i, x_ref) + noise

    def objective(self, i: int, x: Array) -> Array:
        """The saddle payoff L_i (min-half minus max-half quadratics)."""
        m = self.d // 2
        sgn = jnp.concatenate([jnp.ones(m), -jnp.ones(m)])
        # symmetric part of A[i] restricted to the diagonal sign split
        quad = 0.5 * x[i] @ (sgn[:, None] * self.A[i]) @ x[i]
        coup = jnp.einsum("d,jde,je->", x[i], self.B[i], x)
        return quad + self.a[i] @ x[i] + coup

    # ------------------------------------------------------------ diagnostics
    def _block_matrix(self) -> np.ndarray:
        n, d = self.n, self.d
        H = np.zeros((n * d, n * d))
        A = np.asarray(self.A, dtype=np.float64)
        B = np.asarray(self.B, dtype=np.float64)
        for i in range(n):
            H[i * d:(i + 1) * d, i * d:(i + 1) * d] = A[i]
            for j in range(n):
                if j != i:
                    H[i * d:(i + 1) * d, j * d:(j + 1) * d] = B[i, j]
        return H

    def equilibrium(self) -> Array:
        H = self._block_matrix()
        c = np.asarray(self.a, dtype=np.float64).reshape(-1)
        return jnp.asarray(np.linalg.solve(H, -c).reshape(self.n, self.d))

    def constants(self) -> GameConstants:
        return spectral_constants_from_block_matrix(
            self._block_matrix(), [self.d] * self.n
        )


def make_minimax_hetero_game(
    n: int = 6,
    m: int = 4,
    mu: float = 1.0,
    heterogeneity: float = 3.0,
    gamma_max: float = 8.0,
    L_B: float = 4.0,
    sigma: float = 0.1,
    seed: int = 0,
) -> MinimaxHeteroGame:
    """Construct the heterogeneous-client minimax game.

    ``heterogeneity`` spreads the per-client curvature linearly over
    ``[mu, mu * (1 + heterogeneity)]`` and the rotation intensity over
    ``[0, gamma_max]`` (client i's ``gamma_i = gamma_max * i / (n - 1)``);
    0 collapses every client to the same well-conditioned minimization.
    Couplings are random antisymmetric pairs with spectral scale ``L_B``
    drawn from the nested-seed rng ``default_rng([seed, 2])`` (the games'
    per-module seeding discipline).
    """
    if m < 1 or n < 2:
        raise ValueError(f"need m >= 1 and n >= 2, got m={m}, n={n}")
    d = 2 * m
    rng = np.random.default_rng([seed, 2])
    R = np.block([[np.zeros((m, m)), np.eye(m)],
                  [-np.eye(m), np.zeros((m, m))]])
    mus = mu * (1.0 + heterogeneity * np.arange(n) / max(n - 1, 1))
    gammas = gamma_max * np.arange(n) / max(n - 1, 1)
    A = np.stack([mus[i] * np.eye(d) + gammas[i] * R for i in range(n)])
    B = np.zeros((n, n, d, d))
    for i in range(n):
        for j in range(i + 1, n):
            Bij = rng.uniform(-1.0, 1.0, size=(d, d))
            Bij *= L_B / max(np.linalg.norm(Bij, 2), 1e-12)
            B[i, j] = Bij
            B[j, i] = -Bij.T
    a = rng.standard_normal((n, d))
    return MinimaxHeteroGame(
        A=jnp.asarray(A), B=jnp.asarray(B), a=jnp.asarray(a),
        n=n, d=d, sigma=float(sigma),
    )
