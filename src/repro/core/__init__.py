"""MpFL core: n-player games, the PEARL engine, step-sizes, baselines."""

from repro.core.game import (
    GameConstants,
    VectorGame,
    register_game,
    relative_error,
    residual_norm,
)
from repro.core.engine import (
    DropoutSync,
    ExactSync,
    ExtragradientUpdate,
    HeavyBallUpdate,
    JointExtragradientUpdate,
    OptimisticGradientUpdate,
    PartialParticipation,
    PearlEngine,
    PearlResult,
    PLAYER_UPDATES,
    QuantizedSync,
    SgdUpdate,
    SumLocalSgdUpdate,
    SYNC_STRATEGIES,
    SyncStrategy,
)
from repro.core.pearl import pearl_sgd, pearl_sgd_mean
from repro.core import baselines, metrics, stepsize

__all__ = [
    "GameConstants",
    "VectorGame",
    "register_game",
    "relative_error",
    "residual_norm",
    "PearlEngine",
    "PearlResult",
    "SgdUpdate",
    "ExtragradientUpdate",
    "OptimisticGradientUpdate",
    "HeavyBallUpdate",
    "JointExtragradientUpdate",
    "SumLocalSgdUpdate",
    "SyncStrategy",
    "ExactSync",
    "QuantizedSync",
    "PartialParticipation",
    "DropoutSync",
    "PLAYER_UPDATES",
    "SYNC_STRATEGIES",
    "pearl_sgd",
    "pearl_sgd_mean",
    "baselines",
    "metrics",
    "stepsize",
]
