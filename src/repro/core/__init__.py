"""MpFL core: n-player games, PEARL-SGD, theoretical step-sizes, baselines."""

from repro.core.game import (
    GameConstants,
    VectorGame,
    register_game,
    relative_error,
    residual_norm,
)
from repro.core.pearl import PearlResult, pearl_sgd, pearl_sgd_mean
from repro.core import baselines, metrics, stepsize

__all__ = [
    "GameConstants",
    "VectorGame",
    "register_game",
    "relative_error",
    "residual_norm",
    "PearlResult",
    "pearl_sgd",
    "pearl_sgd_mean",
    "baselines",
    "metrics",
    "stepsize",
]
