"""n-player game abstraction for Multiplayer Federated Learning (MpFL).

The paper (Yoon, Choudhury & Loizou, NeurIPS 2025) formulates MpFL as an
n-player game: player ``i`` owns an action block ``x^i`` and an objective
``f_i(x^i; x^{-i}) = E_{xi ~ D_i}[f_{i,xi}(x^i; x^{-i})]`` which it minimizes
*only* in its own block. The target is a joint action ``x*`` with
``F(x*) = 0`` for the joint gradient operator

    F(x) = (grad_{x^1} f_1(x), ..., grad_{x^n} f_n(x)).

This module defines the ``VectorGame`` interface used by the optimization
algorithms in :mod:`repro.core.pearl` and :mod:`repro.core.baselines`. For
the paper's experimental setups all players share the same dimension ``d``,
so a joint action is a dense ``(n, d)`` array — this keeps every algorithm a
single ``vmap``/``scan`` program. Neural-network players (whole parameter
pytrees as actions) are handled separately by :mod:`repro.core.neural` and
:mod:`repro.train.pearl_trainer`.
"""

from __future__ import annotations

import abc
import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class GameConstants:
    """Problem constants used by the theoretical step-size rules.

    Attributes:
      mu:     quasi-strong monotonicity modulus of ``F`` (Assumption QSM).
      ell:    star-cocoercivity parameter of ``F`` (Assumption SCO); following
              the paper/[Facchinei-Pang] we set ``ell = L_F**2 / mu`` when only
              Lipschitzness of ``F`` is available.
      L_max:  max over players of the per-player smoothness ``L_i`` (SM).
      L_F:    Lipschitz constant of the joint operator ``F`` (when finite).
    """

    mu: float
    ell: float
    L_max: float
    L_F: float

    @property
    def kappa(self) -> float:
        """Condition number ``kappa = ell / mu >= 1``."""
        return self.ell / self.mu

    @property
    def q(self) -> float:
        """``q = L_max / sqrt(ell * mu)`` from Theorem 3.4 / Corollary 3.5."""
        return self.L_max / float(np.sqrt(self.ell * self.mu))


class VectorGame(abc.ABC):
    """An n-player game whose joint action is a dense ``(n, d)`` array.

    Subclasses hold jnp arrays as attributes and are registered as pytrees
    (see :func:`register_game`) so instances can cross ``jax.jit`` boundaries.
    """

    n: int
    d: int

    # ------------------------------------------------------------------ API
    @abc.abstractmethod
    def player_grad(self, i: Array, x_i: Array, x_ref: Array) -> Array:
        """Deterministic ``grad_{x^i} f_i(x_i; x_ref^{-i})``.

        Args:
          i:      player index (traced scalar — must be usable under vmap).
          x_i:    player ``i``'s *current local* action, shape ``(d,)``.
          x_ref:  stale joint snapshot ``(n, d)``; row ``i`` is ignored and
                  replaced by ``x_i`` (the player never differentiates w.r.t.
                  the others' actions).

        Returns:
          gradient of shape ``(d,)``.
        """

    def player_grad_stoch(
        self, i: Array, x_i: Array, x_ref: Array, key: Array
    ) -> Array:
        """Unbiased stochastic estimate of :meth:`player_grad` (BV).

        Default: the deterministic gradient (``sigma_i = 0``).
        """
        del key
        return self.player_grad(i, x_i, x_ref)

    # --------------------------------------------------------- joint operator
    def operator(self, x: Array) -> Array:
        """Joint gradient operator ``F(x)``, shape ``(n, d)``."""
        idx = jnp.arange(self.n)
        return jax.vmap(lambda i, xi: self.player_grad(i, xi, x))(idx, x)

    def operator_stoch(self, x: Array, key: Array) -> Array:
        """One stochastic evaluation of ``F`` (independent noise per player)."""
        idx = jnp.arange(self.n)
        keys = jax.random.split(key, self.n)
        return jax.vmap(lambda i, xi, k: self.player_grad_stoch(i, xi, x, k))(
            idx, x, keys
        )

    # ----------------------------------------------------------- diagnostics
    def equilibrium(self) -> Array:
        """Exact equilibrium ``x*`` with ``F(x*) = 0`` (``(n, d)``).

        Subclasses with closed-form/linear structure override this; the
        default raises.
        """
        raise NotImplementedError(f"{type(self).__name__} has no closed form x*")

    def constants(self) -> GameConstants:
        """Theoretical constants (mu, ell, L_max, L_F) for step-size rules."""
        raise NotImplementedError(f"{type(self).__name__} has no known constants")

    def objective(self, i: int, x: Array) -> Array:
        """Scalar objective ``f_i`` at joint action ``x`` (for plots/tests)."""
        raise NotImplementedError


class AggregativeGame(VectorGame):
    """A game whose coupling factors through population moments (aggregative).

    Player ``i``'s gradient depends on the opponents ``x^{-i}`` only through
    aggregate sufficient statistics — the opponent mean, optionally the
    opponent mean-of-squares — so a player can best-respond to an O(d)
    summary instead of the full ``(n, d)`` joint action. This is the
    structural property the engine's
    :class:`~repro.core.engine.MeanFieldView` exploits to run millions of
    players at O(d) per-player state and wire (cf. *Federated Learning as a
    Mean-Field Game*, PAPERS.md).

    The summary convention, shared with the engine: a ``(moments, d)``
    array whose row 0 is the (believed) opponent mean
    ``mean_{j != i} x^j`` and row 1 (when ``summary_moments >= 2``) the
    opponent mean of squares ``mean_{j != i} (x^j)**2``. Whether those rows
    are the exact leave-one-out moments, the population moments (the
    infinitesimal-player approximation), or a sampled-subset estimate is the
    VIEW's choice, not the game's — the game just evaluates the gradient at
    whatever belief it is handed.

    Subclasses must keep :meth:`VectorGame.player_grad` (the full-joint
    contract) consistent with :meth:`player_grad_summary` under the exact
    leave-one-out summary: that consistency is what makes the mean-field
    engine's self-corrected path agree with the exact engine to reduction-
    order ULPs (pinned in tests/test_meanfield.py).
    """

    #: how many opponent moments :meth:`player_grad_summary` consumes
    summary_moments: int = 1

    @abc.abstractmethod
    def player_grad_summary(
        self, i: Array, x_i: Array, own_ref: Array, summary: Array
    ) -> Array:
        """``grad_{x^i} f_i`` from the O(d) opponent summary.

        Args:
          i:        player index (traced; usable under vmap).
          x_i:      player ``i``'s current local action, shape ``(d,)``.
          own_ref:  player ``i``'s own frozen block at the last sync,
                    shape ``(d,)`` (what the summary's owner contributed).
          summary:  ``(moments, d)`` believed opponent moments (row 0 the
                    opponent mean; see class docstring).
        """

    def player_grad_stoch_summary(
        self, i: Array, x_i: Array, own_ref: Array, summary: Array, key: Array
    ) -> Array:
        """Unbiased stochastic estimate of :meth:`player_grad_summary`.

        Default: the deterministic summary gradient (``sigma_i = 0``)."""
        del key
        return self.player_grad_summary(i, x_i, own_ref, summary)

    def population_summary(self, x: Array, moments: int) -> Array:
        """``(moments, d)`` population sufficient statistics of the joint
        action — the O(d) object the mean-field server maintains and
        broadcasts. Row ``p`` is ``mean_i (x^i)**(p+1)``."""
        return jnp.stack(
            [jnp.mean(x ** (p + 1), axis=0) for p in range(moments)]
        )

    def operator_via_summary(self, x: Array) -> Array:
        """Joint operator evaluated through the summary oracle, O(n d).

        Uses the EXACT leave-one-out correction
        ``mean_{j != i} (x^j)**p = (n * mean_k (x^k)**p - (x^i)**p) / (n-1)``,
        so for a true aggregative game this equals :meth:`operator` up to
        reduction order — at O(n d) instead of the O(n^2 d) of vmapping the
        full-joint oracle. The mean-field engine uses this for residual
        diagnostics at million-player n.
        """
        n = self.n
        moments = self.summary_moments
        pop = self.population_summary(x, moments)            # (m, d)
        powers = jnp.stack([x ** (p + 1) for p in range(moments)], axis=1)
        others = (n * pop[None] - powers) / (n - 1)          # (n, m, d)
        idx = jnp.arange(n)
        return jax.vmap(
            lambda i, xi, s: self.player_grad_summary(i, xi, xi, s)
        )(idx, x, others)


def register_game(cls=None, *, data: tuple[str, ...] = (), meta: tuple[str, ...] = ()):
    """Register a ``VectorGame`` dataclass as a JAX pytree.

    ``data`` fields are traced leaves (jnp arrays), ``meta`` fields are static
    hashable auxiliaries (ints, floats, tuples).
    """

    def wrap(c):
        c = dataclasses.dataclass(frozen=True)(c)

        def flatten(g):
            children = tuple(getattr(g, f) for f in data)
            aux = tuple(getattr(g, f) for f in meta)
            return children, aux

        def unflatten(aux, children):
            kwargs = dict(zip(data, children)) | dict(zip(meta, aux))
            return c(**kwargs)

        jax.tree_util.register_pytree_node(c, flatten, unflatten)
        return c

    if cls is not None:
        return wrap(cls)
    return wrap


def joint_with(x_ref: Array, i: Array, x_i: Array) -> Array:
    """Joint action equal to ``x_ref`` with row ``i`` replaced by ``x_i``."""
    return x_ref.at[i].set(x_i)


def relative_error(x: Array, x_star: Array, x0: Array) -> Array:
    """``||x - x*||^2 / ||x0 - x*||^2`` — the paper's plotted metric."""
    return jnp.sum((x - x_star) ** 2) / jnp.sum((x0 - x_star) ** 2)


def residual_norm(game: VectorGame, x: Array) -> Array:
    """``||F(x)||`` — equilibrium residual."""
    return jnp.sqrt(jnp.sum(game.operator(x) ** 2))


def spectral_constants_from_block_matrix(
    H: np.ndarray, block_sizes: list[int]
) -> GameConstants:
    """Constants for an *affine* game ``F(x) = H x + c`` with player blocks.

    - ``mu``    = lambda_min of the symmetric part of ``H`` (strong monotonicity;
      implies QSM).
    - ``L_F``   = sigma_max(H) (Lipschitz constant of F).
    - ``ell``   = L_F**2 / mu — the tight generic cocoercivity bound the paper
      uses (following Facchinei & Pang), see Section 4.1 / Section F.1.
    - ``L_max`` = max over players of sigma_max(H_ii) — the *per-player*
      smoothness, typically far smaller than ``ell`` (Section F.1).
    """
    Hs = 0.5 * (H + H.T)
    mu = float(np.linalg.eigvalsh(Hs).min())
    if mu <= 0:
        raise ValueError(f"game is not strongly monotone: mu={mu:.3e}")
    L_F = float(np.linalg.norm(H, 2))
    ell = L_F**2 / mu
    L_max, off = 0.0, 0
    for b in block_sizes:
        Hii = H[off : off + b, off : off + b]
        L_max = max(L_max, float(np.linalg.norm(Hii, 2)))
        off += b
    return GameConstants(mu=mu, ell=ell, L_max=L_max, L_F=L_F)
