"""Pallas TPU kernels (interpret-validated on CPU; see kernels/common.py).

Each kernel package ships kernel.py (pl.pallas_call + BlockSpec), ops.py
(jit'd public wrapper) and ref.py (pure-jnp oracle used by tests/benchmarks).
"""
