"""jit'd public wrapper around the flash-attention Pallas kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.common import default_interpret, pad_to
from repro.kernels.flash_attention.kernel import flash_attention_bhsd


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool | None = None) -> jax.Array:
    """Flash attention over (B, S, H, hd) with KV pre-expanded to H heads.

    Pads S to block multiples (mask handles the tail), reshapes heads into
    the grid batch, and restores the original layout.
    """
    if interpret is None:
        interpret = default_interpret()
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    bq = min(block_q, max(16, 1 << (sq - 1).bit_length()))
    bk = min(block_k, max(16, 1 << (sk - 1).bit_length()))

    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, sk, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, sk, hd)
    qf, _ = pad_to(qf, 1, bq)
    kf, _ = pad_to(kf, 1, bk)
    vf, _ = pad_to(vf, 1, bk)

    out = flash_attention_bhsd(qf, kf, vf, causal=causal, window=window,
                               block_q=bq, block_k=bk, interpret=interpret,
                               kv_len=sk)
    out = out[:, :sq]
    return out.reshape(b, h, sq, hd).transpose(0, 2, 1, 3)
