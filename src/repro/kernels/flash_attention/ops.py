"""jit'd public wrapper around the flash-attention Pallas kernel.

The Pallas kernel is forward-only; `pallas_call` has no autodiff rule, so
differentiating through it raises at trace time. The public op therefore
carries a ``custom_vjp``: the primal runs the kernel, the backward pass
differentiates the pure-jnp oracle (:mod:`.ref`) on the saved inputs. The
two forwards agree to kernel-parity tolerance (tests/test_kernels.py), so
the cotangents are those of the reference softmax attention — the standard
arrangement when only the forward kernel is hand-written.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.common import default_interpret, pad_to
from repro.kernels.flash_attention.kernel import flash_attention_bhsd
from repro.kernels.flash_attention.ref import attention_ref


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash_attention(q, k, v, causal, window, block_q, block_k, interpret):
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    bq = min(block_q, max(16, 1 << (sq - 1).bit_length()))
    bk = min(block_k, max(16, 1 << (sk - 1).bit_length()))

    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, sk, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, sk, hd)
    qf, _ = pad_to(qf, 1, bq)
    kf, _ = pad_to(kf, 1, bk)
    vf, _ = pad_to(vf, 1, bk)

    out = flash_attention_bhsd(qf, kf, vf, causal=causal, window=window,
                               block_q=bq, block_k=bk, interpret=interpret,
                               kv_len=sk)
    out = out[:, :sq]
    return out.reshape(b, h, sq, hd).transpose(0, 2, 1, 3)


def _flash_fwd(q, k, v, causal, window, block_q, block_k, interpret):
    out = _flash_attention(q, k, v, causal, window, block_q, block_k,
                           interpret)
    return out, (q, k, v)


def _flash_bwd(causal, window, block_q, block_k, interpret, res, g):
    q, k, v = res
    ref_out, vjp = jax.vjp(
        lambda q, k, v: attention_ref(q, k, v, causal=causal, window=window),
        q, k, v,
    )
    return vjp(g.astype(ref_out.dtype))


_flash_attention.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool | None = None) -> jax.Array:
    """Flash attention over (B, S, H, hd) with KV pre-expanded to H heads.

    Pads S to block multiples (mask handles the tail), reshapes heads into
    the grid batch, and restores the original layout. Differentiable: the
    backward pass is the VJP of the jnp oracle (see module docstring).
    """
    if interpret is None:
        interpret = default_interpret()
    return _flash_attention(q, k, v, causal, window, block_q, block_k,
                            interpret)
