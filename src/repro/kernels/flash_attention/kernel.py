"""Flash attention Pallas TPU kernel: online-softmax, VMEM-tiled.

Grid = (batch*heads, q_blocks, k_blocks); the last axis iterates sequentially
on TPU, so the running (max, denom, accumulator) for one (bh, q_block) lives
in VMEM scratch across k-block steps. Block sizes are MXU-aligned (multiples
of 128 on the sequence dims; head_dim is the matmul contraction).

HBM -> VMEM traffic: Q read once per (q_block, k_block) pair is avoided by
the BlockSpec index map (same q tile for all k steps), so traffic is
O(S*hd + S^2/block * 0) for Q plus streamed K/V tiles — the S^2 score matrix
never touches HBM. That is the memory-roofline win over the naive path
quantified in EXPERIMENTS.md Section Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int,
                  block_q: int, block_k: int, kv_len: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale              # (bq, hd)
    k = k_ref[0].astype(jnp.float32)                      # (bk, hd)
    v = v_ref[0].astype(jnp.float32)                      # (bk, hd)
    s = q @ k.T                                           # (bq, bk)

    qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = kpos < kv_len
    if causal:
        mask &= qpos >= kpos
    if window > 0:
        mask &= qpos - kpos < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                                   # (bq, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + p @ v
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret",
                     "kv_len"),
)
def flash_attention_bhsd(q, k, v, *, causal: bool = True, window: int = 0,
                         block_q: int = 128, block_k: int = 128,
                         interpret: bool = True, kv_len: int | None = None):
    """Flash attention over flattened heads.

    q: (BH, Sq, hd); k, v: (BH, Sk, hd). Sq/Sk must be multiples of the block
    sizes (ops.py pads; ``kv_len`` masks the padded key tail). Returns
    (BH, Sq, hd) in q.dtype.
    """
    bh, sq, hd = q.shape
    sk = k.shape[1]
    nq = sq // block_q
    nk = sk // block_k
    scale = hd**-0.5

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, kv_len=kv_len or sk,
    )
    return pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, hd), q.dtype),
        scratch_shapes=[
            # running max / denom / accumulator live across the k-block loop
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
