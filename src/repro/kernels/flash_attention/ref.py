"""Pure-jnp oracle for flash attention (numerically exact softmax attention)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int = 0) -> jax.Array:
    """Naive masked softmax attention.

    q, k, v: (B, S, H, hd) with KV already expanded to H heads.
    Returns (B, S, H, hd). fp32 softmax, output in q.dtype.
    """
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    scale = hd**-0.5
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= qpos >= kpos
    if window > 0:
        mask &= qpos - kpos < window
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(q.dtype), v)
    return out
