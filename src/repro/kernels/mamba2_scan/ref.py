"""Sequential oracle for the Mamba2/SSD selective scan.

The ground-truth recurrence, one timestep at a time:

    h_t = exp(dt_t * A) h_{t-1} + dt_t * (B_t (x) x_t)
    y_t = h_t @ C_t
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
            C: jax.Array, h0: jax.Array | None = None):
    """x (b,L,H,P); dt (b,L,H); A (H,); B, C (b,L,N).

    Returns (y (b,L,H,P), h_final (b,H,P,N)). fp32 throughout.
    """
    b, L, H, P = x.shape
    N = B.shape[-1]
    h = jnp.zeros((b, H, P, N), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp                 # (b,H,P) (b,H) (b,N) (b,N)
        a_t = jnp.exp(dt_t * A[None, :])          # (b,H)
        upd = jnp.einsum("bh,bhp,bn->bhpn", dt_t, x_t, b_t)
        h = a_t[:, :, None, None] * h + upd
        y = jnp.einsum("bhpn,bn->bhp", h, c_t)
        return h, y

    inputs = (
        jnp.moveaxis(x.astype(jnp.float32), 1, 0),
        jnp.moveaxis(dt.astype(jnp.float32), 1, 0),
        jnp.moveaxis(B.astype(jnp.float32), 1, 0),
        jnp.moveaxis(C.astype(jnp.float32), 1, 0),
    )
    h_final, ys = jax.lax.scan(step, h, inputs)
    return jnp.moveaxis(ys, 0, 1), h_final
