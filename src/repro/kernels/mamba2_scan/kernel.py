"""Chunked SSD (Mamba2) Pallas TPU kernel.

Grid = (batch, n_chunks); the chunk axis is the sequentially-iterated minor
grid dimension, so the inter-chunk recurrent state (H, P, N) persists in VMEM
scratch across chunk steps — HBM sees only the chunk inputs/outputs, never
the state. Within a chunk the computation is the attention-like masked
``(C B^T (.) decay) X`` product, all MXU matmuls on (Q x N) / (Q x Q) tiles.

TPU adaptation note (DESIGN.md): the CUDA Mamba2 kernel parallelizes the
intra-chunk work across warps and keeps state in registers; on TPU the
equivalent is VMEM scratch persistence across the sequential grid axis plus
MXU-shaped (128-aligned) chunk tiles.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, hout_ref, h_scr,
                *, n_chunks: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0].astype(jnp.float32)        # (Q, H, P)
    dt = dt_ref[0].astype(jnp.float32)      # (Q, H)
    A = a_ref[...].astype(jnp.float32)      # (H,)
    B = b_ref[0].astype(jnp.float32)        # (Q, N)
    C = c_ref[0].astype(jnp.float32)        # (Q, N)
    Q = x.shape[0]

    log_a = dt * A[None, :]                 # (Q, H), <= 0
    cum = jnp.cumsum(log_a, axis=0)         # inclusive
    total = cum[-1]                         # (H,)

    # ---- intra-chunk (attention-like) ----
    seg = cum[:, None, :] - cum[None, :, :]               # (t, s, H)
    tri = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    decay = jnp.where(tri[..., None], jnp.exp(seg), 0.0)  # (t, s, H)
    cb = C @ B.T                                          # (t, s)
    w = cb[..., None] * decay * dt[None, :, :]            # (t, s, H)
    y = jnp.einsum("tsh,shp->thp", w, x)

    # ---- contribution of carried state ----
    in_decay = jnp.exp(cum)                               # (t, H)
    h_prev = h_scr[...]                                   # (H, P, N)
    y += jnp.einsum("tn,hpn,th->thp", C, h_prev, in_decay)

    # ---- update carried state ----
    state_decay = jnp.exp(total[None, :] - cum) * dt      # (s, H)
    s_new = jnp.einsum("sh,shp,sn->hpn", state_decay, x, B)
    h_scr[...] = jnp.exp(total)[:, None, None] * h_prev + s_new

    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _emit_state():
        hout_ref[0] = h_scr[...].astype(hout_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("chunk", "interpret"),
)
def ssd_chunked_pallas(x, dt, A, B, C, *, chunk: int = 128,
                       interpret: bool = True):
    """x (b,L,H,P); dt (b,L,H); A (H,); B, C (b,L,N). L % chunk == 0.

    Returns (y (b,L,H,P) in x.dtype, h_final (b,H,P,N) fp32).
    """
    b, L, H, P = x.shape
    N = B.shape[-1]
    nc = L // chunk

    kernel = functools.partial(_ssd_kernel, n_chunks=nc)
    y, h_final = pl.pallas_call(
        kernel,
        grid=(b, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, H, P), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, chunk, H), lambda i, j: (i, j, 0)),
            pl.BlockSpec((H,), lambda i, j: (0,)),
            pl.BlockSpec((1, chunk, N), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, N), lambda i, j: (i, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, H, P), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, H, P, N), lambda i, j: (i, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, L, H, P), x.dtype),
            jax.ShapeDtypeStruct((b, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((H, P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, B, C)
    return y, h_final
