"""jit'd public wrapper for the chunked-SSD Pallas kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.common import default_interpret
from repro.kernels.mamba2_scan.kernel import ssd_chunked_pallas


def ssd_scan(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
             C: jax.Array, *, chunk: int = 128,
             interpret: bool | None = None):
    """Drop-in replacement for models.ssm.ssd_chunked (same contract)."""
    if interpret is None:
        interpret = default_interpret()
    L = x.shape[1]
    q = min(chunk, L)
    while L % q:
        q //= 2
    y, h_final = ssd_chunked_pallas(x, dt, A, B, C, chunk=q,
                                    interpret=interpret)
    return y, h_final.astype(x.dtype)
