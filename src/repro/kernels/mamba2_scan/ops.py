"""jit'd public wrapper for the chunked-SSD Pallas kernel.

Forward-only kernel + ``custom_vjp``: the backward pass differentiates the
sequential jnp oracle (:mod:`.ref`) on the saved inputs, so the op is
trainable (see flash_attention/ops.py for the rationale).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.common import default_interpret
from repro.kernels.mamba2_scan.kernel import ssd_chunked_pallas
from repro.kernels.mamba2_scan.ref import ssd_ref


@partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def _ssd_scan(x, dt, A, B, C, chunk, interpret):
    L = x.shape[1]
    q = min(chunk, L)
    while L % q:
        q //= 2
    y, h_final = ssd_chunked_pallas(x, dt, A, B, C, chunk=q,
                                    interpret=interpret)
    return y, h_final.astype(x.dtype)


def _ssd_fwd(x, dt, A, B, C, chunk, interpret):
    out = _ssd_scan(x, dt, A, B, C, chunk, interpret)
    return out, (x, dt, A, B, C)


def _ssd_bwd(chunk, interpret, res, g):
    x, dt, A, B, C = res
    ref_out, vjp = jax.vjp(ssd_ref, x, dt, A, B, C)
    g = jax.tree.map(lambda gi, oi: gi.astype(oi.dtype), g, ref_out)
    return vjp(g)


_ssd_scan.defvjp(_ssd_fwd, _ssd_bwd)


def ssd_scan(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
             C: jax.Array, *, chunk: int = 128,
             interpret: bool | None = None):
    """Drop-in replacement for models.ssm.ssd_chunked (same contract)."""
    if interpret is None:
        interpret = default_interpret()
    return _ssd_scan(x, dt, A, B, C, chunk, interpret)
