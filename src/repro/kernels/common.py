"""Shared kernel plumbing: interpret-mode policy and padding helpers.

TPU v5e is the TARGET; this container is CPU-only. All kernels are authored
with ``pl.pallas_call`` + explicit BlockSpec VMEM tiling for the MXU (block
dims multiples of 128 where the operand feeds a matmul) and VALIDATED with
``interpret=True``, which executes the kernel body on CPU.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def default_interpret() -> bool:
    """Interpret unless we are actually on TPU hardware."""
    return jax.default_backend() != "tpu"


def pad_to(x: jax.Array, axis: int, multiple: int, value: float = 0.0):
    """Pad ``axis`` up to a multiple; returns (padded, original_size)."""
    size = x.shape[axis]
    target = -(-size // multiple) * multiple
    if target == size:
        return x, size
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, target - size)
    return jnp.pad(x, pads, constant_values=value), size
