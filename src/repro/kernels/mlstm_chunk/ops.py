"""jit'd public wrapper for the chunkwise mLSTM Pallas kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.common import default_interpret
from repro.kernels.mlstm_chunk.kernel import mlstm_chunked_pallas


def mlstm_scan(q: jax.Array, k: jax.Array, v: jax.Array, logi: jax.Array,
               logf: jax.Array, *, chunk: int = 128,
               interpret: bool | None = None):
    """Drop-in replacement for models.xlstm.mlstm_chunked.

    q,k,v: (b, L, H, dh); logi/logf (b, L, H). Returns (h (b,L,H,dh),
    (C (b,H,dh,dh), n (b,H,dh), m (b,H))).
    """
    if interpret is None:
        interpret = default_interpret()
    b, L, H, dh = q.shape
    cq = min(chunk, L)
    while L % cq:
        cq //= 2

    def flat(t):
        return t.transpose(0, 2, 1, 3).reshape(b * H, L, dh)

    def flat2(t):
        return t.transpose(0, 2, 1).reshape(b * H, L)

    h, (C, n, m) = mlstm_chunked_pallas(
        flat(q), flat(k), flat(v), flat2(logi), flat2(logf),
        chunk=cq, interpret=interpret,
    )
    h = h.reshape(b, H, L, dh).transpose(0, 2, 1, 3)
    return h, (
        C.reshape(b, H, dh, dh),
        n.reshape(b, H, dh),
        m.reshape(b, H),
    )
