"""jit'd public wrapper for the chunkwise mLSTM Pallas kernel.

Forward-only kernel + ``custom_vjp``: the backward pass differentiates the
sequential jnp oracle (:mod:`.ref`) on the saved inputs, so the op is
trainable (see flash_attention/ops.py for the rationale).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.common import default_interpret
from repro.kernels.mlstm_chunk.kernel import mlstm_chunked_pallas
from repro.kernels.mlstm_chunk.ref import mlstm_ref


@partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def _mlstm_scan(q, k, v, logi, logf, chunk, interpret):
    b, L, H, dh = q.shape
    cq = min(chunk, L)
    while L % cq:
        cq //= 2

    def flat(t):
        return t.transpose(0, 2, 1, 3).reshape(b * H, L, dh)

    def flat2(t):
        return t.transpose(0, 2, 1).reshape(b * H, L)

    h, (C, n, m) = mlstm_chunked_pallas(
        flat(q), flat(k), flat(v), flat2(logi), flat2(logf),
        chunk=cq, interpret=interpret,
    )
    h = h.reshape(b, H, L, dh).transpose(0, 2, 1, 3)
    return h, (
        C.reshape(b, H, dh, dh),
        n.reshape(b, H, dh),
        m.reshape(b, H),
    )


def _mlstm_fwd(q, k, v, logi, logf, chunk, interpret):
    out = _mlstm_scan(q, k, v, logi, logf, chunk, interpret)
    return out, (q, k, v, logi, logf)


def _mlstm_bwd(chunk, interpret, res, g):
    q, k, v, logi, logf = res
    ref_out, vjp = jax.vjp(mlstm_ref, q, k, v, logi, logf)
    g = jax.tree.map(lambda gi, oi: gi.astype(oi.dtype), g, ref_out)
    return vjp(g)


_mlstm_scan.defvjp(_mlstm_fwd, _mlstm_bwd)


def mlstm_scan(q: jax.Array, k: jax.Array, v: jax.Array, logi: jax.Array,
               logf: jax.Array, *, chunk: int = 128,
               interpret: bool | None = None):
    """Drop-in replacement for models.xlstm.mlstm_chunked.

    q,k,v: (b, L, H, dh); logi/logf (b, L, H). Returns (h (b,L,H,dh),
    (C (b,H,dh,dh), n (b,H,dh), m (b,H))).
    """
    if interpret is None:
        interpret = default_interpret()
    return _mlstm_scan(q, k, v, logi, logf, chunk, interpret)
