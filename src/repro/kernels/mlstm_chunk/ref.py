"""Sequential oracle for the stabilized mLSTM recurrence (xLSTM)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def mlstm_ref(q: jax.Array, k: jax.Array, v: jax.Array, logi: jax.Array,
              logf: jax.Array):
    """q,k,v (b,L,H,dh); logi, logf (b,L,H). Returns (h, (C, n, m))."""
    b, L, H, dh = q.shape
    scale = dh**-0.5
    C0 = jnp.zeros((b, H, dh, dh), jnp.float32)
    n0 = jnp.zeros((b, H, dh), jnp.float32)
    m0 = jnp.full((b, H), -1e30, jnp.float32)

    def step(state, inp):
        C, n, m = state
        q_t, k_t, v_t, li, lf = inp
        m_new = jnp.maximum(lf + m, li)
        f_eff = jnp.exp(lf + m - m_new)
        i_eff = jnp.exp(li - m_new)
        C = f_eff[..., None, None] * C + i_eff[..., None, None] * (
            v_t[..., :, None] * k_t[..., None, :]
        )
        n = f_eff[..., None] * n + i_eff[..., None] * k_t
        qs = q_t * scale
        num = jnp.einsum("bhde,bhe->bhd", C, qs)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n, qs)),
                          jnp.exp(-m_new))
        h = num / den[..., None]
        return (C, n, m_new), h

    inputs = tuple(
        jnp.moveaxis(t.astype(jnp.float32), 1, 0)
        for t in (q, k, v, logi, logf)
    )
    (C, n, m), hs = jax.lax.scan(step, (C0, n0, m0), inputs)
    return jnp.moveaxis(hs, 0, 1).astype(q.dtype), (C, n, m)
