"""Chunkwise-parallel mLSTM Pallas TPU kernel.

Grid = (batch*heads, n_chunks): chunk axis sequential, per-(batch, head)
matrix memory C (dh x dh), normalizer n (dh) and log-stabilizer m held in
VMEM scratch across chunks. Intra-chunk work is the masked decay-weighted
QK^T V product (MXU matmuls); the S x S gate matrix only ever exists as a
(chunk x chunk) VMEM tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _mlstm_kernel(q_ref, k_ref, v_ref, li_ref, lf_ref, h_ref,
                  c_out_ref, n_out_ref, m_out_ref,
                  c_scr, n_scr, m_scr, *, n_chunks: int, scale: float):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        c_scr[...] = jnp.zeros_like(c_scr)
        n_scr[...] = jnp.zeros_like(n_scr)
        m_scr[...] = jnp.full_like(m_scr, NEG)

    q = q_ref[0].astype(jnp.float32) * scale   # (Q, dh)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    li = li_ref[0].astype(jnp.float32)         # (Q,)
    lf = lf_ref[0].astype(jnp.float32)
    Q = q.shape[0]

    Fl = jnp.cumsum(lf)                        # inclusive (Q,)
    m_prev = m_scr[0, 0]
    b_term = li - Fl
    cmax = jnp.maximum(m_prev, jax.lax.cummax(b_term))   # (Q,)
    m_t = Fl + cmax
    inter = jnp.exp(m_prev - cmax)             # (Q,)

    seg = Fl[:, None] - Fl[None, :] + li[None, :] - m_t[:, None]
    tri = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    w = jnp.where(tri, jnp.exp(seg), 0.0)      # (t, s)
    qk = q @ k.T                               # (t, s)

    C_prev = c_scr[...]                        # (dh, dh)
    n_prev = n_scr[...][:, 0]                  # (dh,)
    num = (w * qk) @ v + inter[:, None] * (q @ C_prev.T)
    den = jnp.sum(w * qk, axis=1) + inter * (q @ n_prev)
    denom = jnp.maximum(jnp.abs(den), jnp.exp(-m_t))
    h_ref[0] = (num / denom[:, None]).astype(h_ref.dtype)

    # ---- carry ----
    F_tot = Fl[-1]
    m_new = m_t[-1]
    carry_decay = jnp.exp(m_prev + F_tot - m_new)
    upd_w = jnp.exp(li + F_tot - Fl - m_new)   # (s,)
    c_scr[...] = carry_decay * C_prev + (v * upd_w[:, None]).T @ k
    n_scr[...] = carry_decay * n_scr[...] + jnp.sum(
        k * upd_w[:, None], axis=0, keepdims=True
    ).T
    m_scr[...] = jnp.full_like(m_scr, m_new)

    @pl.when(ci == n_chunks - 1)
    def _emit():
        c_out_ref[0] = c_scr[...]
        n_out_ref[0] = n_scr[...][:, 0]
        m_out_ref[0] = m_scr[0, :1]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mlstm_chunked_pallas(q, k, v, logi, logf, *, chunk: int = 128,
                         interpret: bool = True):
    """q,k,v (bh, L, dh); logi/logf (bh, L). L % chunk == 0.

    Returns (h (bh, L, dh), (C (bh, dh, dh), n (bh, dh), m (bh, 1))).
    """
    bh, L, dh = q.shape
    nc = L // chunk
    scale = dh**-0.5

    kernel = functools.partial(_mlstm_kernel, n_chunks=nc, scale=scale)
    h, C, n, m = pl.pallas_call(
        kernel,
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, dh), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, dh), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, dh), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk), lambda i, j: (i, j)),
            pl.BlockSpec((1, chunk), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, dh), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, dh, dh), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, dh), lambda i, j: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, L, dh), q.dtype),
            jax.ShapeDtypeStruct((bh, dh, dh), jnp.float32),
            jax.ShapeDtypeStruct((bh, dh), jnp.float32),
            jax.ShapeDtypeStruct((bh, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((dh, dh), jnp.float32),
            pltpu.VMEM((dh, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, logi, logf)
    return h, (C, n, m)
