"""jit'd public wrapper for the quadratic-game block-operator kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.block_operator.kernel import block_operator_pallas
from repro.kernels.common import default_interpret


def block_operator(A: jax.Array, B: jax.Array, a: jax.Array, x: jax.Array, *,
                   interpret: bool | None = None) -> jax.Array:
    """F(x) for the Section 4.1 game. A (n,d,d); B (n,n,d,d); a, x (n,d)."""
    if interpret is None:
        interpret = default_interpret()
    n, d = x.shape
    # pad d to the MXU lane width for the TPU target
    pad = (-d) % 128 if not interpret else 0
    if pad:
        A = jnp.pad(A, ((0, 0), (0, pad), (0, pad)))
        B = jnp.pad(B, ((0, 0), (0, 0), (0, pad), (0, pad)))
        a = jnp.pad(a, ((0, 0), (0, pad)))
        x = jnp.pad(x, ((0, 0), (0, pad)))
    out = block_operator_pallas(A, B, a, x, interpret=interpret)
    return out[:, :d] if pad else out
