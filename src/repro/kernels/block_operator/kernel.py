"""Blocked joint-operator Pallas kernel for the Section 4.1 quadratic game.

F(x)_i = A_i x^i + a_i + sum_{j != i} B_ij x^j — a block matvec whose
coupling blocks dominate (n^2 of them). Grid = (n players, j-tiles); each
step multiplies a (TILE_J, d, d) slab of player i's coupling row against the
matching slice of the joint vector and accumulates into VMEM scratch, so the
(n*d)^2 block matrix streams tile-by-tile while the accumulator stays
resident. d is padded to the 128 MXU lane width by ops.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _block_op_kernel(a_diag_ref, b_ref, a_vec_ref, x_ref, xall_ref, o_ref,
                     acc_scr, *, tile_j: int, n_tiles: int):
    ji = pl.program_id(1)

    @pl.when(ji == 0)
    def _init():
        # own-block term + offset once
        x_i = x_ref[0]                                    # (d,)
        acc_scr[...] = (a_diag_ref[0] @ x_i + a_vec_ref[0])[None, :]

    b = b_ref[0]                                          # (tile_j, d, d)
    xs = xall_ref[...]                                    # (tile_j, d)
    acc_scr[...] += jnp.einsum(
        "jde,je->d", b.astype(jnp.float32), xs.astype(jnp.float32)
    )[None, :]

    @pl.when(ji == n_tiles - 1)
    def _emit():
        o_ref[0] = acc_scr[0].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tile_j", "interpret"))
def block_operator_pallas(A, B, a, x, *, tile_j: int = 1,
                          interpret: bool = True):
    """A (n,d,d); B (n,n,d,d) zero-diagonal; a (n,d); x (n,d) -> F (n,d)."""
    n, d = x.shape
    n_tiles = n // tile_j

    kernel = functools.partial(_block_op_kernel, tile_j=tile_j,
                               n_tiles=n_tiles)
    return pl.pallas_call(
        kernel,
        grid=(n, n_tiles),
        in_specs=[
            pl.BlockSpec((1, d, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, tile_j, d, d), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, d), lambda i, j: (i, 0)),
            pl.BlockSpec((1, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_j, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, d), jnp.float32)],
        interpret=interpret,
    )(A, B, a, x, x)
