"""Oracle for the quadratic-game joint operator F(x) = A_i x^i + a_i + sum_j B_ij x^j."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def block_operator_ref(A: jax.Array, B: jax.Array, a: jax.Array,
                       x: jax.Array) -> jax.Array:
    """A (n,d,d); B (n,n,d,d) with zero diagonal blocks; a (n,d); x (n,d).

    Returns F(x) of shape (n, d) in fp32.
    """
    A = A.astype(jnp.float32)
    B = B.astype(jnp.float32)
    a = a.astype(jnp.float32)
    x = x.astype(jnp.float32)
    own = jnp.einsum("ide,ie->id", A, x)
    coupling = jnp.einsum("ijde,je->id", B, x)
    return own + a + coupling
