"""Serving: batched prefill + KV/recurrent-cache decode."""

from repro.serve.decode import generate, make_serve_step

__all__ = ["generate", "make_serve_step"]
