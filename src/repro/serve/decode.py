"""Serving: batched prefill + token-by-token decode with KV/recurrent caches."""

from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.model import decode_step, init_cache, prefill

Array = jax.Array


def make_serve_step(cfg: ModelConfig, *, window: int = 0) -> Callable:
    """``serve_step(params, cache, token) -> (next_token, logits, cache)``.

    This is the function lowered for the decode dry-run shapes: ONE new token
    against a ``seq_len``-deep cache.
    """

    def serve_step(params, cache, token):
        logits, cache = decode_step(params, cfg, cache, token, window=window)
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return next_token, logits, cache

    return serve_step


def generate(params, cfg: ModelConfig, batch: dict, *, max_new_tokens: int,
             capacity: int, window: int = 0, temperature: float = 0.0,
             key: Array | None = None) -> Array:
    """Greedy (or sampled) generation loop for examples/tests.

    Returns generated tokens (B, max_new_tokens).
    """
    logits, cache = prefill(params, cfg, batch, capacity=capacity, window=window)
    if temperature > 0.0 and key is None:
        key = jax.random.PRNGKey(0)

    def pick(logits, key):
        if temperature > 0.0:
            return jax.random.categorical(key, logits / temperature)
        return jnp.argmax(logits, axis=-1)

    serve_step = jax.jit(make_serve_step(cfg, window=window))
    token = pick(logits, key).astype(jnp.int32)[:, None]
    out = [token]
    for _ in range(max_new_tokens - 1):
        token, logits, cache = serve_step(params, cache, token)
        out.append(token)
    return jnp.concatenate(out, axis=1)
