"""Pure-pytree optimizers and schedules."""

from repro.optim.optimizers import (
    Optimizer,
    adamw,
    apply_updates,
    clip_by_global_norm,
    cosine_schedule,
    global_norm,
    pearl_local_schedule,
    sgd,
)

__all__ = ["Optimizer", "adamw", "apply_updates", "clip_by_global_norm",
           "cosine_schedule", "global_norm", "pearl_local_schedule", "sgd"]
