"""Minimal pure-pytree optimizers (no external deps).

API mirrors optax: an optimizer is ``(init_fn, update_fn)`` over parameter
pytrees; ``update_fn(grads, state, params) -> (updates, state)`` and updates
are *added* to params. All state lives in plain dicts so it shards/checkpoints
like any other pytree.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = object


class Optimizer(NamedTuple):
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, PyTree], tuple[PyTree, PyTree]]


def sgd(lr: float | Callable[[Array], Array], momentum: float = 0.0) -> Optimizer:
    """SGD with optional (heavy-ball) momentum.

    PEARL-SGD's local steps use this with momentum=0 — the paper's update
    rule x <- x - gamma * g, with gamma possibly a schedule of the step count.
    """

    def init(params):
        state = {"count": jnp.zeros((), jnp.int32)}
        if momentum:
            state["mu"] = jax.tree.map(jnp.zeros_like, params)
        return state

    def update(grads, state, params):
        del params
        step_lr = lr(state["count"]) if callable(lr) else lr
        if momentum:
            mu = jax.tree.map(lambda m, g: momentum * m + g, state["mu"], grads)
            updates = jax.tree.map(lambda m: -step_lr * m, mu)
            new_state = {"count": state["count"] + 1, "mu": mu}
        else:
            updates = jax.tree.map(lambda g: -step_lr * g, grads)
            new_state = {"count": state["count"] + 1}
        return updates, new_state

    return Optimizer(init, update)


def adamw(
    lr: float | Callable[[Array], Array],
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
) -> Optimizer:
    """AdamW with decoupled weight decay and bias correction."""

    def init(params):
        return {
            "count": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(jnp.zeros_like, params),
            "v": jax.tree.map(jnp.zeros_like, params),
        }

    def update(grads, state, params):
        count = state["count"] + 1
        step_lr = lr(count) if callable(lr) else lr
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
        c1 = 1 - b1**count.astype(jnp.float32)
        c2 = 1 - b2**count.astype(jnp.float32)

        def upd(m_, v_, p):
            step = m_ / c1 / (jnp.sqrt(v_ / c2) + eps)
            return -step_lr * (step + weight_decay * p)

        updates = jax.tree.map(upd, m, v, params)
        return updates, {"count": count, "m": m, "v": v}

    return Optimizer(init, update)


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)


def global_norm(tree: PyTree) -> Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(grads: PyTree, max_norm: float) -> PyTree:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads)


# ----------------------------------------------------------------- schedules
def cosine_schedule(peak_lr: float, warmup: int, total: int,
                    floor: float = 0.1) -> Callable[[Array], Array]:
    def fn(count):
        count = count.astype(jnp.float32)
        warm = peak_lr * count / max(warmup, 1)
        frac = jnp.clip((count - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(count < warmup, warm, cos)

    return fn


def pearl_local_schedule(gamma_rounds, tau: int) -> Callable[[Array], Array]:
    """Map a per-round PEARL step-size array to a per-local-step schedule.

    gamma_k = gamma_rounds[k // tau] — the paper keeps gamma constant within
    each round (Theorem 3.6's schedule changes only at synchronizations).
    """
    gammas = jnp.asarray(gamma_rounds, jnp.float32)

    def fn(count):
        idx = jnp.minimum(count // tau, gammas.shape[0] - 1)
        return gammas[idx]

    return fn
