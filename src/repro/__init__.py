"""repro: MpFL / PEARL-SGD production-grade JAX reproduction."""

__version__ = "0.1.0"
