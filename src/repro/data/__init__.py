"""Data pipeline: deterministic heterogeneous per-player token streams."""

from repro.data.synthetic import DataConfig, SyntheticTokenStream

__all__ = ["DataConfig", "SyntheticTokenStream"]
