"""Synthetic heterogeneous data pipeline for MpFL training.

Each player/silo ``i`` draws tokens from its *own* distribution D_i — a
player-specific power-law over a player-specific vocabulary permutation —
matching the paper's fully-heterogeneous (non-iid) setting where no
similarity between players' distributions is assumed. The stream is
deterministic in (seed, player, step) so restarts/checkpoint resumes are
reproducible without storing data state.

The generator is host-side numpy (cheap, streaming); device placement and
sharding happen in the trainer via ``jax.device_put`` with the batch
PartitionSpec.
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    batch_size: int               # per-player batch
    n_players: int = 1
    zipf_exponent: float = 1.1
    seed: int = 0


class SyntheticTokenStream:
    """Deterministic per-player token batches with ngram-ish local structure."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        # player-specific vocabulary permutation => heterogeneous marginals
        self.perms = np.stack(
            [rng.permutation(cfg.vocab_size) for _ in range(cfg.n_players)]
        )
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        probs = ranks**-cfg.zipf_exponent
        self.probs = probs / probs.sum()

    def batch(self, player: int, step: int) -> np.ndarray:
        """Tokens of shape (batch_size, seq_len) for ``player`` at ``step``."""
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, player, step])
        )
        raw = rng.choice(cfg.vocab_size, size=(cfg.batch_size, cfg.seq_len),
                         p=self.probs)
        # local structure: with prob 1/2 copy the previous token shifted by 1
        # (gives the LM something learnable beyond unigram frequencies)
        copy = rng.random((cfg.batch_size, cfg.seq_len)) < 0.5
        shifted = np.roll(raw, 1, axis=1)
        raw = np.where(copy, (shifted + 1) % cfg.vocab_size, raw)
        return self.perms[player][raw].astype(np.int32)

    def player_batches(self, step: int) -> np.ndarray:
        """(n_players, batch_size, seq_len) — one batch per player/silo."""
        return np.stack([self.batch(p, step) for p in range(self.cfg.n_players)])
