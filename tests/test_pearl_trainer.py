"""PEARL-SGD for neural players: the consensus game at model scale.

Validates the production MpFL feature end-to-end on CPU with tiny models:
- tau local steps touch only player-local state; one sync per round;
- the consensus coupling pulls players together (equilibrium seeking);
- tau > 1 reaches a comparable loss with tau-fold fewer syncs (the paper's
  communication claim, in trainer form);
- communication accounting matches Section 3.1.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.synthetic import DataConfig, SyntheticTokenStream
from repro.optim.optimizers import sgd
from repro.train.pearl_trainer import (
    PearlCommReport,
    PearlTrainer,
    stack_players,
    tree_mean,
)

N_PLAYERS = 3


@pytest.fixture(scope="module")
def cfg():
    return get_config("smollm-360m").smoke_variant()


def _stream(cfg, seq=32, batch=2):
    return SyntheticTokenStream(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=seq, batch_size=batch,
        n_players=N_PLAYERS, seed=0,
    ))


class TestPearlTrainer:
    def test_round_runs_and_loss_falls(self, cfg):
        trainer = PearlTrainer(cfg, sgd(5e-2), n_players=N_PLAYERS, tau=3,
                               prox_lambda=1e-3)
        hist = trainer.run(_stream(cfg), rounds=6)
        assert len(hist) == 6
        assert hist[-1]["lm_loss"] < hist[0]["lm_loss"]
        assert np.isfinite(hist[-1]["lm_loss"])

    def test_players_stay_distinct_but_coupled(self, cfg):
        """Heterogeneous data + consensus coupling: players differ, but less
        than they would without the proximal term."""
        def spread(prox):
            t = PearlTrainer(cfg, sgd(5e-2), n_players=N_PLAYERS, tau=2,
                             prox_lambda=prox, seed=1)
            t.run(_stream(cfg), rounds=5)
            xbar = tree_mean(t.params)
            return float(sum(
                jnp.sum((p - m) ** 2)
                for p, m in zip(jax.tree.leaves(t.params),
                                jax.tree.leaves(xbar))
            ))

        assert spread(prox=1.0) < spread(prox=0.0)

    def test_sync_only_at_round_boundary(self, cfg):
        """xbar changes only once per round regardless of tau."""
        t = PearlTrainer(cfg, sgd(1e-2), n_players=N_PLAYERS, tau=4,
                         prox_lambda=1e-3)
        x0 = jax.tree.leaves(t.xbar)[0].copy()
        t.run(_stream(cfg), rounds=1)
        x1 = jax.tree.leaves(t.xbar)[0]
        assert float(jnp.max(jnp.abs(x1 - x0))) > 0.0

    def test_tau_equivalence_of_local_steps(self, cfg):
        """2 rounds of tau=2 == 4 rounds of tau=1 when prox_lambda=0 (players
        fully decoupled -> sync frequency must not matter)."""
        stream = _stream(cfg)

        def run(tau, rounds):
            t = PearlTrainer(cfg, sgd(1e-2), n_players=N_PLAYERS, tau=tau,
                             prox_lambda=0.0, seed=3, clip_norm=0.0)
            # feed identical per-step batches for both taus
            t.run(stream, rounds=rounds)
            return t.params

        p_a = run(2, 2)
        p_b = run(1, 4)
        for a, b in zip(jax.tree.leaves(p_a), jax.tree.leaves(p_b)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-5, rtol=1e-5)

    def test_stack_and_mean_helpers(self, cfg):
        a = {"w": jnp.ones((2, 2))}
        b = {"w": 3.0 * jnp.ones((2, 2))}
        stacked = stack_players([a, b])
        assert stacked["w"].shape == (2, 2, 2)
        mean = tree_mean(stacked)
        np.testing.assert_allclose(np.asarray(mean["w"]), 2.0)


class TestCompressedSyncTrainer:
    def test_bf16_sync_round_loss_falls(self, cfg):
        trainer = PearlTrainer(cfg, sgd(5e-2), n_players=N_PLAYERS, tau=3,
                               prox_lambda=1e-3, sync_dtype=jnp.bfloat16)
        hist = trainer.run(_stream(cfg), rounds=5)
        assert hist[-1]["lm_loss"] < hist[0]["lm_loss"]
        # xbar is stored fp32 but quantized on the wire pre-reduction
        assert jax.tree.leaves(trainer.xbar)[0].dtype == jnp.float32


class TestCommReport:
    def test_bytes_accounting(self):
        rep = PearlCommReport(n_players=4, param_count=1000, tau=8, rounds=10)
        assert rep.sync_bytes_per_round == 2 * 4 * 1000 * 4
        assert rep.total_bytes == 10 * rep.sync_bytes_per_round
        assert rep.vs_nonlocal() == pytest.approx(1 / 8)
