"""PEARL-SGD for neural players: the consensus game at model scale.

Validates the production MpFL feature end-to-end on CPU with tiny models:
- tau local steps touch only player-local state; one sync per round;
- the consensus coupling pulls players together (equilibrium seeking);
- tau > 1 reaches a comparable loss with tau-fold fewer syncs (the paper's
  communication claim, in trainer form);
- communication accounting matches Section 3.1.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.synthetic import DataConfig, SyntheticTokenStream
from repro.optim.optimizers import sgd
from repro.train.pearl_trainer import (
    PearlCommReport,
    PearlTrainer,
    stack_players,
    tree_mean,
)

N_PLAYERS = 3


@pytest.fixture(scope="module")
def cfg():
    return get_config("smollm-360m").smoke_variant()


def _stream(cfg, seq=32, batch=2):
    return SyntheticTokenStream(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=seq, batch_size=batch,
        n_players=N_PLAYERS, seed=0,
    ))


class TestPearlTrainer:
    def test_round_runs_and_loss_falls(self, cfg):
        trainer = PearlTrainer(cfg, sgd(5e-2), n_players=N_PLAYERS, tau=3,
                               prox_lambda=1e-3)
        hist = trainer.run(_stream(cfg), rounds=6)
        assert len(hist) == 6
        assert hist[-1]["lm_loss"] < hist[0]["lm_loss"]
        assert np.isfinite(hist[-1]["lm_loss"])

    def test_players_stay_distinct_but_coupled(self, cfg):
        """Heterogeneous data + consensus coupling: players differ, but less
        than they would without the proximal term."""
        def spread(prox):
            t = PearlTrainer(cfg, sgd(5e-2), n_players=N_PLAYERS, tau=2,
                             prox_lambda=prox, seed=1)
            t.run(_stream(cfg), rounds=5)
            xbar = tree_mean(t.params)
            return float(sum(
                jnp.sum((p - m) ** 2)
                for p, m in zip(jax.tree.leaves(t.params),
                                jax.tree.leaves(xbar))
            ))

        assert spread(prox=1.0) < spread(prox=0.0)

    def test_sync_only_at_round_boundary(self, cfg):
        """xbar changes only once per round regardless of tau."""
        t = PearlTrainer(cfg, sgd(1e-2), n_players=N_PLAYERS, tau=4,
                         prox_lambda=1e-3)
        x0 = jax.tree.leaves(t.xbar)[0].copy()
        t.run(_stream(cfg), rounds=1)
        x1 = jax.tree.leaves(t.xbar)[0]
        assert float(jnp.max(jnp.abs(x1 - x0))) > 0.0

    def test_tau_equivalence_of_local_steps(self, cfg):
        """2 rounds of tau=2 == 4 rounds of tau=1 when prox_lambda=0 (players
        fully decoupled -> sync frequency must not matter)."""
        stream = _stream(cfg)

        def run(tau, rounds):
            t = PearlTrainer(cfg, sgd(1e-2), n_players=N_PLAYERS, tau=tau,
                             prox_lambda=0.0, seed=3, clip_norm=0.0)
            # feed identical per-step batches for both taus
            t.run(stream, rounds=rounds)
            return t.params

        p_a = run(2, 2)
        p_b = run(1, 4)
        for a, b in zip(jax.tree.leaves(p_a), jax.tree.leaves(p_b)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-5, rtol=1e-5)

    def test_stack_and_mean_helpers(self, cfg):
        a = {"w": jnp.ones((2, 2))}
        b = {"w": 3.0 * jnp.ones((2, 2))}
        stacked = stack_players([a, b])
        assert stacked["w"].shape == (2, 2, 2)
        mean = tree_mean(stacked)
        np.testing.assert_allclose(np.asarray(mean["w"]), 2.0)


class TestCompressedSyncTrainer:
    def test_bf16_sync_round_loss_falls(self, cfg):
        trainer = PearlTrainer(cfg, sgd(5e-2), n_players=N_PLAYERS, tau=3,
                               prox_lambda=1e-3, sync_dtype=jnp.bfloat16)
        hist = trainer.run(_stream(cfg), rounds=5)
        assert hist[-1]["lm_loss"] < hist[0]["lm_loss"]
        # xbar is stored fp32 but quantized on the wire pre-reduction
        assert jax.tree.leaves(trainer.xbar)[0].dtype == jnp.float32


class TestTopologyTrainer:
    """Graph topologies + mask strategies through the general stale-block
    merge round — the regimes PR 1's trainer refused."""

    def test_ring_partial_participation_runs_and_loss_falls(self, cfg):
        """The acceptance criterion: ring topology x partial participation,
        no NotImplementedError, training progresses."""
        from repro.core.engine import PartialParticipation
        from repro.core.topology import Ring

        trainer = PearlTrainer(
            cfg, sgd(5e-2), n_players=N_PLAYERS, tau=2, prox_lambda=1e-3,
            topology=Ring(), sync=PartialParticipation(fraction=0.7, seed=0),
        )
        hist = trainer.run(_stream(cfg), rounds=5)
        assert len(hist) == 5
        assert hist[-1]["lm_loss"] < hist[0]["lm_loss"]
        assert np.isfinite(hist[-1]["lm_loss"])

    def test_gossip_refs_are_per_player(self, cfg):
        """Under gossip each player optimizes against its OWN neighborhood
        mean: refs carry a player axis, unlike the replicated star xbar."""
        from repro.core.topology import Ring

        trainer = PearlTrainer(cfg, sgd(5e-2), n_players=N_PLAYERS, tau=2,
                               prox_lambda=1e-3, topology=Ring())
        trainer.run(_stream(cfg), rounds=2)
        ref_leaf = jax.tree.leaves(trainer.refs)[0]
        param_leaf = jax.tree.leaves(trainer.params)[0]
        assert ref_leaf.shape == param_leaf.shape
        assert ref_leaf.shape[0] == N_PLAYERS

    def test_zero_participation_freezes_snapshot(self, cfg):
        """fraction=0: nobody syncs, the stale snapshot (and hence xbar)
        never moves, but local training still advances the players."""
        from repro.core.engine import PartialParticipation

        trainer = PearlTrainer(
            cfg, sgd(5e-2), n_players=N_PLAYERS, tau=2, prox_lambda=1e-3,
            sync=PartialParticipation(fraction=0.0, seed=0),
        )
        x0 = jax.tree.leaves(trainer.xbar)[0].copy()
        p0 = jax.tree.leaves(trainer.params)[0].copy()
        trainer.run(_stream(cfg), rounds=2)
        np.testing.assert_array_equal(
            np.asarray(jax.tree.leaves(trainer.xbar)[0]), np.asarray(x0))
        assert float(jnp.max(jnp.abs(
            jax.tree.leaves(trainer.params)[0] - p0))) > 0.0

    def test_star_full_participation_matches_legacy_path(self, cfg):
        """PartialParticipation(fraction=1.0) through the general round
        reaches the same losses as the legacy star fast path (same batches,
        same init): the stale-block merge generalizes, not perturbs."""
        from repro.core.engine import PartialParticipation

        legacy = PearlTrainer(cfg, sgd(5e-2), n_players=N_PLAYERS, tau=2,
                              prox_lambda=1e-3, seed=2)
        hist_a = legacy.run(_stream(cfg), rounds=3)
        general = PearlTrainer(
            cfg, sgd(5e-2), n_players=N_PLAYERS, tau=2, prox_lambda=1e-3,
            seed=2, sync=PartialParticipation(fraction=1.0, seed=0),
        )
        hist_b = general.run(_stream(cfg), rounds=3)
        for a, b in zip(hist_a, hist_b):
            assert a["lm_loss"] == pytest.approx(b["lm_loss"], rel=1e-5)


class TestAsyncTrainer:
    """Bounded-staleness rounds for neural players: the event-shaped host
    loop (merge-on-arrival into the stale-block snapshot machinery)."""

    def test_async_d0_matches_lockstep_general_round(self, cfg):
        """ZeroDelay with bound 0: the async loop's host-side ref refresh
        reproduces the lockstep stale-block round's losses."""
        from repro.core.async_engine import ZeroDelay
        from repro.core.engine import PartialParticipation

        lockstep = PearlTrainer(
            cfg, sgd(5e-2), n_players=N_PLAYERS, tau=2, prox_lambda=1e-3,
            seed=2, sync=PartialParticipation(fraction=1.0, seed=0),
        )
        hist_a = lockstep.run(_stream(cfg), rounds=3)
        asynchronous = PearlTrainer(
            cfg, sgd(5e-2), n_players=N_PLAYERS, tau=2, prox_lambda=1e-3,
            seed=2, sync=PartialParticipation(fraction=1.0, seed=0),
            delays=ZeroDelay(), max_staleness=0,
        )
        hist_b = asynchronous.run(_stream(cfg), rounds=3)
        for a, b in zip(hist_a, hist_b):
            assert a["lm_loss"] == pytest.approx(b["lm_loss"], rel=1e-5)

    def test_async_staleness_trains_and_counts_rounds(self, cfg):
        """Uniform staleness with a participation mask: training advances,
        and the per-player round counters record what actually arrived."""
        from repro.core.async_engine import StaleSync, UniformDelay
        from repro.core.engine import PartialParticipation

        trainer = PearlTrainer(
            cfg, sgd(5e-2), n_players=N_PLAYERS, tau=2, prox_lambda=1e-3,
            sync=StaleSync(PartialParticipation(fraction=0.7, seed=0),
                           UniformDelay(seed=1), max_staleness=2),
        )
        hist = trainer.run(_stream(cfg), rounds=5)
        assert hist[-1]["lm_loss"] < hist[0]["lm_loss"]
        assert np.isfinite(hist[-1]["lm_loss"])
        # counters: each player merged as many syncs as rounds it drew
        assert trainer.player_rounds.sum() == sum(trainer._round_participants)
        assert (trainer.player_rounds <= 5).all()
        # staleness log covers every round, within the bound
        assert len(trainer.staleness_log) == 5
        assert max(int(row.max()) for row in trainer.staleness_log) <= 2
        # arrival bookkeeping: merged players record which round's snapshot
        # they last saw (-1 = still only the init), bounded by the rounds run
        merged = trainer.player_rounds > 0
        assert (trainer.player_snapshot_round[merged] >= -1).all()
        assert trainer.player_snapshot_round.max() >= 0
        assert trainer.player_snapshot_round.max() < 5

    def test_async_star_exact_forces_general_machinery(self, cfg):
        """Star + ExactSync is the legacy fast path — unless staleness is
        requested, which needs per-player refs and the snapshot history."""
        from repro.core.async_engine import ConstantDelay

        trainer = PearlTrainer(
            cfg, sgd(5e-2), n_players=N_PLAYERS, tau=2, prox_lambda=1e-3,
            delays=ConstantDelay(lag=1), max_staleness=1,
        )
        hist = trainer.run(_stream(cfg), rounds=4)
        assert hist[-1]["lm_loss"] < hist[0]["lm_loss"]
        assert len(trainer._snap_hist) <= 2     # bound + 1 snapshots kept
        ref_leaf = jax.tree.leaves(trainer.refs)[0]
        assert ref_leaf.shape[0] == N_PLAYERS   # per-player references

    def test_trainer_rejects_bad_bounds(self, cfg):
        from repro.core.async_engine import ZeroDelay

        with pytest.raises(ValueError, match="max_staleness"):
            PearlTrainer(cfg, sgd(5e-2), n_players=N_PLAYERS, tau=2,
                         prox_lambda=1e-3, delays=ZeroDelay(),
                         max_staleness=-1)
        trainer = PearlTrainer(cfg, sgd(5e-2), n_players=N_PLAYERS, tau=2,
                               prox_lambda=1e-3)
        with pytest.raises(ValueError, match="rounds"):
            trainer.run(_stream(cfg), rounds=0)

    def test_trainer_rejects_ambiguous_or_incomplete_delay_model(self, cfg):
        """A bound without a schedule would silently run lockstep; a
        StaleSync plus an explicit schedule is ambiguous — both are loud."""
        from repro.core.async_engine import ConstantDelay, StaleSync

        with pytest.raises(ValueError, match="delays"):
            PearlTrainer(cfg, sgd(5e-2), n_players=N_PLAYERS, tau=2,
                         prox_lambda=1e-3, max_staleness=3)
        with pytest.raises(ValueError, match="not both"):
            PearlTrainer(cfg, sgd(5e-2), n_players=N_PLAYERS, tau=2,
                         prox_lambda=1e-3, sync=StaleSync(max_staleness=4),
                         delays=ConstantDelay(lag=2), max_staleness=2)

    def test_make_pearl_round_rejects_stale_sync(self, cfg):
        """The compiled round cannot honor a delay model — only the trainer
        host loop can; the silent-no-op path is closed."""
        from repro.core.async_engine import StaleSync
        from repro.train.pearl_trainer import make_pearl_round

        with pytest.raises(ValueError, match="delay model"):
            make_pearl_round(cfg, sgd(5e-2), tau=2, prox_lambda=1e-3,
                             sync=StaleSync(max_staleness=2))


class TestCommReport:
    def test_bytes_accounting(self):
        rep = PearlCommReport(n_players=4, param_count=1000, tau=8, rounds=10)
        assert rep.sync_bytes_per_round == 2 * 4 * 1000 * 4
        assert rep.total_bytes == 10 * rep.sync_bytes_per_round
        assert rep.vs_nonlocal() == pytest.approx(1 / 8)

    def test_gossip_report_edge_aware(self):
        from repro.core.topology import Ring

        rep = PearlCommReport(n_players=4, param_count=1000, tau=8, rounds=10,
                              topology=Ring())
        up, down = rep.per_round_bytes()
        assert (up == 8 * 1000 * 4).all()   # 2n directed edges x one block
        assert (down == 0).all()
        assert rep.total_bytes == 10 * 8 * 1000 * 4

    def test_report_bills_recorded_participation(self):
        """Mask-aware billing: explicit per-round participants/messages
        override the full-participation defaults."""
        rep = PearlCommReport(n_players=4, param_count=100, tau=2, rounds=3,
                              participants=np.array([2, 0, 4]))
        up, down = rep.per_round_bytes()
        np.testing.assert_array_equal(up, [2 * 100 * 4, 0, 4 * 100 * 4])
        np.testing.assert_array_equal(down, [2 * 100 * 4, 0, 4 * 100 * 4])
        from repro.core.topology import Ring

        g = PearlCommReport(n_players=4, param_count=100, tau=2, rounds=2,
                            topology=Ring(), messages=np.array([6, 0]))
        g_up, g_down = g.per_round_bytes()
        np.testing.assert_array_equal(g_up, [6 * 100 * 4, 0])
        assert (g_down == 0).all()

    def test_trainer_report_uses_drawn_masks(self, cfg):
        """A fraction=0 trainer moved nothing — its default report bills 0
        bytes, while an explicit-rounds report stays the prospective
        full-participation estimate."""
        from repro.core.engine import PartialParticipation

        trainer = PearlTrainer(
            cfg, sgd(5e-2), n_players=N_PLAYERS, tau=2, prox_lambda=1e-3,
            sync=PartialParticipation(fraction=0.0, seed=0),
        )
        trainer.run(_stream(cfg), rounds=2)
        assert trainer.comm_report().total_bytes == 0
        prospective = trainer.comm_report(rounds=2)
        assert prospective.total_bytes > 0

    def test_tree_mean_rejects_mask_strategies(self):
        """tree_mean is the full-participation collective — a mask strategy
        must fail loudly, not silently average everyone."""
        from repro.core.engine import PartialParticipation

        with pytest.raises(ValueError):
            tree_mean({"w": jnp.ones((2, 3))},
                      sync=PartialParticipation(fraction=0.5))

    def test_lowbit_report_bills_per_leaf_scales(self, cfg):
        """An int8 wire bills one f32 scale per transmitted param leaf on
        top of the 1 B/scalar lanes; every other strategy bills zero
        overhead, so the legacy byte pins stay intact."""
        from repro.core.engine import Int8Sync
        from repro.models.model import param_shapes

        trainer = PearlTrainer(cfg, sgd(5e-2), n_players=N_PLAYERS, tau=2,
                               prox_lambda=1e-3, sync=Int8Sync())
        rep = trainer.comm_report(rounds=2)
        n_leaves = len(jax.tree.leaves(param_shapes(cfg)))
        assert rep.uplink_overhead_bytes == 4 * n_leaves
        assert rep.bytes_per_scalar == 1
        up, down = rep.per_round_bytes()
        assert (up == N_PLAYERS * (rep.param_count + 4 * n_leaves)).all()
        # every player downloads the f32 mean
        assert (down == N_PLAYERS * rep.param_count * 4).all()
        plain = PearlCommReport(n_players=4, param_count=100, tau=2,
                                rounds=1)
        assert plain.uplink_overhead_bytes == 0


class TestLowBitTrainer:
    """Int8/Int4 error-feedback wires on the star fast path: the residual
    threads through the jitted round (tree_mean_lowbit)."""

    def test_int8_ef_round_trains_and_carries_residual(self, cfg):
        from repro.core.engine import Int8Sync

        trainer = PearlTrainer(cfg, sgd(5e-2), n_players=N_PLAYERS, tau=2,
                               prox_lambda=1e-3, sync=Int8Sync())
        hist = trainer.run(_stream(cfg), rounds=4)
        assert hist[-1]["lm_loss"] < hist[0]["lm_loss"]
        # the error-feedback residual is live state, not zeros
        res = float(sum(jnp.sum(jnp.abs(l))
                        for l in jax.tree.leaves(trainer._wire_state)))
        assert res > 0.0

    def test_stateless_int8_keeps_zero_state(self, cfg):
        from repro.core.engine import Int8Sync

        trainer = PearlTrainer(cfg, sgd(5e-2), n_players=N_PLAYERS, tau=2,
                               prox_lambda=1e-3,
                               sync=Int8Sync(error_feedback=False))
        trainer.run(_stream(cfg), rounds=2)
        assert all(not np.asarray(l).any()
                   for l in jax.tree.leaves(trainer._wire_state))

    def test_tree_mean_lowbit_matches_strategy_roundtrip(self, cfg):
        """Host semantics: mean == mean_j roundtrip(x_j + e_j), residual
        == what the wire failed to carry."""
        from repro.core.engine import Int8Sync
        from repro.train.pearl_trainer import tree_mean_lowbit

        rng = np.random.default_rng(0)
        stacked = {"w": jnp.asarray(
            rng.standard_normal((N_PLAYERS, 4, 6)), jnp.float32)}
        state = jax.tree.map(jnp.zeros_like, stacked)
        sync = Int8Sync()
        mean, new_state = tree_mean_lowbit(stacked, state, sync)
        flat = stacked["w"].reshape(N_PLAYERS, -1)
        rt = sync.roundtrip(flat)
        np.testing.assert_array_equal(
            np.asarray(mean["w"]),
            np.asarray(jnp.mean(rt, axis=0,
                                dtype=jnp.float32).reshape(4, 6)))
        np.testing.assert_array_equal(
            np.asarray(new_state["w"]),
            np.asarray((flat - rt).reshape(N_PLAYERS, 4, 6)))

    def test_tree_mean_redirects_lowbit_to_lowbit_path(self):
        from repro.core.engine import Int8Sync

        with pytest.raises(ValueError, match="tree_mean_lowbit"):
            tree_mean({"w": jnp.ones((2, 3))}, sync=Int8Sync())

    def test_ef_lowbit_rejected_off_the_fast_path(self, cfg):
        """The general merge has no per-player residual carry: EF low-bit +
        mask/topology raises; error_feedback=False is the escape hatch."""
        from repro.core.engine import Int8Sync
        from repro.core.topology import Ring

        with pytest.raises(ValueError, match="error_feedback=False"):
            PearlTrainer(cfg, sgd(5e-2), n_players=N_PLAYERS, tau=2,
                         prox_lambda=1e-3, topology=Ring(),
                         sync=Int8Sync())
        trainer = PearlTrainer(
            cfg, sgd(5e-2), n_players=N_PLAYERS, tau=2, prox_lambda=1e-3,
            topology=Ring(), sync=Int8Sync(error_feedback=False))
        hist = trainer.run(_stream(cfg), rounds=2)
        assert np.isfinite(hist[-1]["lm_loss"])


multi_device = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs a multi-device (fake) mesh: run under "
           "XLA_FLAGS=--xla_force_host_platform_device_count=8",
)


@multi_device
class TestMeshLoweredTrainer:
    """The PR 8 tentpole pins: mesh x {masks, external refs, staleness}
    compile the general stale-block merge under shard_map, track the
    host-loop trajectories, and bill identical bytes."""

    @pytest.fixture(scope="class")
    def mesh(self):
        from repro.core import collective

        return collective.player_mesh(N_PLAYERS)

    def _run_pair(self, cfg, mesh, rounds=3, **kw):
        host = PearlTrainer(cfg, sgd(5e-2), n_players=N_PLAYERS, tau=2,
                            prox_lambda=1e-3, seed=2, **kw)
        h = host.run(_stream(cfg), rounds=rounds)
        mesht = PearlTrainer(cfg, sgd(5e-2), n_players=N_PLAYERS, tau=2,
                             prox_lambda=1e-3, seed=2, mesh=mesh, **kw)
        m = mesht.run(_stream(cfg), rounds=rounds)
        for a, b in zip(h, m):
            assert a["lm_loss"] == pytest.approx(b["lm_loss"], rel=1e-5)
        hr, mr = host.comm_report(), mesht.comm_report()
        np.testing.assert_array_equal(np.stack(hr.per_round_bytes()),
                                      np.stack(mr.per_round_bytes()))
        return host, mesht

    def test_mask_parity(self, cfg, mesh):
        from repro.core.engine import PartialParticipation

        self._run_pair(cfg, mesh,
                       sync=PartialParticipation(fraction=0.5, seed=7))

    def test_graph_times_mask_parity(self, cfg, mesh):
        from repro.core.engine import PartialParticipation
        from repro.core.topology import Ring

        self._run_pair(cfg, mesh, topology=Ring(),
                       sync=PartialParticipation(fraction=0.7, seed=1))

    def test_external_refs_parity(self, cfg, mesh):
        """Async d=0 (external refs, host-side refresh): the in-round merge
        is elementwise, so the mesh round compiles as plain sharded SPMD."""
        from repro.core.async_engine import ZeroDelay

        self._run_pair(cfg, mesh, delays=ZeroDelay(), max_staleness=0)

    def test_staleness_parity(self, cfg, mesh):
        """Bounded staleness: delayed references come from the host ring
        buffer either way; the lowering must not perturb the schedule."""
        from repro.core.async_engine import ConstantDelay

        host, mesht = self._run_pair(cfg, mesh, rounds=4,
                                     delays=ConstantDelay(lag=1),
                                     max_staleness=1)
        np.testing.assert_array_equal(
            np.stack(host.staleness_log), np.stack(mesht.staleness_log))

    def test_quantized_merge_wire_in_round_hlo(self, cfg, mesh):
        """The merge's all-gather ships bf16 bits (u16) in the compiled
        round — the PR 5 HLO-level claim, now for the general round."""
        from repro.core import collective
        from repro.core.topology import Ring

        trainer = PearlTrainer(
            cfg, sgd(5e-2), n_players=N_PLAYERS, tau=2, prox_lambda=1e-3,
            topology=Ring(), sync_dtype=jnp.bfloat16, mesh=mesh)
        tokens = {"tokens": jnp.zeros((N_PLAYERS, 2, 2, 32), jnp.int32)}
        hlo = trainer._round.lower(
            trainer.params, trainer.opt_state, tokens, trainer.refs,
            trainer.snapshot, jnp.ones((N_PLAYERS,), bool),
            jnp.asarray(trainer._mixes[0]),
        ).compile().as_text()
        report = collective.assert_wire_dtype(hlo, compressed=True)
        assert any(o.op == "all-gather" and o.operand_dtype == "u16"
                   for o in report)
