"""Roofline analysis units: HLO collective parsing, report math, param counts."""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.shapes import DECODE_32K, TRAIN_4K
from repro.models.model import param_shapes
from repro.roofline.analysis import (
    CollectiveStats,
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS,
    active_params,
    build_report,
    count_params,
    model_flops_estimate,
    parse_collectives,
)

HLO_SAMPLE = """
HloModule jit_step
  %all-reduce.81 = f32[16,4096,960]{2,1,0} all-reduce(%fusion.1), channel_id=1, replica_groups=[16,16]<=[256], use_global_device_ids=true
  %all-gather.3 = bf16[2048,1024]{1,0} all-gather(%p0), channel_id=2, replica_groups={{0,1,2,3}}, dimensions={0}
  %rs = f32[128]{0} reduce-scatter(%x), channel_id=3, replica_groups=[256,2]<=[2,256]T(1,0)
  %unrelated = f32[16]{0} add(%a, %b)
  %all-reduce.99 = (f32[8,8]{1,0}, f32[8,8]{1,0}) all-reduce(%c, %d), channel_id=4, replica_groups=[512,1]<=[512]
"""


class TestCollectiveParsing:
    def test_bytes_and_counts(self):
        st = parse_collectives(HLO_SAMPLE, chips_per_pod=256)
        assert st.count == 4
        ar1 = 16 * 4096 * 960 * 4 * 2          # all-reduce counts 2x
        ag = 2048 * 1024 * 2
        rs = 128 * 4
        ar2 = 2 * 8 * 8 * 4 * 2                # tuple all-reduce, both operands
        assert st.bytes_by_op["all-reduce"] == ar1 + ar2
        assert st.bytes_by_op["all-gather"] == ag
        assert st.bytes_by_op["reduce-scatter"] == rs
        assert st.total_bytes == ar1 + ar2 + ag + rs

    def test_pod_span_detection(self):
        st = parse_collectives(HLO_SAMPLE, chips_per_pod=256)
        # the transposed-iota reduce-scatter strides across pods (span 257);
        # the first all-reduce's groups span 16; the tuple all-reduce's
        # groups are contiguous runs of 1.
        rs = 128 * 4
        assert st.pod_bytes == rs

    def test_no_collectives(self):
        st = parse_collectives("%x = f32[4] add(%a, %b)")
        assert st.count == 0 and st.total_bytes == 0


class TestReportMath:
    def test_terms_and_bottleneck(self):
        coll = CollectiveStats({"all-reduce": int(50e9)}, int(50e9), 0, 3)
        rep = build_report(
            arch="a", shape="s", mesh_name="16x16", chips=256,
            cost={"flops": PEAK_FLOPS, "bytes accessed": HBM_BW / 2},
            collectives=coll, peak_memory=1e9, model_flops=PEAK_FLOPS * 256,
        )
        assert rep.compute_s == pytest.approx(1.0)
        assert rep.memory_s == pytest.approx(0.5)
        assert rep.collective_s == pytest.approx(1.0)
        assert rep.bottleneck in ("compute", "collective")
        assert rep.useful_flops_ratio == pytest.approx(1.0)


class TestParamAccounting:
    def test_dense_count_scale(self):
        cfg = get_config("smollm-360m")
        n = count_params(param_shapes(cfg))
        # 360M-class: embeddings 2*49152*960 ~ 94M + 32 blocks
        assert 2.5e8 < n < 5.5e8

    def test_moe_active_far_below_total(self):
        cfg = get_config("qwen3-moe-30b-a3b")
        shapes = param_shapes(cfg)
        total = count_params(shapes)
        active = active_params(cfg, shapes)
        assert 2.0e10 < total < 4.5e10          # ~30B class
        assert active < total / 6               # top-8 of 128 experts
        # known identity: active ~ total - experts*(1-k/E)
        assert active > 1e9

    def test_llama4_total_param_class(self):
        cfg = get_config("llama4-maverick-400b-a17b")
        total = count_params(param_shapes(cfg))
        assert 3.0e11 < total < 5.0e11          # ~400B class

    def test_model_flops_train_vs_decode(self):
        cfg = get_config("smollm-360m")
        shapes = param_shapes(cfg)
        act = active_params(cfg, shapes)
        train = model_flops_estimate(cfg, TRAIN_4K, act)
        dec = model_flops_estimate(cfg, DECODE_32K, act)
        assert train == pytest.approx(6.0 * act * 256 * 4096)
        assert dec == pytest.approx(2.0 * act * 128)
