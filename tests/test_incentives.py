"""Strategic participation: best-response masks against the closed form.

The incentive layer's testbed is :mod:`repro.core.games.participation`:
the continuum network-effects game has a closed-form largest equilibrium,
the discrete midpoint-grid game tracks it within O(1/n), and
:class:`~repro.core.incentives.BestResponseParticipation` with fresh
(optimistic) value estimates IS that discrete game — so the policy's
realized masks are pinned against analytic equilibria, not snapshots.

Plus the composition claims: the policy threads through both dense
engines and the neural trainer as an ordinary selection policy (zero new
plumbing), and under the async engine the best responses see the drawn
staleness row (stale players rationally sit out).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.async_engine import AsyncPearlEngine, UniformDelay
from repro.core.engine import PearlEngine, SgdUpdate
from repro.core.games.participation import (
    NetworkEffectsParticipationGame,
    make_participation_game,
)
from repro.core.incentives import PAYMENT_RULES, BestResponseParticipation
from repro.core.selection import SELECTION_POLICIES, resolve_selection

from helpers import gaussian_x0, weak_quad


def fresh_mask(policy, n, delay_row=None):
    """The policy's round-0 mask: optimistic values, no history."""
    state = policy.select_state(n)
    _, m = policy.select(state, n, 0, delay_row)
    return np.asarray(m)


# ========================================================= closed-form pins
class TestClosedForm:
    def test_discrete_br_matches_meta_game(self):
        """The policy's fixed point IS the meta-game's: same sweep, same
        equilibrium, player by player."""
        game = make_participation_game()
        policy = BestResponseParticipation(
            price=game.price, value_weight=game.value,
            cost_min=game.cost_min, cost_max=game.cost_max)
        game_mask, converged = game.best_response_iterate()
        assert converged
        np.testing.assert_array_equal(
            fresh_mask(policy, game.n), game_mask)

    @pytest.mark.parametrize("price", [0.25, 0.35, 0.45, 0.55])
    def test_interior_rate_tracks_continuum(self, price):
        """Discrete largest-equilibrium rate within 1.5/n of the continuum
        closed form s* = (p - c_min)/((c_max - c_min) - v)."""
        n = 40
        game = NetworkEffectsParticipationGame(
            n=n, price=price, value=0.2)
        policy = BestResponseParticipation(
            price=price, value_weight=0.2)
        rate = fresh_mask(policy, n).mean()
        assert abs(rate - game.equilibrium_rate()) <= 1.5 / n

    def test_free_rider_collapse(self):
        """price <= c_min: the cascade sheds EVERY player from the
        all-ones start — the death spiral, not a proportional decline."""
        game = make_participation_game(price=0.15)
        assert game.equilibrium_rate() == 0.0
        policy = BestResponseParticipation(price=0.15, value_weight=0.2)
        assert not fresh_mask(policy, game.n).any()

    def test_full_participation_regime(self):
        """price + v >= c_max: even the costliest player profits."""
        game = make_participation_game(price=0.75)
        assert game.equilibrium_rate() == 1.0
        policy = BestResponseParticipation(price=0.75, value_weight=0.2)
        assert fresh_mask(policy, game.n).all()

    def test_monotone_cascade_converges_within_n_sweeps(self):
        game = make_participation_game(n=30, price=0.3)
        mask, converged = game.best_response_iterate()
        assert converged
        # an equilibrium: one more sweep is a fixed point
        np.testing.assert_array_equal(game.best_response(mask), mask)

    def test_weak_network_effect_regime_required(self):
        with pytest.raises(ValueError, match="weak-network-effect"):
            NetworkEffectsParticipationGame(
                n=10, price=0.4, value=0.7, cost_min=0.2, cost_max=0.8)


# ============================================================ payment rules
class TestPaymentRules:
    def test_registry_entry_resolves(self):
        assert "best_response" in SELECTION_POLICIES
        assert isinstance(resolve_selection("best_response"),
                          BestResponseParticipation)

    def test_proportional_pays_by_value(self):
        """Under the proportional rule a worthless player's payment is 0,
        so it drops out where the flat rule would keep it."""
        n = 10
        policy = BestResponseParticipation(
            payment="proportional", price=0.85, value_weight=0.0)
        state = policy.select_state(n)
        state = dict(state,
                     values=jnp.asarray([1.0] * 5 + [0.0] * 5),
                     counts=jnp.ones((n,), jnp.int32))
        _, m = policy.select(state, n, 0, None)
        m = np.asarray(m)
        assert m[:5].all() and not m[5:].any()
        # flat control at the same price covers even the costliest player
        # (midpoint grid tops out at 0.77 < 0.85), so everyone stays
        flat = BestResponseParticipation(payment="fixed", price=0.85,
                                         value_weight=0.0)
        _, mf = flat.select(dict(state), n, 0, None)
        assert np.asarray(mf).all()

    def test_auction_fixed_point_and_documented_two_cycle(self):
        """The auction rule is non-monotone (more joiners dilute the
        share). A budget covering the costliest player's share at full
        participation (budget/n >= c_max) is a genuine all-in fixed
        point; below that the simultaneous-move crowd 2-cycles around
        the zero-profit coalition (all-in share pays nobody, solo share
        pays everybody) and the LAST sweep is the documented fallback —
        pinned here via the sweep parity."""
        n = 20
        rich = BestResponseParticipation(payment="auction", budget=16.0,
                                         value_weight=0.0)
        m = fresh_mask(rich, n)
        assert m.all()
        # fixed point: one more sweep against the all-in mask keeps it
        _, m2 = rich.select(rich.select_state(n), n, 1, None)
        assert np.asarray(m2).all()
        even = BestResponseParticipation(payment="auction", budget=1.0,
                                         value_weight=0.0, br_iters=16)
        odd = BestResponseParticipation(payment="auction", budget=1.0,
                                        value_weight=0.0, br_iters=15)
        assert fresh_mask(even, n).all()       # last sweep = all-in phase
        assert not fresh_mask(odd, n).any()    # last sweep = all-out phase

    def test_unknown_payment_rejected(self):
        assert PAYMENT_RULES == ("fixed", "proportional", "auction")
        with pytest.raises(ValueError, match="payment"):
            BestResponseParticipation(payment="bribery")

    def test_knob_ranges_validated(self):
        with pytest.raises(ValueError, match="price"):
            BestResponseParticipation(price=-0.1)
        with pytest.raises(ValueError, match="br_iters"):
            BestResponseParticipation(br_iters=0)
        with pytest.raises(ValueError, match="cost_min"):
            BestResponseParticipation(cost_min=0.9, cost_max=0.1)

    def test_explicit_costs_override_and_length_check(self):
        policy = BestResponseParticipation(
            costs=(0.1, 0.9), price=0.5, value_weight=0.0)
        m = fresh_mask(policy, 2)
        assert m.tolist() == [True, False]
        with pytest.raises(ValueError, match="2 entries for n=3"):
            policy.cost_vector(3)


# ====================================================== staleness coupling
class TestStalenessCoupling:
    def test_stale_players_rationally_sit_out(self):
        """staleness_discount charges the drawn delay as extra cost: a
        player acting on a stale broadcast drops out of the coalition the
        fresh players keep."""
        n = 10
        policy = BestResponseParticipation(
            price=0.9, value_weight=0.1, staleness_discount=0.2)
        delay_row = jnp.asarray([0.0] * 5 + [3.0] * 5)
        m = fresh_mask(policy, n, delay_row)
        assert m[:5].all() and not m[5:].any()
        # staleness-blind control keeps everyone
        blind = BestResponseParticipation(price=0.9, value_weight=0.1)
        assert fresh_mask(blind, n, delay_row).all()

    def test_lockstep_has_no_delay_row(self):
        policy = BestResponseParticipation(
            price=0.9, value_weight=0.1, staleness_discount=0.2)
        assert fresh_mask(policy, 10, None).all()


# ===================================================== engines: zero plumbing
class TestEngineThreading:
    @pytest.fixture(scope="class")
    def game(self):
        return weak_quad()

    def test_runs_in_lockstep_engine(self, game):
        eng = PearlEngine(update=SgdUpdate(),
                          sync=BestResponseParticipation(price=0.9))
        r = eng.run(game, gaussian_x0(game), tau=2, rounds=6, gamma=2e-3,
                    key=jax.random.PRNGKey(0))
        assert np.isfinite(r.rel_errors).all()
        # endogenous participation bills fewer bytes than the full round
        full = PearlEngine(update=SgdUpdate()).run(
            game, gaussian_x0(game), tau=2, rounds=6, gamma=2e-3,
            key=jax.random.PRNGKey(0))
        assert r.bytes_up.sum() <= full.bytes_up.sum()

    def test_runs_in_async_engine_with_staleness(self, game):
        eng = AsyncPearlEngine(
            update=SgdUpdate(),
            sync=BestResponseParticipation(price=0.9,
                                           staleness_discount=0.05),
            delays=UniformDelay(2), max_staleness=2)
        r = eng.run(game, gaussian_x0(game), tau=2, rounds=6, gamma=2e-3,
                    key=jax.random.PRNGKey(0))
        assert np.isfinite(r.rel_errors).all()

    def test_collapse_freezes_the_joint_state(self, game):
        """The all-out equilibrium is legitimate: nobody syncs, nobody
        bills, the joint state never moves off x0."""
        eng = PearlEngine(update=SgdUpdate(),
                          sync=BestResponseParticipation(
                              price=0.05, value_weight=0.0))
        x0 = gaussian_x0(game)
        r = eng.run(game, x0, tau=2, rounds=4, gamma=2e-3,
                    key=jax.random.PRNGKey(0))
        assert int(r.bytes_up.sum()) == 0

    def test_runs_in_trainer_general_merge(self):
        from repro.configs import get_config
        from repro.data.synthetic import DataConfig, SyntheticTokenStream
        from repro.optim.optimizers import sgd
        from repro.train.pearl_trainer import PearlTrainer

        cfg = get_config("smollm-360m").smoke_variant()
        trainer = PearlTrainer(
            cfg, sgd(5e-2), n_players=3, tau=2, prox_lambda=1e-3,
            sync=BestResponseParticipation(price=0.9))
        stream = SyntheticTokenStream(DataConfig(
            vocab_size=cfg.vocab_size, seq_len=16, batch_size=2,
            n_players=3, seed=0))
        hist = trainer.run(stream, rounds=2)
        assert len(hist) == 2
        assert np.isfinite(hist[-1]["lm_loss"])
