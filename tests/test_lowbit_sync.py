"""Sub-bf16 wire tests: int8/int4 quantization, packing, error feedback.

Single-device safe — the codec and the error-feedback dynamics are host
semantics; the mesh lowering of the same wire is pinned in
tests/test_async_mesh.py and tests/test_collective.py. What is pinned
here, per the acceptance criteria:

- ``int4_pack`` / ``int4_unpack`` are bitwise inverses over the full lane
  range, and the single-u8-payload codec (4 scale bytes + lanes) decodes
  to EXACTLY the strategy's ``roundtrip`` — the wire is the quantizer;
- error feedback drives the int8/int4 trajectories to the exact-sync
  fixed point on a weak-coupling quadratic, while int4 WITHOUT the
  residual stalls at a quantization-grid neighborhood (the recorded
  boundary that motivates the default);
- byte accounting: lanes at 1 / 0.5 B per scalar plus one f32 scale per
  relayed block, exact to the byte;
- invalid compositions reject loudly: EF x gossip, EF x trainer
  ``tree_mean``, int4 x odd block size.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import stepsize
from repro.core.async_engine import AsyncPearlEngine, UniformDelay
from repro.core.engine import (
    SYNC_STRATEGIES,
    ExactSync,
    Int4Sync,
    Int8Sync,
    PearlEngine,
    int4_pack,
    int4_unpack,
    int4_quantize,
    int8_quantize,
    lowbit_dequantize,
)
from repro.core.games import make_quadratic_game
from repro.core.topology import Ring
from repro.train.pearl_trainer import tree_mean


@pytest.fixture(scope="module")
def weak():
    # weak coupling (L_B = 1.0): the contraction has slack to absorb
    # quantization noise, so fixed-point claims are sharp
    return make_quadratic_game(n=6, d=10, M=40, L_B=1.0, batch_size=1, seed=0)


@pytest.fixture(scope="module")
def x0w(weak):
    return jnp.asarray(
        np.random.default_rng(0).standard_normal((weak.n, weak.d)),
        dtype=jnp.float32,
    )


def _run(game, x0, sync, rounds=300, engine_cls=PearlEngine, gmul=1.0, **kw):
    gamma = gmul * stepsize.gamma_constant(game.constants(), 4)
    return engine_cls(sync=sync, **kw).run(
        game, x0, tau=4, rounds=rounds, gamma=gamma,
        key=jax.random.PRNGKey(0), stochastic=False)


# =========================================================================
# Quantizer + codec (pure function level)
# =========================================================================
class TestQuantizer:
    def test_int4_pack_unpack_bitwise_inverse(self):
        # every nibble value on both lane positions, plus random tensors
        lanes = jnp.asarray(
            np.stack([np.arange(-8, 8), np.arange(7, -9, -1)]), jnp.int8)
        assert np.array_equal(np.asarray(int4_unpack(int4_pack(lanes))),
                              np.asarray(lanes))
        rng = np.random.default_rng(1)
        q = jnp.asarray(rng.integers(-8, 8, size=(3, 5, 16)), jnp.int8)
        packed = int4_pack(q)
        assert packed.dtype == jnp.uint8
        assert packed.shape == (3, 5, 8)
        assert np.array_equal(np.asarray(int4_unpack(packed)), np.asarray(q))

    def test_int4_pack_rejects_odd_last_axis(self):
        with pytest.raises(ValueError, match="even last axis"):
            int4_pack(jnp.zeros((4, 7), jnp.int8))

    def test_quantize_ranges_and_zero_block(self):
        x = jnp.asarray(
            np.random.default_rng(2).standard_normal((4, 12)) * 50,
            jnp.float32)
        q8, s8 = int8_quantize(x)
        q4, s4 = int4_quantize(x)
        assert int(np.abs(np.asarray(q8)).max()) <= 127
        assert int(np.abs(np.asarray(q4)).max()) <= 7
        # the per-block max quantizes to the top level exactly
        assert np.all(np.abs(np.asarray(q8)).max(axis=-1) == 127)
        # an all-zero block must dequantize to zeros, not NaN (tiny floor)
        zq, zs = int8_quantize(jnp.zeros((2, 6), jnp.float32))
        out = lowbit_dequantize(zq, zs, jnp.float32)
        assert np.array_equal(np.asarray(out), np.zeros((2, 6), np.float32))

    def test_relative_error_bounded_by_grid(self):
        x = jnp.asarray(
            np.random.default_rng(3).standard_normal((8, 64)), jnp.float32)
        for sync, qmax in ((Int8Sync(), 127.0), (Int4Sync(), 7.0)):
            err = np.abs(np.asarray(sync.roundtrip(x) - x))
            step = np.abs(np.asarray(x)).max(axis=-1, keepdims=True) / qmax
            assert np.all(err <= 0.5 * step + 1e-7)


class TestWireCodec:
    @pytest.mark.parametrize("sync", [Int8Sync(), Int4Sync()],
                             ids=["int8", "int4"])
    def test_encode_decode_is_roundtrip_bitwise(self, sync):
        x = jnp.asarray(
            np.random.default_rng(4).standard_normal((6, 32)) * 3,
            jnp.float32)
        payload = sync.wire_encode(x)
        assert payload.dtype == jnp.uint8
        decoded = sync.wire_decode(payload, x.dtype)
        assert np.array_equal(np.asarray(decoded),
                              np.asarray(sync.roundtrip(x)))

    def test_payload_layout_is_scale_plus_lanes(self):
        x = jnp.asarray(
            np.random.default_rng(5).standard_normal((3, 16)), jnp.float32)
        # int8: 4 scale bytes + d lanes; int4: 4 + d/2
        assert Int8Sync().wire_encode(x).shape == (3, 4 + 16)
        assert Int4Sync().wire_encode(x).shape == (3, 4 + 8)
        scale_bits = np.asarray(Int8Sync().wire_encode(x)[..., :4])
        s = np.asarray(int8_quantize(x)[1], np.float32)
        assert np.array_equal(scale_bits.view(np.float32).reshape(3, 1), s)


# =========================================================================
# Error-feedback dynamics (host engine)
# =========================================================================
class TestErrorFeedback:
    # The separating regime: at the full Theorem 3.4 step size the EF noise
    # ball and the biased stall overlap within an order of magnitude; at
    # 0.25x the EF neighborhood shrinks with gamma while the biased stall
    # stays put (it is set by the grid, not the step), so the boundary is
    # two orders wide and robust to platform noise.
    GMUL, ROUNDS = 0.25, 800

    @pytest.mark.parametrize("sync,floor",
                             [(Int8Sync(), 1e-8), (Int4Sync(), 1e-6)],
                             ids=["int8", "int4"])
    def test_ef_reaches_exact_sync_fixed_point(self, weak, x0w, sync, floor):
        exact = _run(weak, x0w, ExactSync(), rounds=self.ROUNDS,
                     gmul=self.GMUL)
        low = _run(weak, x0w, sync, rounds=self.ROUNDS, gmul=self.GMUL)
        # the EF wire is asymptotically unbiased: same fixed point as the
        # exact broadcast, down to a gamma-scaled residual noise floor
        # (measured ~3e-10 int8 / ~9e-8 int4 in this regime)
        assert float(low.rel_errors[-1]) <= \
            max(10.0 * float(exact.rel_errors[-1]), floor)

    def test_int4_without_ef_stalls_at_grid(self, weak, x0w):
        ef = _run(weak, x0w, Int4Sync(), rounds=self.ROUNDS, gmul=self.GMUL)
        no_ef = _run(weak, x0w, Int4Sync(error_feedback=False),
                     rounds=self.ROUNDS, gmul=self.GMUL)
        # the recorded boundary: biased int4 stalls orders of magnitude
        # above the EF fixed point (but does not diverge)
        assert float(no_ef.rel_errors[-1]) >= \
            3e1 * max(float(ef.rel_errors[-1]), 1e-12)
        assert float(no_ef.rel_errors[-1]) < 1.0

    def test_ef_composes_with_bounded_staleness(self, weak, x0w):
        res = _run(weak, x0w, Int8Sync(), engine_cls=AsyncPearlEngine,
                   delays=UniformDelay(seed=0), max_staleness=1)
        assert float(res.rel_errors[-1]) < 1e-6

    def test_wire_state_threads_through_scan(self, weak, x0w):
        # 1 round vs 2x the rounds: if the residual were dropped each round
        # the two trajectories would coincide after rescaling; cheap proxy —
        # EF strictly improves over no-EF already after a few rounds
        ef = _run(weak, x0w, Int4Sync(), rounds=20)
        no_ef = _run(weak, x0w, Int4Sync(error_feedback=False), rounds=20)
        assert float(ef.rel_errors[-1]) < float(no_ef.rel_errors[-1])


# =========================================================================
# Accounting + registry + rejections
# =========================================================================
class TestAccountingAndRejections:
    def test_star_round_bytes_exact(self, weak, x0w):
        n, d = 6, 10
        for sync, lane in ((Int8Sync(), 1.0), (Int4Sync(), 0.5)):
            res = _run(weak, x0w, sync, rounds=3)
            up = n * d * 4                       # f32 uplink blocks
            down = n * (n * d * lane + n * 4)    # lanes + f32 scale per block
            assert list(res.bytes_up) == [up] * 3
            assert list(res.bytes_down) == [int(down)] * 3

    def test_registry_entries(self):
        assert isinstance(SYNC_STRATEGIES["int8"](), Int8Sync)
        assert isinstance(SYNC_STRATEGIES["int4"](), Int4Sync)

    def test_odd_block_size_rejected_for_int4(self):
        game = make_quadratic_game(n=4, d=9, M=20, L_B=1.0, batch_size=1,
                                   seed=0)
        x0 = jnp.zeros((4, 9), jnp.float32)
        with pytest.raises(ValueError, match="even last axis"):
            _run(game, x0, Int4Sync(), rounds=2)

    @pytest.mark.parametrize("engine_cls", [PearlEngine, AsyncPearlEngine])
    def test_ef_rejected_on_gossip(self, weak, x0w, engine_cls):
        with pytest.raises(ValueError, match="error"):
            _run(weak, x0w, Int8Sync(), rounds=2, engine_cls=engine_cls,
                 topology=Ring())

    def test_stateless_lowbit_allowed_on_gossip(self, weak, x0w):
        res = _run(weak, x0w, Int8Sync(error_feedback=False), rounds=50,
                   topology=Ring())
        assert float(res.rel_errors[-1]) < float(res.rel_errors[0])

    def test_trainer_tree_mean_redirects_lowbit(self):
        # stateless per-call tree_mean cannot carry the EF residual; the
        # error points at tree_mean_lowbit, which threads it (PR 8)
        t = {"w": jnp.zeros((4, 8), jnp.float32)}
        with pytest.raises(ValueError, match="tree_mean_lowbit"):
            tree_mean(t, sync=Int8Sync())

    def test_frozen_hashable(self):
        # jit static args require hashability; dataclass must stay frozen
        assert hash(Int4Sync()) == hash(Int4Sync())
        with pytest.raises(dataclasses.FrozenInstanceError):
            Int4Sync().error_feedback = False
