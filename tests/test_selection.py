"""The selection-policy correctness harness (ROADMAP item 4).

What is pinned here, per the acceptance criteria:

- mask properties: value-driven policies select EXACTLY ``participants(n)``
  players every round; the cold start deterministically sweeps the whole
  population; the same ``(seed, round)`` drive realizes the same mask
  sequence twice; PowerOfChoice candidate sets are reproducible from
  ``(seed, round)`` alone (no replay); the closed-form Shapley progress is
  permutation-equivariant with the efficiency identity;
- :class:`UniformSelection` is bit-for-bit :class:`PartialParticipation`
  in BOTH engines — same masks, trajectories, and byte bill;
- value-driven selection separates on warm-start heterogeneity: greedy
  reaches the 1e-3 neighborhood in strictly fewer wire bytes than the
  uniform control at the same fraction;
- byte-accounting invariance: every policy bills exactly the drawn masks
  (the engine ledger equals the strategy's own ``round_bytes`` of the
  known budget), and the trainer — the one mask x mesh path — bills
  identically across host and mesh lowerings;
- the rejection matrix: selection x joint baselines, x dense mean-field,
  x gossip (both engines and the trainer), x the dense engines' mesh, and
  the legacy ``pre_round``/``mask`` surface all fail loudly.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import collective, stepsize
from repro.core.async_engine import (
    AsyncPearlEngine,
    UniformDelay,
    ZeroDelay,
)
from repro.core.engine import (
    JointExtragradientUpdate,
    MeanFieldView,
    PartialParticipation,
    PearlEngine,
)
from repro.core.games import make_mean_field_game, make_quadratic_game
from repro.core.metrics import rounds_to_reach
from repro.core.selection import (
    SELECTION_POLICIES,
    GreedyShapley,
    PowerOfChoice,
    SampledGreedy,
    UCBSelection,
    UniformSelection,
    is_selection_policy,
    resolve_selection,
    shapley_progress,
    validate_selection,
)
from repro.core.topology import Ring

from helpers import assert_runs_bitwise_equal, gaussian_x0, weak_quad

multi_device = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs a multi-device (fake) mesh: run under "
           "XLA_FLAGS=--xla_force_host_platform_device_count=8",
)

N = 6

VALUE_POLICIES = {
    "greedy": lambda **kw: GreedyShapley(**kw),
    "ucb": lambda **kw: UCBSelection(**kw),
    "poc": lambda **kw: PowerOfChoice(**kw),
}


def drive(policy, n, rounds, *, d=4, delta_scale=None, seed=0):
    """Synthetic observe loop: per-round deltas keyed by fold_in(seed, r),
    so a drive is a pure function of ``(policy, n, rounds, seed)``."""
    state = policy.select_state(n)
    masks = []
    scale = (jnp.ones((n, 1)) if delta_scale is None
             else jnp.asarray(delta_scale, jnp.float32)[:, None])
    for r in range(rounds):
        state, m = policy.select(state, n, r, None)
        key = jax.random.fold_in(jax.random.PRNGKey(seed), r)
        delta = scale * jax.random.normal(key, (n, d))
        state = policy.observe(state, m, delta, r)
        masks.append(np.asarray(m))
    return np.stack(masks), jax.tree.map(np.asarray, state)


# =========================================================================
# Mask properties
# =========================================================================
class TestMaskProperties:
    @pytest.mark.parametrize("pname", list(VALUE_POLICIES),
                             ids=list(VALUE_POLICIES))
    @pytest.mark.parametrize("n,fraction", [(6, 0.5), (10, 0.3), (5, 0.2)])
    def test_exact_budget_every_round(self, pname, n, fraction):
        policy = VALUE_POLICIES[pname](fraction=fraction)
        masks, _ = drive(policy, n, 30)
        k = policy.participants(n)
        assert k == max(1, round(fraction * n))
        np.testing.assert_array_equal(masks.sum(axis=1), np.full(30, k))

    @pytest.mark.parametrize("pname", ["greedy", "ucb"])
    def test_cold_start_sweeps_population(self, pname):
        """Unseen players rank +inf, ties break to the lowest index: the
        first ceil(n/k) rounds deterministically partition-sweep the
        population, so every player is observed before greed kicks in."""
        policy = VALUE_POLICIES[pname](fraction=0.3)
        n, k = 10, 3
        masks, state = drive(policy, n, 4)  # ceil(10/3) = 4 rounds
        assert masks[0].tolist() == [True] * 3 + [False] * 7
        assert masks[1].tolist() == [False] * 3 + [True] * 3 + [False] * 4
        assert (state["counts"] >= 1).all()

    @pytest.mark.parametrize("pname", list(VALUE_POLICIES),
                             ids=list(VALUE_POLICIES))
    def test_mask_sequence_deterministic(self, pname):
        policy = VALUE_POLICIES[pname](fraction=0.5)
        a, sa = drive(policy, N, 25, seed=3)
        b, sb = drive(policy, N, 25, seed=3)
        np.testing.assert_array_equal(a, b)
        np.testing.assert_array_equal(sa["values"], sb["values"])

    def test_poc_candidates_reproducible_without_replay(self):
        """Round r's candidate set is a pure function of (seed, round) —
        the per-(seed, round) fold_in discipline, no replay of 0..r-1."""
        policy = PowerOfChoice(fraction=0.5, seed=11)
        direct = np.asarray(policy.candidate_mask(N, 37))
        again = np.asarray(policy.candidate_mask(N, 37))
        np.testing.assert_array_equal(direct, again)
        assert direct.sum() == policy.candidate_count(N)
        other = np.asarray(policy.candidate_mask(N, 38))
        assert not np.array_equal(direct, other) or N <= direct.sum()

    def test_poc_candidate_count_clamped(self):
        assert PowerOfChoice(fraction=0.5).candidate_count(6) == 6
        assert PowerOfChoice(fraction=0.2).candidate_count(10) == 4
        assert PowerOfChoice(fraction=0.2, candidates=1).candidate_count(
            10) == 2  # clamped up to k
        assert PowerOfChoice(fraction=0.5, candidates=99).candidate_count(
            6) == 6  # clamped down to n

    def test_poc_selects_within_candidates(self):
        policy = PowerOfChoice(fraction=0.3, candidates=4, seed=5)
        state = policy.select_state(10)
        for r in range(12):
            state, m = policy.select(state, 10, r, None)
            cand = policy.candidate_mask(10, r)
            assert not np.any(np.asarray(m) & ~np.asarray(cand))
            key = jax.random.fold_in(jax.random.PRNGKey(0), r)
            state = policy.observe(state, m, jax.random.normal(key, (10, 4)),
                                   r)

    def test_shapley_permutation_equivariance(self):
        rng = np.random.default_rng(0)
        delta = jnp.asarray(rng.standard_normal((8, 5)), jnp.float32)
        mask = jnp.asarray([1, 0, 1, 1, 0, 1, 1, 0], bool)
        perm = jnp.asarray(rng.permutation(8))
        phi = np.asarray(shapley_progress(delta, mask))
        phi_p = np.asarray(shapley_progress(delta[perm], mask[perm]))
        np.testing.assert_allclose(phi_p, phi[np.asarray(perm)],
                                   rtol=1e-5, atol=1e-6)

    def test_shapley_efficiency(self):
        """Sum of the closed-form Shapley values IS the coalition progress
        v(participants) = ||sum of masked deltas||^2."""
        rng = np.random.default_rng(1)
        delta = jnp.asarray(rng.standard_normal((6, 7)), jnp.float32)
        mask = jnp.asarray([1, 1, 0, 1, 0, 1], bool)
        phi = shapley_progress(delta, mask)
        v_all = jnp.sum(jnp.sum(jnp.where(mask[:, None], delta, 0.0),
                                axis=0) ** 2)
        assert float(jnp.sum(phi)) == pytest.approx(float(v_all), rel=1e-5)
        assert float(jnp.abs(phi * ~mask).max()) == 0.0

    def test_aging_bounds_starvation(self):
        """A persistently low-value player is still re-selected: the aging
        bonus caps starvation (the frozen-block failure mode — a player the
        greedy rule never picks keeps the game away from equilibrium)."""
        scale = np.ones(N)
        scale[-1] = 1e-3  # player 5 always ships tiny deltas
        policy = GreedyShapley(fraction=0.5, aging=0.05)
        masks, state = drive(policy, N, 120, delta_scale=scale)
        # beyond the cold-start sweep: selected again, repeatedly
        assert int(state["counts"][-1]) >= 3
        gaps = np.diff(np.nonzero(masks[:, -1])[0])
        assert gaps.size and gaps.max() <= int(2 / 0.05) + 1

    def test_property_budget_and_efficiency(self):
        pytest.importorskip("hypothesis",
                            reason="property tests need hypothesis")
        from hypothesis import given, settings, strategies as st

        @settings(max_examples=50, deadline=None)
        @given(n=st.integers(min_value=2, max_value=16),
               fraction=st.floats(min_value=0.05, max_value=1.0),
               seed=st.integers(min_value=0, max_value=2**16))
        def prop(n, fraction, seed):
            policy = GreedyShapley(fraction=fraction)
            k = policy.participants(n)
            assert 1 <= k <= n
            masks, _ = drive(policy, n, 6, seed=seed)
            assert (masks.sum(axis=1) == k).all()

        prop()

    def test_property_shapley_invariance(self):
        pytest.importorskip("hypothesis",
                            reason="property tests need hypothesis")
        from hypothesis import given, settings, strategies as st

        @settings(max_examples=50, deadline=None)
        @given(seed=st.integers(min_value=0, max_value=2**16),
               n=st.integers(min_value=2, max_value=12))
        def prop(seed, n):
            rng = np.random.default_rng(seed)
            delta = jnp.asarray(rng.standard_normal((n, 3)), jnp.float32)
            mask = jnp.asarray(rng.integers(0, 2, n), bool)
            perm = rng.permutation(n)
            phi = np.asarray(shapley_progress(delta, mask))
            phi_p = np.asarray(
                shapley_progress(delta[jnp.asarray(perm)],
                                 mask[jnp.asarray(perm)]))
            np.testing.assert_allclose(phi_p, phi[perm],
                                       rtol=1e-4, atol=1e-5)

        prop()


# =========================================================================
# UniformSelection == PartialParticipation, bit for bit, in BOTH engines
# =========================================================================
class TestUniformPins:
    @pytest.fixture(scope="class")
    def setup(self):
        game = weak_quad(n=N, d=10)
        gamma = 0.4 * stepsize.gamma_constant(game.constants(), 4)
        return game, gamma, gaussian_x0(game, seed=0)

    def _run(self, engine, setup, rounds=40):
        game, gamma, x0 = setup
        return engine.run(game, x0, tau=4, rounds=rounds, gamma=gamma,
                          key=jax.random.PRNGKey(0), stochastic=False)

    def test_lockstep_bit_for_bit(self, setup):
        legacy = self._run(
            PearlEngine(sync=PartialParticipation(fraction=0.5, seed=7)),
            setup)
        sel = self._run(
            PearlEngine(sync=UniformSelection(fraction=0.5, seed=7)), setup)
        assert_runs_bitwise_equal(legacy, sel)

    def test_async_bit_for_bit_under_staleness(self, setup):
        kw = dict(delays=UniformDelay(seed=0), max_staleness=2)
        legacy = self._run(
            AsyncPearlEngine(sync=PartialParticipation(fraction=0.5, seed=7),
                             **kw), setup)
        sel = self._run(
            AsyncPearlEngine(sync=UniformSelection(fraction=0.5, seed=7),
                             **kw), setup)
        assert_runs_bitwise_equal(legacy, sel)

    def test_async_d0_collapses_to_lockstep(self, setup):
        lock = self._run(
            PearlEngine(sync=UniformSelection(fraction=0.5, seed=7)), setup)
        d0 = self._run(
            AsyncPearlEngine(sync=UniformSelection(fraction=0.5, seed=7),
                             delays=ZeroDelay(), max_staleness=0), setup)
        assert_runs_bitwise_equal(lock, d0)


# =========================================================================
# Value-driven selection: the separation + composition smokes
# =========================================================================
class TestValueDriven:
    @pytest.fixture(scope="class")
    def warm(self):
        """Warm-start heterogeneity (the BENCH_selection.json config, shrunk):
        8 of 10 players start AT the equilibrium, 2 start far — uniform
        participation wastes 80% of its slots moving players who are done."""
        game = make_quadratic_game(n=10, d=10, M=40, L_B=1.0, batch_size=1,
                                   seed=1)
        off = np.zeros((10, 10))
        off[:2] = 10.0 * np.random.default_rng(3).standard_normal((2, 10))
        x0 = jnp.asarray(np.asarray(game.equilibrium()) + off, jnp.float32)
        gamma = stepsize.gamma_constant(game.constants(), 4)
        return game, gamma, x0

    def _bytes_to_eq(self, r, threshold=1e-3):
        hit = rounds_to_reach(r.rel_errors, threshold)
        assert hit is not None
        per_round = r.bytes_up + r.bytes_down
        return int(per_round[:hit].sum())

    def test_greedy_beats_uniform_bytes_to_eq(self, warm):
        game, gamma, x0 = warm
        kw = dict(tau=4, rounds=600, gamma=gamma,
                  key=jax.random.PRNGKey(0), stochastic=False)
        greedy = PearlEngine(sync=GreedyShapley(fraction=0.2)).run(
            game, x0, **kw)
        uniform = PearlEngine(sync=UniformSelection(fraction=0.2)).run(
            game, x0, **kw)
        assert self._bytes_to_eq(greedy) < self._bytes_to_eq(uniform)

    def test_selection_composes_with_sampled_mean_field(self):
        game = make_mean_field_game(n=50, d=6, heterogeneity=1.0, seed=0)
        gamma = stepsize.gamma_constant(game.constants(), 4)
        r = PearlEngine(sync=GreedyShapley(fraction=0.2),
                        view=MeanFieldView(sample=8, seed=0)).run(
            game, jnp.zeros((game.n, game.d)), tau=4, rounds=200,
            gamma=gamma, key=jax.random.PRNGKey(0), stochastic=False)
        assert np.isfinite(r.rel_errors[-1])
        assert float(r.rel_errors[-1]) < float(r.rel_errors[1])

    def test_staleness_penalty_runs_in_async(self):
        game = weak_quad(n=N, d=10)
        gamma = 0.4 * stepsize.gamma_constant(game.constants(), 4)
        x0 = gaussian_x0(game, seed=0)
        r = AsyncPearlEngine(
            sync=GreedyShapley(fraction=0.5, staleness_penalty=0.1),
            delays=UniformDelay(seed=0), max_staleness=2).run(
            game, x0, tau=4, rounds=60, gamma=gamma,
            key=jax.random.PRNGKey(0), stochastic=False)
        assert np.isfinite(r.rel_errors[-1])


# =========================================================================
# Byte accounting: the bill IS the drawn masks
# =========================================================================
class TestByteAccounting:
    @pytest.mark.parametrize("pname", list(VALUE_POLICIES),
                             ids=list(VALUE_POLICIES))
    def test_engine_bills_exactly_the_budget(self, pname):
        """Value policies draw exactly k participants; the engine ledger
        must equal the strategy's own round_bytes of that known budget —
        nothing billed full, nothing billed free."""
        game = weak_quad(n=N, d=10)
        gamma = 0.4 * stepsize.gamma_constant(game.constants(), 4)
        policy = VALUE_POLICIES[pname](fraction=0.5)
        r = PearlEngine(sync=policy).run(
            game, gaussian_x0(game, seed=0), tau=4, rounds=20, gamma=gamma,
            key=jax.random.PRNGKey(0), stochastic=False)
        k = policy.participants(N)
        up, down = policy.round_bytes(np.full(20, k), N, game.d, 4)
        np.testing.assert_array_equal(r.bytes_up, up)
        np.testing.assert_array_equal(r.bytes_down, down)


@multi_device
class TestTrainerMeshInvariance:
    """Satellite 3, the mask x mesh half: the trainer's general merge is
    the ONE masked mesh lowering (collective.masked_payload) — the bill,
    computed host-side off the drawn masks, must be identical across
    lowerings for every selection policy."""

    @pytest.fixture(scope="class")
    def mesh(self):
        if jax.device_count() < 2:
            pytest.skip("single device")
        return collective.player_mesh(N)

    @pytest.fixture(scope="class")
    def cfg(self):
        from repro.configs import get_config

        return get_config("smollm-360m").smoke_variant()

    def _stream(self, cfg):
        from repro.data.synthetic import DataConfig, SyntheticTokenStream

        return SyntheticTokenStream(DataConfig(
            vocab_size=cfg.vocab_size, seq_len=32, batch_size=2,
            n_players=N, seed=0,
        ))

    def _build(self, cfg, sync, **kw):
        from repro.optim.optimizers import sgd
        from repro.train.pearl_trainer import PearlTrainer

        return PearlTrainer(cfg, sgd(5e-2), n_players=N, tau=2,
                            prox_lambda=1e-3, seed=2, sync=sync, **kw)

    @pytest.mark.parametrize("pname", ["greedy", "ucb", "uniform"])
    def test_bill_identical_across_lowerings(self, cfg, mesh, pname):
        sync = (UniformSelection(fraction=0.5, seed=7) if pname == "uniform"
                else VALUE_POLICIES[pname](fraction=0.5))
        host = self._build(cfg, sync)
        h = host.run(self._stream(cfg), rounds=3)
        mesht = self._build(cfg, sync, mesh=mesh)
        m = mesht.run(self._stream(cfg), rounds=3)
        assert host._round_participants == mesht._round_participants
        hr, mr = host.comm_report(), mesht.comm_report()
        np.testing.assert_array_equal(np.stack(hr.per_round_bytes()),
                                      np.stack(mr.per_round_bytes()))
        for a, b in zip(h, m):
            assert a["lm_loss"] == pytest.approx(b["lm_loss"], rel=1e-4)

    def test_uniform_bill_matches_partial_participation(self, cfg):
        """The trainer-level half of the uniform pin: same masks, same
        participants, same bytes as the legacy strategy."""
        sel = self._build(cfg, UniformSelection(fraction=0.5, seed=7))
        sel.run(self._stream(cfg), rounds=3)
        legacy = self._build(cfg, PartialParticipation(fraction=0.5, seed=7))
        legacy.run(self._stream(cfg), rounds=3)
        assert sel._round_participants == legacy._round_participants
        np.testing.assert_array_equal(
            np.stack(sel.comm_report().per_round_bytes()),
            np.stack(legacy.comm_report().per_round_bytes()))


# =========================================================================
# Rejection matrix + registry
# =========================================================================
class TestRejectionMatrix:
    def test_selection_rejects_joint_update(self):
        with pytest.raises(ValueError, match="ExactSync"):
            PearlEngine(update=JointExtragradientUpdate(),
                        sync=GreedyShapley())._check_topology()

    def test_selection_rejects_dense_mean_field(self):
        with pytest.raises(ValueError, match="sample"):
            PearlEngine(sync=GreedyShapley(),
                        view=MeanFieldView())._check_topology()

    def test_selection_rejects_gossip_lockstep(self):
        with pytest.raises(ValueError, match="scorer"):
            PearlEngine(topology=Ring(),
                        sync=GreedyShapley())._check_topology()

    def test_selection_rejects_gossip_async(self):
        with pytest.raises(ValueError, match="scorer"):
            AsyncPearlEngine(topology=Ring(), sync=GreedyShapley())._check()

    def test_selection_rejects_engine_mesh(self):
        # a 1-device mesh is enough: the rejection is structural
        mesh = collective.player_mesh(1)
        with pytest.raises(ValueError, match="mask"):
            PearlEngine(sync=GreedyShapley(), mesh=mesh)._check_topology()
        with pytest.raises(ValueError, match="mask"):
            AsyncPearlEngine(sync=GreedyShapley(), mesh=mesh)._check()

    def test_async_selection_rejects_mean_field(self):
        with pytest.raises(ValueError, match="lockstep"):
            AsyncPearlEngine(sync=GreedyShapley(),
                             view=MeanFieldView(sample=8))._check()
        with pytest.raises(ValueError, match="sample"):
            AsyncPearlEngine(sync=GreedyShapley(),
                             view=MeanFieldView())._check()

    def test_legacy_surface_raises(self):
        policy = GreedyShapley()
        with pytest.raises(RuntimeError, match="select"):
            policy.init_state()
        with pytest.raises(RuntimeError, match="select"):
            policy.pre_round(None)
        with pytest.raises(RuntimeError, match="select"):
            policy.mask(N, ())

    def test_validate_selection_is_noop_for_legacy_strategies(self):
        validate_selection(PartialParticipation(fraction=0.5),
                           server=False, mesh=object())

    def test_resolve_selection(self):
        assert resolve_selection(None) is None
        p = GreedyShapley(fraction=0.3)
        assert resolve_selection(p) is p
        for name, cls in SELECTION_POLICIES.items():
            got = resolve_selection(name)
            assert isinstance(got, cls) and is_selection_policy(got)
        with pytest.raises(ValueError, match="unknown selection policy"):
            resolve_selection("shapely")
        with pytest.raises(TypeError, match="SelectionPolicy"):
            resolve_selection(3.0)

    def test_parameter_validation(self):
        for bad in (0.0, -0.1, 1.5):
            with pytest.raises(ValueError, match="fraction"):
                GreedyShapley(fraction=bad)
        with pytest.raises(ValueError, match="memory"):
            GreedyShapley(memory=1.0)
        with pytest.raises(ValueError, match="aging"):
            UCBSelection(aging=-0.1)
        with pytest.raises(ValueError, match="c must"):
            UCBSelection(c=-1.0)
        with pytest.raises(ValueError, match="candidates"):
            PowerOfChoice(candidates=0)
        with pytest.raises(ValueError, match="staleness_penalty"):
            GreedyShapley(staleness_penalty=-0.5)


# =========================================================================
# SampledGreedy: O(k) carried state (the mean-field-scale variant)
# =========================================================================
class TestSampledGreedy:
    def test_state_is_o_k_not_o_n(self):
        """The carried state is t = min(tracked, n) slots plus a cursor —
        independent of the population size."""
        s = SampledGreedy(tracked=16).select_state(100_000)
        assert s["ids"].shape == (16,) and s["values"].shape == (16,)
        assert s["cursor"].shape == ()
        assert SampledGreedy(tracked=64).select_state(8)["ids"].shape == (8,)

    def test_participation_between_explore_and_budget(self):
        """Explore and exploit slots may overlap: at least e, at most k
        players per round — the bill is what the mask says, never more."""
        n = 8
        policy = SampledGreedy(fraction=0.5, tracked=4)
        masks, _ = drive(policy, n, 16)
        per_round = masks.sum(axis=1)
        assert (per_round <= policy.participants(n)).all()
        assert (per_round >= policy.explore_count(n)).all()

    def test_cold_start_round_robin_covers_population(self):
        """With an empty slot table the mask is exactly the cursor window,
        so ceil(n/e) rounds sweep every player — the discovery channel
        doubles as the anti-starvation guarantee."""
        n = 8
        policy = SampledGreedy(fraction=0.25, tracked=4)
        e = policy.explore_count(n)
        state = policy.select_state(n)
        seen = np.zeros(n, bool)
        for r in range(-(-n // e)):
            state, m = policy.select(state, n, r, None)
            assert int(np.asarray(m).sum()) == e  # empty table: no exploit
            seen |= np.asarray(m)
        assert seen.all()

    def test_one_insertion_per_round_and_eviction_rule(self):
        """observe performs exactly ONE insertion: the best untracked
        participant enters iff it beats the worst slot's value."""
        policy = SampledGreedy(fraction=0.5, tracked=2, memory=0.5)
        n = 4
        state = policy.select_state(n)
        mask = jnp.asarray([True, True, False, False])
        delta = jnp.asarray([[1.0, 0.0], [2.0, 0.0],
                             [9.0, 9.0], [9.0, 9.0]])
        # phi = [3, 6, 0, 0]: players 0 and 1 both joined, but only the
        # best (player 1) is inserted this round
        state = policy.observe(state, mask, delta, 0)
        ids = state["ids"].tolist()
        assert ids.count(1) == 1 and 0 not in ids
        # next round the remaining empty slot takes player 0
        state = policy.observe(
            state, jnp.asarray([True, False, False, False]),
            jnp.asarray([[1.0, 0.0]] * n), 1)
        assert sorted(state["ids"].tolist()) == [0, 1]
        vals = dict(zip(state["ids"].tolist(), state["values"].tolist()))
        # a weaker candidate cannot evict a stronger slot
        weak = policy.observe(
            state, jnp.asarray([False, False, True, False]),
            jnp.asarray([[0.1, 0.0]] * n), 2)
        assert sorted(weak["ids"].tolist()) == [0, 1]
        # a stronger one evicts exactly the WORST slot (player 0 here)
        strong = policy.observe(
            state, jnp.asarray([False, False, True, False]),
            jnp.asarray([[50.0, 0.0]] * n), 2)
        assert sorted(strong["ids"].tolist()) == [1, 2]
        got = dict(zip(strong["ids"].tolist(), strong["values"].tolist()))
        assert got[1] == vals[1]  # surviving slot untouched

    def test_tracked_hit_updates_ewm(self):
        policy = SampledGreedy(fraction=0.5, tracked=2, memory=0.5)
        n = 4
        state = dict(policy.select_state(n),
                     ids=jnp.asarray([1, -1], jnp.int32),
                     values=jnp.asarray([6.0, 0.0], jnp.float32))
        mask = jnp.asarray([False, True, False, False])
        delta = jnp.zeros((n, 2)).at[1].set(jnp.asarray([2.0, 0.0]))
        # phi_1 = 4: EWM -> 0.5 * 6 + 0.5 * 4 = 5
        state = policy.observe(state, mask, delta, 0)
        idx = state["ids"].tolist().index(1)
        assert state["values"][idx] == pytest.approx(5.0)

    def test_deterministic_replay(self):
        policy = SampledGreedy(fraction=0.5, tracked=4)
        m1, s1 = drive(policy, 8, 12, seed=3)
        m2, s2 = drive(policy, 8, 12, seed=3)
        np.testing.assert_array_equal(m1, m2)
        for k in s1:
            np.testing.assert_array_equal(s1[k], s2[k])

    def test_discovers_high_value_players(self):
        """The round-robin probe finds the heavy hitters: after a few
        sweeps the slot table holds exactly the high-progress players."""
        n = 12
        policy = SampledGreedy(fraction=0.5, tracked=3, memory=0.5)
        scale = [0.1] * 9 + [10.0] * 3
        _, state = drive(policy, n, 3 * n, delta_scale=scale)
        assert set(state["ids"].tolist()) == {9, 10, 11}

    def test_runs_in_engine_and_bills_at_most_budget(self):
        game = weak_quad(n=N, d=10)
        gamma = 0.4 * stepsize.gamma_constant(game.constants(), 4)
        policy = SampledGreedy(fraction=0.5, tracked=4)
        r = PearlEngine(sync=policy).run(
            game, gaussian_x0(game, seed=0), tau=4, rounds=20, gamma=gamma,
            key=jax.random.PRNGKey(0), stochastic=False)
        assert np.isfinite(r.rel_errors).all()
        k = policy.participants(N)
        up, _ = policy.round_bytes(np.full(20, k), N, game.d, 4)
        assert (np.asarray(r.bytes_up) <= up).all()

    def test_knob_validation(self):
        with pytest.raises(ValueError, match="tracked"):
            SampledGreedy(tracked=0)
        with pytest.raises(ValueError, match="explore"):
            SampledGreedy(explore=0.0)
        with pytest.raises(ValueError, match="explore"):
            SampledGreedy(explore=1.5)
        with pytest.raises(ValueError, match="memory"):
            SampledGreedy(memory=1.0)
