"""Coverage extension: comm metrics edge cases, stochastic EG, sampling serve."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import stepsize
from repro.core.baselines import extragradient
from repro.core.games import make_quadratic_game
from repro.core.metrics import (
    CommunicationModel,
    communication_savings,
    final_plateau,
    rounds_to_reach,
)
from repro.models import init_params
from repro.serve.decode import generate


class TestMetrics:
    def test_rounds_to_reach(self):
        errs = np.array([1.0, 0.5, 0.2, 0.05, 0.01])
        assert rounds_to_reach(errs, 0.2) == 2
        assert rounds_to_reach(errs, 1e-9) is None

    def test_communication_savings(self):
        errs = {
            1: np.array([1.0, 0.5, 0.25, 0.12, 0.06]),
            4: np.array([1.0, 0.2, 0.05, 0.02, 0.01]),
        }
        s = communication_savings(errs, threshold=0.06)
        assert s[1] == pytest.approx(1.0)
        assert s[4] == pytest.approx(2.0)  # tau=4 reaches at round 2 vs 4

    def test_savings_raises_if_tau1_never_reaches(self):
        errs = {1: np.array([1.0, 0.9]), 4: np.array([1.0, 0.01])}
        with pytest.raises(ValueError):
            communication_savings(errs, threshold=0.05)

    def test_final_plateau_window_clamps(self):
        assert final_plateau(np.array([3.0]), window=50) == 3.0

    def test_comm_model_heterogeneous_dims(self):
        cm = CommunicationModel((10, 20, 30), bytes_per_scalar=2)
        assert cm.D == 60 and cm.n == 3
        assert cm.bytes_per_round() == (60 + 3 * 60) * 2
        # ceil division on partial rounds
        assert cm.bytes_for_iterations(10, tau=4) == 3 * cm.bytes_per_round()


class TestStochasticExtragradient:
    def test_converges_to_neighborhood(self):
        g = make_quadratic_game(n=3, d=5, M=20, batch_size=2, seed=4)
        c = g.constants()
        x0 = jnp.asarray(np.random.default_rng(0).standard_normal((3, 5)))
        r = extragradient(g, x0, steps=3000, gamma=0.2 / c.L_F,
                          key=jax.random.PRNGKey(0), stochastic=True)
        assert final_plateau(r.rel_errors, 200) < 0.05


class TestSampledServe:
    def test_temperature_sampling_changes_tokens(self):
        cfg = get_config("stablelm-1.6b").smoke_variant()
        params = init_params(cfg, jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                                  cfg.vocab_size)
        greedy = generate(params, cfg, {"tokens": toks}, max_new_tokens=6,
                          capacity=32, temperature=0.0)
        sampled = generate(params, cfg, {"tokens": toks}, max_new_tokens=6,
                           capacity=32, temperature=5.0,
                           key=jax.random.PRNGKey(7))
        assert greedy.shape == sampled.shape == (2, 6)
        # at high temperature, sampling should diverge from greedy somewhere
        assert np.any(np.asarray(greedy) != np.asarray(sampled))


class TestRobotGradientExactness:
    """Regression test for the stale-snapshot j=i displacement bug: the
    player's own block must never be pulled toward the frozen snapshot."""

    def test_own_term_uses_live_variable(self):
        from repro.core.games import make_robot_game

        g = make_robot_game(sigma=0.0)
        x_ref = jnp.asarray(np.random.default_rng(0).standard_normal((5, 1)))
        x_i = x_ref[0] + 5.0   # player 0 drifted far from the snapshot
        grad = g.player_grad(jnp.asarray(0), x_i, x_ref)
        # analytic: a_0 (x_i - anc_0) + b_0 sum_{j != 0} (x_i - x_ref_j - h_0j)
        manual = g.a_coef[0] * (x_i - g.anchors[0])
        for j in range(1, 5):
            manual = manual + g.b_coef[0] * (x_i - x_ref[j] - g.h[0, j])
        np.testing.assert_allclose(np.asarray(grad), np.asarray(manual),
                                   atol=1e-6)
