"""Device-resident async mesh tests: ring buffer, overlap, multi-sweep.

The multi-device cases run under the CI ``multi-device`` job's fake mesh
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``) and skip on a
single device. What is pinned, per the acceptance criteria:

- the async engine's device-resident snapshot ring buffer is FREE at
  ``D = 0``: bit-for-bit equal to the lockstep mesh engine for every sync
  strategy (exact, bf16, int8+EF, int4+EF) — no extra arithmetic, no
  reordered reductions;
- ``overlap=True`` (double-buffered wire) computes exactly the host
  async engine's declared ``ConstantDelay(1)`` program, up to the known
  mesh-vs-host fusion drift;
- async gossip with ``gossip_steps > 1`` at ``D = 0`` reproduces the
  lockstep multi-sweep engine bitwise, bytes included;
- the overlap rejection matrix: no mesh, gossip topology, or an
  undeclared delay model all fail loudly.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import collective, stepsize
from repro.core.async_engine import (
    AsyncPearlEngine,
    ConstantDelay,
    UniformDelay,
    ZeroDelay,
)
from repro.core.engine import (
    ExactSync,
    Int4Sync,
    Int8Sync,
    PearlEngine,
    QuantizedSync,
)
from repro.core.topology import Ring

from helpers import assert_runs_bitwise_equal, gaussian_x0, weak_quad

multi_device = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs a multi-device (fake) mesh: run under "
           "XLA_FLAGS=--xla_force_host_platform_device_count=8",
)

N = 6

SYNCS = {
    "exact": ExactSync(),
    "bf16": QuantizedSync(jnp.bfloat16),
    "int8": Int8Sync(),
    "int4": Int4Sync(),
}


@pytest.fixture(scope="module")
def mesh():
    if jax.device_count() < 2:
        pytest.skip("single device")
    return collective.player_mesh(N)


@pytest.fixture(scope="module")
def setup():
    game = weak_quad(n=N, d=10)
    # 0.4x the lockstep-safe step: staleness shrinks the stable region,
    # and one shared gamma keeps every engine in it
    gamma = 0.4 * stepsize.gamma_constant(game.constants(), 4)
    x0 = gaussian_x0(game, seed=0)
    return game, gamma, x0


def _run(engine, setup, rounds=40):
    game, gamma, x0 = setup
    return engine.run(game, x0, tau=4, rounds=rounds, gamma=gamma,
                      key=jax.random.PRNGKey(0), stochastic=False)


# =========================================================================
# D = 0: the ring buffer must be free
# =========================================================================
@multi_device
class TestD0Parity:
    @pytest.mark.parametrize("sname", list(SYNCS), ids=list(SYNCS))
    def test_d0_bitwise_equals_lockstep_mesh(self, setup, mesh, sname):
        sync = SYNCS[sname]
        lock = _run(PearlEngine(sync=sync, mesh=mesh), setup)
        d0 = _run(AsyncPearlEngine(sync=sync, mesh=mesh, delays=ZeroDelay(),
                                   max_staleness=0), setup)
        assert_runs_bitwise_equal(lock, d0, check_bytes=False)

    def test_d0_bytes_equal_lockstep(self, setup, mesh):
        lock = _run(PearlEngine(sync=Int8Sync(), mesh=mesh), setup,
                    rounds=10)
        d0 = _run(AsyncPearlEngine(sync=Int8Sync(), mesh=mesh,
                                   delays=ZeroDelay(), max_staleness=0),
                  setup, rounds=10)
        np.testing.assert_array_equal(lock.bytes_up, d0.bytes_up)
        np.testing.assert_array_equal(lock.bytes_down, d0.bytes_down)


# =========================================================================
# Staleness on the mesh: D > 0 rides the device-resident buffer
# =========================================================================
@multi_device
class TestStaleMesh:
    @pytest.mark.parametrize("sname,atol",
                             [("exact", 1e-6), ("int8", 5e-3)],
                             ids=["exact", "int8"])
    def test_mesh_tracks_host_async(self, setup, mesh, sname, atol):
        """Same delay table, host buffer vs device ring buffer: fusion
        drift only in f32; quantization-level flips bound the int8 gap."""
        sync = SYNCS[sname]
        kw = dict(sync=sync, delays=UniformDelay(seed=0), max_staleness=2)
        host = _run(AsyncPearlEngine(**kw), setup)
        shard = _run(AsyncPearlEngine(mesh=mesh, **kw), setup)
        assert shard.rel_errors[-1] == pytest.approx(
            host.rel_errors[-1], rel=0.5, abs=1e-9)
        if sname == "exact":
            np.testing.assert_allclose(np.asarray(shard.x_final),
                                       np.asarray(host.x_final),
                                       rtol=0, atol=atol)

    def test_staleness_recorded_identically(self, setup, mesh):
        kw = dict(delays=UniformDelay(seed=0), max_staleness=3)
        host = _run(AsyncPearlEngine(**kw), setup, rounds=12)
        shard = _run(AsyncPearlEngine(mesh=mesh, **kw), setup, rounds=12)
        np.testing.assert_array_equal(host.staleness, shard.staleness)


# =========================================================================
# Overlap: the double-buffered wire IS ConstantDelay(1)
# =========================================================================
@multi_device
class TestOverlap:
    def test_overlap_is_declared_constant_delay_one(self, setup, mesh):
        over = _run(AsyncPearlEngine(mesh=mesh, delays=ConstantDelay(1),
                                     max_staleness=1, overlap=True), setup)
        host = _run(AsyncPearlEngine(delays=ConstantDelay(1),
                                     max_staleness=1), setup)
        # identical semantics, mesh-vs-host fusion drift only
        np.testing.assert_allclose(np.asarray(over.x_final),
                                   np.asarray(host.x_final),
                                   rtol=0, atol=1e-6)

    @pytest.mark.parametrize("sname", ["int8", "int4"])
    def test_overlap_composes_with_lowbit_ef(self, setup, mesh, sname):
        over = _run(AsyncPearlEngine(sync=SYNCS[sname], mesh=mesh,
                                     delays=ConstantDelay(1),
                                     max_staleness=1, overlap=True),
                    setup, rounds=120)
        assert float(over.rel_errors[-1]) < 1e-4

    def test_overlap_requires_mesh(self):
        with pytest.raises(ValueError, match="mesh"):
            AsyncPearlEngine(delays=ConstantDelay(1), max_staleness=1,
                             overlap=True)._check()

    def test_overlap_rejects_gossip(self, mesh):
        with pytest.raises(ValueError, match="star"):
            AsyncPearlEngine(topology=Ring(), mesh=mesh,
                             delays=ConstantDelay(1), max_staleness=1,
                             overlap=True)._check()

    def test_overlap_rejects_undeclared_staleness(self, mesh):
        # overlap IS one round of staleness; claiming lockstep freshness
        # (or any other delay model) must fail loudly
        with pytest.raises(ValueError, match="ConstantDelay"):
            AsyncPearlEngine(mesh=mesh, overlap=True)._check()
        with pytest.raises(ValueError, match="ConstantDelay"):
            AsyncPearlEngine(mesh=mesh, delays=UniformDelay(seed=0),
                             max_staleness=1, overlap=True)._check()
        with pytest.raises(ValueError, match="ConstantDelay"):
            AsyncPearlEngine(mesh=mesh, delays=ConstantDelay(2),
                             max_staleness=2, overlap=True)._check()

    def test_async_mesh_rejects_gossip_and_masks(self, mesh):
        from repro.core.engine import PartialParticipation
        with pytest.raises(ValueError, match="host path"):
            AsyncPearlEngine(topology=Ring(), mesh=mesh)._check()
        with pytest.raises(ValueError, match="mask"):
            AsyncPearlEngine(sync=PartialParticipation(fraction=0.5),
                             mesh=mesh)._check()


# =========================================================================
# Async gossip multi-sweep (host path; mesh x gossip is rejected above)
# =========================================================================
class TestAsyncGossipMultiSweep:
    def test_d0_bitwise_equals_lockstep_multisweep(self, setup):
        game, gamma, x0 = setup
        lock = _run(PearlEngine(topology=Ring(), gossip_steps=2), setup)
        d0 = _run(AsyncPearlEngine(topology=Ring(), gossip_steps=2,
                                   delays=ZeroDelay(), max_staleness=0),
                  setup)
        assert_runs_bitwise_equal(lock, d0)

    def test_multisweep_tightens_consensus_under_staleness(self, setup):
        one = _run(AsyncPearlEngine(topology=Ring(), gossip_steps=1,
                                    delays=UniformDelay(seed=0),
                                    max_staleness=2), setup, rounds=120)
        two = _run(AsyncPearlEngine(topology=Ring(), gossip_steps=2,
                                    delays=UniformDelay(seed=0),
                                    max_staleness=2), setup, rounds=120)
        assert float(two.rel_errors[-1]) < float(one.rel_errors[-1])

    def test_gossip_steps_validated(self):
        with pytest.raises(ValueError, match="gossip_steps"):
            AsyncPearlEngine(topology=Ring(), gossip_steps=0)._check()
