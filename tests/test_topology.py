"""The topology layer: mixing matrices, gossip convergence, edge-aware bytes.

Load-bearing claims pinned here:
- every graph topology's Metropolis mixing matrix is symmetric doubly
  stochastic; Star stays the server special case;
- on the quadratic game a doubly-stochastic ring reaches the SAME equilibrium
  neighborhood as the star (tolerance-pinned), while a disconnected graph
  provably does not (views of the other component stay frozen at x0);
- byte accounting is edge-aware (gossip bills active links x payload, star
  bills blocks up / joint vector down) and both accounting systems resolve
  their uplink/downlink itemsizes through the ONE shared helper,
  :func:`repro.core.topology.direction_itemsizes` — the engine compresses the
  broadcast, the trainer compresses pre-reduction, and the pinned numbers
  here keep that asymmetry explicit.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import stepsize
from repro.core.engine import (
    DropoutSync,
    ExactSync,
    JointExtragradientUpdate,
    PartialParticipation,
    PearlEngine,
    QuantizedSync,
    SgdUpdate,
)
from repro.core.games import make_quadratic_game
from repro.core.topology import (
    ErdosRenyi,
    ResampledErdosRenyi,
    ExplicitGraph,
    Ring,
    Star,
    TimeVarying,
    TOPOLOGIES,
    Topology,
    Torus,
    direction_itemsizes,
    gossip_round_bytes,
    is_connected,
    is_doubly_stochastic,
    metropolis_weights,
    spectral_gap,
    star_round_bytes,
)


@pytest.fixture(scope="module")
def quad():
    # Weak coupling: gossip's stability margin shrinks with coupling strength
    # (stale inconsistent views act like delays under the antisymmetric
    # coupling), so the Theorem 3.4 step size needs L_B small on sparse graphs.
    return make_quadratic_game(n=4, d=8, M=40, L_B=2.0, batch_size=1, seed=0)


@pytest.fixture(scope="module")
def x0(quad):
    return jnp.asarray(
        np.random.default_rng(7).standard_normal((quad.n, quad.d)),
        dtype=jnp.float32,
    )


# ------------------------------------------------------------------ matrices
class TestMixingMatrices:
    @pytest.mark.parametrize("topo", [
        Ring(), Torus(), ErdosRenyi(p=0.6, seed=3),
        ExplicitGraph(edges=((0, 1), (1, 2), (2, 3), (0, 3))),
    ])
    @pytest.mark.parametrize("n", [4, 6, 9])
    def test_doubly_stochastic_and_symmetric(self, topo, n):
        W = topo.mixing_matrix(n)
        assert W.shape == (n, n)
        assert is_doubly_stochastic(W)
        np.testing.assert_allclose(W, W.T)

    def test_star_is_server_with_mean_mixing(self):
        s = Star()
        assert s.is_server
        np.testing.assert_allclose(s.mixing_matrix(5), np.full((5, 5), 0.2))
        assert not s.adjacency(5).any()

    def test_ring_degrees(self):
        assert (Ring().degrees(6) == 2).all()
        assert Ring().directed_edge_counts(6)[0] == 12

    def test_torus_factors_n(self):
        A = Torus().adjacency(9)           # 3x3 grid, wraparound
        assert (A.sum(axis=1) == 4).all()
        with pytest.raises(ValueError):
            Torus(rows=4).adjacency(9)

    def test_torus_prime_degenerates_to_ring(self):
        np.testing.assert_array_equal(Torus().adjacency(5), Ring().adjacency(5))

    def test_erdos_renyi_reproducible(self):
        a = ErdosRenyi(p=0.5, seed=11).adjacency(8)
        b = ErdosRenyi(p=0.5, seed=11).adjacency(8)
        np.testing.assert_array_equal(a, b)
        assert not np.array_equal(a, ErdosRenyi(p=0.5, seed=12).adjacency(8))

    def test_time_varying_stacks_members(self):
        tv = TimeVarying((Ring(), Torus()))
        stack = tv.mixing_stack(6)
        assert stack.shape == (2, 6, 6)
        np.testing.assert_allclose(stack[0], Ring().mixing_matrix(6))
        assert tv.connected(6)

    def test_connectivity_and_gap(self):
        assert is_connected(Ring().adjacency(7))
        assert not is_connected(np.zeros((3, 3), dtype=bool))
        two_cliques = ExplicitGraph(edges=((0, 1), (2, 3)))
        assert not two_cliques.connected(4)
        assert spectral_gap(Ring().mixing_matrix(4)) > 0.5
        assert spectral_gap(np.eye(4)) == 0.0

    def test_registry_instantiates(self):
        for name, factory in TOPOLOGIES.items():
            topo = factory()
            assert isinstance(topo, Topology), name


# ---------------------------------------------------------------- validation
class TestValidation:
    def test_participation_fraction_bounds(self):
        with pytest.raises(ValueError):
            PartialParticipation(fraction=1.5)
        with pytest.raises(ValueError):
            PartialParticipation(fraction=-0.1)
        PartialParticipation(fraction=0.0)   # boundary values are legal
        PartialParticipation(fraction=1.0)

    def test_dropout_p_bounds(self):
        with pytest.raises(ValueError):
            DropoutSync(p=1.01)
        with pytest.raises(ValueError):
            DropoutSync(p=-0.5)

    def test_erdos_renyi_p_bounds(self):
        with pytest.raises(ValueError):
            ErdosRenyi(p=2.0)

    def test_explicit_graph_bad_edge(self):
        with pytest.raises(ValueError):
            ExplicitGraph(edges=((0, 5),)).adjacency(4)
        with pytest.raises(ValueError):
            ExplicitGraph(edges=((1, 1),)).adjacency(4)

    def test_time_varying_rejects_star_and_empty(self):
        with pytest.raises(ValueError):
            TimeVarying(())
        with pytest.raises(ValueError):
            TimeVarying((Star(),))

    def test_joint_updates_require_star(self, quad, x0):
        eng = PearlEngine(update=JointExtragradientUpdate(), topology=Ring())
        with pytest.raises(ValueError):
            eng.run(quad, x0, rounds=2, gamma=1e-3)

    def test_metropolis_rejects_directed(self):
        A = np.zeros((3, 3), dtype=bool)
        A[0, 1] = True
        with pytest.raises(ValueError):
            metropolis_weights(A)


# -------------------------------------------------------- gossip convergence
class TestGossipConvergence:
    ROUNDS = 1500

    def test_ring_reaches_star_equilibrium_neighborhood(self, quad, x0):
        """Connected doubly-stochastic gossip preserves the equilibrium: the
        anchored view-consensus contracts, so the ring lands in the same
        neighborhood as the exact server broadcast (tolerance-pinned)."""
        gamma = stepsize.gamma_constant(quad.constants(), 4)
        star = PearlEngine().run(quad, x0, tau=4, rounds=self.ROUNDS,
                                 gamma=gamma, stochastic=False)
        ring = PearlEngine(topology=Ring()).run(
            quad, x0, tau=4, rounds=self.ROUNDS, gamma=gamma, stochastic=False)
        assert star.rel_errors[-1] < 1e-10
        assert ring.rel_errors[-1] < 1e-10
        # same equilibrium, not merely both small: final iterates agree
        np.testing.assert_allclose(np.asarray(ring.x_final),
                                   np.asarray(star.x_final), atol=1e-4)

    def test_disconnected_graph_provably_misses_equilibrium(self, quad, x0):
        """Two components never exchange: each player's view of the other
        component stays frozen at x0, so the iterates converge to the wrong
        point — the rel error floors far above the connected runs."""
        two_pairs = ExplicitGraph(edges=((0, 1), (2, 3)))
        assert not two_pairs.connected(quad.n)
        gamma = stepsize.gamma_constant(quad.constants(), 4)
        r = PearlEngine(topology=two_pairs).run(
            quad, x0, tau=4, rounds=self.ROUNDS, gamma=gamma, stochastic=False)
        assert np.isfinite(r.rel_errors[-1])
        assert r.rel_errors[-1] > 1e-2
        # it converged — to the wrong point (stationary, not equilibrium)
        assert abs(r.rel_errors[-1] - r.rel_errors[-100]) < 1e-3

    def test_time_varying_union_connected_converges(self, quad, x0):
        """Alternating two disconnected halves whose UNION is connected still
        reaches the equilibrium (B-connectivity)."""
        tv = TimeVarying((
            ExplicitGraph(edges=((0, 1), (2, 3))),
            ExplicitGraph(edges=((1, 2), (0, 3))),
        ))
        assert tv.connected(quad.n)
        gamma = stepsize.gamma_constant(quad.constants(), 4)
        r = PearlEngine(topology=tv).run(
            quad, x0, tau=4, rounds=self.ROUNDS, gamma=gamma, stochastic=False)
        assert r.rel_errors[-1] < 1e-8

    def test_gossip_steps_tighten_consensus(self, quad, x0):
        """Extra mixing sweeps per round can only improve tracking: error
        after the same rounds is no worse, and the wire bytes scale with the
        sweep count."""
        gamma = stepsize.gamma_constant(quad.constants(), 4)
        one = PearlEngine(topology=Ring(), gossip_steps=1).run(
            quad, x0, tau=4, rounds=400, gamma=gamma, stochastic=False)
        four = PearlEngine(topology=Ring(), gossip_steps=4).run(
            quad, x0, tau=4, rounds=400, gamma=gamma, stochastic=False)
        assert four.rel_errors[-1] <= one.rel_errors[-1] * 1.5
        assert four.total_bytes == 4 * one.total_bytes

    def test_gossip_strategy_randomness_independent_of_noise(self, quad, x0):
        """fraction=1.0 partial participation IS exact gossip, bit-for-bit,
        even in the stochastic setting — topology and participation draw from
        a key chain separate from the sampling noise."""
        gamma = stepsize.gamma_constant(quad.constants(), 4)
        key = jax.random.PRNGKey(5)
        exact = PearlEngine(topology=Ring()).run(
            quad, x0, tau=4, rounds=60, gamma=gamma, key=key)
        part = PearlEngine(sync=PartialParticipation(fraction=1.0),
                           topology=Ring()).run(
            quad, x0, tau=4, rounds=60, gamma=gamma, key=key)
        np.testing.assert_array_equal(np.asarray(exact.x_final),
                                      np.asarray(part.x_final))

    def test_gossip_composes_with_partial_participation(self, quad, x0):
        gamma = stepsize.gamma_constant(quad.constants(), 4)
        r = PearlEngine(sync=PartialParticipation(fraction=0.75, seed=0),
                        topology=Ring()).run(
            quad, x0, tau=4, rounds=3000, gamma=gamma, stochastic=False)
        assert r.rel_errors[-1] < 0.05

    def test_gossip_composes_with_quantization(self, quad, x0):
        """bf16 on every gossip edge: bounded quantization noise, same
        neighborhood."""
        gamma = stepsize.gamma_constant(quad.constants(), 4)
        r = PearlEngine(sync=QuantizedSync(jnp.bfloat16), topology=Ring()).run(
            quad, x0, tau=4, rounds=self.ROUNDS, gamma=gamma, stochastic=False)
        assert r.rel_errors[-1] < 1e-3


# -------------------------------------------------------- edge-aware bytes
class TestEdgeAwareBytes:
    def test_ring_bytes_are_edge_aware(self, quad, x0):
        """Gossip moves (active links) x (n-block view payload) per round —
        deg(i) messages per player, not a server downlink — and every wire
        transfer is counted once (down stays 0)."""
        n, d = x0.shape
        r = PearlEngine(topology=Ring()).run(quad, x0, tau=2, rounds=5,
                                             gamma=1e-3)
        links = 2 * n                        # directed ring edges
        assert int(r.bytes_up[0]) == links * n * d * 4
        assert (r.bytes_down == 0).all()

    def test_partial_participation_cuts_gossip_bytes(self, quad, x0):
        full = PearlEngine(topology=Ring()).run(
            quad, x0, tau=2, rounds=200, gamma=1e-3)
        part = PearlEngine(sync=PartialParticipation(fraction=0.5, seed=0),
                           topology=Ring()).run(
            quad, x0, tau=2, rounds=200, gamma=1e-3)
        assert 0 < part.total_bytes < full.total_bytes

    def test_dropout_bills_every_scheduled_edge(self, quad, x0):
        """Lossy links: transmissions are paid whether delivered or not, and
        the billing stays integer-typed."""
        lossy = PearlEngine(sync=DropoutSync(p=0.3, seed=1),
                            topology=Ring()).run(
            quad, x0, tau=2, rounds=50, gamma=1e-3)
        full = PearlEngine(topology=Ring()).run(
            quad, x0, tau=2, rounds=50, gamma=1e-3)
        assert lossy.total_bytes == full.total_bytes
        assert lossy.bytes_up.dtype == np.int64

    def test_dropout_star_billing_integer_typed(self):
        up, down = DropoutSync(p=0.25).round_bytes(
            np.array([1, 2, 3]), 4, 8, 4)
        assert up.dtype == np.int64 and down.dtype == np.int64
        np.testing.assert_array_equal(up, [4 * 8 * 4] * 3)   # billed full n

    def test_quantized_gossip_halves_wire(self, quad, x0):
        exact = PearlEngine(topology=Ring()).run(quad, x0, tau=2, rounds=5,
                                                 gamma=1e-3)
        comp = PearlEngine(sync=QuantizedSync(jnp.bfloat16),
                           topology=Ring()).run(quad, x0, tau=2, rounds=5,
                                                gamma=1e-3)
        np.testing.assert_array_equal(comp.bytes_up, exact.bytes_up // 2)


# --------------------------------------------- shared itemsize helper (pins)
class TestDirectionItemsizes:
    """Satellite: the engine-vs-trainer quantization-direction asymmetry is
    resolved in ONE place. Engine: broadcast compressed (up exact, down
    wire). Trainer: pre-reduction compressed (up wire, down exact)."""

    def test_engine_direction_pinned(self):
        assert direction_itemsizes(QuantizedSync(jnp.bfloat16), 4,
                                   compressed="down") == (4, 2)
        assert direction_itemsizes(ExactSync(), 4, compressed="down") == (4, 4)

    def test_trainer_direction_pinned(self):
        assert direction_itemsizes(QuantizedSync(jnp.bfloat16), 4,
                                   compressed="up") == (2, 4)
        assert direction_itemsizes(ExactSync(), 4, compressed="up") == (4, 4)

    def test_bad_direction_raises(self):
        with pytest.raises(ValueError):
            direction_itemsizes(ExactSync(), 4, compressed="sideways")

    def test_both_systems_pin_through_helper(self):
        """End-to-end pinned numbers: engine PearlResult (bf16 broadcast)
        vs trainer PearlCommReport (bf16 pre-reduction) for the same shapes."""
        from repro.train.pearl_trainer import PearlCommReport

        n, d = 4, 100
        # engine: star, all participate, bf16 broadcast
        up, down = QuantizedSync(jnp.bfloat16).round_bytes(
            np.array([n]), n, d, 4)
        assert int(up[0]) == n * d * 4            # uplink exact fp32
        assert int(down[0]) == n * n * d * 2      # joint vector at bf16
        # trainer: bf16 uplink, fp32 mean downlink (one block per player)
        rep = PearlCommReport(n_players=n, param_count=d, tau=2, rounds=1,
                              sync_dtype=jnp.bfloat16)
        t_up, t_down = rep.per_round_bytes()
        assert int(t_up[0]) == n * d * 2
        assert int(t_down[0]) == n * d * 4

    def test_trainer_gossip_report_moves_deg_blocks(self):
        """Aggregative consensus game: one parameter block per active edge —
        a ring player moves deg(i)=2 model-sizes per round, independent of n."""
        from repro.train.pearl_trainer import PearlCommReport

        rep = PearlCommReport(n_players=6, param_count=50, tau=2, rounds=3,
                              topology=Ring())
        up, down = rep.per_round_bytes()
        assert (up == 12 * 50 * 4).all()          # 2n directed edges x block
        assert (down == 0).all()
        assert rep.total_bytes == 3 * 12 * 50 * 4

    def test_star_round_bytes_down_blocks(self):
        up, down = star_round_bytes(np.array([3]), n=4, block_scalars=10,
                                    up_itemsize=4, down_itemsize=2,
                                    down_blocks=1)
        assert int(up[0]) == 3 * 10 * 4
        assert int(down[0]) == 3 * 10 * 2

    def test_gossip_round_bytes_payload(self):
        sent, recv = gossip_round_bytes(np.array([8, 0]), payload_blocks=4,
                                        block_scalars=10, itemsize=2)
        np.testing.assert_array_equal(sent, [8 * 4 * 10 * 2, 0])
        assert (recv == 0).all()


# ------------------------------------------------------------- star default
class TestStarDefault:
    def test_default_engine_is_star(self):
        assert PearlEngine().topology.is_server

    def test_topologies_are_hashable_static_args(self):
        for factory in TOPOLOGIES.values():
            hash(factory())   # frozen dataclasses: usable as jit static args


# ------------------------------------------- per-round resampled interaction
class TestResampledErdosRenyi:
    """Sampled-interaction gossip: round r mixes over a fresh G(n, p) draw,
    keyed per-round so every consumer reconstructs graph r from (seed, r)
    alone — no sequential stream to replay."""

    def test_rounds_actually_differ(self):
        stack = ResampledErdosRenyi(p=0.5, seed=3, period=8).adjacency_stack(8)
        assert stack.shape == (8, 8, 8)
        assert any(not np.array_equal(stack[0], stack[r]) for r in range(1, 8))

    def test_round_r_derivable_without_replay(self):
        """Per-round key hierarchy: graph r is a pure function of (seed, r),
        so topologies with different periods agree on their shared prefix —
        the fix a sequential stream could never provide."""
        short = ResampledErdosRenyi(p=0.5, seed=3, period=2)
        long = ResampledErdosRenyi(p=0.5, seed=3, period=8)
        np.testing.assert_array_equal(short.adjacency_stack(8),
                                      long.adjacency_stack(8)[:2])

    def test_reproducible_and_seed_sensitive(self):
        a = ResampledErdosRenyi(p=0.5, seed=11).adjacency_stack(8)
        b = ResampledErdosRenyi(p=0.5, seed=11).adjacency_stack(8)
        np.testing.assert_array_equal(a, b)
        assert not np.array_equal(
            a, ResampledErdosRenyi(p=0.5, seed=12).adjacency_stack(8))

    def test_union_adjacency_and_b_connectivity(self):
        topo = ResampledErdosRenyi(p=0.4, seed=5, period=6)
        stack = topo.adjacency_stack(8)
        np.testing.assert_array_equal(topo.adjacency(8), stack.any(axis=0))
        # connectivity is of the union graph (B-connectivity)
        assert topo.connected(8) == is_connected(stack.any(axis=0))

    def test_each_round_mixing_is_doubly_stochastic(self):
        W = ResampledErdosRenyi(p=0.6, seed=7, period=4).mixing_stack(6)
        assert W.shape == (4, 6, 6)
        for r in range(4):
            assert is_doubly_stochastic(W[r])
            np.testing.assert_allclose(W[r], W[r].T)

    def test_validation(self):
        with pytest.raises(ValueError):
            ResampledErdosRenyi(p=1.5)
        with pytest.raises(ValueError):
            ResampledErdosRenyi(period=0)

    def test_registered(self):
        assert "resampled_erdos_renyi" in TOPOLOGIES
        hash(TOPOLOGIES["resampled_erdos_renyi"]())

    def test_engine_runs_and_converges(self, quad, x0):
        """A union-connected resampled sequence reaches the same equilibrium
        neighborhood as static gossip, cycling the stack by round % period."""
        topo = ResampledErdosRenyi(p=0.7, seed=1, period=4)
        assert topo.connected(quad.n)
        gamma = stepsize.gamma_constant(quad.constants(), 4)
        r = PearlEngine(topology=topo).run(
            quad, x0, tau=4, rounds=1500, gamma=gamma, stochastic=False)
        assert r.rel_errors[-1] < 1e-8
