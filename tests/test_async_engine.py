"""Bounded-staleness async PEARL: the D = 0 pin, degradation, composition.

The load-bearing test is the bit-for-bit equivalence of the async scan at
staleness bound D = 0 against the lockstep engine on the star topology —
across sync strategies and both oracle modes — which anchors the new
subsystem to the PR 1/2 numerics. Around it: the equilibrium neighborhood
degrades monotonically as D grows, staleness composes with compression /
participation / gossip, and the delay schedules honor their contracts.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import stepsize
from repro.core.async_engine import (
    DELAY_SCHEDULES,
    AsyncPearlEngine,
    AsyncPearlResult,
    ConstantDelay,
    StaleSync,
    StragglerDelay,
    UniformDelay,
    ZeroDelay,
)
from repro.core.engine import (
    ExtragradientUpdate,
    JointExtragradientUpdate,
    PartialParticipation,
    PearlEngine,
    QuantizedSync,
)
from repro.core.topology import Ring

from helpers import (
    assert_runs_bitwise_equal,
    gaussian_x0,
    strong_quad,
    weak_quad,
)


@pytest.fixture(scope="module")
def quad():
    return strong_quad()


@pytest.fixture(scope="module")
def weak():
    """Weak coupling: staleness costs rounds instead of destabilizing."""
    return weak_quad()


@pytest.fixture(scope="module")
def x0(quad):
    return gaussian_x0(quad)


@pytest.fixture(scope="module")
def x0w(weak):
    return gaussian_x0(weak, seed=0)


# ------------------------------------------------------------- the D=0 pin
class TestLockstepEquivalence:
    ROUNDS = 50

    @pytest.mark.parametrize("sync", [
        None,
        QuantizedSync(jnp.bfloat16),
        PartialParticipation(fraction=0.5, seed=0),
    ], ids=["exact", "bf16", "partial"])
    @pytest.mark.parametrize("stochastic", [False, True])
    def test_star_d0_bit_for_bit(self, quad, x0, sync, stochastic):
        """D = 0 reproduces the lockstep engine bit-for-bit on the star,
        for every sync strategy and both oracle modes — including the RNG
        chain and the byte accounting."""
        c = quad.constants()
        gamma = stepsize.gamma_constant(c, 4)
        key = jax.random.PRNGKey(0)
        kw = {} if sync is None else {"sync": sync}
        r_sync = PearlEngine(**kw).run(
            quad, x0, tau=4, rounds=self.ROUNDS, gamma=gamma, key=key,
            stochastic=stochastic,
        )
        r_async = AsyncPearlEngine(**kw).run(
            quad, x0, tau=4, rounds=self.ROUNDS, gamma=gamma, key=key,
            stochastic=stochastic,
        )
        assert_runs_bitwise_equal(r_async, r_sync)

    @pytest.mark.parametrize("sync", [
        None,
        PartialParticipation(fraction=0.5, seed=0),
    ], ids=["exact", "partial"])
    def test_ring_d0_bit_for_bit(self, weak, x0w, sync):
        """The server-free path at D = 0 matches the lockstep gossip scan
        (single mixing sweep, the lockstep default) — including under a
        participation mask, which pins the masked-receiver invariant:
        a non-participant keeps its current view."""
        gamma = stepsize.gamma_constant(weak.constants(), 4)
        kw = {"topology": Ring()} if sync is None else {"topology": Ring(),
                                                        "sync": sync}
        r_sync = PearlEngine(**kw).run(
            weak, x0w, tau=4, rounds=60, gamma=gamma, stochastic=False)
        r_async = AsyncPearlEngine(**kw).run(
            weak, x0w, tau=4, rounds=60, gamma=gamma, stochastic=False)
        assert_runs_bitwise_equal(r_async, r_sync)

    def test_zero_bound_ignores_schedule(self, quad, x0):
        """max_staleness = 0 clips every schedule to the lockstep table."""
        gamma = stepsize.gamma_constant(quad.constants(), 2)
        runs = [
            AsyncPearlEngine(delays=sched, max_staleness=0).run(
                quad, x0, tau=2, rounds=20, gamma=gamma,
                key=jax.random.PRNGKey(1))
            for sched in (ZeroDelay(), UniformDelay(seed=9),
                          StragglerDelay(fraction=0.5, seed=9))
        ]
        for r in runs[1:]:
            np.testing.assert_array_equal(np.asarray(r.x_final),
                                          np.asarray(runs[0].x_final))

    def test_stale_sync_spelling_equivalent(self, quad, x0):
        """StaleSync(inner, schedule, D) == the (delays, max_staleness)
        constructor spelling, and carries the wire semantics of its inner
        strategy (bf16 halves the downlink)."""
        gamma = stepsize.gamma_constant(quad.constants(), 4)
        key = jax.random.PRNGKey(2)
        sched = UniformDelay(seed=3)
        a = AsyncPearlEngine(sync=QuantizedSync(jnp.bfloat16),
                             delays=sched, max_staleness=4).run(
            quad, x0, tau=4, rounds=30, gamma=gamma, key=key)
        b = AsyncPearlEngine(sync=StaleSync(QuantizedSync(jnp.bfloat16),
                                            sched, max_staleness=4)).run(
            quad, x0, tau=4, rounds=30, gamma=gamma, key=key)
        np.testing.assert_array_equal(np.asarray(a.x_final),
                                      np.asarray(b.x_final))
        exact = AsyncPearlEngine(delays=sched, max_staleness=4).run(
            quad, x0, tau=4, rounds=30, gamma=gamma, key=key)
        np.testing.assert_array_equal(b.bytes_down, exact.bytes_down // 2)


# ---------------------------------------------------------- staleness cost
class TestStalenessDegradation:
    def test_monotone_degradation_with_bound(self, weak, x0w):
        """At matched tau/gamma/rounds the equilibrium neighborhood degrades
        monotonically as the (deterministic, worst-case) staleness bound
        grows — bounded delay costs rounds, it must not help."""
        gamma = stepsize.gamma_constant(weak.constants(), 4)
        errs = []
        for D in (0, 2, 8):
            r = AsyncPearlEngine(delays=ConstantDelay(lag=D),
                                 max_staleness=D).run(
                weak, x0w, tau=4, rounds=60, gamma=gamma, stochastic=False)
            errs.append(r.rel_errors[-1])
        assert errs[0] < errs[1] < errs[2]

    def test_bytes_invariant_in_staleness(self, weak, x0w):
        """Staleness delays arrival, not transmission: per-round wire bytes
        are identical across D — the cost is purely extra rounds."""
        gamma = stepsize.gamma_constant(weak.constants(), 4)
        runs = [
            AsyncPearlEngine(delays=ConstantDelay(lag=D), max_staleness=D).run(
                weak, x0w, tau=4, rounds=30, gamma=gamma, stochastic=False)
            for D in (0, 8)
        ]
        np.testing.assert_array_equal(runs[0].bytes_up, runs[1].bytes_up)
        np.testing.assert_array_equal(runs[0].bytes_down, runs[1].bytes_down)

    def test_staleness_diagnostics_recorded(self, weak, x0w):
        gamma = stepsize.gamma_constant(weak.constants(), 4)
        r = AsyncPearlEngine(delays=UniformDelay(seed=0), max_staleness=4).run(
            weak, x0w, tau=4, rounds=40, gamma=gamma, stochastic=False)
        assert isinstance(r, AsyncPearlResult)
        assert r.staleness.shape == (40, weak.n)
        assert 0 < r.mean_staleness <= 4
        assert r.max_realized_staleness <= 4


# ------------------------------------------------------------- composition
class TestComposition:
    """Staleness x {compression, participation, gossip} all converge."""

    @pytest.mark.parametrize("kw, tol", [
        ({"sync": QuantizedSync(jnp.bfloat16)}, 1e-4),
        ({"sync": PartialParticipation(fraction=0.5, seed=0)}, 1e-6),
        ({"topology": Ring()}, 1e-4),
        ({"sync": PartialParticipation(fraction=0.5, seed=0),
          "topology": Ring()}, 1e-4),
    ], ids=["bf16", "partial", "ring", "partial-x-ring"])
    def test_staleness_composes(self, weak, x0w, kw, tol):
        gamma = stepsize.gamma_constant(weak.constants(), 4)
        r = AsyncPearlEngine(delays=UniformDelay(seed=0), max_staleness=4,
                             **kw).run(
            weak, x0w, tau=4, rounds=500, gamma=gamma, stochastic=False)
        assert r.rel_errors[-1] < tol

    def test_stale_extragradient_update(self, weak, x0w):
        """The update-rule axis stays orthogonal: local EG under staleness."""
        gamma = stepsize.gamma_constant(weak.constants(), 4)
        r = AsyncPearlEngine(update=ExtragradientUpdate(),
                             delays=UniformDelay(seed=1),
                             max_staleness=2).run(
            weak, x0w, tau=4, rounds=500, gamma=gamma, stochastic=False)
        assert r.rel_errors[-1] < 1e-6


# -------------------------------------------------------------- validation
class TestValidation:
    def test_joint_update_rejected(self, quad, x0):
        eng = AsyncPearlEngine(update=JointExtragradientUpdate())
        with pytest.raises(ValueError, match="fully synchronized"):
            eng.run(quad, x0, rounds=5, gamma=1e-3)

    def test_lockstep_engine_rejects_stale_sync(self, quad, x0):
        """PearlEngine cannot honor a delay schedule — it must refuse the
        wrapper instead of silently running the inner strategy."""
        eng = PearlEngine(sync=StaleSync(max_staleness=4))
        with pytest.raises(ValueError, match="AsyncPearlEngine"):
            eng.run(quad, x0, rounds=5, gamma=1e-3)

    def test_negative_bound_rejected(self):
        with pytest.raises(ValueError, match="max_staleness"):
            StaleSync(max_staleness=-1)

    def test_nested_stale_sync_rejected(self):
        with pytest.raises(ValueError, match="cannot wrap"):
            StaleSync(inner=StaleSync())

    def test_double_delay_spelling_rejected(self, quad, x0):
        """A StaleSync AND a non-default engine-level delay model is
        ambiguous — rejected instead of silently preferring one."""
        eng = AsyncPearlEngine(sync=StaleSync(max_staleness=4),
                               delays=ConstantDelay(lag=2), max_staleness=2)
        with pytest.raises(ValueError, match="not both"):
            eng.run(quad, x0, tau=2, rounds=5, gamma=1e-3)

    def test_bad_schedule_params_rejected(self):
        with pytest.raises(ValueError):
            ConstantDelay(lag=-1)
        with pytest.raises(ValueError):
            StragglerDelay(fraction=1.5)

    def test_tau_and_rounds_validated(self, quad, x0):
        eng = AsyncPearlEngine()
        with pytest.raises(ValueError, match="tau"):
            eng.run(quad, x0, tau=0, rounds=5, gamma=1e-3)
        with pytest.raises(ValueError, match="rounds"):
            eng.trajectory(quad, x0, tau=2, rounds=0, gamma=1e-3)


# ---------------------------------------------------------- delay schedules
class TestDelaySchedules:
    @pytest.mark.parametrize("name", sorted(DELAY_SCHEDULES))
    def test_schedule_contract(self, name):
        """Every registered schedule: right shape, int dtype, within bound,
        reproducible from its seed."""
        sched = DELAY_SCHEDULES[name]()
        a = sched.draw(20, 6, 5)
        b = sched.draw(20, 6, 5)
        assert a.shape == (20, 6)
        assert np.issubdtype(a.dtype, np.integer)
        assert a.min() >= 0 and a.max() <= 5
        np.testing.assert_array_equal(a, b)

    def test_straggler_is_heavy_tailed(self):
        """The straggler subset sits at the bound; the rest stay near 0."""
        table = StragglerDelay(fraction=0.25, seed=0).draw(50, 8, 6)
        always_max = (table == 6).all(axis=0)
        assert always_max.sum() == 2      # ceil(0.25 * 8)
        assert table[:, ~always_max].max() <= 1

    def test_constant_clips_to_bound(self):
        table = ConstantDelay(lag=100).draw(10, 4, 3)
        assert (table == 3).all()

    def test_draw_delay_table_continues_from_start(self):
        """Batching rounds into multiple calls realizes the SAME schedule
        as one long call: entry (r, i) is always global round r's delay."""
        from repro.core.async_engine import draw_delay_table

        sched = UniformDelay(seed=5)
        full = draw_delay_table(sched, 12, 4, 3)
        head = draw_delay_table(sched, 5, 4, 3)
        tail = draw_delay_table(sched, 7, 4, 3, start=5)
        np.testing.assert_array_equal(np.concatenate([head, tail]), full)

    def test_draw_delay_table_validates_shape(self):
        from repro.core.async_engine import DelaySchedule, draw_delay_table

        class Bad(DelaySchedule):
            def draw(self, rounds, n, max_staleness):
                return np.zeros((n, rounds), dtype=np.int32)   # transposed

        with pytest.raises(ValueError, match="shape"):
            draw_delay_table(Bad(), 7, 3, 2)
