"""Sharding-policy unit tests (no multi-device mesh needed: specs are data)."""

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.models.model import param_shapes
from repro.models.sharding import param_partition_specs


def _flat(tree):
    return {
        "/".join(str(p.key) if hasattr(p, "key") else str(p.idx) for p in path): leaf
        for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]
    }


@pytest.fixture(scope="module")
def granite_specs():
    cfg = get_config("granite-34b")
    shapes = param_shapes(cfg)
    specs = param_partition_specs(shapes, cfg, model_size=16)
    return cfg, _flat(shapes), _flat(specs)


class TestSpecRanksAndRules:
    @pytest.mark.parametrize("arch", ARCH_IDS)
    def test_spec_rank_matches_every_leaf(self, arch):
        cfg = get_config(arch)
        shapes = param_shapes(cfg)
        specs = param_partition_specs(shapes, cfg, model_size=16)
        fs, fp = _flat(shapes), _flat(specs)
        for k in fs:
            assert len(fp[k]) == len(fs[k].shape), (arch, k, fp[k], fs[k].shape)

    def test_granite_q_heads_sharded_kv_replicated(self, granite_specs):
        cfg, shapes, specs = granite_specs
        wq = next(k for k in specs if k.endswith("attn/wq"))
        wk = next(k for k in specs if k.endswith("attn/wk"))
        # stacked run: leading layer axis is None
        assert specs[wq] == P(None, None, "model", None)   # 48 % 16 == 0
        assert specs[wk] == P(None, None, None, None)      # kv=1 replicated

    def test_granite_ffn_and_vocab_sharded(self, granite_specs):
        cfg, shapes, specs = granite_specs
        up = next(k for k in specs if k.endswith("mlp/up"))
        down = next(k for k in specs if k.endswith("mlp/down"))
        assert specs[up][-1] == "model"
        assert specs[down][-2] == "model"
        assert specs["embed"] == P("model", None)
        assert specs["lm_head"] == P(None, "model")

    def test_smollm_heads_replicated_ffn_sharded(self):
        cfg = get_config("smollm-360m")
        shapes = param_shapes(cfg)
        specs = _flat(param_partition_specs(shapes, cfg, model_size=16))
        wq = next(k for k in specs if k.endswith("attn/wq"))
        assert specs[wq] == P(None, None, None, None)      # 15 % 16 != 0
        up = next(k for k in specs if k.endswith("mlp/up"))
        assert specs[up][-1] == "model"                    # 2560 % 16 == 0

    def test_moe_experts_sharded_on_expert_axis(self):
        cfg = get_config("qwen3-moe-30b-a3b")
        shapes = param_shapes(cfg)
        specs = _flat(param_partition_specs(shapes, cfg, model_size=16))
        gate = next(k for k in specs if "moe/gate" in k)
        down = next(k for k in specs if "moe/down" in k)
        assert specs[gate] == P(None, "model", None, None)  # (layer, E, D, F)
        assert specs[down] == P(None, "model", None, None)

    def test_mamba_inner_sharded(self):
        cfg = get_config("zamba2-1.2b")
        shapes = param_shapes(cfg)
        specs = _flat(param_partition_specs(shapes, cfg, model_size=16))
        outp = next(k for k in specs if k.endswith("mamba/out_proj"))
        assert specs[outp][-2] == "model"                  # d_inner=4096 % 16
        conv = next(k for k in specs if "mamba/conv/w" in k)
        assert all(a is None for a in specs[conv])

    def test_norms_replicated_everywhere(self):
        for arch in ("granite-34b", "xlstm-125m", "seamless-m4t-medium"):
            cfg = get_config(arch)
            shapes = param_shapes(cfg)
            specs = _flat(param_partition_specs(shapes, cfg, model_size=16))
            for k, s in specs.items():
                if "norm" in k or k.endswith("ln") or "/ln" in k:
                    assert all(a is None for a in s), (arch, k, s)

    def test_dp_only_profile_replicates_everything(self):
        cfg = get_config("xlstm-125m")
        shapes = param_shapes(cfg)
        specs = _flat(param_partition_specs(shapes, cfg, model_size=1))
        for k, s in specs.items():
            assert all(a is None for a in s), (k, s)
