"""Model-component tests: chunked attention vs oracle, MoE numerics, RoPE,
conv decode steps, model-level kernel path equivalence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.kernels.flash_attention.ref import attention_ref
from repro.models.attention import chunked_attention
from repro.models.layers import (
    apply_rope,
    causal_conv1d,
    causal_conv1d_step,
    init_causal_conv,
    rms_norm,
)
from repro.models.moe import init_moe, moe_ffn

SETTINGS = dict(max_examples=10, deadline=None)


class TestChunkedAttention:
    @settings(**SETTINGS)
    @given(
        s=st.sampled_from([32, 64, 128]),
        chunk=st.sampled_from([16, 32, 1024]),
        window=st.sampled_from([0, 24]),
    )
    def test_matches_reference(self, s, chunk, window):
        key = jax.random.PRNGKey(s + chunk)
        b, h, hd = 2, 3, 16
        q = jax.random.normal(key, (b, s, h, hd))
        k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, h, hd))
        v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, h, hd))
        out = chunked_attention(q, k, v, chunk=chunk, causal=True,
                                window=window)
        ref = attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)

    def test_decode_offset(self):
        """Sq=1 query at absolute offset attends to the right prefix."""
        key = jax.random.PRNGKey(0)
        b, s, h, hd = 1, 32, 2, 8
        q = jax.random.normal(key, (b, 1, h, hd))
        k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, h, hd))
        v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, h, hd))
        out = chunked_attention(q, k, v, causal=True, q_offset=10)
        # reference: mask keys > 10
        qpad = jnp.zeros((b, 11, h, hd)).at[:, 10:11].set(q)
        ref = attention_ref(qpad, k[:, :11], v[:, :11], causal=True)[:, 10:11]
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


class TestRoPE:
    def test_relative_property(self):
        """RoPE inner products depend only on relative positions."""
        key = jax.random.PRNGKey(0)
        q = jax.random.normal(key, (1, 1, 1, 32))
        k = jax.random.normal(jax.random.fold_in(key, 1), (1, 1, 1, 32))

        def score(pq, pk):
            qq = apply_rope(q, jnp.asarray([[pq]]), 1e4)
            kk = apply_rope(k, jnp.asarray([[pk]]), 1e4)
            return float(jnp.sum(qq * kk))

        assert score(5, 3) == pytest.approx(score(105, 103), rel=1e-4)
        assert score(5, 3) != pytest.approx(score(5, 4), rel=1e-3)

    def test_zero_position_identity(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (1, 1, 2, 16))
        out = apply_rope(x, jnp.zeros((1, 1), jnp.int32), 1e4)
        np.testing.assert_allclose(np.asarray(out), np.asarray(x), atol=1e-6)


class TestCausalConv:
    @settings(**SETTINGS)
    @given(s=st.sampled_from([4, 9, 16]), c=st.sampled_from([3, 8]))
    def test_step_matches_full(self, s, c):
        key = jax.random.PRNGKey(s * 10 + c)
        params = init_causal_conv(key, c, 4)
        x = jax.random.normal(jax.random.fold_in(key, 1), (2, s, c))
        full = causal_conv1d(params, x)
        win = jnp.zeros((2, 3, c))
        outs = []
        for t in range(s):
            win, y = causal_conv1d_step(params, win, x[:, t])
            outs.append(y)
        np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)),
                                   np.asarray(full), atol=1e-5)


class TestMoE:
    def test_output_shape_and_aux_range(self):
        key = jax.random.PRNGKey(0)
        params = init_moe(key, d_model=32, n_experts=4, d_ff=64)
        x = jax.random.normal(jax.random.fold_in(key, 1), (2, 16, 32))
        out, aux = moe_ffn(params, x, top_k=2, capacity_factor=4.0,
                           group_size=16)
        assert out.shape == x.shape
        assert float(aux) >= 1.0 - 1e-5

    def test_high_capacity_equals_dense_mixture(self):
        """With no drops, MoE == prob-weighted sum of expert FFNs (oracle)."""
        key = jax.random.PRNGKey(1)
        d, e, f = 16, 4, 32
        params = init_moe(key, d_model=d, n_experts=e, d_ff=f)
        x = jax.random.normal(jax.random.fold_in(key, 1), (1, 8, d))
        out, _ = moe_ffn(params, x, top_k=e, capacity_factor=float(e + 1),
                         group_size=8)
        # oracle: full softmax mixture over all experts (top_k = e keeps all)
        logits = x.astype(jnp.float32) @ params["router"]
        probs = jax.nn.softmax(logits, -1)
        gate = jnp.einsum("bsd,edf->bsef", x, params["gate"])
        up = jnp.einsum("bsd,edf->bsef", x, params["up"])
        act = jax.nn.silu(gate) * up
        expert_out = jnp.einsum("bsef,efd->bsed", act, params["down"])
        ref = jnp.einsum("bse,bsed->bsd", probs.astype(x.dtype), expert_out)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-4, rtol=1e-4)

    def test_group_size_changes_flops_not_semantics(self):
        key = jax.random.PRNGKey(2)
        params = init_moe(key, d_model=16, n_experts=4, d_ff=32)
        x = jax.random.normal(jax.random.fold_in(key, 1), (2, 32, 16))
        out_a, _ = moe_ffn(params, x, top_k=1, capacity_factor=8.0,
                           group_size=64)
        out_b, _ = moe_ffn(params, x, top_k=1, capacity_factor=8.0,
                           group_size=16)
        np.testing.assert_allclose(np.asarray(out_a), np.asarray(out_b),
                                   atol=1e-5)


class TestModelKernelPath:
    """use_kernels=True (Pallas interpret) must match the jnp model path."""

    @pytest.mark.parametrize("arch", ["smollm-360m", "zamba2-1.2b", "xlstm-125m"])
    def test_forward_equivalence(self, arch):
        from repro.models import forward, init_params

        cfg = get_config(arch).smoke_variant()
        params = init_params(cfg, jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                  cfg.vocab_size)
        out_jnp = forward(params, cfg, {"tokens": toks})["logits"]
        out_ker = forward(params, cfg, {"tokens": toks},
                          use_kernels=True)["logits"]
        np.testing.assert_allclose(np.asarray(out_ker), np.asarray(out_jnp),
                                   atol=5e-4, rtol=5e-4)


class TestRMSNorm:
    def test_unit_scale_normalizes(self):
        x = jnp.asarray([[3.0, 4.0]])
        out = rms_norm(x, jnp.ones(2), eps=0.0)
        np.testing.assert_allclose(float(jnp.mean(out**2)), 1.0, rtol=1e-5)
