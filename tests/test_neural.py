"""NeuralPlayerAdapter: real model-stack players on the two-axis mesh.

The PR 8 acceptance pin: PearlTrainer trains >= 2 real neural players — a
transformer (smollm) and a non-transformer block (xlstm) — end to end on a
2-axis fake mesh with the Pallas kernel path enabled, and a quantized sync
whose wire dtype is asserted on dry-run HLO. The multi-device CI job runs
this file under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``; on
a single device the mesh cases skip and the host-fallback cases still run.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import collective
from repro.data.synthetic import DataConfig, SyntheticTokenStream
from repro.optim.optimizers import sgd
from repro.train import NeuralPlayerAdapter, two_axis_mesh

multi_device = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs a multi-device (fake) mesh: run under "
           "XLA_FLAGS=--xla_force_host_platform_device_count=8",
)

N = 2


def _stream(cfg, n_players=N):
    return SyntheticTokenStream(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=32, batch_size=2,
        n_players=n_players, seed=0,
    ))


class TestTwoAxisMesh:
    @multi_device
    def test_splits_devices_between_axes(self):
        m = two_axis_mesh(N)
        assert m.shape["players"] * m.shape["model"] == jax.device_count()
        assert N % m.shape["players"] == 0
        assert m.shape["players"] > 1 or m.shape["model"] > 1

    @multi_device
    def test_player_axis_takes_largest_divisor(self):
        devs = jax.devices()
        if len(devs) < 4:
            pytest.skip("needs >= 4 fake devices")
        m = two_axis_mesh(2, devices=devs[:4])
        assert m.shape == {"players": 2, "model": 2}
        m3 = two_axis_mesh(3, devices=devs[:4])
        # 3 players on 4 devices: player axis 3, one model device dropped
        assert m3.shape["players"] == 3

    def test_single_device_returns_none(self):
        assert two_axis_mesh(N, devices=jax.devices()[:1]) is None

    def test_rejects_degenerate_counts(self):
        with pytest.raises(ValueError, match="n_players"):
            two_axis_mesh(0)


class TestAdapterHostFallback:
    """devices=False (or a single device) builds a plain host trainer —
    the path plain tier-1 CI exercises."""

    def test_trains_without_a_mesh(self):
        cfg = get_config("smollm-360m").smoke_variant()
        ad = NeuralPlayerAdapter(cfg, sgd(3e-2), n_players=N, tau=2,
                                 prox_lambda=0.1, devices=False)
        assert ad.mesh is None and ad.inner_specs is None
        hist = ad.run(_stream(cfg), 2)
        assert len(hist) == 2 and np.isfinite(hist[-1]["lm_loss"])
        assert ad.comm_report().sync_bytes_per_round > 0

    def test_player_params_unstack(self):
        cfg = get_config("smollm-360m").smoke_variant()
        ad = NeuralPlayerAdapter(cfg, sgd(3e-2), n_players=N, tau=2,
                                 prox_lambda=0.1, devices=False)
        p0 = ad.player_params(0)
        stacked = jax.tree.leaves(ad.trainer.params)[0]
        assert jax.tree.leaves(p0)[0].shape == stacked.shape[1:]


@multi_device
class TestNeuralPlayersOnMesh:
    """The end-to-end criterion, one arch per model family."""

    @pytest.mark.parametrize("arch", ["smollm-360m", "xlstm-125m"])
    def test_trains_with_kernels_and_quantized_wire(self, arch):
        cfg = get_config(arch).smoke_variant()
        ad = NeuralPlayerAdapter(cfg, sgd(3e-2), n_players=N, tau=2,
                                 prox_lambda=0.1,
                                 sync_dtype=jnp.bfloat16)
        assert ad.trainer._round is not None
        assert ad.mesh.shape["players"] == N
        assert ad.inner_specs is not None
        # the kernel path is on by default — the loss_fn was built with it
        assert ad.trainer is not None
        hlo = ad.lower_round_hlo(seq_len=32, batch_size=2)
        report = collective.assert_wire_dtype(hlo, compressed=True)
        assert any(o.op == "all-gather" and o.operand_dtype == "u16"
                   for o in report)
        hist = ad.run(_stream(cfg), 2)
        assert len(hist) == 2
        assert all(np.isfinite(h["lm_loss"]) for h in hist)

    def test_mesh_matches_host_fallback_losses(self):
        cfg = get_config("smollm-360m").smoke_variant()
        host = NeuralPlayerAdapter(cfg, sgd(3e-2), n_players=N, tau=2,
                                   prox_lambda=0.1, devices=False)
        h = host.run(_stream(cfg), 2)
        mesh = NeuralPlayerAdapter(cfg, sgd(3e-2), n_players=N, tau=2,
                                   prox_lambda=0.1)
        m = mesh.run(_stream(cfg), 2)
        for a, b in zip(h, m):
            assert a["lm_loss"] == pytest.approx(b["lm_loss"], rel=1e-5)

    def test_int8_ef_wire_on_mesh(self):
        """The low-bit EF star wire composes with the two-axis mesh: the
        sync all-gather operand is the single u8 payload."""
        from repro.core.engine import Int8Sync

        cfg = get_config("smollm-360m").smoke_variant()
        ad = NeuralPlayerAdapter(cfg, sgd(3e-2), n_players=N, tau=2,
                                 prox_lambda=0.1, sync=Int8Sync())
        assert ad.trainer._lowbit
        hlo = ad.lower_round_hlo(seq_len=32, batch_size=2)
        report = collective.assert_wire_dtype(hlo, compressed=True)
        assert any(o.op == "all-gather" and o.operand_dtype == "u8"
                   for o in report)
        hist = ad.run(_stream(cfg), 2)
        assert all(np.isfinite(h["lm_loss"]) for h in hist)

    def test_general_merge_on_two_axis_mesh(self):
        """Mask strategy x two-axis mesh: the general stale-block merge
        compiles with the per-leaf tensor-parallel inner specs threaded."""
        from repro.core.engine import PartialParticipation

        cfg = get_config("smollm-360m").smoke_variant()
        ad = NeuralPlayerAdapter(
            cfg, sgd(3e-2), n_players=N, tau=2, prox_lambda=0.1,
            sync=PartialParticipation(fraction=0.5, seed=3))
        assert ad.trainer._general
        hist = ad.run(_stream(cfg), 2)
        assert all(np.isfinite(h["lm_loss"]) for h in hist)


class TestKernelBackward:
    """The custom_vjp that makes the Pallas forward trainable: kernel-path
    gradients must match the pure-jnp path at tolerance (the backward IS
    the jnp oracle, so only forward-residual differences can show up)."""

    @pytest.mark.parametrize("arch", ["smollm-360m", "xlstm-125m",
                                      "zamba2-1.2b"])
    def test_kernel_grads_match_reference(self, arch):
        from repro.models.model import init_params
        from repro.train.train_step import make_loss_fn

        cfg = get_config(arch).smoke_variant()
        params = init_params(cfg, jax.random.PRNGKey(0))
        tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                    cfg.vocab_size)
        batch = {"tokens": tokens}

        def grads(use_kernels):
            loss_fn = make_loss_fn(cfg, use_kernels=use_kernels)
            (_, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch, None)
            return g

        g_ref = grads(False)
        g_ker = grads(True)
        for a, b in zip(jax.tree.leaves(g_ref), jax.tree.leaves(g_ker)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-5)
