"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode.

Each kernel family gets (a) hypothesis-driven randomized shape sweeps and
(b) fixed MXU-aligned cases mirroring production block sizes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels.block_operator.ops import block_operator
from repro.kernels.block_operator.ref import block_operator_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.mamba2_scan.ops import ssd_scan
from repro.kernels.mamba2_scan.ref import ssd_ref
from repro.kernels.mlstm_chunk.ops import mlstm_scan
from repro.kernels.mlstm_chunk.ref import mlstm_ref

SETTINGS = dict(max_examples=12, deadline=None)


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


class TestFlashAttention:
    @settings(**SETTINGS)
    @given(
        b=st.sampled_from([1, 2]),
        s=st.sampled_from([32, 64, 96, 128, 160]),
        h=st.sampled_from([1, 3]),
        hd=st.sampled_from([16, 64]),
        causal=st.booleans(),
        dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    )
    def test_matches_oracle(self, b, s, h, hd, causal, dtype):
        key = jax.random.PRNGKey(b * 1000 + s + h + hd)
        q = _rand(key, (b, s, h, hd), dtype)
        k = _rand(jax.random.fold_in(key, 1), (b, s, h, hd), dtype)
        v = _rand(jax.random.fold_in(key, 2), (b, s, h, hd), dtype)
        out = flash_attention(q, k, v, causal=causal, block_q=32, block_k=32,
                              interpret=True)
        ref = attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                            v.astype(jnp.float32), causal=causal)
        atol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref), atol=atol, rtol=atol)

    @pytest.mark.parametrize("window", [16, 48])
    def test_sliding_window(self, window):
        key = jax.random.PRNGKey(0)
        b, s, h, hd = 2, 128, 2, 32
        q = _rand(key, (b, s, h, hd), jnp.float32)
        k = _rand(jax.random.fold_in(key, 1), (b, s, h, hd), jnp.float32)
        v = _rand(jax.random.fold_in(key, 2), (b, s, h, hd), jnp.float32)
        out = flash_attention(q, k, v, causal=True, window=window,
                              block_q=32, block_k=32, interpret=True)
        ref = attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)

    def test_unaligned_seq_padding(self):
        """Sequence not a multiple of the block size exercises the pad path."""
        key = jax.random.PRNGKey(3)
        b, s, h, hd = 1, 100, 2, 32
        q = _rand(key, (b, s, h, hd), jnp.float32)
        k = _rand(jax.random.fold_in(key, 1), (b, s, h, hd), jnp.float32)
        v = _rand(jax.random.fold_in(key, 2), (b, s, h, hd), jnp.float32)
        out = flash_attention(q, k, v, causal=True, block_q=32, block_k=32,
                              interpret=True)
        ref = attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)

    def test_mxu_aligned_production_blocks(self):
        key = jax.random.PRNGKey(7)
        b, s, h, hd = 1, 512, 2, 128
        q = _rand(key, (b, s, h, hd), jnp.bfloat16)
        k = _rand(jax.random.fold_in(key, 1), (b, s, h, hd), jnp.bfloat16)
        v = _rand(jax.random.fold_in(key, 2), (b, s, h, hd), jnp.bfloat16)
        out = flash_attention(q, k, v, causal=True, block_q=128, block_k=128,
                              interpret=True)
        ref = attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                            v.astype(jnp.float32), causal=True)
        np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref),
                                   atol=3e-2, rtol=3e-2)


class TestMamba2Scan:
    @settings(**SETTINGS)
    @given(
        b=st.sampled_from([1, 2]),
        L=st.sampled_from([32, 64, 128]),
        H=st.sampled_from([1, 4]),
        P=st.sampled_from([8, 16]),
        N=st.sampled_from([4, 8]),
        chunk=st.sampled_from([16, 32]),
    )
    def test_matches_sequential_oracle(self, b, L, H, P, N, chunk):
        key = jax.random.PRNGKey(L + H * 10 + P)
        x = _rand(key, (b, L, H, P), jnp.float32)
        dt = jax.nn.softplus(_rand(jax.random.fold_in(key, 1), (b, L, H),
                                   jnp.float32))
        A = -jnp.exp(0.3 * _rand(jax.random.fold_in(key, 2), (H,), jnp.float32))
        B = _rand(jax.random.fold_in(key, 3), (b, L, N), jnp.float32)
        C = _rand(jax.random.fold_in(key, 4), (b, L, N), jnp.float32)
        y, h = ssd_scan(x, dt, A, B, C, chunk=chunk, interpret=True)
        y_ref, h_ref = ssd_ref(x, dt, A, B, C)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   atol=1e-3, rtol=1e-3)
        np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                                   atol=1e-3, rtol=1e-3)

    def test_bf16_inputs(self):
        key = jax.random.PRNGKey(0)
        b, L, H, P, N = 1, 64, 2, 16, 8
        x = _rand(key, (b, L, H, P), jnp.bfloat16)
        dt = jax.nn.softplus(_rand(jax.random.fold_in(key, 1), (b, L, H),
                                   jnp.float32))
        A = -jnp.exp(0.3 * _rand(jax.random.fold_in(key, 2), (H,), jnp.float32))
        B = _rand(jax.random.fold_in(key, 3), (b, L, N), jnp.bfloat16)
        C = _rand(jax.random.fold_in(key, 4), (b, L, N), jnp.bfloat16)
        y, _ = ssd_scan(x, dt, A, B, C, chunk=16, interpret=True)
        y_ref, _ = ssd_ref(x.astype(jnp.float32), dt, A,
                           B.astype(jnp.float32), C.astype(jnp.float32))
        np.testing.assert_allclose(np.asarray(y, np.float32), np.asarray(y_ref),
                                   atol=0.15, rtol=0.1)


class TestMlstmChunk:
    @settings(**SETTINGS)
    @given(
        b=st.sampled_from([1, 2]),
        L=st.sampled_from([32, 64, 128]),
        H=st.sampled_from([1, 3]),
        dh=st.sampled_from([8, 16]),
        chunk=st.sampled_from([16, 32]),
    )
    def test_matches_sequential_oracle(self, b, L, H, dh, chunk):
        key = jax.random.PRNGKey(L + H + dh)
        q = _rand(key, (b, L, H, dh), jnp.float32)
        k = _rand(jax.random.fold_in(key, 1), (b, L, H, dh), jnp.float32)
        v = _rand(jax.random.fold_in(key, 2), (b, L, H, dh), jnp.float32)
        logi = _rand(jax.random.fold_in(key, 3), (b, L, H), jnp.float32)
        logf = jax.nn.log_sigmoid(
            _rand(jax.random.fold_in(key, 4), (b, L, H), jnp.float32) + 2.0)
        h, (C, n, m) = mlstm_scan(q, k, v, logi, logf, chunk=chunk,
                                  interpret=True)
        h_ref, (C_r, n_r, m_r) = mlstm_ref(q, k, v, logi, logf)
        np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                                   atol=2e-3, rtol=2e-3)
        np.testing.assert_allclose(np.asarray(C), np.asarray(C_r), atol=1e-3,
                                   rtol=1e-3)
        np.testing.assert_allclose(np.asarray(m), np.asarray(m_r), atol=1e-4)


class TestBlockOperator:
    @settings(**SETTINGS)
    @given(
        n=st.sampled_from([2, 3, 5, 8]),
        d=st.sampled_from([4, 10, 16]),
    )
    def test_matches_oracle(self, n, d):
        rng = np.random.default_rng(n * 100 + d)
        A = jnp.asarray(rng.standard_normal((n, d, d)), jnp.float32)
        B = jnp.asarray(rng.standard_normal((n, n, d, d)), jnp.float32)
        B = B.at[jnp.arange(n), jnp.arange(n)].set(0.0)
        a = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
        x = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
        out = block_operator(A, B, a, x, interpret=True)
        ref = block_operator_ref(A, B, a, x)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4,
                                   rtol=1e-4)

    def test_matches_quadratic_game_operator(self):
        """The kernel must agree with QuadraticGame.operator on a real game."""
        from repro.core.games import make_quadratic_game

        g = make_quadratic_game(n=4, d=8, M=10, seed=1)
        A = jnp.mean(g.A, axis=1).astype(jnp.float32)
        B = jnp.mean(g.B, axis=2).astype(jnp.float32)
        a = jnp.mean(g.a, axis=1).astype(jnp.float32)
        x = jnp.asarray(np.random.default_rng(0).standard_normal((4, 8)),
                        jnp.float32)
        out = block_operator(A, B, a, x, interpret=True)
        ref = g.operator(x)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref, np.float32),
                                   atol=1e-4, rtol=1e-4)
