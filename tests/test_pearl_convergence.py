"""Integration tests: PEARL-SGD convergence matches the paper's theorems.

These are the paper-claims validations referenced from EXPERIMENTS.md:
- Theorem 3.3: deterministic linear+exact convergence, rate bounded by
  (1 - gamma tau mu zeta)^R; tau-curves indistinguishable in rounds.
- Theorem 3.4: stochastic linear convergence to a neighborhood; neighborhood
  shrinks as tau grows (the communication gain).
- Theorem 3.6: decreasing step-sizes give exact convergence (error keeps
  falling below any constant-step plateau).
- Section B: Local SGD on the summed objective diverges where PEARL-SGD
  converges.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import stepsize
from repro.core.baselines import extragradient, local_sgd_on_sum, pearl_eg, sgda
from repro.core.games import (
    make_counterexample_game,
    make_noncoco_game,
    make_quadratic_game,
    make_robot_game,
)
from repro.core.metrics import final_plateau
from repro.core.pearl import pearl_sgd, pearl_sgd_mean

@pytest.fixture(scope="module", autouse=True)
def _x64():
    """float64 for the game dynamics — scoped so it can't leak into other
    test modules (bf16/int32 model paths break under global x64)."""
    old = jax.config.read("jax_enable_x64")
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", old)


@pytest.fixture(scope="module")
def quad(_x64):
    return make_quadratic_game(n=4, d=8, M=40, batch_size=1, seed=0)


@pytest.fixture(scope="module")
def x0(quad):
    return jnp.asarray(np.random.default_rng(7).standard_normal((quad.n, quad.d)))


class TestTheorem33Deterministic:
    @pytest.mark.parametrize("tau", [1, 2, 5, 8])
    def test_linear_rate_bound(self, quad, x0, tau):
        """rel_err at round R must respect (1 - gamma tau mu zeta)^R."""
        c = quad.constants()
        gamma = stepsize.gamma_constant(c, tau)
        rounds = 300
        r = pearl_sgd(quad, x0, tau=tau, rounds=rounds, gamma=gamma, stochastic=False)
        rate = stepsize.linear_rate(c, tau, gamma)
        bound = rate ** np.arange(rounds + 1)
        assert np.all(r.rel_errors <= bound * (1 + 1e-6))
        # and it must actually make progress
        assert r.rel_errors[-1] < r.rel_errors[0]

    def test_tau_curves_indistinguishable(self, quad, x0):
        """Fig 2a: with theoretical gamma ~ 1/tau, all tau give the same
        per-round progress in the deterministic setting."""
        c = quad.constants()
        finals = {}
        for tau in (1, 2, 4, 8):
            gamma = stepsize.gamma_constant(c, tau)
            r = pearl_sgd(quad, x0, tau=tau, rounds=200, gamma=gamma, stochastic=False)
            finals[tau] = r.rel_errors[-1]
        vals = np.array(list(finals.values()))
        # all within a small multiplicative band of each other
        assert vals.max() / vals.min() < 1.6

    def test_exact_convergence(self, quad, x0):
        """Unlike heterogeneous Local SGD, convergence is to the *exact*
        equilibrium (no neighborhood) in the deterministic case."""
        c = quad.constants()
        gamma = stepsize.gamma_constant(c, 2)
        r = pearl_sgd(quad, x0, tau=2, rounds=5000, gamma=gamma, stochastic=False)
        assert r.rel_errors[-1] < 1e-6


class TestTheorem34Stochastic:
    def test_converges_to_neighborhood(self, quad, x0):
        c = quad.constants()
        gamma = stepsize.gamma_constant(c, 4)
        mean, _ = pearl_sgd_mean(quad, x0, tau=4, rounds=1500, gamma=gamma, n_seeds=3)
        assert final_plateau(mean) < 0.05

    def test_neighborhood_shrinks_with_tau(self, quad, x0):
        """The communication gain: larger tau -> smaller plateau at the same
        number of communication rounds (Fig 2b / Thm 3.4 remark)."""
        c = quad.constants()
        plateaus = {}
        for tau in (1, 4, 16):
            gamma = stepsize.gamma_constant(c, tau)
            mean, _ = pearl_sgd_mean(
                quad, x0, tau=tau, rounds=2500, gamma=gamma, n_seeds=4
            )
            plateaus[tau] = final_plateau(mean, window=100)
        assert plateaus[4] < plateaus[1]
        assert plateaus[16] < plateaus[1]

    def test_robot_game_matches_fig2c(self):
        """On the Section 4.2 problem larger tau reaches lower error within a
        fixed communication budget."""
        g = make_robot_game()
        c = g.constants()
        x0 = jnp.zeros((5, 1))
        plateaus = {}
        for tau in (1, 8):
            gamma = stepsize.gamma_robot(c, tau)
            mean, _ = pearl_sgd_mean(g, x0, tau=tau, rounds=400, gamma=gamma, n_seeds=5)
            plateaus[tau] = final_plateau(mean, window=50)
        assert plateaus[8] < plateaus[1]


class TestTheorem36DecreasingStep:
    def test_exact_convergence_beats_constant_plateau(self, quad, x0):
        c = quad.constants()
        tau, rounds = 4, 10000
        const = stepsize.gamma_constant(c, tau)
        r_const = pearl_sgd(
            quad, x0, tau=tau, rounds=rounds, gamma=const,
            key=jax.random.PRNGKey(0),
        )
        sched = stepsize.gamma_decreasing(c, tau, rounds)
        r_dec = pearl_sgd(
            quad, x0, tau=tau, rounds=rounds, gamma=sched,
            key=jax.random.PRNGKey(0),
        )
        assert final_plateau(r_dec.rel_errors, 100) < final_plateau(
            r_const.rel_errors, 100
        )

    def test_schedule_shape(self, quad):
        c = quad.constants()
        sched = stepsize.gamma_decreasing(c, 4, 5000)
        # warmup is constant, tail decays ~ 1/p
        assert sched[0] == sched[1]
        assert sched[-1] < sched[0]
        assert sched[-1] == pytest.approx(
            (2 * 4999 + 1) / (5000**2) / (4 * c.mu)
        )


class TestCorollary35:
    def test_horizon_stepsize_valid_and_converges(self, quad, x0):
        c = quad.constants()
        tau = 4
        T = int(40 * c.kappa * tau)  # large enough for eta > kappa tau
        gamma = stepsize.gamma_horizon(c, tau, T)
        assert gamma <= stepsize.gamma_constant(c, 1)
        rounds = T // tau
        r = pearl_sgd(quad, x0, tau=tau, rounds=rounds, gamma=gamma,
                      key=jax.random.PRNGKey(1))
        assert final_plateau(r.rel_errors, 50) < 0.02

    def test_horizon_too_small_raises(self, quad):
        c = quad.constants()
        with pytest.raises(ValueError):
            stepsize.gamma_horizon(c, tau=50, T=10)


class TestBaselines:
    def test_sgda_equals_pearl_tau1(self, quad, x0):
        c = quad.constants()
        gamma = stepsize.gamma_constant(c, 1)
        r1 = sgda(quad, x0, steps=50, gamma=gamma, key=jax.random.PRNGKey(3))
        r2 = pearl_sgd(quad, x0, tau=1, rounds=50, gamma=gamma,
                       key=jax.random.PRNGKey(3))
        np.testing.assert_allclose(
            np.asarray(r1.x_final), np.asarray(r2.x_final), rtol=1e-10
        )

    def test_local_sgd_on_sum_diverges_where_pearl_converges(self):
        g = make_counterexample_game()
        c = g.constants()
        x0 = jnp.ones((2, g.d))
        _, _, _, norms = local_sgd_on_sum(g, x0, steps=4000, gamma=0.05)
        assert norms[-1] > 100 * norms[0]  # divergence
        r = pearl_sgd(g, x0, tau=2, rounds=3000,
                      gamma=stepsize.gamma_constant(c, 2), stochastic=False)
        assert r.rel_errors[-1] < 1e-6

    def test_extragradient_converges(self, quad, x0):
        c = quad.constants()
        r = extragradient(quad, x0, steps=3000, gamma=0.5 / c.L_F,
                          stochastic=False)
        assert r.rel_errors[-1] < 1e-8

    def test_pearl_eg_converges(self, quad, x0):
        c = quad.constants()
        gamma = stepsize.gamma_constant(c, 4)
        r = pearl_eg(quad, x0, tau=4, rounds=1500, gamma=gamma, stochastic=False)
        assert r.rel_errors[-1] < r.rel_errors[0] * 0.1


class TestCompressedSync:
    """Beyond-paper: bf16 compressed broadcast (the paper's Section 3.1
    compression future-work) composed with local steps."""

    def test_bf16_sync_same_plateau(self, quad, x0):
        c = quad.constants()
        gamma = stepsize.gamma_constant(c, 4)
        full = pearl_sgd(quad, x0, tau=4, rounds=1500, gamma=gamma,
                         key=jax.random.PRNGKey(0))
        comp = pearl_sgd(quad, x0, tau=4, rounds=1500, gamma=gamma,
                         key=jax.random.PRNGKey(0), sync_dtype=jnp.bfloat16)
        p_full = final_plateau(full.rel_errors, 100)
        p_comp = final_plateau(comp.rel_errors, 100)
        # quantization noise is absorbed by the Thm 3.4 sigma^2 term
        assert p_comp < 1.5 * p_full

    def test_bf16_sync_deterministic_still_converges(self, quad, x0):
        c = quad.constants()
        gamma = stepsize.gamma_constant(c, 2)
        r = pearl_sgd(quad, x0, tau=2, rounds=2000, gamma=gamma,
                      stochastic=False, sync_dtype=jnp.bfloat16)
        # converges to the bf16-resolution neighborhood of x*
        assert r.rel_errors[-1] < 1e-3


class TestNonCocoerciveStress:
    def test_pearl_converges_without_lipschitzness(self):
        g = make_noncoco_game(n=6, mu=0.5, ell=4.0)
        c = g.constants()
        x0 = 3.0 * jnp.ones((6, 1))
        r = pearl_sgd(g, x0, tau=4, rounds=400,
                      gamma=stepsize.gamma_constant(c, 4), stochastic=False)
        assert r.rel_errors[-1] < 1e-6
