"""Per-architecture smoke tests on reduced variants (deliverable f).

For each of the 10 assigned architectures: instantiate the reduced
(2-layer, d_model<=512, <=4-expert) variant, run one forward and one train
step on CPU, assert output shapes and absence of NaNs, and check
prefill+decode consistency against the full forward (including the
sliding-window ring-buffer path).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import decode_step, forward, init_params, prefill
from repro.optim.optimizers import sgd
from repro.train.train_step import make_train_step

B, S = 2, 33


def _batch(cfg, key, seq=S):
    toks = jax.random.randint(key, (B, seq), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.modality == "vision":
        batch["tokens"] = toks[:, : seq - cfg.n_modality_tokens]
        batch["patch_embeds"] = 0.1 * jax.random.normal(
            jax.random.fold_in(key, 9),
            (B, cfg.n_modality_tokens, cfg.d_model),
        )
    if cfg.enc_layers:
        batch["enc_frames"] = 0.1 * jax.random.normal(
            jax.random.fold_in(key, 8), (B, 16, cfg.d_model)
        )
    return batch


@pytest.fixture(scope="module", params=ARCH_IDS)
def arch_setup(request):
    cfg = get_config(request.param).smoke_variant()
    params = init_params(cfg, jax.random.PRNGKey(0))
    return request.param, cfg, params


class TestSmokeVariants:
    def test_reduced_limits(self, arch_setup):
        _, cfg, _ = arch_setup
        assert cfg.n_layers <= 2
        assert cfg.d_model <= 512
        assert cfg.n_experts <= 4

    def test_forward_shapes_and_finite(self, arch_setup):
        arch, cfg, params = arch_setup
        batch = _batch(cfg, jax.random.PRNGKey(1))
        out = forward(params, cfg, batch)
        s_total = S if cfg.modality != "vision" else S
        assert out["logits"].shape == (B, s_total, cfg.vocab_size)
        assert bool(jnp.isfinite(out["logits"]).all()), arch

    def test_one_train_step(self, arch_setup):
        arch, cfg, params = arch_setup
        opt = sgd(1e-2)
        step = jax.jit(make_train_step(cfg, opt))
        opt_state = opt.init(params)
        batch = _batch(cfg, jax.random.PRNGKey(2))
        new_params, _, metrics = step(params, opt_state, batch)
        assert bool(jnp.isfinite(metrics["total_loss"])), arch
        assert float(metrics["grad_norm"]) > 0.0
        # params actually moved
        moved = any(
            float(jnp.max(jnp.abs(a - b))) > 0
            for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params))
        )
        assert moved

    def test_loss_decreases_over_steps(self, arch_setup):
        """A few steps on a repeated batch must reduce the loss (learnable)."""
        arch, cfg, params = arch_setup
        opt = sgd(5e-2)
        step = jax.jit(make_train_step(cfg, opt, clip_norm=1.0))
        opt_state = opt.init(params)
        batch = _batch(cfg, jax.random.PRNGKey(3))
        first = last = None
        for i in range(8):
            params, opt_state, metrics = step(params, opt_state, batch)
            if first is None:
                first = float(metrics["lm_loss"])
            last = float(metrics["lm_loss"])
        assert last < first, f"{arch}: {first} -> {last}"

    def test_prefill_decode_matches_forward(self, arch_setup):
        arch, cfg, params = arch_setup
        batch = _batch(cfg, jax.random.PRNGKey(4))
        toks = batch["tokens"]
        batch_pre = dict(batch, tokens=toks[:, :-1])
        full = forward(params, cfg, batch)["logits"][:, -1]
        _, cache = prefill(params, cfg, batch_pre, capacity=64)
        dec, cache2 = decode_step(params, cfg, cache, toks[:, -1:])
        np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                                   atol=2e-4, rtol=2e-4)
        assert int(cache2["length"]) == int(cache["length"]) + 1

    def test_multi_token_decode_matches_forward(self, arch_setup):
        """Decode 4 tokens one-by-one == full forward at those positions."""
        arch, cfg, params = arch_setup
        batch = _batch(cfg, jax.random.PRNGKey(5))
        toks = batch["tokens"]
        n_dec = 4
        batch_pre = dict(batch, tokens=toks[:, :-n_dec])
        full = forward(params, cfg, batch)["logits"]
        _, cache = prefill(params, cfg, batch_pre, capacity=64)
        text_off = cfg.n_modality_tokens if cfg.modality == "vision" else 0
        for i in range(n_dec):
            t = toks[:, -n_dec + i : toks.shape[1] - n_dec + i + 1]
            dec, cache = decode_step(params, cfg, cache, t)
            pos = text_off + toks.shape[1] - n_dec + i
            np.testing.assert_allclose(
                np.asarray(dec), np.asarray(full[:, pos]),
                atol=5e-4, rtol=5e-4, err_msg=f"{arch} step {i}",
            )


class TestSlidingWindowDecode:
    """Ring-buffer cache wrap-around for windowed attention (long_500k path)."""

    @pytest.mark.parametrize("arch", ["granite-34b", "zamba2-1.2b"])
    def test_ring_buffer_wraparound(self, arch):
        cfg = get_config(arch).smoke_variant()
        window = 16
        params = init_params(cfg, jax.random.PRNGKey(0))
        key = jax.random.PRNGKey(6)
        seq = 40  # > window so the ring wraps
        toks = jax.random.randint(key, (B, seq), 0, cfg.vocab_size)
        full = forward(params, cfg, {"tokens": toks}, window=window)
        _, cache = prefill(params, cfg, {"tokens": toks[:, :-1]},
                           capacity=window, window=window)
        dec, _ = decode_step(params, cfg, cache, toks[:, -1:], window=window)
        np.testing.assert_allclose(np.asarray(dec),
                                   np.asarray(full["logits"][:, -1]),
                                   atol=2e-4, rtol=2e-4)


class TestLongContextEligibility:
    def test_every_arch_serves_long_context(self):
        """DESIGN.md: every assigned arch must run long_500k, natively (SSM/
        hybrid) or via the sliding-window variant (attention archs)."""
        for arch in ARCH_IDS:
            cfg = get_config(arch)
            ok, why = cfg.supports_long_decode()
            assert ok, f"{arch}: {why}"

    def test_layer_type_counts(self):
        assert get_config("zamba2-1.2b").layer_types().count("attn") == 6
        assert get_config("xlstm-125m").layer_types().count("slstm") == 3
        lt = get_config("llama4-maverick-400b-a17b").layer_types()
        assert lt.count("moe") == 24 and lt.count("attn") == 24
        assert get_config("qwen3-moe-30b-a3b").layer_types() == ("moe",) * 48
