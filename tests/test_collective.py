"""Sharded-collective tests: the explicit wire, verified at the HLO level.

The multi-device cases need a fake mesh — the CI ``multi-device`` job (and
``scripts/ci.sh``) runs this file under
``XLA_FLAGS=--xla_force_host_platform_device_count=8``; on a single device
they skip. The HLO-text parsing tests run everywhere.

What is pinned here, per the acceptance criteria:

- the dry-run HLO of the sharded *quantized* sync contains a cross-player
  collective with a 2-byte operand (the bf16 payload shipped as u16 bits),
  while the exact-sync lowering moves only f32 and the legacy no-mesh
  lowering contains no collectives at all;
- the mesh-lowered star collective matches the host ``tree_mean`` EXACTLY
  in f32 (same gathered buffer, same reduction order on every device) and
  within bounded quantization noise in bf16;
- engine trajectories under mesh lowering track the host path (star and
  ring gossip, f32 and bf16);
- the trainer's general stale-block merge lowers too (masks, graph
  topologies): bitwise host/mesh agreement on the exact wire, masked
  players' payload slots all-zero bits, the wire dtype pinned in HLO;
- the remaining invalid compositions (engine masks, joint updates,
  non-dividing player counts, error-feedback low-bit x general round) are
  rejected loudly.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import collective
from repro.core.engine import (
    ExactSync,
    Int4Sync,
    Int8Sync,
    JointExtragradientUpdate,
    PartialParticipation,
    PearlEngine,
    QuantizedSync,
)
from repro.core.games import make_quadratic_game
from repro.core import stepsize
from repro.core.topology import ErdosRenyi, Ring
from repro.train.pearl_trainer import tree_mean

multi_device = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs a multi-device (fake) mesh: run under "
           "XLA_FLAGS=--xla_force_host_platform_device_count=8",
)

N = 6   # players; divisible meshes exist for 2, 3, 6 devices


@pytest.fixture(scope="module")
def mesh():
    if jax.device_count() < 2:
        pytest.skip("single device")
    return collective.player_mesh(N)


@pytest.fixture(scope="module")
def game_setup():
    game = make_quadratic_game(n=N, d=10, M=40, L_B=1.0, batch_size=1,
                               seed=0)
    gamma = stepsize.gamma_constant(game.constants(), 4)
    x0 = jnp.asarray(
        np.random.default_rng(0).standard_normal((N, 10)), jnp.float32)
    return game, gamma, x0


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w": jnp.asarray(rng.standard_normal((N, 8, 3)), jnp.float32),
        "b": jnp.asarray(rng.standard_normal((N, 5)), jnp.float32),
    }


# =========================================================================
# HLO parsing (single-device safe)
# =========================================================================
class TestWireReport:
    HLO = """
  %all-gather.1 = u16[8,16]{1,0} all-gather(u16[1,16]{1,0} %fusion.1)
  %all-reduce.2 = f32[1,16]{1,0} all-reduce(f32[1,16]{1,0} %param.2)
  %collective-permute.1 = bf16[4]{0} collective-permute(bf16[4]{0} %p)
"""

    def test_operand_dtypes_and_bytes(self):
        ops = collective.wire_dtype_report(self.HLO)
        assert [(o.op, o.operand_dtype) for o in ops] == [
            ("all-gather", "u16"),
            ("all-reduce", "f32"),
            ("collective-permute", "bf16"),
        ]
        assert ops[0].operand_bytes == 16 * 2
        assert ops[1].operand_bytes == 16 * 4

    def test_compressed_filter_and_asserts(self):
        small = collective.compressed_wire_ops(self.HLO)
        assert {o.op for o in small} == {"all-gather", "collective-permute"}
        collective.assert_wire_dtype(self.HLO, compressed=True)
        with pytest.raises(AssertionError, match="compressed"):
            collective.assert_wire_dtype(self.HLO, compressed=False)
        f32_only = "\n".join(l for l in self.HLO.splitlines() if "f32" in l)
        collective.assert_wire_dtype(f32_only, compressed=False)
        with pytest.raises(AssertionError, match="expected"):
            collective.assert_wire_dtype(f32_only, compressed=True)

    def test_legacy_host_tree_mean_has_no_collectives(self):
        """The no-mesh path must compile collective-free: the pin that
        mesh=None left the legacy program untouched."""
        t = _tree()
        for kwargs in ({}, {"sync_dtype": jnp.bfloat16}):
            hlo = jax.jit(
                lambda x, kw=kwargs: tree_mean(x, **kw)
            ).lower(t).compile().as_text()
            assert collective.wire_dtype_report(hlo) == []


# =========================================================================
# Mesh construction
# =========================================================================
class TestPlayerMesh:
    @multi_device
    def test_sizes_to_largest_divisor(self):
        m = collective.player_mesh(N)
        assert N % m.shape[collective.PLAYER_AXIS] == 0
        assert m.shape[collective.PLAYER_AXIS] > 1

    @multi_device
    def test_prime_player_count_beyond_devices_raises(self):
        prime = 1009   # no divisor >= 2 fits any plausible fake mesh
        with pytest.raises(ValueError, match="XLA_FLAGS"):
            collective.player_mesh(prime)

    def test_rejects_degenerate_counts(self):
        with pytest.raises(ValueError, match="n_players"):
            collective.player_mesh(0)

    @multi_device
    def test_uneven_player_dim_rejected(self, mesh):
        size = mesh.shape[collective.PLAYER_AXIS]
        bad = jnp.zeros((size + 1, 4), jnp.float32)
        with pytest.raises(ValueError, match="divide"):
            collective.sharded_tree_mean({"w": bad}, mesh=mesh)


# =========================================================================
# The star collective: exact f32, bounded bf16, explicit wire dtype
# =========================================================================
@multi_device
class TestShardedTreeMean:
    def test_f32_bitwise_matches_host(self, mesh):
        t = _tree()
        host = tree_mean(t)
        shard = tree_mean(t, mesh=mesh)
        for k in t:
            np.testing.assert_array_equal(np.asarray(host[k]),
                                          np.asarray(shard[k]))

    def test_bf16_within_quantization_noise(self, mesh):
        t = _tree()
        host = tree_mean(t)  # exact mean, the ground truth
        shard = tree_mean(t, sync_dtype=jnp.bfloat16, mesh=mesh)
        host_q = tree_mean(t, sync_dtype=jnp.bfloat16)
        eps = 2.0 ** -8   # bf16 relative step
        for k in t:
            scale = float(np.abs(np.asarray(t[k])).max())
            # vs the exact mean: bounded by the quantization step
            assert float(np.abs(np.asarray(host[k])
                                - np.asarray(shard[k])).max()) <= eps * scale
            # vs the host quantized mean: only accumulation-order noise left
            assert float(np.abs(np.asarray(host_q[k])
                                - np.asarray(shard[k])).max()) <= eps * scale

    def test_quantized_wire_is_two_byte_in_hlo(self, mesh):
        t = _tree()
        hlo = jax.jit(
            lambda x: tree_mean(x, sync_dtype=jnp.bfloat16, mesh=mesh)
        ).lower(t).compile().as_text()
        report = collective.assert_wire_dtype(hlo, compressed=True)
        assert any(o.operand_dtype in ("u16", "bf16") for o in report)

    def test_exact_wire_stays_f32_in_hlo(self, mesh):
        t = _tree()
        hlo = jax.jit(
            lambda x: tree_mean(x, mesh=mesh)
        ).lower(t).compile().as_text()
        report = collective.assert_wire_dtype(hlo, compressed=False)
        assert report, "the mesh lowering must move an explicit collective"
        assert {o.operand_dtype for o in report} == {"f32"}

    def test_sync_changes_only_the_wire_dtype(self, mesh):
        """The satellite pin: QuantizedSync x shard_map changes the HLO
        collective dtype; the f32 path does not."""
        t = _tree()

        def dtypes(**kw):
            hlo = jax.jit(
                lambda x: tree_mean(x, mesh=mesh, **kw)
            ).lower(t).compile().as_text()
            return {o.operand_dtype
                    for o in collective.wire_dtype_report(hlo)}

        assert dtypes() == {"f32"}
        assert dtypes(sync_dtype=jnp.bfloat16) == {"u16"}

    def test_mask_strategies_rejected(self, mesh):
        with pytest.raises(ValueError, match="full-participation"):
            collective.sharded_tree_mean(
                _tree(), mesh=mesh, sync=PartialParticipation(fraction=0.5))

    def test_non_leading_axis_rejected(self, mesh):
        with pytest.raises(ValueError, match="axis"):
            tree_mean(_tree(), axis=1, mesh=mesh)


# =========================================================================
# Low-bit wire: the single-u8-payload codec through the collectives
# =========================================================================
class TestLowBitSpec:
    def test_lowbit_syncs_get_the_codec(self):
        for sync in (Int8Sync(), Int4Sync(), Int8Sync(error_feedback=False)):
            spec = collective.wire_spec(sync)
            assert isinstance(spec, collective.LowBitCodec)

    def test_codec_encode_decode_matches_strategy(self):
        x = jnp.asarray(
            np.random.default_rng(0).standard_normal((N, 16)), jnp.float32)
        for sync in (Int8Sync(), Int4Sync()):
            spec = collective.wire_spec(sync)
            payload = spec.encode(x)
            assert payload.dtype == jnp.uint8
            np.testing.assert_array_equal(
                np.asarray(spec.decode(payload, x.dtype)),
                np.asarray(sync.roundtrip(x)))

    def test_cpu_has_no_native_bf16_collective(self):
        # the CPU backend float-normalizes bf16 collective buffers (the PR 1
        # negative result) — the probe must say so, keeping the bit-pattern
        # container in play; single-device hosts trivially have no wire
        assert collective.native_collective_dtype("bfloat16") is False


@multi_device
class TestLowBitWire:
    def test_star_wire_flips_f32_to_u8(self, mesh):
        """The satellite pin, one tier lower than bf16: Int8/Int4Sync x
        shard_map move a SINGLE u8 collective operand — scales ride inside
        the payload, no f32 side channel for a compiler pass to re-widen."""
        x = jnp.asarray(
            np.random.default_rng(1).standard_normal((N, 16)), jnp.float32)

        def dtypes(sync):
            hlo = jax.jit(
                lambda t: collective.sharded_joint_wire(t, mesh=mesh,
                                                        sync=sync)
            ).lower(x).compile().as_text()
            collective.assert_wire_dtype(
                hlo, compressed=not isinstance(sync, ExactSync))
            return {o.operand_dtype
                    for o in collective.wire_dtype_report(hlo)}

        assert dtypes(ExactSync()) == {"f32"}
        assert dtypes(Int8Sync()) == {"u8"}
        assert dtypes(Int4Sync()) == {"u8"}

    def test_wire_roundtrip_matches_host_bitwise(self, mesh):
        """The mesh wire IS the quantizer: gather-decode must equal the
        host ``roundtrip`` exactly, so host/mesh trajectory comparisons
        are about fusion order, never about the codec."""
        x = jnp.asarray(
            np.random.default_rng(2).standard_normal((N, 16)) * 5,
            jnp.float32)
        for sync in (Int8Sync(), Int4Sync()):
            out = collective.sharded_joint_wire(x, mesh=mesh, sync=sync)
            np.testing.assert_array_equal(np.asarray(out),
                                          np.asarray(sync.roundtrip(x)))


# =========================================================================
# Engine lowering: star and gossip, trajectories and wire
# =========================================================================
@multi_device
class TestEngineMesh:
    def test_star_f32_tracks_host(self, game_setup, mesh):
        game, gamma, x0 = game_setup
        host = PearlEngine().run(game, x0, tau=4, rounds=60, gamma=gamma,
                                 stochastic=False)
        shard = PearlEngine(mesh=mesh).run(game, x0, tau=4, rounds=60,
                                           gamma=gamma, stochastic=False)
        # same values through the wire; only fusion-level (ULP) drift allowed
        np.testing.assert_allclose(np.asarray(shard.x_final),
                                   np.asarray(host.x_final),
                                   rtol=0, atol=1e-6)
        assert shard.rel_errors[-1] == pytest.approx(host.rel_errors[-1],
                                                     rel=1e-3, abs=1e-9)

    def test_star_bf16_bounded_quantization_noise(self, game_setup, mesh):
        game, gamma, x0 = game_setup
        sync = QuantizedSync(jnp.bfloat16)
        host = PearlEngine(sync=sync).run(game, x0, tau=4, rounds=60,
                                          gamma=gamma, stochastic=False)
        shard = PearlEngine(sync=sync, mesh=mesh).run(
            game, x0, tau=4, rounds=60, gamma=gamma, stochastic=False)
        np.testing.assert_allclose(np.asarray(shard.x_final),
                                   np.asarray(host.x_final),
                                   rtol=0, atol=5e-3)
        # both reach the same equilibrium neighborhood
        assert shard.rel_errors[-1] < 1e-4

    def test_ring_gossip_tracks_host(self, game_setup, mesh):
        game, gamma, x0 = game_setup
        for sync, atol in ((ExactSync(), 1e-6),
                           (QuantizedSync(jnp.bfloat16), 5e-3)):
            host = PearlEngine(topology=Ring(), sync=sync).run(
                game, x0, tau=4, rounds=60, gamma=gamma, stochastic=False)
            shard = PearlEngine(topology=Ring(), sync=sync, mesh=mesh).run(
                game, x0, tau=4, rounds=60, gamma=gamma, stochastic=False)
            np.testing.assert_allclose(np.asarray(shard.x_final),
                                       np.asarray(host.x_final),
                                       rtol=0, atol=atol)

    def test_byte_accounting_identical_across_lowerings(self, game_setup,
                                                        mesh):
        """The mesh changes the program, never the bill: per-round bytes
        must match the host run exactly."""
        game, gamma, x0 = game_setup
        sync = QuantizedSync(jnp.bfloat16)
        host = PearlEngine(sync=sync).run(game, x0, tau=4, rounds=10,
                                          gamma=gamma, stochastic=False)
        shard = PearlEngine(sync=sync, mesh=mesh).run(
            game, x0, tau=4, rounds=10, gamma=gamma, stochastic=False)
        np.testing.assert_array_equal(host.bytes_up, shard.bytes_up)
        np.testing.assert_array_equal(host.bytes_down, shard.bytes_down)

    def test_ring_lowers_to_collective_permute(self, mesh, game_setup):
        """Circulant graphs relay per neighbor edge, and the bf16 relay
        crosses as 2-byte bits."""
        if mesh.shape[collective.PLAYER_AXIS] != N:
            pytest.skip("permute lowering needs one player per device")
        V = jnp.zeros((N, N, 4), jnp.float32)
        ring = Ring()
        W = jnp.asarray(ring.mixing_matrix(N), jnp.float32)
        link_w = jnp.where(jnp.asarray(ring.adjacency(N)), W, 0.0)
        self_w = 1.0 - jnp.sum(link_w, axis=1)
        offsets = collective.circulant_offsets(ring.adjacency(N))
        assert offsets == (1, N - 1)
        hlo = jax.jit(
            lambda v, lw, sw: collective.sharded_mix_sweep(
                v, lw, sw, mesh=mesh, sync=QuantizedSync(jnp.bfloat16),
                offsets=offsets)
        ).lower(V, link_w, self_w).compile().as_text()
        report = collective.assert_wire_dtype(hlo, compressed=True)
        assert any(o.op == "collective-permute"
                   and o.operand_dtype in ("u16", "bf16") for o in report)

    def test_directed_circulant_permute_matches_dense_mix(self, mesh):
        """The permute lowering is direction-correct: receiver i takes
        V_{i+o} at weight link_w[i, i+o], so even a DIRECTED circulant
        (offsets not closed under negation) matches the dense einsum."""
        if mesh.shape[collective.PLAYER_AXIS] != N:
            pytest.skip("permute lowering needs one player per device")
        rng = np.random.default_rng(0)
        V = jnp.asarray(rng.standard_normal((N, N, 4)), jnp.float32)
        A = np.zeros((N, N), dtype=bool)
        A[np.arange(N), (np.arange(N) + 1) % N] = True   # directed cycle
        offsets = collective.circulant_offsets(A)
        assert offsets == (1,)
        link_w = jnp.asarray(np.where(A, 0.4, 0.0), jnp.float32)
        self_w = 1.0 - jnp.sum(link_w, axis=1)
        out = collective.sharded_mix_sweep(
            V, link_w, self_w, mesh=mesh, sync=ExactSync(), offsets=offsets)
        ref = (jnp.einsum("ij,jkd->ikd", link_w, V)
               + self_w[:, None, None] * V)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=0, atol=1e-6)

    def test_erdos_renyi_falls_back_to_gather_relay(self, game_setup, mesh):
        """Non-circulant graphs take the all-gather relay and still
        converge to the host trajectory."""
        game, gamma, x0 = game_setup
        topo = ErdosRenyi(p=0.5, seed=2)
        assert collective.circulant_offsets(topo.adjacency(N)) is None
        host = PearlEngine(topology=topo).run(
            game, x0, tau=4, rounds=40, gamma=gamma, stochastic=False)
        shard = PearlEngine(topology=topo, mesh=mesh).run(
            game, x0, tau=4, rounds=40, gamma=gamma, stochastic=False)
        np.testing.assert_allclose(np.asarray(shard.x_final),
                                   np.asarray(host.x_final),
                                   rtol=0, atol=1e-6)

    def test_mesh_rejects_masks_and_joint_updates(self, mesh):
        with pytest.raises(ValueError, match="mask"):
            PearlEngine(sync=PartialParticipation(fraction=0.5),
                        mesh=mesh)._check_topology()
        with pytest.raises(ValueError, match="joint"):
            PearlEngine(update=JointExtragradientUpdate(),
                        mesh=mesh)._check_topology()


# =========================================================================
# Trainer lowering
# =========================================================================
@multi_device
class TestTrainerMesh:
    @pytest.fixture(scope="class")
    def cfg(self):
        from repro.configs import get_config

        return get_config("smollm-360m").smoke_variant()

    def _stream(self, cfg, n_players):
        from repro.data.synthetic import DataConfig, SyntheticTokenStream

        return SyntheticTokenStream(DataConfig(
            vocab_size=cfg.vocab_size, seq_len=32, batch_size=2,
            n_players=n_players, seed=0,
        ))

    def test_star_round_matches_host_losses(self, cfg, mesh):
        from repro.optim.optimizers import sgd
        from repro.train.pearl_trainer import PearlTrainer

        host = PearlTrainer(cfg, sgd(5e-2), n_players=N, tau=2,
                            prox_lambda=1e-3, seed=2,
                            sync_dtype=jnp.bfloat16)
        h = host.run(self._stream(cfg, N), rounds=3)
        mesht = PearlTrainer(cfg, sgd(5e-2), n_players=N, tau=2,
                             prox_lambda=1e-3, seed=2,
                             sync_dtype=jnp.bfloat16, mesh=mesh)
        m = mesht.run(self._stream(cfg, N), rounds=3)
        for a, b in zip(h, m):
            assert a["lm_loss"] == pytest.approx(b["lm_loss"], rel=1e-4)

    def test_ring_general_round_compiles_and_tracks_host(self, cfg, mesh):
        """The PR 8 lowering: graph topology x mesh compiles the general
        stale-block merge under shard_map (it used to be rejected) and the
        bf16 trajectory stays within quantization/fusion noise of the host
        loop."""
        from repro.optim.optimizers import sgd
        from repro.train.pearl_trainer import PearlTrainer

        host = PearlTrainer(cfg, sgd(5e-2), n_players=N, tau=2,
                            prox_lambda=1e-3, seed=2, topology=Ring(),
                            sync_dtype=jnp.bfloat16)
        h = host.run(self._stream(cfg, N), rounds=3)
        mesht = PearlTrainer(cfg, sgd(5e-2), n_players=N, tau=2,
                             prox_lambda=1e-3, seed=2, topology=Ring(),
                             sync_dtype=jnp.bfloat16, mesh=mesh)
        m = mesht.run(self._stream(cfg, N), rounds=3)
        for a, b in zip(h, m):
            assert a["lm_loss"] == pytest.approx(b["lm_loss"], rel=1e-4)

    def test_masked_merge_compiles_and_bills_identically(self, cfg, mesh):
        """mesh x mask strategy: the exact-wire merge moves the same values
        (host/mesh diverge only at XLA fusion order around the shard_map
        boundary) and the byte accounting — billed host-side off the drawn
        masks — is identical across lowerings."""
        from repro.optim.optimizers import sgd
        from repro.train.pearl_trainer import PearlTrainer

        def build(**kw):
            return PearlTrainer(cfg, sgd(5e-2), n_players=N, tau=2,
                                prox_lambda=1e-3, seed=2,
                                sync=PartialParticipation(fraction=0.5,
                                                          seed=7), **kw)

        host = build()
        h = host.run(self._stream(cfg, N), rounds=3)
        mesht = build(mesh=mesh)
        m = mesht.run(self._stream(cfg, N), rounds=3)
        for a, b in zip(h, m):
            assert a["lm_loss"] == pytest.approx(b["lm_loss"], rel=1e-5)
        hr, mr = host.comm_report(), mesht.comm_report()
        np.testing.assert_array_equal(np.stack(hr.per_round_bytes()),
                                      np.stack(mr.per_round_bytes()))

    def test_ef_lowbit_general_round_still_rejected(self, cfg, mesh):
        """The one general-round composition that stays rejected: an
        error-feedback low-bit wire has no per-player residual carry in the
        stale-block merge (stateless error_feedback=False is the supported
        spelling)."""
        from repro.optim.optimizers import sgd
        from repro.train.pearl_trainer import PearlTrainer

        with pytest.raises(ValueError, match="error_feedback=False"):
            PearlTrainer(cfg, sgd(5e-2), n_players=N, tau=2,
                         prox_lambda=1e-3, topology=Ring(), mesh=mesh,
                         sync=Int8Sync())


# =========================================================================
# The general stale-block merge, lowered
# =========================================================================
class TestMaskedPayload:
    def test_masked_rows_are_zero_bits(self):
        """The zero-payload claim, at its testable surface: a masked
        player's slot in the wire buffer is all-zero bits, for the raw f32
        wire and for every encoded container."""
        x = jnp.asarray(
            np.random.default_rng(3).standard_normal((N, 16)) * 3,
            jnp.float32)
        mask = jnp.asarray([True, False, True, False, False, True])
        for sync in (ExactSync(), QuantizedSync(jnp.bfloat16), Int8Sync(
                error_feedback=False), Int4Sync(error_feedback=False)):
            payload = collective.masked_payload(
                x, mask, collective.wire_spec(sync))
            rows = np.asarray(payload)
            masked = rows[~np.asarray(mask)]
            assert not masked.any(), f"{type(sync).__name__} leaked bits"
            kept = rows[np.asarray(mask)]
            assert kept.any()


@multi_device
class TestShardedStaleMerge:
    def _state(self, seed=0):
        rng = np.random.default_rng(seed)
        mk = lambda: {
            "w": jnp.asarray(rng.standard_normal((N, 8, 3)), jnp.float32),
            "b": jnp.asarray(rng.standard_normal((N, 5)), jnp.float32),
        }
        mask = jnp.asarray(rng.random(N) < 0.6)
        mix = jnp.asarray(Ring().mixing_matrix(N), jnp.float32)
        return mk(), mk(), mk(), mask, mix

    def _host_merge(self, new_p, snapshot, refs, mask, mix, sync):
        wire = jax.tree.map(lambda p: sync.compress(p).astype(p.dtype),
                            new_p)
        per = lambda m, x: m.reshape((-1,) + (1,) * (x.ndim - 1))
        snap = jax.tree.map(lambda w, s: jnp.where(per(mask, w), w, s),
                            wire, snapshot)
        mixed = jax.tree.map(
            lambda s: jnp.einsum("ij,j...->i...", mix.astype(s.dtype), s),
            snap)
        new_refs = jax.tree.map(
            lambda mx, r: jnp.where(per(mask, mx), mx, r), mixed, refs)
        return new_refs, snap

    @pytest.mark.parametrize("sync", [ExactSync(),
                                      QuantizedSync(jnp.bfloat16),
                                      Int8Sync(error_feedback=False)])
    def test_matches_host_semantics(self, mesh, sync):
        new_p, snapshot, refs, mask, mix = self._state()
        href, hsnap = self._host_merge(new_p, snapshot, refs, mask, mix,
                                       sync)
        mref, msnap = collective.sharded_stale_merge(
            new_p, snapshot, refs, mask, mix, mesh=mesh, sync=sync)
        for k in new_p:
            # decode(encode(x)) is bit-identical to compress(x).astype, and
            # the merge/mix reduce the same rows in the same order — bitwise
            np.testing.assert_array_equal(np.asarray(hsnap[k]),
                                          np.asarray(msnap[k]))
            np.testing.assert_array_equal(np.asarray(href[k]),
                                          np.asarray(mref[k]))

    def test_wire_dtype_in_hlo(self, mesh):
        new_p, snapshot, refs, mask, mix = self._state()

        def dtypes(sync):
            hlo = jax.jit(
                lambda *a: collective.sharded_stale_merge(
                    *a, mesh=mesh, sync=sync)
            ).lower(new_p, snapshot, refs, mask, mix).compile().as_text()
            return {o.operand_dtype
                    for o in collective.wire_dtype_report(hlo)
                    if o.op == "all-gather"}

        assert dtypes(ExactSync()) == {"f32"}
        assert dtypes(QuantizedSync(jnp.bfloat16)) == {"u16"}
        assert dtypes(Int8Sync(error_feedback=False)) == {"u8"}
