"""Runtime substrate tests: optimizers, data pipeline, checkpointing, serving."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import latest_step, restore_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.data.synthetic import DataConfig, SyntheticTokenStream
from repro.models import init_params
from repro.optim.optimizers import (
    adamw,
    apply_updates,
    clip_by_global_norm,
    cosine_schedule,
    global_norm,
    pearl_local_schedule,
    sgd,
)
from repro.serve.decode import generate


class TestOptimizers:
    def _quadratic(self, opt, steps=200):
        target = jnp.asarray([1.0, -2.0, 3.0])
        params = {"w": jnp.zeros(3)}
        state = opt.init(params)

        for _ in range(steps):
            grads = {"w": 2 * (params["w"] - target)}
            updates, state = opt.update(grads, state, params)
            params = apply_updates(params, updates)
        return float(jnp.max(jnp.abs(params["w"] - target)))

    def test_sgd_converges(self):
        assert self._quadratic(sgd(0.1)) < 1e-4

    def test_sgd_momentum_converges(self):
        assert self._quadratic(sgd(0.05, momentum=0.9)) < 1e-4

    def test_adamw_converges(self):
        assert self._quadratic(adamw(0.1), steps=400) < 1e-2

    def test_clip_by_global_norm(self):
        grads = {"a": jnp.full((10,), 100.0)}
        clipped = clip_by_global_norm(grads, 1.0)
        assert float(global_norm(clipped)) <= 1.0 + 1e-5

    def test_cosine_schedule(self):
        fn = cosine_schedule(1.0, warmup=10, total=100)
        assert float(fn(jnp.asarray(0))) == 0.0
        assert float(fn(jnp.asarray(10))) == pytest.approx(1.0)
        assert float(fn(jnp.asarray(100))) == pytest.approx(0.1, abs=1e-6)

    def test_pearl_local_schedule_round_constant(self):
        """Matches Thm 3.6: gamma changes only at synchronization boundaries."""
        gammas = np.array([0.1, 0.05, 0.025])
        fn = pearl_local_schedule(gammas, tau=4)
        vals = [float(fn(jnp.asarray(k))) for k in range(12)]
        assert vals[:4] == [pytest.approx(0.1)] * 4
        assert vals[4:8] == [pytest.approx(0.05)] * 4
        assert vals[8:] == [pytest.approx(0.025)] * 4


class TestSyntheticData:
    def test_deterministic(self):
        cfg = DataConfig(vocab_size=100, seq_len=16, batch_size=4, n_players=3)
        s1 = SyntheticTokenStream(cfg)
        s2 = SyntheticTokenStream(cfg)
        np.testing.assert_array_equal(s1.batch(1, 5), s2.batch(1, 5))

    def test_heterogeneous_players(self):
        """Different players must have different marginals (non-iid)."""
        cfg = DataConfig(vocab_size=50, seq_len=64, batch_size=16, n_players=2)
        s = SyntheticTokenStream(cfg)
        h0 = np.bincount(s.batch(0, 0).ravel(), minlength=50)
        h1 = np.bincount(s.batch(1, 0).ravel(), minlength=50)
        # total-variation distance between empirical marginals
        tv = 0.5 * np.abs(h0 / h0.sum() - h1 / h1.sum()).sum()
        assert tv > 0.3

    def test_shapes_and_range(self):
        cfg = DataConfig(vocab_size=64, seq_len=8, batch_size=3, n_players=2)
        s = SyntheticTokenStream(cfg)
        batch = s.player_batches(0)
        assert batch.shape == (2, 3, 8)
        assert batch.min() >= 0 and batch.max() < 64


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        state = {
            "params": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                       "nested": {"b": jnp.ones((4,), jnp.bfloat16)}},
            "opt": {"count": jnp.asarray(7, jnp.int32)},
        }
        save_checkpoint(str(tmp_path), 42, state)
        assert latest_step(str(tmp_path)) == 42
        restored = restore_checkpoint(str(tmp_path), 42, state)
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))
            assert a.dtype == b.dtype

    def test_latest_of_many(self, tmp_path):
        for step in (1, 5, 3):
            save_checkpoint(str(tmp_path), step, {"x": {"v": jnp.zeros(2)}})
        assert latest_step(str(tmp_path)) == 5


class TestServe:
    def test_generate_greedy_deterministic(self):
        cfg = get_config("smollm-360m").smoke_variant()
        params = init_params(cfg, jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                  cfg.vocab_size)
        out1 = generate(params, cfg, {"tokens": toks}, max_new_tokens=5,
                        capacity=64)
        out2 = generate(params, cfg, {"tokens": toks}, max_new_tokens=5,
                        capacity=64)
        assert out1.shape == (2, 5)
        np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
        assert int(out1.max()) < cfg.vocab_size

    def test_generate_recurrent_arch(self):
        cfg = get_config("xlstm-125m").smoke_variant()
        params = init_params(cfg, jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (1, 12), 0,
                                  cfg.vocab_size)
        out = generate(params, cfg, {"tokens": toks}, max_new_tokens=4,
                       capacity=32)
        assert out.shape == (1, 4)
