"""Unit tests for the paper's game constructions (Sections 4.1, 4.2, B, F.2)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.games import (
    make_counterexample_game,
    make_noncoco_game,
    make_quadratic_game,
    make_robot_game,
)

@pytest.fixture(scope="module", autouse=True)
def _x64():
    """float64 for the game dynamics — scoped so it can't leak into other
    test modules (bf16/int32 model paths break under global x64)."""
    old = jax.config.read("jax_enable_x64")
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", old)


@pytest.fixture(scope="module")
def quad(_x64):
    return make_quadratic_game(n=4, d=6, M=20, seed=3)


class TestQuadraticGame:
    def test_equilibrium_is_zero_of_operator(self, quad):
        res = jnp.linalg.norm(quad.operator(quad.equilibrium()))
        assert float(res) < 1e-8

    def test_operator_matches_autodiff(self, quad):
        """F must equal the per-player autodiff gradients of the objectives."""
        x = jnp.asarray(np.random.default_rng(0).standard_normal((quad.n, quad.d)))
        F = quad.operator(x)
        for i in range(quad.n):
            gi = jax.grad(lambda xi: quad.objective(i, x.at[i].set(xi)))(x[i])
            np.testing.assert_allclose(np.asarray(F[i]), np.asarray(gi), atol=1e-8)

    def test_antisymmetric_coupling_cancels_in_monotonicity(self, quad):
        """<F(x)-F(y), x-y> >= mu ||x-y||^2 with mu = min eig of the A blocks."""
        c = quad.constants()
        rng = np.random.default_rng(1)
        for _ in range(10):
            x = jnp.asarray(rng.standard_normal((quad.n, quad.d)))
            y = jnp.asarray(rng.standard_normal((quad.n, quad.d)))
            lhs = float(jnp.sum((quad.operator(x) - quad.operator(y)) * (x - y)))
            rhs = c.mu * float(jnp.sum((x - y) ** 2))
            assert lhs >= rhs - 1e-8

    def test_stochastic_oracle_unbiased(self, quad):
        x = jnp.asarray(np.random.default_rng(2).standard_normal((quad.n, quad.d)))
        full = quad.operator(x)
        keys = jax.random.split(jax.random.PRNGKey(0), 4000)
        samples = jax.vmap(lambda k: quad.operator_stoch(x, k))(keys)
        np.testing.assert_allclose(
            np.asarray(jnp.mean(samples, axis=0)), np.asarray(full),
            atol=5e-2, rtol=5e-2,
        )

    def test_weak_coupling_regime(self, quad):
        """The §F.1 regime L_max << ell must hold for the default instance."""
        c = quad.constants()
        assert c.L_max < c.ell / 10
        assert c.q < 1.0


class TestRobotGame:
    def test_equilibrium(self):
        g = make_robot_game()
        res = jnp.linalg.norm(g.operator(g.equilibrium()))
        assert float(res) < 1e-10

    def test_grad_matches_autodiff(self):
        g = make_robot_game()
        x = jnp.asarray(np.random.default_rng(0).standard_normal((5, 1)))
        F = g.operator(x)
        for i in range(5):
            gi = jax.grad(lambda xi: g.objective(i, x.at[i].set(xi)))(x[i])
            np.testing.assert_allclose(np.asarray(F[i]), np.asarray(gi), atol=1e-10)

    def test_paper_coefficients(self):
        g = make_robot_game()
        np.testing.assert_allclose(np.asarray(g.a_coef), 10.0 + np.arange(1, 6) / 6.0)
        np.testing.assert_allclose(np.asarray(g.b_coef), np.arange(1, 6) / 6.0)
        assert np.asarray(g.h).shape == (5, 5, 1)
        # h is antisymmetric in the paper's table
        h = np.asarray(g.h)[:, :, 0]
        np.testing.assert_allclose(h, -h.T)

    def test_noise_variance(self):
        g = make_robot_game(sigma=10.0)
        x = jnp.zeros((5, 1))
        keys = jax.random.split(jax.random.PRNGKey(1), 5000)
        det = g.player_grad(jnp.asarray(0), x[0], x)
        samp = jax.vmap(lambda k: g.player_grad_stoch(jnp.asarray(0), x[0], x, k))(keys)
        var = float(jnp.var(samp - det))
        assert abs(var - 100.0) / 100.0 < 0.1


class TestNonCocoGame:
    def test_qsm_and_sco_hold(self):
        """Numerically check <F(x), x-x*> >= mu||x-x*||^2 and >= ||F(x)||^2/ell."""
        g = make_noncoco_game(n=5, mu=0.5, ell=4.0)
        rng = np.random.default_rng(0)
        for _ in range(50):
            x = jnp.asarray(rng.uniform(-10, 10, size=(5, 1)))
            F = g.operator(x)
            inner = float(jnp.sum(F * x))
            assert inner >= 0.5 * float(jnp.sum(x**2)) - 1e-9
            assert inner >= float(jnp.sum(F**2)) / 4.0 - 1e-9

    def test_not_lipschitz(self):
        """Cross-sensitivity of F grows with ||x|| — F is non-Lipschitz."""
        g = make_noncoco_game(n=2, mu=0.5, ell=4.0)

        def ratio(scale):
            x = jnp.asarray([[scale], [0.7]])
            y = jnp.asarray([[scale], [0.7 + 1e-4]])
            return float(
                jnp.linalg.norm(g.operator(x) - g.operator(y))
                / jnp.linalg.norm(x - y)
            )

        assert ratio(1e4) > 100 * ratio(1.0)


class TestCounterexampleGame:
    def test_equilibrium(self):
        g = make_counterexample_game()
        res = jnp.linalg.norm(g.operator(g.equilibrium()))
        assert float(res) < 1e-10

    def test_sum_gradient_couplings_cancel(self):
        """grad of (f1+f2)/2 must not depend on the bilinear coupling B."""
        g = make_counterexample_game(coupling=5.0, seed=1)
        g0 = make_counterexample_game(coupling=0.0, seed=1)
        x = jnp.asarray(np.random.default_rng(0).standard_normal((2, g.d)))
        np.testing.assert_allclose(
            np.asarray(g.sum_gradient(x)), np.asarray(g0.sum_gradient(x)), atol=1e-12
        )

    def test_sum_gradient_matches_autodiff(self):
        g = make_counterexample_game(seed=2)
        x = jnp.asarray(np.random.default_rng(3).standard_normal((2, g.d)))

        def fsum(xx):
            return 0.5 * (g.objective(0, xx) + g.objective(1, xx))

        np.testing.assert_allclose(
            np.asarray(g.sum_gradient(x)), np.asarray(jax.grad(fsum)(x)), atol=1e-10
        )

    def test_divergent_instance(self):
        """Default instance has lambda_min(A) < 1/10 -> sum-dynamics diverge."""
        g = make_counterexample_game()
        lam = np.linalg.eigvalsh(np.asarray(g.A)).min()
        assert lam < 0.1
