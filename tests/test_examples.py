"""Example smoke tests: both drivers run end to end with tiny settings.

The examples are the repo's user-facing entry points — these smokes pin that
they stay runnable as the trainer/serving APIs evolve (PR 8 ported both onto
NeuralPlayerAdapter). Single-device safe; on a fake mesh the same code paths
land on the two-axis mesh.
"""

import sys

import numpy as np
import pytest

sys.path.insert(0, "examples")


class TestFederatedLmGame:
    def test_smoke_runs_and_reports(self, capsys):
        from federated_lm_game import main

        adapter = main(["--steps", "4", "--tau", "2", "--players", "2",
                        "--seq", "32", "--batch", "2", "--no-kernels"])
        out = capsys.readouterr().out
        assert "lm_loss" in out and "communication ledger" in out
        assert adapter.trainer.history
        assert np.isfinite(adapter.trainer.history[-1]["lm_loss"])

    def test_masked_ring_smoke(self, capsys):
        from federated_lm_game import main

        adapter = main(["--steps", "4", "--tau", "2", "--players", "3",
                        "--seq", "32", "--batch", "2", "--no-kernels",
                        "--topology", "ring", "--participation", "0.7"])
        out = capsys.readouterr().out
        assert "ring topology" in out
        # mask-aware billing: the ledger reflects the drawn masks
        assert adapter.comm_report().total_bytes >= 0

    def test_participation_composes_only_with_exact(self):
        from federated_lm_game import main

        with pytest.raises(SystemExit):
            main(["--sync", "int8", "--participation", "0.5"])


class TestServeLm:
    def test_equilibrium_serving_smoke(self, capsys):
        from serve_lm import main

        players = main(["--arch", "smollm-360m", "--players", "2",
                        "--rounds", "1", "--tau", "1", "--batch", "1",
                        "--prompt-len", "16", "--new-tokens", "4"])
        out = capsys.readouterr().out
        assert len(players) == 2
        assert "player 0" in out and "player 1" in out
        assert "trained 2 players" in out

    def test_random_init_mode_still_works(self, capsys):
        from serve_lm import main

        players = main(["--arch", "smollm-360m", "--rounds", "0",
                        "--batch", "1", "--prompt-len", "16",
                        "--new-tokens", "4"])
        assert len(players) == 1
        assert "random init" in capsys.readouterr().out

    def test_multimodal_requires_random_init(self):
        from serve_lm import main

        with pytest.raises(SystemExit, match="rounds 0"):
            main(["--arch", "seamless-m4t-medium", "--rounds", "1"])
