"""Shared fixtures-by-convention for the engine test files.

The same three things were growing verbatim copies across
``test_engine.py``, ``test_async_engine.py``, and ``test_async_mesh.py``
(and would have grown a fourth copy in ``test_selection.py``): the
canonical quadratic test games with their Gaussian starts, the
verbatim-compact legacy scan loops the engine is pinned against, and the
bit-for-bit run comparison used by every D = 0 / refactor-equivalence pin.
They live here once.  Plain functions, not pytest fixtures — each test
file keeps its own ``@pytest.fixture`` scoping and caching decisions.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.games import make_quadratic_game

# canonical test games --------------------------------------------------------


def strong_quad():
    """The PR 1 anchor game: strong coupling (default L_B = 20), n = 4."""
    return make_quadratic_game(n=4, d=8, M=40, batch_size=1, seed=0)


def weak_quad(n=6, d=10, seed=0):
    """Weak coupling (L_B = 1): staleness and masks cost rounds instead of
    destabilizing — the async/mesh composition game."""
    return make_quadratic_game(n=n, d=d, M=40, L_B=1.0, batch_size=1,
                               seed=seed)


def gaussian_x0(game, seed=7):
    """The standard Gaussian start, f32, keyed the way the seed tests were."""
    return jnp.asarray(
        np.random.default_rng(seed).standard_normal((game.n, game.d)),
        dtype=jnp.float32,
    )


# run comparison --------------------------------------------------------------


def assert_runs_bitwise_equal(a, b, *, check_bytes=True):
    """The bit-for-bit pin: two engine results realized the SAME run.

    Iterates, error curves, and (by default) both byte ledgers must match
    exactly — a refactor or a D = 0 collapse may not perturb a single ULP
    or bill a single different byte.
    """
    np.testing.assert_array_equal(np.asarray(a.x_final), np.asarray(b.x_final))
    np.testing.assert_array_equal(a.rel_errors, b.rel_errors)
    if check_bytes:
        np.testing.assert_array_equal(a.bytes_up, b.bytes_up)
        np.testing.assert_array_equal(a.bytes_down, b.bytes_down)


# legacy reference loops ------------------------------------------------------


def legacy_pearl_sgd(game, x0, gammas, key, *, tau, stochastic,
                     sync_dtype=None):
    """Verbatim-compact copy of the seed repo's pearl.py::_run scan loop."""
    n = x0.shape[0]

    def local_updates(i, x_sync, gamma, key):
        if sync_dtype is not None:
            x_ref = x_sync.astype(sync_dtype).astype(x_sync.dtype)
            x_ref = x_ref.at[i].set(x_sync[i])
        else:
            x_ref = x_sync

        def step(x_i, k):
            if stochastic:
                g = game.player_grad_stoch(i, x_i, x_ref, k)
            else:
                g = game.player_grad(i, x_i, x_ref)
            return x_i - gamma * g, None

        keys = jax.random.split(key, tau)
        x_i, _ = jax.lax.scan(step, x_sync[i], keys)
        return x_i

    def round_body(carry, gamma):
        x_sync, key = carry
        key, sub = jax.random.split(key)
        player_keys = jax.random.split(sub, n)
        x_next = jax.vmap(local_updates, in_axes=(0, None, None, 0))(
            jnp.arange(n), x_sync, gamma, player_keys
        )
        return (x_next, key), x_next

    (x_final, _), xs = jax.lax.scan(round_body, (x0, key), gammas)
    return x_final, xs


def legacy_pearl_eg(game, x0, gammas, key, *, tau, stochastic):
    """Verbatim-compact copy of the seed repo's baselines.py::_pearl_eg_run."""
    n = x0.shape[0]

    def local(i, x_sync, gamma, key):
        def step(x_i, k):
            k1, k2 = jax.random.split(k)
            if stochastic:
                g_half = game.player_grad_stoch(i, x_i, x_sync, k1)
                x_half = x_i - gamma * g_half
                g = game.player_grad_stoch(i, x_half, x_sync, k2)
            else:
                x_half = x_i - gamma * game.player_grad(i, x_i, x_sync)
                g = game.player_grad(i, x_half, x_sync)
            return x_i - gamma * g, None

        keys = jax.random.split(key, tau)
        x_i, _ = jax.lax.scan(step, x_sync[i], keys)
        return x_i

    def round_body(carry, gamma):
        x_sync, key = carry
        key, sub = jax.random.split(key)
        pkeys = jax.random.split(sub, n)
        x_next = jax.vmap(local, in_axes=(0, None, None, 0))(
            jnp.arange(n), x_sync, gamma, pkeys
        )
        return (x_next, key), x_next

    (x, _), xs = jax.lax.scan(round_body, (x0, key), gammas)
    return x, xs
