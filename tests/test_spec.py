"""EngineSpec: the one config object, the one compatibility matrix.

Three pins:

1. **Spec spelling is bit-for-bit the legacy kwargs.** For each entry
   point (both dense engines and the neural trainer) a run configured
   through ``spec=EngineSpec(...)`` realizes the identical trajectory and
   byte ledger as the same axes passed as constructor kwargs — the spec is
   sugar, not a second code path.
2. **Two sources of truth are rejected**, same-value redundancy is not.
3. **docs/ARCHITECTURE.md's rejection table IS validate_spec.** The
   doc-sync test parses the table and fires every row: a row whose
   combination no longer raises — or a new rejection without a row — fails
   here, so the docs cannot drift from the matrix.

Plus the deprecation shims: the PR 1 adapters and ``make_pearl_round``
warn exactly once per process and keep working.
"""

import pathlib
import re
import warnings

import jax.numpy as jnp
import pytest

import repro.core.spec as spec_mod
from repro.core.async_engine import (
    AsyncPearlEngine,
    ConstantDelay,
    StaleSync,
    UniformDelay,
)
from repro.core.engine import (
    DecentralizedExtragradientUpdate,
    ExactSync,
    ExtragradientUpdate,
    Int4Sync,
    Int8Sync,
    JointExtragradientUpdate,
    MeanFieldView,
    PartialParticipation,
    PearlEngine,
    QuantizedSync,
    SgdUpdate,
    StarView,
)
from repro.core.games import make_quadratic_game
from repro.core.games.meanfield import MeanFieldQuadraticGame, make_mean_field_game
from repro.core.incentives import BestResponseParticipation
from repro.core.selection import GreedyShapley
from repro.core.spec import (
    EngineSpec,
    merge_trainer_spec,
    resolve_stale_sync,
    validate_spec,
    validate_tree_mean,
)
from repro.core.stepsize import SpectralPolicy
from repro.core.topology import Ring

from helpers import assert_runs_bitwise_equal, gaussian_x0, weak_quad

ARCH = pathlib.Path(__file__).resolve().parents[1] / "docs" / "ARCHITECTURE.md"


# ============================================================= equivalence
class TestSpecEquivalence:
    """spec= realizes bit-for-bit the legacy kwargs spelling."""

    @pytest.fixture(scope="class")
    def game(self):
        return weak_quad()

    def _run(self, engine, game, **kw):
        import jax

        return engine.run(game, gaussian_x0(game), tau=2, rounds=6,
                          gamma=2e-3, key=jax.random.PRNGKey(0), **kw)

    @pytest.mark.parametrize("axes", [
        dict(update=ExtragradientUpdate(), sync=Int8Sync()),
        dict(topology=Ring(), gossip_steps=2,
             sync=QuantizedSync(jnp.bfloat16)),
        dict(sync=GreedyShapley(fraction=0.5, seed=3)),
    ], ids=["eg-int8-star", "ring-bf16-2sweeps", "selection"])
    def test_lockstep_spec_equals_kwargs(self, game, axes):
        legacy = self._run(PearlEngine(**axes), game)
        specd = self._run(PearlEngine(spec=EngineSpec(**axes)), game)
        assert_runs_bitwise_equal(legacy, specd)

    def test_async_spec_equals_kwargs(self, game):
        axes = dict(update=SgdUpdate(), sync=Int8Sync())
        timing = dict(delays=UniformDelay(2), max_staleness=2)
        legacy = self._run(AsyncPearlEngine(**axes, **timing), game)
        specd = self._run(
            AsyncPearlEngine(spec=EngineSpec(**axes), **timing), game)
        assert_runs_bitwise_equal(legacy, specd)

    def test_every_axis_lands_on_the_engine(self):
        s = EngineSpec(update=ExtragradientUpdate(), sync=Int8Sync(),
                       topology=Ring(), gossip_steps=3,
                       policy=SpectralPolicy(), mesh_axis="players")
        eng = PearlEngine(spec=s)
        assert eng.update == ExtragradientUpdate()
        assert eng.sync == Int8Sync()
        assert eng.topology == Ring()
        assert eng.gossip_steps == 3
        assert eng.policy == SpectralPolicy()
        assert eng.mesh_axis == "players"

    def test_trainer_spec_equals_kwargs(self):
        from repro.configs import get_config
        from repro.data.synthetic import DataConfig, SyntheticTokenStream
        from repro.optim.optimizers import sgd
        from repro.train.pearl_trainer import PearlTrainer

        cfg = get_config("smollm-360m").smoke_variant()

        def stream():
            return SyntheticTokenStream(DataConfig(
                vocab_size=cfg.vocab_size, seq_len=16, batch_size=2,
                n_players=2, seed=0))

        def run(**kw):
            t = PearlTrainer(cfg, sgd(5e-2), n_players=2, tau=2,
                             prox_lambda=1e-3, **kw)
            hist = t.run(stream(), rounds=2)
            return t, hist

        t_legacy, h_legacy = run(sync=Int8Sync())
        t_spec, h_spec = run(spec=EngineSpec(sync=Int8Sync()))
        assert [h["lm_loss"] for h in h_legacy] == \
               [h["lm_loss"] for h in h_spec]
        import jax
        import numpy as np

        for a, b in zip(jax.tree.leaves(t_legacy.params),
                        jax.tree.leaves(t_spec.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ================================================================ conflicts
class TestSpecConflicts:
    def test_both_ways_different_values_rejected(self):
        with pytest.raises(ValueError, match="both ways"):
            PearlEngine(sync=Int8Sync(),
                        spec=EngineSpec(sync=ExactSync()))

    def test_both_ways_same_value_is_fine(self):
        eng = PearlEngine(sync=Int8Sync(), spec=EngineSpec(sync=Int8Sync()))
        assert eng.sync == Int8Sync()

    def test_spec_must_be_an_enginespec(self):
        with pytest.raises(TypeError, match="EngineSpec"):
            PearlEngine(spec={"sync": ExactSync()})

    def test_trainer_rejects_update_axis(self):
        with pytest.raises(ValueError, match="no 'update' axis"):
            merge_trainer_spec(EngineSpec(update=SgdUpdate()),
                               topology=None, policy=None, round_kwargs={})

    def test_trainer_sync_both_ways_rejected(self):
        with pytest.raises(ValueError, match="both ways"):
            merge_trainer_spec(EngineSpec(sync=Int8Sync()),
                               topology=None, policy=None,
                               round_kwargs={"sync": ExactSync()})

    def test_set_axes_lists_only_set_fields(self):
        assert EngineSpec().set_axes() == {}
        assert EngineSpec(gossip_steps=2).set_axes() == {"gossip_steps": 2}


# =========================================================== doc-table sync
def _table_rows():
    """First-column cell of every data row in the rejection table."""
    section = ARCH.read_text().split(
        "## Which combinations are rejected, and why", 1)[1]
    section = section.split("\n## ", 1)[0]
    rows = []
    for line in section.splitlines():
        if (line.startswith("|") and not line.startswith("|---")
                and "| Verdict |" not in line):
            rows.append(line.split("|")[1].strip())
    return rows


def _mesh_sentinel():
    # validate_spec only branches on mesh presence; the collectives that
    # would consume it are never reached by a rejected composition
    return object()


def _two_moment_game():
    class TwoMomentGame(MeanFieldQuadraticGame):
        summary_moments = 2

    g = make_mean_field_game(n=4, d=2)
    return TwoMomentGame(A=g.A, a=g.a, n=g.n, d=g.d, beta=g.beta)


def _trainer_validate(**kw):
    defaults = dict(trainer=True, delays=None, max_staleness=0,
                    external_refs=False, trainer_init=False,
                    staleness_available=False, policy_remedy="r",
                    coupling=1.0)
    defaults.update(kw)
    spec = defaults.pop("spec")
    return validate_spec(spec, **defaults)


# One trigger per table row, keyed by the row's first-column text VERBATIM.
# Key-set equality against the parsed table is the sync guarantee: add a
# rejection without a row (or a row without a live rejection) and this
# test fails.
TRIGGERS = {
    "`JointUpdate` × non-`ExactSync`":
        lambda: validate_spec(EngineSpec(
            update=JointExtragradientUpdate(),
            sync=QuantizedSync(jnp.bfloat16))),
    "`JointUpdate` × graph topology":
        lambda: validate_spec(EngineSpec(
            update=JointExtragradientUpdate(), topology=Ring())),
    "`JointUpdate` × non-`theorem34` policy":
        lambda: validate_spec(EngineSpec(
            update=JointExtragradientUpdate(), policy=SpectralPolicy(),
            topology=Ring())),
    "`JointUpdate` × `AsyncPearlEngine`":
        lambda: validate_spec(EngineSpec(update=JointExtragradientUpdate()),
                              async_=True),
    "`StaleSync` × `PearlEngine`":
        lambda: validate_spec(EngineSpec(sync=StaleSync(
            ExactSync(), UniformDelay(2), 2))),
    "`StaleSync` + engine-level `delays`/`max_staleness`":
        lambda: resolve_stale_sync(
            StaleSync(ExactSync(), UniformDelay(2), 2), UniformDelay(2), 2),
    "`delay_adaptive` × `PearlEngine` (lockstep)":
        lambda: validate_spec(EngineSpec(policy="delay_adaptive")),
    "`delay_adaptive` × lockstep trainer round":
        lambda: _trainer_validate(spec=EngineSpec(
            policy="delay_adaptive", sync=ExactSync())),
    "`spectral` × `star` (any engine, and the trainer)":
        lambda: validate_spec(EngineSpec(policy="spectral")),
    "`spectral` trainer without `coupling > 1.0`":
        lambda: _trainer_validate(spec=EngineSpec(
            policy="spectral", sync=ExactSync(), topology=Ring()),
            trainer_init=True, coupling=1.0),
    "`decentralized_eg` × `star`":
        lambda: validate_spec(EngineSpec(
            update=DecentralizedExtragradientUpdate())),
    "`decentralized_eg` × mask strategy (`partial`/`dropout`)":
        lambda: validate_spec(EngineSpec(
            update=DecentralizedExtragradientUpdate(), topology=Ring(),
            sync=PartialParticipation(fraction=0.5, seed=0))),
    "`decentralized_eg` × `AsyncPearlEngine`":
        lambda: validate_spec(EngineSpec(
            update=DecentralizedExtragradientUpdate(), topology=Ring()),
            async_=True),
    "`int8`/`int4` with `error_feedback=True` × graph topology":
        lambda: validate_spec(EngineSpec(sync=Int8Sync(), topology=Ring())),
    "`int4` × odd block dimension":
        lambda: Int4Sync(error_feedback=False).roundtrip(
            jnp.zeros((2, 3))),
    "`AsyncPearlEngine(mesh=…)` × graph topology":
        lambda: validate_spec(EngineSpec(
            topology=Ring(), mesh=_mesh_sentinel()), async_=True),
    "`overlap=True` without `mesh` / on gossip / without "
    "`delays=ConstantDelay(1), max_staleness=1`":
        lambda: validate_spec(EngineSpec(), async_=True, overlap=True),
    "`tree_mean` × mask strategy":
        lambda: validate_tree_mean(
            PartialParticipation(fraction=0.5, seed=0), 0, None),
    "`mesh` × mask strategy (dense engines)":
        lambda: validate_spec(EngineSpec(
            sync=PartialParticipation(fraction=0.5, seed=0),
            mesh=_mesh_sentinel())),
    "`mesh` × `JointUpdate`":
        lambda: validate_spec(EngineSpec(
            update=JointExtragradientUpdate(), mesh=_mesh_sentinel())),
    "`StarView` × graph topology / `GossipView` × star":
        lambda: validate_spec(EngineSpec(view=StarView(), topology=Ring())),
    "`MeanFieldView` × graph topology":
        lambda: validate_spec(EngineSpec(
            view=MeanFieldView(), topology=Ring())),
    "`MeanFieldView` × non-`AggregativeGame`":
        lambda: validate_spec(EngineSpec(view=MeanFieldView()),
                              game=make_quadratic_game(n=2, d=2, M=2)),
    "`MeanFieldView(moments=m)` × game with `summary_moments > m`":
        lambda: validate_spec(EngineSpec(view=MeanFieldView(moments=1)),
                              game=_two_moment_game()),
    "`MeanFieldView` × `JointUpdate` / `decentralized_eg`":
        lambda: validate_spec(EngineSpec(
            view=MeanFieldView(), update=JointExtragradientUpdate())),
    "`MeanFieldView` × mask strategy (`partial`/`dropout`)":
        lambda: validate_spec(EngineSpec(
            view=MeanFieldView(),
            sync=PartialParticipation(fraction=0.5, seed=0))),
    "`MeanFieldView` × `mesh`":
        lambda: validate_spec(EngineSpec(
            view=MeanFieldView(), mesh=_mesh_sentinel())),
    "`MeanFieldView(sample=k)` × error-feedback sync / × "
    "`AsyncPearlEngine`":
        lambda: validate_spec(EngineSpec(
            view=MeanFieldView(sample=2), sync=Int8Sync())),
    "trainer `view=` anything but `MeanFieldView(moments=1, "
    "self_correction=False, sample=None)`":
        lambda: _trainer_validate(spec=EngineSpec(
            view=StarView(), sync=ExactSync())),
    "selection policy × graph topology (both engines AND the trainer)":
        lambda: validate_spec(EngineSpec(
            sync=GreedyShapley(), topology=Ring())),
    "selection policy × dense-engine `mesh`":
        lambda: validate_spec(EngineSpec(
            sync=GreedyShapley(), mesh=_mesh_sentinel())),
    "selection policy × dense `MeanFieldView` (`sample=None`)":
        lambda: validate_spec(EngineSpec(
            sync=GreedyShapley(), view=MeanFieldView())),
    "selection policy's legacy `init_state`/`pre_round`/`mask` surface":
        lambda: GreedyShapley().pre_round(None),
    "incentive policy (`best_response`) × `JointUpdate`":
        lambda: validate_spec(EngineSpec(
            update=JointExtragradientUpdate(),
            sync=BestResponseParticipation())),
    "incentive policy (`best_response`) × dense `MeanFieldView`":
        lambda: validate_spec(EngineSpec(
            sync=BestResponseParticipation(), view=MeanFieldView())),
    "spec axis given BOTH ways (`EngineSpec(update=…)` + constructor "
    "`update=…`, different values)":
        lambda: PearlEngine(update=ExtragradientUpdate(),
                            spec=EngineSpec(update=SgdUpdate())),
    "trainer spec with `update` / `gossip_steps`":
        lambda: merge_trainer_spec(EngineSpec(gossip_steps=2),
                                   topology=None, policy=None,
                                   round_kwargs={}),
    "`tau < 1`, `rounds < 1`, `gossip_steps < 1`, `max_staleness < 0`, "
    "fractions/probabilities outside `[0, 1]`, selection knobs out of "
    "range (`memory ∉ [0, 1)`, `aging < 0`, `c < 0`, `candidates < 1`, "
    "`tracked < 1`, `explore ∉ (0, 1]`), incentive knobs out of range "
    "(unknown `payment` rule, negative `price`/`budget`/`value_weight`/"
    "`staleness_discount`, `br_iters < 1`, `cost_min > cost_max`), "
    "nested `StaleSync`, `MeanFieldView` with `moments ∉ {1, 2}` or "
    "`sample < 1` or `sample > n−1`":
        lambda: BestResponseParticipation(payment="bribery"),
}


class TestDocTableSync:
    def test_table_and_triggers_cover_each_other(self):
        rows = _table_rows()
        assert len(rows) == len(set(rows)), "duplicate table rows"
        assert set(rows) == set(TRIGGERS), (
            "docs/ARCHITECTURE.md rejection table and "
            "tests/test_spec.py::TRIGGERS disagree:\n"
            f"  rows without a trigger: {sorted(set(rows) - set(TRIGGERS))}\n"
            f"  triggers without a row: {sorted(set(TRIGGERS) - set(rows))}"
        )

    @pytest.mark.parametrize("row", sorted(TRIGGERS),
                             ids=lambda r: r[:48].replace(" ", "_"))
    def test_every_row_fires(self, row):
        with pytest.raises((ValueError, RuntimeError)):
            TRIGGERS[row]()


# ============================================================= deprecation
class TestDeprecationShims:
    @pytest.fixture(autouse=True)
    def _fresh_warned(self, monkeypatch):
        monkeypatch.setattr(spec_mod, "_LEGACY_WARNED", set())

    def test_warn_legacy_is_one_time(self):
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            spec_mod.warn_legacy("thing", "use EngineSpec")
            spec_mod.warn_legacy("thing", "use EngineSpec")
        dep = [x for x in w if issubclass(x.category, DeprecationWarning)]
        assert len(dep) == 1
        assert "EngineSpec" in str(dep[0].message)

    def test_pearl_sgd_warns_and_matches_engine(self):
        import jax

        game = make_quadratic_game(n=2, d=2, M=4)
        x0 = gaussian_x0(game)
        from repro.core.pearl import pearl_sgd

        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            r_legacy = pearl_sgd(game, x0, tau=2, rounds=3, gamma=1e-3,
                                 key=jax.random.PRNGKey(0))
        assert any(issubclass(x.category, DeprecationWarning) for x in w)
        r_spec = PearlEngine(spec=EngineSpec(update=SgdUpdate())).run(
            game, x0, tau=2, rounds=3, gamma=1e-3,
            key=jax.random.PRNGKey(0))
        assert_runs_bitwise_equal(r_legacy, r_spec)

    def test_make_pearl_round_warns_once(self):
        from repro.configs import get_config
        from repro.optim.optimizers import sgd
        from repro.train.pearl_trainer import make_pearl_round

        cfg = get_config("smollm-360m").smoke_variant()
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            make_pearl_round(cfg, sgd(1e-2), tau=1, prox_lambda=0.0)
            make_pearl_round(cfg, sgd(1e-2), tau=1, prox_lambda=0.0)
        dep = [x for x in w if issubclass(x.category, DeprecationWarning)]
        assert len(dep) == 1
        assert "make_pearl_round" in str(dep[0].message)
