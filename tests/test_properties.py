"""Hypothesis property tests on system invariants.

- Generated quadratic games always satisfy QSM/antisymmetry regardless of
  draw (the D.1 construction).
- PEARL-SGD with the theoretical step-size never diverges (deterministic).
- Theoretical step-sizes respect their defining inequalities.
- MoE dispatch conserves token mass and respects capacity.
- Communication accounting is monotone in tau.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import stepsize
from repro.core.games import make_quadratic_game
from repro.core.metrics import CommunicationModel
from repro.core.pearl import pearl_sgd
from repro.models.moe import _top_k_dispatch

SETTINGS = dict(max_examples=10, deadline=None)


class TestQuadraticGameConstruction:
    @settings(**SETTINGS)
    @given(
        n=st.integers(2, 5),
        d=st.integers(2, 8),
        L_B=st.floats(0.5, 30.0),
        seed=st.integers(0, 10_000),
    )
    def test_qsm_holds_for_any_draw(self, n, d, L_B, seed):
        g = make_quadratic_game(n=n, d=d, M=5, L_B=L_B, seed=seed)
        c = g.constants()
        assert c.mu > 0
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.standard_normal((n, d)))
        y = jnp.asarray(rng.standard_normal((n, d)))
        lhs = float(jnp.sum((g.operator(x) - g.operator(y)) * (x - y)))
        assert lhs >= c.mu * float(jnp.sum((x - y) ** 2)) - 1e-6

    @settings(**SETTINGS)
    @given(seed=st.integers(0, 1000), tau=st.sampled_from([1, 2, 5, 10]))
    def test_pearl_never_diverges_with_theory_stepsize(self, seed, tau):
        g = make_quadratic_game(n=3, d=4, M=5, seed=seed)
        c = g.constants()
        x0 = jnp.asarray(np.random.default_rng(seed).standard_normal((3, 4)))
        r = pearl_sgd(g, x0, tau=tau, rounds=50,
                      gamma=stepsize.gamma_constant(c, tau), stochastic=False)
        assert np.all(np.isfinite(r.rel_errors))
        assert r.rel_errors[-1] <= 1.0 + 1e-9  # monotone-ish contraction


class TestStepsizeRules:
    @settings(**SETTINGS)
    @given(
        mu=st.floats(0.1, 2.0),
        kappa=st.floats(1.0, 500.0),
        q=st.floats(0.01, 1.0),
        tau=st.integers(1, 50),
    )
    def test_constant_stepsize_bounds(self, mu, kappa, q, tau):
        from repro.core.game import GameConstants

        ell = mu * kappa
        L_max = q * float(np.sqrt(ell * mu))
        c = GameConstants(mu=mu, ell=ell, L_max=L_max, L_F=float(np.sqrt(ell * mu)))
        gamma = stepsize.gamma_constant(c, tau)
        # defining inequality of Thm 3.3/3.4
        assert gamma <= 1.0 / (ell * tau + 2 * (tau - 1) * L_max * np.sqrt(kappa)) + 1e-12
        # zeta > 0 (contraction well-defined); 1 > rate > 0
        assert stepsize.contraction_zeta(c, tau, gamma) > 0
        assert 0.0 <= stepsize.linear_rate(c, tau, gamma) < 1.0

    @settings(**SETTINGS)
    @given(tau=st.integers(1, 8), rounds=st.integers(10, 200))
    def test_decreasing_schedule_is_nonincreasing_after_warmup(self, tau, rounds):
        from repro.core.game import GameConstants

        c = GameConstants(mu=0.5, ell=10.0, L_max=1.0, L_F=3.0)
        sched = stepsize.gamma_decreasing(c, tau, rounds)
        assert np.all(sched > 0)
        tail = sched[int(2 * (1 + 2 * c.q) * c.kappa) + 1 :]
        assert np.all(np.diff(tail) <= 1e-12)


class TestMoEDispatchInvariants:
    @settings(**SETTINGS)
    @given(
        g=st.integers(1, 3),
        s=st.sampled_from([8, 16, 32]),
        e=st.sampled_from([2, 4, 8]),
        k=st.integers(1, 3),
        seed=st.integers(0, 100),
    )
    def test_capacity_and_mass(self, g, s, e, k, seed):
        k = min(k, e)
        key = jax.random.PRNGKey(seed)
        probs = jax.nn.softmax(jax.random.normal(key, (g, s, e)), axis=-1)
        capacity = max(1, int(np.ceil(s * k * 2.0 / e)))
        dispatch, combine, aux = _top_k_dispatch(probs, k, capacity)
        d = np.asarray(dispatch)
        # each (expert, slot) holds at most one token
        assert d.sum(axis=1).max() <= 1.0 + 1e-6
        # each token dispatched at most k times, never negatively
        per_token = d.sum(axis=(2, 3))
        assert per_token.max() <= k + 1e-6 and d.min() >= 0.0
        # combine weights of surviving tokens sum to ~1
        cw = np.asarray(combine).sum(axis=(2, 3))
        surviving = per_token >= k - 1e-6
        np.testing.assert_allclose(cw[surviving], 1.0, atol=1e-5)
        assert float(aux) >= 1.0 - 1e-5  # >= 1 with equality iff balanced


class TestCommunicationModel:
    @settings(**SETTINGS)
    @given(
        dims=st.lists(st.integers(1, 100), min_size=2, max_size=6),
        tau_a=st.integers(1, 10),
    )
    def test_bytes_monotone_in_tau(self, dims, tau_a):
        cm = CommunicationModel(tuple(dims))
        iters = 1000
        b1 = cm.bytes_for_iterations(iters, tau_a)
        b2 = cm.bytes_for_iterations(iters, tau_a + 1)
        assert b2 <= b1
        # downlink carries the n-scaled joint vector (Section 3.1)
        assert cm.bytes_per_round() == (1 + cm.n) * cm.D * 4
