"""Config/spec-layer tests: assigned hyperparameters, shapes, window policy."""

import jax

import jax.numpy as jnp
import pytest

from repro.configs import ALL_SHAPES, ARCH_IDS, get_config, get_shape
from repro.configs.shapes import DECODE_32K, LONG_500K, PREFILL_32K, TRAIN_4K
from repro.launch.specs import (
    decode_input_specs,
    input_specs,
    pick_window,
    train_input_specs,
)

ASSIGNED = {
    "granite-34b": dict(n_layers=88, d_model=6144, n_heads=48, n_kv_heads=1,
                        d_ff=24576, vocab_size=49152),
    "stablelm-1.6b": dict(n_layers=24, d_model=2048, n_heads=32,
                          n_kv_heads=32, d_ff=5632, vocab_size=100352),
    "chameleon-34b": dict(n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8,
                          d_ff=22016, vocab_size=65536),
    "llama4-maverick-400b-a17b": dict(n_layers=48, d_model=5120, n_heads=40,
                                      n_kv_heads=8, vocab_size=202048,
                                      n_experts=128, top_k=1, moe_d_ff=8192),
    "smollm-360m": dict(n_layers=32, d_model=960, n_heads=15, n_kv_heads=5,
                        d_ff=2560, vocab_size=49152),
    "moonshot-v1-16b-a3b": dict(n_layers=48, d_model=2048, n_heads=16,
                                n_kv_heads=16, vocab_size=163840,
                                n_experts=64, top_k=6, moe_d_ff=1408),
    "qwen3-moe-30b-a3b": dict(n_layers=48, d_model=2048, n_heads=32,
                              n_kv_heads=4, vocab_size=151936, n_experts=128,
                              top_k=8, moe_d_ff=768),
    "seamless-m4t-medium": dict(n_layers=12, d_model=1024, n_heads=16,
                                n_kv_heads=16, d_ff=4096, vocab_size=256206,
                                enc_layers=12),
    "zamba2-1.2b": dict(n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
                        d_ff=8192, vocab_size=32000, ssm_state=64),
    "xlstm-125m": dict(n_layers=12, d_model=768, n_heads=4, n_kv_heads=4,
                       d_ff=0, vocab_size=50304),
}


class TestAssignedHyperparameters:
    @pytest.mark.parametrize("arch", sorted(ASSIGNED))
    def test_exact_assigned_values(self, arch):
        cfg = get_config(arch)
        for field, expected in ASSIGNED[arch].items():
            assert getattr(cfg, field) == expected, (arch, field)

    def test_all_ten_archs_registered(self):
        assert len(ARCH_IDS) == 10
        assert set(ASSIGNED) == set(ARCH_IDS)

    def test_citations_present(self):
        for arch in ARCH_IDS:
            assert get_config(arch).citation, arch

    def test_head_dims_are_consistent(self):
        for arch in ARCH_IDS:
            cfg = get_config(arch)
            assert cfg.d_model % cfg.n_heads == 0 or cfg.head_dim
            assert cfg.n_heads % cfg.n_kv_heads == 0


class TestShapes:
    def test_assigned_shapes(self):
        assert (TRAIN_4K.seq_len, TRAIN_4K.global_batch) == (4096, 256)
        assert (PREFILL_32K.seq_len, PREFILL_32K.global_batch) == (32768, 32)
        assert (DECODE_32K.seq_len, DECODE_32K.global_batch) == (32768, 128)
        assert (LONG_500K.seq_len, LONG_500K.global_batch) == (524288, 1)
        assert len(ALL_SHAPES) == 4

    def test_modes(self):
        assert TRAIN_4K.mode == "train"
        assert PREFILL_32K.mode == "prefill"
        assert DECODE_32K.mode == LONG_500K.mode == "decode"

    def test_get_shape_errors(self):
        with pytest.raises(KeyError):
            get_shape("nope")


class TestInputSpecs:
    def test_dense_train_specs(self):
        cfg = get_config("stablelm-1.6b")
        specs = train_input_specs(cfg, TRAIN_4K)
        assert specs["tokens"].shape == (256, 4096)
        assert specs["tokens"].dtype == jnp.int32

    def test_vlm_specs_split_patches(self):
        cfg = get_config("chameleon-34b")
        specs = train_input_specs(cfg, TRAIN_4K)
        assert specs["patch_embeds"].shape == (256, 1024, 8192)
        assert specs["tokens"].shape == (256, 4096 - 1024)

    def test_audio_specs_have_frames(self):
        cfg = get_config("seamless-m4t-medium")
        specs = train_input_specs(cfg, TRAIN_4K)
        assert specs["enc_frames"].shape == (256, 4096, 1024)

    def test_decode_specs_cache_sized_to_context(self):
        cfg = get_config("stablelm-1.6b")
        specs = decode_input_specs(cfg, DECODE_32K)
        assert specs["token"].shape == (128, 1)
        k = specs["cache"]["runs"][0]["k"]
        assert k.shape == (24, 128, 32768, 32, 64)   # (layers, B, C, KV, hd)

    def test_windowed_decode_cache_is_ring_sized(self):
        cfg = get_config("granite-34b")
        specs = decode_input_specs(cfg, LONG_500K, window=cfg.sliding_window)
        k = specs["cache"]["runs"][0]["k"]
        assert k.shape[2] == cfg.sliding_window      # ring buffer, not 500k

    def test_ssm_decode_cache_is_o1(self):
        cfg = get_config("xlstm-125m")
        specs = decode_input_specs(cfg, LONG_500K)
        total = sum(
            int(jnp.prod(jnp.asarray(l.shape)))
            for l in jax.tree.leaves(specs["cache"])
        )
        # recurrent state is independent of the 524288-token context
        assert total < 50_000_000

    def test_input_specs_dispatch(self):
        cfg = get_config("smollm-360m")
        assert "tokens" in input_specs(cfg, TRAIN_4K)
        assert "cache" in input_specs(cfg, DECODE_32K)


class TestWindowPolicy:
    def test_dense_full_attention_except_long(self):
        cfg = get_config("granite-34b")
        assert pick_window(cfg, TRAIN_4K) == 0
        assert pick_window(cfg, PREFILL_32K) == 0
        assert pick_window(cfg, DECODE_32K) == 0
        assert pick_window(cfg, LONG_500K) == cfg.sliding_window > 0

    def test_hybrid_always_windowed(self):
        cfg = get_config("zamba2-1.2b")
        for shape in ALL_SHAPES:
            assert pick_window(cfg, shape) == cfg.sliding_window



