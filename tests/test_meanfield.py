"""The mean-field path: O(d) references validated against the exact engine.

Load-bearing claims pinned here:
- on the SYMMETRIC quadratic game (identical players) the population mean is
  a true sufficient statistic even without the leave-one-out correction, and
  the mean-field engine agrees with the exact engine to reduction-order ULPs;
- with the self-correction (the exact leave-one-out identity) the agreement
  holds on HETEROGENEOUS games at any n;
- without it (the infinitesimal-player idealization) the converged gap to the
  exact equilibrium shrinks monotonically in n at fixed seeds, on nested
  populations;
- the full rejection matrix: every composition whose semantics a summary
  reference would silently change (masks, joint updates, gossip sweeps,
  meshes, error feedback x sampling, non-aggregative games) raises loudly;
- `record_trajectory` is a pure output change: opting back into the stacked
  trajectory is bit-for-bit on x_final, and sampled-interaction rounds are
  reproducible from (seed, round, player) alone;
- async mean-field: D = 0 reproduces the lockstep summary program
  bit-for-bit; D > 0 runs the summary ring buffer and still converges.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import stepsize
from repro.core.async_engine import (
    AsyncPearlEngine,
    ConstantDelay,
    UniformDelay,
)
from repro.core.engine import (
    DecentralizedExtragradientUpdate,
    DropoutSync,
    ExtragradientUpdate,
    GossipView,
    Int8Sync,
    JOINT_VIEWS,
    JointExtragradientUpdate,
    MeanFieldView,
    PartialParticipation,
    PearlEngine,
    QuantizedSync,
    StarView,
    resolve_view,
)
from repro.core.games import (
    MeanFieldQuadraticGame,
    make_mean_field_game,
    make_quadratic_game,
)
from repro.core.topology import Ring, Star

ROUNDS = 40
TAU = 4


@pytest.fixture(scope="module")
def game():
    return make_mean_field_game(n=50, d=6, heterogeneity=1.0, seed=0)


@pytest.fixture(scope="module")
def sym_game():
    return make_mean_field_game(n=50, d=6, heterogeneity=0.0, seed=0)


def run(g, *, view=None, sync=None, update=None, rounds=ROUNDS, **kw):
    eng_kw = {}
    if view is not None:
        eng_kw["view"] = view
    if sync is not None:
        eng_kw["sync"] = sync
    if update is not None:
        eng_kw["update"] = update
    gamma = stepsize.gamma_constant(g.constants(), TAU)
    return PearlEngine(**eng_kw).run(
        g, jnp.zeros((g.n, g.d)), tau=TAU, rounds=rounds, gamma=gamma,
        key=jax.random.PRNGKey(0), stochastic=False, **kw)


class TestExactAgreement:
    def test_symmetric_game_uncorrected_mean_is_sufficient(self, sym_game):
        """Identical players: every trajectory row coincides, so the raw
        population mean IS the leave-one-out mean — the uncorrected
        mean-field path matches the exact engine to reduction order."""
        r_exact = run(sym_game)
        r_mf = run(sym_game, view=MeanFieldView(self_correction=False))
        np.testing.assert_allclose(np.asarray(r_mf.x_final),
                                   np.asarray(r_exact.x_final),
                                   rtol=0, atol=1e-6)

    def test_self_corrected_matches_exact_engine_heterogeneous(self, game):
        """The leave-one-out identity makes the O(d) path follow the exact
        O(n d) broadcast on heterogeneous games — reduction-order ULPs."""
        r_exact = run(game)
        r_mf = run(game, view=MeanFieldView())
        np.testing.assert_allclose(np.asarray(r_mf.x_final),
                                   np.asarray(r_exact.x_final),
                                   rtol=0, atol=1e-6)
        np.testing.assert_allclose(r_mf.rel_errors, r_exact.rel_errors,
                                   rtol=0, atol=1e-6)

    def test_self_corrected_matches_under_extragradient(self, game):
        r_exact = run(game, update=ExtragradientUpdate())
        r_mf = run(game, update=ExtragradientUpdate(), view=MeanFieldView())
        np.testing.assert_allclose(np.asarray(r_mf.x_final),
                                   np.asarray(r_exact.x_final),
                                   rtol=0, atol=1e-6)

    def test_converges_to_closed_form_equilibrium(self, game):
        r = run(game, view=MeanFieldView(), rounds=200)
        assert r.rel_errors[-1] < 1e-5
        np.testing.assert_allclose(np.asarray(r.x_final),
                                   np.asarray(game.equilibrium()),
                                   rtol=0, atol=1e-3)

    def test_uncorrected_converges_to_mean_field_equilibrium(self, game):
        """The infinitesimal-player path finds the mean-field fixed point,
        NOT the exact finite-n equilibrium — the gap is the approximation."""
        r = run(game, view=MeanFieldView(self_correction=False), rounds=400)
        mf_star = np.asarray(game.mean_field_equilibrium())
        x_star = np.asarray(game.equilibrium())
        err_mf = np.abs(np.asarray(r.x_final) - mf_star).max()
        err_exact = np.abs(np.asarray(r.x_final) - x_star).max()
        assert err_mf < 1e-4
        assert err_exact > 10 * err_mf   # the finite-n gap is real at n=50


class TestGapShrinkage:
    def test_closed_form_gap_monotone_in_n(self):
        """Nested populations at a fixed seed: the per-player mean-field
        error (exact vs infinitesimal-player equilibrium) decreases in n."""
        gaps = []
        for n in (10, 30, 100, 300, 1000):
            g = make_mean_field_game(n=n, d=6, heterogeneity=1.0, seed=0)
            diff = np.asarray(g.equilibrium(), dtype=np.float64) \
                - np.asarray(g.mean_field_equilibrium(), dtype=np.float64)
            gaps.append(float(np.abs(diff).max()))
        assert all(a > b for a, b in zip(gaps, gaps[1:])), gaps
        # O(1/(n-1)) rate: 100x the players, ~100x smaller gap
        assert gaps[-1] < gaps[0] / 50

    def test_run_gap_monotone_in_n(self):
        """Same shrinkage measured on actual engine runs: converge the
        uncorrected path, compare against the exact equilibrium."""
        gaps = []
        for n in (10, 30, 100):
            g = make_mean_field_game(n=n, d=6, heterogeneity=1.0, seed=0)
            r = run(g, view=MeanFieldView(self_correction=False), rounds=400)
            gaps.append(float(np.abs(
                np.asarray(r.x_final) - np.asarray(g.equilibrium())).max()))
        assert all(a > b for a, b in zip(gaps, gaps[1:])), gaps

    def test_sampled_interaction_beats_raw_mean_in_expectation(self, game):
        """sample=k draws exclude the reader, so the sampled estimate is
        unbiased for the leave-one-out mean — its converged iterate should
        land near the EXACT equilibrium (noise-limited), not the mean-field
        one."""
        r = run(game, view=MeanFieldView(sample=16, seed=3), rounds=400)
        x_star = np.asarray(game.equilibrium())
        err = np.abs(np.asarray(r.x_final) - x_star).max()
        assert err < 0.15  # sampling noise floor at constant gamma


class TestByteAccounting:
    def test_summary_wire_is_o_d_per_player(self, game):
        n, d = game.n, game.d
        r_exact = run(game)
        r_mf = run(game, view=MeanFieldView())
        # uplink unchanged: every player still uploads its block
        assert r_mf.bytes_up[0] == r_exact.bytes_up[0] == n * d * 4
        # downlink: the (moments, d) summary per player, not the (n, d) joint
        assert r_exact.bytes_down[0] == n * n * d * 4
        assert r_mf.bytes_down[0] == n * 1 * d * 4

    def test_two_moment_summary_bills_both_rows(self, game):
        r = run(game, view=MeanFieldView(moments=2))
        assert r.bytes_down[0] == game.n * 2 * game.d * 4

    def test_quantized_summary_halves_downlink(self, game):
        r = run(game, view=MeanFieldView(),
                sync=QuantizedSync(jnp.bfloat16))
        assert r.bytes_down[0] == game.n * game.d * 2
        assert r.bytes_up[0] == game.n * game.d * 4

    def test_low_bit_summary_bills_scale_overhead(self, game):
        r = run(game, view=MeanFieldView(), sync=Int8Sync())
        # one int8 summary block + one scale per player
        assert r.bytes_down[0] == game.n * (game.d * 1 + 4)

    def test_per_player_bytes_flat_in_n(self):
        per_player = []
        for n in (20, 80):
            g = make_mean_field_game(n=n, d=6, heterogeneity=1.0, seed=0)
            r = run(g, view=MeanFieldView(), rounds=3)
            per_player.append(r.bytes_down[0] / n)
        assert per_player[0] == per_player[1] == 6 * 4


class TestRecordTrajectory:
    def test_default_omits_trajectory_and_pins_x_final(self, game):
        r_off = run(game, view=MeanFieldView())
        r_on = run(game, view=MeanFieldView(), record_trajectory=True)
        assert r_off.xs is None
        assert r_on.xs.shape == (ROUNDS, game.n, game.d)
        np.testing.assert_array_equal(np.asarray(r_on.x_final),
                                      np.asarray(r_off.x_final))
        np.testing.assert_allclose(r_on.rel_errors, r_off.rel_errors,
                                   rtol=1e-6, atol=1e-9)

    def test_legacy_star_path_opt_in_is_bit_for_bit(self, game):
        """The exact path: record_trajectory=True must reproduce the run's
        x_final bit-for-bit AND its xs must match trajectory()."""
        r_on = run(game, record_trajectory=True)
        r_off = run(game)
        np.testing.assert_array_equal(np.asarray(r_on.x_final),
                                      np.asarray(r_off.x_final))
        gamma = stepsize.gamma_constant(game.constants(), TAU)
        xs = PearlEngine().trajectory(
            game, jnp.zeros((game.n, game.d)), tau=TAU, rounds=ROUNDS,
            gamma=gamma, key=jax.random.PRNGKey(0), stochastic=False)
        np.testing.assert_array_equal(np.asarray(r_on.xs), np.asarray(xs))

    def test_at_equilibrium_rel_errors_stay_zero(self, game):
        """The guarded normalization survives the in-scan squared-error
        path: starting AT x* keeps the curve at 0, not 0/0."""
        x_star = game.equilibrium()
        gamma = stepsize.gamma_constant(game.constants(), TAU)
        r = PearlEngine(view=MeanFieldView()).run(
            game, x_star, tau=TAU, rounds=5, gamma=gamma,
            key=jax.random.PRNGKey(0), stochastic=False)
        assert r.rel_errors[0] == 0.0
        assert np.all(np.isfinite(r.rel_errors))


class TestSampledInteraction:
    def test_reproducible_across_runs(self, game):
        v = MeanFieldView(sample=8, seed=7)
        r1 = run(game, view=v)
        r2 = run(game, view=v)
        np.testing.assert_array_equal(np.asarray(r1.x_final),
                                      np.asarray(r2.x_final))

    def test_seed_changes_draws(self, game):
        r1 = run(game, view=MeanFieldView(sample=8, seed=0), rounds=5)
        r2 = run(game, view=MeanFieldView(sample=8, seed=1), rounds=5)
        assert not np.array_equal(np.asarray(r1.x_final),
                                  np.asarray(r2.x_final))

    def test_larger_sample_tracks_dense_summary(self, game):
        """More draws, less sampling noise: sample=n-1-ish should sit closer
        to the exact engine's iterate than a small sample does."""
        r_exact = run(game)
        errs = {}
        for k in (2, 32):
            r = run(game, view=MeanFieldView(sample=k, seed=5))
            errs[k] = float(np.abs(np.asarray(r.x_final)
                                   - np.asarray(r_exact.x_final)).max())
        assert errs[32] < errs[2]


class TestRejectionMatrix:
    def test_mean_field_needs_star(self, game):
        with pytest.raises(ValueError, match="single summary owner"):
            PearlEngine(topology=Ring(), view=MeanFieldView()).run(
                game, jnp.zeros((game.n, game.d)), tau=1, rounds=1, gamma=0.1)

    def test_star_view_needs_server(self):
        with pytest.raises(ValueError, match="server broadcast"):
            resolve_view(StarView(), Ring())

    def test_gossip_view_needs_graph(self):
        with pytest.raises(ValueError, match="has none"):
            resolve_view(GossipView(), Star())

    @pytest.mark.parametrize("sync", [PartialParticipation(fraction=0.5),
                                      DropoutSync(p=0.2)])
    def test_mean_field_rejects_masks(self, game, sync):
        with pytest.raises(ValueError, match="PARTIAL population"):
            run(game, view=MeanFieldView(), sync=sync, rounds=1)

    def test_mean_field_rejects_joint_update(self, game):
        with pytest.raises(ValueError, match="joint baselines require"):
            run(game, view=MeanFieldView(), update=JointExtragradientUpdate(),
                rounds=1)

    def test_mean_field_rejects_gossip_sweep_update(self, game):
        with pytest.raises(ValueError, match="no views to mix"):
            PearlEngine(update=DecentralizedExtragradientUpdate(),
                        view=MeanFieldView()).run(
                game, jnp.zeros((game.n, game.d)), tau=1, rounds=1, gamma=0.1)

    def test_mean_field_rejects_mesh(self, game):
        with pytest.raises(ValueError, match="needs no collective lowering"):
            PearlEngine(mesh=object(), view=MeanFieldView())._check_topology(
                game)

    def test_error_feedback_rejects_sampling(self, game):
        with pytest.raises(ValueError, match="no single wire tensor"):
            run(game, view=MeanFieldView(sample=4), sync=Int8Sync(), rounds=1)

    def test_non_aggregative_game_rejected(self):
        quad = make_quadratic_game(n=4, d=8, M=40, L_B=2.0, batch_size=1,
                                   seed=0)
        with pytest.raises(ValueError, match="AggregativeGame"):
            run(quad, view=MeanFieldView(), rounds=1)

    def test_insufficient_moments_rejected(self, game):
        class TwoMomentGame(MeanFieldQuadraticGame):
            summary_moments = 2

        g2 = TwoMomentGame(A=game.A, a=game.a, n=game.n, d=game.d,
                           beta=game.beta)
        with pytest.raises(ValueError, match="maintains only 1"):
            PearlEngine(view=MeanFieldView(moments=1))._check_topology(g2)

    def test_oversized_sample_rejected(self, game):
        with pytest.raises(ValueError, match="exceeds"):
            run(game, view=MeanFieldView(sample=game.n), rounds=1)

    def test_invalid_view_args(self):
        with pytest.raises(ValueError, match="moments"):
            MeanFieldView(moments=3)
        with pytest.raises(ValueError, match="sample"):
            MeanFieldView(sample=0)

    def test_async_rejects_sampling(self, game):
        with pytest.raises(ValueError, match="joint ring buffer"):
            AsyncPearlEngine(view=MeanFieldView(sample=4))._check(game)

    def test_async_rejects_masks(self, game):
        with pytest.raises(ValueError, match="PARTIAL population"):
            AsyncPearlEngine(view=MeanFieldView(),
                             sync=PartialParticipation(fraction=0.5))._check(game)

    def test_async_mean_field_needs_star(self):
        with pytest.raises(ValueError, match="single summary owner"):
            AsyncPearlEngine(topology=Ring(), view=MeanFieldView())._check()

    def test_registry_exposes_three_views(self):
        assert set(JOINT_VIEWS) == {"star", "gossip", "mean_field"}
        assert JOINT_VIEWS["mean_field"]().summary_based
        assert not JOINT_VIEWS["star"]().summary_based


class TestTrainerView:
    """The neural trainer accepts exactly the view its wire implements."""

    @pytest.fixture(scope="class")
    def cfg(self):
        from repro.configs import get_config

        return get_config("smollm-360m").smoke_variant()

    def _round(self, cfg, **kw):
        from repro.optim.optimizers import sgd
        from repro.train.pearl_trainer import make_pearl_round

        return make_pearl_round(cfg, sgd(1e-2), tau=2, prox_lambda=1e-3,
                                **kw)

    def test_uncorrected_mean_field_view_names_the_fast_path(self, cfg):
        fn = self._round(cfg, view=MeanFieldView(self_correction=False))
        assert callable(fn)

    def test_star_view_rejected(self, cfg):
        with pytest.raises(ValueError, match="never the"):
            self._round(cfg, view=StarView())

    def test_corrected_view_rejected(self, cfg):
        with pytest.raises(ValueError, match="only summary it implements"):
            self._round(cfg, view=MeanFieldView())

    def test_sampled_view_rejected(self, cfg):
        with pytest.raises(ValueError, match="only summary it implements"):
            self._round(cfg, view=MeanFieldView(self_correction=False,
                                                sample=2))

    def test_view_rejected_on_general_round(self, cfg):
        with pytest.raises(ValueError, match="stale-block round"):
            self._round(cfg, view=MeanFieldView(self_correction=False),
                        sync=PartialParticipation(fraction=0.5))
        with pytest.raises(ValueError, match="stale-block round"):
            self._round(cfg, view=MeanFieldView(self_correction=False),
                        topology=Ring())


class TestAsyncMeanField:
    @pytest.mark.parametrize("sync", [None, QuantizedSync(jnp.bfloat16),
                                      Int8Sync()])
    def test_d0_bit_for_bit_with_lockstep(self, game, sync):
        """D = 0 async mean-field IS the lockstep summary program — carry,
        RNG chain, wire, and the in-scan error outputs all collapse."""
        kw = {} if sync is None else {"sync": sync}
        gamma = stepsize.gamma_constant(game.constants(), TAU)
        x0 = jnp.zeros((game.n, game.d))
        r_sync = PearlEngine(view=MeanFieldView(), **kw).run(
            game, x0, tau=TAU, rounds=ROUNDS, gamma=gamma,
            key=jax.random.PRNGKey(0), stochastic=False)
        r_async = AsyncPearlEngine(view=MeanFieldView(), **kw).run(
            game, x0, tau=TAU, rounds=ROUNDS, gamma=gamma,
            key=jax.random.PRNGKey(0), stochastic=False)
        np.testing.assert_array_equal(np.asarray(r_async.x_final),
                                      np.asarray(r_sync.x_final))
        np.testing.assert_array_equal(r_async.rel_errors, r_sync.rel_errors)

    @pytest.mark.parametrize("self_correction", [True, False])
    def test_staleness_runs_and_converges(self, game, self_correction):
        gamma = stepsize.gamma_constant(game.constants(), TAU)
        r = AsyncPearlEngine(
            view=MeanFieldView(self_correction=self_correction),
            delays=UniformDelay(seed=1), max_staleness=3,
        ).run(game, jnp.zeros((game.n, game.d)), tau=TAU, rounds=200,
              gamma=gamma, key=jax.random.PRNGKey(0), stochastic=False)
        assert r.max_realized_staleness > 0
        assert r.rel_errors[-1] < 1e-2

    def test_stale_summary_differs_from_fresh(self, game):
        """ConstantDelay(1) must actually read LAST round's summary."""
        gamma = stepsize.gamma_constant(game.constants(), TAU)
        x0 = jnp.zeros((game.n, game.d))
        r0 = AsyncPearlEngine(view=MeanFieldView()).run(
            game, x0, tau=TAU, rounds=10, gamma=gamma, stochastic=False)
        r1 = AsyncPearlEngine(view=MeanFieldView(), delays=ConstantDelay(1),
                              max_staleness=1).run(
            game, x0, tau=TAU, rounds=10, gamma=gamma, stochastic=False)
        assert not np.array_equal(np.asarray(r0.x_final),
                                  np.asarray(r1.x_final))

    def test_ef_wire_survives_staleness(self, game):
        """Int8 error feedback banks an O(d) residual against the summary;
        under staleness the buffered slots hold decoded summaries."""
        gamma = stepsize.gamma_constant(game.constants(), TAU)
        r = AsyncPearlEngine(view=MeanFieldView(), sync=Int8Sync(),
                             delays=UniformDelay(seed=2), max_staleness=2,
                             ).run(game, jnp.zeros((game.n, game.d)),
                                   tau=TAU, rounds=150, gamma=gamma,
                                   stochastic=False)
        assert r.rel_errors[-1] < 1e-2
