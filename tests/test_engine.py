"""The unified PEARL engine: equivalence, new plugins, communication accounting.

The load-bearing test is the bit-for-bit equivalence of the engine against
compact copies of the PRE-ENGINE scan loops (the seed repo's ``_run`` and
``_pearl_eg_run``): the refactor must not perturb a single ULP of the paper
reproductions, including the RNG chain. The public ``pearl_sgd`` /
``pearl_eg`` adapters are exercised through the engine, so this pins the
whole stack.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import stepsize
from repro.core.baselines import pearl_eg
from repro.core.engine import (
    DropoutSync,
    ExactSync,
    ExtragradientUpdate,
    HeavyBallUpdate,
    JointExtragradientUpdate,
    OptimisticGradientUpdate,
    PartialParticipation,
    PearlEngine,
    QuantizedSync,
    SgdUpdate,
    as_round_gammas,
)
from repro.core.metrics import final_plateau
from repro.core.pearl import pearl_sgd

from helpers import (
    assert_runs_bitwise_equal,
    gaussian_x0,
    legacy_pearl_eg as _legacy_pearl_eg,
    legacy_pearl_sgd as _legacy_pearl_sgd,
    strong_quad,
)


@pytest.fixture(scope="module")
def quad():
    return strong_quad()


@pytest.fixture(scope="module")
def x0(quad):
    return gaussian_x0(quad)


# -------------------------------------------------------------- equivalence
class TestLegacyEquivalence:
    ROUNDS = 50

    @pytest.mark.parametrize("tau", [1, 4])
    @pytest.mark.parametrize("stochastic", [False, True])
    @pytest.mark.parametrize("sync_dtype", [None, jnp.bfloat16])
    def test_pearl_sgd_bit_for_bit(self, quad, x0, tau, stochastic, sync_dtype):
        c = quad.constants()
        gamma = stepsize.gamma_constant(c, tau)
        gammas = as_round_gammas(gamma, self.ROUNDS)
        key = jax.random.PRNGKey(0)
        x_ref, _ = _legacy_pearl_sgd(
            quad, x0, gammas, key, tau=tau, stochastic=stochastic,
            sync_dtype=sync_dtype,
        )
        r = pearl_sgd(
            quad, x0, tau=tau, rounds=self.ROUNDS, gamma=gamma, key=key,
            stochastic=stochastic, sync_dtype=sync_dtype,
        )
        np.testing.assert_array_equal(np.asarray(r.x_final), np.asarray(x_ref))

    @pytest.mark.parametrize("tau", [1, 4])
    @pytest.mark.parametrize("stochastic", [False, True])
    def test_pearl_eg_bit_for_bit(self, quad, x0, tau, stochastic):
        c = quad.constants()
        gamma = stepsize.gamma_constant(c, tau)
        gammas = as_round_gammas(gamma, self.ROUNDS)
        key = jax.random.PRNGKey(3)
        x_ref, _ = _legacy_pearl_eg(
            quad, x0, gammas, key, tau=tau, stochastic=stochastic,
        )
        r = pearl_eg(
            quad, x0, tau=tau, rounds=self.ROUNDS, gamma=gamma, key=key,
            stochastic=stochastic,
        )
        np.testing.assert_array_equal(np.asarray(r.x_final), np.asarray(x_ref))

    @pytest.mark.parametrize("stochastic", [False, True])
    def test_joint_extragradient_bit_for_bit(self, quad, x0, stochastic):
        """The fully-communicating EG baseline preserves the seed repo's
        key chain (key, k1, k2 = split(key, 3)) exactly."""
        from repro.core.baselines import extragradient

        c = quad.constants()
        gamma = jnp.float32(0.5 / c.L_F)
        gammas = as_round_gammas(gamma, self.ROUNDS)
        key = jax.random.PRNGKey(2)

        def step(carry, g):
            x, k = carry
            k, k1, k2 = jax.random.split(k, 3)
            if stochastic:
                x_half = x - g * quad.operator_stoch(x, k1)
                grad = quad.operator_stoch(x_half, k2)
            else:
                x_half = x - g * quad.operator(x)
                grad = quad.operator(x_half)
            return (x - g * grad, k), None

        (x_ref, _), _ = jax.lax.scan(step, (x0, key), gammas)
        r = extragradient(quad, x0, steps=self.ROUNDS, gamma=gamma, key=key,
                          stochastic=stochastic)
        np.testing.assert_array_equal(np.asarray(r.x_final), np.asarray(x_ref))

    def test_direct_engine_matches_adapter(self, quad, x0):
        """PearlEngine called directly == the pearl_sgd adapter."""
        c = quad.constants()
        gamma = stepsize.gamma_constant(c, 4)
        eng = PearlEngine(update=SgdUpdate(), sync=ExactSync())
        r1 = eng.run(quad, x0, tau=4, rounds=40, gamma=gamma,
                     key=jax.random.PRNGKey(1))
        r2 = pearl_sgd(quad, x0, tau=4, rounds=40, gamma=gamma,
                       key=jax.random.PRNGKey(1))
        assert_runs_bitwise_equal(r1, r2)


# ------------------------------------------------------------- new plugins
class TestNewUpdateRules:
    def test_optimistic_gradient_converges(self, quad, x0):
        c = quad.constants()
        gamma = stepsize.gamma_constant(c, 4)
        eng = PearlEngine(update=OptimisticGradientUpdate())
        r = eng.run(quad, x0, tau=4, rounds=2500, gamma=gamma, stochastic=False)
        assert r.rel_errors[-1] < 1e-3
        assert r.rel_errors[-1] < r.rel_errors[0]

    def test_heavy_ball_converges(self, quad, x0):
        c = quad.constants()
        gamma = stepsize.gamma_constant(c, 4)
        eng = PearlEngine(update=HeavyBallUpdate(beta=0.5))
        r = eng.run(quad, x0, tau=4, rounds=2500, gamma=gamma, stochastic=False)
        assert r.rel_errors[-1] < 1e-3

    def test_joint_eg_counts_two_syncs(self, quad, x0):
        c = quad.constants()
        eng = PearlEngine(update=JointExtragradientUpdate())
        r = eng.run(quad, x0, rounds=10, gamma=0.5 / c.L_F, stochastic=False)
        exact = PearlEngine().run(quad, x0, tau=1, rounds=10,
                                  gamma=0.5 / c.L_F, stochastic=False)
        assert r.total_bytes == 2 * exact.total_bytes


class TestSyncStrategies:
    def test_partial_participation_converges(self, quad, x0):
        """Random half of the players syncing each round still reaches the
        equilibrium (deterministic gradients, stale blocks for the rest)."""
        c = quad.constants()
        gamma = stepsize.gamma_constant(c, 4)
        eng = PearlEngine(update=SgdUpdate(),
                          sync=PartialParticipation(fraction=0.5, seed=0))
        r = eng.run(quad, x0, tau=4, rounds=3000, gamma=gamma, stochastic=False)
        assert r.rel_errors[-1] < 0.02

    def test_partial_participation_moves_fewer_bytes(self, quad, x0):
        c = quad.constants()
        gamma = stepsize.gamma_constant(c, 4)
        full = PearlEngine().run(quad, x0, tau=4, rounds=300, gamma=gamma,
                                 stochastic=False)
        part = PearlEngine(sync=PartialParticipation(fraction=0.5, seed=0)).run(
            quad, x0, tau=4, rounds=300, gamma=gamma, stochastic=False
        )
        assert 0 < part.total_bytes < full.total_bytes

    def test_dropout_converges_and_pays_full_bytes(self, quad, x0):
        c = quad.constants()
        gamma = stepsize.gamma_constant(c, 4)
        eng = PearlEngine(sync=DropoutSync(p=0.2, seed=1))
        r = eng.run(quad, x0, tau=4, rounds=2500, gamma=gamma, stochastic=False)
        assert r.rel_errors[-1] < 5e-3
        # unreliable links: transmissions are paid whether or not delivered
        full = PearlEngine().run(quad, x0, tau=4, rounds=2500, gamma=gamma,
                                 stochastic=False)
        assert r.total_bytes == full.total_bytes

    def test_strategy_randomness_does_not_perturb_noise_stream(self, quad, x0):
        """Switching sync strategy must not change the sampling-noise keys:
        with fraction=1.0 partial participation IS exact sync, bit-for-bit,
        even in the stochastic setting."""
        c = quad.constants()
        gamma = stepsize.gamma_constant(c, 4)
        key = jax.random.PRNGKey(5)
        exact = PearlEngine().run(quad, x0, tau=4, rounds=60, gamma=gamma,
                                  key=key)
        part = PearlEngine(sync=PartialParticipation(fraction=1.0)).run(
            quad, x0, tau=4, rounds=60, gamma=gamma, key=key
        )
        assert_runs_bitwise_equal(exact, part)

    def test_quantized_downlink_bytes_halved(self, quad, x0):
        c = quad.constants()
        gamma = stepsize.gamma_constant(c, 2)
        full = PearlEngine().run(quad, x0, tau=2, rounds=20, gamma=gamma)
        comp = PearlEngine(sync=QuantizedSync(jnp.bfloat16)).run(
            quad, x0, tau=2, rounds=20, gamma=gamma
        )
        np.testing.assert_array_equal(comp.bytes_up, full.bytes_up)
        np.testing.assert_array_equal(comp.bytes_down, full.bytes_down // 2)


# ------------------------------------------------------------- accounting
class TestCommAccounting:
    def test_exact_sync_bytes_match_section31(self, quad, x0):
        """up = n*d*bps per round; down = n * (n*d) * bps (joint vector to
        every player) — the CommunicationModel convention, per round."""
        r = PearlEngine().run(quad, x0, tau=4, rounds=7, gamma=1e-3)
        n, d = x0.shape
        bps = np.dtype(np.asarray(x0).dtype).itemsize
        assert r.bytes_up.shape == (7,)
        assert int(r.bytes_up[0]) == n * d * bps
        assert int(r.bytes_down[0]) == n * n * d * bps
        assert r.total_bytes == 7 * (n * d * bps + n * n * d * bps)

    def test_comm_report_derives_bytes_per_scalar(self):
        from repro.train.pearl_trainer import PearlCommReport

        exact = PearlCommReport(n_players=4, param_count=100, tau=2, rounds=3)
        bf16 = PearlCommReport(n_players=4, param_count=100, tau=2, rounds=3,
                               sync_dtype=jnp.bfloat16)
        assert exact.bytes_per_scalar == 4
        assert bf16.bytes_per_scalar == 2
        # trainer semantics: uplink quantized (pre-reduction), f32 mean
        # broadcast back — so bf16 saves the uplink half only
        assert bf16.downlink_bytes_per_scalar == 4
        assert bf16.total_bytes == exact.total_bytes * 3 // 4
        up, down = bf16.per_round_bytes()
        assert up.shape == (3,)
        assert int(up.sum() + down.sum()) == bf16.total_bytes

    def test_comm_report_from_sync(self):
        from repro.train.pearl_trainer import PearlCommReport

        rep = PearlCommReport.from_sync(
            QuantizedSync(jnp.bfloat16), n_players=2, param_count=10, tau=4,
            rounds=5,
        )
        assert rep.bytes_per_scalar == 2

    def test_trainer_accepts_mask_strategies(self):
        """Mask strategies and graph topologies now compile the general
        stale-block merge round (the PR 1 NotImplementedError is gone) —
        the two-signature dispatch is pinned here, end-to-end training in
        tests/test_pearl_trainer.py."""
        from repro.core.topology import Ring, Star
        from repro.train.pearl_trainer import needs_general_round

        assert needs_general_round(PartialParticipation(fraction=0.5), Star())
        assert needs_general_round(ExactSync(), Ring())
        assert not needs_general_round(ExactSync(), Star())
        assert not needs_general_round(QuantizedSync(jnp.bfloat16), Star())


# ------------------------------------------------------- bugfix regressions
class TestEngineValidation:
    """Regressions for the silent-failure sweep: loud errors instead of
    silently-wrong numbers."""

    def test_joint_update_rejects_non_exact_sync(self, quad, x0):
        """A JointUpdate never consults the sync strategy (no pre_round /
        mask / view) yet used to accept any strategy and bill ExactSync
        bytes — now a loud error."""
        for sync in (QuantizedSync(jnp.bfloat16),
                     PartialParticipation(fraction=0.5, seed=0),
                     DropoutSync(p=0.1, seed=0)):
            eng = PearlEngine(update=JointExtragradientUpdate(), sync=sync)
            with pytest.raises(ValueError, match="ExactSync"):
                eng.run(quad, x0, rounds=5, gamma=1e-3)

    def test_joint_update_with_exact_sync_still_runs(self, quad, x0):
        r = PearlEngine(update=JointExtragradientUpdate()).run(
            quad, x0, rounds=5, gamma=1e-3)
        assert np.isfinite(r.rel_errors).all()

    def test_rel_errors_finite_when_started_at_equilibrium(self, quad):
        """||x0 - x*||^2 = 0 used to NaN the whole rel_errors curve; the
        guarded denominator falls back to absolute squared errors (0 at the
        start, finite throughout)."""
        x_star = quad.equilibrium()
        r = PearlEngine().run(quad, x_star, tau=2, rounds=10, gamma=1e-3,
                              stochastic=False)
        assert np.isfinite(r.rel_errors).all()
        assert r.rel_errors[0] == 0.0
        # deterministic gradients from the equilibrium: F(x*) = 0, so the
        # iterates never move and the curve stays identically zero
        np.testing.assert_allclose(r.rel_errors, 0.0, atol=1e-12)

    def test_rel_errors_normalized_away_from_equilibrium(self, quad, x0):
        r = PearlEngine().run(quad, x0, tau=2, rounds=10, gamma=1e-3,
                              stochastic=False)
        assert r.rel_errors[0] == 1.0

    @pytest.mark.parametrize("bad", [{"tau": 0}, {"tau": -3}])
    def test_tau_validated(self, quad, x0, bad):
        """tau = 0 used to silently return the iterates unchanged via a
        zero-length inner scan."""
        with pytest.raises(ValueError, match="tau"):
            PearlEngine().run(quad, x0, rounds=5, gamma=1e-3, **bad)
        with pytest.raises(ValueError, match="tau"):
            PearlEngine().trajectory(quad, x0, rounds=5, gamma=1e-3, **bad)

    def test_rounds_validated(self, quad, x0):
        with pytest.raises(ValueError, match="rounds"):
            PearlEngine().run(quad, x0, tau=2, rounds=0, gamma=1e-3)

    def test_make_pearl_round_validates_tau(self):
        """The neural-trainer round mirrors the engine's tau check."""
        from repro.configs import get_config
        from repro.optim.optimizers import sgd
        from repro.train.pearl_trainer import make_pearl_round

        cfg = get_config("smollm-360m").smoke_variant()
        with pytest.raises(ValueError, match="tau"):
            make_pearl_round(cfg, sgd(1e-2), tau=0, prox_lambda=1e-3)


# --------------------------------------------------------------- schedules
class TestSchedules:
    def test_warmup_cosine_shape(self):
        sched = stepsize.gamma_warmup_cosine(1.0, 100, warmup_frac=0.1,
                                             final_frac=0.1)
        assert sched.shape == (100,)
        assert np.argmax(sched) == 9            # peak at the end of warmup
        assert sched[0] < sched[9]
        assert sched[-1] == pytest.approx(0.1, rel=1e-6)

    def test_warmup_cosine_as_engine_schedule(self, quad, x0):
        """The callable form plugs straight into the engine's gamma arg."""
        c = quad.constants()
        peak = stepsize.gamma_constant(c, 4)
        sched = stepsize.gamma_warmup_cosine(peak, warmup_frac=0.05)
        r = pearl_sgd(quad, x0, tau=4, rounds=2500, gamma=sched,
                      stochastic=False)
        assert r.rel_errors[-1] < 0.05

    def test_bad_gamma_shape_raises(self):
        with pytest.raises(ValueError):
            as_round_gammas(np.ones(7), 9)
