"""Step-size policies: identity pins, monotonicity, loud mismatches, DEG.

The load-bearing tests are the trace-time identity pins — ``theorem34``
compiles the literal policy-free program, and ``delay_adaptive`` at D = 0
reproduces it bit-for-bit on the star — which anchor the policy layer to
the PR 1-3 numerics. Around them: the hypothesis property that the
delay-corrected Theorem 3.4 rule is monotone non-increasing in BOTH tau and
the delay, the strong-coupling rescue (the BENCH_async.json headline in
small), the decentralized-extragradient stability margin on the ring, and
every policy/engine mismatch rejecting loudly instead of silently running
with defaults.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import stepsize
from repro.core.async_engine import (
    AsyncPearlEngine,
    StragglerDelay,
    UniformDelay,
)
from repro.core.engine import (
    DecentralizedExtragradientUpdate,
    JointExtragradientUpdate,
    PartialParticipation,
    PearlEngine,
    build_round_context,
)
from repro.core.games import make_quadratic_game
from repro.core.metrics import rounds_to_reach
from repro.core.stepsize import (
    STEPSIZE_POLICIES,
    DelayAdaptivePolicy,
    RoundContext,
    SpectralPolicy,
    Theorem34Policy,
    gamma_delay_adaptive,
    resolve_policy,
)
from repro.core.topology import Ring


@pytest.fixture(scope="module")
def quad():
    return make_quadratic_game(n=4, d=8, M=40, batch_size=1, seed=0)


@pytest.fixture(scope="module")
def weak():
    return make_quadratic_game(n=6, d=10, M=40, L_B=1.0, batch_size=1, seed=0)


@pytest.fixture(scope="module")
def strong():
    """Strong coupling: bounded staleness at the fixed Theorem 3.4 step size
    diverges outright (the regime the delay-adaptive policy rescues)."""
    return make_quadratic_game(n=6, d=10, M=40, L_B=5.0, batch_size=1, seed=0)


@pytest.fixture(scope="module")
def ring_strong():
    """Strong coupling for the ring: plain gossip diverges at every
    gossip_steps tried (the regime spectral/DEG rescue)."""
    return make_quadratic_game(n=6, d=10, M=40, L_B=2.5, batch_size=1, seed=0)


def _x0(game, seed=0):
    return jnp.asarray(
        np.random.default_rng(seed).standard_normal((game.n, game.d)),
        dtype=jnp.float32,
    )


# ---------------------------------------------------------- identity pins
class TestIdentityPins:
    @pytest.mark.parametrize("stochastic", [False, True])
    def test_theorem34_is_the_policy_free_program(self, quad, stochastic):
        """policy='theorem34' compiles the literal policy-free engine —
        bit-for-bit, including bytes."""
        gamma = stepsize.gamma_constant(quad.constants(), 4)
        x0 = _x0(quad)
        key = jax.random.PRNGKey(0)
        a = PearlEngine().run(quad, x0, tau=4, rounds=40, gamma=gamma,
                              key=key, stochastic=stochastic)
        b = PearlEngine(policy="theorem34").run(
            quad, x0, tau=4, rounds=40, gamma=gamma, key=key,
            stochastic=stochastic)
        np.testing.assert_array_equal(np.asarray(a.x_final),
                                      np.asarray(b.x_final))
        np.testing.assert_array_equal(a.rel_errors, b.rel_errors)
        np.testing.assert_array_equal(a.bytes_up, b.bytes_up)

    @pytest.mark.parametrize("stochastic", [False, True])
    def test_delay_adaptive_d0_bit_for_bit_star(self, quad, stochastic):
        """delay_adaptive at D = 0 reduces to theorem34 AT TRACE TIME: the
        async engine with a zero staleness bound reproduces the lockstep
        engine bit-for-bit on the star, policy and all."""
        gamma = stepsize.gamma_constant(quad.constants(), 4)
        x0 = _x0(quad)
        key = jax.random.PRNGKey(1)
        lockstep = PearlEngine().run(quad, x0, tau=4, rounds=40, gamma=gamma,
                                     key=key, stochastic=stochastic)
        adaptive = AsyncPearlEngine(delays=UniformDelay(seed=3),
                                    max_staleness=0,
                                    policy="delay_adaptive").run(
            quad, x0, tau=4, rounds=40, gamma=gamma, key=key,
            stochastic=stochastic)
        np.testing.assert_array_equal(np.asarray(adaptive.x_final),
                                      np.asarray(lockstep.x_final))
        np.testing.assert_array_equal(adaptive.rel_errors,
                                      lockstep.rel_errors)

    def test_gossip_theorem34_is_policy_free(self, weak):
        gamma = stepsize.gamma_constant(weak.constants(), 4)
        x0 = _x0(weak)
        a = PearlEngine(topology=Ring()).run(
            weak, x0, tau=4, rounds=30, gamma=gamma, stochastic=False)
        b = PearlEngine(topology=Ring(), policy=Theorem34Policy()).run(
            weak, x0, tau=4, rounds=30, gamma=gamma, stochastic=False)
        np.testing.assert_array_equal(np.asarray(a.x_final),
                                      np.asarray(b.x_final))

    def test_identity_policy_shares_jit_cache_across_games(self):
        """The default engine must NOT retrace per game instance: the round
        context (static, game-derived floats) is only built for policies
        that read it."""
        from repro.core.engine import _engine_scan

        g1 = make_quadratic_game(n=3, d=6, M=10, L_B=1.0, batch_size=1,
                                 seed=11)
        g2 = make_quadratic_game(n=3, d=6, M=10, L_B=2.0, batch_size=1,
                                 seed=12)
        kw = dict(tau=2, rounds=5, gamma=1e-3, stochastic=False)
        PearlEngine().run(g1, _x0(g1, seed=1), **kw)
        size_after_first = _engine_scan._cache_size()
        PearlEngine().run(g2, _x0(g2, seed=2), **kw)
        assert _engine_scan._cache_size() == size_after_first

    def test_spectral_identity_when_uncoupled_or_fully_mixing(self):
        """C = 0 (uncoupled) or lag = 0 (exact mixing) resolves to the
        identity at trace time."""
        pol = SpectralPolicy()
        uncoupled = RoundContext(tau=4, spectral_gap=0.5, coupling=1.0)
        mixing = RoundContext(tau=4, spectral_gap=1.0, coupling=7.0)
        sentinel = object()
        assert pol.round_gammas(sentinel, uncoupled) is sentinel
        assert pol.round_gammas(sentinel, mixing) is sentinel


# ----------------------------------------------------------- monotonicity
class TestMonotonicity:
    def test_reduces_to_gamma_constant_at_zero_delay(self, quad):
        c = quad.constants()
        for tau in (1, 2, 8):
            assert gamma_delay_adaptive(c, tau, 0) == pytest.approx(
                stepsize.gamma_constant(c, tau))

    def test_per_player_row_monotone_in_delay(self, quad):
        c = quad.constants()
        row = gamma_delay_adaptive(c, 4, np.array([0, 1, 4, 16]))
        assert (np.diff(row) < 0).all()

    def test_policy_row_matches_helper(self, quad):
        """The in-scan policy applies exactly the documented correction."""
        c = quad.constants()
        gamma = stepsize.gamma_constant(c, 4)
        delays = np.array([0, 2, 5, 16], dtype=np.int32)
        ctx = RoundContext(tau=4, max_staleness=16, delay_row=delays)
        row = np.asarray(DelayAdaptivePolicy().round_gammas(gamma, ctx))
        np.testing.assert_allclose(row, gamma_delay_adaptive(c, 4, delays),
                                   rtol=1e-6)


class TestMonotonicityProperty:
    """Hypothesis property: gamma_delay_adaptive is monotone non-increasing
    in BOTH tau and D (the shape Theorem 3.4's stability argument needs)."""

    def test_monotone_in_tau_and_delay(self, quad):
        pytest.importorskip("hypothesis",
                            reason="property tests need hypothesis")
        from hypothesis import given, settings, strategies as st

        c = quad.constants()

        @settings(max_examples=200, deadline=None)
        @given(tau=st.integers(min_value=1, max_value=256),
               delay=st.floats(min_value=0.0, max_value=1e3),
               dtau=st.integers(min_value=1, max_value=64),
               ddelay=st.floats(min_value=0.0, max_value=1e3))
        def prop(tau, delay, dtau, ddelay):
            g = gamma_delay_adaptive(c, tau, delay)
            assert gamma_delay_adaptive(c, tau + dtau, delay) <= g + 1e-18
            assert gamma_delay_adaptive(c, tau, delay + ddelay) <= g + 1e-18

        prop()


# ------------------------------------------------------ the rescue (small)
class TestStrongCouplingRescue:
    """BENCH_async.json / BENCH_engine.json headlines, shrunk to test size."""

    def test_delay_adaptive_rescues_straggler_d16(self, strong):
        gamma = stepsize.gamma_constant(strong.constants(), 4)
        x0 = _x0(strong)
        kw = dict(tau=4, rounds=800, gamma=gamma, key=jax.random.PRNGKey(0),
                  stochastic=False)
        sched = StragglerDelay(fraction=0.25, seed=0)
        fixed = AsyncPearlEngine(delays=sched, max_staleness=16).run(
            strong, x0, **kw)
        adaptive = AsyncPearlEngine(delays=sched, max_staleness=16,
                                    policy="delay_adaptive").run(
            strong, x0, **kw)
        f = float(fixed.rel_errors[-1])
        assert not np.isfinite(f) or f > 1e3        # fixed diverges
        assert float(adaptive.rel_errors[-1]) < 0.5  # adaptive contracts

    def test_spectral_rescues_ring_at_gossip_steps_1(self, ring_strong):
        gamma = stepsize.gamma_constant(ring_strong.constants(), 4)
        x0 = _x0(ring_strong)
        kw = dict(tau=4, rounds=1000, gamma=gamma, stochastic=False)
        fixed = PearlEngine(topology=Ring()).run(ring_strong, x0, **kw)
        more_sweeps = PearlEngine(topology=Ring(), gossip_steps=4).run(
            ring_strong, x0, **kw)
        spectral = PearlEngine(topology=Ring(), policy="spectral").run(
            ring_strong, x0, **kw)
        for diverging in (fixed, more_sweeps):
            f = float(diverging.rel_errors[-1])
            assert not np.isfinite(f) or f > 1e3
        assert float(spectral.rel_errors[-1]) < 0.1

    def test_deg_converges_where_plain_gossip_cannot(self, ring_strong):
        """DEG x spectral at gossip_steps = 1 converges markedly faster than
        sgd x spectral (the correction phase sees the extrapolated views),
        while DEG x theorem34 confirms the policy is still needed."""
        gamma = stepsize.gamma_constant(ring_strong.constants(), 4)
        x0 = _x0(ring_strong)
        kw = dict(tau=4, rounds=1000, gamma=gamma, stochastic=False)
        deg_fixed = PearlEngine(update=DecentralizedExtragradientUpdate(),
                                topology=Ring()).run(ring_strong, x0, **kw)
        f = float(deg_fixed.rel_errors[-1])
        assert not np.isfinite(f) or f > 1e3
        deg = PearlEngine(update=DecentralizedExtragradientUpdate(),
                          topology=Ring(), policy="spectral").run(
            ring_strong, x0, **kw)
        sgd = PearlEngine(topology=Ring(), policy="spectral").run(
            ring_strong, x0, **kw)
        assert float(deg.rel_errors[-1]) < 1e-2
        assert float(deg.rel_errors[-1]) < float(sgd.rel_errors[-1])


# ------------------------------------------------- decentralized EG basics
class TestDecentralizedExtragradient:
    def test_converges_on_weak_coupling_ring(self, weak):
        gamma = stepsize.gamma_constant(weak.constants(), 4)
        r = PearlEngine(update=DecentralizedExtragradientUpdate(),
                        topology=Ring()).run(
            weak, _x0(weak), tau=4, rounds=400, gamma=gamma,
            stochastic=False)
        assert rounds_to_reach(r.rel_errors, 1e-6) is not None

    def test_bills_two_sweeps_per_round(self, weak):
        """DEG moves exactly twice the wire of a gossip_steps = 1 round."""
        gamma = stepsize.gamma_constant(weak.constants(), 4)
        kw = dict(tau=4, rounds=5, gamma=gamma, stochastic=False)
        deg = PearlEngine(update=DecentralizedExtragradientUpdate(),
                          topology=Ring()).run(weak, _x0(weak), **kw)
        sgd = PearlEngine(topology=Ring()).run(weak, _x0(weak), **kw)
        np.testing.assert_array_equal(deg.bytes_up, 2 * sgd.bytes_up)


# -------------------------------------------------------------- validation
class TestValidation:
    def test_lockstep_engine_rejects_delay_adaptive(self, quad):
        eng = PearlEngine(policy="delay_adaptive")
        with pytest.raises(ValueError, match="AsyncPearlEngine"):
            eng.run(quad, _x0(quad), rounds=5, gamma=1e-3)

    def test_star_rejects_spectral(self, quad):
        with pytest.raises(ValueError, match="server-free"):
            PearlEngine(policy="spectral").run(
                quad, _x0(quad), rounds=5, gamma=1e-3)
        with pytest.raises(ValueError, match="server-free"):
            AsyncPearlEngine(policy="spectral").run(
                quad, _x0(quad), rounds=5, gamma=1e-3)

    def test_joint_update_rejects_non_identity_policy(self, quad):
        eng = PearlEngine(update=JointExtragradientUpdate(),
                          policy=SpectralPolicy(), topology=Ring())
        with pytest.raises(ValueError, match="theorem34"):
            eng.run(quad, _x0(quad), rounds=5, gamma=1e-3)

    def test_deg_rejected_on_star_and_under_masks_and_async(self, quad):
        with pytest.raises(ValueError, match="JointExtragradientUpdate"):
            PearlEngine(update=DecentralizedExtragradientUpdate()).run(
                quad, _x0(quad), rounds=5, gamma=1e-3)
        with pytest.raises(ValueError, match="full participation"):
            PearlEngine(update=DecentralizedExtragradientUpdate(),
                        topology=Ring(),
                        sync=PartialParticipation(fraction=0.5, seed=0)).run(
                quad, _x0(quad), rounds=5, gamma=1e-3)
        with pytest.raises(ValueError, match="delayed equivalent"):
            AsyncPearlEngine(update=DecentralizedExtragradientUpdate(),
                             topology=Ring()).run(
                quad, _x0(quad), rounds=5, gamma=1e-3)

    def test_unknown_policy_name_rejected(self):
        with pytest.raises(ValueError, match="unknown step-size policy"):
            resolve_policy("nope")

    def test_bad_strengths_rejected(self):
        with pytest.raises(ValueError, match="strength"):
            DelayAdaptivePolicy(strength=0.0)
        with pytest.raises(ValueError, match="strength"):
            SpectralPolicy(strength=-1.0)

    def test_registry_round_trips(self):
        for name, ctor in STEPSIZE_POLICIES.items():
            assert resolve_policy(name) == ctor()
        assert resolve_policy(None) == Theorem34Policy()

    def test_trainer_round_rejects_mismatches(self):
        """make_pearl_round refuses policies the compiled round cannot
        honor (no staleness counters / no mixing spectrum)."""
        from repro.configs import get_config
        from repro.optim.optimizers import sgd
        from repro.train.pearl_trainer import make_pearl_round

        cfg = get_config("smollm-360m").smoke_variant()
        with pytest.raises(ValueError, match="staleness"):
            make_pearl_round(cfg, sgd(1e-2), tau=2, prox_lambda=0.1,
                             policy="delay_adaptive")
        with pytest.raises(ValueError, match="spectral gap"):
            make_pearl_round(cfg, sgd(1e-2), tau=2, prox_lambda=0.1,
                             policy="spectral")

    def test_trainer_spectral_requires_coupling_estimate(self):
        """spectral with the default coupling=1.0 would silently be the
        identity — the trainer demands an explicit L_F/L_max estimate."""
        from repro.configs import get_config
        from repro.optim.optimizers import sgd
        from repro.train.pearl_trainer import PearlTrainer

        cfg = get_config("smollm-360m").smoke_variant()
        with pytest.raises(ValueError, match="coupling"):
            PearlTrainer(cfg, sgd(1e-2), n_players=3, tau=2,
                         prox_lambda=0.1, topology=Ring(),
                         policy="spectral")


# ------------------------------------------------------------ context glue
class TestRoundContext:
    def test_build_round_context_star_and_ring(self, weak):
        star_ctx = build_round_context(weak, __import__(
            "repro.core.topology", fromlist=["Star"]).Star(), tau=4)
        assert star_ctx.spectral_gap == 1.0
        c = weak.constants()
        assert star_ctx.coupling == pytest.approx(c.L_F / c.L_max)
        ring_ctx = build_round_context(weak, Ring(), tau=4,
                                       max_staleness=3)
        assert 0.0 < ring_ctx.spectral_gap < 1.0
        assert ring_ctx.max_staleness == 3
        assert ring_ctx.delay_row is None
        row = np.arange(weak.n)
        assert ring_ctx.with_delays(row).delay_row is row

    def test_constantless_game_gets_neutral_coupling(self):
        from repro.core.game import VectorGame
        from repro.core.topology import Star

        class Bare(VectorGame):
            n, d = 2, 3

            def player_grad(self, i, x_i, x_ref):
                return x_i

        ctx = build_round_context(Bare(), Star(), tau=2)
        assert ctx.coupling == 1.0
