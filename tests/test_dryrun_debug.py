"""Dry-run integration tests on a small fake-device mesh (subprocess).

jax pins the device count at first init, so these run
``--xla_force_host_platform_device_count=8`` in fresh subprocesses: a
(2, 2, 2) pod/data/model mesh exercising the same builders as the 512-chip
production dry-run, including the PEARL pod-axis round.
"""

import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


@pytest.mark.slow
class TestDebugMeshDryrun:
    def test_train_step_lowers_and_compiles_on_2x2x2(self):
        out = _run("""
            import dataclasses, jax, json
            from repro.configs import get_config
            from repro.configs.shapes import InputShape
            from repro.launch.mesh import make_debug_mesh
            from repro.launch.builders import build_train_lowered
            from repro.roofline.analysis import parse_collectives

            cfg = get_config('smollm-360m').smoke_variant()
            cfg = dataclasses.replace(cfg, d_model=256, n_heads=4, n_kv_heads=2,
                                      head_dim=64)
            shape = InputShape('t', 64, 8, 'train')
            mesh = make_debug_mesh(pod=2, data=2, model=2)
            lowered, _ = build_train_lowered(cfg, shape, mesh)
            compiled = lowered.compile()
            coll = parse_collectives(compiled.as_text(), chips_per_pod=4)
            ca = compiled.cost_analysis()
            ca = ca[0] if isinstance(ca, list) else ca  # jax<0.4.30 wraps in a list
            print(json.dumps({'flops': ca['flops'],
                              'coll_ops': coll.count,
                              'coll_bytes': coll.total_bytes}))
        """)
        rec = json.loads(out.strip().splitlines()[-1])
        assert rec["flops"] > 0
        assert rec["coll_ops"] > 0       # grad all-reduce at minimum
        assert rec["coll_bytes"] > 0

    def test_decode_step_lowers_on_2x2(self):
        out = _run("""
            import jax, json
            from repro.configs import get_config
            from repro.configs.shapes import InputShape
            from repro.launch.mesh import make_debug_mesh
            from repro.launch.builders import build_decode_lowered

            cfg = get_config('zamba2-1.2b').smoke_variant()
            shape = InputShape('d', 128, 8, 'decode')
            mesh = make_debug_mesh(data=4, model=2)
            lowered, _ = build_decode_lowered(cfg, shape, mesh,
                                              window=cfg.sliding_window)
            compiled = lowered.compile()
            ca = compiled.cost_analysis()
            ca = ca[0] if isinstance(ca, list) else ca  # jax<0.4.30 wraps in a list
            print(json.dumps({'ok': True, 'flops': ca['flops']}))
        """)
        assert json.loads(out.strip().splitlines()[-1])["ok"]

    def test_pearl_round_pod_collective_scales_inversely_with_tau(self):
        """The paper's claim on compiled HLO: pod-axis sync bytes per LOCAL
        STEP fall by ~tau when tau grows (sync cost amortized)."""
        out = _run("""
            import json
            from repro.configs import get_config
            from repro.configs.shapes import InputShape
            from repro.launch.mesh import make_debug_mesh
            from repro.launch.builders import build_pearl_lowered
            from repro.roofline.analysis import parse_collectives

            cfg = get_config('smollm-360m').smoke_variant()
            shape = InputShape('t', 64, 4, 'train')
            mesh = make_debug_mesh(pod=2, data=2, model=2)
            res = {}
            for tau in (1, 4):
                lowered, _ = build_pearl_lowered(cfg, shape, mesh, tau=tau,
                                                 n_players=2)
                hlo = lowered.compile().as_text()
                coll = parse_collectives(hlo, chips_per_pod=4)
                res[tau] = coll.pod_bytes / tau
            print(json.dumps(res))
        """)
        rec = json.loads(out.strip().splitlines()[-1])
        per_step_tau1 = rec["1"]
        per_step_tau4 = rec["4"]
        assert per_step_tau1 > 0
        # tau=4 amortizes the sync across 4 local steps
        assert per_step_tau4 < 0.5 * per_step_tau1
