"""Figures 2a/2b: PEARL-SGD on the quadratic n-player game (Section 4.1).

- Deterministic (Fig 2a): with the theoretical gamma ~ 1/tau, all tau produce
  indistinguishable per-round error curves. Derived metric: max/min spread of
  the final relative errors across tau (should be ~1).
- Stochastic (Fig 2b): larger tau reaches a smaller error within the same
  communication budget. Derived metric: plateau(tau)/plateau(1) < 1, and the
  communication-round savings at a fixed accuracy threshold.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import stepsize
from repro.core.games import make_quadratic_game
from repro.core.metrics import communication_savings, final_plateau
from repro.core.pearl import pearl_sgd, pearl_sgd_mean

TAUS = (1, 2, 4, 5, 8, 20)


def run(rounds_det: int = 300, rounds_sto: int = 2000, n_seeds: int = 5):
    game = make_quadratic_game(n=5, d=10, M=100, batch_size=1, seed=0)
    c = game.constants()
    x0 = jnp.asarray(np.random.default_rng(1).standard_normal((game.n, game.d)))

    # ---- Fig 2a: deterministic ----
    finals = {}
    t0 = time.perf_counter()
    for tau in TAUS:
        gamma = stepsize.gamma_constant(c, tau)
        r = pearl_sgd(game, x0, tau=tau, rounds=rounds_det, gamma=gamma,
                      stochastic=False)
        finals[tau] = r.rel_errors[-1]
    us = (time.perf_counter() - t0) * 1e6 / len(TAUS)
    spread = max(finals.values()) / min(finals.values())
    emit("fig2a_deterministic_tau_spread", us,
         f"spread={spread:.3f};finals=" + "|".join(
             f"tau{t}:{v:.3e}" for t, v in finals.items()))

    # ---- Fig 2b: stochastic ----
    errors_by_tau = {}
    t0 = time.perf_counter()
    for tau in TAUS:
        gamma = stepsize.gamma_constant(c, tau)
        mean, _ = pearl_sgd_mean(game, x0, tau=tau, rounds=rounds_sto,
                                 gamma=gamma, n_seeds=n_seeds)
        errors_by_tau[tau] = mean
    us = (time.perf_counter() - t0) * 1e6 / len(TAUS)
    plateaus = {t: final_plateau(e, 100) for t, e in errors_by_tau.items()}
    ratio20 = plateaus[20] / plateaus[1]
    threshold = 2.0 * plateaus[20]
    try:
        savings = communication_savings(errors_by_tau, threshold)
        best = max(savings.items(), key=lambda kv: kv[1])
        sav = f"best_savings=tau{best[0]}x{best[1]:.1f}"
    except ValueError:
        sav = "best_savings=n/a"
    emit("fig2b_stochastic_neighborhood", us,
         f"plateau_ratio_tau20={ratio20:.3f};{sav};plateaus=" + "|".join(
             f"tau{t}:{v:.2e}" for t, v in plateaus.items()))
    return finals, plateaus


if __name__ == "__main__":
    run()
