"""Engine sweeps: update x sync matrix, bytes-to-equilibrium by topology,
and the gossip step-size-policy/extragradient stability sweep.

Three benchmarks on the quadratic game:

- ``run``: one row per (update, sync) cell — final relative error after a
  fixed communication budget plus the engine's per-round byte accounting;
- ``run_topologies``: the topology layer's headline question — how many WIRE
  BYTES does each communication graph need to reach the equilibrium
  neighborhood, swept over (star | ring | Erdos-Renyi) x tau. Star pays the
  server downlink (``n`` blocks to every player); gossip pays per active edge
  but relays full views and tolerates less coupling, so bytes-to-equilibrium
  is the honest comparison, with edge-aware per-round accounting from
  :mod:`repro.core.topology`;
- ``run_gossip_policies``: strong-coupling ring where plain gossip diverges
  for every ``gossip_steps`` tried — the ``spectral`` step-size policy and
  the decentralized extragradient restore convergence at gossip_steps = 1.

``python -m benchmarks.bench_engine --json BENCH_engine.json`` writes the
sweeps as structured JSON so future PRs can track bytes-to-equilibrium.
"""

from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import stepsize
from repro.core.engine import (
    DecentralizedExtragradientUpdate,
    DropoutSync,
    ExactSync,
    ExtragradientUpdate,
    HeavyBallUpdate,
    OptimisticGradientUpdate,
    PartialParticipation,
    PearlEngine,
    QuantizedSync,
    SgdUpdate,
)
from repro.core.games import make_quadratic_game
from repro.core.metrics import rounds_to_reach
from repro.core.topology import ErdosRenyi, Ring, Star


UPDATES = {
    "sgd": SgdUpdate(),
    "eg": ExtragradientUpdate(),
    "ogda": OptimisticGradientUpdate(),
    "hb": HeavyBallUpdate(beta=0.5),
}

SYNCS = {
    "exact": ExactSync(),
    "bf16": QuantizedSync(jnp.bfloat16),
    "partial": PartialParticipation(fraction=0.5, seed=0),
    "dropout": DropoutSync(p=0.1, seed=0),
}

TOPOLOGIES = {
    "star": Star(),
    "ring": Ring(),
    "erdos_renyi": ErdosRenyi(p=0.5, seed=2),   # seed chosen connected at n=6
}


def run(tau: int = 4, rounds: int = 800):
    game = make_quadratic_game(n=5, d=10, M=40, batch_size=1, seed=0)
    c = game.constants()
    gamma = stepsize.gamma_constant(c, tau)
    x0 = jnp.asarray(
        np.random.default_rng(0).standard_normal((game.n, game.d)),
        dtype=jnp.float32,
    )

    rows = []
    t0 = time.perf_counter()
    for uname, update in UPDATES.items():
        for sname, sync in SYNCS.items():
            r = PearlEngine(update=update, sync=sync).run(
                game, x0, tau=tau, rounds=rounds, gamma=gamma,
                key=jax.random.PRNGKey(0), stochastic=False,
            )
            rows.append((uname, sname, r.rel_errors[-1], r.total_bytes))
    us = (time.perf_counter() - t0) * 1e6 / len(rows)

    derived = ";".join(
        f"{u}x{s}:err={e:.2e},KB={b / 1e3:.0f}" for u, s, e, b in rows
    )
    emit("engine_matrix", us, derived)
    return rows


def run_topologies(taus=(1, 4, 16), rounds: int = 4000,
                   threshold: float = 1e-4):
    """Bytes-to-equilibrium: star vs ring vs random graph x tau.

    Weak-coupling game (gossip's stability margin shrinks with coupling: the
    stale inconsistent views act like delays under the antisymmetric
    coupling). Reports, per (topology, tau): rounds to reach ``threshold``
    relative error and the cumulative edge-aware wire bytes at that round
    (None when never reached within the budget).
    """
    game = make_quadratic_game(n=6, d=10, M=40, L_B=1.0, batch_size=1, seed=0)
    c = game.constants()
    x0 = jnp.asarray(
        np.random.default_rng(0).standard_normal((game.n, game.d)),
        dtype=jnp.float32,
    )

    rows = []
    t0 = time.perf_counter()
    for tname, topo in TOPOLOGIES.items():
        for tau in taus:
            gamma = stepsize.gamma_constant(c, tau)
            r = PearlEngine(topology=topo).run(
                game, x0, tau=tau, rounds=rounds, gamma=gamma,
                stochastic=False,
            )
            # rel_errors[0] is the pre-communication sentinel, so index
            # ``hit`` means "after hit rounds" and per_round[:hit] is exactly
            # the wire traffic spent to get there (hit=0 -> 0 bytes).
            hit = rounds_to_reach(r.rel_errors, threshold)
            final = float(r.rel_errors[-1])
            per_round = r.bytes_up + r.bytes_down
            bytes_to_eq = int(per_round[:hit].sum()) if hit is not None else None
            rows.append({
                "topology": tname,
                "tau": tau,
                "rounds": rounds,   # the budget, for budget-aware drift checks
                "rounds_to_eq": hit,
                "bytes_to_eq": bytes_to_eq,
                "final_rel_error": final,
                "diverged": bool(not np.isfinite(final) or final > 1e3),
                "bytes_per_round": int(per_round[0]),
            })
    us = (time.perf_counter() - t0) * 1e6 / len(rows)

    def _fmt(row):
        kb = "-" if row["bytes_to_eq"] is None else f"{row['bytes_to_eq'] / 1e3:.0f}"
        return (f"{row['topology']}xtau{row['tau']}:"
                f"R={row['rounds_to_eq']},KB={kb}")

    emit("engine_topology", us, ";".join(_fmt(r) for r in rows))
    return rows


def run_gossip_policies(tau: int = 4, rounds: int = 4000,
                        threshold: float = 1e-6):
    """Gossip stability at strong coupling: fixed vs spectral vs DEG.

    Ring topology on a strongly-coupled quadratic game (L_B = 2.5 — past
    the point where ANY ``gossip_steps`` stabilizes the fixed Theorem 3.4
    step size): the rows pin that (a) plain gossip diverges at gossip_steps
    1 AND 4 — the PR 2 bytes-for-margin tradeoff has run out; (b) the
    ``spectral`` policy (gamma divided by the Metropolis mixing-lag x excess
    coupling) restores convergence at gossip_steps = 1 with zero extra wire
    bytes; (c) the decentralized extragradient converges in ~half the
    rounds at the same per-sweep wire rate (2 sweeps/round), because its
    correction phase sees the extrapolated neighborhood view instead of
    paying for more averaging.
    """
    game = make_quadratic_game(n=6, d=10, M=40, L_B=2.5, batch_size=1,
                               seed=0)
    c = game.constants()
    gamma = stepsize.gamma_constant(c, tau)
    x0 = jnp.asarray(
        np.random.default_rng(0).standard_normal((game.n, game.d)),
        dtype=jnp.float32,
    )

    cells = [
        ("sgd", "theorem34", 1, PearlEngine(topology=Ring())),
        ("sgd", "theorem34", 4, PearlEngine(topology=Ring(),
                                            gossip_steps=4)),
        ("sgd", "spectral", 1, PearlEngine(topology=Ring(),
                                           policy="spectral")),
        ("decentralized_eg", "theorem34", 1,
         PearlEngine(update=DecentralizedExtragradientUpdate(),
                     topology=Ring())),
        ("decentralized_eg", "spectral", 1,
         PearlEngine(update=DecentralizedExtragradientUpdate(),
                     topology=Ring(), policy="spectral")),
    ]

    rows = []
    t0 = time.perf_counter()
    for uname, pname, gs, eng in cells:
        r = eng.run(game, x0, tau=tau, rounds=rounds, gamma=gamma,
                    stochastic=False)
        final = float(r.rel_errors[-1])
        hit = rounds_to_reach(r.rel_errors, threshold)
        per_round = r.bytes_up + r.bytes_down
        rows.append({
            "update": uname,
            "policy": pname,
            "gossip_steps": gs,
            "tau": tau,
            "rounds": rounds,
            "rounds_to_eq": hit,
            "bytes_to_eq": (int(per_round[:hit].sum())
                            if hit is not None else None),
            "final_rel_error": final,
            "diverged": bool(not np.isfinite(final) or final > 1e3),
            "bytes_per_round": int(per_round[0]),
        })
    us = (time.perf_counter() - t0) * 1e6 / len(rows)

    def _fmt(row):
        tag = "DIV" if row["diverged"] else f"{row['final_rel_error']:.1e}"
        return (f"{row['update']}x{row['policy']}xgs{row['gossip_steps']}:"
                f"R={row['rounds_to_eq']},err={tag}")

    emit("engine_gossip_policy", us, ";".join(_fmt(r) for r in rows))
    return rows


def main() -> None:
    import argparse

    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--tau", type=int, default=4)
    parser.add_argument("--rounds", type=int, default=800)
    parser.add_argument("--topology-rounds", type=int, default=4000)
    parser.add_argument("--policy-rounds", type=int, default=4000,
                        help="budget for the gossip policy/extragradient "
                             "sweep (spectral sgd needs ~2700 rounds)")
    parser.add_argument("--json", type=str, default=None, metavar="PATH",
                        help="write the sweeps as structured JSON "
                             "(BENCH_*.json convention for tracking)")
    args = parser.parse_args()

    matrix = run(tau=args.tau, rounds=args.rounds)
    topo = run_topologies(rounds=args.topology_rounds)
    gossip_policy = run_gossip_policies(rounds=args.policy_rounds)
    if args.json:
        payload = {
            "benchmark": "bench_engine",
            "matrix": [
                {"update": u, "sync": s, "rel_error": float(e),
                 "total_bytes": int(b)} for u, s, e, b in matrix
            ],
            "topology": topo,
            "gossip_policy": gossip_policy,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {args.json}", flush=True)


if __name__ == "__main__":
    main()
