"""Engine matrix sweep: update-rule x sync-strategy on the quadratic game.

One row per (update, sync) cell: final relative error after a fixed
communication budget plus the engine's per-round byte accounting — the
"handle every scenario" demonstration that each paper variant and each
beyond-paper communication regime is a constructor argument, not a new
scan loop.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import stepsize
from repro.core.engine import (
    DropoutSync,
    ExactSync,
    ExtragradientUpdate,
    HeavyBallUpdate,
    OptimisticGradientUpdate,
    PartialParticipation,
    PearlEngine,
    QuantizedSync,
    SgdUpdate,
)
from repro.core.games import make_quadratic_game


UPDATES = {
    "sgd": SgdUpdate(),
    "eg": ExtragradientUpdate(),
    "ogda": OptimisticGradientUpdate(),
    "hb": HeavyBallUpdate(beta=0.5),
}

SYNCS = {
    "exact": ExactSync(),
    "bf16": QuantizedSync(jnp.bfloat16),
    "partial": PartialParticipation(fraction=0.5, seed=0),
    "dropout": DropoutSync(p=0.1, seed=0),
}


def run(tau: int = 4, rounds: int = 800):
    game = make_quadratic_game(n=5, d=10, M=40, batch_size=1, seed=0)
    c = game.constants()
    gamma = stepsize.gamma_constant(c, tau)
    x0 = jnp.asarray(
        np.random.default_rng(0).standard_normal((game.n, game.d)),
        dtype=jnp.float32,
    )

    rows = []
    t0 = time.perf_counter()
    for uname, update in UPDATES.items():
        for sname, sync in SYNCS.items():
            r = PearlEngine(update=update, sync=sync).run(
                game, x0, tau=tau, rounds=rounds, gamma=gamma,
                key=jax.random.PRNGKey(0), stochastic=False,
            )
            rows.append((uname, sname, r.rel_errors[-1], r.total_bytes))
    us = (time.perf_counter() - t0) * 1e6 / len(rows)

    derived = ";".join(
        f"{u}x{s}:err={e:.2e},KB={b / 1e3:.0f}" for u, s, e, b in rows
    )
    emit("engine_matrix", us, derived)
    return rows


if __name__ == "__main__":
    run()
