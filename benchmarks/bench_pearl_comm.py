"""PEARL at model scale: communication bytes vs accuracy for neural players.

The production claim (DESIGN.md Section 3): on the pod-mapped consensus game,
tau local steps per sync cut cross-pod traffic by tau at (near-)equal loss.
This CPU-scale benchmark trains the reduced smollm players for a fixed number
of LOCAL STEPS under different tau and reports (loss, sync bytes).
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.configs import get_config
from repro.data.synthetic import DataConfig, SyntheticTokenStream
from repro.optim.optimizers import sgd
from repro.roofline.analysis import count_params
from repro.train.pearl_trainer import PearlCommReport, PearlTrainer


def run(local_steps: int = 24, n_players: int = 2):
    cfg = get_config("smollm-360m").smoke_variant()
    stream = SyntheticTokenStream(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=64, batch_size=4,
        n_players=n_players, seed=0,
    ))
    from repro.models.model import param_shapes

    n_params = count_params(param_shapes(cfg))

    rows = []
    t0 = time.perf_counter()
    for tau in (1, 4, 8):
        trainer = PearlTrainer(cfg, sgd(5e-2), n_players=n_players, tau=tau,
                               prox_lambda=1e-3, seed=0)
        hist = trainer.run(stream, rounds=local_steps // tau)
        loss = np.mean([h["lm_loss"] for h in hist[-2:]])
        rep = PearlCommReport(n_players=n_players, param_count=n_params,
                              tau=tau, rounds=local_steps // tau)
        rows.append((tau, loss, rep.total_bytes))
    us = (time.perf_counter() - t0) * 1e6 / 3

    base = rows[0]
    derived = ";".join(
        f"tau{t}:loss={l:.4f},syncMB={b / 1e6:.1f},bytes_saved={base[2] / b:.0f}x"
        for t, l, b in rows
    )
    emit("pearl_comm_vs_accuracy", us, derived)
    return rows


if __name__ == "__main__":
    run()
